"""Distributed-correctness tests: run a subprocess with 8 virtual host
devices and check that sharded execution (FSDP x TP mesh, including the
shard_map expert-parallel MoE) is NUMERICALLY IDENTICAL to unsharded
execution, and that the sharding rule table produces sane specs."""

import os
import subprocess
import sys
import textwrap

import pytest

from jax.sharding import PartitionSpec

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch
    from repro.models.model import forward, model_def
    from repro.models.param import materialize, logical_axes
    from repro.sharding import tree_shardings, spec_for
    from repro.compat import activate_mesh, make_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    assert len(jax.devices()) == 8, jax.devices()
    arch = os.environ["TEST_ARCH"]
    cfg = get_arch(arch).smoke
    if cfg.family == "moe":
        # capacity is computed per token-shard: make it generous so NO tokens
        # drop in either execution and outputs must match exactly (default
        # 1.25 keeps drop semantics for perf runs)
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    pdefs = model_def(cfg)
    params = materialize(pdefs, jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)

    # unsharded reference (single device semantics)
    ref = forward(params, {"tokens": toks}, cfg)

    mesh = make_mesh((2, 4), ("data", "model"))
    with activate_mesh(mesh):
        p_sh = tree_shardings(logical_axes(pdefs), params, mesh)
        params_s = jax.device_put(params, p_sh)
        toks_s = jax.device_put(
            toks, NamedSharding(mesh, spec_for(["batch", None],
                                               toks.shape, mesh)))
        out = jax.jit(lambda p, t: forward(p, {"tokens": t}, cfg))(
            params_s, toks_s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    print("DISTRIBUTED_OK", arch)
""")


@pytest.mark.parametrize("arch", ["gemma-2b", "granite-moe-3b-a800m",
                                  "mamba2-2.7b", "recurrentgemma-9b"])
def test_sharded_equals_unsharded(arch):
    env = dict(os.environ, TEST_ARCH=arch,
               PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert f"DISTRIBUTED_OK {arch}" in res.stdout


def test_spec_for_drops_nondivisible():
    from repro.compat import make_mesh
    from repro.sharding import spec_for
    mesh = make_mesh((1, 1), ("data", "model"))
    # size-1 mesh axes -> everything replicated
    spec = spec_for(("embed", "heads"), (64, 8), mesh)
    assert spec == PartitionSpec(None, None)


def test_spec_for_rules():
    from repro.sharding import spec_for

    class FakeMesh:
        shape = {"data": 4, "model": 2}
    mesh = FakeMesh()
    assert spec_for(("embed", "ff"), (64, 64), mesh) == \
        PartitionSpec("data", "model")
    # kv_heads = 1 (MQA) is not divisible by model=2 -> dropped
    assert spec_for(("embed", "kv_heads"), (64, 1), mesh) == \
        PartitionSpec("data", None)
    # batch maps to the (pod, data) group; pod absent -> data only
    assert spec_for(("batch", None), (8, 16), mesh) == \
        PartitionSpec("data", None)

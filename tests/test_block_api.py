"""The block-entry API redesign: symmetric ``block(x, params, *, cfg,
mesh, pin, in_layout) -> (y, out_layout)`` signatures, the ``SchedulePin``
axis object, and the warn-once deprecation shims covering every legacy
spelling (positional params-first order, the ``kcfg=`` kwarg, the
per-axis ``ConvKernelConfig`` fields)."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SchedulePin, resolve_pin, set_kernel_config
from repro.configs.base import ConvKernelConfig, _WARNED
from repro.models.common import separable_block, separable_def
from repro.models.mbconv import mbconv_block, mbconv_def
from repro.models.param import materialize

KCFG = ConvKernelConfig(interpret=True)


@pytest.fixture(autouse=True)
def _fresh_warn_state():
    """Each case sees the warn-once shims unfired."""
    saved = set(_WARNED)
    _WARNED.clear()
    yield
    _WARNED.clear()
    _WARNED.update(saved)


def _mbconv_fixture(rng_key=0, ci=8, co=8):
    params = materialize(mbconv_def(ci, co, k=3, expand_ratio=2),
                         jax.random.key(rng_key))
    x = jnp.asarray(np.random.default_rng(rng_key).normal(
        size=(2, 9, 9, ci)), jnp.float32)
    return x, params


def test_new_signature_returns_layout_tuple():
    x, params = _mbconv_fixture()
    with warnings.catch_warnings():
        warnings.simplefilter("error")         # new spelling: silent
        out = mbconv_block(x, params, stride=1, cfg=KCFG)
    y, lay = out
    assert y.shape == x.shape
    assert lay == "replicated"                 # no mesh: nothing sharded

    sparams = materialize(separable_def(8, 16), jax.random.key(1))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ys, lays = separable_block(x, sparams, stride=1, cfg=KCFG)
    assert ys.shape == (2, 9, 9, 16)
    assert lays == "replicated"


def test_legacy_positional_order_warns_once_and_returns_bare_array():
    x, params = _mbconv_fixture()
    want, _ = mbconv_block(x, params, stride=1, cfg=KCFG)
    with pytest.warns(DeprecationWarning, match="mbconv_block"):
        got = mbconv_block(params, x, stride=1, cfg=KCFG)
    assert isinstance(got, jax.Array)          # bare array, no tuple
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    with warnings.catch_warnings():
        warnings.simplefilter("error")         # second call: warned already
        again = mbconv_block(params, x, stride=1, cfg=KCFG)
    np.testing.assert_allclose(again, want, rtol=1e-5, atol=1e-5)


def test_legacy_positional_separable_warns_once():
    x, _ = _mbconv_fixture()
    sparams = materialize(separable_def(8, 16), jax.random.key(1))
    want, _ = separable_block(x, sparams, stride=1, cfg=KCFG)
    with pytest.warns(DeprecationWarning, match="separable_block"):
        got = separable_block(sparams, x, stride=1, cfg=KCFG)
    assert isinstance(got, jax.Array)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_kcfg_kwarg_aliases_cfg_with_warning():
    x, params = _mbconv_fixture()
    want, _ = mbconv_block(x, params, stride=1, cfg=KCFG)
    with pytest.warns(DeprecationWarning, match="kcfg"):
        got, lay = mbconv_block(x, params, stride=1, kcfg=KCFG)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert lay == "replicated"


def test_schedule_pin_merge_and_layout_sugar():
    explicit = SchedulePin(mode="retain", layout="model_sharded")
    base = SchedulePin(mode="recompute", residency="strip_dma")
    merged = explicit.merged_over(base)
    assert merged.mode == "retain"             # explicit wins
    assert merged.residency == "strip_dma"     # base fills the gap
    # the layout axis is sugar over the collective
    assert merged.resolved_collective == "psum_scatter"
    assert SchedulePin(layout="replicated").resolved_collective \
        == "ring_allreduce"
    assert SchedulePin(collective="psum_scatter").resolved_collective \
        == "psum_scatter"
    assert SchedulePin().resolved_collective is None
    with pytest.raises(ValueError, match="pin conflict"):
        _ = SchedulePin(collective="ring_allreduce",
                        layout="model_sharded").resolved_collective


def test_resolve_pin_precedence():
    """Explicit pin > cfg.pin > legacy per-axis config fields."""
    cfg = ConvKernelConfig(mbconv_mode="retain", residency="resident",
                           pin=SchedulePin(mode="recompute"))
    eff = resolve_pin(cfg, family="mbconv")
    assert eff.mode == "recompute"             # cfg.pin beats legacy field
    assert eff.residency == "resident"         # legacy fills unpinned axis
    eff2 = resolve_pin(cfg, pin=SchedulePin(mode="retain"))
    assert eff2.mode == "retain"               # call-site pin beats both
    # the fused toggle resolves per family
    cfg2 = ConvKernelConfig(fused_separable=False, fused_mbconv=True)
    assert resolve_pin(cfg2, family="separable").fused is False
    assert resolve_pin(cfg2, family="mbconv").fused is True


def test_set_kernel_config_legacy_fields_warn_once():
    try:
        with pytest.warns(DeprecationWarning, match="SchedulePin"):
            set_kernel_config(residency="resident")
        with warnings.catch_warnings():
            warnings.simplefilter("error")     # warned once, stays quiet
            set_kernel_config(collective="ring_allreduce")
            set_kernel_config(pin=SchedulePin(residency="strip_dma"))
    finally:
        set_kernel_config()                    # restore defaults


def test_pin_kwarg_steers_the_block():
    """A pin that forces the staged (non-fused) path must change the
    routing but not the math."""
    x, params = _mbconv_fixture()
    fused, _ = mbconv_block(x, params, stride=1, cfg=KCFG)
    staged, lay = mbconv_block(x, params, stride=1, cfg=KCFG,
                               pin=SchedulePin(fused=False))
    assert lay == "replicated"
    np.testing.assert_allclose(staged, fused, rtol=1e-4, atol=1e-4)

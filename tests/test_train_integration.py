"""End-to-end training integration: loss descends, checkpoints restore
bit-exactly, elastic restore works onto a different mesh, SIGTERM-style
emergency save works, optimizer variants behave."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, DataState, make_batch
from repro.launch.mesh import make_local_mesh
from repro.launch.train import Trainer
from repro.models.model import ModelConfig
from repro.train import checkpoint as ckpt
from repro.train.optim import (
    OptimConfig, compress_int8, decompress_int8, make_optimizer,
)
from repro.train.step import TrainConfig, make_train_step

CFG = ModelConfig(name="ti", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab=64, dtype="float32")


def _mk_trainer(tmp, steps_lr=200, microbatches=1, **opt_kw):
    dcfg = DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=8)
    tcfg = TrainConfig(
        optim=OptimConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=steps_lr,
                          **opt_kw),
        microbatches=microbatches)
    return Trainer(CFG, tcfg, dcfg, ckpt_dir=tmp, mesh=None)


def test_loss_descends(tmp_path):
    tr = _mk_trainer(str(tmp_path))
    losses = tr.run(steps=30, ckpt_every=0, log_every=0)
    assert losses[-1] < losses[0] * 0.9, (losses[0], losses[-1])


def test_checkpoint_resume_exact(tmp_path):
    d = str(tmp_path / "ck")
    tr = _mk_trainer(d)
    tr.run(steps=10, ckpt_every=5, log_every=0)
    # continue to 15 from the step-10 checkpoint in a fresh trainer
    tr2 = _mk_trainer(d)
    assert tr2.maybe_restore() and tr2.step == 10
    losses_resumed = tr2.run(steps=15, ckpt_every=0, log_every=0)

    # reference: train 15 straight without interruption
    tr3 = _mk_trainer(str(tmp_path / "ref"))
    losses_straight = tr3.run(steps=15, ckpt_every=0, log_every=0)
    np.testing.assert_allclose(losses_resumed[-1], losses_straight[-1],
                               rtol=1e-5, atol=1e-6)


def test_elastic_restore_different_mesh(tmp_path):
    """Save without a mesh, restore onto a local mesh (and vice versa)."""
    d = str(tmp_path / "ck")
    tr = _mk_trainer(d)
    tr.run(steps=3, ckpt_every=3, log_every=0)

    mesh = make_local_mesh()
    dcfg = DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=8)
    tcfg = TrainConfig(optim=OptimConfig(peak_lr=3e-3, warmup_steps=5,
                                         decay_steps=200))
    tr2 = Trainer(CFG, tcfg, dcfg, ckpt_dir=d, mesh=mesh)
    assert tr2.maybe_restore() and tr2.step == 3
    losses = tr2.run(steps=6, ckpt_every=0, log_every=0)
    assert np.isfinite(losses).all()


def test_emergency_save_on_sigterm_flag(tmp_path):
    d = str(tmp_path / "ck")
    tr = _mk_trainer(d)
    tr._sigterm = True                      # simulate SIGTERM delivery
    tr.run(steps=50, ckpt_every=0, log_every=0)
    assert ckpt.latest_step(d) == 1         # saved at first boundary, exited


def test_atomic_checkpoint_publish(tmp_path):
    d = str(tmp_path / "ck")
    tr = _mk_trainer(d)
    tr.run(steps=2, ckpt_every=2, log_every=0)
    entries = os.listdir(d)
    assert all(not e.startswith(".tmp") for e in entries), entries


def test_microbatch_accumulation_matches_full_batch():
    dcfg = DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=8)
    batch_np, _ = make_batch(dcfg, DataState(seed=1, step=0))
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}

    from repro.models.param import materialize
    from repro.models.model import model_def
    params = materialize(model_def(CFG), jax.random.key(0))

    outs = {}
    for n_micro in (1, 2, 4):
        tcfg = TrainConfig(optim=OptimConfig(peak_lr=1e-3, clip_norm=1e9),
                           microbatches=n_micro)
        init_opt, train_step = make_train_step(CFG, tcfg)
        opt = init_opt(params)
        new_p, _, m = jax.jit(train_step)(params, opt, batch)
        outs[n_micro] = (m["loss"], new_p)
    np.testing.assert_allclose(outs[1][0], outs[2][0], rtol=1e-4)
    l1 = jax.tree.leaves(outs[1][1])
    for a, b in zip(l1, jax.tree.leaves(outs[4][1])):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-5)


def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    err = jnp.zeros_like(g)
    # repeated compression of a CONSTANT gradient: error feedback makes the
    # running mean of dequantized grads converge to the true gradient
    total = jnp.zeros_like(g)
    for i in range(32):
        q, s, err = compress_int8(g, err)
        total = total + decompress_int8(q, s)
    mean = total / 32
    rel = float(jnp.abs(mean - g).max() / jnp.abs(g).max())
    assert rel < 0.02, rel


def test_compressed_training_descends(tmp_path):
    tr = _mk_trainer(str(tmp_path), compress_grads=True)
    losses = tr.run(steps=25, ckpt_every=0, log_every=0)
    assert losses[-1] < losses[0] * 0.95


def test_factored_second_moment_descends(tmp_path):
    tr = _mk_trainer(str(tmp_path), factored=True)
    losses = tr.run(steps=25, ckpt_every=0, log_every=0)
    assert losses[-1] < losses[0] * 0.95


def test_factored_state_is_smaller():
    from repro.models.param import materialize
    from repro.models.model import model_def
    params = materialize(model_def(CFG), jax.random.key(0))

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    full = make_optimizer(OptimConfig(factored=False))[0](params)
    fact = make_optimizer(OptimConfig(factored=True))[0](params)
    assert nbytes(fact.v) < 0.2 * nbytes(full.v)


def test_step_retry_on_transient_failure(tmp_path, monkeypatch):
    tr = _mk_trainer(str(tmp_path))
    real_step = tr.train_step
    calls = {"n": 0}

    def flaky(*a, **k):
        calls["n"] += 1
        if calls["n"] == 2:              # fail once on the second step
            raise RuntimeError("transient host failure")
        return real_step(*a, **k)

    tr.train_step = flaky
    losses = tr.run(steps=3, ckpt_every=0, max_retries=2, log_every=0)
    assert len(losses) == 3 and calls["n"] == 4  # 3 ok + 1 failed attempt

"""Distributed parity + collective battery for the SHARDED fused ConvDK
paths (``kernels.convdk_sharded``) under the 8-virtual-device harness.

Every case proves the same three-way equality the single-device suite
proves, but under ``shard_map`` partitioning (batch on "data", the channel
grid on "model") across mesh shapes (8,1), (4,2), (2,4):

    sharded fused == single-device fused == staged kernel == lax oracle

plus the collective-structure assertions the numerics alone cannot make:
the MBConv SE pool crosses devices via exactly the modeled psums (counted
by intercepting ``jax.lax.psum``), and the separable sharding is
collective-free.

Execution model: when this process already has >= 8 devices (the
dedicated CI step sets ``XLA_FLAGS=--xla_force_host_platform_device_count
=8`` before pytest starts) each case runs IN-PROCESS and fails loudly.
Otherwise — the plain tier-1 run, where jax is already initialized with
one device — the same script body runs in a subprocess with the flag set,
so the battery is never silently skipped.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HAVE_8 = jax.device_count() >= 8

MESHES = ["8x1", "4x2", "2x4"]

_PREAMBLE = textwrap.dedent("""
    import os
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh
    from repro.kernels import (
        convdk_fused_separable, convdk_fused_separable_sharded,
        convdk_mbconv_fused, convdk_mbconv_fused_sharded,
        convdk_mbconv_staged, convdk_separable_staged, mbconv_ref,
        separable_ref,
    )

    assert jax.device_count() >= 8, jax.devices()
    TOL = dict(rtol=1e-4, atol=1e-4)

    def rand(rng, shape, scale=1.0):
        return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)

    def mbconv_params(rng, c_in, expand, c_out, k, se_ratio=0.25):
        c_mid = c_in * expand
        c_se = max(1, int(c_in * se_ratio))
        if expand == 1:
            w_exp, exp_act = jnp.eye(c_mid, dtype=jnp.float32), None
        else:
            w_exp, exp_act = rand(rng, (c_in, c_mid)), "silu"
        return (w_exp, rand(rng, (k, k, c_mid), 0.3),
                rand(rng, (c_mid, c_se)), rand(rng, (c_se,), 0.1),
                rand(rng, (c_se, c_mid)), rand(rng, (c_mid,), 0.1),
                rand(rng, (c_mid, c_out))), exp_act

    def parse_mesh(text):
        dp, mp = (int(t) for t in text.split("x"))
        return make_mesh((dp, mp), ("data", "model"))
""")


def run_case(body: str) -> None:
    src = _PREAMBLE + textwrap.dedent(body)
    if HAVE_8:
        exec(compile(src, "<distributed-fused-case>", "exec"),
             {"__name__": "__distributed_fused__"})
        return
    # barrier forced on (the pre-probe default, harmless on any build) so
    # the per-case subprocesses skip the ~6 s residual-forwarding probe;
    # the probe regression test calls residual_forwarding_probe()
    # directly, which probes regardless of the mode
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    env.setdefault("CONVDK_RESIDUAL_BARRIER", "on")
    res = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]


# ---------------------------------------------------------------------------
# parity sweeps: sharded == single-device fused == staged == lax oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh", MESHES)
def test_sharded_separable_parity(mesh):
    """Separable: batch on "data", c_out on "model", across k x s."""
    run_case(f"""
    mesh = parse_mesh("{mesh}")
    rng = np.random.default_rng(0)
    b, h, w_in, ci, co = 8, 9, 9, 8, 16
    x = rand(rng, (b, h, w_in, ci))
    for k in (3, 5):
        w_dw = rand(rng, (k, k, ci), 0.3)
        w_pw = rand(rng, (ci, co))
        for s in (1, 2):
            got = convdk_fused_separable_sharded(
                x, w_dw, w_pw, mesh=mesh, stride=s, tile_h=3,
                dw_act="relu", act="relu6", interpret=True)
            single = convdk_fused_separable(
                x, w_dw, w_pw, stride=s, tile_h=3, dw_act="relu",
                act="relu6", interpret=True)
            staged = convdk_separable_staged(
                x, w_dw, w_pw, stride=s, tile_h=3, dw_act="relu",
                act="relu6", interpret=True)
            want = separable_ref(x, w_dw, w_pw, stride=s, dw_act="relu",
                                 act="relu6")
            assert got.shape == want.shape, (got.shape, want.shape)
            np.testing.assert_allclose(got, single, err_msg=f"k{{k}}s{{s}}",
                                       **TOL)
            np.testing.assert_allclose(got, staged, err_msg=f"k{{k}}s{{s}}",
                                       **TOL)
            np.testing.assert_allclose(got, want, err_msg=f"k{{k}}s{{s}}",
                                       **TOL)
    print("SEPARABLE_PARITY_OK {mesh}")
    """)


@pytest.mark.parametrize("mesh", MESHES)
def test_sharded_mbconv_parity(mesh):
    """MBConv: batch on "data", c_mid on "model", across k x s and BOTH
    pass-2 modes — retain and recompute exercise the psum'd pool on each
    side of the crossover."""
    run_case(f"""
    mesh = parse_mesh("{mesh}")
    rng = np.random.default_rng(1)
    b, h, w_in, ci, e, co = 8, 9, 9, 8, 2, 16
    x = rand(rng, (b, h, w_in, ci))
    for k in (3, 5):
        weights, exp_act = mbconv_params(rng, ci, e, co, k)
        for s in (1, 2):
            want = mbconv_ref(x, *weights, stride=s)
            single = convdk_mbconv_fused(x, *weights, stride=s, tile_h=3,
                                         interpret=True)
            staged = convdk_mbconv_staged(x, *weights, stride=s, tile_h=3,
                                          interpret=True)
            for mode in ("retain", "recompute"):
                got = convdk_mbconv_fused_sharded(
                    x, *weights, mesh=mesh, stride=s, tile_h=3, mode=mode,
                    interpret=True)
                tag = f"k{{k}}s{{s}}{{mode}}"
                assert got.shape == want.shape, (got.shape, want.shape)
                np.testing.assert_allclose(got, single, err_msg=tag, **TOL)
                np.testing.assert_allclose(got, staged, err_msg=tag, **TOL)
                np.testing.assert_allclose(got, want, err_msg=tag, **TOL)
    print("MBCONV_PARITY_OK {mesh}")
    """)


def test_sharded_mbconv_expand_ratio_one():
    """MBConv1 (identity expand) shards c_mid == c_in on "model": the
    identity column slice selects each shard's input channels."""
    run_case("""
    mesh = parse_mesh("2x4")
    rng = np.random.default_rng(2)
    ci = co = 16
    x = rand(rng, (8, 9, 9, ci))
    weights, exp_act = mbconv_params(rng, ci, 1, co, 3)
    assert exp_act is None
    want = mbconv_ref(x, *weights, stride=1, exp_act=None)
    for mode in ("retain", "recompute"):
        got = convdk_mbconv_fused_sharded(
            x, *weights, mesh=mesh, stride=1, tile_h=3, mode=mode,
            exp_act=None, interpret=True)
        np.testing.assert_allclose(got, want, err_msg=mode, **TOL)
    print("MBCONV1_SHARDED_OK")
    """)


@pytest.mark.parametrize("mesh", ["4x2", "2x4"])
def test_sharded_mbconv_psum_scatter_parity(mesh):
    """The psum_scatter pass-2 variant over k{3,5} x s{1,2}: the
    (c_out-sharded, then implicitly gathered) global output equals the
    ring variant, the single-device kernel and the lax oracle — and the
    returned array really is sharded on c_out across "model"."""
    run_case(f"""
    mesh = parse_mesh("{mesh}")
    mp = mesh.shape["model"]
    rng = np.random.default_rng(7)
    b, h, w_in, ci, e, co = 8, 9, 9, 8, 2, 16
    x = rand(rng, (b, h, w_in, ci))
    for k in (3, 5):
        weights, exp_act = mbconv_params(rng, ci, e, co, k)
        for s in (1, 2):
            want = mbconv_ref(x, *weights, stride=s)
            single = convdk_mbconv_fused(x, *weights, stride=s, tile_h=3,
                                         interpret=True)
            for mode in ("retain", "recompute"):
                ring = convdk_mbconv_fused_sharded(
                    x, *weights, mesh=mesh, stride=s, tile_h=3, mode=mode,
                    interpret=True, collective="ring_allreduce")
                scat = convdk_mbconv_fused_sharded(
                    x, *weights, mesh=mesh, stride=s, tile_h=3, mode=mode,
                    interpret=True, collective="psum_scatter")
                tag = f"k{{k}}s{{s}}{{mode}}"
                assert scat.shape == want.shape, (scat.shape, want.shape)
                np.testing.assert_allclose(scat, ring, err_msg=tag, **TOL)
                np.testing.assert_allclose(scat, single, err_msg=tag, **TOL)
                np.testing.assert_allclose(scat, want, err_msg=tag, **TOL)
                # the layout-aware exit: output sharded on c_out
                spec = scat.sharding.spec
                assert spec[-1] == "model", spec
    print("PSUM_SCATTER_PARITY_OK {mesh}")
    """)


def test_sharded_mbconv_psum_scatter_pads_indivisible():
    """c_out that does not divide the model axis no longer refuses: the
    projection partial is zero-padded to round_up(c_out, mp) columns,
    scattered at the padded width, and the global view sliced back — so
    the scatter variant covers EVERY layer, matching the ring variant and
    the lax oracle bit-for-tolerance on c_out 18 over mp 4."""
    run_case("""
    mesh = parse_mesh("2x4")
    rng = np.random.default_rng(8)
    weights, _ = mbconv_params(rng, 8, 2, 18, 3)   # c_out 18 % 4 != 0
    x = rand(rng, (8, 9, 9, 8))
    want = mbconv_ref(x, *weights, stride=1)
    ring = convdk_mbconv_fused_sharded(x, *weights, mesh=mesh, stride=1,
                                       tile_h=3, interpret=True)
    scat = convdk_mbconv_fused_sharded(x, *weights, mesh=mesh, stride=1,
                                       tile_h=3, interpret=True,
                                       collective="psum_scatter")
    assert scat.shape == (8, 9, 9, 18), scat.shape
    np.testing.assert_allclose(scat, ring, **TOL)
    np.testing.assert_allclose(scat, want, **TOL)
    print("PSUM_SCATTER_PAD_OK")
    """)


@pytest.mark.parametrize("mesh", ["4x2", "2x4"])
def test_sharded_input_layout_entry_variants(mesh):
    """``in_layout="model_sharded"`` entry variants against the oracle:
    the e>1 gather entry (all-gather c_in, then the dense expand), the
    e==1 free entry (identity expand consumes the local c_in slice with
    NO entry collective), and the sharded-in separable (partial pointwise
    over local c_in rows, psum/psum_scatter exit)."""
    run_case(f"""
    mesh = parse_mesh("{mesh}")
    rng = np.random.default_rng(12)
    b, h, w_in, ci, co = 8, 9, 9, 8, 16

    # e > 1: gather entry — sharded arrival, dense expand needs all c_in
    x = rand(rng, (b, h, w_in, ci))
    weights, _ = mbconv_params(rng, ci, 2, co, 3)
    want = mbconv_ref(x, *weights, stride=1)
    got = convdk_mbconv_fused_sharded(
        x, *weights, mesh=mesh, stride=1, tile_h=3, interpret=True,
        in_layout="model_sharded")
    np.testing.assert_allclose(got, want, err_msg="gather-entry", **TOL)

    # e == 1: free entry — identity expand on the local slice
    xi = rand(rng, (b, h, w_in, co))
    weights1, exp_act = mbconv_params(rng, co, 1, co, 3)
    assert exp_act is None
    want1 = mbconv_ref(xi, *weights1, stride=1, exp_act=None)
    got1 = convdk_mbconv_fused_sharded(
        xi, *weights1, mesh=mesh, stride=1, tile_h=3, interpret=True,
        exp_act=None, in_layout="model_sharded")
    np.testing.assert_allclose(got1, want1, err_msg="free-entry", **TOL)

    # separable sharded-in: partial pointwise + scatter/psum exit
    w_dw = rand(rng, (3, 3, ci), 0.3)
    w_pw = rand(rng, (ci, co))
    wantd = separable_ref(x, w_dw, w_pw, stride=1, dw_act="relu",
                          act="relu6")
    for coll in ("ring_allreduce", "psum_scatter"):
        gotd = convdk_fused_separable_sharded(
            x, w_dw, w_pw, mesh=mesh, stride=1, tile_h=3, dw_act="relu",
            act="relu6", interpret=True, in_layout="model_sharded",
            collective=coll)
        np.testing.assert_allclose(gotd, wantd, err_msg=coll, **TOL)
    print("SHARDED_IN_PARITY_OK {mesh}")
    """)


def test_pod_axis_is_pure_data_parallelism():
    """A ("pod", "data", "model") mesh routes instead of raising/falling
    back: batch shards over pod*data jointly, parity holds for both
    families and both collectives, and the model layer routes through the
    sharded wrappers on the pod mesh."""
    run_case("""
    from repro.configs.base import ConvKernelConfig
    from repro.kernels import can_shard_fused, conv_mesh_shape
    from repro.models.mbconv import mbconv_block, mbconv_def
    from repro.models.param import materialize

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    assert conv_mesh_shape(mesh) == (4, 2)
    assert can_shard_fused(mesh, batch=8, channels=16)
    assert not can_shard_fused(mesh, batch=6, channels=16)  # 6 % 4 != 0

    rng = np.random.default_rng(9)
    b, ci, e, co, k = 8, 8, 2, 16, 3
    x = rand(rng, (b, 9, 9, ci))
    w_dw = rand(rng, (k, k, ci), 0.3)
    w_pw = rand(rng, (ci, co))
    got = convdk_fused_separable_sharded(x, w_dw, w_pw, mesh=mesh,
                                         stride=1, tile_h=3, interpret=True)
    np.testing.assert_allclose(got, separable_ref(x, w_dw, w_pw, stride=1),
                               **TOL)

    weights, _ = mbconv_params(rng, ci, e, co, k)
    want = mbconv_ref(x, *weights, stride=1)
    for coll in ("ring_allreduce", "psum_scatter"):
        got = convdk_mbconv_fused_sharded(
            x, *weights, mesh=mesh, stride=1, tile_h=3, interpret=True,
            collective=coll)
        np.testing.assert_allclose(got, want, err_msg=coll, **TOL)

    # model-layer routing on the pod mesh matches the mesh-free output
    kcfg = ConvKernelConfig(interpret=True)
    params = materialize(mbconv_def(16, 16, k=3, expand_ratio=2),
                         jax.random.key(0))
    xb = rand(rng, (8, 9, 9, 16))
    np.testing.assert_allclose(
        mbconv_block(params, xb, stride=1, kcfg=kcfg, mesh=mesh),
        mbconv_block(params, xb, stride=1, kcfg=kcfg), **TOL)
    print("POD_AXIS_OK")
    """)


# ---------------------------------------------------------------------------
# collective structure: the SE pool crosses devices via psum — asserted by
# intercepting the collective, not by numerics
# ---------------------------------------------------------------------------

def test_mbconv_pool_psum_intercepted():
    """Intercept ``jax.lax.psum`` during the sharded MBConv trace: exactly
    two collectives over "model" — the (B_local, C_se) SE squeeze partial
    (the pass-1 pool leaving the chip before the pass-2 gate) and the
    (B_local, H', W', C_out) projection partial — in BOTH pass-2 modes,
    while the separable sharding stays collective-free."""
    run_case("""
    # the interception counts psums at TRACE time — drop the cached jitted
    # entry points so this case traces fresh instead of reusing a trace an
    # earlier test already built (the no-retrace behavior under test in
    # test_staging.py::test_sharded_entry_point_traces_once), and settle
    # the residual-barrier decision FIRST so the probe's own tiny sharded
    # grad cannot run (and get counted) inside the interception window
    # (residual_barrier_needed skips the probe when the mode is forced)
    from repro import compat
    from repro.kernels.convdk_sharded import (
        _mbconv_sharded_entry, _sep_sharded_entry)
    compat.residual_barrier_needed()
    _mbconv_sharded_entry.cache_clear()
    _sep_sharded_entry.cache_clear()
    mesh = parse_mesh("2x4")
    rng = np.random.default_rng(3)
    b, h, w_in, ci, e, co, k, s = 8, 9, 9, 8, 2, 16, 3, 1
    cse = max(1, ci // 4)
    x = rand(rng, (b, h, w_in, ci))
    weights, _ = mbconv_params(rng, ci, e, co, k)
    want = mbconv_ref(x, *weights, stride=s)

    calls = []
    orig_psum = jax.lax.psum

    def counting_psum(val, axis_name, **kw):
        calls.append((jnp.shape(val), axis_name))
        return orig_psum(val, axis_name, **kw)

    jax.lax.psum = counting_psum
    try:
        for mode in ("retain", "recompute"):
            calls.clear()
            got = convdk_mbconv_fused_sharded(
                x, *weights, mesh=mesh, stride=s, tile_h=3, mode=mode,
                interpret=True)
            np.testing.assert_allclose(got, want, err_msg=mode,
                                       rtol=1e-4, atol=1e-4)
            model_calls = [c for c in calls if c[1] == "model"]
            assert len(model_calls) == 2, (mode, calls)
            squeeze, proj = model_calls
            # psum #1: the pooled SE squeeze partial, one tiny vector per
            # batch-shard row — the pool's ONLY trip off-chip
            assert squeeze[0] == (b // 2, cse), (mode, squeeze)
            # psum #2: the projection partials over the c_mid shards
            assert proj[0] == (b // 2, h, w_in, co), (mode, proj)

        # the separable partitioning (c_out on "model") must stay
        # collective-free: its c_in reduction is device-local
        calls.clear()
        w_dw = rand(rng, (3, 3, ci), 0.3)
        w_pw = rand(rng, (ci, co))
        out = convdk_fused_separable_sharded(
            x, w_dw, w_pw, mesh=mesh, stride=1, tile_h=3, interpret=True)
        np.testing.assert_allclose(
            out, separable_ref(x, w_dw, w_pw, stride=1),
            rtol=1e-4, atol=1e-4)
        assert not calls, calls
    finally:
        jax.lax.psum = orig_psum
    print("PSUM_INTERCEPT_OK")
    """)


def test_mbconv_psum_scatter_intercepted():
    """Intercept both collectives during the scatter-variant trace:
    exactly ONE ``psum_scatter`` (the projection partial, over "model")
    and exactly one remaining ``psum`` (the SE squeeze — it must stay an
    all-reduce: the excite FC consumes it replicated), in both pass-2
    modes."""
    run_case("""
    # settle the residual-barrier decision BEFORE counting collectives —
    # the probe's own tiny sharded grad would otherwise run inside the
    # window (residual_barrier_needed skips it when the mode is forced)
    from repro import compat
    from repro.kernels.convdk_sharded import _mbconv_sharded_entry
    compat.residual_barrier_needed()
    _mbconv_sharded_entry.cache_clear()
    mesh = parse_mesh("2x4")
    rng = np.random.default_rng(10)
    b, h, w_in, ci, e, co, k, s = 8, 9, 9, 8, 2, 16, 3, 1
    cse = max(1, ci // 4)
    x = rand(rng, (b, h, w_in, ci))
    weights, _ = mbconv_params(rng, ci, e, co, k)
    want = mbconv_ref(x, *weights, stride=s)

    psums, scatters = [], []
    orig_psum, orig_scatter = jax.lax.psum, jax.lax.psum_scatter

    def counting_psum(val, axis_name, **kw):
        psums.append((jnp.shape(val), axis_name))
        return orig_psum(val, axis_name, **kw)

    def counting_scatter(val, axis_name, **kw):
        scatters.append((jnp.shape(val), axis_name))
        return orig_scatter(val, axis_name, **kw)

    jax.lax.psum, jax.lax.psum_scatter = counting_psum, counting_scatter
    try:
        for mode in ("retain", "recompute"):
            psums.clear(); scatters.clear()
            got = convdk_mbconv_fused_sharded(
                x, *weights, mesh=mesh, stride=s, tile_h=3, mode=mode,
                interpret=True, collective="psum_scatter")
            np.testing.assert_allclose(got, want, err_msg=mode,
                                       rtol=1e-4, atol=1e-4)
            model_scatters = [c for c in scatters if c[1] == "model"]
            model_psums = [c for c in psums if c[1] == "model"]
            assert len(model_scatters) == 1, (mode, scatters)
            assert len(model_psums) == 1, (mode, psums)
            # the scattered projection partial is the full per-shard
            # output block; the psum'd squeeze partial stays tiny
            assert model_scatters[0][0] == (b // 2, h, w_in, co), scatters
            assert model_psums[0][0] == (b // 2, cse), psums
    finally:
        jax.lax.psum, jax.lax.psum_scatter = orig_psum, orig_scatter
    print("PSUM_SCATTER_INTERCEPT_OK")
    """)


def test_chained_blocks_zero_intermediate_all_gather():
    """The network-level acceptance pair: an e>1 producer exiting via
    psum_scatter (c_out divides mp, so its output STAYS model-sharded)
    chained straight into an e==1 identity-expand consumer taking
    ``in_layout="model_sharded"`` through the free entry.  Intercepting
    all three collectives proves the boundary is crossed with ZERO
    all-gathers — the scatter saving is kept, not repaid at the next
    entry — while the chained output matches the single-device oracle
    composition."""
    run_case("""
    # settle the residual-barrier decision and drop cached entry traces so
    # the interception window sees exactly this chain's collectives
    from repro import compat
    from repro.kernels.convdk_sharded import (
        _mbconv_sharded_entry, _sep_sharded_entry)
    compat.residual_barrier_needed()
    _mbconv_sharded_entry.cache_clear()
    _sep_sharded_entry.cache_clear()
    mesh = parse_mesh("2x4")
    rng = np.random.default_rng(13)
    b, h, w_in, ci, e, cm = 8, 9, 9, 8, 2, 16
    x = rand(rng, (b, h, w_in, ci))
    wa, _ = mbconv_params(rng, ci, e, cm, 3)        # 8 -> 16, scatter exit
    wb, exp_act = mbconv_params(rng, cm, 1, cm, 3)  # 16 -> 16, free entry
    assert exp_act is None

    want = mbconv_ref(mbconv_ref(x, *wa, stride=1), *wb, stride=1,
                      exp_act=None)

    gathers, psums, scatters = [], [], []
    orig_ag = jax.lax.all_gather
    orig_psum, orig_scatter = jax.lax.psum, jax.lax.psum_scatter

    def counting_ag(val, axis_name, **kw):
        gathers.append((jnp.shape(val), axis_name))
        return orig_ag(val, axis_name, **kw)

    def counting_psum(val, axis_name, **kw):
        psums.append((jnp.shape(val), axis_name))
        return orig_psum(val, axis_name, **kw)

    def counting_scatter(val, axis_name, **kw):
        scatters.append((jnp.shape(val), axis_name))
        return orig_scatter(val, axis_name, **kw)

    jax.lax.all_gather = counting_ag
    jax.lax.psum, jax.lax.psum_scatter = counting_psum, counting_scatter
    try:
        y = convdk_mbconv_fused_sharded(
            x, *wa, mesh=mesh, stride=1, tile_h=3, interpret=True,
            collective="psum_scatter")
        assert y.sharding.spec[-1] == "model", y.sharding.spec
        z = convdk_mbconv_fused_sharded(
            y, *wb, mesh=mesh, stride=1, tile_h=3, interpret=True,
            exp_act=None, in_layout="model_sharded",
            collective="psum_scatter")
        np.testing.assert_allclose(z, want, rtol=1e-4, atol=1e-4)
        # the load-bearing assertion: nothing re-gathered the boundary
        model_gathers = [c for c in gathers if c[1] == "model"]
        assert not model_gathers, gathers
        # structure check: one scatter exit per block, one squeeze psum
        # per block — and nothing else
        model_scatters = [c for c in scatters if c[1] == "model"]
        model_psums = [c for c in psums if c[1] == "model"]
        assert len(model_scatters) == 2, scatters
        assert len(model_psums) == 2, psums
        # the consumer's output is still sharded: the chain could keep going
        assert z.sharding.spec[-1] == "model", z.sharding.spec
    finally:
        jax.lax.all_gather = orig_ag
        jax.lax.psum, jax.lax.psum_scatter = orig_psum, orig_scatter
    print("CHAIN_ZERO_GATHER_OK")
    """)


# ---------------------------------------------------------------------------
# custom_vjp residual forwarding: the probe + the barrier it gates
# ---------------------------------------------------------------------------

def test_residual_forwarding_probe_and_barrier():
    """Regression for the upstream custom_vjp residual-forwarding bug:
    the probe must reach a verdict on this 8-device harness, the MBConv
    ``w_dw`` cotangent must match central finite differences with the
    barrier forced ON and in probe-gated auto mode, and whenever the
    probe reports the bug, forcing the barrier OFF must reproduce the
    miscount (i.e. the probe detects something real — on fixed builds the
    same forced-OFF grad must instead be exact, proving auto-disable is
    safe)."""
    run_case("""
    from repro import compat
    from repro.kernels.convdk_sharded import _mbconv_sharded_entry

    probe = compat.residual_forwarding_probe()
    assert probe in (True, False), probe     # 8 devices: must be conclusive

    mesh = parse_mesh("2x4")
    rng = np.random.default_rng(11)
    ci, e, co, k = 8, 2, 16, 3
    x = rand(rng, (4, 5, 5, ci))
    weights, _ = mbconv_params(rng, ci, e, co, k)
    w_dw = weights[1]

    def loss_at(wd):
        ws = (weights[0], wd) + tuple(weights[2:])
        return float((convdk_mbconv_fused_sharded(
            x, *ws, mesh=mesh, stride=1, tile_h=2, interpret=True) ** 2
        ).sum())

    def grad_now(wd):
        _mbconv_sharded_entry.cache_clear()   # decisions bake into traces
        def loss(w):
            ws = (weights[0], w) + tuple(weights[2:])
            return (convdk_mbconv_fused_sharded(
                x, *ws, mesh=mesh, stride=1, tile_h=2,
                interpret=True) ** 2).sum()
        return jax.grad(loss)(wd)

    # central finite differences along a few random directions
    def fd_check(g, tag, expect_exact=True):
        eps = 1e-2
        fails = 0
        for seed in range(3):
            v = rand(np.random.default_rng(seed), w_dw.shape)
            v = v / jnp.linalg.norm(v)
            fd = (loss_at(w_dw + eps * v) - loss_at(w_dw - eps * v)) \\
                / (2 * eps)
            an = float(jnp.vdot(g, v))
            if abs(an - fd) > 2e-2 * max(1.0, abs(fd)):
                fails += 1
        if expect_exact:
            assert fails == 0, (tag, fails)
        return fails

    try:
        compat.set_residual_barrier("on")
        fd_check(grad_now(w_dw), "barrier-on")
        compat.set_residual_barrier("auto")
        fd_check(grad_now(w_dw), "auto")
        compat.set_residual_barrier("off")
        fails_off = fd_check(grad_now(w_dw), "barrier-off",
                             expect_exact=not probe)
        if probe:
            # the miscount multiplies the cotangent by the model-axis
            # size: every direction must disagree with finite differences
            assert fails_off == 3, fails_off
    finally:
        compat.set_residual_barrier("auto")
        _mbconv_sharded_entry.cache_clear()
    print("RESIDUAL_PROBE_OK probe=%s" % probe)
    """)


# ---------------------------------------------------------------------------
# model-layer routing + autodiff under the mesh
# ---------------------------------------------------------------------------

def test_sharded_block_routing_and_grad():
    """``mbconv_block`` / ``separable_block`` with a mesh route through the
    sharded wrappers (matching the mesh-free output bit for bit in math),
    fall back cleanly when the grid does not divide, and stay
    differentiable end to end."""
    run_case("""
    from repro.configs.base import ConvKernelConfig
    from repro.models.common import separable_block
    from repro.models.mbconv import mbconv_block, mbconv_def
    from repro.models.param import materialize

    mesh = parse_mesh("4x2")
    kcfg = ConvKernelConfig(interpret=True)
    rng = np.random.default_rng(4)
    params = materialize(mbconv_def(16, 16, k=3, expand_ratio=2),
                         jax.random.key(0))
    x = rand(rng, (8, 9, 9, 16))
    meshed = mbconv_block(params, x, stride=1, kcfg=kcfg, mesh=mesh)
    plain = mbconv_block(params, x, stride=1, kcfg=kcfg)
    np.testing.assert_allclose(meshed, plain, **TOL)

    sep = {"dw": rand(rng, (3, 3, 16), 0.3), "pw": rand(rng, (16, 16))}
    meshed_s = separable_block(sep, x, stride=1, kcfg=kcfg, mesh=mesh)
    plain_s = separable_block(sep, x, stride=1, kcfg=kcfg)
    np.testing.assert_allclose(meshed_s, plain_s, **TOL)

    # non-divisible batch (7 % 4 != 0): falls back to the single-device
    # kernel, still correct
    x_odd = rand(rng, (7, 9, 9, 16))
    np.testing.assert_allclose(
        mbconv_block(params, x_odd, stride=1, kcfg=kcfg, mesh=mesh),
        mbconv_block(params, x_odd, stride=1, kcfg=kcfg), **TOL)

    # autodiff through the sharded route (VJP via the reference
    # composition, the single-device wrappers' pattern)
    def loss(p):
        return (mbconv_block(p, x, stride=1, kcfg=kcfg, mesh=mesh) ** 2).sum()

    def loss_plain(p):
        return (mbconv_block(p, x, stride=1, kcfg=kcfg) ** 2).sum()

    g = jax.grad(loss)(params)
    g_ref = jax.grad(loss_plain)(params)
    for key in sorted(params):
        np.testing.assert_allclose(g[key], g_ref[key], err_msg=key,
                                   rtol=2e-3, atol=2e-3)
    print("ROUTING_GRAD_OK")
    """)


# ---------------------------------------------------------------------------
# guard rails (cheap: no device harness needed)
# ---------------------------------------------------------------------------

def test_sharded_wrappers_reject_bad_grids():
    from repro.compat import make_mesh
    from repro.kernels import can_shard_fused

    mesh = make_mesh((1, 1), ("data", "model"))
    assert can_shard_fused(mesh, batch=4, channels=16)
    assert not can_shard_fused(make_mesh((1,), ("data",)), 4, 16)

    import jax.numpy as jnp
    from repro.kernels import convdk_mbconv_fused_sharded

    x = jnp.zeros((3, 8, 8, 8), jnp.float32)   # batch 3: indivisible later
    w_exp = jnp.zeros((8, 16), jnp.float32)
    w_dw = jnp.zeros((3, 3, 16), jnp.float32)
    w_se1, b_se1 = jnp.zeros((16, 2), jnp.float32), jnp.zeros(2, jnp.float32)
    w_se2, b_se2 = jnp.zeros((2, 16), jnp.float32), jnp.zeros(16, jnp.float32)
    w_proj = jnp.zeros((16, 8), jnp.float32)
    bad = make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="data"):
        convdk_mbconv_fused_sharded(x, w_exp, w_dw, w_se1, b_se1, w_se2,
                                    b_se2, w_proj, mesh=bad, interpret=True)

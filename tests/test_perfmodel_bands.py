"""Reproduction gates: the analytical model must land in (or defensibly near)
the paper's reported bands.  Tolerances and known deviations are documented in
DESIGN.md §Reproduction-fidelity:

* v3-Large / v3-Small compute-latency reductions overshoot because our WS
  baseline leaves tiles idle for C < 64 layers (the paper's baseline appears
  to mitigate this partially); their totals are gated with a wider tolerance.
* k5-heavy models (v3-S, EfficientNet) under-report TM utilization vs the
  paper (their packing accounting for 5x5 kernels is not fully specified).
"""


import pytest
from hypothesis import given, settings, strategies as st

from repro.core.perfmodel import (
    COLLECTIVE_MODES,
    DATAFLOWS,
    MBCONV_MODES,
    RESIDENCY_MODES,
    MacroConfig,
    MBConvShape,
    SeparableShape,
    can_psum_scatter,
    compare_networks,
    cost_ws_convdk,
    mbconv_fused_traffic,
    reduction,
    sharded_mbconv_staged_traffic,
    sharded_mbconv_traffic,
    sharded_separable_staged_traffic,
    sharded_separable_traffic,
)
from repro.core.tiling import DWLayer, plan_layer
from repro.core.workloads import (
    EFFICIENTNET_B0_MBCONV,
    MOBILENET_V2_SEPARABLE,
    NETWORKS,
    PAPER_BANDS,
)

MACRO = MacroConfig()


@pytest.fixture(scope="module")
def results():
    return {name: compare_networks(name, layers, MACRO)
            for name, layers in NETWORKS.items()}


# ---------------------------------------------------------------------------
# scheduler / plan unit behaviour
# ---------------------------------------------------------------------------

def test_fig5_little_example():
    """Paper Fig. 5: 128x24x24 ifmap, 3x3 s1 kernel -> LITTLE, N_ch = 2."""
    plan = plan_layer(DWLayer(c=128, h=24, w=24, k=3, s=1))
    assert plan.mode == "LITTLE"
    assert plan.n_ch == 2
    # all 128 channels resident across the 64 tiles in one round
    assert plan.rounds == 1


def test_big_selected_for_wide_maps():
    plan = plan_layer(DWLayer(c=32, h=112, w=112, k=3, s=1))
    assert plan.mode == "BIG"
    assert plan.n_ch == 1
    # Eq. (8) with T_w = 60: N = (60 - 3 + 1)//3 = 19
    assert plan.strips[0].sched.N == 19
    # idle tiles host duplicated kernels (32 channels x 2 strips = 64 jobs)
    assert plan.jobs == 64 and plan.tile_dup == 1


def test_strip_cover_is_exact():
    for layer in NETWORKS["efficientnet_b0"]:
        plan = plan_layer(layer)
        assert plan.strip_out_total == layer.out_w


def test_utilization_beats_baselines():
    for name, layers in NETWORKS.items():
        for layer in layers:
            plan = plan_layer(layer)
            base = (layer.k ** 2) / 180.0
            assert plan.tm_utilization > 3 * base, (name, layer)
            assert plan.tm_utilization <= 1.0


# ---------------------------------------------------------------------------
# Fig. 7(b) — DRAM traffic identical across dataflows
# ---------------------------------------------------------------------------

def test_fig7b_dram_identical(results):
    for name, flows in results.items():
        base = flows["ws_base"].dram_words
        for df in DATAFLOWS:
            assert flows[df].dram_words == base


# ---------------------------------------------------------------------------
# Fig. 7(c) — buffer-traffic reduction 77.4-87.0 % (WS)
# ---------------------------------------------------------------------------

def test_fig7c_ws_band(results):
    lo, hi = PAPER_BANDS["buffer_traffic_reduction_ws"]
    for name, flows in results.items():
        red = reduction(flows["ws_base"].buffer_words,
                        flows["ws_convdk"].buffer_words)
        assert lo - 2.0 <= red <= hi + 2.0, (name, red)


def test_fig7c_is_band(results):
    lo, hi = PAPER_BANDS["buffer_traffic_reduction_ws"]
    for name, flows in results.items():
        red = reduction(flows["is_base"].buffer_words,
                        flows["is_convdk"].buffer_words)
        assert lo - 2.0 <= red <= hi + 2.0, (name, red)


# ---------------------------------------------------------------------------
# Fig. 7(d) — energy reductions
# ---------------------------------------------------------------------------

def _buffer_energy(net):
    e = net.energy_pj(MACRO)
    return net.buffer_words * 8 * MACRO.e_buffer_pj + e["tm"] + e["trf"]


def test_fig7d_ws_buffer_energy_band(results):
    lo, hi = PAPER_BANDS["buffer_energy_reduction_ws"]
    for name, flows in results.items():
        red = reduction(_buffer_energy(flows["ws_base"]),
                        _buffer_energy(flows["ws_convdk"]))
        assert lo - 2.0 <= red <= hi + 2.0, (name, red)


def test_fig7d_is_buffer_energy_band(results):
    lo, hi = PAPER_BANDS["buffer_energy_reduction_is"]
    for name, flows in results.items():
        red = reduction(_buffer_energy(flows["is_base"]),
                        _buffer_energy(flows["is_convdk"]))
        assert lo - 2.0 <= red <= hi + 2.0, (name, red)


def test_fig7d_ws_total_energy_band(results):
    lo, hi = PAPER_BANDS["energy_reduction_ws"]
    for name, flows in results.items():
        red = reduction(flows["ws_base"].energy_pj(MACRO)["total"],
                        flows["ws_convdk"].energy_pj(MACRO)["total"])
        assert lo - 3.0 <= red <= hi + 3.0, (name, red)


def test_fig7d_is_total_energy_band(results):
    lo, hi = PAPER_BANDS["energy_reduction_is"]
    for name, flows in results.items():
        red = reduction(flows["is_base"].energy_pj(MACRO)["total"],
                        flows["is_convdk"].energy_pj(MACRO)["total"])
        assert lo - 3.0 <= red <= hi + 7.0, (name, red)


# ---------------------------------------------------------------------------
# Fig. 7(e) / Fig. 8 — latency
# ---------------------------------------------------------------------------

def test_fig7e_ws_latency_band(results):
    lo, hi = PAPER_BANDS["latency_reduction_ws"]
    for name, flows in results.items():
        red = reduction(flows["ws_base"].total_clks,
                        flows["ws_convdk"].total_clks)
        # v3 models overshoot via baseline tile idling (DESIGN.md)
        tol_hi = 12.0 if "v3" in name else 3.0
        assert lo - 3.0 <= red <= hi + tol_hi, (name, red)


def test_fig8_ws_buffer_latency_band(results):
    lo, hi = PAPER_BANDS["buffer_latency_reduction_ws"]
    for name, flows in results.items():
        red = reduction(flows["ws_base"].buffer_clks,
                        flows["ws_convdk"].buffer_clks)
        assert lo - 2.0 <= red <= hi + 2.0, (name, red)


def test_fig8_is_buffer_latency_band(results):
    lo, hi = PAPER_BANDS["buffer_latency_reduction_is"]
    for name, flows in results.items():
        red = reduction(flows["is_base"].buffer_clks,
                        flows["is_convdk"].buffer_clks)
        assert lo - 5.0 <= red <= hi + 2.0, (name, red)


def test_baseline_buffer_share(results):
    """Baseline buffer traffic = 13.1-16.8 % of total latency (Sec. V-C)."""
    lo, hi = PAPER_BANDS["baseline_buffer_latency_share"]
    for name, flows in results.items():
        share = 100 * flows["ws_base"].buffer_clks / flows["ws_base"].total_clks
        assert lo - 1.5 <= share <= hi + 1.5, (name, share)


def test_is_baseline_slower_than_ws_baseline(results):
    """Sec. V-C: word-by-word TM writes make IS latency exceed WS latency."""
    for name, flows in results.items():
        assert flows["is_base"].total_clks > flows["ws_base"].total_clks
        assert flows["is_base"].buffer_clks > flows["ws_base"].buffer_clks


def test_dram_traffic_pipelined(results):
    """Sec. IV-D: DRAM transfers hide behind compute for every layer."""
    for name, flows in results.items():
        for cost in flows["ws_convdk"].layers:
            assert cost.dram_pipelined_ok(MACRO), (name, cost.layer)


# ---------------------------------------------------------------------------
# Sharded traffic: the paper's reduction claim must survive partitioning
# ---------------------------------------------------------------------------

SHARD_MESHES = ((8, 1), (4, 2), (2, 4))


def _b0_shape(layer, b=8):
    ci, co, e, k, s, hw = layer
    return MBConvShape(b=b, h=hw, w=hw, c_in=ci, c_mid=ci * e, c_out=co,
                       k=k, s=s)


@given(layer=st.sampled_from(list(EFFICIENTNET_B0_MBCONV)),
       mesh=st.sampled_from(SHARD_MESHES),
       tile_h=st.sampled_from([1, 2, 4, 8, 16, 32]),
       mode=st.sampled_from(list(MBCONV_MODES)))
@settings(max_examples=150, deadline=None)
def test_sharded_traffic_survives_partitioning(layer, mesh, tile_h, mode):
    """Property, any B0 layer x mesh shape x (tile_h, mode):

    (a) per-DEVICE sharded traffic never exceeds the single-device traffic
        of the same schedule — sharding must divide the modeled HBM words,
        never multiply them;
    (b) the SE-squeeze/projection psum bytes are IDENTICAL for the fused
        and staged pipelines (both reduce over the full c_mid), so
    (c) total sharded bytes (every device's HBM + collectives) of the
        AUTOTUNED schedule stay strictly below the identically partitioned
        staged baseline — the paper's reduction claim under partitioning.
    """
    from repro.core.autotune import select_mbconv_schedule

    shape = _b0_shape(layer)
    tile_h = max(1, min(tile_h, shape.out_h))
    sharded = sharded_mbconv_traffic(shape, tile_h, mode, mesh)
    single = mbconv_fused_traffic(shape, tile_h, mode)
    assert sharded.per_device_bytes <= single.total_bytes, (layer, mesh)
    assert sharded.n_devices == mesh[0] * mesh[1]      # B0 grids all divide

    staged = sharded_mbconv_staged_traffic(shape, tile_h, mesh)
    assert sharded.collective_words == staged.collective_words

    sch = select_mbconv_schedule(shape, mesh_shape=mesh)
    assert sch.mesh_shape == mesh
    assert sch.total_bytes < sch.staged_total_bytes, (layer, mesh, sch)


def test_sharded_b0_gate_exhaustive():
    """The (c) leg of the property, exhaustively: every B0 layer x every
    mesh shape, at the autotuned schedule (the CI ``--mesh`` gate's exact
    claim)."""
    from repro.core.autotune import select_mbconv_schedule

    for layer in EFFICIENTNET_B0_MBCONV:
        for mesh in SHARD_MESHES + ((1, 1),):
            shape = _b0_shape(layer)
            sch = select_mbconv_schedule(shape, mesh_shape=mesh)
            assert sch.total_bytes < sch.staged_total_bytes, (layer, mesh)
            # the psum term is live exactly when the model axis shards
            assert (sch.collective_words > 0) == (mesh[1] > 1), (layer, mesh)


def test_schedule_totals_are_shardedtraffic_totals():
    """Anti-divergence property (the single-source-of-truth contract):
    for EVERY B0 MBConv layer and EVERY MBv2 separable block x mesh
    {(8,1),(4,2),(2,4)} x residency x collective mode, the solved
    schedule's byte accounting IS the ``perfmodel.ShardedTraffic`` —
    identical objects (and therefore identical totals), not re-derived
    numbers.  This is the property that makes ``autotune`` structurally
    unable to drift from the traffic model."""
    from repro.core.autotune import (
        select_fused_schedule,
        select_mbconv_schedule,
    )

    for layer in EFFICIENTNET_B0_MBCONV:
        shape = _b0_shape(layer)
        for mesh in SHARD_MESHES:
            for res in RESIDENCY_MODES:
                for coll in (None,) + COLLECTIVE_MODES:
                    if coll == "psum_scatter" \
                            and not can_psum_scatter(shape, mesh):
                        continue
                    sch = select_mbconv_schedule(
                        shape, mesh_shape=mesh, residency=res,
                        collective=coll)
                    want = sharded_mbconv_traffic(
                        shape, sch.tile_h, sch.mode, mesh,
                        residency=sch.residency, collective=sch.collective)
                    assert sch.sharded == want, (layer, mesh, res, coll)
                    assert sch.total_bytes == want.total_bytes
                    want_staged = sharded_mbconv_staged_traffic(
                        shape, sch.tile_h, mesh, collective=sch.collective)
                    assert sch.staged == want_staged, (layer, mesh, res,
                                                       coll)
                    assert sch.staged_total_bytes == want_staged.total_bytes

    for layer, c_out in MOBILENET_V2_SEPARABLE:
        shape = SeparableShape(b=8, h=layer.h, w=layer.w, c_in=layer.c,
                               c_out=c_out, k=layer.k, s=layer.s)
        for mesh in SHARD_MESHES:
            for res in RESIDENCY_MODES:
                sch = select_fused_schedule(shape, mesh_shape=mesh,
                                            residency=res)
                want = sharded_separable_traffic(
                    shape, sch.tile_h, mesh, residency=sch.residency)
                assert sch.sharded == want, (layer, c_out, mesh, res)
                assert sch.total_bytes == want.total_bytes
                assert sch.staged == sharded_separable_staged_traffic(
                    shape, sch.tile_h, mesh), (layer, c_out, mesh, res)


def test_psum_scatter_halves_projection_collective():
    """The collective axis is real money: on (2, 4) the autotuner flips
    at least one B0 layer to psum_scatter, its total never exceeds the
    ring pin, and the modeled collective bytes land ~2x below the ring
    (the squeeze term keeps the ratio just under 2)."""
    from repro.core.autotune import select_mbconv_schedule

    mesh = (2, 4)
    scatter_picks = 0
    for layer in EFFICIENTNET_B0_MBCONV:
        shape = _b0_shape(layer)
        auto = select_mbconv_schedule(shape, mesh_shape=mesh)
        ring = select_mbconv_schedule(shape, mesh_shape=mesh,
                                      collective="ring_allreduce")
        assert auto.total_bytes <= ring.total_bytes, layer
        assert ring.collective == "ring_allreduce"
        if auto.collective == "psum_scatter":
            scatter_picks += 1
            # collective words do not depend on tile_h/mode/residency,
            # so the ratio compares cleanly across the two solves
            ratio = ring.collective_bytes / auto.collective_bytes
            assert 1.8 < ratio <= 2.0, (layer, ratio)
    assert scatter_picks > 0


def test_psum_scatter_pads_indivisible_c_out():
    """Non-dividing c_out no longer rejects scatter: the projection is
    padded to the next model-factor multiple (zero columns contribute zero
    partials, so the reduction is exact) and the scatter words are priced
    at the padded width.  The pad overhead is real — the auto solve only
    flips when the padded scatter still beats the ring."""
    from repro.core.autotune import select_mbconv_schedule
    from repro.core.perfmodel import scatter_c_out

    shape = MBConvShape(b=8, h=14, w=14, c_in=80, c_mid=480, c_out=114,
                        k=5, s=1)                      # 114 % 4 != 0
    assert can_psum_scatter(shape, (2, 4))
    assert scatter_c_out(114, 4) == 116
    pinned = select_mbconv_schedule(shape, mesh_shape=(2, 4),
                                    collective="psum_scatter")
    assert pinned.collective == "psum_scatter"
    # scatter words = 2(mp-1)*squeeze + (mp-1)*padded projection, per dp group
    dp, mp = 2, 4
    squeeze = (shape.b // dp) * shape.c_se
    proj_pad = (shape.b // dp) * shape.out_h * shape.out_w * 116
    assert pinned.collective_words == dp * (2 * (mp - 1) * squeeze
                                            + (mp - 1) * proj_pad)
    ring = select_mbconv_schedule(shape, mesh_shape=(2, 4),
                                  collective="ring_allreduce")
    assert pinned.collective_words < ring.collective_words
    # off-mesh the axis is degenerate: everything normalizes to the ring
    off = select_mbconv_schedule(shape, mesh_shape=(1, 1))
    assert off.collective == "ring_allreduce" and off.collective_words == 0


def test_macs_conserved():
    """Every dataflow performs the same MAC count (same convolution)."""
    for name, layers in NETWORKS.items():
        for layer in layers:
            dk = cost_ws_convdk(layer, MACRO)
            # ConvDK compute cycles x 64 >= exact MAC-output count; tail-strip
            # waste is worst for 5x5 kernels on 7x7 maps (out_len 10 vs 7).
            outs = layer.c * layer.out_h * layer.out_w
            assert dk.compute_cycles * 64 >= outs
            assert dk.compute_cycles * 64 <= 1.5 * outs + 64 * 64

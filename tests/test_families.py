"""Family-generic block stack: no-SE MBConv and MobileNet-V3 act
variants vs independent oracles, the single-pass Fused-MBConv kernel vs
a dense-conv oracle (fwd + grad), the se=off collective contract, the
fusedmb pass-split property, and the MobileNet-V3-Large /
EfficientNet-V2-S models end to end through the family-generic network
solver — sequential-oracle parity single-device and sharded."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import (
    BlockRow,
    get_fusedmb_schedule,
    get_mbconv_schedule,
)
from repro.core.perfmodel import (
    MBConvShape,
    fusedmb_pass_traffic,
)
from repro.kernels import (
    convdk_fusedmb_fused,
    convdk_fusedmb_staged,
    convdk_mbconv_fused,
)

TOL = dict(rtol=1e-4, atol=1e-4)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HAVE_8 = jax.device_count() >= 8


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def _act(x, name):
    if name is None:
        return x
    return {"silu": jax.nn.silu, "relu": jax.nn.relu,
            "hard_swish": jax.nn.hard_swish, "sigmoid": jax.nn.sigmoid,
            "hard_sigmoid": jax.nn.hard_sigmoid}[name](x)


def _dw(x, w_dw, stride):
    k_h, k_w, c_mid = w_dw.shape
    return jax.lax.conv_general_dilated(
        x, jnp.transpose(w_dw, (2, 0, 1))[:, None],
        window_strides=(stride, stride), padding="SAME",
        feature_group_count=c_mid,
        dimension_numbers=("NHWC", "OIHW", "NHWC"))


def _mbconv_oracle(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                   stride, exp_act="silu", dw_act="silu", se_act="silu",
                   gate_act="sigmoid"):
    """Independent MBConv oracle (explicit lax convs, explicit optional
    SE — NOT the repo's mbconv_ref), covering the family axes: per-block
    act, no-SE when ``w_se1 is None``, V3's (relu, hard_sigmoid) SE."""
    d = _act(_dw(_act(x @ w_exp, exp_act), w_dw, stride), dw_act)
    if w_se1 is not None:
        gate = _act(_act(d.mean(axis=(1, 2)) @ w_se1 + b_se1, se_act)
                    @ w_se2 + b_se2, gate_act)
        d = d * gate[:, None, None, :]
    return d @ w_proj


def _fusedmb_oracle(x, w_conv, w_proj, stride, act="silu"):
    """Independent Fused-MBConv oracle: ONE dense lax conv, act,
    projection einsum (NOT the repo's fusedmb_ref)."""
    e = jax.lax.conv_general_dilated(
        x, w_conv, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jnp.einsum("bhwc,cd->bhwd", _act(e, act), w_proj)


# ---------------------------------------------------------------------------
# kernel numerics: the family axes vs independent oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [3, 5])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("mode", ["retain", "recompute"])
def test_mbconv_no_se_matches_oracle(k, stride, mode):
    """se=off (ALL SE weights None): the pool, both FCs and the gate
    disappear from the two-pass kernel, matching the SE-less oracle."""
    rng = np.random.default_rng(k * 10 + stride)
    b, h, w_in, ci, e, co = 2, 13, 11, 8, 3, 16
    x = _rand(rng, (b, h, w_in, ci))
    w_exp = _rand(rng, (ci, ci * e))
    w_dw = _rand(rng, (k, k, ci * e), 0.3)
    w_proj = _rand(rng, (ci * e, co))
    got = convdk_mbconv_fused(x, w_exp, w_dw, None, None, None, None,
                              w_proj, stride=stride, mode=mode, tile_h=4,
                              interpret=True)
    want = _mbconv_oracle(x, w_exp, w_dw, None, None, None, None, w_proj,
                          stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("k", [3, 5])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("mode", ["retain", "recompute"])
def test_mbconv_v3_flavor_matches_oracle(k, stride, mode):
    """MobileNet-V3's late stages: hard_swish expand/DW with the (relu,
    hard_sigmoid) SE MLP, against the explicit oracle."""
    rng = np.random.default_rng(k * 100 + stride)
    b, h, w_in, ci, e, co = 2, 11, 9, 8, 2, 12
    c_mid, c_se = ci * e, max(1, ci // 4)
    x = _rand(rng, (b, h, w_in, ci))
    weights = (_rand(rng, (ci, c_mid)), _rand(rng, (k, k, c_mid), 0.3),
               _rand(rng, (c_mid, c_se)), _rand(rng, (c_se,), 0.1),
               _rand(rng, (c_se, c_mid)), _rand(rng, (c_mid,), 0.1),
               _rand(rng, (c_mid, co)))
    got = convdk_mbconv_fused(
        x, *weights, stride=stride, mode=mode, tile_h=4,
        exp_act="hard_swish", dw_act="hard_swish", se_act="relu",
        gate_act="hard_sigmoid", interpret=True)
    want = _mbconv_oracle(x, *weights, stride, exp_act="hard_swish",
                          dw_act="hard_swish", se_act="relu",
                          gate_act="hard_sigmoid")
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("k", [3, 5])
@pytest.mark.parametrize("stride", [1, 2])
def test_fusedmb_matches_dense_conv_oracle(k, stride):
    """The single-pass Fused-MBConv kernel == dense conv -> act ->
    projection, and the staged baseline computes the identical block."""
    rng = np.random.default_rng(k + stride)
    b, h, w_in, ci, cm, co = 2, 13, 11, 8, 24, 16
    x = _rand(rng, (b, h, w_in, ci))
    w_conv = _rand(rng, (k, k, ci, cm), 0.3)
    w_proj = _rand(rng, (cm, co))
    want = _fusedmb_oracle(x, w_conv, w_proj, stride)
    for tile_h in (1, 4):
        got = convdk_fusedmb_fused(x, w_conv, w_proj, stride=stride,
                                   tile_h=tile_h, interpret=True)
        assert got.shape == want.shape
        np.testing.assert_allclose(got, want, **TOL)
    staged = convdk_fusedmb_staged(x, w_conv, w_proj, stride=stride,
                                   interpret=True)
    np.testing.assert_allclose(staged, want, **TOL)


def test_fusedmb_grad_matches_oracle():
    rng = np.random.default_rng(17)
    x = _rand(rng, (1, 10, 9, 8))
    w_conv = _rand(rng, (3, 3, 8, 16), 0.3)
    w_proj = _rand(rng, (16, 12))

    def loss(fn):
        return lambda *p: (fn(*p) ** 2).sum()

    f = loss(lambda *p: convdk_fusedmb_fused(*p, stride=2, interpret=True))
    r = loss(lambda *p: _fusedmb_oracle(*p, 2))
    g = jax.grad(f, argnums=(0, 1, 2))(x, w_conv, w_proj)
    g_ref = jax.grad(r, argnums=(0, 1, 2))(x, w_conv, w_proj)
    for got, want in zip(g, g_ref):
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# pass-split property + family-generic rows
# ---------------------------------------------------------------------------

def test_fusedmb_pass2_traffic_is_exactly_zero():
    """The one-pass family's pass-2 figures are identically zero at EVERY
    (shape, tile_h, residency) — the structural fact the pipeliner leans
    on when it refuses to hide a consumer behind a fusedmb block."""
    for (ci, cm, co, hw, k, s) in [(24, 24, 24, 56, 3, 1),
                                   (24, 96, 48, 56, 3, 2),
                                   (64, 256, 128, 14, 3, 2)]:
        shape = MBConvShape(b=1, h=hw, w=hw, c_in=ci, c_mid=cm, c_out=co,
                            k=k, s=s, se_ratio=0.0)
        for tile_h in (1, 4, 8):
            for res in ("resident", "strip_dma", "strip_dma_db"):
                p1, p2 = fusedmb_pass_traffic(shape, tile_h, 128, res)
                assert p2.total_bytes == 0, (shape, tile_h, res, p2)
                assert p1.total_bytes > 0
        sch = get_fusedmb_schedule(1, hw, hw, ci, cm, co, k, s)
        assert sch.total_bytes < sch.staged_total_bytes


def test_blockrow_legacy_tuple_compat():
    """Legacy 7-tuples ARE BlockRows: same positional head, mbconv/silu
    defaults — the solver accepts mixed row vocabularies."""
    r = BlockRow(56, 56, 24, 144, 40, 5, 2)
    assert (r.family, r.act, r.se_ratio) == ("mbconv", "silu", 0.25)
    f = BlockRow(56, 56, 24, 96, 24, 3, 1, family="fusedmb", act="silu",
                 se_ratio=0.25)
    assert f.se_ratio == 0.0                 # fusedmb never carries SE


def test_model_tables_match_workload_tables():
    """The model builders' spec tables and the core workload tables are
    two views of the same networks — row for row, family, act and SE
    included."""
    from repro.core.workloads import (
        effnet_v2_chain_rows, mobilenet_v3_chain_rows)
    from repro.models.mbconv import (
        EffNetV2Config, MobileNetV3Config, block_chain_rows,
        effnet_v2_block_specs, mobilenet_v3_specs)

    v3 = block_chain_rows(mobilenet_v3_specs(MobileNetV3Config()), 112, 112)
    assert v3 == mobilenet_v3_chain_rows("large")
    assert {r.act for r in v3} == {"relu", "hard_swish"}
    assert any(r.se_ratio == 0.0 for r in v3)
    assert any(r.se_ratio > 0.0 for r in v3)

    v2s = block_chain_rows(effnet_v2_block_specs(EffNetV2Config()), 112, 112)
    assert v2s == effnet_v2_chain_rows()
    assert len(v2s) == 40
    assert [r.family for r in v2s][:10] == ["fusedmb"] * 10
    assert all(r.family == "mbconv" for r in v2s[10:])


def test_family_axes_in_schedule_cache_keys():
    """act/se are schedule-cache axes: a no-SE or hard_swish solve never
    collides with the silu/se-on pick for the same layer shape."""
    base = get_mbconv_schedule(1, 14, 14, 16, 64, 24, 3, 1)
    no_se = get_mbconv_schedule(1, 14, 14, 16, 64, 24, 3, 1, se_ratio=0.0)
    hs = get_mbconv_schedule(1, 14, 14, 16, 64, 24, 3, 1, act="hard_swish")
    assert base.traffic.total_bytes >= no_se.traffic.total_bytes
    assert no_se.traffic.total_bytes < base.staged_traffic.total_bytes
    assert hs.tile_h >= 1


# ---------------------------------------------------------------------------
# end-to-end: V3-Large and V2-S vs sequential oracles
# ---------------------------------------------------------------------------

def _sequential_blocks(x, specs, params):
    """Sequential oracle for the block chain: repo refs (lax math) +
    identity residuals, one block at a time — the graph path must match."""
    from repro.kernels import fusedmb_ref, mbconv_ref

    for i, sp in enumerate(specs):
        p = params[f"block{i}"]
        if sp.family == "fusedmb":
            y = fusedmb_ref(x, p["conv"], p["proj"], stride=sp.s,
                            act=sp.act)
        else:
            if "exp" in p:
                w_exp, exp_act = p["exp"], sp.act
            else:
                w_exp, exp_act = jnp.eye(sp.c_mid, dtype=x.dtype), None
            y = mbconv_ref(x, w_exp, p["dw"], p.get("se_w1"),
                           p.get("se_b1"), p.get("se_w2"), p.get("se_b2"),
                           p["proj"], stride=sp.s, exp_act=exp_act,
                           dw_act=sp.act, se_act=sp.se_act,
                           gate_act=sp.gate_act)
        if sp.has_residual:
            y = y + x
        x = y
    return x


def _v3_oracle(params, images, cfg):
    from repro.models.mbconv import mobilenet_v3_specs

    x = jax.lax.conv_general_dilated(
        images, params["stem"], (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = _sequential_blocks(jax.nn.hard_swish(x), mobilenet_v3_specs(cfg),
                           params)
    x = jax.nn.hard_swish(jnp.einsum("bhwc,cd->bhwd", x, params["head"]))
    x = jax.nn.hard_swish(x.mean(axis=(1, 2)) @ params["fc"])
    return x @ params["cls_w"] + params["cls_b"]


def _v2s_oracle(params, images, cfg):
    from repro.models.mbconv import effnet_v2_block_specs

    x = jax.lax.conv_general_dilated(
        images, params["stem"], (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = _sequential_blocks(jax.nn.silu(x), effnet_v2_block_specs(cfg),
                           params)
    x = jax.nn.silu(jnp.einsum("bhwc,cd->bhwd", x, params["head"]))
    return x.mean(axis=(1, 2)) @ params["cls_w"] + params["cls_b"]


def test_mobilenet_v3_matches_sequential_oracle():
    """V3-Large (width-scaled) through blockgraph == the sequential
    per-block ref loop, forward AND gradient, on the fused kernel path."""
    from repro.configs.base import ConvKernelConfig
    from repro.models.mbconv import MobileNetV3Config, mobilenet_v3_def
    from repro.models.mbconv import mobilenet_v3_apply
    from repro.models.param import materialize

    cfg = MobileNetV3Config(num_classes=4, width_mult=0.125)
    params = materialize(mobilenet_v3_def(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    x = _rand(rng, (1, 16, 16, 3), 0.5)
    kcfg = ConvKernelConfig(interpret=True)
    logits = mobilenet_v3_apply(params, x, cfg, kcfg=kcfg)
    want = _v3_oracle(params, x, cfg)
    assert logits.shape == (1, 4)
    np.testing.assert_allclose(logits, want, **TOL)

    g = jax.grad(lambda p: (mobilenet_v3_apply(p, x, cfg, kcfg=kcfg)
                            ** 2).sum())(params)
    g_ref = jax.grad(lambda p: (_v3_oracle(p, x, cfg) ** 2).sum())(params)
    for got, want in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_efficientnet_v2_s_matches_sequential_oracle():
    """V2-S (truncated stages: fused head + MBConv tail) through the
    mixed-family blockgraph == the sequential ref loop, fwd + grad."""
    from repro.configs.base import ConvKernelConfig
    from repro.models.mbconv import (
        EffNetV2Config, efficientnet_v2_s_apply, efficientnet_v2_s_def)
    from repro.models.param import materialize

    cfg = EffNetV2Config(num_classes=4, width_mult=0.25, head_c=128,
                         stages=(("fusedmb", 1, 3, 1, 24, 1),
                                 ("fusedmb", 4, 3, 2, 48, 2),
                                 ("mbconv", 4, 3, 2, 64, 2)))
    params = materialize(efficientnet_v2_s_def(cfg), jax.random.key(1))
    rng = np.random.default_rng(1)
    x = _rand(rng, (1, 16, 16, 3), 0.5)
    kcfg = ConvKernelConfig(interpret=True)
    logits = efficientnet_v2_s_apply(params, x, cfg, kcfg=kcfg)
    want = _v2s_oracle(params, x, cfg)
    assert logits.shape == (1, 4)
    np.testing.assert_allclose(logits, want, **TOL)

    g = jax.grad(lambda p: (efficientnet_v2_s_apply(p, x, cfg, kcfg=kcfg)
                            ** 2).sum())(params)
    g_ref = jax.grad(lambda p: (_v2s_oracle(p, x, cfg) ** 2).sum())(params)
    for got, want in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_one_pass_nodes_validate_and_refuse_phantom_overlap():
    """Graph contract: fusedmb nodes carry an EMPTY pass 2, validate as
    one-pass producers, and a pipelined entry directly behind one is a
    validation error (there is no pass-2 compute to hide the DMA in)."""
    from repro.models.blockgraph import (
        BlockGraph, BlockNode, GraphValidationError, fusedmb_stage_io,
        mbconv_stage_io)

    p1, p2 = fusedmb_stage_io(3)
    assert "act3" in p1.reads and "act4" in p1.writes
    assert not p2.reads and not p2.writes

    from repro.configs.base import ConvKernelConfig
    from repro.models.blockgraph import build_block_graph
    from repro.models.mbconv import (
        EffNetV2Config, effnet_v2_block_specs, efficientnet_v2_s_def)
    from repro.models.param import materialize

    cfg = EffNetV2Config(num_classes=4, width_mult=0.25, head_c=64,
                         stages=(("fusedmb", 2, 3, 1, 24, 2),))
    params = materialize(efficientnet_v2_s_def(cfg), jax.random.key(0))
    specs = effnet_v2_block_specs(cfg)
    graph = build_block_graph(specs, params,
                              kcfg=ConvKernelConfig(interpret=True))
    graph.validate()                         # one-pass chain is well-formed
    assert all(n.one_pass for n in graph.nodes)

    # a pipelined entry behind the one-pass producer must refuse
    p1b, p2b = mbconv_stage_io(1, mode="retain")
    bad = BlockGraph(nodes=(
        BlockNode(0, "fusedmb0", *fusedmb_stage_io(0)),
        BlockNode(1, "mbconv1", p1b, p2b, entry_overlap="pipelined")))
    with pytest.raises(GraphValidationError, match="single-pass"):
        bad.validate()


# ---------------------------------------------------------------------------
# sharded: the se=off collective contract + end-to-end model parity
# (8-virtual-device harness, in-process when available, else subprocess)
# ---------------------------------------------------------------------------

_PREAMBLE = textwrap.dedent("""
    import os
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh

    assert jax.device_count() >= 8, jax.devices()

    def rand(rng, shape, scale=1.0):
        return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)

    mesh = make_mesh((2, 4), ("data", "model"))
""")


def run_case(body: str) -> None:
    src = _PREAMBLE + textwrap.dedent(body)
    if HAVE_8:
        exec(compile(src, "<families-sharded-case>", "exec"),
             {"__name__": "__families_sharded__"})
        return
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    env.setdefault("CONVDK_RESIDUAL_BARRIER", "on")
    res = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]


def test_no_se_block_emits_zero_squeeze_collectives():
    """Intercept ``jax.lax.psum`` during the sharded se=off MBConv trace
    under the (2,4) mesh: the ONLY collective over "model" is the
    projection partial — the SE squeeze psum is GONE, in both pass-2
    modes (the modeled se=off collective saving is structural, not just
    an accounting delta)."""
    run_case("""
    from repro import compat
    from repro.kernels import convdk_mbconv_fused_sharded, mbconv_ref
    from repro.kernels.convdk_sharded import _mbconv_sharded_entry
    compat.residual_barrier_needed()
    _mbconv_sharded_entry.cache_clear()
    rng = np.random.default_rng(4)
    b, h, w_in, ci, e, co, k, s = 8, 9, 9, 8, 2, 16, 3, 1
    x = rand(rng, (b, h, w_in, ci))
    w_exp = rand(rng, (ci, ci * e))
    w_dw = rand(rng, (k, k, ci * e), 0.3)
    w_proj = rand(rng, (ci * e, co))
    weights = (w_exp, w_dw, None, None, None, None, w_proj)
    want = mbconv_ref(x, *weights, stride=s)

    calls = []
    orig_psum = jax.lax.psum

    def counting_psum(val, axis_name, **kw):
        calls.append((jnp.shape(val), axis_name))
        return orig_psum(val, axis_name, **kw)

    jax.lax.psum = counting_psum
    try:
        for mode in ("retain", "recompute"):
            calls.clear()
            got = convdk_mbconv_fused_sharded(
                x, *weights, mesh=mesh, stride=s, tile_h=3, mode=mode,
                interpret=True)
            np.testing.assert_allclose(got, want, err_msg=mode,
                                       rtol=1e-4, atol=1e-4)
            model_calls = [c for c in calls if c[1] == "model"]
            # exactly ONE model-axis collective: the projection partial.
            # ZERO squeeze psums — there is no SE pool to reduce.
            assert len(model_calls) == 1, (mode, calls)
            assert model_calls[0][0] == (b // 2, h, w_in, co), model_calls
    finally:
        jax.lax.psum = orig_psum
    print("NO_SE_ZERO_SQUEEZE_OK")
    """)


def test_sharded_models_match_single_device():
    """V3-Large and V2-S end to end on the (2,4) mesh at b=8: the
    solver-planned sharded run equals the single-device run (which the
    oracle tests above pin to the sequential refs)."""
    run_case("""
    from repro.configs.base import ConvKernelConfig
    from repro.models.mbconv import (
        EffNetV2Config, MobileNetV3Config, efficientnet_v2_s_apply,
        efficientnet_v2_s_def, mobilenet_v3_apply, mobilenet_v3_def)
    from repro.models.param import materialize

    kcfg = ConvKernelConfig(interpret=True)
    rng = np.random.default_rng(5)
    x = rand(rng, (8, 16, 16, 3), 0.5)

    cfg = MobileNetV3Config(num_classes=4, width_mult=0.125)
    params = materialize(mobilenet_v3_def(cfg), jax.random.key(0))
    single = mobilenet_v3_apply(params, x, cfg, kcfg=kcfg)
    sharded = mobilenet_v3_apply(params, x, cfg, kcfg=kcfg, mesh=mesh)
    np.testing.assert_allclose(sharded, single, rtol=1e-4, atol=1e-4)

    v2cfg = EffNetV2Config(num_classes=4, width_mult=0.25, head_c=128,
                           stages=(("fusedmb", 1, 3, 1, 24, 1),
                                   ("fusedmb", 4, 3, 2, 48, 2),
                                   ("mbconv", 4, 3, 2, 64, 2)))
    v2params = materialize(efficientnet_v2_s_def(v2cfg), jax.random.key(1))
    single2 = efficientnet_v2_s_apply(v2params, x, v2cfg, kcfg=kcfg)
    sharded2 = efficientnet_v2_s_apply(v2params, x, v2cfg, kcfg=kcfg,
                                       mesh=mesh)
    np.testing.assert_allclose(sharded2, single2, rtol=1e-4, atol=1e-4)
    print("SHARDED_MODELS_OK")
    """)

"""Cross-block pipelining battery.

Three layers, matching the feature's stack:

* **Model properties** — the per-pass splits (traffic, collective words,
  VMEM) sum EXACTLY to their whole-block counterparts over the full B0
  sweep, and the overlapped boundary latency is never above the
  serialized one (by hypothesis over arbitrary fitted coefficients).
* **Solver gate** — ``solve_network_schedule`` on the (2,4) b=8 B0 chain
  pipelines >= 1 boundary with modeled chain latency strictly below the
  serialized plan, and only annotates boundaries that are collective- and
  transition-free (the hazard preconditions).
* **Graph + executor** — ``models.blockgraph`` validates legal chains,
  rejects tampered overlap marks (streamed-set / WAW / WAR hazards), and
  the graph-lowered ``efficientnet_b0_apply`` is bit-exact — forward AND
  grad — with the explicit sequential loop it replaced, over mesh
  {(8,1),(2,4)} x {planned(pipelined), pinned retain, pinned recompute},
  under the same 8-virtual-device harness as ``test_distributed_fused``.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.autotune import (
    TPUConfig,
    greedy_network_schedule,
    mbconv_pass_vmem_bytes,
    mbconv_vmem_footprint_bytes,
    network_rows_from_table,
    solve_network_schedule,
)
from repro.core.perfmodel import (
    COLLECTIVE_MODES,
    RESIDENCY_MODES,
    MBConvShape,
    PerfCoefficients,
    boundary_overlap_us,
    mbconv_fused_traffic,
    mbconv_pass_traffic,
    mbconv_pass_us,
    sharded_mbconv_pass_costs,
    sharded_mbconv_traffic,
)
from repro.core.workloads import EFFICIENTNET_B0_MBCONV
from repro.models.blockgraph import (
    BlockGraph,
    BlockNode,
    GraphValidationError,
    StageIO,
    mbconv_stage_io,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HAVE_8 = jax.device_count() >= 8

B0_SHAPES = [
    MBConvShape(b=8, h=hw, w=hw, c_in=ci, c_mid=ci * e, c_out=co, k=k, s=s)
    for ci, co, e, k, s, hw in EFFICIENTNET_B0_MBCONV
]


# ---------------------------------------------------------------------------
# pass-split exactness: the halves always sum to the whole
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["retain", "recompute"])
@pytest.mark.parametrize("residency", RESIDENCY_MODES)
def test_pass_traffic_sums_to_whole(mode, residency):
    for shape in B0_SHAPES:
        for tile_h in (1, 4, 16):
            whole = mbconv_fused_traffic(shape, tile_h, mode,
                                         residency=residency)
            p1, p2 = mbconv_pass_traffic(shape, tile_h, mode,
                                         residency=residency)
            assert p1.read_words + p2.read_words == whole.read_words
            assert p1.write_words + p2.write_words == whole.write_words
            assert p1.dma_issues + p2.dma_issues == whole.dma_issues
            assert p1.dtype_bytes == p2.dtype_bytes == whole.dtype_bytes


@pytest.mark.parametrize("collective", COLLECTIVE_MODES)
@pytest.mark.parametrize("in_layout", ["replicated", "model_sharded"])
def test_sharded_pass_costs_sum_to_sharded_traffic(collective, in_layout):
    """Device bytes AND collective words of the pass split reconcile with
    ``sharded_mbconv_traffic`` (entry transition words included)."""
    for shape in B0_SHAPES:
        st_ = sharded_mbconv_traffic(
            shape, 4, "retain", (2, 4), collective=collective,
            in_layout=in_layout)
        pc = sharded_mbconv_pass_costs(
            shape, 4, "retain", (2, 4), collective=collective,
            in_layout=in_layout)
        dev = pc.pass1.total_bytes + pc.pass2.total_bytes
        assert dev == st_.device.total_bytes
        coll = pc.pass1_collective_words + pc.pass2_collective_words
        assert coll == st_.collective_words + st_.transition_words


@pytest.mark.parametrize("mode", ["retain", "recompute"])
@pytest.mark.parametrize("residency", RESIDENCY_MODES)
def test_pass_vmem_sums_to_footprint(mode, residency):
    tpu = TPUConfig()
    for shape in B0_SHAPES:
        for tile_h in (1, 4, 16):
            whole = mbconv_vmem_footprint_bytes(shape, tile_h, tpu,
                                                residency, mode)
            p1, p2 = mbconv_pass_vmem_bytes(shape, tile_h, tpu,
                                            residency, mode)
            assert p1 + p2 == whole
            assert p1 > 0 and p2 > 0


# ---------------------------------------------------------------------------
# overlap latency: pipelined <= serialized, for ANY fitted coefficients
# ---------------------------------------------------------------------------

_B0_CHAIN_COSTS = [
    sharded_mbconv_pass_costs(shape, 4, "retain", (2, 4))
    for shape in B0_SHAPES
]


@given(base=st.floats(-5000, 5000),
       per_mb=st.floats(0, 20000),
       per_issue=st.floats(0, 1000),
       per_coll_mb=st.floats(0, 20000))
@settings(max_examples=50, deadline=None)
def test_pipelined_never_above_serialized(base, per_mb, per_issue,
                                          per_coll_mb):
    """For every B0 boundary and any coefficient fit, the overlapped
    boundary latency max(p2, p1) sits at or below the serialized sum —
    the structural guarantee the CI gate's strictness rides on."""
    coeffs = PerfCoefficients(base_us=base, us_per_mb=per_mb,
                              us_per_dma_issue=per_issue,
                              us_per_collective_mb=per_coll_mb,
                              n_samples=1, rms_us=0.0)
    for prev, cur in zip(_B0_CHAIN_COSTS, _B0_CHAIN_COSTS[1:]):
        p2 = mbconv_pass_us(coeffs, prev.pass2,
                            prev.pass2_collective_words)
        p1 = mbconv_pass_us(coeffs, cur.pass1, cur.pass1_collective_words)
        serial = boundary_overlap_us(p2, p1, "serial")
        pipe = boundary_overlap_us(p2, p1, "pipelined")
        assert pipe == max(p2, p1)
        assert serial == p2 + p1
        assert pipe <= serial


# ---------------------------------------------------------------------------
# the solver gate
# ---------------------------------------------------------------------------

def test_network_dp_pipelines_b0_on_model_sharded_mesh():
    """The acceptance criterion: on (2,4) b=8, >= 1 boundary pipelines,
    modeled chain latency drops strictly below the serialized plan, and
    the annotation is byte-neutral + only marks hazard-free boundaries."""
    chain = network_rows_from_table(EFFICIENTNET_B0_MBCONV)
    plan = solve_network_schedule(chain, 8, (2, 4))
    assert len(plan.pipelined_boundaries) >= 1
    assert plan.pipelined_latency_us() < plan.serial_latency_us()
    # byte-neutral: the annotated plan still beats greedy (the PR-6 gate)
    greedy = greedy_network_schedule(chain, 8, (2, 4))
    assert plan.total_bytes < greedy.total_bytes
    for i in plan.pipelined_boundaries:
        bp = plan.blocks[i]
        assert bp.schedule.overlap == "pipelined"
        assert bp.entry_overlap == "pipelined"
        # hazard preconditions: no boundary regather, no entry repay
        assert bp.boundary_words == 0
        assert bp.schedule.transition_bytes == 0
    for bp in plan.blocks:
        if bp.entry_overlap == "serial":
            assert bp.schedule.overlap == "serial"
    # per-boundary report rows agree with the chain totals
    rows = plan.boundary_latencies()
    saving = sum(r["serialized_us"] - r["overlap_us"] for r in rows)
    assert plan.serial_latency_us() - plan.pipelined_latency_us() \
        == pytest.approx(saving)


def test_network_dp_degenerate_mesh_still_sound():
    """(1,1) b=1: whatever the annotation finds, pipelined <= serialized
    and every accessor stays self-consistent."""
    chain = network_rows_from_table(EFFICIENTNET_B0_MBCONV)
    plan = solve_network_schedule(chain, 1, (1, 1))
    assert plan.pipelined_latency_us() <= plan.serial_latency_us()
    assert len(plan.boundary_latencies()) == len(plan.blocks) - 1


# ---------------------------------------------------------------------------
# graph validation: legal chains pass, tampered overlap marks raise
# ---------------------------------------------------------------------------

def _chain(n=3, mode="retain", pipelined=()):
    nodes = []
    for i in range(n):
        p1, p2 = mbconv_stage_io(i, mode=mode, residual=False)
        nodes.append(BlockNode(
            index=i, name=f"mbconv{i}", pass1=p1, pass2=p2,
            entry_overlap="pipelined" if i in pipelined else "serial"))
    return nodes


def test_graph_validates_legal_pipelined_chain():
    for mode in ("retain", "recompute"):
        g = BlockGraph(nodes=tuple(_chain(4, mode, pipelined=(1, 2, 3))))
        g.validate()
        assert g.pipelined_boundaries == (1, 2, 3)


def test_graph_rejects_first_node_pipelined():
    with pytest.raises(GraphValidationError, match="no producer"):
        BlockGraph(nodes=tuple(_chain(2, pipelined=(0,)))).validate()


def test_graph_rejects_misindexed_chain():
    nodes = _chain(3)
    nodes[1] = BlockNode(index=2, name="mbconv2", pass1=nodes[1].pass1,
                         pass2=nodes[1].pass2)
    with pytest.raises(GraphValidationError, match="chain order"):
        BlockGraph(nodes=tuple(nodes)).validate()


def test_graph_rejects_non_activation_stream():
    """A side buffer flowing producer-pass-2 -> consumer-pass-1 makes the
    boundary unpipelinable — the validator must catch the tamper."""
    nodes = _chain(2, pipelined=(1,))
    tampered = StageIO.of(nodes[1].pass1.reads | {"dw0"},
                          nodes[1].pass1.writes)
    nodes[1] = BlockNode(index=1, name="mbconv1", pass1=tampered,
                         pass2=nodes[1].pass2, entry_overlap="pipelined")
    nodes[0] = BlockNode(index=0, name="mbconv0", pass1=nodes[0].pass1,
                         pass2=StageIO.of(nodes[0].pass2.reads,
                                          nodes[0].pass2.writes | {"dw0"}))
    with pytest.raises(GraphValidationError, match="boundary activation"):
        BlockGraph(nodes=tuple(nodes)).validate()


def test_graph_rejects_write_write_hazard():
    nodes = _chain(2, pipelined=(1,))
    tampered = StageIO.of(nodes[1].pass1.reads,
                          nodes[1].pass1.writes | {"act1"})
    nodes[1] = BlockNode(index=1, name="mbconv1", pass1=tampered,
                         pass2=nodes[1].pass2, entry_overlap="pipelined")
    with pytest.raises(GraphValidationError, match="write-write"):
        BlockGraph(nodes=tuple(nodes)).validate()


def test_graph_rejects_write_after_read_hazard():
    """A recompute producer still reads ITS entry activation in pass 2;
    a consumer pass 1 clobbering it must be rejected."""
    nodes = _chain(2, mode="recompute", pipelined=(1,))
    tampered = StageIO.of(nodes[1].pass1.reads,
                          nodes[1].pass1.writes | {"act0"})
    nodes[1] = BlockNode(index=1, name="mbconv1", pass1=tampered,
                         pass2=nodes[1].pass2, entry_overlap="pipelined")
    with pytest.raises(GraphValidationError, match="still reads"):
        BlockGraph(nodes=tuple(nodes)).validate()


def test_graph_rejects_bad_overlap_mode():
    with pytest.raises(ValueError):
        _p1, _p2 = mbconv_stage_io(0)
        BlockNode(index=0, name="mbconv0", pass1=_p1, pass2=_p2,
                  entry_overlap="overlapped")


def test_build_graph_matches_plan_annotation():
    """The built graph inherits the plan's solved overlap marks 1:1 and
    validates — the lowering path CI exercises, minus the jit."""
    from repro.configs.efficientnet_b0 import efficientnet_b0_smoke
    from repro.models.blockgraph import build_mbconv_graph
    from repro.models.mbconv import (
        effnet_block_specs, effnet_chain_rows, efficientnet_b0_def,
    )
    from repro.models.param import materialize
    from repro.core.autotune import get_network_plan
    cfg = efficientnet_b0_smoke(width_mult=0.125, num_classes=4)
    params = materialize(efficientnet_b0_def(cfg), jax.random.key(0))
    specs = effnet_block_specs(cfg)
    plan = get_network_plan(effnet_chain_rows(specs, 16, 16), 8, (2, 4),
                            dtype_bytes=4, se_ratio=cfg.se_ratio)
    g = build_mbconv_graph(specs, params, plan=plan)
    g.validate()
    assert g.pipelined_boundaries == plan.pipelined_boundaries
    for node, bp in zip(g.nodes, plan.blocks):
        assert node.entry_overlap == bp.entry_overlap
    # serial build: same chain, no overlap marks
    g0 = build_mbconv_graph(specs, params)
    g0.validate()
    assert g0.pipelined_boundaries == ()


# ---------------------------------------------------------------------------
# executor parity: graph lowering == sequential loop, fwd AND grad
# ---------------------------------------------------------------------------

_PREAMBLE = textwrap.dedent("""
    import os
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh
    from repro.configs.base import ConvKernelConfig, SchedulePin
    from repro.models.mbconv import (
        EffNetConfig, efficientnet_b0_apply, efficientnet_b0_def,
        effnet_block_specs, effnet_chain_rows, mbconv_block,
    )
    from repro.models.param import materialize

    assert jax.device_count() >= 8, jax.devices()

    cfg = EffNetConfig(width_mult=0.125, num_classes=4)
    params = materialize(efficientnet_b0_def(cfg), jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (8, 24, 24, 3),
                             jnp.float32)
    specs = effnet_block_specs(cfg)

    def parse_mesh(text):
        dp, mp = (int(t) for t in text.split("x"))
        return make_mesh((dp, mp), ("data", "model"))

    def loop_reference(params, imgs, kcfg, mesh, plan):
        '''The pre-graph executor: stem + explicit sequential block loop
        + head, threading the plan pins exactly as the old code did.'''
        dt = jnp.dtype(cfg.dtype)
        x = jax.lax.conv_general_dilated(
            imgs.astype(dt), params["stem"].astype(dt), (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.silu(x)
        if plan is not None and mesh is not None \\
                and plan.stem_layout == "model_sharded":
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as _P
            from repro.kernels.convdk_sharded import MODEL_AXIS, _batch_axes
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, _P(_batch_axes(mesh), None, None,
                                          MODEL_AXIS)))
        for i, sp in enumerate(specs):
            if plan is not None:
                bp = plan.blocks[i]
                pin = SchedulePin(mode=bp.schedule.mode,
                                  residency=bp.schedule.residency,
                                  collective=bp.schedule.collective)
                x, _ = mbconv_block(x, params[f"block{i}"], stride=sp.s,
                                    cfg=kcfg, mesh=mesh, pin=pin,
                                    in_layout=bp.in_layout,
                                    overlap=bp.entry_overlap)
            else:
                x, _ = mbconv_block(x, params[f"block{i}"], stride=sp.s,
                                    cfg=kcfg, mesh=mesh)
        x = jax.nn.silu(jnp.einsum("bhwc,cd->bhwd", x,
                                   params["head"].astype(x.dtype)))
        x = x.mean(axis=(1, 2))
        return x @ params["cls_w"].astype(x.dtype) \\
            + params["cls_b"].astype(x.dtype)

    def assert_bitexact_fwd_and_grad(kcfg, mesh, plan, tag):
        got = efficientnet_b0_apply(params, imgs, cfg, kcfg=kcfg,
                                    mesh=mesh, plan=plan)
        want = loop_reference(params, imgs, kcfg, mesh, plan)
        assert jnp.array_equal(got, want), f"{tag}: forward diverged"
        g_got = jax.grad(lambda p: efficientnet_b0_apply(
            p, imgs, cfg, kcfg=kcfg, mesh=mesh, plan=plan).sum())(params)
        g_want = jax.grad(lambda p: loop_reference(
            p, imgs, kcfg, mesh, plan).sum())(params)
        leaves_got, tdef_got = jax.tree_util.tree_flatten(g_got)
        leaves_want, tdef_want = jax.tree_util.tree_flatten(g_want)
        assert tdef_got == tdef_want, tag
        for a, b in zip(leaves_got, leaves_want):
            assert jnp.array_equal(a, b), f"{tag}: grad diverged"
""")


def run_case(body: str) -> None:
    src = _PREAMBLE + textwrap.dedent(body)
    if HAVE_8:
        exec(compile(src, "<blockgraph-parity-case>", "exec"),
             {"__name__": "__blockgraph_parity__"})
        return
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    env.setdefault("CONVDK_RESIDUAL_BARRIER", "on")
    res = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]


@pytest.mark.parametrize("mesh", ["8x1", "2x4"])
def test_planned_pipelined_chain_parity(mesh):
    """The tentpole parity: the graph-lowered apply under the SOLVED plan
    (pipelined boundaries included) is bit-exact — forward and grad —
    with the explicit sequential loop threading the same plan."""
    run_case(f"""
    mesh = parse_mesh("{mesh}")
    kcfg = ConvKernelConfig(interpret=True)
    from repro.core.autotune import get_network_plan
    from repro.core.perfmodel import PerfCoefficients, set_perf_coefficients
    from repro.kernels import conv_mesh_shape
    # the default fit's base term floors the smoke model's tiny passes to
    # zero latency, so the annotation (rightly) finds no win; install a
    # positive fit so the solved plan REALLY pipelines for the parity run
    set_perf_coefficients(PerfCoefficients(
        base_us=0.0, us_per_mb=1000.0, us_per_dma_issue=1.0,
        us_per_collective_mb=1000.0, n_samples=1, rms_us=0.0))
    try:
        plan = get_network_plan(effnet_chain_rows(specs, 12, 12), 8,
                                conv_mesh_shape(mesh), dtype_bytes=4,
                                se_ratio=cfg.se_ratio)
        if conv_mesh_shape(mesh)[1] > 1:
            assert len(plan.pipelined_boundaries) >= 1, plan
        assert_bitexact_fwd_and_grad(kcfg, mesh, plan, "planned/{mesh}")
    finally:
        set_perf_coefficients(None)
    print("PLANNED_PARITY_OK {mesh}")
    """)


@pytest.mark.parametrize("mesh", ["8x1", "2x4"])
@pytest.mark.parametrize("mode", ["retain", "recompute"])
def test_pinned_mode_chain_parity(mesh, mode):
    """Graph vs loop under a pinned pass-2 mode (autotune off, so the pin
    reaches every block unchanged) — fwd and grad bit-exact."""
    run_case(f"""
    mesh = parse_mesh("{mesh}")
    kcfg = ConvKernelConfig(interpret=True, autotune=False,
                            mbconv_mode="{mode}")
    assert_bitexact_fwd_and_grad(kcfg, mesh, None, "{mode}/{mesh}")
    print("PINNED_PARITY_OK {mode} {mesh}")
    """)

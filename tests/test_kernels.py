"""Pallas ConvDK kernels vs pure-jnp oracles: shape/dtype/stride sweeps in
interpret mode (kernel bodies execute on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    causal_conv1d_ref,
    causal_conv1d_update_ref,
    convdk_causal_conv1d,
    convdk_depthwise2d,
    depthwise2d_ref,
)

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# depthwise Conv2D
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [3, 5])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_dw2d_matches_ref(k, stride, padding):
    rng = np.random.default_rng(k * 10 + stride)
    b, h, w_in, c = 2, 14, 19, 24
    x = _rand(rng, (b, h, w_in, c), jnp.float32)
    w = _rand(rng, (k, k, c), jnp.float32)
    got = convdk_depthwise2d(x, w, stride=stride, padding=padding, interpret=True)
    want = depthwise2d_ref(x, w, stride=stride, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, **TOL[jnp.float32])


@pytest.mark.parametrize("shape", [(1, 7, 7, 8), (2, 28, 28, 32),
                                   (1, 12, 33, 130), (3, 9, 8, 3)])
def test_dw2d_shape_sweep(shape):
    rng = np.random.default_rng(1)
    b, h, w_in, c = shape
    x = _rand(rng, shape, jnp.float32)
    w = _rand(rng, (3, 3, c), jnp.float32)
    got = convdk_depthwise2d(x, w, stride=1, padding="SAME", interpret=True)
    want = depthwise2d_ref(x, w, stride=1, padding="SAME")
    np.testing.assert_allclose(got, want, **TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dw2d_dtypes(dtype):
    rng = np.random.default_rng(5)
    x = _rand(rng, (2, 16, 16, 16), dtype)
    w = _rand(rng, (3, 3, 16), dtype)
    got = convdk_depthwise2d(x, w, stride=2, padding="SAME", interpret=True)
    want = depthwise2d_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                           stride=2, padding="SAME")
    np.testing.assert_allclose(np.asarray(got, np.float32), want, **TOL[dtype])


def test_dw2d_tile_h_invariance():
    """The strip tiling (IB->TRF staging granularity) must not change values."""
    rng = np.random.default_rng(7)
    x = _rand(rng, (1, 23, 17, 8), jnp.float32)
    w = _rand(rng, (3, 3, 8), jnp.float32)
    outs = [
        convdk_depthwise2d(x, w, stride=1, padding="SAME", tile_h=th,
                           interpret=True)
        for th in (1, 4, 8, 16)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# causal depthwise Conv1D (Mamba-2 / RecurrentGemma stem)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 3, 4])
@pytest.mark.parametrize("l", [8, 100, 515])
def test_conv1d_matches_ref(k, l):
    rng = np.random.default_rng(k + l)
    b, d = 2, 40
    x = _rand(rng, (b, l, d), jnp.float32)
    w = _rand(rng, (k, d), jnp.float32)
    bias = _rand(rng, (d,), jnp.float32)
    got = convdk_causal_conv1d(x, w, bias, tile_l=64, interpret=True)
    want = causal_conv1d_ref(x, w, bias)
    np.testing.assert_allclose(got, want, **TOL[jnp.float32])


@pytest.mark.parametrize("activation", [None, "silu"])
def test_conv1d_fused_activation(activation):
    rng = np.random.default_rng(3)
    x = _rand(rng, (1, 37, 16), jnp.float32)
    w = _rand(rng, (4, 16), jnp.float32)
    got = convdk_causal_conv1d(x, w, None, activation=activation,
                               tile_l=16, interpret=True)
    want = causal_conv1d_ref(x, w, None, activation=activation)
    np.testing.assert_allclose(got, want, **TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_dtypes(dtype):
    rng = np.random.default_rng(9)
    x = _rand(rng, (2, 64, 128), dtype)
    w = _rand(rng, (4, 128), dtype)
    got = convdk_causal_conv1d(x, w, None, tile_l=32, interpret=True)
    want = causal_conv1d_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), want, **TOL[dtype])


def test_conv1d_decode_step_consistent_with_prefill():
    """Streaming decode (update ref) must continue the prefill exactly."""
    rng = np.random.default_rng(11)
    b, l, d, k = 2, 20, 12, 4
    x = _rand(rng, (b, l, d), jnp.float32)
    w = _rand(rng, (k, d), jnp.float32)
    full = causal_conv1d_ref(x, w)

    state = jnp.zeros((b, k - 1, d))
    ys = []
    for t in range(l):
        y, state = causal_conv1d_update_ref(state, x[:, t], w)
        ys.append(y)
    stream = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(stream, full, rtol=1e-5, atol=1e-5)


def test_conv1d_grad_flows():
    rng = np.random.default_rng(13)
    x = _rand(rng, (1, 32, 8), jnp.float32)
    w = _rand(rng, (4, 8), jnp.float32)

    def loss(w):
        return convdk_causal_conv1d(x, w, None, tile_l=16, interpret=True).sum()

    def loss_ref(w):
        return causal_conv1d_ref(x, w).sum()

    g = jax.grad(loss)(w)
    g_ref = jax.grad(loss_ref)(w)
    np.testing.assert_allclose(g, g_ref, rtol=1e-5, atol=1e-5)

"""The §Perf optimization knobs must not change numerics.

Each knob is validated two ways: (a) single-device — flag on == flag off
bit-near; (b) 8-virtual-device subprocess — sharded+flagged == unsharded
reference (the same harness as test_distributed).
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import forward, model_def
from repro.models.param import materialize

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_vocab_padding_preserves_logits():
    cfg = get_arch("granite-moe-3b-a800m").smoke
    cfgp = dataclasses.replace(cfg, vocab_pad_multiple=16)
    assert cfgp.padded_vocab % 16 == 0 and cfgp.padded_vocab >= cfg.vocab

    params = materialize(model_def(cfgp), jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    lg = forward(params, {"tokens": toks}, cfgp)
    assert lg.shape[-1] == cfgp.padded_vocab
    # padded classes are masked to -inf -> argmax never selects them
    assert int(jnp.argmax(lg, -1).max()) < cfg.vocab
    assert bool((lg[..., cfg.vocab:] < -1e29).all())


@pytest.mark.parametrize("flags", [
    {"seq_shard_attn": True},
    {"seq_shard_attn": True, "vocab_pad_multiple": 16},
    {"seq_shard_resid": True},
])
def test_knobs_noop_on_single_device(flags):
    """Without a mesh the knobs must be exact no-ops numerically."""
    cfg = get_arch("qwen1.5-4b").smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    ref = forward(params, {"tokens": toks}, cfg)

    cfg2 = dataclasses.replace(cfg, **flags)
    if cfg2.padded_vocab == cfg.vocab:
        out = forward(params, {"tokens": toks}, cfg2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch
    from repro.models.model import forward, model_def
    from repro.models.param import materialize, logical_axes
    from repro.sharding import tree_shardings, spec_for
    from repro.compat import activate_mesh, make_mesh
    from jax.sharding import NamedSharding

    cfg = get_arch("qwen1.5-4b").smoke
    # 4-way model axis; qwen smoke has 4 heads -> divisible, so FORCE the
    # seq-shard path by giving it 3 kv heads? instead use n_kv_heads=2 with
    # model=4 -> non-divisible -> SP engages.
    cfg = dataclasses.replace(cfg, n_kv_heads=2, seq_shard_attn=True,
                              seq_shard_resid=True, vocab_pad_multiple=16)
    pdefs = model_def(cfg)
    params = materialize(pdefs, jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    ref = forward(params, {"tokens": toks}, cfg)   # no mesh: knobs dormant

    mesh = make_mesh((2, 4), ("data", "model"))
    with activate_mesh(mesh):
        p_sh = tree_shardings(logical_axes(pdefs), params, mesh)
        params_s = jax.device_put(params, p_sh)
        toks_s = jax.device_put(toks, NamedSharding(
            mesh, spec_for(["batch", None], toks.shape, mesh)))
        out = jax.jit(lambda p, t: forward(p, {"tokens": t}, cfg))(
            params_s, toks_s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-3, atol=3e-3)
    print("PERF_KNOBS_OK")
""")


def test_knobs_sharded_equal_unsharded():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "PERF_KNOBS_OK" in res.stdout

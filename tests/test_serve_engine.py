"""Serving engine integration: generation runs for every decoder family,
prefill-via-scan matches forward, BIG/LITTLE admission buckets correctly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import forward, model_def
from repro.models.param import materialize
from repro.serve.engine import Engine, ServeConfig

DECODERS = ["gemma-2b", "mamba2-2.7b", "recurrentgemma-9b",
            "deepseek-v2-236b"]


@pytest.mark.parametrize("arch", DECODERS)
def test_generate_runs(arch):
    cfg = get_arch(arch).smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=4))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    out = eng.generate(prompts)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab).all()


def test_prefill_matches_forward():
    cfg = get_arch("gemma-2b").smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=2))
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)

    from repro.models.model import init_decode_state
    state = init_decode_state(cfg, 2, 16, jnp.float32)
    _, last_logits = eng._prefill(params, prompts, state)
    full = forward(params, {"tokens": prompts}, cfg)
    np.testing.assert_allclose(np.asarray(last_logits),
                               np.asarray(full[:, -1]), rtol=4e-3, atol=4e-3)


def test_big_little_admission():
    cfg = get_arch("gemma-2b").smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(little_threshold=16))
    reqs = [np.zeros(4), np.zeros(100), np.zeros(8), np.zeros(5),
            np.zeros(200)] + [np.zeros(3)] * 8
    batches = eng.schedule(reqs)
    little = [b for b in batches if len(b) > 1]
    big = [b for b in batches if len(b) == 1]
    assert little and big
    assert {i for b in big for i in b} == {1, 4}
    assert all(len(reqs[i]) < 16 for b in little for i in b)

"""Serving engine integration: generation runs for every decoder family,
prefill-via-scan matches forward, BIG/LITTLE admission buckets correctly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import forward, model_def
from repro.models.param import materialize
from repro.serve.engine import Engine, ServeConfig

DECODERS = ["gemma-2b", "mamba2-2.7b", "recurrentgemma-9b",
            "deepseek-v2-236b"]


@pytest.mark.parametrize("arch", DECODERS)
def test_generate_runs(arch):
    cfg = get_arch(arch).smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=4))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    out = eng.generate(prompts)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab).all()


def test_prefill_matches_forward():
    cfg = get_arch("gemma-2b").smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=2))
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)

    from repro.models.model import init_decode_state
    state = init_decode_state(cfg, 2, 16, jnp.float32)
    _, last_logits = eng._prefill(params, prompts, state)
    full = forward(params, {"tokens": prompts}, cfg)
    np.testing.assert_allclose(np.asarray(last_logits),
                               np.asarray(full[:, -1]), rtol=4e-3, atol=4e-3)


def test_big_little_admission():
    cfg = get_arch("gemma-2b").smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(little_threshold=16))
    reqs = [np.zeros(4), np.zeros(100), np.zeros(8), np.zeros(5),
            np.zeros(200)] + [np.zeros(3)] * 8
    batches = eng.schedule(reqs)
    little = [b for b in batches if len(b) > 1]
    big = [b for b in batches if len(b) == 1]
    assert little and big
    assert {i for b in big for i in b} == {1, 4}
    assert all(len(reqs[i]) < 16 for b in little for i in b)


def test_eos_early_stop_and_masking():
    """eos_id is load-bearing: rows past EOS emit eos_id for the rest of
    the row, and once every row finishes the decode loop stops early."""
    cfg = get_arch("gemma-2b").smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    base = Engine(cfg, params, ServeConfig(max_new_tokens=6))
    prompts = np.zeros((2, 4), np.int32)
    ref = base.generate(prompts)

    # pick the first token greedy decoding actually emits as the EOS id:
    # every row then finishes immediately and the rest must be eos-filled
    eos = int(ref[0, 0])
    assert int(ref[1, 0]) == eos  # identical prompts -> identical greedy row
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=6, eos_id=eos))
    out = eng.generate(prompts)
    assert out.shape == (2, 6)
    assert (out == eos).all()

    # and a non-matching eos id must leave greedy output untouched
    never = int(ref.max()) + 1
    eng2 = Engine(cfg, params, ServeConfig(max_new_tokens=6, eos_id=never))
    np.testing.assert_array_equal(eng2.generate(prompts), ref)


def test_sampled_rngs_differ():
    """Two sampled calls must differ when seeded differently — and the
    rng=None default must derive a fresh key per call (not replay key(0))."""
    cfg = get_arch("gemma-2b").smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    # the smoke model's random-init logits are sharply peaked; a high
    # temperature flattens them enough that sampling has real entropy
    scfg = ServeConfig(max_new_tokens=16, greedy=False, temperature=50.0)
    eng = Engine(cfg, params, scfg)
    rng = np.random.default_rng(2)
    prompts = rng.integers(1, cfg.vocab, (2, 4)).astype(np.int32)

    a = eng.generate(prompts, jax.random.key(1))
    b = eng.generate(prompts, jax.random.key(2))
    assert (a != b).any()

    # default-rng path: the per-call fold_in must advance
    c = eng.generate(prompts)
    d = eng.generate(prompts)
    assert (c != d).any()

    # but an explicit key stays reproducible
    e = eng.generate(prompts, jax.random.key(1))
    np.testing.assert_array_equal(a, e)


def test_generate_many_pads_and_orders():
    """generate_many consumes schedule(): mixed-length prompts come back
    in request order, and a packed prompt's output matches running the
    same prompt alone left-padded to its bucket."""
    cfg = get_arch("gemma-2b").smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    scfg = ServeConfig(max_new_tokens=4, little_threshold=16,
                       little_pack=2, length_bucket=8)
    eng = Engine(cfg, params, scfg)
    rng = np.random.default_rng(2)
    reqs = [rng.integers(1, cfg.vocab, n).astype(np.int32)
            for n in (3, 7, 100, 5)]
    outs = eng.generate_many(reqs)
    assert len(outs) == len(reqs)
    assert all(o.shape == (4,) for o in outs)

    # request 0 (len 3) packs into the len<=8 bucket: same tokens must
    # come from a solo left-padded (1, 8) prompt
    solo = np.full((1, 8), scfg.pad_id, np.int32)
    solo[0, 8 - 3:] = reqs[0]
    np.testing.assert_array_equal(outs[0], eng.generate(solo)[0])

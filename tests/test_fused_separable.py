"""Fused depthwise-separable ConvDK kernel vs the XLA oracle, the autotune
schedule layer, and the fused-vs-staged HBM traffic accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import (
    TPUConfig,
    candidate_schedules,
    get_fused_schedule,
    select_fused_schedule,
    vmem_footprint_bytes,
)
from repro.core.perfmodel import (
    SeparableShape,
    fused_separable_traffic,
    staged_separable_traffic,
)
from repro.core.workloads import MOBILENET_V2_SEPARABLE
from repro.kernels import (
    convdk_fused_separable,
    convdk_separable_staged,
    separable_ref,
)

TOL = dict(rtol=1e-4, atol=1e-4)


def _rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _oracle(x, w_dw, w_pw, stride, padding="SAME"):
    """Independent oracle: lax depthwise conv composed with lax.dot_general
    for the pointwise stage (NOT the repo's separable_ref)."""
    k_h, k_w, c = w_dw.shape
    dw = jax.lax.conv_general_dilated(
        x, jnp.transpose(w_dw, (2, 0, 1))[:, None],
        window_strides=(stride, stride), padding=padding,
        feature_group_count=c,
        dimension_numbers=("NHWC", "OIHW", "NHWC"))
    return jax.lax.dot_general(
        dw, w_pw, dimension_numbers=(((3,), (0,)), ((), ())))


# ---------------------------------------------------------------------------
# numerics vs the XLA DW+PW oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [3, 5])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_fused_matches_xla_oracle(k, stride, padding):
    rng = np.random.default_rng(k * 10 + stride)
    b, h, w_in, ci, co = 2, 15, 19, 24, 40        # odd H, odd W
    x = _rand(rng, (b, h, w_in, ci))
    w_dw = _rand(rng, (k, k, ci))
    w_pw = _rand(rng, (ci, co))
    got = convdk_fused_separable(x, w_dw, w_pw, stride=stride,
                                 padding=padding, interpret=True)
    want = _oracle(x, w_dw, w_pw, stride, padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("shape", [
    (1, 7, 7, 8, 16),        # LITTLE-regime ifmap, tiny channels
    (2, 13, 11, 130, 40),    # >128 input channels: multi-ci-block reduction
    (1, 9, 33, 32, 200),     # >128 output channels: multi-co-block grid
    (3, 28, 28, 96, 24),     # MobileNet-V2-like block
])
def test_fused_shape_sweep(shape):
    rng = np.random.default_rng(1)
    b, h, w_in, ci, co = shape
    x = _rand(rng, (b, h, w_in, ci))
    w_dw = _rand(rng, (3, 3, ci))
    w_pw = _rand(rng, (ci, co))
    got = convdk_fused_separable(x, w_dw, w_pw, stride=1, interpret=True)
    want = _oracle(x, w_dw, w_pw, 1)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("tile_h", [1, 3, 8, 32])
def test_fused_tile_h_invariant(tile_h):
    """Any tile_h gives the same numbers — schedule is perf-only."""
    rng = np.random.default_rng(7)
    x = _rand(rng, (1, 17, 13, 16))
    w_dw = _rand(rng, (3, 3, 16))
    w_pw = _rand(rng, (16, 24))
    got = convdk_fused_separable(x, w_dw, w_pw, stride=2, tile_h=tile_h,
                                 interpret=True)
    want = _oracle(x, w_dw, w_pw, 2)
    np.testing.assert_allclose(got, want, **TOL)


def test_fused_mid_block_activation():
    """dw_act fuses exactly: DW is depthwise, so the per-channel-block DW
    accumulator is final before the PW contraction."""
    rng = np.random.default_rng(3)
    x = _rand(rng, (2, 12, 12, 16))
    w_dw = _rand(rng, (3, 3, 16))
    w_pw = _rand(rng, (16, 8))
    got = convdk_fused_separable(x, w_dw, w_pw, stride=1, dw_act="relu6",
                                 act="relu", interpret=True)
    dw = jax.lax.conv_general_dilated(
        x, jnp.transpose(w_dw, (2, 0, 1))[:, None], (1, 1), "SAME",
        feature_group_count=16, dimension_numbers=("NHWC", "OIHW", "NHWC"))
    want = jax.nn.relu(jnp.clip(dw, 0.0, 6.0) @ w_pw)
    np.testing.assert_allclose(got, want, **TOL)


def test_fused_matches_staged_pipeline():
    """The fused kernel and the staged two-kernel path are the same math."""
    rng = np.random.default_rng(9)
    x = _rand(rng, (2, 14, 14, 48))
    w_dw = _rand(rng, (5, 5, 48))
    w_pw = _rand(rng, (48, 64))
    for s in (1, 2):
        fused = convdk_fused_separable(x, w_dw, w_pw, stride=s,
                                       dw_act="relu", interpret=True)
        staged = convdk_separable_staged(x, w_dw, w_pw, stride=s,
                                         dw_act="relu", interpret=True)
        np.testing.assert_allclose(fused, staged, **TOL)


def test_fused_grad_matches_reference():
    rng = np.random.default_rng(5)
    x = _rand(rng, (1, 10, 11, 8))
    w_dw = _rand(rng, (3, 3, 8))
    w_pw = _rand(rng, (8, 12))

    def loss(fn):
        return lambda x_, wd_, wp_: (fn(x_, wd_, wp_) ** 2).sum()

    f = loss(lambda a, b, c: convdk_fused_separable(
        a, b, c, stride=2, dw_act="relu", interpret=True))
    r = loss(lambda a, b, c: separable_ref(a, b, c, stride=2, dw_act="relu"))
    g = jax.grad(f, argnums=(0, 1, 2))(x, w_dw, w_pw)
    g_ref = jax.grad(r, argnums=(0, 1, 2))(x, w_dw, w_pw)
    for got, want in zip(g, g_ref):
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# traffic accounting + autotune
# ---------------------------------------------------------------------------

def test_fused_traffic_below_staged_all_mbv2_layers():
    """The tentpole claim, asserted layer by layer: the fused pipeline's
    modeled HBM traffic is <= (strictly below) the staged two-kernel path
    for every MobileNet-V2 separable block."""
    assert len(MOBILENET_V2_SEPARABLE) == 17
    for layer, c_out in MOBILENET_V2_SEPARABLE:
        sch = get_fused_schedule(1, layer.h, layer.w, layer.c, c_out,
                                 layer.k, layer.s)
        assert sch.traffic.total_bytes < sch.staged_traffic.total_bytes, \
            (layer, c_out, sch)


def test_fused_traffic_below_staged_any_tile_h():
    """Not an autotune artifact: fused wins at every candidate tile_h too."""
    shape = SeparableShape(b=1, h=28, w=28, c_in=192, c_out=64, k=3, s=2)
    for th in (1, 2, 4, 8, 14):
        fused = fused_separable_traffic(shape, th)
        staged = staged_separable_traffic(shape, th)
        assert fused.total_bytes < staged.total_bytes, th


def test_pick_channel_block_minimizes_padding():
    """Channel blocking must not inflate real MobileNet widths: every c
    divisible by 8 gets a zero-padding block; ties go to the widest."""
    from repro.core.perfmodel import pick_channel_block
    for c, want in [(144, 72), (192, 96), (576, 96), (960, 120),
                    (384, 128), (128, 128), (32, 32), (8, 8)]:
        assert pick_channel_block(c) == want, (c, want)
    for c in range(1, 300):
        b = pick_channel_block(c)
        assert b % 8 == 0 and 8 <= b <= 128
        # never worse than the naive min(128, round_up(c, 8)) cap
        naive = min(128, -(-c // 8) * 8)
        pad_b = -(-c // b) * b - c
        pad_naive = -(-c // naive) * naive - c
        assert pad_b <= pad_naive, (c, b, naive)


def test_autotune_respects_vmem_budget():
    tpu = TPUConfig(vmem_bytes=256 * 1024)
    shape = SeparableShape(b=1, h=112, w=112, c_in=96, c_out=24, k=3, s=1)
    for cand in candidate_schedules(shape, tpu):
        assert vmem_footprint_bytes(shape, cand.tile_h, tpu,
                                    cand.residency) <= tpu.vmem_bytes


def test_autotune_selects_minimum_traffic():
    shape = SeparableShape(b=1, h=56, w=56, c_in=144, c_out=24, k=3, s=1)
    best = select_fused_schedule(shape)
    for cand in candidate_schedules(shape):
        assert best.traffic.total_bytes <= cand.traffic.total_bytes
    assert 1 <= best.tile_h <= shape.out_h
    assert best.modeled_saving > 0


def test_autotuned_schedule_runs():
    """The selected schedule is directly runnable on the kernel."""
    rng = np.random.default_rng(11)
    b, h, w_in, ci, co, s = 1, 28, 28, 96, 24, 2
    sch = get_fused_schedule(b, h, w_in, ci, co, 3, s)
    x = _rand(rng, (b, h, w_in, ci))
    w_dw = _rand(rng, (3, 3, ci))
    w_pw = _rand(rng, (ci, co))
    got = convdk_fused_separable(x, w_dw, w_pw, stride=s,
                                 tile_h=sch.tile_h, interpret=True)
    want = _oracle(x, w_dw, w_pw, s)
    np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# model-layer routing
# ---------------------------------------------------------------------------

def test_separable_block_routes_both_paths():
    from repro.configs.base import ConvKernelConfig
    from repro.models.common import separable_block, separable_def
    from repro.models.param import materialize

    params = materialize(separable_def(16, 24), jax.random.key(0))
    rng = np.random.default_rng(2)
    x = _rand(rng, (2, 14, 14, 16))
    fused = separable_block(
        params, x, stride=2,
        kcfg=ConvKernelConfig(fused_separable=True, interpret=True))
    staged = separable_block(
        params, x, stride=2,
        kcfg=ConvKernelConfig(fused_separable=False, interpret=True))
    assert fused.shape == (2, 7, 7, 24)
    np.testing.assert_allclose(fused, staged, **TOL)


def test_vlm_vision_stem_forward():
    from repro.models.model import ModelConfig, forward, model_def
    from repro.models.param import materialize

    cfg = ModelConfig(name="vlm-stem", family="vlm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                      vocab=64, dtype="float32", vision_stem=True,
                      vision_stem_c0=8, vision_stem_blocks=2)
    params = materialize(model_def(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    imgs = _rand(rng, (2, 32, 32, 3))
    logits = forward(params, {"tokens": toks, "images": imgs}, cfg)
    # 32 -> 16 (stem/2) -> 8 -> 4: 16 patch tokens prepended to 6 text tokens
    assert logits.shape == (2, 16 + 6, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

"""Persistent (JSON) schedule cache: disk round-trips, measured-entry
priority, and graceful degradation without a cache dir."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import (
    ScheduleCache,
    TPUConfig,
    benchmark_fused_sweep,
    get_fused_schedule,
    get_mbconv_schedule,
    get_schedule_cache,
    set_schedule_cache_dir,
)


@pytest.fixture
def cache_dir(tmp_path):
    """Point the global schedule cache at a temp dir; restore afterwards."""
    cache = set_schedule_cache_dir(tmp_path)
    yield tmp_path, cache
    set_schedule_cache_dir(None)


def _entries(tmp_path):
    payload = json.loads((tmp_path / "convdk_schedules.json").read_text())
    assert payload["version"] == 1
    return payload["entries"]


def test_schedule_persists_to_disk(cache_dir):
    tmp_path, cache = cache_dir
    sch = get_fused_schedule(1, 56, 56, 144, 24, 3, 1)
    entries = _entries(tmp_path)
    (key,) = [k for k in entries if k.startswith("sep|")]
    assert "b1-h56-w56-ci144-co24-k3-s1" in key
    assert entries[key]["tile_h"] == sch.tile_h
    assert entries[key]["source"] == "model"

    msch = get_mbconv_schedule(1, 14, 14, 80, 480, 112, 5, 1)
    entries = _entries(tmp_path)
    (mkey,) = [k for k in entries if k.startswith("mbconv|")]
    assert "ci80-cm480-co112-k5-s1" in mkey
    assert entries[mkey]["mode"] == msch.mode


def test_disk_entry_survives_process_restart(cache_dir):
    """A restart is simulated by dropping the in-process layer: the lookup
    must come back from the JSON file (proved by editing the file)."""
    tmp_path, cache = cache_dir
    get_fused_schedule(1, 28, 28, 192, 64, 3, 2)
    entries = _entries(tmp_path)
    (key,) = list(entries)
    edited = dict(entries[key], tile_h=2, source="measured")
    (tmp_path / "convdk_schedules.json").write_text(
        json.dumps({"version": 1, "entries": {key: edited}}))

    cache.clear_memory()                       # "new process"
    sch = get_fused_schedule(1, 28, 28, 192, 64, 3, 2)
    assert sch.tile_h == 2                     # came from disk, not the model


def test_measured_sweep_persists_and_outranks_model(cache_dir):
    tmp_path, cache = cache_dir
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 12, 12, 8)), jnp.float32)
    w_dw = jnp.asarray(rng.normal(size=(3, 3, 8)), jnp.float32)
    w_pw = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    best, results = benchmark_fused_sweep(
        x, w_dw, w_pw, stride=1, tile_hs=[1, 4], iters=1, interpret=True,
        persist=True)
    assert dict(results).keys() == {1, 4}
    entries = _entries(tmp_path)
    (key,) = [k for k in entries if "ci8-co16" in k]
    assert entries[key]["source"] == "measured"
    assert entries[key]["tile_h"] == best

    # a later model pick must NOT clobber the measured ground truth...
    cache.clear_memory()
    sch = get_fused_schedule(1, 12, 12, 8, 16, 3, 1)
    assert sch.tile_h == best
    assert _entries(tmp_path)[key]["source"] == "measured"


def test_invalid_disk_tile_h_falls_back_to_model(cache_dir):
    tmp_path, cache = cache_dir
    get_fused_schedule(1, 16, 16, 8, 8, 3, 1)
    entries = _entries(tmp_path)
    (key,) = list(entries)
    entries[key]["tile_h"] = 9999              # > out_h: stale / corrupt
    (tmp_path / "convdk_schedules.json").write_text(
        json.dumps({"version": 1, "entries": entries}))
    cache.clear_memory()
    sch = get_fused_schedule(1, 16, 16, 8, 8, 3, 1)
    assert 1 <= sch.tile_h <= 16


def test_malformed_entry_falls_back_to_model(cache_dir):
    """A valid-JSON file with a garbage ENTRY (wrong type, missing or
    non-numeric tile_h, bad mode) must degrade to the analytical model,
    never crash schedule lookup."""
    tmp_path, cache = cache_dir
    want = get_fused_schedule(1, 16, 16, 8, 8, 3, 1)
    mwant = get_mbconv_schedule(1, 14, 14, 16, 64, 24, 3, 1)
    entries = _entries(tmp_path)
    (skey,) = [k for k in entries if k.startswith("sep|")]
    (mkey,) = [k for k in entries if k.startswith("mbconv|")]
    for bad_sep, bad_mb in [
        ("garbage", "garbage"),                      # non-dict entry
        ({}, {}),                                    # missing tile_h
        ({"tile_h": "huge"}, {"tile_h": None}),      # non-numeric tile_h
        ({"tile_h": [4]}, {"tile_h": 4, "mode": "teleport"}),  # bad mode
    ]:
        (tmp_path / "convdk_schedules.json").write_text(json.dumps(
            {"version": 1, "entries": {skey: bad_sep, mkey: bad_mb}}))
        cache.clear_memory()
        assert get_fused_schedule(1, 16, 16, 8, 8, 3, 1) == want
        assert get_mbconv_schedule(1, 14, 14, 16, 64, 24, 3, 1) == mwant


def test_cache_key_includes_full_tpu_config(cache_dir):
    """Schedules solved under one TPUConfig are never reused for another:
    c_block and the tile_h candidate set are part of the key."""
    tmp_path, _cache = cache_dir
    base = TPUConfig()
    get_fused_schedule(1, 56, 56, 144, 24, 3, 1, tpu=base)
    narrow = TPUConfig(c_block=64)
    sch = get_fused_schedule(1, 56, 56, 144, 24, 3, 1, tpu=narrow)
    assert sch.co_block <= 64                    # solved, not cache-echoed
    coarse = TPUConfig(tile_h_candidates=(2,))
    sch2 = get_fused_schedule(1, 56, 56, 144, 24, 3, 1, tpu=coarse)
    assert sch2.tile_h == 2
    assert len(_entries(tmp_path)) == 3          # three distinct keys


def test_sharded_and_unsharded_picks_do_not_collide(cache_dir):
    """Regression for the mesh_shape cache axis: sharded and unsharded
    schedules for the SAME layer shape live under distinct keys, and a
    disk round-trip edits exactly the partitioning it targets."""
    tmp_path, cache = cache_dir
    base = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1)
    sharded = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                                  mesh_shape=(2, 4))
    assert base.mesh_shape == (1, 1) and sharded.mesh_shape == (2, 4)
    entries = _entries(tmp_path)
    keys = [k for k in entries if k.startswith("mbconv|")]
    assert len(keys) == 2                      # no collision
    (ukey,) = [k for k in keys if "|mesh1x1|" in k]
    (skey,) = [k for k in keys if "|mesh2x4|" in k]
    assert ukey.replace("|mesh1x1|", "|mesh2x4|") == skey   # same layer key

    # round-trip: a measured edit to the SHARDED entry survives a
    # "restart" and steers only the sharded lookup
    entries[skey] = dict(entries[skey], tile_h=1, mode="recompute",
                         source="measured")
    (tmp_path / "convdk_schedules.json").write_text(
        json.dumps({"version": 1, "entries": entries}))
    cache.clear_memory()
    again = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                                mesh_shape=(2, 4))
    assert (again.tile_h, again.mode) == (1, "recompute")
    unsharded = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1)
    assert (unsharded.tile_h, unsharded.mode) == (base.tile_h, base.mode)

    # separable family too, and non-divisible grids normalize to the
    # EFFECTIVE factors — all-or-nothing, exactly the kernel routing's
    # can_shard_fused policy, so the cache never holds a partitioning the
    # kernels will not run
    get_fused_schedule(8, 28, 28, 64, 64, 3, 1)
    sharded_sep = get_fused_schedule(8, 28, 28, 64, 64, 3, 1,
                                     mesh_shape=(4, 2))
    assert sharded_sep.mesh_shape == (4, 2)
    half = get_fused_schedule(8, 28, 28, 64, 63, 3, 1, mesh_shape=(4, 2))
    assert half.mesh_shape == (1, 1)           # batch divides, c_out no ->
    odd = get_fused_schedule(7, 28, 28, 64, 63, 3, 1, mesh_shape=(4, 2))
    assert odd.mesh_shape == (1, 1)            # ... whole layer 1-core
    sep_keys = [k for k in _entries(tmp_path) if k.startswith("sep|")]
    assert sorted(k.split("|")[3] for k in sep_keys) == \
        ["mesh1x1", "mesh1x1", "mesh1x1", "mesh4x2"]


def test_legacy_pre_mesh_keys_migrate(cache_dir):
    """Entries persisted before the mesh_shape key axis (no ``mesh``
    segment) were all single-device picks: they must be honored as the
    ``mesh1x1`` entries — a measured sweep from an old deployment keeps
    outranking model picks instead of being silently orphaned."""
    tmp_path, cache = cache_dir
    sch = get_fused_schedule(1, 28, 28, 192, 64, 3, 2)
    (key,) = list(_entries(tmp_path))
    # the pre-mesh era predates BOTH later key axes (mesh and residency)
    legacy_key = key.replace("|mesh1x1|", "|").replace("|res=auto|", "|")
    assert "|mesh" not in legacy_key and "|res=" not in legacy_key \
        and len(legacy_key.split("|")) == 5
    edited = 2 if sch.tile_h != 2 else 4
    (tmp_path / "convdk_schedules.json").write_text(json.dumps(
        {"version": 1,
         "entries": {legacy_key: {"tile_h": edited, "source": "measured"}}}))
    cache.clear_memory()                       # "new process", old file
    assert get_fused_schedule(1, 28, 28, 192, 64, 3, 2).tile_h == edited


def test_legacy_pre_collective_keys_migrate(cache_dir):
    """MBConv entries persisted before the collective axis (no ``coll=``
    segment) must be honored as the ``coll=auto`` picks — the measured
    (tile_h, mode) wins and the collective is re-solved at that point —
    while separable keys never grow the segment."""
    tmp_path, cache = cache_dir
    sch = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                              mesh_shape=(2, 4))
    assert sch.collective in ("ring_allreduce", "psum_scatter")
    (key,) = list(_entries(tmp_path))
    assert "|coll=auto|" in key
    legacy_key = key.replace("|coll=auto|", "|")       # pre-collective era
    edited_th = 1 if sch.tile_h != 1 else 2
    (tmp_path / "convdk_schedules.json").write_text(json.dumps(
        {"version": 1,
         "entries": {legacy_key: {"tile_h": edited_th, "mode": "recompute",
                                  "source": "measured"}}}))
    cache.clear_memory()                               # "new process"
    again = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                                mesh_shape=(2, 4))
    assert (again.tile_h, again.mode) == (edited_th, "recompute")
    assert again.collective in ("ring_allreduce", "psum_scatter")

    # a pinned collective solves (and caches) under its own key
    ring = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                               mesh_shape=(2, 4),
                               collective="ring_allreduce")
    assert ring.collective == "ring_allreduce"
    assert any("|coll=ring_allreduce|" in k for k in _entries(tmp_path))

    get_fused_schedule(8, 28, 28, 64, 64, 3, 1, mesh_shape=(2, 4))
    sep_keys = [k for k in _entries(tmp_path) if k.startswith("sep|")]
    assert sep_keys and all("coll=" not in k for k in sep_keys)


def test_legacy_pre_layout_keys_migrate(cache_dir):
    """MBConv entries persisted before the input-layout axis (no
    ``layout=`` segment) were all solved for a replicated arrival — the
    only entry form that existed — so they must be honored as the
    ``layout=replicated`` picks after a disk round-trip, while a
    c_in-sharded arrival solves (and caches) under its own
    ``layout=model_sharded`` key instead of echoing the replicated
    schedule."""
    tmp_path, cache = cache_dir
    sch = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                              mesh_shape=(2, 4))
    (key,) = list(_entries(tmp_path))
    assert "|layout=replicated|" in key
    legacy_key = key.replace("|layout=replicated|", "|")   # pre-layout era
    assert "layout=" not in legacy_key
    edited_th = 1 if sch.tile_h != 1 else 2
    (tmp_path / "convdk_schedules.json").write_text(json.dumps(
        {"version": 1,
         "entries": {legacy_key: {"tile_h": edited_th, "mode": "recompute",
                                  "source": "measured"}}}))
    cache.clear_memory()                                   # "new process"
    again = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                                mesh_shape=(2, 4))
    assert (again.tile_h, again.mode) == (edited_th, "recompute")
    assert again.in_layout == "replicated"

    # a sharded arrival must NOT hit the migrated replicated entry: it
    # solves fresh and persists under layout=model_sharded
    sharded = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                                  mesh_shape=(2, 4),
                                  in_layout="model_sharded")
    assert sharded.in_layout == "model_sharded"
    keys = list(_entries(tmp_path))
    assert any("|layout=model_sharded|" in k for k in keys)
    assert any("|layout=replicated|" in k for k in keys)


def test_legacy_pre_overlap_keys_migrate(cache_dir):
    """MBConv entries persisted before the cross-block overlap axis (no
    ``ov=`` segment) were all solved under the serial-entry VMEM budget —
    so they must be honored as the ``ov=serial`` picks after a disk
    round-trip, while a pipelined entry (halved pass-1 VMEM budget)
    solves and caches under its own ``ov=pipelined`` key instead of
    echoing the serial schedule."""
    tmp_path, cache = cache_dir
    sch = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                              mesh_shape=(2, 4))
    (key,) = list(_entries(tmp_path))
    assert "|ov=serial|" in key
    legacy_key = key.replace("|ov=serial|", "|")           # pre-overlap era
    assert "ov=" not in legacy_key
    edited_th = 1 if sch.tile_h != 1 else 2
    (tmp_path / "convdk_schedules.json").write_text(json.dumps(
        {"version": 1,
         "entries": {legacy_key: {"tile_h": edited_th, "mode": "recompute",
                                  "source": "measured"}}}))
    cache.clear_memory()                                   # "new process"
    again = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                                mesh_shape=(2, 4))
    assert (again.tile_h, again.mode) == (edited_th, "recompute")
    assert again.overlap == "serial"

    # a pipelined entry must NOT hit the migrated serial entry: it
    # solves fresh (halved pass-1 budget) and persists under ov=pipelined
    pipe = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                               mesh_shape=(2, 4), overlap="pipelined")
    assert pipe.overlap == "pipelined"
    keys = list(_entries(tmp_path))
    assert any("|ov=pipelined|" in k for k in keys)
    assert any("|ov=serial|" in k for k in keys)

    # separable keys never grow the segment
    get_fused_schedule(8, 28, 28, 64, 64, 3, 1, mesh_shape=(2, 4))
    sep_keys = [k for k in _entries(tmp_path) if k.startswith("sep|")]
    assert sep_keys and all("ov=" not in k for k in sep_keys)


def test_legacy_pre_family_keys_migrate(cache_dir):
    """MBConv entries persisted before the family axes (no ``act=`` /
    ``se=`` segments) were all silu + SE-on picks — the only variant that
    existed — so they must be honored as the ``act=silu|se=on`` entries
    after a disk round-trip (no cold re-solve of a measured schedule),
    while se=off and hard_swish solves cache under their OWN keys instead
    of echoing the migrated pick."""
    tmp_path, cache = cache_dir
    sch = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                              mesh_shape=(2, 4))
    (key,) = list(_entries(tmp_path))
    assert "|act=silu|se=on|" in key
    legacy_key = key.replace("|act=silu|se=on|", "|")    # pre-family era
    assert "act=" not in legacy_key and "se=" not in legacy_key
    edited_th = 1 if sch.tile_h != 1 else 2
    (tmp_path / "convdk_schedules.json").write_text(json.dumps(
        {"version": 1,
         "entries": {legacy_key: {"tile_h": edited_th, "mode": "recompute",
                                  "source": "measured"}}}))
    cache.clear_memory()                                 # "new process"
    again = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                                mesh_shape=(2, 4))
    assert (again.tile_h, again.mode) == (edited_th, "recompute")

    # the se=off and hard_swish variants must NOT hit the migrated silu
    # se-on entry: they solve fresh and persist under their own segments
    no_se = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                                mesh_shape=(2, 4), se_ratio=0.0)
    hs = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1,
                             mesh_shape=(2, 4), act="hard_swish")
    assert no_se.traffic.total_bytes <= again.traffic.total_bytes
    keys = list(_entries(tmp_path))
    assert any("|act=silu|se=off|" in k for k in keys)
    assert any("|act=hard_swish|se=on|" in k for k in keys)
    assert hs.tile_h >= 1

    # the CHAIN end to end: a key from the original (pre-mesh, pre-res,
    # pre-coll, pre-layout, pre-overlap, pre-family) era walks all six
    # migrations and still lands on the modern entry
    oldest = key
    for seg in ("|mesh2x4|", "|res=auto|", "|coll=auto|",
                "|layout=replicated|", "|ov=serial|", "|act=silu|se=on|"):
        oldest = oldest.replace(seg, "|")
    assert len(oldest.split("|")) < len(key.split("|"))
    (tmp_path / "convdk_schedules.json").write_text(json.dumps(
        {"version": 1,
         "entries": {oldest: {"tile_h": edited_th, "mode": "recompute",
                              "source": "measured"}}}))
    cache.clear_memory()
    chained = get_mbconv_schedule(8, 14, 14, 80, 480, 112, 5, 1)
    assert (chained.tile_h, chained.mode) == (edited_th, "recompute")

    # separable keys never grow the family segments
    get_fused_schedule(8, 28, 28, 64, 64, 3, 1)
    sep_keys = [k for k in _entries(tmp_path) if k.startswith("sep|")]
    assert sep_keys and all("act=" not in k and "se=" not in k
                            for k in sep_keys)


def test_corrupt_cache_file_is_ignored(cache_dir):
    tmp_path, _cache = cache_dir
    (tmp_path / "convdk_schedules.json").write_text("{not json")
    sch = get_fused_schedule(1, 8, 8, 8, 8, 3, 1)
    assert sch.tile_h >= 1
    # and the file heals on the next write
    assert _entries(tmp_path)


def test_memory_only_mode_without_dir():
    set_schedule_cache_dir(None)
    try:
        cache = get_schedule_cache()
        assert cache.path is None
        a = get_fused_schedule(1, 20, 20, 16, 16, 3, 1)
        b = get_fused_schedule(1, 20, 20, 16, 16, 3, 1)
        assert a == b                          # in-process layer still works
    finally:
        set_schedule_cache_dir(None)


def test_cache_isolated_per_shape_and_kind(cache_dir):
    tmp_path, _cache = cache_dir
    get_fused_schedule(1, 14, 14, 48, 64, 5, 1)
    get_mbconv_schedule(1, 14, 14, 48, 192, 64, 5, 1)
    get_mbconv_schedule(1, 14, 14, 48, 192, 64, 5, 2)
    assert len(_entries(tmp_path)) == 3


def test_schedule_cache_ignores_unwritable_dir(tmp_path):
    """Persistence is best-effort: an unwritable dir must not break
    schedule selection."""
    cache = ScheduleCache(tmp_path / "missing" / "x")
    cache.directory = tmp_path / "convdk_schedules.json"  # a FILE, not a dir
    cache.directory.write_text("occupied")
    cache.put("k", {"tile_h": 1, "source": "model"})
    assert cache.get("k") == {"tile_h": 1, "source": "model"}


# ---------------------------------------------------------------------------
# telemetry counters (hit/miss/put/migration)
# ---------------------------------------------------------------------------


def _counts():
    from repro.core import telemetry
    t = telemetry.get_telemetry()
    return {k: t.get(f"schedule_cache.{k}")
            for k in ("hit.memory", "hit.disk", "miss", "put",
                      "migrated_keys")}


def test_cache_counters_hit_miss_put(cache_dir):
    tmp_path, cache = cache_dir
    base = _counts()
    get_fused_schedule(1, 30, 30, 64, 32, 3, 1)     # miss -> solve -> put
    after_solve = _counts()
    assert after_solve["miss"] == base["miss"] + 1
    assert after_solve["put"] == base["put"] + 1
    get_fused_schedule(1, 30, 30, 64, 32, 3, 1)     # in-process hit
    after_mem = _counts()
    assert after_mem["hit.memory"] == after_solve["hit.memory"] + 1
    assert after_mem["miss"] == after_solve["miss"]
    cache.clear_memory()                            # simulated restart
    get_fused_schedule(1, 30, 30, 64, 32, 3, 1)     # disk hit
    after_disk = _counts()
    assert after_disk["hit.disk"] == after_mem["hit.disk"] + 1
    assert after_disk["put"] == after_mem["put"]    # echo, no re-record


def test_cache_counters_migration(cache_dir):
    import json as _json

    tmp_path, cache = cache_dir
    legacy = "sep|b1-h30-w30-ci64-co32-k3-s1|dtb4|v16777216-c128-t1.2.4.8.16.32|cpu"
    (tmp_path / "convdk_schedules.json").write_text(_json.dumps(
        {"version": 1, "entries": {
            legacy: {"tile_h": 4, "source": "measured"}}}))
    cache.clear_memory()
    base = _counts()
    get_fused_schedule(1, 30, 30, 64, 32, 3, 1)
    after = _counts()
    assert after["migrated_keys"] == base["migrated_keys"] + 1

"""Network-level layout solver: the DP over the B0 chain that picks
per-block (residency, collective, in-layout, out-layout) jointly.

The greedy reference solves every block in isolation and silently repays
each sharded exit with an all-gather at the next replicated entry; the DP
must never lose to it, and on a real model-parallel mesh it must win
STRICTLY by keeping at least one boundary sharded (on B0 that is the
stem -> block0 pair: block0 is the chain's only identity-expand block, the
only entry that consumes a sharded arrival collective-free)."""

import pytest

from repro.core.autotune import (
    MBConvShape,
    TPUConfig,
    _stem_words,
    get_network_plan,
    greedy_network_schedule,
    network_rows_from_table,
    select_mbconv_schedule,
    solve_network_schedule,
)
from repro.core.perfmodel import (
    can_shard_input,
    layout_transition_words,
    scatter_c_out,
)
from repro.core.workloads import EFFICIENTNET_B0_MBCONV

ROWS = network_rows_from_table(EFFICIENTNET_B0_MBCONV)


@pytest.mark.parametrize("mesh", [(1, 1), (8, 1), (4, 2), (2, 4)])
def test_solved_never_worse_than_greedy(mesh):
    solved = solve_network_schedule(ROWS, 8, mesh_shape=mesh)
    greedy = greedy_network_schedule(ROWS, 8, mesh_shape=mesh)
    assert solved.total_bytes <= greedy.total_bytes, mesh
    assert len(solved.blocks) == len(ROWS) == 16


def test_solved_strictly_better_with_sharded_pair_on_2x4():
    """The acceptance gate: strict end-to-end win, >= 1 boundary kept
    sharded, and the winning pair is stem -> block0 (the identity-expand
    entry).  The solved chain repays NOTHING — every boundary it shards
    is consumed in place."""
    solved = solve_network_schedule(ROWS, 8, mesh_shape=(2, 4))
    greedy = greedy_network_schedule(ROWS, 8, mesh_shape=(2, 4))
    assert solved.total_bytes < greedy.total_bytes
    assert len(solved.sharded_pairs) >= 1
    assert (-1, 0) in solved.sharded_pairs     # stem feeds block0 sharded
    assert solved.stem_layout == "model_sharded"
    assert solved.blocks[0].in_layout == "model_sharded"
    assert solved.transition_bytes == 0        # nothing gathered back
    assert greedy.transition_bytes > 0         # greedy repays every exit
    assert greedy.sharded_pairs == ()          # ... so nothing stays sharded
    # the parts re-sum to the plan totals on both policies
    for plan in (solved, greedy):
        assert plan.total_bytes == (
            plan.stem_bytes + plan.block_bytes
            + plan.boundary_words * plan.dtype_bytes)


def test_single_device_degenerates_to_greedy():
    """On (1, 1) there is no layout axis: both policies collapse to the
    same replicated chain with zero boundary traffic."""
    solved = solve_network_schedule(ROWS, 1, mesh_shape=(1, 1))
    greedy = greedy_network_schedule(ROWS, 1, mesh_shape=(1, 1))
    assert solved.total_bytes == greedy.total_bytes
    assert solved.sharded_pairs == ()
    assert solved.boundary_words == 0
    assert all(p.in_layout == "replicated" and p.out_layout == "replicated"
               for p in solved.blocks)


def test_network_plan_cached_and_trace_safe():
    a = get_network_plan(ROWS, 8, mesh_shape=(2, 4))
    b = get_network_plan([list(r) for r in ROWS], 8, mesh_shape=(2, 4))
    assert a is b                              # lru-cached, list rows ok
    assert a.policy == "solved"


def test_stem_words_price_replication():
    """A replicated stem writes mp copies of the activation mesh-wide; a
    sharded one writes each element once."""
    full = 8 * 112 * 112 * 32
    assert _stem_words(8, 112, 112, 32, (2, 4), "replicated") == full * 4
    assert _stem_words(8, 112, 112, 32, (2, 4), "model_sharded") == full
    # c that does not divide mp cannot shard: both layouts price replicated
    assert _stem_words(8, 112, 112, 3, (2, 4), "model_sharded") == \
        _stem_words(8, 112, 112, 3, (2, 4), "replicated")


def test_identity_expand_consumes_sharded_free():
    """The e==1 entry takes a model-sharded arrival with zero transition
    words; a real-expand entry at the same mesh gathers c_in first — the
    tie that forces the DP's strict win onto the identity-expand pair."""
    e1 = MBConvShape(b=8, h=112, w=112, c_in=32, c_mid=32, c_out=16,
                     k=3, s=1)
    assert can_shard_input(e1, (2, 4))
    sch = select_mbconv_schedule(e1, TPUConfig(), (2, 4),
                                 in_layout="model_sharded")
    assert sch.in_layout == "model_sharded"
    assert sch.transition_words == 0

    ex = MBConvShape(b=8, h=56, w=56, c_in=24, c_mid=144, c_out=24,
                     k=3, s=1)
    assert not can_shard_input(ex, (2, 4))     # real expand: no free entry
    schx = select_mbconv_schedule(ex, TPUConfig(), (2, 4),
                                  in_layout="model_sharded")
    assert schx.transition_words > 0           # the entry gather is priced
    # and it equals the boundary repay the DP would pay instead
    assert schx.transition_words == layout_transition_words(
        8, 56, 56, 24, (2, 4), "model_sharded", "replicated")


def test_out_layout_tracks_collective():
    """psum_scatter leaves model_sharded (the gather back to a global
    view — if any consumer needs one — is priced at the NEXT boundary,
    keeping scatter + repay == ring); a ring exit is replicated.  The
    padded scatter (c_out % mp != 0) still scatters, at the rounded-up
    width."""
    div = MBConvShape(b=8, h=14, w=14, c_in=80, c_mid=480, c_out=112,
                      k=5, s=1)
    sch = select_mbconv_schedule(div, TPUConfig(), (2, 4),
                                 collective="psum_scatter")
    assert sch.out_layout == "model_sharded"
    pad = MBConvShape(b=8, h=14, w=14, c_in=80, c_mid=480, c_out=114,
                      k=5, s=1)
    assert scatter_c_out(114, 4) == 116
    schp = select_mbconv_schedule(pad, TPUConfig(), (2, 4),
                                  collective="psum_scatter")
    assert schp.collective == "psum_scatter"
    assert schp.collective_words < select_mbconv_schedule(
        pad, TPUConfig(), (2, 4),
        collective="ring_allreduce").collective_words
    ring = select_mbconv_schedule(div, TPUConfig(), (2, 4),
                                  collective="ring_allreduce")
    assert ring.out_layout == "replicated"

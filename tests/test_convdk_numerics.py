"""ConvDK executors must equal strided-convolution oracles exactly.

The CIM dataflow computes the SAME arithmetic as a plain depthwise conv,
just in a different order (duplicated kernels + shifted strip reads), so on
float32 the results must match to machine-epsilon-level tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.convdk import (
    convdk_1d,
    convdk_2d_strip,
    dwconv2d_convdk,
    dwconv2d_oracle,
)
from repro.core.schedule import make_schedule

PAPER_KS = [(3, 1), (3, 2), (5, 1), (5, 2)]


def _conv1d_oracle(kernel, ia, stride):
    k = kernel.shape[0]
    out_len = (ia.shape[0] - k) // stride + 1
    idx = np.arange(out_len)[:, None] * stride + np.arange(k)[None, :]
    return (ia[idx] * kernel[None, :]).sum(-1)


@pytest.mark.parametrize("k,s", PAPER_KS)
@pytest.mark.parametrize("N", [1, 2, 5, 19])
def test_convdk_1d_matches_oracle(k, s, N):
    sched = make_schedule(k, s, N)
    rng = np.random.default_rng(42 + k * 10 + s + N)
    kernel = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    ia = jnp.asarray(rng.normal(size=(sched.ia_len,)), jnp.float32)
    got = convdk_1d(kernel, ia, sched)
    want = _conv1d_oracle(np.asarray(kernel), np.asarray(ia), s)[: sched.out_len]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("k,s", PAPER_KS)
@pytest.mark.parametrize("k_h", [1, 3, 5])
def test_convdk_2d_strip_matches_oracle(k, s, k_h):
    N = 4
    sched = make_schedule(k, s, N)
    rng = np.random.default_rng(7)
    kernel = jnp.asarray(rng.normal(size=(k_h, k)), jnp.float32)
    strip = jnp.asarray(rng.normal(size=(k_h, sched.ia_len)), jnp.float32)
    got = convdk_2d_strip(kernel, strip, sched)
    # oracle: valid 2D conv of the strip, stride s along width only
    want = np.zeros(sched.out_len, np.float32)
    for m in range(sched.out_len):
        want[m] = float(
            (np.asarray(strip)[:, m * s : m * s + k] * np.asarray(kernel)).sum()
        )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("k,s", PAPER_KS)
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_dwconv2d_convdk_matches_lax(k, s, padding):
    C, H, W = 8, 17, 23
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(C, H, W)), jnp.float32)
    kern = jnp.asarray(rng.normal(size=(C, k, k)), jnp.float32)
    got = dwconv2d_convdk(x, kern, stride=s, padding=padding)
    want = dwconv2d_oracle(x, kern, stride=s, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dwconv2d_narrow_ifmap_little_regime():
    """W << T_w (the LITTLE scheduler regime): still exact."""
    C, H, W = 16, 7, 7
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(C, H, W)), jnp.float32)
    kern = jnp.asarray(rng.normal(size=(C, 3, 3)), jnp.float32)
    got = dwconv2d_convdk(x, kern, stride=1, padding="SAME")
    want = dwconv2d_oracle(x, kern, stride=1, padding="SAME")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dwconv2d_jit_and_grad():
    """ConvDK is an ordinary differentiable JAX computation."""
    C, H, W = 4, 12, 12
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(C, H, W)), jnp.float32)
    kern = jnp.asarray(rng.normal(size=(C, 3, 3)), jnp.float32)

    f = jax.jit(lambda x, k: dwconv2d_convdk(x, k, stride=1, padding="SAME").sum())
    g = jax.grad(f, argnums=1)(x, kern)
    f_ref = lambda x, k: dwconv2d_oracle(x, k, stride=1, padding="SAME").sum()
    g_ref = jax.grad(f_ref, argnums=1)(x, kern)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-4)


@given(
    ks=st.sampled_from(PAPER_KS),
    C=st.integers(1, 6),
    H=st.integers(6, 30),
    W=st.integers(6, 40),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_dwconv2d_hypothesis(ks, C, H, W, seed):
    k, s = ks
    if H < k or W < k:
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(C, H, W)), jnp.float32)
    kern = jnp.asarray(rng.normal(size=(C, k, k)), jnp.float32)
    got = dwconv2d_convdk(x, kern, stride=s, padding="SAME")
    want = dwconv2d_oracle(x, kern, stride=s, padding="SAME")
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

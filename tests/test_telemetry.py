"""Telemetry subsystem: counters/spans, the canonical ``measure()`` harness
(including the exact call-count contract that fixes the old double-eval
warmup), trace-time counter semantics under jit, the ``BENCH_<host>.json``
schema round-trip, the trajectory differ's regression detection, and the
measured-calibration fit."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import telemetry
from repro.core.perfmodel import (
    PerfCoefficients,
    fit_perf_coefficients,
    predict_walltime_us,
)
from repro.core.telemetry import Measurement, Telemetry, measure
from repro.core.trajectory import (
    bench_filename,
    diff_bench,
    load_bench,
    rank_agreement,
    validate_bench,
    write_bench,
)

# ---------------------------------------------------------------------------
# counters + spans
# ---------------------------------------------------------------------------


def test_counter_accumulates_and_defaults_zero():
    t = Telemetry()
    assert t.get("x") == 0
    t.count("x")
    t.count("x", 2)
    t.count("y", 0.5)
    assert t.get("x") == 3
    assert t.get("y") == 0.5


def test_span_aggregates_count_total_min_max():
    t = Telemetry()
    for _ in range(3):
        with t.span("work"):
            pass
    st = t.span_stat("work")
    assert st.count == 3
    assert st.total_s >= st.max_s >= st.min_s >= 0
    assert t.span_stat("absent") is None


def test_span_records_on_exception():
    t = Telemetry()
    with pytest.raises(RuntimeError):
        with t.span("boom"):
            raise RuntimeError("x")
    assert t.span_stat("boom").count == 1


def test_snapshot_is_json_ready_and_reset_clears():
    import json

    t = Telemetry()
    t.count("a.b", 4)
    with t.span("s"):
        pass
    t.record("lat", 0.25)
    snap = t.snapshot()
    json.dumps(snap)                       # must serialize as-is
    assert snap["counters"] == {"a.b": 4}
    assert snap["spans"]["s"]["count"] == 1
    assert snap["series"]["lat"]["count"] == 1
    t.reset()
    assert t.snapshot() == {"counters": {}, "spans": {}, "series": {}}


def test_series_bounded_and_summarized():
    t = Telemetry()
    for v in range(telemetry.SERIES_CAP + 10):
        t.record("depth", v)
    vals = t.series("depth")
    assert len(vals) == telemetry.SERIES_CAP     # oldest samples dropped
    assert vals[-1] == telemetry.SERIES_CAP + 9
    summ = t.snapshot()["series"]["depth"]
    assert summ["max"] == telemetry.SERIES_CAP + 9
    assert summ["p50"] in vals                   # nearest-rank: a real sample
    assert t.series("absent") == ()


def test_percentiles_nearest_rank():
    assert telemetry.percentiles([]) == {}
    p = telemetry.percentiles([3.0, 1.0, 2.0, 4.0], qs=(50, 99))
    assert p == {"p50": 2.0, "p99": 4.0}         # ceil-rank order statistics
    assert telemetry.percentiles([7.0])["p50"] == 7.0


def test_global_sugar_routes_to_one_registry():
    before = telemetry.get_telemetry().get("test.sugar")
    telemetry.counter("test.sugar", 2)
    assert telemetry.get_telemetry().get("test.sugar") == before + 2
    assert telemetry.snapshot()["counters"]["test.sugar"] == before + 2


# ---------------------------------------------------------------------------
# measure(): the one timing harness
# ---------------------------------------------------------------------------


def test_measure_call_count_exact():
    # The old kernel_bench warmup called fn TWICE to probe its return type
    # (`fn(*args)[0] ... if isinstance(fn(*args), tuple)`); measure() must
    # call exactly warmup + iters times, whatever fn returns.
    calls = []
    m = measure(lambda: calls.append(1), iters=3, warmup=1)
    assert len(calls) == 4
    assert m.iters == 3
    assert all(t >= 0 for t in m.times_s)


def test_measure_handles_tuple_and_array_returns():
    x = jnp.arange(8.0)
    m_tuple = measure(lambda: (x * 2, x + 1), iters=2)
    m_array = measure(lambda: x * 2, iters=2)
    assert m_tuple.iters == m_array.iters == 2


def test_measure_statistics_and_validation():
    m = Measurement(name="n", times_s=(3e-3, 1e-3, 2e-3))
    assert m.best_s == 1e-3
    assert m.mean_s == pytest.approx(2e-3)
    assert m.best_us == pytest.approx(1e3)
    with pytest.raises(ValueError):
        measure(lambda: None, iters=0)


def test_measure_records_named_span():
    t = telemetry.get_telemetry()
    before = t.span_stat("measure.tm_probe")
    n0 = before.count if before else 0
    measure(lambda: None, iters=1, name="tm_probe")
    assert t.span_stat("measure.tm_probe").count == n0 + 1


def test_counter_ticks_at_trace_time_under_jit():
    # Counters are host-side Python state: inside a jitted function they
    # tick once per COMPILATION, not per call — the documented semantic
    # the kernel hooks rely on (plans/dispatches are trace-time work).
    t = Telemetry()

    @jax.jit
    def f(v):
        t.count("traced")
        return v * 2

    f(jnp.float32(1.0))
    f(jnp.float32(2.0))
    f(jnp.float32(3.0))
    assert t.get("traced") == 1
    f(jnp.arange(4.0))                     # new shape -> new trace
    assert t.get("traced") == 2


def test_staging_plan_hooks_count_issues_and_words():
    from repro.kernels.staging import strip_plan

    t = telemetry.get_telemetry()
    base = {k: t.get(k) for k in ("staging.plans", "staging.dma_issues",
                                  "staging.window_words")}
    plan = strip_plan(h_tot=18, w_tot=16, w_span=16, c_block=8, tile_h=4,
                      grid=(1, 4, 2), window_dims=(0, 1, 2), stride=1,
                      k_h=3, residency="strip_dma_db")
    assert t.get("staging.plans") == base["staging.plans"] + 1
    assert t.get("staging.dma_issues") == base["staging.dma_issues"] + 8
    assert t.get("staging.window_words") == (
        base["staging.window_words"] + 8 * plan.in_rows * 16 * 8)
    # resident plans issue no DMA
    strip_plan(h_tot=18, w_tot=16, w_span=16, c_block=8, tile_h=4,
               grid=(1, 4, 2), window_dims=(0, 1, 2), stride=1, k_h=3,
               residency="resident")
    assert t.get("staging.dma_issues") == base["staging.dma_issues"] + 8


# ---------------------------------------------------------------------------
# host fingerprint + BENCH round-trip
# ---------------------------------------------------------------------------


def test_host_fingerprint_and_slug():
    fp = telemetry.host_fingerprint()
    for key in ("node", "system", "machine", "python", "jax", "backend"):
        assert fp[key]
    slug = telemetry.host_slug({"node": "my host!", "backend": "cpu"})
    assert slug == "my-host-cpu"
    assert bench_filename({"node": "a", "backend": "cpu"}) == \
        "BENCH_a-cpu.json"


def _records(bytes0=1000, axes0=None, wall0=50.0):
    return [
        {"name": "l0", "shape": {"hw": 7},
         "axes": axes0 or {"tile_h": 4, "mode": "retain"},
         "modeled_bytes": bytes0, "walltime_us": wall0,
         "candidates": [
             {"axes": {"tile_h": 4, "mode": "retain"},
              "modeled_bytes": bytes0, "walltime_us": wall0},
             {"axes": {"tile_h": 4, "mode": "recompute"},
              "modeled_bytes": bytes0 + 500, "walltime_us": wall0 + 10},
         ]},
        {"name": "l1", "shape": {"hw": 14},
         "axes": {"tile_h": 8, "mode": "recompute"},
         "modeled_bytes": 2000, "walltime_us": 80.0},
    ]


def test_bench_round_trip(tmp_path):
    fp = {"node": "ci", "backend": "cpu", "machine": "x86_64",
          "system": "Linux", "jax": "0.4.37"}
    path = write_bench(tmp_path, _records(), config={"scale": 4},
                       counters={"counters": {"c": 1}, "spans": {}},
                       fingerprint=fp)
    assert path.name == "BENCH_ci-cpu.json"
    loaded = load_bench(path)
    assert [r["name"] for r in loaded["records"]] == ["l0", "l1"]
    assert loaded["config"]["scale"] == 4
    assert loaded["host"]["node"] == "ci"
    assert loaded["counters"]["counters"]["c"] == 1


def test_bench_schema_rejects_malformed():
    with pytest.raises(ValueError):
        validate_bench({"version": 1, "kind": "wrong", "records": [{}],
                        "host": {}})
    with pytest.raises(ValueError):
        validate_bench({"version": 1, "kind": "convdk-bench-trajectory",
                        "records": [], "host": {}})
    with pytest.raises(ValueError):                       # missing keys
        validate_bench({"version": 1, "kind": "convdk-bench-trajectory",
                        "records": [{"name": "x"}], "host": {}})
    with pytest.raises(ValueError):                       # duplicate name
        validate_bench({
            "version": 1, "kind": "convdk-bench-trajectory", "host": {},
            "records": _records() + _records()})


# ---------------------------------------------------------------------------
# the trajectory differ
# ---------------------------------------------------------------------------


def _bench(records, node="ci", config=None):
    return {"version": 1, "kind": "convdk-bench-trajectory",
            "host": {"node": node, "backend": "cpu", "machine": "x86_64",
                     "system": "Linux", "jax": "0.4.37"},
            "config": config or {"scale": 4}, "records": records}


def test_diff_clean_is_ok():
    d = diff_bench(_bench(_records()), _bench(_records()))
    assert d.ok and d.walltime_enforced


def test_diff_detects_modeled_bytes_regression():
    d = diff_bench(_bench(_records()), _bench(_records(bytes0=1500)))
    assert not d.ok
    assert any("modeled bytes regressed" in f for f in d.failures)


def test_diff_detects_axis_flip_and_allows_when_asked():
    new = _bench(_records(axes0={"tile_h": 2, "mode": "recompute"}))
    d = diff_bench(_bench(_records()), new)
    assert any("axes changed" in f for f in d.failures)
    d2 = diff_bench(_bench(_records()), new, allow_axis_changes=True)
    assert d2.ok


def test_diff_detects_missing_record():
    new = _bench(_records()[:1])
    d = diff_bench(_bench(_records()), new)
    assert any("disappeared" in f for f in d.failures)


def test_diff_walltime_gates_only_on_comparable_hosts():
    slow = _bench(_records(wall0=500.0))
    same_host = diff_bench(_bench(_records()), slow)
    assert not same_host.ok
    other_host = diff_bench(_bench(_records()),
                            _bench(_records(wall0=500.0), node="laptop"))
    assert other_host.ok                   # noted, not gated
    assert any("walltime" in n for n in other_host.notes)
    forced = diff_bench(_bench(_records()),
                        _bench(_records(wall0=500.0), node="laptop"),
                        enforce_walltime=True)
    assert not forced.ok


def test_diff_rejects_incomparable_config():
    d = diff_bench(_bench(_records()),
                   _bench(_records(), config={"scale": 8}))
    assert not d.ok
    assert any("config.scale" in f for f in d.failures)


def test_diff_cli_exit_codes(tmp_path, capsys):
    from repro.core.trajectory import main as traj_main

    fp = {"node": "ci", "backend": "cpu"}
    old = write_bench(tmp_path / "old.json", _records(), fingerprint=fp,
                      config={"scale": 4})
    new = write_bench(tmp_path / "new.json", _records(bytes0=9000),
                      fingerprint=fp, config={"scale": 4})
    assert traj_main(["diff", str(old), str(old)]) == 0
    assert traj_main(["diff", str(old), str(new)]) == 1
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "modeled bytes regressed" in out


def test_rank_agreement_controlled_pairs():
    recs = _records()
    agr = rank_agreement(recs, "mode")
    # one controlled pair: bytes0 < bytes0+500 and wall0 < wall0+10 agree
    assert agr == {"axis": "mode", "pairs": 1, "agree": 1,
                   "model_ties": 0, "agreement": 1.0}
    assert rank_agreement(recs, "residency") is None


# ---------------------------------------------------------------------------
# measured calibration fit
# ---------------------------------------------------------------------------


def test_fit_recovers_planted_coefficients():
    base, per_mb, per_issue = 7.0, 3.0, 0.25
    samples = [
        {"walltime_us": base + per_mb * mb + per_issue * di,
         "modeled_bytes": mb * 1e6, "dma_issues": di}
        for mb, di in [(1, 0), (2, 8), (4, 2), (8, 32), (3, 16)]]
    c = fit_perf_coefficients(samples)
    assert isinstance(c, PerfCoefficients)
    assert c.base_us == pytest.approx(base, abs=1e-6)
    assert c.us_per_mb == pytest.approx(per_mb, abs=1e-6)
    assert c.us_per_dma_issue == pytest.approx(per_issue, abs=1e-6)
    assert c.us_per_collective_mb == 0.0   # constant column -> dropped
    assert c.rms_us == pytest.approx(0.0, abs=1e-6)
    assert predict_walltime_us(
        c, modeled_bytes=2e6, dma_issues=8) == pytest.approx(
        base + 2 * per_mb + 8 * per_issue, abs=1e-6)


def test_fit_rejects_underdetermined():
    with pytest.raises(ValueError):
        fit_perf_coefficients([])
    with pytest.raises(ValueError):
        # 2 samples, 3 varying cost columns + intercept = 4 free terms
        fit_perf_coefficients([
            {"walltime_us": 1.0, "modeled_bytes": 1e6, "dma_issues": 1,
             "collective_bytes": 1e5},
            {"walltime_us": 2.0, "modeled_bytes": 2e6, "dma_issues": 3,
             "collective_bytes": 4e5}])
    # a single sample IS enough for an intercept-only fit (every cost
    # column constant -> dropped): degrade, don't crash
    c = fit_perf_coefficients(
        [{"walltime_us": 5.0, "modeled_bytes": 1e6}])
    assert c.base_us == pytest.approx(5.0)
    assert c.us_per_mb == 0.0

"""Property tests for the ConvDK schedule — Theorems 1 & 2 of the paper.

Theorem 2 is the load-bearing claim: for every valid (k, s, N), the shift
cycles a = 0..l-1 jointly produce EVERY output index m in [0, out_len)
EXACTLY ONCE.  We test it exhaustively over the paper's realistic (k, s)
space and by hypothesis over a wider space.
"""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.schedule import (
    ConvDKConditionError,
    block_period,
    check_conditions,
    covered_outputs,
    duplication_number,
    is_exact_cover,
    make_schedule,
    shift_count,
    solve_m1_n1,
)

# (k, s) pairs used by MobileNet/EfficientNet DWConv layers.
PAPER_KS = [(3, 1), (3, 2), (5, 1), (5, 2)]
# Wider valid space: odd k, s < k, gcd(k, s) = 1.
VALID_KS = [
    (k, s)
    for k in (3, 5, 7, 9, 11, 13)
    for s in range(1, k)
    if math.gcd(k, s) == 1
]


def test_paper_worked_example():
    """Sec. III-A worked example: k=3, s=2, N=30 -> n1=1, m1=2, 3 cycles of
    15 sub-cycles with the exact n and m progressions printed in the paper."""
    sched = make_schedule(3, 2, 30)
    assert (sched.m1, sched.n1) == (2, 1)
    assert sched.l == 3 and sched.p == 2
    c0, c1, c2 = sched.cycles
    assert c0.ns == tuple(range(0, 30, 2)) and c0.ms == tuple(range(0, 45, 3))
    assert c1.ns == tuple(range(1, 30, 2)) and c1.ms == tuple(range(2, 45, 3))
    assert c2.ns == tuple(range(0, 30, 2)) and c2.ms == tuple(range(1, 44, 3))
    assert all(len(c.ns) == 15 for c in sched.cycles)
    assert is_exact_cover(sched)
    assert sched.out_len == 45  # m in [0, 44]


@pytest.mark.parametrize("k,s", VALID_KS)
@pytest.mark.parametrize("N", [1, 2, 3, 7, 30])
def test_exact_cover_theorem2(k, s, N):
    sched = make_schedule(k, s, N)
    assert is_exact_cover(sched), (k, s, N)


@pytest.mark.parametrize("k,s", VALID_KS)
def test_eq6_invariant_theorem1(k, s):
    """Every emitted (a, n, m) satisfies m*s = n*k + a (Eq. 6)."""
    sched = make_schedule(k, s, 11)
    for cyc in sched.cycles:
        for n, m in zip(cyc.ns, cyc.ms):
            assert m * sched.s == n * sched.k + cyc.a


@pytest.mark.parametrize("k,s", VALID_KS)
def test_m1_n1_least_solution(k, s):
    m1, n1 = solve_m1_n1(k, s)
    assert m1 * s == n1 * k + 1
    # minimality
    for m in range(m1):
        assert (m * s - 1) % k != 0 or m * s < 1


def test_conditions_reject_invalid():
    with pytest.raises(ConvDKConditionError):
        check_conditions(4, 1)  # even k
    with pytest.raises(ConvDKConditionError):
        check_conditions(3, 3)  # s not < k
    with pytest.raises(ConvDKConditionError):
        check_conditions(9, 3)  # gcd != 1 -> Condition 2 unsolvable
    with pytest.raises(ConvDKConditionError):
        make_schedule(3, 1, 0)  # N must be >= 1


@given(
    ks=st.sampled_from(VALID_KS),
    N=st.integers(min_value=1, max_value=64),
)
@settings(max_examples=200, deadline=None)
def test_exact_cover_hypothesis(ks, N):
    k, s = ks
    sched = make_schedule(k, s, N)
    ms = covered_outputs(sched)
    assert len(ms) == len(set(ms))
    assert set(ms) == set(range(sched.out_len))
    # Each sub-cycle produces exactly one output -> totals match.
    assert sched.total_subcycles == sched.out_len


@given(ks=st.sampled_from(VALID_KS), N=st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_lengths(ks, N):
    k, s = ks
    sched = make_schedule(k, s, N)
    assert sched.ia_len == N * k + sched.l - 1
    assert sched.out_len == ((N - 1) * k + sched.l - 1) // s + 1
    assert sched.l == shift_count(k, s) and sched.p == block_period(k, s)
    assert sched.tm_rows_used == N * k


def test_duplication_number_eq8():
    # Paper Fig. 4(a): k_w = 3, s = 1, T_w = 60 -> N = (60 - 3 + 1)//3 = 19
    assert duplication_number(3, 1, width=224, t_w=60) == 19
    # Paper Fig. 5: W = 24 < T_w -> N = (24 - 3 + 1)//3 = 7
    assert duplication_number(3, 1, width=24, t_w=60) == 7
    # stride-2 3x3: l = 3 -> N = (60 - 3 + 1)//3 = 19
    assert duplication_number(3, 2, width=112, t_w=60) == 19
    # 5x5 s=1 on T_w = 36 (k_h = 5): l = 5 -> (36 - 5 + 1)//5 = 6
    assert duplication_number(5, 1, width=112, t_w=36) == 6
    assert duplication_number(3, 1, width=2, t_w=60) == 0

"""Per-architecture smoke tests: instantiate the REDUCED config of the same
family, run one forward and one gradient step on CPU, assert output shapes
and no NaNs.  Also decode-vs-prefill consistency for every decoder family.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import (
    ModelConfig, decode_step, forward, init_decode_state, materialize,
    model_def,
)
from repro.models.common import softmax_cross_entropy

ARCHS = list_archs()
B, S = 2, 16


def _batch(cfg: ModelConfig, rng):
    if cfg.family == "encoder":
        return {
            "embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                  jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32),
        }
    if cfg.family == "vlm":
        n_img = cfg.n_img_tokens
        return {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, S - n_img)), jnp.int32),
            "img_embeds": jnp.asarray(
                rng.normal(size=(B, n_img, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10, ARCHS


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_arch(arch).config
    expected = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "mamba2-2.7b": (64, 2560, 80, 80, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff if cfg.family != "ssm" else 0, cfg.vocab)
    assert got == expected, (arch, got, expected)
    if arch == "deepseek-v2-236b":
        assert cfg.kv_lora == 512 and cfg.n_experts == 160 and cfg.top_k == 6
    if arch == "granite-moe-3b-a800m":
        assert cfg.n_experts == 40 and cfg.top_k == 8
    if arch == "mamba2-2.7b":
        assert cfg.d_state == 128
    if arch == "recurrentgemma-9b":
        assert cfg.window == 2048 and cfg.pattern == ("R", "R", "A")


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad_step(arch):
    spec = get_arch(arch)
    cfg = spec.smoke
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = materialize(model_def(cfg), jax.random.key(0))
    batch = _batch(cfg, rng)

    logits = forward(params, batch, cfg)
    s_out = S if cfg.family != "vlm" else S  # vlm concat keeps total = S
    assert logits.shape == (B, s_out, cfg.vocab), logits.shape
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in forward"

    def loss_fn(p):
        lg = forward(p, batch, cfg)
        return softmax_cross_entropy(lg, batch["labels"])

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), "NaN loss"
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), "NaN grads"
    # one SGD step must change the loss
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = loss_fn(new_params)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_arch(a).config.family != "encoder"])
def test_smoke_decode_matches_prefill(arch):
    spec = get_arch(arch)
    cfg = spec.smoke
    if cfg.family == "moe":
        # Prefill computes expert capacity over the whole batch (tokens can
        # drop at cf=1.25); decode sees one token per step and never drops.
        # Make capacity generous so BOTH paths route every token and the
        # outputs must match (same convention as test_distributed).
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    rng = np.random.default_rng(7)
    params = materialize(model_def(cfg), jax.random.key(1))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    full = forward(params, {"tokens": toks}, cfg)
    state = init_decode_state(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, state = decode_step(params, state, {"tokens": toks[:, t]}, cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)

"""The strip-DMA staging engine: k x s x residency parity sweeps vs the
lax oracle for every fused pipeline, scratch-vs-VMEM-budget properties,
residency traffic invariants, the legacy cache-key migration, and the
sharded jitted-entry-point trace-count regression."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.autotune import (
    TPUConfig,
    candidate_mbconv_schedules,
    candidate_schedules,
    get_fused_schedule,
    mbconv_vmem_footprint_bytes,
    select_fused_schedule,
    select_mbconv_schedule,
    set_schedule_cache_dir,
    vmem_footprint_bytes,
)
from repro.core.perfmodel import (
    RESIDENCY_MODES,
    MBConvShape,
    SeparableShape,
    fused_separable_traffic,
    mbconv_fused_traffic,
    mbconv_staging_bytes,
    separable_staging_bytes,
    staging_slots,
)
from repro.core.workloads import (
    EFFICIENTNET_V2_K7_SEPARABLE,
    EFFICIENTNET_V2_K7_STEM,
)
from repro.kernels import convdk_fused_separable, convdk_mbconv_fused

TOL = dict(rtol=1e-4, atol=1e-4)


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def _sep_oracle(x, w_dw, w_pw, stride, padding="SAME"):
    """Independent oracle: lax depthwise conv + lax.dot_general pointwise
    (NOT the repo's separable_ref)."""
    k_h, k_w, c = w_dw.shape
    dw = jax.lax.conv_general_dilated(
        x, jnp.transpose(w_dw, (2, 0, 1))[:, None],
        window_strides=(stride, stride), padding=padding,
        feature_group_count=c,
        dimension_numbers=("NHWC", "OIHW", "NHWC"))
    return jax.lax.dot_general(
        dw, w_pw, dimension_numbers=(((3,), (0,)), ((), ())))


def _mbconv_oracle(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                   stride, exp_act):
    """Independent oracle: explicit lax convs + explicit SE."""
    e = x @ w_exp
    if exp_act == "silu":
        e = jax.nn.silu(e)
    k_h, k_w, c_mid = w_dw.shape
    d = jax.lax.conv_general_dilated(
        e, jnp.transpose(w_dw, (2, 0, 1))[:, None],
        window_strides=(stride, stride), padding="SAME",
        feature_group_count=c_mid,
        dimension_numbers=("NHWC", "OIHW", "NHWC"))
    d = jax.nn.silu(d)
    pooled = d.mean(axis=(1, 2))
    s1 = jax.nn.silu(pooled @ w_se1 + b_se1)
    gate = jax.nn.sigmoid(s1 @ w_se2 + b_se2)
    return (d * gate[:, None, None, :]) @ w_proj


# ---------------------------------------------------------------------------
# the tentpole parity sweep: k x s x residency, every pipeline, vs lax
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("residency", RESIDENCY_MODES)
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("k", [3, 5, 7])
def test_separable_staging_parity(k, stride, residency):
    """The DMA-structured staging path (and its double-buffered variant)
    computes bit-for-bit what the resident path and the lax oracle do —
    interpret mode executes the same engine code as a TPU launch."""
    rng = np.random.default_rng(k * 10 + stride)
    x = _rand(rng, (2, 13, 11, 24))
    w_dw = _rand(rng, (k, k, 24))
    w_pw = _rand(rng, (24, 40))
    got = convdk_fused_separable(x, w_dw, w_pw, stride=stride,
                                 interpret=True, residency=residency)
    want = _sep_oracle(x, w_dw, w_pw, stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("residency", RESIDENCY_MODES)
@pytest.mark.parametrize("mode", ["retain", "recompute"])
@pytest.mark.parametrize("k,stride", [(3, 1), (3, 2), (5, 1), (5, 2),
                                      (7, 1), (7, 2)])
def test_mbconv_staging_parity(k, stride, mode, residency):
    """Both MBConv pass-2 variants through the engine — including the
    double-buffered DMA stream of the retained DW tensor — match the lax
    oracle for k in {3, 5, 7} x s in {1, 2}."""
    rng = np.random.default_rng(k * 100 + stride * 10)
    ci, e, co = 8, 3, 16
    cm, cse = ci * e, 2
    x = _rand(rng, (1, 10, 9, ci))
    weights = (_rand(rng, (ci, cm)), _rand(rng, (k, k, cm), 0.3),
               _rand(rng, (cm, cse)), _rand(rng, (cse,), 0.1),
               _rand(rng, (cse, cm)), _rand(rng, (cm,), 0.1),
               _rand(rng, (cm, co)))
    got = convdk_mbconv_fused(x, *weights, stride=stride, mode=mode,
                              interpret=True, residency=residency)
    want = _mbconv_oracle(x, *weights, stride=stride, exp_act="silu")
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("residency", ["strip_dma", "strip_dma_db"])
def test_staging_multi_block_grids(residency):
    """DMA windows track the channel-block grid dim: >128 input channels
    (multi-ci-block reduction) and >128 output channels both stage
    correctly, including the window prefetch crossing c-block boundaries."""
    rng = np.random.default_rng(5)
    x = _rand(rng, (2, 9, 11, 130))
    w_dw = _rand(rng, (3, 3, 130))
    w_pw = _rand(rng, (130, 200))
    got = convdk_fused_separable(x, w_dw, w_pw, stride=1, interpret=True,
                                 residency=residency)
    want = _sep_oracle(x, w_dw, w_pw, 1)
    np.testing.assert_allclose(got, want, **TOL)


def test_staging_tile_h_invariant():
    """Any tile_h gives the same numbers under DMA staging — the window
    geometry is perf-only, exactly as in the resident rendering."""
    rng = np.random.default_rng(7)
    x = _rand(rng, (1, 17, 13, 16))
    w_dw = _rand(rng, (3, 3, 16))
    w_pw = _rand(rng, (16, 24))
    want = _sep_oracle(x, w_dw, w_pw, 2)
    for tile_h in (1, 3, 8, 32):
        got = convdk_fused_separable(x, w_dw, w_pw, stride=2, tile_h=tile_h,
                                     interpret=True,
                                     residency="strip_dma_db")
        np.testing.assert_allclose(got, want, **TOL)


def test_staging_grad_flows():
    """The DMA-staged forward keeps the exact custom VJP."""
    rng = np.random.default_rng(9)
    x = _rand(rng, (1, 8, 8, 8))
    w_dw = _rand(rng, (3, 3, 8))
    w_pw = _rand(rng, (8, 8))

    def loss(res):
        def f(x_, wd_, wp_):
            return jnp.sum(convdk_fused_separable(
                x_, wd_, wp_, stride=1, interpret=True, residency=res) ** 2)
        return jax.grad(f, argnums=(0, 1, 2))(x, w_dw, w_pw)

    g_res = loss("resident")
    g_dma = loss("strip_dma_db")
    for a, b in zip(g_res, g_dma):
        np.testing.assert_allclose(a, b, **TOL)


# ---------------------------------------------------------------------------
# k=7 workload rows (EfficientNet-V2 stems)
# ---------------------------------------------------------------------------

def test_k7_workload_rows_priced_below_staged():
    """The new k=7 stem rows flow through schedule solving and keep the
    fused-below-staged invariant at full stem resolution."""
    assert [layer.k for layer in EFFICIENTNET_V2_K7_STEM] == [7, 7]
    for layer, c_out in EFFICIENTNET_V2_K7_SEPARABLE:
        sch = get_fused_schedule(1, layer.h, layer.w, layer.c, c_out,
                                 layer.k, layer.s)
        assert sch.traffic.total_bytes < sch.staged_traffic.total_bytes, \
            (layer, c_out, sch)


def test_k7_kernel_parity_vs_lax():
    """A scaled-down k=7 stem block runs the fused kernel (DMA-staged)
    against the lax oracle — the tap loop is k-generic end to end."""
    layer, c_out = EFFICIENTNET_V2_K7_SEPARABLE[0]
    rng = np.random.default_rng(77)
    x = _rand(rng, (1, 18, 18, layer.c))
    w_dw = _rand(rng, (7, 7, layer.c), 0.2)
    w_pw = _rand(rng, (layer.c, c_out))
    got = convdk_fused_separable(x, w_dw, w_pw, stride=layer.s,
                                 interpret=True, residency="strip_dma_db")
    want = _sep_oracle(x, w_dw, w_pw, layer.s)
    np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# traffic / scratch model invariants
# ---------------------------------------------------------------------------

def test_db_moves_same_bytes_as_single_slot():
    """Double-buffering buys overlap, not traffic: byte-identical to
    strip_dma at every (shape, tile_h), at exactly 2x the strip scratch."""
    shape = SeparableShape(b=2, h=28, w=28, c_in=144, c_out=32, k=3, s=1)
    for th in (1, 4, 8, 28):
        dma = fused_separable_traffic(shape, th, residency="strip_dma")
        db = fused_separable_traffic(shape, th, residency="strip_dma_db")
        assert dma.total_bytes == db.total_bytes
        assert dma.dma_issues == db.dma_issues > 0
        assert (separable_staging_bytes(shape, th, "strip_dma_db")
                == 2 * separable_staging_bytes(shape, th, "strip_dma"))


def test_resident_pays_full_height_refetch():
    """With more than one c_in block, the resident rendering re-reads the
    full padded height per revisiting grid cell — strictly more HBM than
    strip DMA (the honest pricing of the legacy BlockSpec path); with one
    c_in block the resident input is fetched once and can win."""
    multi = SeparableShape(b=1, h=28, w=28, c_in=192, c_out=64, k=3, s=1)
    res = fused_separable_traffic(multi, 8, residency="resident")
    dma = fused_separable_traffic(multi, 8, residency="strip_dma")
    assert res.total_bytes > dma.total_bytes
    assert res.dma_issues == 0
    single = SeparableShape(b=1, h=28, w=28, c_in=64, c_out=256, k=3, s=1)
    res1 = fused_separable_traffic(single, 4, residency="resident")
    dma1 = fused_separable_traffic(single, 4, residency="strip_dma")
    assert res1.total_bytes < dma1.total_bytes   # fetched once, reused


def test_mbconv_residency_invariants():
    shape = MBConvShape(b=1, h=14, w=14, c_in=112, c_mid=672, c_out=192,
                        k=5, s=2)
    for mode in ("retain", "recompute"):
        dma = mbconv_fused_traffic(shape, 4, mode, residency="strip_dma")
        db = mbconv_fused_traffic(shape, 4, mode, residency="strip_dma_db")
        assert dma.total_bytes == db.total_bytes
        assert dma.dma_issues == db.dma_issues > 0
        assert (mbconv_staging_bytes(shape, 4, mode, "strip_dma_db")
                == 2 * mbconv_staging_bytes(shape, 4, mode, "strip_dma"))
    # the retained-DW stream is non-overlapping: retain staging exceeds
    # recompute staging by exactly the DW slot buffers
    assert (mbconv_staging_bytes(shape, 4, "retain", "strip_dma")
            > mbconv_staging_bytes(shape, 4, "recompute", "strip_dma"))


def test_staging_slots():
    assert [staging_slots(r) for r in RESIDENCY_MODES] == [0, 1, 2]
    with pytest.raises(ValueError):
        staging_slots("vmem")
    with pytest.raises(ValueError):
        fused_separable_traffic(
            SeparableShape(b=1, h=8, w=8, c_in=8, c_out=8, k=3, s=1),
            4, residency="hbm")


# ---------------------------------------------------------------------------
# property: solved schedules never exceed the VMEM budget
# ---------------------------------------------------------------------------

sep_shape_st = st.builds(
    SeparableShape,
    b=st.sampled_from([1, 2, 8]),
    h=st.sampled_from([7, 14, 28, 56, 112]),
    w=st.sampled_from([7, 14, 28, 56, 112]),
    c_in=st.sampled_from([8, 24, 96, 144, 192, 576, 960]),
    c_out=st.sampled_from([8, 24, 64, 160, 320]),
    k=st.sampled_from([3, 5, 7]),
    s=st.sampled_from([1, 2]),
)


@given(shape=sep_shape_st)
@settings(max_examples=120, deadline=None)
def test_separable_scratch_never_exceeds_budget(shape):
    """Property: every feasible candidate's modeled staging scratch — and
    its whole VMEM footprint — fits the autotuner's budget, and the
    winning schedule is among the candidates it was solved from."""
    tpu = TPUConfig(vmem_bytes=4 * 1024 * 1024)
    cands = candidate_schedules(shape, tpu)
    assert cands
    for cand in cands:
        fp = vmem_footprint_bytes(shape, cand.tile_h, tpu, cand.residency)
        assert fp <= tpu.vmem_bytes, cand
        assert separable_staging_bytes(
            shape, cand.tile_h, cand.residency, tpu.c_block) <= fp
    best = select_fused_schedule(shape, tpu)
    assert (best.tile_h, best.residency) in {
        (c.tile_h, c.residency) for c in cands}


mbconv_shape_st = st.builds(
    MBConvShape,
    b=st.sampled_from([1, 4]),
    h=st.sampled_from([7, 14, 28, 56]),
    w=st.sampled_from([7, 14, 28, 56]),
    c_in=st.sampled_from([16, 40, 112, 192]),
    c_mid=st.sampled_from([96, 240, 672, 1152]),
    c_out=st.sampled_from([16, 40, 112, 320]),
    k=st.sampled_from([3, 5, 7]),
    s=st.sampled_from([1, 2]),
)


@given(shape=mbconv_shape_st)
@settings(max_examples=80, deadline=None)
def test_mbconv_scratch_never_exceeds_budget(shape):
    tpu = TPUConfig(vmem_bytes=8 * 1024 * 1024)
    cands = candidate_mbconv_schedules(shape, tpu)
    assert cands
    for cand in cands:
        fp = mbconv_vmem_footprint_bytes(shape, cand.tile_h, tpu,
                                         cand.residency, cand.mode)
        assert fp <= tpu.vmem_bytes, cand
        assert mbconv_staging_bytes(
            shape, cand.tile_h, cand.mode, cand.residency,
            tpu.c_block) <= fp
    best = select_mbconv_schedule(shape, tpu)
    assert best.residency in RESIDENCY_MODES


# ---------------------------------------------------------------------------
# cache-key migration: legacy entries keep outranking model picks
# ---------------------------------------------------------------------------

def test_legacy_cache_entries_survive_residency_migration(tmp_path):
    """A measured entry persisted BEFORE the residency axis (and even
    before the mesh axis) must still be honored: its tile_h wins, and the
    residency is re-solved at that tile_h instead of orphaned."""
    from repro.core.autotune import _sep_key

    shape = SeparableShape(b=1, h=28, w=28, c_in=96, c_out=24, k=3, s=1)
    new_key = _sep_key(shape, TPUConfig())
    assert "|res=auto|" in new_key
    pre_res_key = new_key.replace("|res=auto|", "|")       # 6-segment era
    pre_mesh_key = pre_res_key.replace("|mesh1x1|", "|")   # 5-segment era
    for legacy_key in (pre_res_key, pre_mesh_key):
        (tmp_path / "legacy").mkdir(exist_ok=True)
        cache_file = tmp_path / "legacy" / "convdk_schedules.json"
        cache_file.write_text(json.dumps({
            "version": 1,
            "entries": {legacy_key: {"tile_h": 2, "source": "measured"}},
        }))
        try:
            set_schedule_cache_dir(tmp_path / "legacy")
            sch = get_fused_schedule(1, 28, 28, 96, 24, 3, 1)
            assert sch.tile_h == 2, legacy_key       # measured pick honored
            assert sch.residency in RESIDENCY_MODES  # re-solved, not stale
        finally:
            set_schedule_cache_dir(None)


def test_pinned_mbconv_mode_solves_under_that_mode():
    """A pinned pass-2 mode must re-solve tile_h/residency under ITS OWN
    VMEM footprint (retain carries the retained-DW stream buffers the
    recompute winner never paid for) and must not echo the free-solve's
    cached entry."""
    from repro.core.autotune import get_mbconv_schedule

    set_schedule_cache_dir(None)
    tpu = TPUConfig(vmem_bytes=640 * 1024)
    kwargs = dict(b=1, h=56, w=56, c_in=24, c_mid=144, c_out=40, k=5, s=2,
                  tpu=tpu)
    free = get_mbconv_schedule(**kwargs)
    for mode in ("retain", "recompute"):
        pinned = get_mbconv_schedule(**kwargs, mode=mode)
        assert pinned.mode == mode
        fp = mbconv_vmem_footprint_bytes(
            MBConvShape(b=1, h=56, w=56, c_in=24, c_mid=144, c_out=40,
                        k=5, s=2),
            pinned.tile_h, tpu, pinned.residency, mode)
        assert fp <= tpu.vmem_bytes, (mode, pinned)
    # the free-solve entry is still intact after the pinned lookups
    again = get_mbconv_schedule(**kwargs)
    assert (again.tile_h, again.mode, again.residency) \
        == (free.tile_h, free.mode, free.residency)


def test_pinned_residency_gets_its_own_cache_entry():
    """Pinned and auto requests never collide: each residency pin solves
    (and caches) under its own key and returns schedules at that pin."""
    set_schedule_cache_dir(None)
    auto = get_fused_schedule(1, 56, 56, 144, 32, 3, 1)
    for res in RESIDENCY_MODES:
        pinned = get_fused_schedule(1, 56, 56, 144, 32, 3, 1, residency=res)
        assert pinned.residency == res
    # the auto entry was not clobbered by the pins
    again = get_fused_schedule(1, 56, 56, 144, 32, 3, 1)
    assert (again.tile_h, again.residency) == (auto.tile_h, auto.residency)


# ---------------------------------------------------------------------------
# sharded jitted entry points: no re-trace at serving rate
# ---------------------------------------------------------------------------

def test_sharded_entry_point_traces_once():
    """ROADMAP edge: the sharded wrappers used to rebuild the shard_map
    closure per call, re-tracing the whole fused pipeline at serving rate.
    The cached jitted entry must trace ONCE per (mesh, schedule, shapes)."""
    from repro.compat import make_mesh
    from repro.kernels import (
        convdk_fused_separable_sharded, convdk_mbconv_fused_sharded,
    )
    from repro.kernels.convdk_sharded import TRACE_COUNTS

    mesh = make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(3)
    x = _rand(rng, (2, 10, 10, 16))
    w_dw = _rand(rng, (3, 3, 16))
    w_pw = _rand(rng, (16, 8))

    first = convdk_fused_separable_sharded(
        x, w_dw, w_pw, mesh=mesh, stride=1, interpret=True,
        residency="strip_dma_db")
    base = TRACE_COUNTS["separable"]
    for _ in range(3):
        out = convdk_fused_separable_sharded(
            x, w_dw, w_pw, mesh=mesh, stride=1, interpret=True,
            residency="strip_dma_db")
    assert TRACE_COUNTS["separable"] == base, "sharded separable re-traced"
    np.testing.assert_allclose(out, first, **TOL)
    np.testing.assert_allclose(out, _sep_oracle(x, w_dw, w_pw, 1), **TOL)

    ci, cm, cse, co = 8, 16, 2, 8
    weights = (_rand(rng, (ci, cm)), _rand(rng, (3, 3, cm), 0.3),
               _rand(rng, (cm, cse)), _rand(rng, (cse,), 0.1),
               _rand(rng, (cse, cm)), _rand(rng, (cm,), 0.1),
               _rand(rng, (cm, co)))
    xm = _rand(rng, (2, 8, 8, ci))
    first = convdk_mbconv_fused_sharded(
        xm, *weights, mesh=mesh, stride=1, interpret=True)
    base = TRACE_COUNTS["mbconv"]
    for _ in range(3):
        out = convdk_mbconv_fused_sharded(
            xm, *weights, mesh=mesh, stride=1, interpret=True)
    assert TRACE_COUNTS["mbconv"] == base, "sharded mbconv re-traced"
    np.testing.assert_allclose(out, first, **TOL)


def test_sharded_entry_point_retraces_on_new_schedule():
    """Distinct static schedules are distinct entries — no stale reuse."""
    from repro.compat import make_mesh
    from repro.kernels import convdk_fused_separable_sharded
    from repro.kernels.convdk_sharded import TRACE_COUNTS

    mesh = make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(4)
    x = _rand(rng, (1, 9, 9, 8))
    w_dw = _rand(rng, (3, 3, 8))
    w_pw = _rand(rng, (8, 8))
    convdk_fused_separable_sharded(x, w_dw, w_pw, mesh=mesh, tile_h=2,
                                   interpret=True)
    base = TRACE_COUNTS["separable"]
    out = convdk_fused_separable_sharded(x, w_dw, w_pw, mesh=mesh, tile_h=3,
                                         interpret=True)
    assert TRACE_COUNTS["separable"] > base
    np.testing.assert_allclose(out, _sep_oracle(x, w_dw, w_pw, 1), **TOL)

"""Two-pass fused MBConv kernel vs the pure jax.lax reference, the
retain/recompute traffic model, the autotuned schedule layer, and the
EfficientNet-B0 builder."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.autotune import (
    TPUConfig,
    candidate_mbconv_schedules,
    get_mbconv_schedule,
    mbconv_vmem_footprint_bytes,
    select_mbconv_schedule,
)
from repro.core.perfmodel import (
    MBCONV_MODES,
    MBConvShape,
    mbconv_best_fused_traffic,
    mbconv_staged_traffic,
)
from repro.core.workloads import EFFICIENTNET_B0, EFFICIENTNET_B0_MBCONV
from repro.kernels import convdk_mbconv_fused, convdk_mbconv_staged

TOL = dict(rtol=1e-4, atol=1e-4)


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def _mbconv_params(rng, c_in, expand, c_out, k, se_ratio=0.25):
    c_mid = c_in * expand
    c_se = max(1, int(c_in * se_ratio))
    if expand == 1:
        w_exp, exp_act = jnp.eye(c_mid, dtype=jnp.float32), None
    else:
        w_exp, exp_act = _rand(rng, (c_in, c_mid)), "silu"
    return (w_exp, _rand(rng, (k, k, c_mid), 0.3),
            _rand(rng, (c_mid, c_se)), _rand(rng, (c_se,), 0.1),
            _rand(rng, (c_se, c_mid)), _rand(rng, (c_mid,), 0.1),
            _rand(rng, (c_mid, c_out))), exp_act


def _oracle(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj, stride,
            exp_act="silu"):
    """Independent oracle: explicit lax convs + explicit SE (NOT the repo's
    mbconv_ref)."""
    e = x @ w_exp
    if exp_act == "silu":
        e = jax.nn.silu(e)
    k_h, k_w, c_mid = w_dw.shape
    d = jax.lax.conv_general_dilated(
        e, jnp.transpose(w_dw, (2, 0, 1))[:, None],
        window_strides=(stride, stride), padding="SAME",
        feature_group_count=c_mid,
        dimension_numbers=("NHWC", "OIHW", "NHWC"))
    d = jax.nn.silu(d)
    gate = jax.nn.sigmoid(
        jax.nn.silu(d.mean(axis=(1, 2)) @ w_se1 + b_se1) @ w_se2 + b_se2)
    return (d * gate[:, None, None, :]) @ w_proj


# ---------------------------------------------------------------------------
# numerics vs the lax + explicit-SE oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [3, 5])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("mode", ["retain", "recompute"])
def test_mbconv_fused_matches_lax_oracle(k, stride, mode):
    rng = np.random.default_rng(k * 10 + stride)
    b, h, w_in, ci, e, co = 2, 15, 11, 8, 3, 16      # odd H, odd W
    x = _rand(rng, (b, h, w_in, ci))
    weights, exp_act = _mbconv_params(rng, ci, e, co, k)
    got = convdk_mbconv_fused(x, *weights, stride=stride, mode=mode,
                              tile_h=4, interpret=True)
    want = _oracle(x, *weights, stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, **TOL)


def test_mbconv_expand_ratio_one():
    """MBConv1 (no expansion conv): identity expand + exp_act=None is the
    exact same math as running DW directly on the input."""
    rng = np.random.default_rng(5)
    ci = co = 16
    x = _rand(rng, (1, 9, 9, ci))
    weights, exp_act = _mbconv_params(rng, ci, 1, co, 3)
    assert exp_act is None
    for mode in MBCONV_MODES:
        got = convdk_mbconv_fused(x, *weights, stride=1, mode=mode,
                                  exp_act=None, interpret=True)
        want = _oracle(x, *weights, 1, exp_act=None)
        np.testing.assert_allclose(got, want, **TOL)


def test_mbconv_retain_recompute_agree():
    """Both pass-2 variants compute the identical block (schedule is
    traffic-only, like tile_h)."""
    rng = np.random.default_rng(7)
    x = _rand(rng, (2, 14, 14, 8))
    weights, _ = _mbconv_params(rng, 8, 4, 24, 5)
    for tile_h in (1, 3, 8):
        a = convdk_mbconv_fused(x, *weights, stride=2, mode="retain",
                                tile_h=tile_h, interpret=True)
        b = convdk_mbconv_fused(x, *weights, stride=2, mode="recompute",
                                tile_h=tile_h, interpret=True)
        np.testing.assert_allclose(a, b, **TOL)


def test_mbconv_fused_matches_staged_pipeline():
    """The two-pass fused kernel and the staged DW->HBM->SE->PW path are
    the same math."""
    rng = np.random.default_rng(9)
    x = _rand(rng, (2, 13, 12, 16))
    weights, _ = _mbconv_params(rng, 16, 2, 24, 3)
    for s in (1, 2):
        fused = convdk_mbconv_fused(x, *weights, stride=s, interpret=True)
        staged = convdk_mbconv_staged(x, *weights, stride=s, interpret=True)
        np.testing.assert_allclose(fused, staged, **TOL)


def test_mbconv_b0_layer_shapes_parity():
    """Acceptance gate: the fused two-pass kernel matches the lax reference
    for EVERY EfficientNet-B0 layer topology (channel-scaled so interpret
    mode stays fast; k, s, expand ratio, SE ratio and the channel-block
    structure are the real ones)."""
    rng = np.random.default_rng(11)
    seen = set()
    for ci, co, e, k, s, hw in EFFICIENTNET_B0_MBCONV:
        topo = (ci, co, e, k, s)
        if topo in seen:            # repeated stage-interior blocks
            continue
        seen.add(topo)
        ci_s, co_s = max(8, ci // 8), max(8, co // 8)
        hw_s = min(hw, 14)
        x = _rand(rng, (1, hw_s, hw_s, ci_s))
        weights, exp_act = _mbconv_params(rng, ci_s, e, co_s, k)
        sch = get_mbconv_schedule(1, hw_s, hw_s, ci_s, ci_s * e, co_s, k, s)
        got = convdk_mbconv_fused(x, *weights, stride=s, tile_h=sch.tile_h,
                                  mode=sch.mode, exp_act=exp_act,
                                  interpret=True)
        want = _oracle(x, *weights, s, exp_act=exp_act)
        np.testing.assert_allclose(got, want, err_msg=str(topo), **TOL)


def test_mbconv_grad_matches_reference():
    from repro.kernels import mbconv_ref

    rng = np.random.default_rng(3)
    x = _rand(rng, (1, 10, 9, 8))
    weights, _ = _mbconv_params(rng, 8, 3, 12, 3)

    def loss(fn):
        return lambda *p: (fn(*p) ** 2).sum()

    f = loss(lambda *p: convdk_mbconv_fused(*p, stride=2, mode="retain",
                                            interpret=True))
    r = loss(lambda *p: mbconv_ref(*p, stride=2))
    g = jax.grad(f, argnums=tuple(range(8)))(x, *weights)
    g_ref = jax.grad(r, argnums=tuple(range(8)))(x, *weights)
    for got, want in zip(g, g_ref):
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# two-pass traffic model + autotune
# ---------------------------------------------------------------------------

def test_mbconv_traffic_below_staged_all_b0_layers():
    """The tentpole claim, asserted layer by layer: the two-pass fused
    pipeline's modeled HBM traffic is strictly below the staged
    DW->HBM->SE->PW baseline for every EfficientNet-B0 MBConv block."""
    assert len(EFFICIENTNET_B0_MBCONV) == 16
    modes = set()
    for ci, co, e, k, s, hw in EFFICIENTNET_B0_MBCONV:
        sch = get_mbconv_schedule(1, hw, hw, ci, ci * e, co, k, s)
        assert sch.traffic.total_bytes < sch.staged_traffic.total_bytes, \
            (ci, co, e, k, s, hw, sch)
        modes.add(sch.mode)
    # B0 exercises BOTH sides of the retain/recompute crossover
    assert modes == set(MBCONV_MODES)


def _shape(c_in, e, hw, k, s, c_out):
    return MBConvShape(b=1, h=hw, w=hw, c_in=c_in, c_mid=c_in * e,
                       c_out=c_out, k=k, s=s)


mbconv_shape_st = st.builds(
    _shape,
    c_in=st.sampled_from([8, 16, 24, 32, 48, 64, 96, 128]),
    e=st.sampled_from([1, 4, 6]),
    hw=st.integers(7, 64),
    k=st.sampled_from([3, 5]),
    s=st.sampled_from([1, 2]),
    c_out=st.sampled_from([8, 16, 24, 40, 64, 96]),
)


@given(shape=mbconv_shape_st)
@settings(max_examples=150, deadline=None)
def test_mbconv_schedule_choice_never_exceeds_staged(shape):
    """Property: the autotuned (tile_h, mode) choice is (a) the cheaper of
    retain/recompute at its tile_h, (b) minimal over all candidates, and
    (c) strictly below the staged baseline."""
    sch = select_mbconv_schedule(shape)
    mode, best = mbconv_best_fused_traffic(shape, sch.tile_h,
                                           residency=sch.residency)
    assert sch.traffic.total_bytes == best.total_bytes
    for cand in candidate_mbconv_schedules(shape):
        assert sch.traffic.total_bytes <= cand.traffic.total_bytes
    assert sch.traffic.total_bytes < sch.staged_traffic.total_bytes
    assert 1 <= sch.tile_h <= shape.out_h
    assert sch.mode in MBCONV_MODES


def test_mbconv_best_mode_below_staged_any_tile_h():
    """On a representative high-resolution block the two-pass win is not an
    autotune artifact: the cheaper mode beats staged at EVERY candidate
    tile_h.  (Deep 7x7 blocks DO lose at deliberately bad tile_h — the
    per-layer schedule solve is load-bearing there, which is the point of
    ``select_mbconv_schedule``.)"""
    shape = _shape(16, 6, 112, 3, 2, 24)
    for tile_h in (1, 2, 4, 8, 16, 32):
        tile_h = max(1, min(tile_h, shape.out_h))
        _, best = mbconv_best_fused_traffic(shape, tile_h)
        staged = mbconv_staged_traffic(shape, tile_h)
        assert best.total_bytes < staged.total_bytes, tile_h


def test_mbconv_retain_recompute_crossover_structure():
    """Retain wins when the DW tensor is small vs the re-staged input
    (deep, low-resolution layers); recompute wins when re-reading input
    strips is cheaper than a DW round-trip (wide, high-resolution
    layers)."""
    deep = _shape(192, 6, 7, 5, 1, 192)     # 7x7x1152 tail
    wide = _shape(16, 6, 112, 3, 2, 24)     # 112x112x96 head
    assert select_mbconv_schedule(deep).mode == "retain"
    assert select_mbconv_schedule(wide).mode == "recompute"


def test_mbconv_autotune_respects_vmem_budget():
    tpu = TPUConfig(vmem_bytes=512 * 1024)
    shape = _shape(16, 6, 56, 3, 1, 24)
    for cand in candidate_mbconv_schedules(shape, tpu):
        assert mbconv_vmem_footprint_bytes(
            shape, cand.tile_h, tpu, cand.residency, cand.mode) \
            <= tpu.vmem_bytes


def test_mbconv_autotuned_schedule_runs():
    """The selected (tile_h, mode) is directly runnable on the kernel."""
    rng = np.random.default_rng(13)
    ci, e, co, k, s, hw = 16, 4, 24, 5, 2, 14
    sch = get_mbconv_schedule(1, hw, hw, ci, ci * e, co, k, s)
    x = _rand(rng, (1, hw, hw, ci))
    weights, _ = _mbconv_params(rng, ci, e, co, k)
    got = convdk_mbconv_fused(x, *weights, stride=s, tile_h=sch.tile_h,
                              mode=sch.mode, interpret=True)
    want = _oracle(x, *weights, s)
    np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# model layer: mbconv_block, EfficientNet-B0, VLM stem
# ---------------------------------------------------------------------------

def test_mbconv_block_routes_both_paths_and_residual():
    from repro.configs.base import ConvKernelConfig
    from repro.models.mbconv import mbconv_block, mbconv_def
    from repro.models.param import materialize

    params = materialize(mbconv_def(16, 16, k=3, expand_ratio=4),
                         jax.random.key(0))
    rng = np.random.default_rng(2)
    x = _rand(rng, (2, 14, 14, 16))
    fused = mbconv_block(
        params, x, stride=1,
        kcfg=ConvKernelConfig(fused_mbconv=True, interpret=True))
    staged = mbconv_block(
        params, x, stride=1,
        kcfg=ConvKernelConfig(fused_mbconv=False, interpret=True))
    assert fused.shape == (2, 14, 14, 16)
    np.testing.assert_allclose(fused, staged, **TOL)
    # the identity residual is live: zeroing the projection leaves x
    zeroed = dict(params, proj=jnp.zeros_like(params["proj"]))
    out = mbconv_block(
        zeroed, x, stride=1,
        kcfg=ConvKernelConfig(fused_mbconv=True, interpret=True))
    np.testing.assert_allclose(out, x, **TOL)


def test_effnet_block_specs_match_workloads_table():
    """The model builder's stage table, the workloads MBConv table and the
    paper's DW table are three views of the same network."""
    from repro.models.mbconv import EffNetConfig, effnet_block_specs

    specs = effnet_block_specs(EffNetConfig())
    assert [(sp.c_in, sp.c_out, sp.expand_ratio, sp.k, sp.s)
            for sp in specs] \
        == [t[:5] for t in EFFICIENTNET_B0_MBCONV]
    hw = 112
    for sp, layer in zip(specs, EFFICIENTNET_B0):
        assert (sp.c_mid, sp.k, sp.s) == (layer.c, layer.k, layer.s)
        assert layer.h == hw
        hw = -(-hw // sp.s)


def test_efficientnet_b0_forward_backward():
    from repro.configs.efficientnet_b0 import efficientnet_b0_smoke
    from repro.models.mbconv import efficientnet_b0_apply, efficientnet_b0_def
    from repro.models.param import materialize

    cfg = efficientnet_b0_smoke(width_mult=0.125, num_classes=4)
    params = materialize(efficientnet_b0_def(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    x = _rand(rng, (1, 16, 16, 3))
    logits = efficientnet_b0_apply(params, x, cfg)
    assert logits.shape == (1, 4)
    assert bool(jnp.isfinite(logits).all())

    def loss_fn(p):
        return (efficientnet_b0_apply(p, x, cfg) ** 2).sum()

    grads = jax.grad(loss_fn)(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


def test_vision_stem_arch_validated():
    from repro.models.model import ModelConfig, vision_stem_def

    cfg = ModelConfig(family="vlm", vision_stem=True,
                      vision_stem_arch="MBConv")          # typo/case slip
    with pytest.raises(ValueError, match="vision_stem_arch"):
        vision_stem_def(cfg)


def test_vlm_mbconv_vision_stem_forward():
    from repro.configs.efficientnet_b0 import efficientnet_b0_vlm
    from repro.models.model import forward, model_def
    from repro.models.param import materialize

    cfg = efficientnet_b0_vlm(d_model=64, n_heads=4, n_kv_heads=4,
                              head_dim=16, d_ff=128, vocab=64,
                              dtype="float32", vision_stem_c0=8)
    assert cfg.vision_stem_arch == "mbconv"
    params = materialize(model_def(cfg), jax.random.key(0))
    assert "exp" in params["vstem"]["sep0"]          # SE-equipped MBConv stem
    assert "se_w1" in params["vstem"]["sep0"]
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    imgs = _rand(rng, (2, 32, 32, 3))
    logits = forward(params, {"tokens": toks, "images": imgs}, cfg)
    # 32 -> 16 (stem/2) -> 8 -> 4: 16 patch tokens prepended to 6 text tokens
    assert logits.shape == (2, 16 + 6, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

"""Vision serving engine: resolution-bucket admission, shape-stable
batches (one trace per bucket — asserted via the trace-time counter),
load-shedding at the queue bound, and exact reconciliation of telemetry
byte counters against the solved plans' modeled traffic."""

import jax
import numpy as np
import pytest

from repro.configs.efficientnet_b0 import efficientnet_b0_smoke
from repro.core import telemetry
from repro.models.mbconv import efficientnet_b0_def
from repro.models.param import materialize
from repro.serve import VisionEngine, VisionServeConfig
from repro.serve.vision import layer_names

RES = (16, 24, 32)


@pytest.fixture(scope="module")
def engine_parts():
    cfg = efficientnet_b0_smoke(width_mult=0.125, num_classes=4)
    params = materialize(efficientnet_b0_def(cfg), jax.random.key(0))
    return cfg, params


def _engine(engine_parts, **kw):
    cfg, params = engine_parts
    kw.setdefault("resolutions", RES)
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_queue", 8)
    return VisionEngine(params, cfg, VisionServeConfig(**kw))


def _img(rng, side):
    return rng.random((side, side, 3), np.float32)


def test_bucket_admission(engine_parts):
    telemetry.reset()
    eng = _engine(engine_parts)
    assert eng.bucket_for(14, 9) == 16
    assert eng.bucket_for(16, 16) == 16
    assert eng.bucket_for(17, 4) == 24     # longest side picks the bucket
    assert eng.bucket_for(32, 32) == 32
    assert eng.bucket_for(33, 1) is None   # above the largest bucket

    rng = np.random.default_rng(0)
    assert eng.submit(_img(rng, 12)) == 0
    assert eng.submit(_img(rng, 40)) is None          # oversize -> shed
    assert telemetry.get_telemetry().get("serve.shed.oversize") == 1
    with pytest.raises(ValueError):
        eng.submit(rng.random((8, 8), np.float32))    # not (H, W, 3)


def test_load_shedding_at_queue_bound(engine_parts):
    telemetry.reset()
    eng = _engine(engine_parts, max_queue=3)
    rng = np.random.default_rng(1)
    rids = [eng.submit(_img(rng, 16)) for _ in range(5)]
    assert rids[:3] == [0, 1, 2]
    assert rids[3:] == [None, None]        # queue at bound -> shed
    t = telemetry.get_telemetry()
    assert t.get("serve.shed.queue_full") == 2
    assert t.get("serve.admitted") == 3
    assert eng.shed == 2
    # draining frees the queue: admission resumes
    eng.drain()
    assert eng.submit(_img(rng, 16)) == 3


def test_mixed_stream_shape_stable_batches(engine_parts):
    """Mixed 16/24/32 submissions must compile ONCE per bucket, never per
    request or per batch: the trace-time counter inside each bucket's
    jitted apply is the retrace detector."""
    telemetry.reset()
    eng = _engine(engine_parts)
    rng = np.random.default_rng(2)
    sides = (14, 16, 24, 20, 32, 30, 12)   # buckets: 3x r16, 2x r24, 2x r32
    for side in sides:
        assert eng.submit(_img(rng, side)) is not None
    results = eng.drain()
    assert eng.pending() == 0
    assert [r.rid for r in sorted(results, key=lambda r: r.rid)] \
        == list(range(len(sides)))
    assert all(r.logits.shape == (4,) for r in results)
    assert all(r.latency_s >= r.queue_wait_s >= 0 for r in results)

    t = telemetry.get_telemetry()
    # r16 takes sides 14,16,12 (2 batches of batch_size=2), r24 takes
    # 24,20 (1 batch), r32 takes 32,30 (1 batch)
    assert t.get("serve.batches.r16") == 2
    assert t.get("serve.batches.r24") == 1
    assert t.get("serve.batches.r32") == 1
    assert t.get("serve.pad_slots.r16") == 1
    # THE shape-stability assertion: one compilation per bucket
    for res in RES:
        assert t.get(f"serve.trace.r{res}") == 1, res

    # FIFO within a bucket, batches keyed by the oldest waiter
    by_rid = {r.rid: r for r in results}
    assert [by_rid[i].bucket for i in range(7)] \
        == [16, 16, 24, 24, 32, 32, 16]


def test_counters_reconcile_with_modeled_traffic(engine_parts):
    """The acceptance gate: every (bucket, layer) byte counter equals
    n_batches x the solved plan's modeled bytes for that layer, and the
    per-layer rows sum to ``NetworkPlan.total_bytes`` — the engine
    charges exactly what ``perfmodel``'s ShardedTraffic prices."""
    telemetry.reset()
    eng = _engine(engine_parts)
    rng = np.random.default_rng(3)
    for side in (16, 16, 16, 24, 32, 32):
        eng.submit(_img(rng, side))
    eng.drain()

    t = telemetry.get_telemetry()
    n_layers = len(layer_names(len(eng.specs)))
    for res in RES:
        nb = t.get(f"serve.batches.r{res}")
        assert nb >= 1
        modeled = eng.modeled_layer_bytes(res)
        assert len(modeled) == n_layers
        for layer, (total, coll) in modeled.items():
            assert t.get(f"serve.bytes.r{res}.{layer}") == nb * total
            assert t.get(f"serve.collective.r{res}.{layer}") == nb * coll
        plan = eng.plan_for(res)
        assert sum(tb for tb, _ in modeled.values()) == plan.total_bytes


def test_request_traffic_shares_sum_to_plan(engine_parts):
    telemetry.reset()
    eng = _engine(engine_parts)
    rng = np.random.default_rng(4)
    for side in (16, 12, 24):              # one full r16 batch + short r24
        eng.submit(_img(rng, side))
    results = eng.drain()
    r16 = [r for r in results if r.bucket == 16]
    r24 = [r for r in results if r.bucket == 24]
    assert sum(r.traffic_bytes for r in r16) \
        == pytest.approx(eng.plan_for(16).total_bytes)
    # a lone rider on a padded batch is charged the WHOLE batch
    assert r24[0].traffic_bytes == pytest.approx(
        eng.plan_for(24).total_bytes)


def test_plan_solved_once_per_bucket(engine_parts):
    """Steady state never re-solves: the autotune counters must show one
    network-plan solve per bucket and reuses for every later launch."""
    telemetry.reset()
    eng = _engine(engine_parts, resolutions=(16,))
    rng = np.random.default_rng(5)
    for _ in range(6):
        eng.submit(_img(rng, 16))
    eng.drain()
    t = telemetry.get_telemetry()
    assert t.get("serve.batches.r16") == 3
    # plan_for caches in-engine; the underlying get_network_plan fires
    # once on the first launch path (solve OR reuse from another test's
    # lru cache) — what matters is the engine asked autotune only once
    assert (t.get("autotune.network_plan.solve")
            + t.get("autotune.network_plan.reuse")) == 1


def test_latency_series_and_percentiles(engine_parts):
    telemetry.reset()
    eng = _engine(engine_parts, resolutions=(16,))
    rng = np.random.default_rng(6)
    for _ in range(4):
        eng.submit(_img(rng, 16))
    eng.drain()
    assert len(telemetry.series("serve.latency_s")) == 4
    pct = eng.latency_percentiles()
    assert set(pct) == {"p50", "p90", "p99"}
    assert 0 < pct["p50"] <= pct["p90"] <= pct["p99"]
    snap = telemetry.get_telemetry().snapshot()
    assert snap["series"]["serve.queue_wait_s"]["count"] == 4


def test_priority_admission_two_level_fifo(engine_parts):
    """Priority requests are served ahead of earlier normal requests
    (FIFO within each lane; the batch back-fills from the normal lane's
    same bucket), and the priority counter tracks them."""
    telemetry.reset()
    eng = _engine(engine_parts, resolutions=(16,), batch_size=2)
    rng = np.random.default_rng(7)
    r0 = eng.submit(_img(rng, 16))                       # normal
    r1 = eng.submit(_img(rng, 16))                       # normal
    r2 = eng.submit(_img(rng, 16), priority=1)           # priority
    r3 = eng.submit(_img(rng, 16), priority=1)           # priority
    assert [r0, r1, r2, r3] == [0, 1, 2, 3]
    # batch 1 = both priority requests, ahead of the earlier normal two
    first = eng.step()
    assert sorted(r.rid for r in first) == [2, 3]
    second = eng.step()
    assert sorted(r.rid for r in second) == [0, 1]
    t = telemetry.get_telemetry()
    assert t.get("serve.admitted") == 4
    assert t.get("serve.admitted.priority") == 2


def test_priority_batch_backfills_from_normal_lane(engine_parts):
    """A lone priority request rides with same-bucket normal waiters —
    the priority lane picks the bucket, the normal lane fills the pack."""
    telemetry.reset()
    eng = _engine(engine_parts, batch_size=2)
    rng = np.random.default_rng(8)
    eng.submit(_img(rng, 24))                            # normal, r24
    eng.submit(_img(rng, 16))                            # normal, r16
    eng.submit(_img(rng, 16), priority=1)                # priority, r16
    batch = eng.step()
    # the priority waiter's bucket (16) launches first, back-filled with
    # the normal r16 request; the older normal r24 request waits
    assert sorted(r.rid for r in batch) == [1, 2]
    assert all(r.bucket == 16 for r in batch)
    assert eng.pending() == 1
    rest = eng.drain()
    assert [r.rid for r in rest] == [0]


def test_priority_does_not_bypass_shedding(engine_parts):
    """The queue bound covers both lanes combined: priority admission
    reorders service among the admitted, never the shed accounting."""
    telemetry.reset()
    eng = _engine(engine_parts, resolutions=(16,), max_queue=2)
    rng = np.random.default_rng(9)
    assert eng.submit(_img(rng, 16)) == 0
    assert eng.submit(_img(rng, 16), priority=1) == 1
    assert eng.submit(_img(rng, 16), priority=1) is None  # bound -> shed
    assert eng.submit(_img(rng, 16)) is None
    t = telemetry.get_telemetry()
    assert t.get("serve.shed.queue_full") == 2
    assert eng.shed == 2
    assert eng.pending() == 2


def test_pipelined_boundaries_counter(engine_parts):
    """Solving a bucket's plan records the solved overlap count — 0 on
    the degenerate (1,1) mesh is fine; what matters is the counter fires
    once per bucket at solve time and matches the plan."""
    telemetry.reset()
    eng = _engine(engine_parts, resolutions=(16,))
    plan = eng.plan_for(16)
    t = telemetry.get_telemetry()
    assert t.get("serve.pipelined_boundaries.r16") \
        == len(plan.pipelined_boundaries)
    eng.plan_for(16)                                     # cached: no re-count
    assert t.get("serve.pipelined_boundaries.r16") \
        == len(plan.pipelined_boundaries)


def test_serve_config_validation():
    with pytest.raises(ValueError):
        VisionServeConfig(resolutions=())
    with pytest.raises(ValueError):
        VisionServeConfig(resolutions=(32, 16))      # not ascending
    with pytest.raises(ValueError):
        VisionServeConfig(resolutions=(16, 16, 24))  # duplicate
    with pytest.raises(ValueError):
        VisionServeConfig(resolutions=(16,), batch_size=0)

"""Suite-wide bootstrap: src-layout import path + hypothesis fallback.

Runs before any test module imports, so the whole suite collects even when
optional dev dependencies (hypothesis) are missing — property tests then run
against the deterministic fallback in ``repro.testing``.
"""

import os
import sys

# Pin the residual-forwarding barrier ON for the suite (unless the caller
# already chose): the first sharded dispatch otherwise runs a ~5 s gradient
# probe whose answer on fixed JAX builds is "barrier off" — and the barrier
# is exact either way, so tests buy nothing with those seconds.  The env
# must be set before ``repro.compat`` is imported (it reads it at import).
os.environ.setdefault("CONVDK_RESIDUAL_BARRIER", "on")

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.testing import install_hypothesis_fallback  # noqa: E402

HYPOTHESIS_FALLBACK = install_hypothesis_fallback()

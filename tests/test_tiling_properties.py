"""Hypothesis property tests on the BIG/LITTLE scheduler invariants."""

from hypothesis import given, settings, strategies as st

from repro.core.tiling import DWLayer, MacroConfig, plan_layer

MACRO = MacroConfig()

layer_st = st.builds(
    DWLayer,
    c=st.integers(1, 1024),
    h=st.integers(7, 224),
    w=st.integers(7, 224),
    k=st.sampled_from([3, 5]),
    s=st.sampled_from([1, 2]),
)


@given(layer=layer_st)
@settings(max_examples=200, deadline=None)
def test_plan_invariants(layer):
    plan = plan_layer(layer, MACRO)
    # 1. every output column is produced exactly once across strips
    assert plan.strip_out_total == layer.out_w
    # 2. the stationary memory never overflows
    assert 0 < plan.tm_rows_used <= MACRO.tm_words
    # 3. the streaming register file never overflows (per tile):
    #    n_ch channel strips of the main schedule
    ia_main = plan.strips[0].sched.ia_len
    assert plan.n_ch * layer.k * ia_main <= MACRO.trf_words
    # 4. regime selection matches the paper's rule
    t_w = MACRO.t_w(layer.k)
    assert plan.mode == ("BIG" if layer.padded_w > t_w else "LITTLE")
    # 5. BIG never packs channels
    if plan.mode == "BIG":
        assert plan.n_ch == 1
    # 6. parallelism accounting is consistent
    assert 1 <= plan.tiles_active <= MACRO.n_tiles
    assert plan.rounds >= 1
    assert plan.jobs * 1 >= plan.rounds  # jobs fill at least `rounds` waves


@given(layer=layer_st)
@settings(max_examples=100, deadline=None)
def test_strip_schedules_are_valid(layer):
    plan = plan_layer(layer, MACRO)
    for sp in plan.strips:
        # each strip schedule covers its claimed outputs
        assert sp.out_cols <= sp.sched.out_len
        # strip fits the TRF rows allotted to one channel
        assert layer.k * sp.sched.ia_len <= MACRO.trf_words

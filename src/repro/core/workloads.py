"""Per-layer DWConv tables for the paper's five evaluation models.

All tables are the canonical 224x224-input configurations from the
respective papers:

* MobileNetV1  [arXiv:1704.04861, Table 1]
* MobileNetV2  [arXiv:1801.04381, Table 2]  (t = 6 expansion)
* MobileNetV3-Large / -Small  [arXiv:1905.02244, Tables 1-2]
* EfficientNet-B0  [arXiv:1905.11946, Table 1]

Each entry is the depthwise stage of a block: (channels of the *expanded*
tensor the DWConv runs on, ifmap H=W at that point, kernel k, stride s).
Pointwise (1x1) convolutions are not listed: the paper evaluates DWConv
dataflows only (PWConv uses the ordinary long-input-channel WS mapping).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from .tiling import DWLayer


def _dw(c: int, hw: int, k: int, s: int) -> DWLayer:
    return DWLayer(c=c, h=hw, w=hw, k=k, s=s)


MOBILENET_V1: List[DWLayer] = [
    _dw(32, 112, 3, 1),
    _dw(64, 112, 3, 2),
    _dw(128, 56, 3, 1),
    _dw(128, 56, 3, 2),
    _dw(256, 28, 3, 1),
    _dw(256, 28, 3, 2),
    *[_dw(512, 14, 3, 1) for _ in range(5)],
    _dw(512, 14, 3, 2),
    _dw(1024, 7, 3, 1),
]

# MobileNetV2: expanded channels = t * c_in of the preceding block.
MOBILENET_V2: List[DWLayer] = [
    _dw(32, 112, 3, 1),     # first bottleneck, t = 1
    _dw(96, 112, 3, 2),     # 16 -> 24, t = 6
    _dw(144, 56, 3, 1),
    _dw(144, 56, 3, 2),     # 24 -> 32
    _dw(192, 28, 3, 1),
    _dw(192, 28, 3, 1),
    _dw(192, 28, 3, 2),     # 32 -> 64
    *[_dw(384, 14, 3, 1) for _ in range(3)],
    _dw(384, 14, 3, 1),     # 64 -> 96 stage (s = 1)
    _dw(576, 14, 3, 1),
    _dw(576, 14, 3, 1),
    _dw(576, 14, 3, 2),     # 96 -> 160
    _dw(960, 7, 3, 1),
    _dw(960, 7, 3, 1),
    _dw(960, 7, 3, 1),      # 160 -> 320 (s = 1)
]

# MobileNetV3-Large: (k, expanded size, s, ifmap hw)
_V3L: List[Tuple[int, int, int, int]] = [
    (3, 16, 1, 112),
    (3, 64, 2, 112),
    (3, 72, 1, 56),
    (5, 72, 2, 56),
    (5, 120, 1, 28),
    (5, 120, 1, 28),
    (3, 240, 2, 28),
    (3, 200, 1, 14),
    (3, 184, 1, 14),
    (3, 184, 1, 14),
    (3, 480, 1, 14),
    (3, 672, 1, 14),
    (5, 672, 2, 14),
    (5, 960, 1, 7),
    (5, 960, 1, 7),
]
MOBILENET_V3_LARGE: List[DWLayer] = [_dw(e, hw, k, s) for k, e, s, hw in _V3L]

# MobileNetV3-Small
_V3S: List[Tuple[int, int, int, int]] = [
    (3, 16, 2, 112),
    (3, 72, 2, 56),
    (3, 88, 1, 28),
    (5, 96, 2, 28),
    (5, 240, 1, 14),
    (5, 240, 1, 14),
    (5, 120, 1, 14),
    (5, 144, 1, 14),
    (5, 288, 2, 14),
    (5, 576, 1, 7),
    (5, 576, 1, 7),
]
MOBILENET_V3_SMALL: List[DWLayer] = [_dw(e, hw, k, s) for k, e, s, hw in _V3S]

# EfficientNet-B0: MBConv blocks, (k, expanded size, s, ifmap hw)
_EFFB0: List[Tuple[int, int, int, int]] = [
    (3, 32, 1, 112),     # MBConv1
    (3, 96, 2, 112),     # stage 3 first
    (3, 144, 1, 56),
    (5, 144, 2, 56),     # stage 4 first
    (5, 240, 1, 28),
    (3, 240, 2, 28),     # stage 5 first
    (3, 480, 1, 14),
    (3, 480, 1, 14),
    (5, 480, 1, 14),     # stage 6 (s = 1, 14x14)
    (5, 672, 1, 14),
    (5, 672, 1, 14),
    (5, 672, 2, 14),     # stage 7 first
    (5, 1152, 1, 7),
    (5, 1152, 1, 7),
    (5, 1152, 1, 7),
    (3, 1152, 1, 7),     # stage 8
]
EFFICIENTNET_B0: List[DWLayer] = [_dw(e, hw, k, s) for k, e, s, hw in _EFFB0]


# MobileNetV2 pointwise-projection output channels per DW entry above
# (the linear-bottleneck channel the 1x1 conv maps the expanded tensor to;
# arXiv:1801.04381 Table 2).  Drives the fused separable-block traffic
# accounting: the DW table alone cannot price the fused DW+PW pipeline.
MOBILENET_V2_PW_OUT: List[int] = [
    16,                # 32 -> 16, t = 1
    24, 24,            # 96/144 -> 24
    32, 32, 32,        # 144/192 -> 32
    64, 64, 64, 64,    # 192/384 -> 64
    96, 96, 96,        # 384/576 -> 96
    160, 160, 160,     # 576/960 -> 160
    320,               # 960 -> 320
]
assert len(MOBILENET_V2_PW_OUT) == len(MOBILENET_V2)

# (DW stage, pointwise C_out) pairs — the full separable block per layer.
MOBILENET_V2_SEPARABLE: List[Tuple[DWLayer, int]] = list(
    zip(MOBILENET_V2, MOBILENET_V2_PW_OUT))


# EfficientNet-B0 full MBConv blocks: (c_in, c_out, expand_ratio, k, s,
# ifmap hw) per block [arXiv:1905.11946, Table 1; SE ratio 0.25 throughout].
# The DW stage of each entry (c_in * expand_ratio channels at hw) must
# reproduce the EFFICIENTNET_B0 DW table above — asserted below, and the
# model builder in ``models.mbconv`` derives the same list from the stage
# table (tests pin all three views together).
EFFICIENTNET_B0_MBCONV: List[Tuple[int, int, int, int, int, int]] = [
    (32, 16, 1, 3, 1, 112),      # MBConv1
    (16, 24, 6, 3, 2, 112),      # stage 3 first
    (24, 24, 6, 3, 1, 56),
    (24, 40, 6, 5, 2, 56),       # stage 4 first
    (40, 40, 6, 5, 1, 28),
    (40, 80, 6, 3, 2, 28),       # stage 5 first
    (80, 80, 6, 3, 1, 14),
    (80, 80, 6, 3, 1, 14),
    (80, 112, 6, 5, 1, 14),      # stage 6 (s = 1, 14x14)
    (112, 112, 6, 5, 1, 14),
    (112, 112, 6, 5, 1, 14),
    (112, 192, 6, 5, 2, 14),     # stage 7 first
    (192, 192, 6, 5, 1, 7),
    (192, 192, 6, 5, 1, 7),
    (192, 192, 6, 5, 1, 7),
    (192, 320, 6, 3, 1, 7),      # stage 8
]
assert [(k, ci * e, s, hw) for ci, _co, e, k, s, hw
        in EFFICIENTNET_B0_MBCONV] == _EFFB0


# MobileNet-V3 per-row block metadata: (c_in, c_out, SE, act) aligned
# with the DW tables above [arXiv:1905.02244, Tables 1-2].  The DW table
# alone prices only the depthwise stage; the full two-pass fused block
# additionally needs the projection width and the per-row SE/act facts
# (V3 runs relu early stages, hard_swish late, SE on SOME blocks — a
# no-SE row must be priced with zero SE bytes).
MOBILENET_V3_LARGE_META: List[Tuple[int, int, bool, str]] = [
    (16, 16, False, "relu"),
    (16, 24, False, "relu"),
    (24, 24, False, "relu"),
    (24, 40, True, "relu"),
    (40, 40, True, "relu"),
    (40, 40, True, "relu"),
    (40, 80, False, "hard_swish"),
    (80, 80, False, "hard_swish"),
    (80, 80, False, "hard_swish"),
    (80, 80, False, "hard_swish"),
    (80, 112, True, "hard_swish"),
    (112, 112, True, "hard_swish"),
    (112, 160, True, "hard_swish"),
    (160, 160, True, "hard_swish"),
    (160, 160, True, "hard_swish"),
]
assert len(MOBILENET_V3_LARGE_META) == len(MOBILENET_V3_LARGE)

MOBILENET_V3_SMALL_META: List[Tuple[int, int, bool, str]] = [
    (16, 16, True, "relu"),
    (16, 24, False, "relu"),
    (24, 24, False, "relu"),
    (24, 40, True, "hard_swish"),
    (40, 40, True, "hard_swish"),
    (40, 40, True, "hard_swish"),
    (40, 48, True, "hard_swish"),
    (48, 48, True, "hard_swish"),
    (48, 96, True, "hard_swish"),
    (96, 96, True, "hard_swish"),
    (96, 96, True, "hard_swish"),
]
assert len(MOBILENET_V3_SMALL_META) == len(MOBILENET_V3_SMALL)


def mobilenet_v3_chain_rows(variant: str = "large", se_ratio: float = 0.25
                            ) -> tuple:
    """Family-generic ``core.autotune.BlockRow`` chain of MobileNet-V3
    for the network-level layout solver — the analogue of
    ``models.mbconv.effnet_chain_rows`` built from the canonical workload
    tables: each row carries its DW stage (expanded width, hw, k, s)
    plus the per-row projection width, SE flag and act from the META
    tables above."""
    dw_rows, meta = {
        "large": (MOBILENET_V3_LARGE, MOBILENET_V3_LARGE_META),
        "small": (MOBILENET_V3_SMALL, MOBILENET_V3_SMALL_META),
    }[variant]
    from .autotune import BlockRow
    return tuple(
        BlockRow(dw.h, dw.w, c_in, dw.c, c_out, dw.k, dw.s,
                 family="mbconv", act=act,
                 se_ratio=se_ratio if se else 0.0)
        for dw, (c_in, c_out, se, act) in zip(dw_rows, meta))


# EfficientNet-V2-S body stages [arXiv:2104.00298, Table 2]:
# (family, expand_ratio, k, s, c_out, repeats) — Fused-MBConv stages 1-3
# (dense expand+DW collapse, no SE), MBConv tail with SE 0.25.  Mirrors
# ``models.mbconv.EFFNET_V2_S_STAGES`` (a test pins the two views
# together; core cannot import models).
EFFICIENTNET_V2_S_STAGES: List[Tuple[str, int, int, int, int, int]] = [
    ("fusedmb", 1, 3, 1, 24, 2),
    ("fusedmb", 4, 3, 2, 48, 4),
    ("fusedmb", 4, 3, 2, 64, 4),
    ("mbconv", 4, 3, 2, 128, 6),
    ("mbconv", 6, 3, 1, 160, 9),
    ("mbconv", 6, 3, 2, 256, 15),
]


def effnet_v2_chain_rows(h: int = 112, w: int = 112,
                         se_ratio: float = 0.25, stem_c: int = 24
                         ) -> tuple:
    """The EfficientNet-V2-S ``BlockRow`` chain (40 blocks) at
    stem-output spatial dims ``h`` x ``w`` — a MIXED-FAMILY chain: the
    fused head's rows carry ``family="fusedmb"`` (always-replicated
    entries, zero pass-2 traffic), the tail ``family="mbconv"`` with SE.
    The expansion-1 fused stage widens c_mid to c_out so the single-pass
    projection stays well-formed (matching the model builder)."""
    from .autotune import BlockRow
    rows, c_in, hh, ww = [], stem_c, h, w
    for family, expand, k, s, c_out, repeats in EFFICIENTNET_V2_S_STAGES:
        for i in range(repeats):
            si = s if i == 0 else 1
            c_mid = max(c_in * expand, c_out) if family == "fusedmb" \
                else c_in * expand
            rows.append(BlockRow(
                hh, ww, c_in, c_mid, c_out, k, si, family=family,
                act="silu",
                se_ratio=0.0 if family == "fusedmb" else se_ratio))
            hh, ww = -(-hh // si), -(-ww // si)
            c_in = c_out
    return tuple(rows)


# EfficientNet-V2-style k=7 stem probes (ROADMAP "stride/kernel
# generality"): the fused-MBConv heads of the V2 family push the DW kernel
# to 7x7 at stem resolutions.  The ConvDK tap loop, the staging engine and
# the HBM traffic model are k-generic; these rows pin k=7 in the workload
# tables so schedule solving, the parity sweeps and the traffic gates
# exercise it alongside the paper's k in {3, 5}.
EFFICIENTNET_V2_K7_STEM: List[DWLayer] = [
    _dw(48, 112, 7, 2),      # stem head, stride-2 downsample
    _dw(96, 56, 7, 1),       # first body stage at 56x56
]

# (DW stage, pointwise C_out) pairs — the full separable block per k=7 row
# (drives the fused separable-block traffic accounting, as the V2 head's
# projection widths).
EFFICIENTNET_V2_K7_SEPARABLE: List[Tuple[DWLayer, int]] = [
    (EFFICIENTNET_V2_K7_STEM[0], 64),
    (EFFICIENTNET_V2_K7_STEM[1], 96),
]


NETWORKS: Dict[str, List[DWLayer]] = {
    "mobilenet_v1": MOBILENET_V1,
    "mobilenet_v2": MOBILENET_V2,
    "mobilenet_v3_large": MOBILENET_V3_LARGE,
    "mobilenet_v3_small": MOBILENET_V3_SMALL,
    "efficientnet_b0": EFFICIENTNET_B0,
}

# Paper-reported bands (Sec. V / VII) used as reproduction gates.
PAPER_BANDS = {
    # Fig. 7(a): WS ConvDK TM utilization per model (percent)
    "utilization": {
        "mobilenet_v1": 86.15,
        "mobilenet_v2": 86.76,
        "mobilenet_v3_large": 84.00,
        "mobilenet_v3_small": 86.97,
        "efficientnet_b0": 85.94,
    },
    # Fig. 7(c): buffer-traffic reduction vs WS baseline, percent (min, max)
    "buffer_traffic_reduction_ws": (77.4, 87.0),
    # Fig. 7(d): total traffic-energy reduction vs baselines, percent
    "energy_reduction_ws": (10.1, 17.9),
    "energy_reduction_is": (12.8, 20.3),
    # buffer-only energy reductions quoted in Sec. V-C
    "buffer_energy_reduction_ws": (78.4, 87.2),
    "buffer_energy_reduction_is": (81.2, 88.3),
    # Fig. 7(e): total latency reduction, percent
    "latency_reduction_ws": (15.6, 27.8),
    "latency_reduction_is": (18.1, 29.3),
    # Fig. 8: buffer-traffic *latency* reduction, percent
    "buffer_latency_reduction_ws": (50.5, 58.7),
    "buffer_latency_reduction_is": (47.1, 55.9),
    # Fig. 8: baseline buffer-latency share of total latency, percent
    "baseline_buffer_latency_share": (13.1, 16.8),
}

"""CIM macro model and the BIG/LITTLE scheduler (Sec. III-B of the paper).

The macro: 64 tiles, each with a 180-word (8-bit) Tile Memory (TM, stationary
operand) and a 180-word Tile Register File (TRF, streaming operand).  The
paper's worked numbers all use the 180-word capacity (``T_w = floor(180/k_h)``
= 60 for k_h = 3; ``N_ch = 2`` for a 24-wide 128-channel ifmap), so that is
the capacity this model uses.  (Table I lists 11.25 KiB per TM/TRF — the
physical SRAM array including bit-serial planes; the *dataflow-visible*
capacity is 180 words, per Secs. II-III.)

``plan_layer`` turns one DWConv layer into a static execution plan:

* BIG scheduler (W > T_w): each tile hosts one channel's ``k_h x strip``
  sub-ifmap; the width is tiled into ConvDK strips; kernels are duplicated
  across idle tiles (``floor(N_tile / jobs)`` extra copies) to split rows.
* LITTLE scheduler (W <= T_w): ``N_ch`` channels share one TRF so the TM
  stays full; each tile serves ``N_ch`` channels per compute cycle round.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Literal, Tuple

from .schedule import (
    ConvDKConditionError,
    ConvDKSchedule,
    duplication_number,
    make_schedule,
)


@dataclass(frozen=True)
class MacroConfig:
    """Hardware constants of the CIM macro (paper Secs. II, IV, Table I)."""

    n_tiles: int = 64
    tm_words: int = 180          # stationary words per tile (8-bit each)
    trf_words: int = 180         # streaming words per tile
    ib_bytes: int = 16 * 1024    # input buffer
    ob_bytes: int = 16 * 1024    # output buffer
    wb_bytes: int = 4 * 1024     # weight buffer
    clk_hz: float = 250e6        # 250 MHz
    clks_per_compute: int = 10   # pipelined 8-bit bit-serial MAC (Sec. IV-D)
    # energy constants (Sec. V-C), pJ/bit
    e_dram_pj: float = 20.0
    e_buffer_pj: float = 1.139
    e_tm_write_pj: float = 0.017
    e_trf_write_pj: float = 0.028
    dram_bw_gbps: float = 25.6   # DDR4-3200

    def t_w(self, k_h: int) -> int:
        return self.trf_words // k_h


@dataclass(frozen=True)
class DWLayer:
    """One depthwise-conv layer: C channels, HxW ifmap, k x k kernel, stride s.

    SAME padding throughout (the five models use 'same' convs).
    """

    c: int
    h: int
    w: int
    k: int
    s: int

    @property
    def out_h(self) -> int:
        return -(-self.h // self.s)

    @property
    def out_w(self) -> int:
        return -(-self.w // self.s)

    @property
    def padded_h(self) -> int:
        return (self.out_h - 1) * self.s + self.k

    @property
    def padded_w(self) -> int:
        return (self.out_w - 1) * self.s + self.k

    @property
    def macs(self) -> int:
        return self.c * self.out_h * self.out_w * self.k * self.k

    @property
    def ifmap_words(self) -> int:
        return self.c * self.h * self.w

    @property
    def ofmap_words(self) -> int:
        return self.c * self.out_h * self.out_w

    @property
    def kernel_words(self) -> int:
        return self.c * self.k * self.k


@dataclass(frozen=True)
class StripSpec:
    """One ConvDK strip across the width: schedule + output columns covered."""

    sched: ConvDKSchedule
    out_cols: int  # outputs taken from this strip (<= sched.out_len)


@dataclass(frozen=True)
class LayerPlan:
    """Static BIG/LITTLE execution plan for one layer on the macro."""

    layer: DWLayer
    mode: Literal["BIG", "LITTLE"]
    n_ch: int                   # channels per tile (1 for BIG)
    strips: Tuple[StripSpec, ...]
    tile_dup: int               # kernel copies across idle tiles (>= 1)
    jobs: int                   # (channel x strip) jobs before duplication
    rounds: int                 # sequential tile-assignment rounds
    tm_rows_used: int           # stationary rows occupied per tile
    tiles_active: int           # tiles busy in the steady state

    @property
    def tm_utilization(self) -> float:
        return self.tm_rows_used / 180.0

    @property
    def strip_out_total(self) -> int:
        return sum(sp.out_cols for sp in self.strips)


def _plan_strips(k: int, s: int, out_w: int, n_cap: int) -> Tuple[StripSpec, ...]:
    """Tile the output width into ConvDK strips of at most ``n_cap`` blocks.

    The last strip is sized to the remaining outputs (smaller N), mirroring a
    real scheduler that does not fetch a full-width halo for a 2-column tail.
    """
    strips: List[StripSpec] = []
    remaining = out_w
    while remaining > 0:
        sched = make_schedule(k, s, n_cap)
        if sched.out_len >= remaining:
            # tail strip: smallest N whose out_len covers the remainder
            n_tail = n_cap
            while n_tail > 1:
                cand = make_schedule(k, s, n_tail - 1)
                if cand.out_len >= remaining:
                    n_tail -= 1
                    sched = cand
                else:
                    break
            strips.append(StripSpec(sched=sched, out_cols=remaining))
            remaining = 0
        else:
            strips.append(StripSpec(sched=sched, out_cols=sched.out_len))
            remaining -= sched.out_len
    return tuple(strips)


def plan_layer(layer: DWLayer, macro: MacroConfig = MacroConfig()) -> LayerPlan:
    """BIG/LITTLE scheduling decision + static plan for one DWConv layer.

    Both regimes share the strip machinery; they differ in channel packing:

    * BIG  (padded W > T_w): strips fill the TRF, one channel per tile
      (``n_ch = 1``); kernels are duplicated over idle tiles.
    * LITTLE (padded W <= T_w): the strip is the (padded) full width and
      ``n_ch = floor(TRF / (k_h * ia_len))`` channels are concatenated in one
      TRF so the TM stays full (Fig. 4(c)-(d); Fig. 5's N_ch = 2 example).
    """
    k, s = layer.k, layer.s
    t_w = macro.t_w(k)
    w_pad = layer.padded_w

    n_cap = duplication_number(k, s, w_pad, t_w)
    if n_cap < 1:
        raise ConvDKConditionError(f"TRF too small for {layer}")
    strips = _plan_strips(k, s, layer.out_w, n_cap)
    mode: Literal["BIG", "LITTLE"] = "BIG" if w_pad > t_w else "LITTLE"

    ia_main = strips[0].sched.ia_len
    n_ch = max(1, macro.trf_words // (k * ia_main)) if mode == "LITTLE" else 1

    jobs = math.ceil(layer.c / n_ch) * len(strips)
    tile_dup = max(1, macro.n_tiles // jobs)
    rounds = math.ceil(jobs / macro.n_tiles)
    if mode == "LITTLE":
        # Fig. 4(c): channel strips are CONCATENATED in the TRF; leftover
        # columns host a partial next-channel segment at block granularity
        # (a channel may split across tiles, as BIG strips already do).
        l = strips[0].sched.l
        leftover = t_w - n_ch * ia_main
        bonus_blocks = max(0, (leftover - (l - 1)) // k)
        tm_rows = min(
            macro.tm_words,
            (n_ch * strips[0].sched.N + bonus_blocks) * k * k,
        )
    else:
        tm_rows = min(macro.tm_words, strips[0].sched.N * k * k)
    active = min(jobs * tile_dup, macro.n_tiles)
    return LayerPlan(
        layer=layer, mode=mode, n_ch=n_ch, strips=strips,
        tile_dup=tile_dup, jobs=jobs, rounds=rounds,
        tm_rows_used=tm_rows, tiles_active=active,
    )


def baseline_ws_utilization(layer: DWLayer) -> float:
    """Conventional WS: one vectorized k x k kernel per tile column."""
    return (layer.k * layer.k) / 180.0


def baseline_is_utilization(layer: DWLayer, macro: MacroConfig = MacroConfig()) -> float:
    """IS (Morphable-CIM-like): a k_h x W sub-ifmap is stationary in the TM;
    utilization is capped by the ifmap strip size (Sec. V-A: 'constrained by
    the ifmap size')."""
    return min(layer.k * layer.padded_w, macro.tm_words) / macro.tm_words

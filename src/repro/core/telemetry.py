"""Structured telemetry for the ConvDK stack: counters, spans, and the one
canonical ``measure()`` timing harness.

Every schedule decision in this repo is solved from modeled byte counts;
this module is where the *measured* side of the story lives, plus the
counters that let a run explain what it actually did:

* **Counters** — monotonically increasing named totals (bytes modeled, DMA
  issues, collective words, schedule-cache hits/misses/migrations, solver
  decisions).  Incrementing is a dict update behind a lock: cheap enough
  to leave permanently on.
* **Spans** — named wall-time aggregates (count / total / min / max) via
  the ``span(name)`` context manager.
* **Series** — bounded sample recorders (``record(name, value)``) for
  distributions the aggregates cannot answer: request latencies, queue
  depths.  A series keeps the most recent ``SERIES_CAP`` samples and
  summarizes as count / last / max / nearest-rank percentiles
  (``percentiles()``) — the serving layer's p50/p90/p99 live here.
* **``measure()``** — THE timing loop for real kernel executions: warmup
  calls (compile) followed by timed iterations, each blocked to
  completion with ``jax.block_until_ready`` (which walks pytrees, so
  tuple-returning benchmarks no longer need — and no longer get — the
  call-it-twice probe the old ad-hoc loops used).  ``benchmarks/run.py``,
  ``benchmarks/kernel_bench.py`` and ``core.autotune``'s measured sweeps
  all route through it.

**Jit semantics** (pinned by ``tests/test_telemetry.py``): counters are
host-side Python state, so an increment placed inside a jitted function
fires at TRACE time — once per compilation, not once per call.  That is
the honest semantic for the hooks this repo installs (staging plans,
sharded dispatches, schedule solves are all trace-time work); anything
that must tick per execution belongs in the caller, around the call.

The global registry is process-wide.  ``snapshot()`` returns plain dicts
(JSON-ready, the form ``BENCH_<host>.json`` artifacts embed);
``reset()`` zeroes it (tests).
"""

from __future__ import annotations

import math
import os
import platform
import re
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence, Tuple

__all__ = [
    "Measurement",
    "SERIES_CAP",
    "SpanStat",
    "Telemetry",
    "counter",
    "get_telemetry",
    "host_fingerprint",
    "host_slug",
    "measure",
    "percentiles",
    "record",
    "reset",
    "series",
    "snapshot",
    "span",
]

# samples kept per series (most recent win): enough for stable p99 at
# serving smoke scale without unbounded growth on a long-lived engine
SERIES_CAP = 4096


def percentiles(values: Sequence[float],
                qs: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
    """Nearest-rank percentiles of ``values`` as ``{"p50": ...}``.

    Nearest-rank (ceil(q/100 * n)-th order statistic) rather than
    interpolation: every reported number is a latency that actually
    happened, which is the honest form for small serving samples.
    Empty input -> empty dict."""
    if not values:
        return {}
    ordered = sorted(values)
    n = len(ordered)
    out = {}
    for q in qs:
        rank = min(n, max(1, math.ceil(q / 100.0 * n)))
        out[f"p{q:g}"] = ordered[rank - 1]
    return out


@dataclass
class SpanStat:
    """Aggregate wall-time of one named span."""

    count: int = 0
    total_s: float = 0.0
    min_s: float = float("inf")
    max_s: float = 0.0

    def add(self, seconds: float) -> None:
        self.count += 1
        self.total_s += seconds
        self.min_s = min(self.min_s, seconds)
        self.max_s = max(self.max_s, seconds)

    def as_dict(self) -> Dict[str, float]:
        return {"count": self.count, "total_s": self.total_s,
                "min_s": self.min_s if self.count else 0.0,
                "max_s": self.max_s}


class Telemetry:
    """A counter + span registry.  One process-wide instance lives in this
    module; tests may construct private ones."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._counters: Dict[str, float] = {}
        self._spans: Dict[str, SpanStat] = {}
        self._series: Dict[str, deque] = {}

    # -- counters ------------------------------------------------------------

    def count(self, name: str, value: float = 1) -> None:
        """Add ``value`` to counter ``name`` (created at 0)."""
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def get(self, name: str, default: float = 0) -> float:
        with self._lock:
            return self._counters.get(name, default)

    # -- spans ---------------------------------------------------------------

    @contextmanager
    def span(self, name: str):
        """Time a ``with`` block into the span aggregate ``name``."""
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            dt = time.perf_counter() - t0
            with self._lock:
                self._spans.setdefault(name, SpanStat()).add(dt)

    def span_stat(self, name: str) -> Optional[SpanStat]:
        with self._lock:
            return self._spans.get(name)

    # -- series --------------------------------------------------------------

    def record(self, name: str, value: float) -> None:
        """Append one sample to series ``name`` (bounded to SERIES_CAP)."""
        with self._lock:
            self._series.setdefault(
                name, deque(maxlen=SERIES_CAP)).append(float(value))

    def series(self, name: str) -> Tuple[float, ...]:
        """The retained samples of series ``name`` (oldest first)."""
        with self._lock:
            return tuple(self._series.get(name, ()))

    # -- registry ------------------------------------------------------------

    def snapshot(self) -> Dict[str, dict]:
        """JSON-ready view:
        ``{"counters": {...}, "spans": {...}, "series": {...}}`` — series
        summarize to count/last/max plus nearest-rank p50/p90/p99."""
        with self._lock:
            series = {}
            for k in sorted(self._series):
                vals = self._series[k]
                series[k] = {"count": len(vals), "last": vals[-1],
                             "max": max(vals), **percentiles(vals)}
            return {
                "counters": dict(sorted(self._counters.items())),
                "spans": {k: v.as_dict()
                          for k, v in sorted(self._spans.items())},
                "series": series,
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._spans.clear()
            self._series.clear()


_GLOBAL = Telemetry()


def get_telemetry() -> Telemetry:
    return _GLOBAL


def counter(name: str, value: float = 1) -> None:
    """Increment a global counter (module-level sugar)."""
    _GLOBAL.count(name, value)


def span(name: str):
    """Global span context manager (module-level sugar)."""
    return _GLOBAL.span(name)


def record(name: str, value: float) -> None:
    """Append one sample to a global series (module-level sugar)."""
    _GLOBAL.record(name, value)


def series(name: str) -> Tuple[float, ...]:
    """Retained samples of a global series (module-level sugar)."""
    return _GLOBAL.series(name)


def snapshot() -> Dict[str, dict]:
    return _GLOBAL.snapshot()


def reset() -> None:
    _GLOBAL.reset()


# ---------------------------------------------------------------------------
# the canonical timing harness
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Measurement:
    """Result of one ``measure()`` run: the timed iterations, in order."""

    name: Optional[str]
    times_s: Tuple[float, ...]

    @property
    def iters(self) -> int:
        return len(self.times_s)

    @property
    def best_s(self) -> float:
        """Fastest iteration — the least-noise estimate of the kernel."""
        return min(self.times_s)

    @property
    def mean_s(self) -> float:
        return sum(self.times_s) / len(self.times_s)

    @property
    def best_us(self) -> float:
        return self.best_s * 1e6

    @property
    def mean_us(self) -> float:
        return self.mean_s * 1e6


def measure(fn: Callable, *args, iters: int = 5, warmup: int = 1,
            name: Optional[str] = None, **kwargs) -> Measurement:
    """Time ``fn(*args, **kwargs)``: ``warmup`` untimed calls (compile /
    cache fill), then ``iters`` timed calls, each blocked to completion.

    ``jax.block_until_ready`` walks arbitrary pytrees (tuples included)
    and passes non-arrays through, so this one loop serves jax kernels,
    tuple-returning sweeps and plain-Python table builders alike — no
    per-call-site probing of the return type, and never an extra
    evaluation to decide how to block (the bug the old ad-hoc loops had).

    With ``name`` the total wall time (warmup included) is also recorded
    as the global span ``measure.<name>``.
    """
    if iters < 1:
        raise ValueError(f"measure() needs iters >= 1, got {iters}")
    import jax

    ctx = _GLOBAL.span(f"measure.{name}") if name else None
    try:
        if ctx is not None:
            ctx.__enter__()
        for _ in range(max(0, warmup)):
            jax.block_until_ready(fn(*args, **kwargs))
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args, **kwargs))
            times.append(time.perf_counter() - t0)
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)
    return Measurement(name=name, times_s=tuple(times))


# ---------------------------------------------------------------------------
# host identity (BENCH_<host>.json artifacts)
# ---------------------------------------------------------------------------


def host_fingerprint() -> Dict[str, object]:
    """Where a measurement ran: the fields two BENCH artifacts must share
    for their wall times to be comparable (the trajectory differ enforces
    byte/axis fields regardless — those are host-independent)."""
    try:
        import jax
        jax_version = jax.__version__
        backend = jax.default_backend()
    except Exception:  # pragma: no cover - jax is always importable here
        jax_version, backend = "unknown", "unknown"
    return {
        "node": platform.node() or "unknown",
        "system": platform.system(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "jax": jax_version,
        "backend": backend,
        "cpu_count": os.cpu_count() or 0,
    }


def host_slug(fingerprint: Optional[Dict[str, object]] = None) -> str:
    """Filesystem-safe host tag for ``BENCH_<host>.json`` filenames."""
    fp = fingerprint or host_fingerprint()
    raw = f"{fp.get('node', 'unknown')}-{fp.get('backend', 'unknown')}"
    slug = re.sub(r"[^A-Za-z0-9_.-]+", "-", str(raw))
    slug = re.sub(r"-{2,}", "-", slug).strip("-")
    return slug or "unknown"

"""Executable ConvDK (convolution with duplicated kernels) in pure JAX.

This module *numerically executes* the paper's Algorithms 1-2 with the exact
data movement of the CIM macro:

* the kernel is duplicated ``N`` times along the stationary dimension (the
  role of the Tile Memory, TM);
* one IA strip is loaded once (the role of the Tile Register File, TRF) and
  re-read at ``l = lcm(k,s)/s`` static shift offsets ``a``;
* each shift cycle performs all block dot-products in parallel (the parallel
  bitlines of the TM) and the multiplication-enable mask ``e_n`` selects the
  blocks whose results are valid outputs for that shift (Theorem 1).

The functions here are the *reference semantics* for the Pallas TPU kernels in
``repro.kernels`` and are themselves validated against
``jax.lax.conv_general_dilated`` oracles in the test-suite.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .schedule import ConvDKSchedule, make_schedule, duplication_number


# ---------------------------------------------------------------------------
# 1-D ConvDK (Algorithm 1)
# ---------------------------------------------------------------------------

def convdk_1d(kernel: jax.Array, ia: jax.Array, sched: ConvDKSchedule) -> jax.Array:
    """1-D ConvDK (Algorithm 1): ``z = kernel * ia`` with stride ``sched.s``.

    Parameters
    ----------
    kernel : (k,) weights.
    ia     : (sched.ia_len,) input-activation strip.
    sched  : static schedule from ``make_schedule``.

    Returns (sched.out_len,) strided convolution output.
    """
    k, N = sched.k, sched.N
    if kernel.shape != (k,):
        raise ValueError(f"kernel shape {kernel.shape} != ({k},)")
    if ia.shape[-1] != sched.ia_len:
        raise ValueError(f"ia length {ia.shape[-1]} != {sched.ia_len}")

    z = jnp.zeros((sched.out_len,), dtype=jnp.result_type(kernel, ia))
    for cyc in sched.cycles:  # static loop over l shift cycles
        # Parallel block dot-products for shift a: the TM computes ALL N block
        # results at once; block n sees IA window [a + n*k, a + n*k + k).
        windows = jax.lax.dynamic_slice_in_dim(ia, cyc.a, N * k).reshape(N, k)
        y = windows @ kernel  # (N,) — one inner product per bitline group
        if cyc.ns:
            # e_n mask: only blocks in cyc.ns are enabled; their results land
            # at output indices cyc.ms (disjoint across cycles by Theorem 2).
            z = z.at[np.asarray(cyc.ms)].set(y[np.asarray(cyc.ns)])
    return z


# ---------------------------------------------------------------------------
# 2-D strip ConvDK (Eq. 7 — one (channel, output-row) strip in one tile)
# ---------------------------------------------------------------------------

def convdk_2d_strip(
    kernel: jax.Array, ia_strip: jax.Array, sched: ConvDKSchedule
) -> jax.Array:
    """DWConv of one ``k_h x ia_len`` IA strip with a ``k_h x k_w`` kernel.

    Implements Eq. (7) for a fixed channel c and output row h:
        y_{n,a} = sum_j sum_i  K[j, i] * I[j, i + n*k_w + a]
    All blocks n are evaluated in parallel per shift a (single TM read),
    masked by e_n, scattered to output columns m.

    ia_strip : (k_h, sched.ia_len)
    kernel   : (k_h, k_w)
    returns  : (sched.out_len,)
    """
    k, N = sched.k, sched.N
    k_h = kernel.shape[0]
    if kernel.shape != (k_h, k):
        raise ValueError(f"kernel shape {kernel.shape} != ({k_h}, {k})")
    if ia_strip.shape != (k_h, sched.ia_len):
        raise ValueError(f"ia_strip shape {ia_strip.shape} != ({k_h}, {sched.ia_len})")

    z = jnp.zeros((sched.out_len,), dtype=jnp.result_type(kernel, ia_strip))
    for cyc in sched.cycles:
        windows = jax.lax.dynamic_slice_in_dim(
            ia_strip, cyc.a, N * k, axis=1
        ).reshape(k_h, N, k)
        y = jnp.einsum("jni,ji->n", windows, kernel)
        if cyc.ns:
            z = z.at[np.asarray(cyc.ms)].set(y[np.asarray(cyc.ns)])
    return z


# ---------------------------------------------------------------------------
# Full depthwise Conv2D via ConvDK strips (Algorithm 2 orchestration)
# ---------------------------------------------------------------------------

def _strip_starts(out_w: int, per_strip: int, s: int):
    """Static width-tiling: each strip produces ``per_strip`` output columns;
    consecutive strips overlap by the kernel halo.  Returns (out_start, in_start)
    pairs; the final strip is right-aligned so no partial strip is needed."""
    starts = []
    o = 0
    while o < out_w:
        o_eff = max(0, min(o, out_w - per_strip))  # right-align last strip
        starts.append((o_eff, o_eff * s))
        if o_eff + per_strip >= out_w:
            break
        o = o_eff + per_strip
    return starts


def dwconv2d_convdk(
    x: jax.Array,
    kernels: jax.Array,
    stride: int = 1,
    padding: str | int = "SAME",
    t_w: Optional[int] = None,
    trf_len: int = 180,
) -> jax.Array:
    """Depthwise Conv2D computed with the ConvDK dataflow (Algorithm 2).

    The orchestration mirrors the macro: for every (channel, output row), a
    ``k_h x strip`` IA slice is "loaded into the TRF" and consumed through the
    ConvDK shift schedule.  Width larger than the TRF capacity is tiled into
    overlapping strips (the BIG scheduler's partitioning).

    Parameters
    ----------
    x        : (C, H, W) single-image ifmap (use vmap for batches).
    kernels  : (C, k_h, k_w) one kernel per channel.
    stride   : s (same for both dims, as in the paper's models).
    padding  : "SAME", "VALID" or explicit symmetric int pad.
    t_w      : TRF strip-width cap; default ``trf_len // k_h`` (paper's T_w).
    """
    C, H, W = x.shape
    Ck, k_h, k_w = kernels.shape
    if Ck != C:
        raise ValueError(f"channel mismatch {Ck} != {C}")
    s = stride

    if padding == "SAME":
        out_h = -(-H // s)
        out_w = -(-W // s)
        pad_h = max(0, (out_h - 1) * s + k_h - H)
        pad_w = max(0, (out_w - 1) * s + k_w - W)
        pads = ((pad_h // 2, pad_h - pad_h // 2), (pad_w // 2, pad_w - pad_w // 2))
    elif padding == "VALID":
        out_h = (H - k_h) // s + 1
        out_w = (W - k_w) // s + 1
        pads = ((0, 0), (0, 0))
    else:
        p = int(padding)
        out_h = (H + 2 * p - k_h) // s + 1
        out_w = (W + 2 * p - k_w) // s + 1
        pads = ((p, p), (p, p))
    xp = jnp.pad(x, ((0, 0),) + pads)
    Wp = xp.shape[2]

    if t_w is None:
        t_w = trf_len // k_h
    N = duplication_number(k_w, s, Wp, t_w)
    if N < 1:
        raise ValueError(f"strip too narrow: W={Wp}, t_w={t_w}, k_w={k_w}, s={s}")
    sched = make_schedule(k_w, s, N)

    starts = _strip_starts(out_w, sched.out_len, s)

    def one_channel_row(xc: jax.Array, kc: jax.Array, h: int) -> jax.Array:
        rows = jax.lax.dynamic_slice_in_dim(xc, h * s, k_h, axis=0)  # (k_h, Wp)
        outs = []
        for (o0, i0) in starts:
            strip = jax.lax.dynamic_slice_in_dim(rows, i0, sched.ia_len, axis=1)
            outs.append((o0, convdk_2d_strip(kc, strip, sched)))
        row = jnp.zeros((out_w,), dtype=x.dtype)
        for o0, z in outs:
            take = min(sched.out_len, out_w - o0)
            row = jax.lax.dynamic_update_slice_in_dim(row, z[:take], o0, axis=0)
        return row

    # The strip may read past the padded width on the final (right-aligned)
    # tile when ia_len > Wp - i0; pad once on the right to cover it.
    max_i0 = max(i0 for _, i0 in starts)
    need = max_i0 + sched.ia_len
    if need > Wp:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, need - Wp)))

    rows_h = jnp.arange(out_h)
    per_channel = jax.vmap(
        lambda xc, kc: jax.vmap(lambda h: one_channel_row(xc, kc, h))(rows_h)
    )
    return per_channel(xp, kernels)  # (C, out_h, out_w)


# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------

def dwconv2d_oracle(
    x: jax.Array, kernels: jax.Array, stride: int = 1, padding: str | int = "SAME"
) -> jax.Array:
    """Reference depthwise Conv2D via lax.conv_general_dilated (CHW single image)."""
    C = x.shape[0]
    lhs = x[None]  # (1, C, H, W)
    rhs = kernels[:, None]  # (C, 1, k_h, k_w)  OIHW with groups=C
    if padding == "SAME":
        pad = "SAME"
    elif padding == "VALID":
        pad = "VALID"
    else:
        p = int(padding)
        pad = ((p, p), (p, p))
    out = jax.lax.conv_general_dilated(
        lhs, rhs,
        window_strides=(stride, stride),
        padding=pad,
        feature_group_count=C,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]

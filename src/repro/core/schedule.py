"""ConvDK scheduling — Theorems 1-2 and Algorithm 1 of the paper as executable
number theory.

The paper ("Computing-In-Memory Dataflow for Minimal Buffer Traffic", Song &
Jeong, 2025) proves that a 1-D convolution ``z = k * I`` with kernel width
``k_w`` (odd) and stride ``s < k_w`` can be computed from an ``N``-times
duplicated kernel and a *single* loaded IA strip that is shifted only
``l - 1 = lcm(k_w, s)/s - 1`` times:

    every output index ``m`` satisfies   m*s = n*k_w + a          (Eq. 6)

for exactly one pair ``(a, n)`` with shift ``a in [0, l)`` and kernel-block
index ``n in [0, N)``.  Theorem 1 gives the arithmetic progression of valid
``(m, n)`` for each ``a``; Theorem 2 proves the progressions for different
``a`` are disjoint and jointly cover all non-negative integers, provided

    Condition 1:  k_w odd, s < k_w
    Condition 2:  exists m1, n1 >= 0 with  m1*s = n1*k_w + 1
    Condition 3:  gcd(m1, l) == 1  where  l = lcm(k_w, s)/s

Everything in this module is plain Python integer arithmetic: the schedule is
*static* (computed at trace time) and consumed by the JAX/Pallas executors in
``convdk.py`` and ``kernels/``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Tuple


class ConvDKConditionError(ValueError):
    """Raised when (k, s) violate Conditions 1-3 and ConvDK does not apply."""


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def shift_count(k: int, s: int) -> int:
    """``l = lcm(k, s)/s`` — number of IA shift positions (a = 0 .. l-1)."""
    return _lcm(k, s) // s


def block_period(k: int, s: int) -> int:
    """``p = lcm(k, s)/k`` — period of the active-block index n within a cycle."""
    return _lcm(k, s) // k


def check_conditions(k: int, s: int) -> None:
    """Validate Conditions 1-3 from Sec. II-C.  Raises ConvDKConditionError."""
    if k < 1 or s < 1:
        raise ConvDKConditionError(f"k={k}, s={s} must be positive")
    if k % 2 == 0:
        raise ConvDKConditionError(f"Condition 1 violated: k={k} must be odd")
    if not s < k:
        raise ConvDKConditionError(f"Condition 1 violated: s={s} must be < k={k}")
    if math.gcd(k, s) != 1:
        # m1*s = n1*k + 1 has a solution iff gcd(s, k) | 1.
        raise ConvDKConditionError(
            f"Condition 2 violated: m1*s = n1*k + 1 unsolvable for k={k}, s={s} "
            f"(gcd={math.gcd(k, s)})"
        )
    m1, _ = solve_m1_n1(k, s)
    l = shift_count(k, s)
    if math.gcd(m1, l) != 1:
        raise ConvDKConditionError(
            f"Condition 3 violated: gcd(m1={m1}, l={l}) != 1 for k={k}, s={s}"
        )


def solve_m1_n1(k: int, s: int) -> Tuple[int, int]:
    """Least non-negative (m1, n1) with ``m1*s = n1*k + 1`` (Condition 2).

    ``m1`` is the modular inverse of ``s`` mod ``k`` (least positive residue);
    ``n1`` follows.  Requires gcd(k, s) == 1.
    """
    if math.gcd(k, s) != 1:
        raise ConvDKConditionError(f"no m1, n1 exist for k={k}, s={s}")
    m1 = pow(s, -1, k)  # in [0, k); == least non-negative solution
    n1 = (m1 * s - 1) // k
    return m1, n1


def duplication_number(k_w: int, s: int, width: int, t_w: int) -> int:
    """Eq. (8): ``N = (min(W, T_w) - lcm(k_w, s)/s + 1) // k_w``.

    ``width`` is the ifmap width W, ``t_w`` the widest strip the TRF can hold.
    Returns 0 when the strip is too narrow for even one kernel block.
    """
    l = shift_count(k_w, s)
    return max(0, (min(width, t_w) - l + 1) // k_w)


@dataclass(frozen=True)
class ShiftCycle:
    """One shift cycle ``a``: the active block indices ``n`` (multiplication-
    enable e_n = 1) and the output indices ``m`` they produce, in sub-cycle
    order (Algorithm 1's inner while loop)."""

    a: int
    ns: Tuple[int, ...]
    ms: Tuple[int, ...]


@dataclass(frozen=True)
class ConvDKSchedule:
    """Full static schedule of 1-D ConvDK for (k, s, N).

    Attributes
    ----------
    k, s, N  : kernel width, stride, duplication number.
    l        : number of shift cycles (``lcm(k,s)/s``).
    p        : block-index period per cycle (``lcm(k,s)/k``).
    m1, n1   : base solution of ``m1*s = n1*k + 1``.
    ia_len   : required IA strip length  ``N*k + l - 1``.
    out_len  : produced output length  ``floor(((N-1)k + l - 1)/s) + 1``.
    cycles   : per-shift ``ShiftCycle`` records (Algorithm 1 unrolled).
    """

    k: int
    s: int
    N: int
    l: int
    p: int
    m1: int
    n1: int
    ia_len: int
    out_len: int
    cycles: Tuple[ShiftCycle, ...] = field(repr=False)

    @property
    def total_subcycles(self) -> int:
        """Total MAC sub-cycles = total outputs produced (one per sub-cycle)."""
        return sum(len(c.ns) for c in self.cycles)

    @property
    def tm_rows_used(self) -> int:
        """Stationary rows occupied by the duplicated 1-D kernel (N*k)."""
        return self.N * self.k

    def active(self, a: int) -> ShiftCycle:
        return self.cycles[a]


@lru_cache(maxsize=None)
def make_schedule(k: int, s: int, N: int) -> ConvDKSchedule:
    """Build the static (a, n, m) schedule of Algorithm 1.

    for a = 0 .. l-1:
        n <- a*n1 mod p ;  m <- a*m1 mod l
        while n < N:  emit (a, n, m);  n += p;  m += l
    """
    check_conditions(k, s)
    if N < 1:
        raise ConvDKConditionError(f"duplication number N={N} must be >= 1")
    l = shift_count(k, s)
    p = block_period(k, s)
    m1, n1 = solve_m1_n1(k, s)

    cycles = []
    for a in range(l):
        n = (a * n1) % p
        m = (a * m1) % l
        ns, ms = [], []
        while n < N:
            # Invariant (Eq. 6): the emitted pair satisfies m*s == n*k + a.
            assert m * s == n * k + a, (m, s, n, k, a)
            ns.append(n)
            ms.append(m)
            n += p
            m += l
        cycles.append(ShiftCycle(a=a, ns=tuple(ns), ms=tuple(ms)))

    ia_len = N * k + l - 1
    out_len = ((N - 1) * k + l - 1) // s + 1
    return ConvDKSchedule(
        k=k, s=s, N=N, l=l, p=p, m1=m1, n1=n1,
        ia_len=ia_len, out_len=out_len, cycles=tuple(cycles),
    )


def covered_outputs(sched: ConvDKSchedule) -> Tuple[int, ...]:
    """All output indices m the schedule writes, in emission order."""
    out = []
    for c in sched.cycles:
        out.extend(c.ms)
    return tuple(out)


def is_exact_cover(sched: ConvDKSchedule) -> bool:
    """Theorem 2 check: every m in [0, out_len) is written exactly once."""
    ms = covered_outputs(sched)
    return len(ms) == len(set(ms)) and set(ms) == set(range(sched.out_len))

"""Analytical traffic / energy / latency model for the four dataflows
(Sec. V of the paper): WS baseline, IS baseline, WS ConvDK, IS ConvDK.

Accounting rules (each rule cites the paper sentence it encodes):

* **Traffic words** — 8-bit words crossing a buffer port.
  - IB side: ifmap words into the tile array (TRF for WS, TM for IS).
  - WB side: weight words into the tile array (TM for WS, TRF for IS).
  - OB side: ofmap words out of the accumulators.
* **Latency clocks** (Sec. IV-D):
  - TRF strip write = 1 clk per load event, tiles in parallel ("All TRFs are
    loaded ... at a single write cycle").
  - TM writes are word-by-word, 1 clk/word per tile; kernel duplication costs
    one extra clk per duplicated word ("9 cycles for the original weights and
    one additional cycle per duplicated weight" -> 2*k^2 for a duplicated 3x3).
  - OB write = 1 clk per 64-wide output round.
  - Compute = 10 clks per compute cycle (pipelined bit-serial 8-bit MAC);
    each compute cycle retires one output element per active tile.
  - DRAM traffic is pipelined behind compute (checked, flagged if it is not).
* **Energy** (Sec. V-C): DRAM 20 pJ/bit; IB/WB/OB SRAM access 1.139 pJ/bit;
  TM write 0.017 pJ/bit; TRF write 0.028 pJ/bit.  Physical TM/TRF bits
  written include duplicated copies; buffer-port energy counts unique words.

Interpretation choices (under-specified in the paper, fixed here and
documented in DESIGN.md):

1. WS-baseline TRF loads carry the k_h*k_w patch per output element with no
   inter-output reuse (the under-utilization the paper criticizes).
2. ConvDK strips exploit *vertical halo reuse*: consecutive output rows of
   the same (channel, strip) job share k_h - s input rows already resident
   in the register file, so only s*ia_len fresh words are fetched per new
   row.  This is the "maximizing data reuse" that yields the paper's
   77-87 % buffer-traffic reduction; without it the ceiling is 1 - s/k.
3. Tiles run asynchronously: total compute clocks = total sub-cycles /
   64-way parallelism, with kernel duplication across idle tiles providing
   the parallel slack (Sec. III-B "duplicated over idle tiles").
4. The headline "buffer traffic" metric (Fig. 7(c)) counts the IB- and
   WB-side streams; OB words are identical across dataflows and are
   reported separately (they enter energy and latency regardless).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from .tiling import (
    DWLayer,
    MacroConfig,
    baseline_is_utilization,
    baseline_ws_utilization,
    plan_layer,
)

Dataflow = str  # "ws_base" | "is_base" | "ws_convdk" | "is_convdk"
DATAFLOWS: Tuple[Dataflow, ...] = ("ws_base", "is_base", "ws_convdk", "is_convdk")


@dataclass
class LayerCost:
    """All accounting for one layer under one dataflow."""

    layer: DWLayer
    dataflow: Dataflow
    # traffic words (8-bit) per buffer port
    ib_words: int = 0
    wb_words: int = 0
    ob_words: int = 0
    # physical bits written into tile storage (includes duplicate copies)
    tm_write_words: int = 0
    trf_write_words: int = 0
    # DRAM words (same for all dataflows; loop-nest and buffers fixed)
    dram_words: int = 0
    # latency, clocks
    ib_clks: int = 0
    wb_clks: int = 0
    ob_clks: int = 0
    compute_cycles: int = 0   # x10 clks each
    # utilization of the stationary memory (TM), 0..1
    tm_utilization: float = 0.0

    @property
    def buffer_words(self) -> int:
        """Fig. 7(c) metric: input-side buffer streams (see module note 4)."""
        return self.ib_words + self.wb_words

    @property
    def buffer_words_all(self) -> int:
        return self.ib_words + self.wb_words + self.ob_words

    @property
    def buffer_clks(self) -> int:
        return self.ib_clks + self.wb_clks + self.ob_clks

    @property
    def compute_clks(self) -> int:
        return self.compute_cycles * 10

    @property
    def total_clks(self) -> int:
        return self.buffer_clks + self.compute_clks

    def energy_pj(self, m: MacroConfig) -> Dict[str, float]:
        dram = self.dram_words * 8 * m.e_dram_pj
        buf = (self.ib_words + self.wb_words + self.ob_words) * 8 * m.e_buffer_pj
        tm = self.tm_write_words * 8 * m.e_tm_write_pj
        trf = self.trf_write_words * 8 * m.e_trf_write_pj
        return {"dram": dram, "buffer": buf, "tm": tm, "trf": trf,
                "total": dram + buf + tm + trf}

    def latency_ns(self, m: MacroConfig) -> float:
        return self.total_clks / m.clk_hz * 1e9

    def dram_pipelined_ok(self, m: MacroConfig) -> bool:
        """Sec. IV-D: DRAM transfer must hide behind compute."""
        dram_ns = self.dram_words / (m.dram_bw_gbps * 1e9) * 1e9
        return dram_ns <= self.compute_clks / m.clk_hz * 1e9


def _dram_words(layer: DWLayer) -> int:
    return layer.ifmap_words + layer.kernel_words + layer.ofmap_words


def _p64(x: int, m: MacroConfig) -> int:
    """Ceil-divide by the tile count (64-way spatial parallelism)."""
    return math.ceil(x / m.n_tiles)


# ---------------------------------------------------------------------------
# WS baseline — conventional weight-stationary CIM dataflow
# ---------------------------------------------------------------------------

def cost_ws_base(layer: DWLayer, m: MacroConfig = MacroConfig()) -> LayerCost:
    k2 = layer.k * layer.k
    outs = layer.out_h * layer.out_w
    ch_rounds = math.ceil(layer.c / m.n_tiles)

    ib_words = layer.c * outs * k2          # k^2 patch per output, no reuse
    wb_words = layer.c * k2                 # weights written once, stationary
    ob_words = layer.ofmap_words

    return LayerCost(
        layer=layer, dataflow="ws_base",
        ib_words=ib_words, wb_words=wb_words, ob_words=ob_words,
        tm_write_words=wb_words, trf_write_words=ib_words,
        dram_words=_dram_words(layer),
        ib_clks=ch_rounds * outs,           # 1-clk parallel TRF strip writes
        wb_clks=ch_rounds * k2,             # word-by-word TM writes
        ob_clks=_p64(layer.ofmap_words, m),
        compute_cycles=ch_rounds * outs,    # 1 output / tile / compute cycle
        tm_utilization=baseline_ws_utilization(layer),
    )


# ---------------------------------------------------------------------------
# IS baseline — input-stationary (Morphable-CIM-like)
# ---------------------------------------------------------------------------

def cost_is_base(layer: DWLayer, m: MacroConfig = MacroConfig()) -> LayerCost:
    k, s = layer.k, layer.s
    k2 = k * k
    outs = layer.out_h * layer.out_w
    ch_rounds = math.ceil(layer.c / m.n_tiles)

    # IS baseline (Morphable-CIM-like): the IA row strip is stationary in the
    # TM, re-written word-by-word per output row with no halo reuse (Sec. V-B
    # / VI: "the TMs are frequently re-written word-by-word"); the WEIGHTS
    # stream through the TRF per output element — Fig. 7(d): "in the IS
    # baseline, the weight movement is dominant".
    ib_words = layer.c * layer.out_h * k * layer.padded_w
    wb_words = layer.c * outs * k2          # weight patch per output
    ob_words = layer.ofmap_words

    return LayerCost(
        layer=layer, dataflow="is_base",
        ib_words=ib_words, wb_words=wb_words, ob_words=ob_words,
        tm_write_words=ib_words, trf_write_words=wb_words,
        dram_words=_dram_words(layer),
        ib_clks=_p64(ib_words, m),          # word-by-word TM writes
        wb_clks=ch_rounds * outs,           # 1-clk TRF weight events
        ob_clks=_p64(layer.ofmap_words, m),
        compute_cycles=ch_rounds * outs,
        tm_utilization=baseline_is_utilization(layer, m),
    )


# ---------------------------------------------------------------------------
# ConvDK dataflows (WS and IS variants share the BIG/LITTLE plan)
# ---------------------------------------------------------------------------

def _convdk_common(layer: DWLayer, m: MacroConfig):
    plan = plan_layer(layer, m)
    k, s = layer.k, layer.s
    # fresh ifmap words per (channel, strip) job over all output rows:
    # k_h rows for the first output row, s new rows for each of the rest
    # (vertical halo reuse inside the register file; module note 2).
    row_factor = k + (layer.out_h - 1) * s
    ia_words_per_ch = sum(sp.sched.ia_len for sp in plan.strips) * row_factor
    ifmap_stream_words = layer.c * ia_words_per_ch
    # one output element per sub-cycle; async tile packing (module note 3)
    total_subcycles = layer.c * layer.out_h * sum(
        sp.sched.out_len for sp in plan.strips
    )
    compute_cycles = _p64(total_subcycles, m)
    # strip-load events: one per (tile job, output row)
    load_events = plan.jobs * layer.out_h
    return plan, ifmap_stream_words, compute_cycles, load_events


def cost_ws_convdk(layer: DWLayer, m: MacroConfig = MacroConfig()) -> LayerCost:
    plan, ifmap_words, compute_cycles, load_events = _convdk_common(layer, m)
    k2 = layer.k * layer.k
    dup_blocks = sum(sp.sched.N for sp in plan.strips)

    wb_words = layer.c * k2                 # unique weights read from WB once
    # physical TM bits include the N duplicated copies (multi-access write)
    tm_write_words = layer.c * dup_blocks * k2

    return LayerCost(
        layer=layer, dataflow="ws_convdk",
        ib_words=ifmap_words, wb_words=wb_words, ob_words=layer.ofmap_words,
        tm_write_words=tm_write_words, trf_write_words=ifmap_words,
        dram_words=_dram_words(layer),
        ib_clks=_p64(load_events, m),       # 1-clk parallel TRF strip writes
        # duplicated kernel write: 2*k^2 clks per assignment round (Sec. IV-B)
        wb_clks=plan.rounds * 2 * k2,
        ob_clks=_p64(layer.ofmap_words, m),
        compute_cycles=compute_cycles,
        tm_utilization=plan.tm_utilization,
    )


def cost_is_convdk(layer: DWLayer, m: MacroConfig = MacroConfig()) -> LayerCost:
    plan, ifmap_words, compute_cycles, load_events = _convdk_common(layer, m)
    k2 = layer.k * layer.k
    dup_blocks = sum(sp.sched.N for sp in plan.strips)

    # IS: the IA strip is stationary in the TM (word-by-word writes, with the
    # same vertical halo reuse); the DUPLICATED kernel sits in the TRF and is
    # loaded once per (channel, strip) job, staying resident across all rows.
    wb_words = plan.jobs * k2               # unique kernel words per job
    trf_write_words = plan.jobs * dup_blocks * k2

    return LayerCost(
        layer=layer, dataflow="is_convdk",
        ib_words=ifmap_words, wb_words=wb_words, ob_words=layer.ofmap_words,
        tm_write_words=ifmap_words, trf_write_words=trf_write_words,
        dram_words=_dram_words(layer),
        ib_clks=_p64(ifmap_words, m),       # word-by-word TM writes
        wb_clks=_p64(plan.jobs, m),         # 1-clk TRF weight events
        ob_clks=_p64(layer.ofmap_words, m),
        compute_cycles=compute_cycles,
        tm_utilization=plan.tm_utilization,
    )


COST_FNS: Dict[Dataflow, Callable[..., LayerCost]] = {
    "ws_base": cost_ws_base,
    "is_base": cost_is_base,
    "ws_convdk": cost_ws_convdk,
    "is_convdk": cost_is_convdk,
}


# ---------------------------------------------------------------------------
# Network-level aggregation (Figs. 7-8)
# ---------------------------------------------------------------------------

@dataclass
class NetworkCost:
    name: str
    dataflow: Dataflow
    layers: List[LayerCost] = field(default_factory=list)

    def _sum(self, attr: str) -> int:
        return sum(getattr(c, attr) for c in self.layers)

    @property
    def buffer_words(self) -> int:
        return self._sum("buffer_words")

    @property
    def buffer_words_all(self) -> int:
        return self._sum("buffer_words_all")

    @property
    def dram_words(self) -> int:
        return self._sum("dram_words")

    @property
    def buffer_clks(self) -> int:
        return self._sum("buffer_clks")

    @property
    def compute_clks(self) -> int:
        return self._sum("compute_clks")

    @property
    def total_clks(self) -> int:
        return self._sum("total_clks")

    def energy_pj(self, m: MacroConfig = MacroConfig()) -> Dict[str, float]:
        tot: Dict[str, float] = {"dram": 0.0, "buffer": 0.0, "tm": 0.0,
                                 "trf": 0.0, "total": 0.0}
        for c in self.layers:
            for key, v in c.energy_pj(m).items():
                tot[key] += v
        return tot

    def mean_tm_utilization(self) -> float:
        """Compute-cycle-weighted mean TM utilization (Fig. 7(a))."""
        num = sum(c.tm_utilization * c.compute_cycles for c in self.layers)
        den = sum(c.compute_cycles for c in self.layers)
        return num / den if den else 0.0

    def latency_ms(self, m: MacroConfig = MacroConfig()) -> float:
        return self.total_clks / m.clk_hz * 1e3


def evaluate_network(
    name: str,
    layers: Iterable[DWLayer],
    dataflow: Dataflow,
    macro: MacroConfig = MacroConfig(),
) -> NetworkCost:
    fn = COST_FNS[dataflow]
    net = NetworkCost(name=name, dataflow=dataflow)
    for layer in layers:
        net.layers.append(fn(layer, macro))
    return net


def compare_networks(
    name: str, layers: Iterable[DWLayer], macro: MacroConfig = MacroConfig()
) -> Dict[Dataflow, NetworkCost]:
    layers = list(layers)
    return {df: evaluate_network(name, layers, df, macro) for df in DATAFLOWS}


def reduction(base: float, ours: float) -> float:
    """Percent reduction vs a baseline (positive = we are smaller)."""
    return 100.0 * (1.0 - ours / base) if base else 0.0


# ---------------------------------------------------------------------------
# TPU-kernel HBM traffic model (the executable analogue of the CIM model)
#
# The CIM accounting above prices IB/WB/OB buffer ports; the Pallas kernels
# pay the same structural costs at the HBM<->VMEM boundary.  These functions
# price the two executable separable-block pipelines so core/autotune.py can
# pick a fused schedule per layer shape (per-layer schedule selection a la
# MIREDO) and tests/benchmarks can assert fused < staged:
#
# * staged: stage_row_strips materializes overlapping strips (halo rows
#   written AND re-read), the DW output round-trips through HBM before the
#   pointwise matmul.
# * fused:  each strip is DMA'd once per c_out block straight from the
#   unstaged input; DW output stays in VMEM; only the block output is
#   written.
# ---------------------------------------------------------------------------


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# Input residency: how the fused kernels stage their big input streams.
#
# ``resident``      — BlockSpec keeps the full padded height of a channel
#                     block in VMEM; strip windows are pl.ds slices.  Pallas
#                     refetches the whole block every time the block index
#                     changes, so with more than one channel block the input
#                     is re-read at FULL height per revisiting grid cell.
# ``strip_dma``     — input lives in ANY/HBM; each grid cell DMAs exactly
#                     its halo'd strip window into one VMEM scratch slot.
#                     HBM words = the strip-staging accounting (halo rows
#                     re-read across strips, never re-written).
# ``strip_dma_db``  — same windows, double-buffered (2 slots + prefetch of
#                     the next cell's window): identical HBM words, 2x the
#                     strip scratch, DMA latency hidden behind compute.
#
# The executable engine is ``kernels.staging``; these constants and the
# residency-aware pricing below keep the model and the kernels in lockstep.
# ---------------------------------------------------------------------------

RESIDENCY_MODES: Tuple[str, ...] = ("resident", "strip_dma", "strip_dma_db")
DEFAULT_RESIDENCY = "strip_dma_db"


def validate_residency(residency: str) -> str:
    if residency not in RESIDENCY_MODES:
        raise ValueError(
            f"residency must be one of {RESIDENCY_MODES}, got {residency!r}")
    return residency


def staging_slots(residency: str) -> int:
    """VMEM strip-scratch slots a residency mode allocates (0 = the input
    is BlockSpec-resident instead of engine-staged)."""
    validate_residency(residency)
    return {"resident": 0, "strip_dma": 1, "strip_dma_db": 2}[residency]


def pick_channel_block(c: int, cap: int = 128) -> int:
    """Channel block size minimizing zero-padding, then maximizing width.

    ``min(cap, round_up(c, 8))`` pads e.g. 144 channels to 256 (+78 % HBM
    words and MACs on real MobileNet-V2 widths).  Instead: among blocks
    b <= cap (multiples of 8), pick the one whose padded channel count
    ``round_up(c, b)`` is smallest, breaking ties toward the widest block
    (fills the 128-lane axis).  For c divisible by 8 this always pads zero:
    144 -> 72, 192/576 -> 96, 960 -> 120, 384 -> 128.
    """
    c8 = _round_up(max(c, 1), 8)
    if c8 <= cap:
        return c8
    return min((b for b in range(8, cap + 1, 8)),
               key=lambda b: (_round_up(c8, b), -b))


@dataclass(frozen=True)
class SeparableShape:
    """One depthwise-separable block instance as the TPU kernel sees it."""

    b: int          # batch
    h: int          # ifmap height (pre-padding)
    w: int          # ifmap width
    c_in: int       # depthwise / expanded channels
    c_out: int      # pointwise projection channels
    k: int          # square kernel
    s: int          # stride
    dtype_bytes: int = 4

    @property
    def out_h(self) -> int:
        return -(-self.h // self.s)

    @property
    def out_w(self) -> int:
        return -(-self.w // self.s)

    @property
    def padded_w(self) -> int:
        return (self.out_w - 1) * self.s + self.k

    @property
    def padded_h(self) -> int:
        return (self.out_h - 1) * self.s + self.k

    @classmethod
    def from_dw_layer(cls, layer: DWLayer, c_out: int, b: int = 1,
                      dtype_bytes: int = 4) -> "SeparableShape":
        return cls(b=b, h=layer.h, w=layer.w, c_in=layer.c, c_out=c_out,
                   k=layer.k, s=layer.s, dtype_bytes=dtype_bytes)


@dataclass(frozen=True)
class HBMTraffic:
    """HBM words moved by one block under one pipeline.

    ``dma_issues`` counts the explicit strip-window async copies the
    staging engine issues (0 for ``resident``, whose input moves through
    implicit BlockSpec fetches, and for the staged baselines) — the
    issue-rate side of the latency story the byte counts cannot show.
    """

    read_words: int
    write_words: int
    dtype_bytes: int = 4
    dma_issues: int = 0

    @property
    def total_words(self) -> int:
        return self.read_words + self.write_words

    @property
    def total_bytes(self) -> int:
        return self.total_words * self.dtype_bytes


def _strip_counts(shape: SeparableShape, tile_h: int) -> Tuple[int, int]:
    """(n_th, in_rows): row-strip count and staged rows per strip."""
    tile_h = max(1, min(tile_h, shape.out_h))
    n_th = -(-shape.out_h // tile_h)
    in_rows = (tile_h - 1) * shape.s + shape.k
    return n_th, in_rows


def _covered_rows(shape, tile_h: int) -> int:
    """Rows of the input as LAUNCHED: the kernels height-cover-pad so the
    last strip's window stays in bounds, so when ``tile_h`` does not
    divide ``out_h`` this exceeds ``padded_h`` — the resident BlockSpec
    keeps (and refetches) this full height, not just ``padded_h``."""
    tile_h = max(1, min(tile_h, shape.out_h))
    n_th = -(-shape.out_h // tile_h)
    return (n_th * tile_h - 1) * shape.s + shape.k


def staged_separable_traffic(
    shape: SeparableShape, tile_h: int, c_block: int = 128
) -> HBMTraffic:
    """HBM traffic of the staged two-kernel pipeline.

    1. stage_row_strips: read the padded input once, WRITE the overlapping
       strips tensor (halo rows duplicated in HBM),
    2. DW kernel: read the strips + DW taps, write the DW output,
    3. PW matmul: re-read the DW output + PW weight, write the block output.
    """
    n_th, in_rows = _strip_counts(shape, tile_h)
    strips = shape.b * n_th * in_rows * shape.padded_w * shape.c_in
    ifmap = shape.b * shape.padded_h * shape.padded_w * shape.c_in
    tile_h_eff = max(1, min(tile_h, shape.out_h))
    dw_out = shape.b * n_th * tile_h_eff * shape.out_w * shape.c_in
    out = shape.b * shape.out_h * shape.out_w * shape.c_out
    w_dw = shape.k * shape.k * shape.c_in
    w_pw = shape.c_in * shape.c_out
    reads = ifmap + strips + w_dw + dw_out + w_pw
    writes = strips + dw_out + out
    return HBMTraffic(reads, writes, shape.dtype_bytes)


def _n_co_blocks(c_out: int, c_block: int) -> int:
    return -(-c_out // min(c_block, max(8, _round_up(c_out, 8))))


def _n_chan_blocks(c: int, c_block: int) -> int:
    cb = pick_channel_block(c, c_block)
    return _round_up(c, cb) // cb


def fused_separable_traffic(
    shape: SeparableShape, tile_h: int, c_block: int = 128,
    residency: str = DEFAULT_RESIDENCY,
) -> HBMTraffic:
    """HBM traffic of the fused single-pass pipeline under one residency.

    ``strip_dma`` / ``strip_dma_db``: each (strip, c_in block) window is
    DMA'd once per c_out block straight from the unstaged HBM input (halo
    rows re-read across strips but never written) — double-buffering moves
    the same words, earlier.  ``resident``: the full padded height of a
    channel block is BlockSpec-fetched, and REFETCHED whenever the block
    index changes — with more than one c_in block that is every grid cell,
    which is exactly the honest price of the legacy rendering.  In every
    mode the DW output lives and dies in VMEM, the only activation write
    is the block output, and weight blocks are re-fetched per revisiting
    grid cell.
    """
    validate_residency(residency)
    n_th, in_rows = _strip_counts(shape, tile_h)
    n_co = -(-shape.c_out // min(c_block, max(8, shape.c_out)))
    n_ci = _n_chan_blocks(shape.c_in, c_block)
    strips = shape.b * n_th * in_rows * shape.padded_w * shape.c_in
    # resident fetches move the input at its LAUNCHED height (height-cover
    # padding included), not just padded_h
    x_full = shape.b * _covered_rows(shape, tile_h) * shape.padded_w \
        * shape.c_in
    out = shape.b * shape.out_h * shape.out_w * shape.c_out
    w_dw = shape.k * shape.k * shape.c_in * n_th * n_co
    w_pw = shape.c_in * shape.c_out * n_th
    if residency == "resident":
        x_reads = x_full * (n_th * n_co if n_ci > 1 else 1)
        issues = 0
    else:
        x_reads = strips * n_co
        issues = shape.b * n_th * n_co * n_ci
    reads = x_reads + w_dw + w_pw
    writes = out
    return HBMTraffic(reads, writes, shape.dtype_bytes, issues)


def separable_staging_bytes(
    shape: SeparableShape, tile_h: int,
    residency: str = DEFAULT_RESIDENCY, c_block: int = 128,
) -> int:
    """VMEM bytes the fused separable kernel's INPUT stream occupies under
    one residency: the slot buffer(s) for the DMA modes (2x for
    double-buffering), the full-padded-height channel block otherwise."""
    validate_residency(residency)
    _n_th, in_rows = _strip_counts(shape, tile_h)
    ci = pick_channel_block(shape.c_in, c_block)
    if residency == "resident":
        # the launched (height-cover-padded) block, not just padded_h
        return (_covered_rows(shape, tile_h) * shape.padded_w * ci
                * shape.dtype_bytes)
    return (staging_slots(residency) * in_rows * shape.padded_w * ci
            * shape.dtype_bytes)


# ---------------------------------------------------------------------------
# MBConv (EfficientNet) two-pass traffic model
#
# The SE squeeze (global pool) between DW and PW breaks the single-strip
# residency of the fused separable pipeline: the projection cannot start
# until every strip's DW output has been pooled.  The two-pass fused
# schedule keeps the DW tensor out of the staged HBM round-trips anyway:
#
# * pass 1: expand-PW + DW per strip, the SE pool accumulated on-chip; the
#   DW output is either RETAINED (written once to HBM, re-read once by pass
#   2) or DISCARDED (pass 2 recomputes expand+DW from the input strips).
# * pass 2: the SE scale folds into the projection-PW contraction in the
#   same VMEM residency as the (retained or recomputed) DW block.
#
# The retain/recompute crossover is a pure traffic tradeoff: retain pays
# E * (1 + n_co) words for the DW tensor E; recompute pays the input strips
# and expand/DW weights again, n_co more times.  ``mbconv_fused_traffic``
# prices both so the autotuner can pick per layer shape.
# ---------------------------------------------------------------------------


MBCONV_MODES: Tuple[str, ...] = ("retain", "recompute")


@dataclass(frozen=True)
class MBConvShape:
    """One MBConv block instance as the TPU kernels see it."""

    b: int          # batch
    h: int          # ifmap height (pre-padding)
    w: int          # ifmap width
    c_in: int       # block input channels
    c_mid: int      # expanded channels (the DW / SE width)
    c_out: int      # projection output channels
    k: int          # square DW kernel
    s: int          # stride
    se_ratio: float = 0.25
    dtype_bytes: int = 4

    @property
    def out_h(self) -> int:
        return -(-self.h // self.s)

    @property
    def out_w(self) -> int:
        return -(-self.w // self.s)

    @property
    def padded_w(self) -> int:
        return (self.out_w - 1) * self.s + self.k

    @property
    def padded_h(self) -> int:
        return (self.out_h - 1) * self.s + self.k

    @property
    def has_se(self) -> bool:
        """``se_ratio <= 0`` means NO squeeze-excite at all (the V3 blocks
        that skip it, and every Fused-MBConv block): no pool, no MLP, no
        gate — the kernels skip those stages outright and the model must
        price zero words for them."""
        return self.se_ratio > 0

    @property
    def c_se(self) -> int:
        """SE bottleneck width — EfficientNet sizes it off the BLOCK INPUT
        channels, not the expanded width.  Zero when the block has no SE."""
        if not self.has_se:
            return 0
        return max(1, int(self.c_in * self.se_ratio))

    @property
    def has_expand(self) -> bool:
        return self.c_mid != self.c_in

    @property
    def se_words(self) -> int:
        """SE MLP parameter words (two FCs + biases); zero without SE."""
        if not self.has_se:
            return 0
        return 2 * self.c_mid * self.c_se + self.c_se + self.c_mid


def _mbconv_common(shape: MBConvShape, tile_h: int, c_block: int):
    n_th, in_rows = _strip_counts(
        SeparableShape(b=shape.b, h=shape.h, w=shape.w, c_in=shape.c_in,
                       c_out=shape.c_out, k=shape.k, s=shape.s), tile_h)
    tile_h_eff = max(1, min(tile_h, shape.out_h))
    cm_block = pick_channel_block(shape.c_mid, c_block)
    n_cm = _round_up(shape.c_mid, cm_block) // cm_block
    n_co = _n_co_blocks(shape.c_out, c_block)
    strips = shape.b * n_th * in_rows * shape.padded_w * shape.c_in
    # DW tensor words as retained on HBM (whole strips incl. masked rows)
    e_rows = shape.b * n_th * tile_h_eff * shape.out_w * shape.c_mid
    out = shape.b * shape.out_h * shape.out_w * shape.c_out
    w_exp = shape.c_in * shape.c_mid if shape.has_expand else 0
    w_dw = shape.k * shape.k * shape.c_mid
    w_proj = shape.c_mid * shape.c_out
    pool = shape.b * shape.c_mid
    return n_th, n_cm, n_co, strips, e_rows, out, w_exp, w_dw, w_proj, pool


def mbconv_pass_traffic(
    shape: MBConvShape, tile_h: int, mode: str = "retain",
    c_block: int = 128, residency: str = DEFAULT_RESIDENCY,
) -> Tuple[HBMTraffic, HBMTraffic]:
    """Per-pass HBM traffic of the two-pass fused MBConv pipeline.

    Returns ``(pass1, pass2)`` such that their fields SUM exactly to
    ``mbconv_fused_traffic`` (that function is defined as the merge, so
    the split cannot drift).  The boundary between the two passes is the
    SE-pool barrier:

    * ``pass1``: input strip reads per c_mid block + per-strip expand/DW
      weight refetches + the SE pool write, the SE MLP words (the MLP
      runs on the pass-1 pool before pass 2 can gate), and — under
      ``mode == "retain"`` — the one DW-tensor retain write.
    * ``pass2``: the retained-DW re-read per c_out block (or the
      recompute re-read of strips + expand/DW weights), the SE scale +
      projection-weight reads, and the block's only activation write.

    A no-SE block (``shape.has_se == False``) has no pool barrier: the
    kernels drop every pool/scale/MLP word, and under ``recompute`` pass
    1 is skipped ENTIRELY (it would produce nothing), so its pass-1
    figures here are exactly zero — the single remaining launch does all
    the work and is priced on pass 2.

    The split is what cross-block pipelining prices: pass 2 of block i
    and pass 1 of block i+1 touch disjoint buffers (pass 2 reads DW_i /
    scale_i and writes act_{i+1}; pass 1 of i+1 reads act_{i+1} strips as
    they land and writes DW_{i+1} / pool_{i+1}), so a boundary can pay
    ``max`` instead of ``sum`` — see ``boundary_overlap_us``.
    """
    if mode not in MBCONV_MODES:
        raise ValueError(mode)
    validate_residency(residency)
    (n_th, n_cm, n_co, strips, e_rows, out, w_exp, w_dw, w_proj,
     pool) = _mbconv_common(shape, tile_h, c_block)
    n_ci = _n_chan_blocks(shape.c_in, c_block)
    # launched height incl. height-cover padding (see _covered_rows)
    x_full = shape.b * _covered_rows(shape, tile_h) * shape.padded_w \
        * shape.c_in
    resident = residency == "resident"
    se = shape.has_se
    scale = pool if se else 0                      # SE gate, (B, C_mid) words
    # pass 1: strips per c_mid block + per-strip weight refetches + pool.
    # se=off + recompute: the kernel skips pass 1 outright — zero words.
    issues1 = 0
    reads1 = 0
    writes1 = 0
    if se or mode == "retain":
        if resident:
            reads1 = x_full * (n_cm * n_th if n_ci > 1 else 1)
        else:
            reads1 = strips * n_cm
            issues1 += shape.b * n_cm * n_th * n_ci
        reads1 += (w_exp + w_dw) * n_th
    if se:
        writes1 += pool
        # SE MLP between passes (host-side; tiny but accounted with pass 1
        # — it consumes the pass-1 pool and must finish before pass 2 gates)
        reads1 += pool + shape.se_words
        writes1 += scale
    # pass 2
    issues2 = 0
    if mode == "retain":
        writes1 += e_rows                          # pass-1 DW retain write
        reads2 = e_rows * n_co + scale * n_th * n_co + w_proj * n_th
        if not resident:
            issues2 += shape.b * n_co * n_th * n_cm
    else:
        if resident:
            reads2 = x_full * (n_co * n_th * n_cm if n_ci > 1 else 1)
        else:
            reads2 = strips * n_cm * n_co
            issues2 += shape.b * n_co * n_th * n_cm * n_ci
        reads2 += ((w_exp + w_dw) * n_th * n_co
                   + scale * n_th * n_co + w_proj * n_th)
    writes2 = out
    return (HBMTraffic(reads1, writes1, shape.dtype_bytes, issues1),
            HBMTraffic(reads2, writes2, shape.dtype_bytes, issues2))


def mbconv_fused_traffic(
    shape: MBConvShape, tile_h: int, mode: str = "retain",
    c_block: int = 128, residency: str = DEFAULT_RESIDENCY,
) -> HBMTraffic:
    """HBM traffic of the two-pass fused MBConv pipeline (one mode, one
    residency).

    Pass 1 reads each input strip once per c_mid block (expand reduction
    innermost) and writes only the on-chip-accumulated SE pool — plus the
    DW tensor once when ``mode == "retain"``.  Pass 2 reads the retained DW
    tensor once per c_out block, or (``mode == "recompute"``) re-reads the
    input strips and expand/DW weights instead; either way the SE scale and
    projection happen in the same VMEM residency, and the only activation
    write of the whole block is the final output.

    Residency changes how the INPUT streams price: the DMA modes move
    exactly the halo'd strip windows (``strip_dma_db`` double-buffers the
    same words); ``resident`` BlockSpec-refetches the full padded height of
    a c_in block every revisiting grid cell.  The retained-DW re-read is a
    non-overlapping block stream, so its words are residency-invariant.

    Defined as the SUM of ``mbconv_pass_traffic`` — the whole-block total
    and the per-pass split cannot diverge.
    """
    p1, p2 = mbconv_pass_traffic(shape, tile_h, mode, c_block, residency)
    return HBMTraffic(p1.read_words + p2.read_words,
                      p1.write_words + p2.write_words,
                      shape.dtype_bytes, p1.dma_issues + p2.dma_issues)


def mbconv_staging_bytes(
    shape: MBConvShape, tile_h: int, mode: str = "retain",
    residency: str = DEFAULT_RESIDENCY, c_block: int = 128,
) -> int:
    """VMEM bytes the two-pass MBConv kernels' staged input streams occupy
    under one residency: the halo'd input-window stream (both passes'
    launches stage it identically) plus, for ``mode == "retain"``, the
    retained-DW block stream pass 2 re-reads."""
    validate_residency(residency)
    if mode not in MBCONV_MODES:
        raise ValueError(mode)
    tile_h_eff = max(1, min(tile_h, shape.out_h))
    in_rows = (tile_h_eff - 1) * shape.s + shape.k
    ci = pick_channel_block(shape.c_in, c_block)
    cm = pick_channel_block(shape.c_mid, c_block)
    slots = staging_slots(residency)
    dw_stream = tile_h_eff * shape.out_w * cm * shape.dtype_bytes
    if residency == "resident":
        # the launched (height-cover-padded) block, not just padded_h
        x_bytes = (_covered_rows(shape, tile_h) * shape.padded_w * ci
                   * shape.dtype_bytes)
        dw_bytes = dw_stream                      # per-strip resident block
    else:
        x_bytes = slots * in_rows * shape.padded_w * ci * shape.dtype_bytes
        dw_bytes = slots * dw_stream
    return x_bytes + (dw_bytes if mode == "retain" else 0)


def mbconv_best_fused_traffic(
    shape: MBConvShape, tile_h: int, c_block: int = 128,
    residency: str = DEFAULT_RESIDENCY,
) -> Tuple[str, HBMTraffic]:
    """(mode, traffic) of the cheaper two-pass variant at this (tile_h,
    residency)."""
    priced = [(m, mbconv_fused_traffic(shape, tile_h, m, c_block, residency))
              for m in MBCONV_MODES]
    return min(priced, key=lambda mt: mt[1].total_bytes)


def mbconv_staged_traffic(
    shape: MBConvShape, tile_h: int, c_block: int = 128
) -> HBMTraffic:
    """HBM traffic of the staged MBConv pipeline (the PR-1-era baseline):

    1. expand PW: read x + w_exp, write the expanded map,
    2. stage_row_strips over the expanded map (halo rows duplicated in HBM),
    3. DW kernel: read strips + taps, write the DW output,
    4. SE (when the block has one): read the DW output for the pool, run
       the MLP, then re-read AND re-write the DW output applying the gate,
    5. projection PW: re-read the (scaled) DW output + w_proj, write out.

    Exactly the weight-stationary-baseline behaviour the paper criticizes:
    the squeeze forces the whole DW tensor through HBM four more times.
    A no-SE block skips stage 4 entirely — the staged baseline saves its
    gate round-trips too, so the fused-vs-staged margin stays honest.
    """
    (n_th, _n_cm, _n_co, _strips, e_rows, out, w_exp, w_dw, w_proj,
     pool) = _mbconv_common(shape, tile_h, c_block)
    x_words = shape.b * shape.h * shape.w * shape.c_in
    xe = shape.b * shape.h * shape.w * shape.c_mid
    xe_pad = shape.b * shape.padded_h * shape.padded_w * shape.c_mid
    n_th_, in_rows = _strip_counts(
        SeparableShape(b=shape.b, h=shape.h, w=shape.w, c_in=shape.c_mid,
                       c_out=shape.c_out, k=shape.k, s=shape.s), tile_h)
    strips_e = shape.b * n_th_ * in_rows * shape.padded_w * shape.c_mid
    reads = (x_words + w_exp                      # expand
             + xe_pad                             # staging read
             + strips_e + w_dw                    # DW kernel
             + e_rows + w_proj)                   # projection read
    writes = ((xe if shape.has_expand else 0)     # expanded map
              + strips_e                          # staged strips
              + e_rows                            # DW output
              + out)
    if shape.has_se:
        reads += (e_rows + shape.se_words         # SE pool + MLP params
                  + e_rows + pool)                # gate apply read
        writes += (pool                           # gate
                   + e_rows)                      # scaled DW output
    if not shape.has_expand:
        reads -= x_words                          # no expand stage: DW stages
    return HBMTraffic(reads, writes, shape.dtype_bytes)


# ---------------------------------------------------------------------------
# Fused-MBConv (EfficientNet-V2) single-pass traffic model
#
# Fused-MBConv collapses the expand-PW + DW pair into ONE dense k x k
# convolution (C_in -> C_mid) and never carries SE, so nothing forces a
# pool barrier: the whole block — dense conv, activation, 1x1 projection —
# runs as a SINGLE pass in one VMEM residency.  The family reuses the
# MBConvShape vocabulary (c_mid is the dense conv's output width) with
# ``se_ratio == 0`` REQUIRED; its weights differ though: one dense
# k*k*c_in*c_mid tensor instead of expand + DW taps.
#
# Pass-split convention: the family is priced through the same
# ``(pass1, pass2)`` interface as MBConv so the network solver and the
# pipelining model stay family-generic — pass 1 carries the ENTIRE block
# and pass 2 is EXACTLY zero (property-tested).  A zero pass 2 is what
# keeps ``boundary_overlap_us`` honest at a single-pass producer: there
# is no pass-2 compute for the next block's pass-1 DMA to hide behind, so
# the boundary prices serial automatically (min(p2, p1) == 0).
# ---------------------------------------------------------------------------


def _require_no_se(shape: MBConvShape) -> None:
    if shape.has_se:
        raise ValueError(
            f"Fused-MBConv never carries SE; got se_ratio="
            f"{shape.se_ratio!r} — build the shape with se_ratio=0")


def fusedmb_pass_traffic(
    shape: MBConvShape, tile_h: int, c_block: int = 128,
    residency: str = DEFAULT_RESIDENCY,
) -> Tuple[HBMTraffic, HBMTraffic]:
    """Per-pass HBM traffic of the single-pass Fused-MBConv pipeline:
    ``(whole_block, exactly_zero)``.

    The one launch reads each input strip once per (c_mid, c_out) block
    pair (the dense-conv c_in reduction is innermost, the projection's
    c_mid reduction next), refetches the dense conv weight per revisiting
    (strip, c_out) cell and the projection weight per strip, and writes
    only the block output — the expanded map lives and dies in VMEM,
    exactly the separable fusion story at MBConv widths.
    """
    _require_no_se(shape)
    validate_residency(residency)
    (n_th, n_cm, n_co, strips, _e_rows, out, _w_exp, _w_dw, w_proj,
     _pool) = _mbconv_common(shape, tile_h, c_block)
    n_ci = _n_chan_blocks(shape.c_in, c_block)
    w_conv = shape.k * shape.k * shape.c_in * shape.c_mid
    # launched height incl. height-cover padding (see _covered_rows)
    x_full = shape.b * _covered_rows(shape, tile_h) * shape.padded_w \
        * shape.c_in
    issues = 0
    if residency == "resident":
        reads = x_full * (n_co * n_th * n_cm if n_ci > 1 else 1)
    else:
        reads = strips * n_cm * n_co
        issues += shape.b * n_co * n_th * n_cm * n_ci
    reads += w_conv * n_th * n_co + w_proj * n_th
    return (HBMTraffic(reads, out, shape.dtype_bytes, issues),
            HBMTraffic(0, 0, shape.dtype_bytes, 0))


def fusedmb_fused_traffic(
    shape: MBConvShape, tile_h: int, c_block: int = 128,
    residency: str = DEFAULT_RESIDENCY,
) -> HBMTraffic:
    """HBM traffic of the single-pass Fused-MBConv pipeline.  Defined as
    the sum of ``fusedmb_pass_traffic`` (whose pass 2 is exactly zero) —
    the whole-block total and the per-pass split cannot diverge."""
    p1, p2 = fusedmb_pass_traffic(shape, tile_h, c_block, residency)
    return HBMTraffic(p1.read_words + p2.read_words,
                      p1.write_words + p2.write_words,
                      shape.dtype_bytes, p1.dma_issues + p2.dma_issues)


def fusedmb_staged_traffic(
    shape: MBConvShape, tile_h: int, c_block: int = 128
) -> HBMTraffic:
    """HBM traffic of the staged Fused-MBConv pipeline (what
    ``convdk_fusedmb_staged`` actually runs):

    1. dense conv: read the input + w_conv, write the expanded map,
    2. projection PW: re-read the expanded map + w_proj, write out.

    The expanded map (c_mid = expand * c_in wide) makes the HBM
    round-trip the fusion deletes — the same story as the separable
    baseline, at Fused-MBConv widths."""
    _require_no_se(shape)
    del tile_h, c_block
    x_words = shape.b * shape.h * shape.w * shape.c_in
    xe = shape.b * shape.out_h * shape.out_w * shape.c_mid
    out = shape.b * shape.out_h * shape.out_w * shape.c_out
    w_conv = shape.k * shape.k * shape.c_in * shape.c_mid
    w_proj = shape.c_mid * shape.c_out
    reads = x_words + w_conv + xe + w_proj
    writes = xe + out
    return HBMTraffic(reads, writes, shape.dtype_bytes)


def fusedmb_staging_bytes(
    shape: MBConvShape, tile_h: int,
    residency: str = DEFAULT_RESIDENCY, c_block: int = 128,
) -> int:
    """VMEM bytes the Fused-MBConv kernel's INPUT stream occupies under
    one residency (single pass, no retained stream — the input window is
    the only staged tensor)."""
    _require_no_se(shape)
    validate_residency(residency)
    tile_h_eff = max(1, min(tile_h, shape.out_h))
    in_rows = (tile_h_eff - 1) * shape.s + shape.k
    ci = pick_channel_block(shape.c_in, c_block)
    if residency == "resident":
        # the launched (height-cover-padded) block, not just padded_h
        return (_covered_rows(shape, tile_h) * shape.padded_w * ci
                * shape.dtype_bytes)
    return (staging_slots(residency) * in_rows * shape.padded_w * ci
            * shape.dtype_bytes)


# ---------------------------------------------------------------------------
# Sharded traffic: per-device HBM + collective bytes
#
# ``kernels.convdk_sharded`` partitions the fused pipelines over the
# ("data", "model") mesh (an optional "pod" axis folds into the data
# factor as a pure data-parallel outer multiplier): batch on "data" for
# both families, c_out on "model" for separable (collective-free: the
# c_in reduction is local) and c_mid on "model" for MBConv (the SE
# squeeze FC and the projection PW reduce over the full expanded width,
# so each becomes a cross-device reduction).  The paper's reduction claim
# must be re-proved under this partitioning — Eyeriss-style reuse
# analysis does not transfer for free — so the model prices BOTH terms:
#
# * per-device HBM traffic = the single-device model evaluated at the
#   shard shape (batch/dp, channel grid/mp), and
# * collective words, per the schedule's **collective** axis:
#   - ``ring_allreduce``: 2*(mp-1) words per reduced word per model group
#     (reduce-scatter + all-gather; the result lands replicated), and
#   - ``psum_scatter`` (MBConv projection only): (mp-1) words per reduced
#     word — the reduce-scatter half alone, the pass-2 output leaving the
#     kernel SHARDED on c_out for a consumer that wants it that way.  The
#     SE squeeze partial always rings: the excite FC needs it replicated.
#   Words are summed over the dp model groups.  Non-divisible axes drop
#   to 1 (the ``spec_for`` policy).
#
# ``ShardedTraffic`` is the SINGLE source of truth for mesh-wide byte
# totals: ``core.autotune`` schedules carry these objects and delegate
# every total to them, so the solver and the model cannot diverge.
# ---------------------------------------------------------------------------


COLLECTIVE_MODES: Tuple[str, ...] = ("ring_allreduce", "psum_scatter")
DEFAULT_COLLECTIVE = "ring_allreduce"

# Inter-block layout axis: how a block's activation tensor sits across the
# "model" groups at a block BOUNDARY.  ``replicated`` is the classic form
# (every device holds the full (B_local, H, W, C) slice of its data group);
# ``model_sharded`` splits the channel dim over "model" — the form a
# psum_scatter pass-2 leaves behind, and the form an identity-expand MBConv
# (or sharded-c_in separable) entry can consume collective-free.
LAYOUT_MODES: Tuple[str, ...] = ("replicated", "model_sharded")
DEFAULT_LAYOUT = "replicated"


def validate_collective(collective: str) -> str:
    if collective not in COLLECTIVE_MODES:
        raise ValueError(
            f"collective must be one of {COLLECTIVE_MODES}, "
            f"got {collective!r}")
    return collective


def validate_layout(layout: str) -> str:
    if layout not in LAYOUT_MODES:
        raise ValueError(
            f"layout must be one of {LAYOUT_MODES}, got {layout!r}")
    return layout


@dataclass(frozen=True)
class ShardedTraffic:
    """One fused block under one (data, model) partitioning."""

    device: HBMTraffic           # HBM traffic of ONE device's shard
    collective_words: int        # interconnect words, summed over the mesh
    n_devices: int
    mesh_shape: Tuple[int, int] = (1, 1)
    collective: str = DEFAULT_COLLECTIVE   # reduction layout priced above
    in_layout: str = DEFAULT_LAYOUT        # how the input arrives
    transition_words: int = 0    # entry-side layout repay (all-gather words)

    @property
    def dtype_bytes(self) -> int:
        return self.device.dtype_bytes

    @property
    def per_device_bytes(self) -> int:
        return self.device.total_bytes

    @property
    def collective_bytes(self) -> int:
        return self.collective_words * self.dtype_bytes

    @property
    def transition_bytes(self) -> int:
        return self.transition_words * self.dtype_bytes

    @property
    def out_layout(self) -> str:
        """Layout the block's output LEAVES in: sharded on c_out after a
        psum_scatter pass-2, replicated otherwise."""
        _dp, mp = self.mesh_shape
        if mp > 1 and self.collective == "psum_scatter":
            return "model_sharded"
        return DEFAULT_LAYOUT

    @property
    def total_bytes(self) -> int:
        """All bytes moved anywhere: every device's HBM traffic plus the
        interconnect words (reductions AND any entry-side layout repay) —
        the number the staged single-device baseline is compared against."""
        return (self.device.total_bytes * self.n_devices
                + self.collective_bytes + self.transition_bytes)


def shard_factors(batch: int, channels: int,
                  mesh_shape: Tuple[int, int]) -> Tuple[int, int]:
    """Effective (data, model) split, matching ``kernels.can_shard_fused``
    exactly: the kernel routing is ALL-OR-NOTHING (a grid either runs the
    sharded wrapper on the whole mesh or falls back to one device), so if
    either mesh axis fails to divide its grid axis the whole layer prices
    as (1, 1) — the model must never describe a partitioning the kernels
    will not run."""
    dp, mp = mesh_shape
    if dp < 1 or mp < 1 or batch % dp != 0 or channels % mp != 0:
        return 1, 1
    return dp, mp


def separable_shard(
    shape: SeparableShape, mesh_shape: Tuple[int, int],
    in_layout: str = DEFAULT_LAYOUT,
) -> Tuple[SeparableShape, Tuple[int, int]]:
    """(per-device shard shape, effective factors) for the separable
    partitioning.

    ``replicated`` input: batch over "data", c_out over "model" (the PW
    reduction stays device-local).  ``model_sharded`` input: batch over
    "data", c_in over "model" — each device sees its channel slice of the
    input, DW is channel-local, and the PW contraction becomes a partial
    over the local c_in rows (collective priced separately)."""
    validate_layout(in_layout)
    if in_layout == "model_sharded":
        dp, mp = shard_factors(shape.b, shape.c_in, mesh_shape)
        if mp > 1:
            return replace(shape, b=shape.b // dp,
                           c_in=shape.c_in // mp), (dp, mp)
        return replace(shape, b=shape.b // dp), (dp, mp)
    dp, mp = shard_factors(shape.b, shape.c_out, mesh_shape)
    return replace(shape, b=shape.b // dp, c_out=shape.c_out // mp), (dp, mp)


def can_shard_input(shape: MBConvShape,
                    mesh_shape: Tuple[int, int]) -> bool:
    """True iff the MBConv block can CONSUME a c_in-sharded input without
    any entry collective: only the identity-expand form (c_mid == c_in)
    qualifies — its "expand" is elementwise, so device d's c_in slice is
    exactly the c_mid slice its DW taps need.  A real expand (e > 1) is a
    dense contraction over ALL of c_in, so every device needs the full
    input and a sharded arrival must be gathered back (priced as
    ``transition_words``, never a win — see ``sharded_mbconv_traffic``)."""
    _dp, mp = shard_factors(shape.b, shape.c_mid, mesh_shape)
    return mp > 1 and not shape.has_expand


def mbconv_shard(
    shape: MBConvShape, mesh_shape: Tuple[int, int],
    in_layout: str = DEFAULT_LAYOUT,
) -> Tuple[MBConvShape, Tuple[int, int]]:
    """(per-device shard shape, effective factors) for the MBConv
    partitioning: batch over "data", c_mid over "model".  With a
    ``model_sharded`` input layout on an identity-expand block the input
    channels shard too (c_in == c_mid there), shrinking every pass-1
    strip read by the model factor."""
    validate_layout(in_layout)
    dp, mp = shard_factors(shape.b, shape.c_mid, mesh_shape)
    local = replace(shape, b=shape.b // dp, c_mid=shape.c_mid // mp)
    if (in_layout == "model_sharded" and mp > 1 and not shape.has_expand):
        local = replace(local, c_in=shape.c_in // mp)
    return local, (dp, mp)


def _separable_collective_words(shape: SeparableShape, dp: int, mp: int,
                                collective: str) -> int:
    """Interconnect words of the sharded-c_in separable form: the PW
    contraction is a partial over each device's c_in rows, reduced across
    the model group — full ring under ``ring_allreduce`` (output lands
    replicated) or the reduce-scatter half under ``psum_scatter`` (output
    leaves sharded on c_out, zero-padded to the model factor)."""
    validate_collective(collective)
    if mp <= 1:
        return 0
    b_local = shape.b // dp
    out = b_local * shape.out_h * shape.out_w * shape.c_out
    if collective == "psum_scatter":
        return dp * (mp - 1) * (b_local * shape.out_h * shape.out_w
                                * scatter_c_out(shape.c_out, mp))
    return dp * 2 * (mp - 1) * out


def sharded_separable_traffic(
    shape: SeparableShape, tile_h: int, mesh_shape: Tuple[int, int] = (1, 1),
    c_block: int = 128, residency: str = DEFAULT_RESIDENCY,
    in_layout: str = DEFAULT_LAYOUT, collective: str = DEFAULT_COLLECTIVE,
) -> ShardedTraffic:
    """Per-device traffic of the sharded fused separable block.

    ``replicated`` input (default): batch on "data", c_out on "model";
    c_in stays replicated so the PW reduction is device-local and the
    collective term is zero.  ``model_sharded`` input: c_in shards on
    "model" instead — each device reads only its channel slice of the
    input (mp-fold fewer strip words) but the PW partial must reduce
    across the group, priced per ``collective``.  ``residency`` prices
    each device's input staging either way."""
    validate_layout(in_layout)
    local, (dp, mp) = separable_shard(shape, mesh_shape, in_layout)
    if in_layout == "model_sharded" and mp > 1:
        return ShardedTraffic(
            device=fused_separable_traffic(local, tile_h, c_block, residency),
            collective_words=_separable_collective_words(
                shape, dp, mp, collective),
            n_devices=dp * mp, mesh_shape=(dp, mp), collective=collective,
            in_layout=in_layout)
    return ShardedTraffic(
        device=fused_separable_traffic(local, tile_h, c_block, residency),
        collective_words=0, n_devices=dp * mp, mesh_shape=(dp, mp))


def sharded_separable_staged_traffic(
    shape: SeparableShape, tile_h: int, mesh_shape: Tuple[int, int] = (1, 1),
    c_block: int = 128,
) -> ShardedTraffic:
    """The staged two-kernel pipeline under the SAME partitioning — the
    baseline a sharded deployment would actually run (its PW reduction is
    also c_in-local, so it is collective-free too)."""
    local, (dp, mp) = separable_shard(shape, mesh_shape)
    return ShardedTraffic(
        device=staged_separable_traffic(local, tile_h, c_block),
        collective_words=0, n_devices=dp * mp, mesh_shape=(dp, mp))


def can_psum_scatter(shape: MBConvShape,
                     mesh_shape: Tuple[int, int]) -> bool:
    """True iff the psum_scatter pass-2 variant is runnable at this
    partitioning: the layer actually shards on "model".  Non-dividing
    c_out no longer rejects — the kernel zero-pads the projection columns
    to the next multiple of the model factor and scatters the padded dim
    (priced as such: see ``scatter_c_out``)."""
    _dp, mp = shard_factors(shape.b, shape.c_mid, mesh_shape)
    return mp > 1


def scatter_c_out(c_out: int, mp: int) -> int:
    """Channel width a psum_scatter pass-2 actually moves: c_out rounded
    up to the model factor (the pad-to-mp columns are zeros of the padded
    projection weight, scattered like any other — wire words are honest
    about them)."""
    if mp <= 1:
        return c_out
    return _round_up(c_out, mp)


def layout_transition_words(
    b: int, h: int, w: int, c: int, mesh_shape: Tuple[int, int],
    producer_layout: str, consumer_layout: str,
) -> int:
    """Interconnect words to move a (b, h, w, c) activation from the
    producer's boundary layout to the consumer's: an all-gather of the
    missing (mp-1)/mp channel slices per model group (summed over the dp
    groups) when a sharded output feeds a replicated entry; free when the
    layouts match, and free when a replicated output feeds a sharded
    entry (each device slices locally)."""
    validate_layout(producer_layout)
    validate_layout(consumer_layout)
    dp, mp = mesh_shape
    if (mp <= 1 or producer_layout != "model_sharded"
            or consumer_layout == "model_sharded"):
        return 0
    b_local = b // dp if dp > 1 and b % dp == 0 else b
    # (mp-1) words per gathered word per model group — same convention as
    # the reduce-scatter half, so scatter + repay-gather == ring exactly
    return dp * (mp - 1) * b_local * h * w * scatter_c_out(c, mp)


def _mbconv_entry_transition_words(shape: MBConvShape, dp: int, mp: int,
                                   in_layout: str) -> int:
    """Entry-side repay when a c_in-sharded input feeds a REAL expand
    (e > 1): the dense expand contraction needs all of c_in on every
    device, so the entry all-gathers the missing slices — (mp-1) words
    per held word per model group, summed over the dp groups.  Zero for
    the identity-expand entry (the shard IS what the block needs) and for
    replicated arrivals."""
    if mp <= 1 or in_layout != "model_sharded" or not shape.has_expand:
        return 0
    b_local = shape.b // dp
    return dp * (mp - 1) * b_local * shape.h * shape.w * shape.c_in


def _mbconv_collective_words(shape: MBConvShape, dp: int, mp: int,
                             collective: str = DEFAULT_COLLECTIVE) -> int:
    """Interconnect words for the two c_mid reductions, per ``collective``:

    * the (B_local, C_se) SE squeeze partial always ring-all-reduces
      (2*(mp-1) words per reduced word per model group — the excite FC
      consumes it replicated);
    * the (B_local, H', W', C_out) projection partial ring-all-reduces
      under ``ring_allreduce`` or pays only the reduce-scatter half,
      (mp-1) words per reduced word, under ``psum_scatter`` — the pass-2
      output then leaves the kernel sharded on c_out.  Non-dividing c_out
      scatters at the zero-padded width (``scatter_c_out``)."""
    squeeze, proj = _mbconv_collective_split(shape, dp, mp, collective)
    return squeeze + proj


def _mbconv_collective_split(
    shape: MBConvShape, dp: int, mp: int,
    collective: str = DEFAULT_COLLECTIVE,
) -> Tuple[int, int]:
    """``_mbconv_collective_words`` split by pass: ``(squeeze, proj)``
    mesh-wide words.  The SE-squeeze ring belongs to pass 1 (pass 2
    cannot gate until it lands); the projection reduction belongs to
    pass 2.  ``_mbconv_collective_words`` is defined as the sum."""
    validate_collective(collective)
    if mp <= 1:
        return 0, 0
    b_local = shape.b // dp
    # c_se is 0 for a no-SE block, so the squeeze ring vanishes exactly
    # when the kernel emits no squeeze psum
    squeeze = b_local * shape.c_se
    proj = b_local * shape.out_h * shape.out_w * shape.c_out
    if collective == "psum_scatter":
        proj_words = (mp - 1) * (b_local * shape.out_h * shape.out_w
                                 * scatter_c_out(shape.c_out, mp))
    else:
        proj_words = 2 * (mp - 1) * proj
    return dp * 2 * (mp - 1) * squeeze, dp * proj_words


def sharded_mbconv_traffic(
    shape: MBConvShape, tile_h: int, mode: str = "retain",
    mesh_shape: Tuple[int, int] = (1, 1), c_block: int = 128,
    residency: str = DEFAULT_RESIDENCY,
    collective: str = DEFAULT_COLLECTIVE,
    in_layout: str = DEFAULT_LAYOUT,
) -> ShardedTraffic:
    """Per-device traffic + collective bytes of the sharded two-pass
    MBConv.

    Batch splits over "data", c_mid over "model".  Two reductions cross
    the model groups: the (B_local, C_se) SE squeeze partial (the pass-1
    pool leaving the chip once, before the pass-2 gate) and the
    (B_local, H', W', C_out) projection partial — the latter priced per
    ``collective`` (``ring_allreduce`` replicates the output,
    ``psum_scatter`` halves the wire words and leaves it sharded on
    c_out).  ``residency`` prices each device's input staging.

    ``in_layout`` prices the ENTRY: an identity-expand block consumes a
    ``model_sharded`` input collective-free at mp-fold smaller strip
    reads (c_in shards with c_mid); a real expand must gather a sharded
    arrival back (``transition_words``) — the honest reason e > 1
    boundaries never win by staying sharded."""
    validate_layout(in_layout)
    local, (dp, mp) = mbconv_shard(shape, mesh_shape, in_layout)
    eff_layout = in_layout if mp > 1 else DEFAULT_LAYOUT
    return ShardedTraffic(
        device=mbconv_fused_traffic(local, tile_h, mode, c_block, residency),
        collective_words=_mbconv_collective_words(shape, dp, mp, collective),
        n_devices=dp * mp, mesh_shape=(dp, mp), collective=collective,
        in_layout=eff_layout,
        transition_words=_mbconv_entry_transition_words(
            shape, dp, mp, eff_layout))


def sharded_mbconv_staged_traffic(
    shape: MBConvShape, tile_h: int, mesh_shape: Tuple[int, int] = (1, 1),
    c_block: int = 128, collective: str = DEFAULT_COLLECTIVE,
    in_layout: str = DEFAULT_LAYOUT,
) -> ShardedTraffic:
    """The staged MBConv pipeline under the SAME partitioning.

    With c_mid sharded, the staged path pays the IDENTICAL collectives
    (its SE squeeze and projection also reduce over the full expanded
    width, and its projection could equally reduce-scatter) — priced
    under the SAME ``collective`` mode as the fused pipeline, so the
    fused-vs-staged margin under sharding is decided by the HBM side,
    exactly the paper's claim re-proved per partition.  ``in_layout``
    prices its entry identically too."""
    validate_layout(in_layout)
    local, (dp, mp) = mbconv_shard(shape, mesh_shape, in_layout)
    eff_layout = in_layout if mp > 1 else DEFAULT_LAYOUT
    return ShardedTraffic(
        device=mbconv_staged_traffic(local, tile_h, c_block),
        collective_words=_mbconv_collective_words(shape, dp, mp, collective),
        n_devices=dp * mp, mesh_shape=(dp, mp), collective=collective,
        in_layout=eff_layout,
        transition_words=_mbconv_entry_transition_words(
            shape, dp, mp, eff_layout))


def fusedmb_shard(
    shape: MBConvShape, mesh_shape: Tuple[int, int],
) -> Tuple[MBConvShape, Tuple[int, int]]:
    """(per-device shard shape, effective factors) for the Fused-MBConv
    partitioning: batch over "data", c_mid over "model".  c_in NEVER
    shards — the dense k x k conv contracts over all of it on every
    device, so the input must arrive replicated (the kernel rejects
    anything else)."""
    _require_no_se(shape)
    dp, mp = shard_factors(shape.b, shape.c_mid, mesh_shape)
    return replace(shape, b=shape.b // dp, c_mid=shape.c_mid // mp), (dp, mp)


def _fusedmb_collective_words(shape: MBConvShape, dp: int, mp: int,
                              collective: str) -> int:
    """Interconnect words of the sharded Fused-MBConv: ONE reduction — the
    (B_local, H', W', C_out) projection partial over the c_mid shards —
    priced per ``collective`` exactly like the MBConv projection.  No SE
    means no squeeze ring: the projection collective is the family's
    entire wire bill."""
    validate_collective(collective)
    if mp <= 1:
        return 0
    b_local = shape.b // dp
    if collective == "psum_scatter":
        return dp * (mp - 1) * (b_local * shape.out_h * shape.out_w
                                * scatter_c_out(shape.c_out, mp))
    out = b_local * shape.out_h * shape.out_w * shape.c_out
    return dp * 2 * (mp - 1) * out


def sharded_fusedmb_traffic(
    shape: MBConvShape, tile_h: int, mesh_shape: Tuple[int, int] = (1, 1),
    c_block: int = 128, residency: str = DEFAULT_RESIDENCY,
    collective: str = DEFAULT_COLLECTIVE,
    in_layout: str = DEFAULT_LAYOUT,
) -> ShardedTraffic:
    """Per-device traffic + collective bytes of the sharded single-pass
    Fused-MBConv: batch on "data", c_mid on "model", projection partial
    reduced per ``collective``.

    ``in_layout`` must be ``replicated`` — mirroring the kernel, which
    raises for a sharded arrival (the dense conv needs all of c_in).  A
    sharded producer feeding this family repays its layout at the
    BOUNDARY (``layout_transition_words``), never inside the block."""
    validate_layout(in_layout)
    if in_layout != DEFAULT_LAYOUT:
        raise ValueError(
            f"fusedmb consumes replicated arrivals only, got {in_layout!r}")
    local, (dp, mp) = fusedmb_shard(shape, mesh_shape)
    return ShardedTraffic(
        device=fusedmb_fused_traffic(local, tile_h, c_block, residency),
        collective_words=_fusedmb_collective_words(shape, dp, mp, collective),
        n_devices=dp * mp, mesh_shape=(dp, mp), collective=collective,
        in_layout=DEFAULT_LAYOUT)


def sharded_fusedmb_staged_traffic(
    shape: MBConvShape, tile_h: int, mesh_shape: Tuple[int, int] = (1, 1),
    c_block: int = 128, collective: str = DEFAULT_COLLECTIVE,
    in_layout: str = DEFAULT_LAYOUT,
) -> ShardedTraffic:
    """The staged Fused-MBConv pipeline under the SAME partitioning — its
    projection also reduces over the c_mid shards, so it pays the
    identical collective and the fused-vs-staged margin is decided by the
    HBM side, per partition."""
    validate_layout(in_layout)
    if in_layout != DEFAULT_LAYOUT:
        raise ValueError(
            f"fusedmb consumes replicated arrivals only, got {in_layout!r}")
    local, (dp, mp) = fusedmb_shard(shape, mesh_shape)
    return ShardedTraffic(
        device=fusedmb_staged_traffic(local, tile_h, c_block),
        collective_words=_fusedmb_collective_words(shape, dp, mp, collective),
        n_devices=dp * mp, mesh_shape=(dp, mp), collective=collective,
        in_layout=DEFAULT_LAYOUT)


# ---------------------------------------------------------------------------
# Cross-block pipelining: per-pass costs + overlap-aware latency
#
# Pass 2 of block i and pass 1 of block i+1 touch disjoint buffers (pass 2
# reads DW_i / scale_i and writes act_{i+1}; pass 1 of i+1 reads act_{i+1}
# strips as they land and writes DW_{i+1} / pool_{i+1}), so a block-chain
# executor can hide the consumer's pass-1 DMA behind the producer's pass-2
# compute — the staging engine's double-buffering generalized one level
# up.  A pipelined boundary then prices as max(pass2_us, pass1_us) instead
# of their sum.  The verdict is calibrated, not asserted: the pass
# latencies come from the fitted ``PerfCoefficients`` applied to the
# per-pass traffic split above.
# ---------------------------------------------------------------------------


OVERLAP_MODES: Tuple[str, ...] = ("serial", "pipelined")
DEFAULT_OVERLAP = "serial"


def validate_overlap(overlap: str) -> str:
    if overlap not in OVERLAP_MODES:
        raise ValueError(
            f"overlap must be one of {OVERLAP_MODES}, got {overlap!r}")
    return overlap


@dataclass(frozen=True)
class MBConvPassCosts:
    """The two-pass split of one sharded MBConv block's costs: per-device
    HBM traffic plus the mesh-wide collective words each pass must wait
    on.  Sums exactly to ``sharded_mbconv_traffic`` (property-tested)."""

    pass1: HBMTraffic            # one device's pass-1 (+SE MLP) traffic
    pass2: HBMTraffic            # one device's pass-2 traffic
    pass1_collective_words: int  # SE squeeze ring + any entry repay
    pass2_collective_words: int  # projection reduction (ring or scatter)

    @property
    def dtype_bytes(self) -> int:
        return self.pass1.dtype_bytes

    @property
    def pass1_collective_bytes(self) -> int:
        return self.pass1_collective_words * self.dtype_bytes

    @property
    def pass2_collective_bytes(self) -> int:
        return self.pass2_collective_words * self.dtype_bytes


def sharded_mbconv_pass_costs(
    shape: MBConvShape, tile_h: int, mode: str = "retain",
    mesh_shape: Tuple[int, int] = (1, 1), c_block: int = 128,
    residency: str = DEFAULT_RESIDENCY,
    collective: str = DEFAULT_COLLECTIVE,
    in_layout: str = DEFAULT_LAYOUT,
) -> MBConvPassCosts:
    """Per-pass split of ``sharded_mbconv_traffic`` at the same point.

    Device traffic splits via ``mbconv_pass_traffic`` on the shard shape;
    collective words split via ``_mbconv_collective_split`` (squeeze →
    pass 1, projection → pass 2).  Any entry-side layout repay gathers
    BEFORE the first strip can stream, so it lands on pass 1 — one more
    reason a boundary with transition words never pipelines.
    """
    validate_layout(in_layout)
    local, (dp, mp) = mbconv_shard(shape, mesh_shape, in_layout)
    eff_layout = in_layout if mp > 1 else DEFAULT_LAYOUT
    p1, p2 = mbconv_pass_traffic(local, tile_h, mode, c_block, residency)
    squeeze, proj = _mbconv_collective_split(shape, dp, mp, collective)
    entry = _mbconv_entry_transition_words(shape, dp, mp, eff_layout)
    return MBConvPassCosts(pass1=p1, pass2=p2,
                           pass1_collective_words=squeeze + entry,
                           pass2_collective_words=proj)


def sharded_fusedmb_pass_costs(
    shape: MBConvShape, tile_h: int,
    mesh_shape: Tuple[int, int] = (1, 1), c_block: int = 128,
    residency: str = DEFAULT_RESIDENCY,
    collective: str = DEFAULT_COLLECTIVE,
    in_layout: str = DEFAULT_LAYOUT,
) -> MBConvPassCosts:
    """Per-pass split of ``sharded_fusedmb_traffic`` at the same point:
    the whole single-pass block (HBM AND the projection collective) lands
    on pass 1, pass 2 is exactly zero.  ``boundary_overlap_us`` then
    prices a boundary BEHIND this block as serial automatically — a
    single-pass producer has no pass-2 compute for the next block's
    pass-1 DMA to hide behind, and the model must never pretend it does.
    """
    validate_layout(in_layout)
    if in_layout != DEFAULT_LAYOUT:
        raise ValueError(
            f"fusedmb consumes replicated arrivals only, got {in_layout!r}")
    local, (dp, mp) = fusedmb_shard(shape, mesh_shape)
    p1, p2 = fusedmb_pass_traffic(local, tile_h, c_block, residency)
    proj = _fusedmb_collective_words(shape, dp, mp, collective)
    return MBConvPassCosts(pass1=p1, pass2=p2,
                           pass1_collective_words=proj,
                           pass2_collective_words=0)


# ---------------------------------------------------------------------------
# Measured calibration: fitting walltime coefficients onto the byte model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PerfCoefficients:
    """Least-squares fit of measured walltime onto the modeled cost terms.

    ``walltime_us ~ base_us + us_per_mb * bytes/1e6
                  + us_per_dma_issue * dma_issues
                  + us_per_collective_mb * collective_bytes/1e6``

    The two non-byte terms are exactly the costs the byte model cannot
    see: the per-issue overhead of explicit strip DMA (the open question
    behind ``resident`` winning half the B0 table) and the latency of a
    collective word relative to an HBM word.  ``rms_us`` is the fit
    residual — report it next to the coefficients, a fit that explains
    nothing should not decide knobs.
    """

    base_us: float
    us_per_mb: float
    us_per_dma_issue: float
    us_per_collective_mb: float
    n_samples: int
    rms_us: float

    def as_dict(self) -> Dict[str, float]:
        return {
            "base_us": self.base_us,
            "us_per_mb": self.us_per_mb,
            "us_per_dma_issue": self.us_per_dma_issue,
            "us_per_collective_mb": self.us_per_collective_mb,
            "n_samples": self.n_samples,
            "rms_us": self.rms_us,
        }


def fit_perf_coefficients(samples: Iterable[dict]) -> PerfCoefficients:
    """Fit :class:`PerfCoefficients` from measured samples.

    Each sample is a dict with ``walltime_us`` and ``modeled_bytes``
    (required) plus optional ``dma_issues`` and ``collective_bytes``.
    Cost columns that are constant across the sample set are dropped
    from the regression (their coefficient is reported as 0.0 — the
    data cannot identify them), so a single-device CPU sweep with no
    collectives still yields a well-posed byte/issue fit.
    """
    import numpy as np

    rows = [(float(s["walltime_us"]), float(s["modeled_bytes"]) / 1e6,
             float(s.get("dma_issues", 0)),
             float(s.get("collective_bytes", 0)) / 1e6)
            for s in samples]
    if not rows:
        raise ValueError("fit_perf_coefficients needs at least one sample")
    y = np.array([r[0] for r in rows])
    cols = {"us_per_mb": np.array([r[1] for r in rows]),
            "us_per_dma_issue": np.array([r[2] for r in rows]),
            "us_per_collective_mb": np.array([r[3] for r in rows])}
    active = [k for k, v in cols.items() if float(v.max() - v.min()) > 0]
    design = np.column_stack(
        [np.ones(len(rows))] + [cols[k] for k in active])
    if len(rows) < design.shape[1]:
        raise ValueError(
            f"fit needs >= {design.shape[1]} samples for "
            f"{design.shape[1]} free terms, got {len(rows)}")
    coef, *_ = np.linalg.lstsq(design, y, rcond=None)
    fitted = dict.fromkeys(cols, 0.0)
    for name, value in zip(active, coef[1:]):
        fitted[name] = float(value)
    rms = float(np.sqrt(np.mean((design @ coef - y) ** 2)))
    return PerfCoefficients(
        base_us=float(coef[0]), n_samples=len(rows), rms_us=rms, **fitted)


def predict_walltime_us(coeffs: PerfCoefficients, *, modeled_bytes: float,
                        dma_issues: float = 0,
                        collective_bytes: float = 0) -> float:
    """Walltime the calibrated model expects for one cost point."""
    return (coeffs.base_us
            + coeffs.us_per_mb * modeled_bytes / 1e6
            + coeffs.us_per_dma_issue * dma_issues
            + coeffs.us_per_collective_mb * collective_bytes / 1e6)


# Fallback calibration for latency-shaped decisions (the overlap axis)
# when no fresh fit is installed: fit_perf_coefficients over the B0
# ``kernel_bench --measure --measure-scale 8 --measure-iters 1`` candidate
# sweep on this repo's CPU interpret-mode reference host (2026-08-09).
# CPU interpret walltimes swing under load (see ROADMAP PR-7 edges), so
# these decide only RELATIVE pass weights; deployments should install a
# host-local fit via ``set_perf_coefficients(fit_perf_coefficients(...))``
# — ``roofline_bench --bench`` prints one from any BENCH artifact.
DEFAULT_PERF_COEFFICIENTS = PerfCoefficients(
    base_us=-1508.24, us_per_mb=3559.22, us_per_dma_issue=68.68,
    us_per_collective_mb=0.0, n_samples=32, rms_us=4446.75)

_installed_coefficients: Optional[PerfCoefficients] = None


def set_perf_coefficients(coeffs: Optional[PerfCoefficients]) -> None:
    """Install a measured fit as the process-wide calibration (``None``
    reverts to ``DEFAULT_PERF_COEFFICIENTS``)."""
    global _installed_coefficients
    _installed_coefficients = coeffs


def get_perf_coefficients() -> PerfCoefficients:
    """The calibration latency-shaped decisions use: the installed fit
    if ``set_perf_coefficients`` provided one, else the defaults."""
    return (_installed_coefficients if _installed_coefficients is not None
            else DEFAULT_PERF_COEFFICIENTS)


def mbconv_pass_us(coeffs: PerfCoefficients, traffic: HBMTraffic,
                   collective_words: int = 0) -> float:
    """Calibrated walltime of ONE pass, floored at zero (an lstsq fit can
    go negative at tiny extrapolated points; a pass never takes negative
    time, and the floor keeps ``boundary_overlap_us`` monotone)."""
    return max(0.0, predict_walltime_us(
        coeffs, modeled_bytes=traffic.total_bytes,
        dma_issues=traffic.dma_issues,
        collective_bytes=collective_words * traffic.dtype_bytes))


def boundary_overlap_us(pass2_us: float, pass1_us: float,
                        overlap: str = DEFAULT_OVERLAP) -> float:
    """Modeled latency of one block boundary: the producer's pass-2 tail
    plus the consumer's pass-1 head when serialized, their ``max`` when
    the boundary pipelines (the consumer's pass-1 DMA streams behind the
    producer's pass-2 compute).  Both terms are >= 0, so pipelined <=
    serialized ALWAYS — the saving is ``min(pass2_us, pass1_us)``."""
    validate_overlap(overlap)
    if overlap == "pipelined":
        return max(pass2_us, pass1_us)
    return pass2_us + pass1_us


def overlap_saving_us(pass2_us: float, pass1_us: float) -> float:
    """Latency a pipelined boundary hides vs serialized: min of the two
    overlapped terms (sum - max)."""
    return min(pass2_us, pass1_us)

"""``BENCH_<host>.json`` perf-trajectory artifacts: schema, IO, and the
PR-over-PR differ.

One artifact is one measured run of the B0 bench (``kernel_bench
--measure``): per-layer wall time, the modeled bytes the schedule was
solved from, the solver's chosen schedule axes, and the host fingerprint
the numbers were taken on.  CI uploads the artifact and diffs it against
the committed baseline so a perf regression surfaces as a number in a
failing step, not a vibe in a review comment.

What the differ gates on is deliberately split by determinism:

* **Deterministic fields** — record coverage, modeled bytes, solver axes,
  and the bench config they were produced under — must match (bytes may
  only grow within ``bytes_tol``).  These are pure functions of the model
  and solver, so ANY host can regress them and the diff fails loudly.
* **Wall times** are compared, but only ENFORCED when the two artifacts'
  host fingerprints are comparable (same node/machine/backend/jax) or the
  caller passes ``enforce_walltime`` — a CI runner's clock is not a
  laptop's, and a gate that cries wolf teaches people to delete it.

The per-record ``candidates`` list (one entry per (schedule-axes) point
measured) additionally feeds ``rank_agreement``: the
modeled-vs-measured ordering check ``roofline_bench`` reports per axis.

CLI (the CI diff step):

    PYTHONPATH=src python -m repro.core.trajectory diff OLD NEW \
        [--walltime-tol 0.5] [--allow-axis-changes] [--enforce-walltime]
"""

from __future__ import annotations

import itertools
import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from .telemetry import host_fingerprint, host_slug

__all__ = [
    "BENCH_KIND",
    "BENCH_VERSION",
    "BenchDiff",
    "bench_filename",
    "diff_bench",
    "load_bench",
    "rank_agreement",
    "validate_bench",
    "write_bench",
]

BENCH_VERSION = 1
BENCH_KIND = "convdk-bench-trajectory"

# record keys every BENCH entry must carry (the differ's contract)
_RECORD_REQUIRED = ("name", "shape", "axes", "modeled_bytes", "walltime_us")

# host-fingerprint fields that must agree for wall times to be comparable
_HOST_COMPARABLE = ("node", "machine", "system", "backend", "jax")

# config fields that change what the deterministic record fields MEAN —
# artifacts produced under different values are not diffable
_CONFIG_IDENTITY = ("scale", "mesh", "batch", "dtype_bytes")


def bench_filename(fingerprint: Optional[dict] = None) -> str:
    return f"BENCH_{host_slug(fingerprint)}.json"


def validate_bench(payload: dict) -> dict:
    """Schema check; returns the payload or raises ``ValueError``."""
    if not isinstance(payload, dict):
        raise ValueError("BENCH payload must be a JSON object")
    if payload.get("version") != BENCH_VERSION:
        raise ValueError(
            f"BENCH version must be {BENCH_VERSION}, "
            f"got {payload.get('version')!r}")
    if payload.get("kind") != BENCH_KIND:
        raise ValueError(f"BENCH kind must be {BENCH_KIND!r}, "
                         f"got {payload.get('kind')!r}")
    records = payload.get("records")
    if not isinstance(records, list) or not records:
        raise ValueError("BENCH needs a non-empty records list")
    seen = set()
    for rec in records:
        if not isinstance(rec, dict):
            raise ValueError(f"BENCH record must be an object, got {rec!r}")
        missing = [k for k in _RECORD_REQUIRED if k not in rec]
        if missing:
            raise ValueError(
                f"BENCH record {rec.get('name')!r} missing {missing}")
        if rec["name"] in seen:
            raise ValueError(f"duplicate BENCH record {rec['name']!r}")
        seen.add(rec["name"])
    if not isinstance(payload.get("host"), dict):
        raise ValueError("BENCH needs a host fingerprint object")
    return payload


def write_bench(out: Path | str, records: Sequence[dict], *,
                config: Optional[dict] = None,
                counters: Optional[dict] = None,
                knobs: Optional[dict] = None,
                fingerprint: Optional[dict] = None) -> Path:
    """Write one BENCH artifact.  ``out`` may be a directory (the file is
    named ``BENCH_<host>.json`` inside it) or an explicit file path."""
    fp = fingerprint or host_fingerprint()
    payload = validate_bench({
        "version": BENCH_VERSION,
        "kind": BENCH_KIND,
        "created_at": time.time(),
        "host": fp,
        "config": dict(config or {}),
        "records": list(records),
        "counters": dict(counters or {}),
        "knobs": dict(knobs or {}),
    })
    out = Path(out)
    path = out / bench_filename(fp) if out.suffix != ".json" else out
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(payload, indent=1, sort_keys=True))
    tmp.replace(path)
    return path


def load_bench(path: Path | str) -> dict:
    return validate_bench(json.loads(Path(path).read_text()))


# ---------------------------------------------------------------------------
# the PR-over-PR differ
# ---------------------------------------------------------------------------


@dataclass
class BenchDiff:
    """Outcome of diffing two BENCH artifacts.

    ``failures`` are gate-worthy regressions (each one a complete,
    number-carrying sentence); ``notes`` are informational deltas.  The
    diff is green iff ``ok``."""

    failures: List[str] = field(default_factory=list)
    notes: List[str] = field(default_factory=list)
    hosts_comparable: bool = False
    walltime_enforced: bool = False

    @property
    def ok(self) -> bool:
        return not self.failures

    def format(self) -> str:
        lines = []
        status = "OK" if self.ok else "REGRESSED"
        wt = ("enforced" if self.walltime_enforced
              else "informational (hosts differ)")
        lines.append(f"# trajectory diff: {status} (walltime gate: {wt})")
        for msg in self.failures:
            lines.append(f"FAIL {msg}")
        for msg in self.notes:
            lines.append(f"note {msg}")
        return "\n".join(lines)


def _hosts_comparable(old: dict, new: dict) -> bool:
    oh, nh = old.get("host", {}), new.get("host", {})
    return all(oh.get(k) == nh.get(k) for k in _HOST_COMPARABLE)


def diff_bench(old: dict, new: dict, *, walltime_tol: float = 0.5,
               bytes_tol: float = 0.0, allow_axis_changes: bool = False,
               enforce_walltime: Optional[bool] = None) -> BenchDiff:
    """Diff two validated BENCH payloads, ``old`` the baseline.

    Gates: identical bench config, full record coverage, modeled bytes
    within ``bytes_tol`` (relative), unchanged solver axes (unless
    ``allow_axis_changes``), and — when enforced (see module doc) — wall
    time within ``walltime_tol`` (relative slowdown of the per-record
    best time)."""
    validate_bench(old)
    validate_bench(new)
    diff = BenchDiff(hosts_comparable=_hosts_comparable(old, new))
    diff.walltime_enforced = (diff.hosts_comparable
                              if enforce_walltime is None
                              else enforce_walltime)

    oc, nc = old.get("config", {}), new.get("config", {})
    for key in _CONFIG_IDENTITY:
        if oc.get(key) != nc.get(key):
            diff.failures.append(
                f"config.{key} differs (baseline {oc.get(key)!r} vs "
                f"{nc.get(key)!r}): artifacts are not comparable — "
                f"regenerate the baseline with the current bench config")
    if diff.failures:
        return diff

    old_recs = {r["name"]: r for r in old["records"]}
    new_recs = {r["name"]: r for r in new["records"]}
    for name in old_recs:
        if name not in new_recs:
            diff.failures.append(
                f"{name}: record disappeared from the bench "
                f"(baseline covered it)")
    for name in new_recs:
        if name not in old_recs:
            diff.notes.append(f"{name}: new record (not in baseline)")

    for name, orec in old_recs.items():
        nrec = new_recs.get(name)
        if nrec is None:
            continue
        # deterministic byte fields: always the whole-block bytes, plus
        # the two-pass split when BOTH artifacts carry it (the pipeline
        # model's inputs — older baselines without the split stay valid)
        byte_fields = {"modeled_bytes": "modeled bytes"}
        for f, lbl in (("modeled_pass1_bytes", "modeled pass-1 bytes"),
                       ("modeled_pass2_bytes", "modeled pass-2 bytes")):
            if f in orec and f in nrec:
                byte_fields[f] = lbl
        for field, label in byte_fields.items():
            ob, nb = orec[field], nrec[field]
            if nb > ob * (1 + bytes_tol):
                diff.failures.append(
                    f"{name}: {label} regressed {ob} -> {nb} "
                    f"(+{100 * (nb - ob) / max(ob, 1):.1f}% > tol "
                    f"{100 * bytes_tol:.1f}%)")
            elif nb < ob:
                diff.notes.append(
                    f"{name}: {label} improved {ob} -> {nb} "
                    f"({100 * (ob - nb) / max(ob, 1):.1f}% less)")
        if orec["axes"] != nrec["axes"]:
            msg = (f"{name}: solver axes changed {orec['axes']} -> "
                   f"{nrec['axes']}")
            if allow_axis_changes:
                diff.notes.append(msg)
            else:
                diff.failures.append(
                    msg + " (pass --allow-axis-changes and refresh the "
                          "baseline if intentional)")
        ow, nw = orec["walltime_us"], nrec["walltime_us"]
        if ow > 0 and nw > ow * (1 + walltime_tol):
            msg = (f"{name}: walltime {ow:.1f}us -> {nw:.1f}us "
                   f"(+{100 * (nw - ow) / ow:.1f}% > tol "
                   f"{100 * walltime_tol:.0f}%)")
            if diff.walltime_enforced:
                diff.failures.append(msg)
            else:
                diff.notes.append(msg + " [hosts differ: not gated]")
        elif ow > 0 and nw < ow / (1 + walltime_tol):
            diff.notes.append(
                f"{name}: walltime improved {ow:.1f}us -> {nw:.1f}us")
    return diff


# ---------------------------------------------------------------------------
# modeled-vs-measured rank agreement (per schedule axis)
# ---------------------------------------------------------------------------


def rank_agreement(records: Sequence[dict], axis: str) -> Optional[dict]:
    """Does the byte model ORDER candidates the way the stopwatch does?

    Over every record's ``candidates`` list, take each pair that differs
    ONLY in ``axis`` (all other axes equal — a controlled comparison),
    and check whether the modeled-bytes ordering matches the measured
    walltime ordering.  Returns ``{"pairs", "agree", "model_ties",
    "agreement"}`` (agreement over non-tied pairs) or None when no
    record measured two points along the axis."""
    agree = disagree = model_ties = 0
    for rec in records:
        cands = [c for c in rec.get("candidates", ())
                 if axis in c.get("axes", {})]
        key = lambda c: tuple(sorted(  # noqa: E731
            (k, v) for k, v in c["axes"].items() if k != axis))
        by_rest: Dict[tuple, list] = {}
        for c in cands:
            by_rest.setdefault(key(c), []).append(c)
        for group in by_rest.values():
            for a, b in itertools.combinations(group, 2):
                if a["axes"][axis] == b["axes"][axis]:
                    continue
                db = a["modeled_bytes"] - b["modeled_bytes"]
                dt = a["walltime_us"] - b["walltime_us"]
                if db == 0:
                    model_ties += 1
                elif (db > 0) == (dt > 0):
                    agree += 1
                else:
                    disagree += 1
    pairs = agree + disagree + model_ties
    if pairs == 0:
        return None
    decided = agree + disagree
    return {
        "axis": axis,
        "pairs": pairs,
        "agree": agree,
        "model_ties": model_ties,
        "agreement": agree / decided if decided else None,
    }


# ---------------------------------------------------------------------------
# CLI: the CI diff step
# ---------------------------------------------------------------------------


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.trajectory",
        description="diff two BENCH_<host>.json perf-trajectory artifacts")
    sub = ap.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("diff", help="baseline-vs-current trajectory diff")
    d.add_argument("baseline", help="committed baseline BENCH json")
    d.add_argument("current", help="freshly measured BENCH json")
    d.add_argument("--walltime-tol", type=float, default=0.5,
                   help="relative walltime slowdown tolerated (default 0.5)")
    d.add_argument("--bytes-tol", type=float, default=0.0,
                   help="relative modeled-bytes growth tolerated (default 0)")
    d.add_argument("--allow-axis-changes", action="store_true",
                   help="demote solver-axis flips from failures to notes")
    d.add_argument("--enforce-walltime", action="store_true",
                   help="gate walltime even across differing hosts")
    args = ap.parse_args(argv)

    diff = diff_bench(
        load_bench(args.baseline), load_bench(args.current),
        walltime_tol=args.walltime_tol, bytes_tol=args.bytes_tol,
        allow_axis_changes=args.allow_axis_changes,
        enforce_walltime=args.enforce_walltime or None)
    print(diff.format())
    return 0 if diff.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

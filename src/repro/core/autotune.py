"""Per-layer schedule selection for the fused ConvDK kernels.

MIREDO-style per-layer solving: instead of one fixed ``tile_h`` for every
block, each layer shape gets its own fused schedule, chosen by the
analytical HBM traffic model in ``core.perfmodel`` (primary) with an
optional measured fallback sweep (ground truth when the model cannot
separate candidates, or when a deployment wants real timings).  Two block
families are solved:

* separable (``FusedSchedule``): DW + PW in one pass — pick ``tile_h`` AND
  the input **residency** ("resident" | "strip_dma" | "strip_dma_db", the
  staging-engine axis: VMEM feasibility counts the slot buffers — 2x strip
  scratch for double-buffering — and the traffic model prices each mode);
* MBConv (``MBConvSchedule``): expand + DW + SE + PW in two passes — pick
  ``tile_h``, the residency, the pass-2 ``mode`` ("retain" writes the
  DW tensor to HBM once and re-reads it; "recompute" re-runs expand+DW
  from the input strips; the traffic model prices the crossover per layer
  shape), AND — under a model-sharded mesh — the ``collective`` axis
  ("ring_allreduce" | "psum_scatter": how the pass-2 projection partial
  is reduced across the model groups; scatter halves the wire words and
  leaves the output sharded on c_out).

Every schedule carries the ``perfmodel.ShardedTraffic`` pair it was
solved from and DELEGATES all byte totals to it (``_ScheduleTraffic``):
the solver optimizes exactly the bytes the model prices — there is no
second accounting to drift.

Schedule solving is trace-time work and must never re-run inside a jitted
step, so selections are cached.  The cache has two layers:

1. an in-process dict (always on), and
2. an optional JSON file under a configurable cache directory, keyed by
   (kernel kind, layer shape, dtype bytes, jax backend) — measured sweeps
   and model picks survive restarts and can ship as a lookup table.
   Enable it with ``set_schedule_cache_dir(path)`` or the
   ``CONVDK_CACHE_DIR`` environment variable; entries recorded from a
   measured sweep (``source == "measured"``) take priority over model
   picks for the same key.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple

from .perfmodel import (
    COLLECTIVE_MODES,
    DEFAULT_COLLECTIVE,
    DEFAULT_RESIDENCY,
    MBCONV_MODES,
    RESIDENCY_MODES,
    HBMTraffic,
    MBConvShape,
    SeparableShape,
    ShardedTraffic,
    can_psum_scatter,
    mbconv_shard,
    mbconv_staging_bytes,
    pick_channel_block,
    separable_shard,
    separable_staging_bytes,
    shard_factors,
    sharded_mbconv_staged_traffic,
    sharded_mbconv_traffic,
    sharded_separable_staged_traffic,
    sharded_separable_traffic,
    validate_collective,
    validate_residency,
)

MeshShape = Tuple[int, int]   # ("data", "model") axis sizes, (1, 1) = 1 core

# Solver preference among byte-identical residencies: double-buffering hides
# the strip DMA behind compute at 2x scratch, single-slot DMA is the
# VMEM-tight fallback, and full-height residency is the last resort (its
# traffic collapses only for single-channel-block layers that fit VMEM).
_RESIDENCY_RANK = {"strip_dma_db": 0, "strip_dma": 1, "resident": 2}


@dataclass(frozen=True)
class TPUConfig:
    """Budget knobs for fused-schedule selection on one core."""

    vmem_bytes: int = 16 * 1024 * 1024   # per-core VMEM budget
    c_block: int = 128                   # lane width
    tile_h_candidates: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)


class _ScheduleTraffic:
    """Accounting VIEW shared by both schedule families.

    A schedule carries the two ``perfmodel.ShardedTraffic`` objects it was
    solved from — ``sharded`` (the fused pipeline) and ``staged`` (the
    identically partitioned staged baseline) — and every byte total here
    DELEGATES to them.  ``perfmodel`` is the single pricing authority for
    device bytes, collective bytes and DMA issues; the solver never
    re-derives a mesh-wide total, so the bytes the autotuner optimizes
    are — identically, not approximately — the bytes the traffic model
    prices (the anti-divergence property in tests/test_perfmodel_bands.py
    pins this down).  For the default ``mesh_shape == (1, 1)`` the device
    traffic is the whole layer (the PR-1 semantics, unchanged).  The
    staged baseline pays the SAME collective words (its reductions over
    the sharded channel axis are the same collectives, priced under the
    same ``collective`` mode), so the fused-vs-staged margin stays an
    HBM-side comparison."""

    @property
    def traffic(self) -> HBMTraffic:
        """PER-DEVICE fused HBM traffic (one shard of the launch)."""
        return self.sharded.device

    @property
    def staged_traffic(self) -> HBMTraffic:
        """PER-DEVICE staged-baseline HBM traffic."""
        return self.staged.device

    @property
    def mesh_shape(self) -> Tuple[int, int]:
        return self.sharded.mesh_shape

    @property
    def n_devices(self) -> int:
        return self.sharded.n_devices

    @property
    def collective(self) -> str:
        """The reduction layout the collectives were priced under."""
        return self.sharded.collective

    @property
    def collective_words(self) -> int:
        return self.sharded.collective_words

    @property
    def collective_bytes(self) -> int:
        return self.sharded.collective_bytes

    @property
    def total_bytes(self) -> int:
        """All bytes moved anywhere (every device's HBM + collectives) —
        ``perfmodel.ShardedTraffic.total_bytes``, verbatim."""
        return self.sharded.total_bytes

    @property
    def staged_total_bytes(self) -> int:
        return self.staged.total_bytes

    @property
    def modeled_saving(self) -> float:
        """Fraction of staged bytes the fused schedule avoids."""
        base = self.staged.total_bytes
        return 1.0 - self.sharded.total_bytes / base if base else 0.0


@dataclass(frozen=True)
class FusedSchedule(_ScheduleTraffic):
    """One selected schedule for ``convdk_fused_separable``.

    The separable partitioning (c_out on "model") is collective-free, so
    its ``ShardedTraffic`` always has 0 collective words — the accounting
    view exists for symmetry with ``MBConvSchedule`` (doc on
    ``_ScheduleTraffic``)."""

    tile_h: int
    ci_block: int
    co_block: int
    sharded: ShardedTraffic      # fused pricing (the solver's objective)
    staged: ShardedTraffic       # identically partitioned staged baseline
    residency: str = DEFAULT_RESIDENCY   # input-staging mode


@dataclass(frozen=True)
class MBConvSchedule(_ScheduleTraffic):
    """One selected two-pass schedule for ``convdk_mbconv_fused``.

    Under a mesh the c_mid partitioning pays two cross-device reductions
    (SE squeeze + projection partials) priced inside ``sharded`` /
    ``staged`` under the schedule's **collective** axis — ring all-reduce
    or the psum_scatter pass-2 variant whose output leaves the kernel
    sharded on c_out (doc on ``_ScheduleTraffic``; ``self.collective``
    reads the solved mode)."""

    tile_h: int
    mode: str                    # "retain" | "recompute"
    ci_block: int
    cm_block: int
    co_block: int
    sharded: ShardedTraffic      # fused pricing (the solver's objective)
    staged: ShardedTraffic       # identically partitioned staged baseline
    residency: str = DEFAULT_RESIDENCY   # input-staging mode


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _blocks(c: int, cap: int) -> int:
    return min(cap, _round_up(c, 8))


# ---------------------------------------------------------------------------
# persistent schedule cache
# ---------------------------------------------------------------------------

_CACHE_DIR_ENV = "CONVDK_CACHE_DIR"
_CACHE_FILE = "convdk_schedules.json"


def _backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:  # pragma: no cover - jax always importable here
        return "unknown"


class ScheduleCache:
    """Two-layer schedule cache: in-process dict + optional JSON file.

    Disk entries store only the *decision* (tile_h, mode, source); traffic
    numbers are deterministic functions of the shape and are rebuilt by the
    model on load, so the file format survives model refinements.
    """

    def __init__(self, directory: Optional[Path]):
        self.directory = Path(directory).expanduser() if directory else None
        self._mem: Dict[str, dict] = {}
        self._disk: Optional[Dict[str, dict]] = None   # lazily loaded

    @property
    def path(self) -> Optional[Path]:
        return self.directory / _CACHE_FILE if self.directory else None

    @staticmethod
    def _migrate_key(key: str) -> str:
        """Upgrade legacy cache keys in place, chaining the three schema
        migrations so measured sweeps keep outranking model picks instead
        of being silently orphaned:

        * pre-mesh entries (5 segments, no ``mesh`` segment) were all
          solved single-device — they ARE the ``mesh1x1`` picks;
        * pre-residency entries (no ``res=`` segment) were solved before
          residency was a pinnable axis — they ARE the ``res=auto`` picks
          (the solver now chooses the residency; a legacy measured tile_h
          keeps its priority and the residency is re-solved at that
          tile_h, see ``get_fused_schedule``);
        * pre-collective MBConv entries (no ``coll=`` segment) were
          solved before the projection-reduction layout was an axis —
          they ARE the ``coll=auto`` picks (the collective is re-solved
          at the entry's (tile_h, mode, residency); separable keys never
          grow the segment — that partitioning is collective-free)."""
        parts = key.split("|")
        if len(parts) == 5 and parts[0] in ("sep", "mbconv") \
                and not parts[3].startswith("mesh"):
            parts.insert(3, "mesh1x1")
        if len(parts) == 6 and parts[0] in ("sep", "mbconv") \
                and parts[3].startswith("mesh") \
                and not parts[4].startswith("res="):
            parts.insert(4, "res=auto")
        if len(parts) >= 7 and parts[0] == "mbconv" \
                and parts[3].startswith("mesh") \
                and parts[4].startswith("res=") \
                and not parts[5].startswith("coll="):
            parts.insert(5, "coll=auto")
        return "|".join(parts)

    def _load_disk(self) -> Dict[str, dict]:
        if self._disk is None:
            self._disk = {}
            if self.path is not None:
                try:
                    payload = json.loads(self.path.read_text())
                    if payload.get("version") == 1:
                        self._disk = {
                            self._migrate_key(k): v
                            for k, v in payload.get("entries", {}).items()}
                except (OSError, ValueError):
                    pass                   # unreadable cache = empty cache
        return self._disk

    def _flush(self) -> None:
        if self.path is None:
            return
        try:
            self.directory.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(".tmp")
            tmp.write_text(json.dumps(
                {"version": 1, "entries": self._load_disk()},
                indent=1, sort_keys=True))
            tmp.replace(self.path)
        except OSError:
            pass                           # persistence is best-effort

    def get(self, key: str) -> Optional[dict]:
        hit = self._mem.get(key)
        if hit is not None:
            return hit
        hit = self._load_disk().get(key)
        if hit is not None:
            self._mem[key] = hit
        return hit

    def put(self, key: str, entry: dict, persist: bool = True) -> None:
        self._mem[key] = entry
        if persist and self.path is not None:
            disk = self._load_disk()
            # never let a model pick clobber a measured entry (malformed
            # old entries — non-dicts — are overwritten, not honored)
            old = disk.get(key)
            if isinstance(old, dict) and old.get("source") == "measured" \
                    and entry.get("source") != "measured":
                return
            disk[key] = entry
            self._flush()

    def clear_memory(self) -> None:
        """Drop the in-process layer (tests: force a disk round-trip)."""
        self._mem.clear()
        self._disk = None


_SCHEDULE_CACHE: Optional[ScheduleCache] = None


def get_schedule_cache() -> ScheduleCache:
    global _SCHEDULE_CACHE
    if _SCHEDULE_CACHE is None:
        env = os.environ.get(_CACHE_DIR_ENV)
        _SCHEDULE_CACHE = ScheduleCache(Path(env) if env else None)
    return _SCHEDULE_CACHE


def set_schedule_cache_dir(directory: Optional[os.PathLike]) -> ScheduleCache:
    """Point the persistent schedule cache at ``directory`` (None = memory
    only).  Resets the in-process layer so the new directory is
    authoritative."""
    global _SCHEDULE_CACHE
    _SCHEDULE_CACHE = ScheduleCache(
        Path(directory) if directory is not None else None)
    return _SCHEDULE_CACHE


def _tpu_key(tpu: TPUConfig) -> str:
    """Every TPUConfig field enters the key: a schedule solved (and
    VMEM-checked) under one config must never be reused for another."""
    ths = "x".join(str(t) for t in tpu.tile_h_candidates)
    return f"vmem{tpu.vmem_bytes}-cb{tpu.c_block}-th{ths}"


def _res_segment(residency: Optional[str]) -> str:
    """Key segment for the REQUESTED residency: a pinned mode gets its own
    entry (its pick is solved under a different feasibility set); ``None``
    (the solver chooses) is the ``res=auto`` entry that legacy keys migrate
    into."""
    if residency is not None:
        validate_residency(residency)
    return f"res={residency or 'auto'}"


def _sep_key(shape: SeparableShape, tpu: TPUConfig,
             mesh_shape: MeshShape = (1, 1),
             residency: Optional[str] = None) -> str:
    """Schedule-cache key.  The EFFECTIVE mesh factors are part of the key:
    a schedule solved for one partitioning (per-device shard shapes, psum
    terms, VMEM headroom) must never be echoed for another — sharded and
    unsharded picks live in distinct entries.  Likewise the requested
    residency (``res=auto`` when the solver chooses)."""
    dp, mp = shard_factors(shape.b, shape.c_out, mesh_shape)
    return (f"sep|b{shape.b}-h{shape.h}-w{shape.w}-ci{shape.c_in}"
            f"-co{shape.c_out}-k{shape.k}-s{shape.s}|dtb{shape.dtype_bytes}"
            f"|mesh{dp}x{mp}|{_res_segment(residency)}|{_tpu_key(tpu)}"
            f"|{_backend()}")


def _coll_segment(collective: Optional[str]) -> str:
    """Key segment for the REQUESTED collective mode (``coll=auto`` when
    the solver chooses — the segment legacy MBConv keys migrate into)."""
    if collective is not None:
        validate_collective(collective)
    return f"coll={collective or 'auto'}"


def _mbconv_key(shape: MBConvShape, tpu: TPUConfig,
                mesh_shape: MeshShape = (1, 1),
                residency: Optional[str] = None,
                mode: Optional[str] = None,
                collective: Optional[str] = None) -> str:
    dp, mp = shard_factors(shape.b, shape.c_mid, mesh_shape)
    # a pinned pass-2 mode gets its OWN entries (appended segment, so the
    # unpinned key format — and its migration chain — is untouched): a
    # tile_h/residency solved under one mode's VMEM footprint must never
    # be echoed for the other
    pin = f"|mode={mode}" if mode is not None else ""
    return (f"mbconv|b{shape.b}-h{shape.h}-w{shape.w}-ci{shape.c_in}"
            f"-cm{shape.c_mid}-co{shape.c_out}-k{shape.k}-s{shape.s}"
            f"|dtb{shape.dtype_bytes}|mesh{dp}x{mp}"
            f"|{_res_segment(residency)}|{_coll_segment(collective)}"
            f"|{_tpu_key(tpu)}|{_backend()}{pin}")


def _entry_tile_h(hit, out_h: int):
    """Validated tile_h from a cache entry, or None if the entry is
    malformed or stale (a bad cache file must degrade to the model, never
    crash schedule lookup)."""
    try:
        tile_h = int(hit["tile_h"])
    except (TypeError, KeyError, ValueError):
        return None
    return tile_h if 1 <= tile_h <= out_h else None


def _entry_residency(hit) -> Optional[str]:
    """Validated residency from a cache entry; None for legacy entries
    (recorded before the residency axis) or malformed values — the caller
    then re-solves the residency at the entry's tile_h."""
    res = hit.get("residency") if isinstance(hit, dict) else None
    return res if res in RESIDENCY_MODES else None


def _entry_collective(hit) -> Optional[str]:
    """Validated collective mode from a cache entry; None for legacy
    entries (recorded before the collective axis) or malformed values —
    the caller then re-solves the collective at the entry's pick."""
    coll = hit.get("collective") if isinstance(hit, dict) else None
    return coll if coll in COLLECTIVE_MODES else None


# Solver preference among byte-identical collective modes: the ring
# all-reduce is the conservative default (output replicated, any consumer
# layout); ties essentially never occur — psum_scatter strictly undercuts
# the ring whenever the projection payload is nonzero.
_COLLECTIVE_RANK = {"ring_allreduce": 0, "psum_scatter": 1}


def _collective_set(shape: MBConvShape, eff: MeshShape,
                    collective: Optional[str]) -> Tuple[str, ...]:
    """Collective modes the solver may price at this partitioning.

    Off-mesh (effective model factor 1) the axis is degenerate: nothing
    crosses devices, so everything normalizes to the ring default — a
    scatter pin is meaningless there and is ignored rather than cached as
    a distinct non-schedule.  On-mesh, ``None`` enumerates the ring plus
    (where ``c_out`` divides the model groups) the psum_scatter pass-2
    variant; a pin restricts to that mode, raising when the pinned
    scatter is not runnable — the solver must never describe a layout the
    kernels will reject."""
    _dp, mp = eff
    if mp <= 1:
        return (DEFAULT_COLLECTIVE,)
    if collective is None:
        if can_psum_scatter(shape, eff):
            return COLLECTIVE_MODES
        return (DEFAULT_COLLECTIVE,)
    validate_collective(collective)
    if collective == "psum_scatter" and not can_psum_scatter(shape, eff):
        raise ValueError(
            f"psum_scatter pinned but c_out={shape.c_out} does not divide "
            f"over model={mp}")
    return (collective,)


# ---------------------------------------------------------------------------
# separable (single-pass) schedules
# ---------------------------------------------------------------------------

def vmem_footprint_bytes(shape: SeparableShape, tile_h: int,
                         tpu: TPUConfig,
                         residency: str = DEFAULT_RESIDENCY) -> int:
    """Modeled VMEM residency of one fused grid cell under one residency.

    Counts the input staging (the strip-DMA slot buffer(s) — 2x for
    double-buffering — or the full-height resident block), the f32 DW
    accumulator, the f32 PW scratch accumulator and both weight blocks:
    the budget the staging engine's rendering of the kernel must respect.
    """
    ci = pick_channel_block(shape.c_in, tpu.c_block)
    co = _blocks(shape.c_out, tpu.c_block)
    tile_h = max(1, min(tile_h, shape.out_h))
    x_win = separable_staging_bytes(shape, tile_h, residency, tpu.c_block)
    dw_acc = tile_h * shape.out_w * ci * 4
    pw_acc = tile_h * shape.out_w * co * 4
    weights = (shape.k * shape.k * ci + ci * co) * shape.dtype_bytes
    return x_win + dw_acc + pw_acc + weights


def _residency_set(residency: Optional[str]) -> Tuple[str, ...]:
    if residency is None:
        return RESIDENCY_MODES
    validate_residency(residency)
    return (residency,)


def candidate_schedules(
    shape: SeparableShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
) -> Tuple[FusedSchedule, ...]:
    """All VMEM-feasible (tile_h, residency) schedules, model-priced.

    ``residency=None`` enumerates every staging mode (the solver's
    default); a pinned mode restricts the candidate set.  Under a mesh,
    feasibility and channel blocks are solved at the PER-DEVICE shard
    shape (batch/data, c_out/model) — a shard has more VMEM headroom per
    channel block than the whole layer."""
    local, eff = separable_shard(shape, mesh_shape)
    ci = pick_channel_block(local.c_in, tpu.c_block)
    co = _blocks(local.c_out, tpu.c_block)
    out: list[FusedSchedule] = []
    seen = set()
    ths = [max(1, min(th, shape.out_h)) for th in tpu.tile_h_candidates]
    feasible = [(th, res) for th in ths for res in _residency_set(residency)
                if vmem_footprint_bytes(local, th, tpu, res)
                <= tpu.vmem_bytes]
    for th, res in feasible or [(1, residency or "strip_dma")]:
        if (th, res) in seen:
            continue
        seen.add((th, res))
        out.append(FusedSchedule(
            tile_h=th, ci_block=ci, co_block=co,
            sharded=sharded_separable_traffic(shape, th, eff, tpu.c_block,
                                              res),
            staged=sharded_separable_staged_traffic(shape, th, eff,
                                                    tpu.c_block),
            residency=res,
        ))
    return tuple(out)


def select_fused_schedule(
    shape: SeparableShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
) -> FusedSchedule:
    """Pick the (tile_h, residency) minimizing modeled total traffic —
    per-device HBM bytes across all devices plus collectives (ties ->
    larger tile_h: fewer grid cells, bigger MXU contractions; then the
    residency rank: double-buffered DMA > single-slot DMA > resident,
    since equal bytes moved earlier hide latency)."""
    cands = candidate_schedules(shape, tpu, mesh_shape, residency)
    return min(cands, key=lambda c: (c.total_bytes, -c.tile_h,
                                     _RESIDENCY_RANK[c.residency]))


def _schedule_at(shape: SeparableShape, tile_h: int, tpu: TPUConfig,
                 mesh_shape: MeshShape = (1, 1),
                 residency: str = DEFAULT_RESIDENCY) -> FusedSchedule:
    local, eff = separable_shard(shape, mesh_shape)
    return FusedSchedule(
        tile_h=tile_h,
        ci_block=pick_channel_block(local.c_in, tpu.c_block),
        co_block=_blocks(local.c_out, tpu.c_block),
        sharded=sharded_separable_traffic(shape, tile_h, eff, tpu.c_block,
                                          residency),
        staged=sharded_separable_staged_traffic(shape, tile_h, eff,
                                                tpu.c_block),
        residency=residency,
    )


def _solve_residency_at(shape: SeparableShape, tile_h: int, tpu: TPUConfig,
                        mesh_shape: MeshShape) -> str:
    """Best residency at a FIXED tile_h (legacy cache entries pin tile_h
    but predate the residency axis): min bytes among VMEM-feasible modes,
    ties broken by the residency rank."""
    local, eff = separable_shard(shape, mesh_shape)
    modes = [res for res in RESIDENCY_MODES
             if vmem_footprint_bytes(local, tile_h, tpu, res)
             <= tpu.vmem_bytes] or ["strip_dma"]
    return min(modes, key=lambda res: (
        sharded_separable_traffic(shape, tile_h, eff, tpu.c_block,
                                  res).device.total_bytes,
        _RESIDENCY_RANK[res]))


def get_fused_schedule(
    b: int, h: int, w: int, c_in: int, c_out: int, k: int, s: int,
    dtype_bytes: int = 4, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
) -> FusedSchedule:
    """Cached per-layer-shape schedule lookup (trace-time safe).

    Consults the in-process cache, then the JSON cache (where a measured
    sweep may have recorded ground truth), then the analytical model.
    ``mesh_shape`` is the ("data", "model") partitioning the schedule will
    run under and ``residency`` the requested staging pin (None = solver's
    choice) — both are cache-key axes, so different partitionings or pins
    never collide.  Legacy entries (pre-residency) keep their tile_h
    priority; the residency is re-solved at that tile_h."""
    shape = SeparableShape(b=b, h=h, w=w, c_in=c_in, c_out=c_out, k=k, s=s,
                           dtype_bytes=dtype_bytes)
    cache = get_schedule_cache()
    key = _sep_key(shape, tpu, mesh_shape, residency)
    hit = cache.get(key)
    tile_h = _entry_tile_h(hit, shape.out_h) if hit is not None else None
    if tile_h is not None:
        res = residency or _entry_residency(hit) \
            or _solve_residency_at(shape, tile_h, tpu, mesh_shape)
        return _schedule_at(shape, tile_h, tpu, mesh_shape, res)
    sched = select_fused_schedule(shape, tpu, mesh_shape, residency)
    cache.put(key, {"tile_h": sched.tile_h, "residency": sched.residency,
                    "source": "model", "recorded_at": time.time()})
    return sched


# ---------------------------------------------------------------------------
# MBConv (two-pass) schedules
# ---------------------------------------------------------------------------

def mbconv_vmem_footprint_bytes(shape: MBConvShape, tile_h: int,
                                tpu: TPUConfig,
                                residency: str = DEFAULT_RESIDENCY,
                                mode: str = "retain") -> int:
    """Modeled VMEM residency of one two-pass MBConv grid cell.

    The dominant terms are the input staging (slot buffers or the resident
    block; ``retain`` adds the pass-2 DW re-read stream) and the f32
    expand accumulator over the staged strip window at ``cm_block`` lanes
    (pass 1 and recompute pass 2 share it); pass 2 adds the f32 projection
    accumulator.  Summing both passes' terms is deliberately conservative
    — the launches are separate, but a schedule that only fits one of them
    is not worth distinguishing."""
    ci = pick_channel_block(shape.c_in, tpu.c_block)
    cm = pick_channel_block(shape.c_mid, tpu.c_block)
    co = _blocks(shape.c_out, tpu.c_block)
    tile_h = max(1, min(tile_h, shape.out_h))
    in_rows = (tile_h - 1) * shape.s + shape.k
    w_need = (shape.out_w - 1) * shape.s + shape.k
    staging = mbconv_staging_bytes(shape, tile_h, mode, residency,
                                   tpu.c_block)
    exp_acc = in_rows * w_need * cm * 4
    dw_blk = tile_h * shape.out_w * cm * 4
    proj_acc = tile_h * shape.out_w * co * 4
    weights = (ci * cm + shape.k * shape.k * cm + cm * co) * shape.dtype_bytes
    return staging + exp_acc + dw_blk + proj_acc + weights


def candidate_mbconv_schedules(
    shape: MBConvShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
    mode: Optional[str] = None, collective: Optional[str] = None,
) -> Tuple[MBConvSchedule, ...]:
    """All VMEM-feasible (tile_h, mode, residency, collective) schedules,
    model-priced.

    A pinned ``mode`` restricts the candidate set, so tile_h/residency are
    solved (and VMEM-checked) under THAT mode's footprint — a retain pin
    must pay for the retained-DW stream buffers the recompute winner never
    carried.  Under a mesh, feasibility and channel blocks are solved at
    the per-device shard shape (batch/data, c_mid/model); the
    retain/recompute crossover therefore re-solves per partitioning — a
    shard's DW slice is mp-fold cheaper to retain than the whole expanded
    tensor.  The **collective** axis (projection reduction layout) only
    exists on-mesh: ring all-reduce always, psum_scatter where c_out
    divides the model groups (``_collective_set``); it does not enter the
    VMEM check — both layouts run the identical kernels."""
    if mode is not None and mode not in MBCONV_MODES:
        raise ValueError(mode)
    modes = MBCONV_MODES if mode is None else (mode,)
    local, eff = mbconv_shard(shape, mesh_shape)
    colls = _collective_set(shape, eff, collective)
    ci = pick_channel_block(local.c_in, tpu.c_block)
    cm = pick_channel_block(local.c_mid, tpu.c_block)
    co = _blocks(local.c_out, tpu.c_block)
    out: list[MBConvSchedule] = []
    seen = set()
    ths = [max(1, min(th, shape.out_h)) for th in tpu.tile_h_candidates]
    combos = [(th, md, res)
              for th in ths for md in modes
              for res in _residency_set(residency)
              if mbconv_vmem_footprint_bytes(local, th, tpu, res, md)
              <= tpu.vmem_bytes]
    if not combos:
        combos = [(1, md, residency or "strip_dma") for md in modes]
    staged_cache: dict = {}
    for th, md, res in combos:
        for coll in colls:
            if (th, md, res, coll) in seen:
                continue
            seen.add((th, md, res, coll))
            if (th, coll) not in staged_cache:
                staged_cache[th, coll] = sharded_mbconv_staged_traffic(
                    shape, th, eff, tpu.c_block, coll)
            out.append(MBConvSchedule(
                tile_h=th, mode=md, ci_block=ci, cm_block=cm, co_block=co,
                sharded=sharded_mbconv_traffic(shape, th, md, eff,
                                               tpu.c_block, res, coll),
                staged=staged_cache[th, coll],
                residency=res,
            ))
    return tuple(out)


def select_mbconv_schedule(
    shape: MBConvShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
    mode: Optional[str] = None, collective: Optional[str] = None,
) -> MBConvSchedule:
    """Pick (tile_h, mode, residency, collective) minimizing modeled total
    two-pass traffic (ties -> larger tile_h, then retain: one DW
    round-trip beats recompute MACs; then the residency rank, then the
    ring default).  ``mode``/``residency``/``collective`` pins restrict
    the solve."""
    cands = candidate_mbconv_schedules(shape, tpu, mesh_shape, residency,
                                       mode, collective)
    return min(cands, key=lambda c: (c.total_bytes, -c.tile_h,
                                     c.mode != "retain",
                                     _RESIDENCY_RANK[c.residency],
                                     _COLLECTIVE_RANK[c.collective]))


def _mbconv_schedule_at(shape: MBConvShape, tile_h: int, mode: str,
                        tpu: TPUConfig, mesh_shape: MeshShape = (1, 1),
                        residency: str = DEFAULT_RESIDENCY,
                        collective: str = DEFAULT_COLLECTIVE
                        ) -> MBConvSchedule:
    local, eff = mbconv_shard(shape, mesh_shape)
    if eff[1] <= 1:
        collective = DEFAULT_COLLECTIVE   # degenerate axis: nothing crosses
    return MBConvSchedule(
        tile_h=tile_h, mode=mode,
        ci_block=pick_channel_block(local.c_in, tpu.c_block),
        cm_block=pick_channel_block(local.c_mid, tpu.c_block),
        co_block=_blocks(local.c_out, tpu.c_block),
        sharded=sharded_mbconv_traffic(shape, tile_h, mode, eff,
                                       tpu.c_block, residency, collective),
        staged=sharded_mbconv_staged_traffic(shape, tile_h, eff,
                                             tpu.c_block, collective),
        residency=residency,
    )


def _solve_mbconv_residency_at(shape: MBConvShape, tile_h: int, mode: str,
                               tpu: TPUConfig, mesh_shape: MeshShape) -> str:
    """Best residency at a FIXED (tile_h, mode) — see
    ``_solve_residency_at``.  Collective words are residency-invariant,
    so per-device bytes decide."""
    local, eff = mbconv_shard(shape, mesh_shape)
    modes = [res for res in RESIDENCY_MODES
             if mbconv_vmem_footprint_bytes(local, tile_h, tpu, res, mode)
             <= tpu.vmem_bytes] or ["strip_dma"]
    return min(modes, key=lambda res: (
        sharded_mbconv_traffic(shape, tile_h, mode, eff, tpu.c_block,
                               res).device.total_bytes,
        _RESIDENCY_RANK[res]))


def _solve_mbconv_collective_at(shape: MBConvShape, tile_h: int, mode: str,
                                tpu: TPUConfig, mesh_shape: MeshShape,
                                residency: str) -> str:
    """Best collective at a FIXED (tile_h, mode, residency) — legacy
    cache entries predate the collective axis: min total bytes among the
    runnable layouts, ties to the ring default."""
    _local, eff = mbconv_shard(shape, mesh_shape)
    return min(_collective_set(shape, eff, None), key=lambda coll: (
        sharded_mbconv_traffic(shape, tile_h, mode, eff, tpu.c_block,
                               residency, coll).total_bytes,
        _COLLECTIVE_RANK[coll]))


def get_mbconv_schedule(
    b: int, h: int, w: int, c_in: int, c_mid: int, c_out: int, k: int,
    s: int, se_ratio: float = 0.25, dtype_bytes: int = 4,
    tpu: TPUConfig = TPUConfig(), mesh_shape: MeshShape = (1, 1),
    residency: Optional[str] = None, mode: Optional[str] = None,
    collective: Optional[str] = None,
) -> MBConvSchedule:
    """Cached per-layer-shape two-pass schedule lookup (trace-time safe).

    ``mesh_shape`` and the requested ``residency``/``mode``/``collective``
    pins enter the cache key (see ``get_fused_schedule``): a pinned
    pass-2 mode solves tile_h and residency under that mode's VMEM
    footprint instead of echoing a schedule solved for the other mode,
    and a pinned collective prices (and caches) under that reduction
    layout only.  Legacy entries keep their (tile_h, mode) priority with
    the residency — and, for pre-collective entries, the collective —
    re-solved at that point."""
    shape = MBConvShape(b=b, h=h, w=w, c_in=c_in, c_mid=c_mid, c_out=c_out,
                        k=k, s=s, se_ratio=se_ratio, dtype_bytes=dtype_bytes)
    cache = get_schedule_cache()
    key = _mbconv_key(shape, tpu, mesh_shape, residency, mode, collective)
    hit = cache.get(key)
    tile_h = _entry_tile_h(hit, shape.out_h) if hit is not None else None
    hit_mode = hit.get("mode") if isinstance(hit, dict) else None
    if tile_h is not None and hit_mode in MBCONV_MODES \
            and (mode is None or hit_mode == mode):
        res = residency or _entry_residency(hit) \
            or _solve_mbconv_residency_at(shape, tile_h, hit_mode, tpu,
                                          mesh_shape)
        coll = collective or _entry_collective(hit) \
            or _solve_mbconv_collective_at(shape, tile_h, hit_mode, tpu,
                                           mesh_shape, res)
        return _mbconv_schedule_at(shape, tile_h, hit_mode, tpu,
                                   mesh_shape, res, coll)
    sched = select_mbconv_schedule(shape, tpu, mesh_shape, residency, mode,
                                   collective)
    cache.put(key, {"tile_h": sched.tile_h, "mode": sched.mode,
                    "residency": sched.residency,
                    "collective": sched.collective, "source": "model",
                    "recorded_at": time.time()})
    return sched


# ---------------------------------------------------------------------------
# measured fallback
# ---------------------------------------------------------------------------

def benchmark_fused_sweep(
    x, w_dw, w_pw, *, stride: int, padding: str = "SAME",
    tile_hs: Optional[Sequence[int]] = None, iters: int = 3,
    interpret: Optional[bool] = None, persist: bool = False,
    tpu: TPUConfig = TPUConfig(), residency: Optional[str] = None,
) -> Tuple[int, Tuple[Tuple[int, float], ...]]:
    """Measured fallback: time the real fused kernel per candidate tile_h.

    Returns (best_tile_h, ((tile_h, seconds_per_call), ...)).  Use when the
    analytical model ties candidates or a deployment wants ground truth; the
    sweep runs each candidate ``iters`` times after one warmup call, under
    ``residency`` (None = the kernels' default staging mode).  With
    ``persist=True`` the winning tile_h is recorded in the schedule cache —
    under the same residency request it was measured at — as a
    ``"measured"`` entry (which outranks model picks and, when a cache dir
    is configured, survives restarts).
    """
    import jax

    from ..kernels.convdk_fused import convdk_fused_separable

    res_used = residency or DEFAULT_RESIDENCY
    out_h = -(-x.shape[1] // stride)
    if tile_hs is None:
        tile_hs = [t for t in TPUConfig().tile_h_candidates if t <= out_h] or [1]
    results = []
    for th in tile_hs:
        fn = lambda: convdk_fused_separable(  # noqa: E731
            x, w_dw, w_pw, stride=stride, padding=padding, tile_h=th,
            interpret=interpret, residency=res_used)
        jax.block_until_ready(fn())                      # warmup / compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn())
        results.append((th, (time.perf_counter() - t0) / iters))
    best = min(results, key=lambda r: r[1])[0]
    if persist:
        b, h, w_in, c_in = x.shape
        shape = SeparableShape(
            b=b, h=h, w=w_in, c_in=c_in, c_out=w_pw.shape[1],
            k=w_dw.shape[0], s=stride, dtype_bytes=x.dtype.itemsize)
        entry = {"tile_h": best, "source": "measured",
                 "recorded_at": time.time(),
                 "timings_s": {str(th): t for th, t in results}}
        if residency is not None:
            # only a REQUESTED residency is ground truth worth recording;
            # an unpinned sweep timed one mode's tile_h candidates without
            # comparing modes, so the auto entry leaves residency to the
            # solver (re-solved at the measured tile_h on lookup)
            entry["residency"] = res_used
        get_schedule_cache().put(
            _sep_key(shape, tpu, residency=residency), entry)
    return best, tuple(results)

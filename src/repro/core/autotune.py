"""Per-layer schedule selection for the fused ConvDK kernels.

MIREDO-style per-layer solving: instead of one fixed ``tile_h`` for every
block, each layer shape gets its own fused schedule, chosen by the
analytical HBM traffic model in ``core.perfmodel`` (primary) with an
optional measured fallback sweep (ground truth when the model cannot
separate candidates, or when a deployment wants real timings).  Two block
families are solved:

* separable (``FusedSchedule``): DW + PW in one pass — pick ``tile_h``;
* MBConv (``MBConvSchedule``): expand + DW + SE + PW in two passes — pick
  ``tile_h`` AND the pass-2 ``mode`` ("retain" writes the DW tensor to HBM
  once and re-reads it; "recompute" re-runs expand+DW from the input
  strips; the traffic model prices the crossover per layer shape).

Schedule solving is trace-time work and must never re-run inside a jitted
step, so selections are cached.  The cache has two layers:

1. an in-process dict (always on), and
2. an optional JSON file under a configurable cache directory, keyed by
   (kernel kind, layer shape, dtype bytes, jax backend) — measured sweeps
   and model picks survive restarts and can ship as a lookup table.
   Enable it with ``set_schedule_cache_dir(path)`` or the
   ``CONVDK_CACHE_DIR`` environment variable; entries recorded from a
   measured sweep (``source == "measured"``) take priority over model
   picks for the same key.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple

from .perfmodel import (
    MBCONV_MODES,
    HBMTraffic,
    MBConvShape,
    SeparableShape,
    mbconv_shard,
    pick_channel_block,
    separable_shard,
    shard_factors,
    sharded_mbconv_staged_traffic,
    sharded_mbconv_traffic,
    sharded_separable_staged_traffic,
    sharded_separable_traffic,
)

MeshShape = Tuple[int, int]   # ("data", "model") axis sizes, (1, 1) = 1 core


@dataclass(frozen=True)
class TPUConfig:
    """Budget knobs for fused-schedule selection on one core."""

    vmem_bytes: int = 16 * 1024 * 1024   # per-core VMEM budget
    c_block: int = 128                   # lane width
    tile_h_candidates: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)


class _ScheduleTotals:
    """Mesh-wide byte accounting shared by both schedule families.

    ``traffic`` / ``staged_traffic`` are PER-DEVICE: for the default
    ``mesh_shape == (1, 1)`` that is the whole layer (the PR-1 semantics,
    unchanged); under a (data, model) mesh they price one shard of the
    sharded launch.  ``collective_words`` is identical for the fused and
    staged pipelines (the staged path's reductions over the sharded
    channel axis are the same psums), so the fused-vs-staged margin stays
    an HBM-side comparison."""

    @property
    def n_devices(self) -> int:
        return self.mesh_shape[0] * self.mesh_shape[1]

    @property
    def collective_bytes(self) -> int:
        return self.collective_words * self.traffic.dtype_bytes

    @property
    def total_bytes(self) -> int:
        """All bytes moved anywhere (every device's HBM + collectives)."""
        return self.traffic.total_bytes * self.n_devices \
            + self.collective_bytes

    @property
    def staged_total_bytes(self) -> int:
        return self.staged_traffic.total_bytes * self.n_devices \
            + self.collective_bytes

    @property
    def modeled_saving(self) -> float:
        """Fraction of staged bytes the fused schedule avoids."""
        base = self.staged_total_bytes
        return 1.0 - self.total_bytes / base if base else 0.0


@dataclass(frozen=True)
class FusedSchedule(_ScheduleTotals):
    """One selected schedule for ``convdk_fused_separable``.

    The separable partitioning (c_out on "model") is collective-free, so
    ``collective_words`` is always 0 here — it exists for symmetry with
    ``MBConvSchedule`` (accounting doc on ``_ScheduleTotals``)."""

    tile_h: int
    ci_block: int
    co_block: int
    traffic: HBMTraffic          # modeled fused HBM traffic at this tile_h
    staged_traffic: HBMTraffic   # modeled staged-pipeline traffic (baseline)
    mesh_shape: Tuple[int, int] = (1, 1)
    collective_words: int = 0


@dataclass(frozen=True)
class MBConvSchedule(_ScheduleTotals):
    """One selected two-pass schedule for ``convdk_mbconv_fused``.

    Under a mesh the c_mid partitioning pays two psums (SE squeeze +
    projection partials), priced in ``collective_words`` (accounting doc
    on ``_ScheduleTotals``)."""

    tile_h: int
    mode: str                    # "retain" | "recompute"
    ci_block: int
    cm_block: int
    co_block: int
    traffic: HBMTraffic          # modeled two-pass traffic at (tile_h, mode)
    staged_traffic: HBMTraffic   # modeled staged MBConv pipeline (baseline)
    mesh_shape: Tuple[int, int] = (1, 1)
    collective_words: int = 0


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _blocks(c: int, cap: int) -> int:
    return min(cap, _round_up(c, 8))


# ---------------------------------------------------------------------------
# persistent schedule cache
# ---------------------------------------------------------------------------

_CACHE_DIR_ENV = "CONVDK_CACHE_DIR"
_CACHE_FILE = "convdk_schedules.json"


def _backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:  # pragma: no cover - jax always importable here
        return "unknown"


class ScheduleCache:
    """Two-layer schedule cache: in-process dict + optional JSON file.

    Disk entries store only the *decision* (tile_h, mode, source); traffic
    numbers are deterministic functions of the shape and are rebuilt by the
    model on load, so the file format survives model refinements.
    """

    def __init__(self, directory: Optional[Path]):
        self.directory = Path(directory).expanduser() if directory else None
        self._mem: Dict[str, dict] = {}
        self._disk: Optional[Dict[str, dict]] = None   # lazily loaded

    @property
    def path(self) -> Optional[Path]:
        return self.directory / _CACHE_FILE if self.directory else None

    @staticmethod
    def _migrate_key(key: str) -> str:
        """Upgrade a pre-mesh cache key in place: entries persisted before
        the ``mesh_shape`` schedule axis (5 segments, no ``mesh`` segment)
        were all solved single-device, so they ARE the ``mesh1x1`` picks —
        a measured sweep recorded under the old format must keep outranking
        model picks instead of being silently orphaned."""
        parts = key.split("|")
        if len(parts) == 5 and parts[0] in ("sep", "mbconv") \
                and not parts[3].startswith("mesh"):
            parts.insert(3, "mesh1x1")
            return "|".join(parts)
        return key

    def _load_disk(self) -> Dict[str, dict]:
        if self._disk is None:
            self._disk = {}
            if self.path is not None:
                try:
                    payload = json.loads(self.path.read_text())
                    if payload.get("version") == 1:
                        self._disk = {
                            self._migrate_key(k): v
                            for k, v in payload.get("entries", {}).items()}
                except (OSError, ValueError):
                    pass                   # unreadable cache = empty cache
        return self._disk

    def _flush(self) -> None:
        if self.path is None:
            return
        try:
            self.directory.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(".tmp")
            tmp.write_text(json.dumps(
                {"version": 1, "entries": self._load_disk()},
                indent=1, sort_keys=True))
            tmp.replace(self.path)
        except OSError:
            pass                           # persistence is best-effort

    def get(self, key: str) -> Optional[dict]:
        hit = self._mem.get(key)
        if hit is not None:
            return hit
        hit = self._load_disk().get(key)
        if hit is not None:
            self._mem[key] = hit
        return hit

    def put(self, key: str, entry: dict, persist: bool = True) -> None:
        self._mem[key] = entry
        if persist and self.path is not None:
            disk = self._load_disk()
            # never let a model pick clobber a measured entry (malformed
            # old entries — non-dicts — are overwritten, not honored)
            old = disk.get(key)
            if isinstance(old, dict) and old.get("source") == "measured" \
                    and entry.get("source") != "measured":
                return
            disk[key] = entry
            self._flush()

    def clear_memory(self) -> None:
        """Drop the in-process layer (tests: force a disk round-trip)."""
        self._mem.clear()
        self._disk = None


_SCHEDULE_CACHE: Optional[ScheduleCache] = None


def get_schedule_cache() -> ScheduleCache:
    global _SCHEDULE_CACHE
    if _SCHEDULE_CACHE is None:
        env = os.environ.get(_CACHE_DIR_ENV)
        _SCHEDULE_CACHE = ScheduleCache(Path(env) if env else None)
    return _SCHEDULE_CACHE


def set_schedule_cache_dir(directory: Optional[os.PathLike]) -> ScheduleCache:
    """Point the persistent schedule cache at ``directory`` (None = memory
    only).  Resets the in-process layer so the new directory is
    authoritative."""
    global _SCHEDULE_CACHE
    _SCHEDULE_CACHE = ScheduleCache(
        Path(directory) if directory is not None else None)
    return _SCHEDULE_CACHE


def _tpu_key(tpu: TPUConfig) -> str:
    """Every TPUConfig field enters the key: a schedule solved (and
    VMEM-checked) under one config must never be reused for another."""
    ths = "x".join(str(t) for t in tpu.tile_h_candidates)
    return f"vmem{tpu.vmem_bytes}-cb{tpu.c_block}-th{ths}"


def _sep_key(shape: SeparableShape, tpu: TPUConfig,
             mesh_shape: MeshShape = (1, 1)) -> str:
    """Schedule-cache key.  The EFFECTIVE mesh factors are part of the key:
    a schedule solved for one partitioning (per-device shard shapes, psum
    terms, VMEM headroom) must never be echoed for another — sharded and
    unsharded picks live in distinct entries."""
    dp, mp = shard_factors(shape.b, shape.c_out, mesh_shape)
    return (f"sep|b{shape.b}-h{shape.h}-w{shape.w}-ci{shape.c_in}"
            f"-co{shape.c_out}-k{shape.k}-s{shape.s}|dtb{shape.dtype_bytes}"
            f"|mesh{dp}x{mp}|{_tpu_key(tpu)}|{_backend()}")


def _mbconv_key(shape: MBConvShape, tpu: TPUConfig,
                mesh_shape: MeshShape = (1, 1)) -> str:
    dp, mp = shard_factors(shape.b, shape.c_mid, mesh_shape)
    return (f"mbconv|b{shape.b}-h{shape.h}-w{shape.w}-ci{shape.c_in}"
            f"-cm{shape.c_mid}-co{shape.c_out}-k{shape.k}-s{shape.s}"
            f"|dtb{shape.dtype_bytes}|mesh{dp}x{mp}|{_tpu_key(tpu)}"
            f"|{_backend()}")


def _entry_tile_h(hit, out_h: int):
    """Validated tile_h from a cache entry, or None if the entry is
    malformed or stale (a bad cache file must degrade to the model, never
    crash schedule lookup)."""
    try:
        tile_h = int(hit["tile_h"])
    except (TypeError, KeyError, ValueError):
        return None
    return tile_h if 1 <= tile_h <= out_h else None


# ---------------------------------------------------------------------------
# separable (single-pass) schedules
# ---------------------------------------------------------------------------

def vmem_footprint_bytes(shape: SeparableShape, tile_h: int,
                         tpu: TPUConfig) -> int:
    """Modeled VMEM residency of one fused grid cell (per-strip staging).

    Counts the staged input window, the f32 DW accumulator, the f32 PW
    scratch accumulator and both weight blocks — the production budget a
    DMA'd (``ANY``-space input) rendering of the kernel must respect.
    """
    ci = pick_channel_block(shape.c_in, tpu.c_block)
    co = _blocks(shape.c_out, tpu.c_block)
    tile_h = max(1, min(tile_h, shape.out_h))
    in_rows = (tile_h - 1) * shape.s + shape.k
    x_win = in_rows * shape.padded_w * ci * shape.dtype_bytes
    dw_acc = tile_h * shape.out_w * ci * 4
    pw_acc = tile_h * shape.out_w * co * 4
    weights = (shape.k * shape.k * ci + ci * co) * shape.dtype_bytes
    return x_win + dw_acc + pw_acc + weights


def candidate_schedules(
    shape: SeparableShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1),
) -> Tuple[FusedSchedule, ...]:
    """All VMEM-feasible schedules for one layer shape, model-priced.

    Under a mesh, feasibility and channel blocks are solved at the
    PER-DEVICE shard shape (batch/data, c_out/model) — a shard has more
    VMEM headroom per channel block than the whole layer."""
    local, eff = separable_shard(shape, mesh_shape)
    ci = pick_channel_block(local.c_in, tpu.c_block)
    co = _blocks(local.c_out, tpu.c_block)
    out: list[FusedSchedule] = []
    seen = set()
    ths = [max(1, min(th, shape.out_h)) for th in tpu.tile_h_candidates]
    feasible = [th for th in ths
                if vmem_footprint_bytes(local, th, tpu) <= tpu.vmem_bytes]
    for th in feasible or [1]:
        if th in seen:
            continue
        seen.add(th)
        sharded = sharded_separable_traffic(shape, th, eff, tpu.c_block)
        staged = sharded_separable_staged_traffic(shape, th, eff, tpu.c_block)
        out.append(FusedSchedule(
            tile_h=th, ci_block=ci, co_block=co,
            traffic=sharded.device, staged_traffic=staged.device,
            mesh_shape=eff, collective_words=sharded.collective_words,
        ))
    return tuple(out)


def select_fused_schedule(
    shape: SeparableShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1),
) -> FusedSchedule:
    """Pick the schedule minimizing modeled total traffic — per-device HBM
    bytes across all devices plus collectives (ties -> larger tile_h:
    fewer grid cells, bigger MXU contractions)."""
    cands = candidate_schedules(shape, tpu, mesh_shape)
    return min(cands, key=lambda c: (c.total_bytes, -c.tile_h))


def _schedule_at(shape: SeparableShape, tile_h: int, tpu: TPUConfig,
                 mesh_shape: MeshShape = (1, 1)) -> FusedSchedule:
    local, eff = separable_shard(shape, mesh_shape)
    sharded = sharded_separable_traffic(shape, tile_h, eff, tpu.c_block)
    staged = sharded_separable_staged_traffic(shape, tile_h, eff, tpu.c_block)
    return FusedSchedule(
        tile_h=tile_h,
        ci_block=pick_channel_block(local.c_in, tpu.c_block),
        co_block=_blocks(local.c_out, tpu.c_block),
        traffic=sharded.device, staged_traffic=staged.device,
        mesh_shape=eff, collective_words=sharded.collective_words,
    )


def get_fused_schedule(
    b: int, h: int, w: int, c_in: int, c_out: int, k: int, s: int,
    dtype_bytes: int = 4, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1),
) -> FusedSchedule:
    """Cached per-layer-shape schedule lookup (trace-time safe).

    Consults the in-process cache, then the JSON cache (where a measured
    sweep may have recorded ground truth), then the analytical model.
    ``mesh_shape`` is the ("data", "model") partitioning the schedule will
    run under — part of the cache key, so sharded and unsharded picks for
    the same layer shape never collide."""
    shape = SeparableShape(b=b, h=h, w=w, c_in=c_in, c_out=c_out, k=k, s=s,
                           dtype_bytes=dtype_bytes)
    cache = get_schedule_cache()
    key = _sep_key(shape, tpu, mesh_shape)
    hit = cache.get(key)
    tile_h = _entry_tile_h(hit, shape.out_h) if hit is not None else None
    if tile_h is not None:
        return _schedule_at(shape, tile_h, tpu, mesh_shape)
    sched = select_fused_schedule(shape, tpu, mesh_shape)
    cache.put(key, {"tile_h": sched.tile_h, "source": "model",
                    "recorded_at": time.time()})
    return sched


# ---------------------------------------------------------------------------
# MBConv (two-pass) schedules
# ---------------------------------------------------------------------------

def mbconv_vmem_footprint_bytes(shape: MBConvShape, tile_h: int,
                                tpu: TPUConfig) -> int:
    """Modeled VMEM residency of one two-pass MBConv grid cell.

    The dominant term is the f32 expand accumulator over the staged strip
    window at ``cm_block`` lanes (pass 1 and recompute pass 2 share it);
    pass 2 adds the f32 projection accumulator."""
    ci = pick_channel_block(shape.c_in, tpu.c_block)
    cm = pick_channel_block(shape.c_mid, tpu.c_block)
    co = _blocks(shape.c_out, tpu.c_block)
    tile_h = max(1, min(tile_h, shape.out_h))
    in_rows = (tile_h - 1) * shape.s + shape.k
    w_need = (shape.out_w - 1) * shape.s + shape.k
    x_win = in_rows * shape.padded_w * ci * shape.dtype_bytes
    exp_acc = in_rows * w_need * cm * 4
    dw_blk = tile_h * shape.out_w * cm * 4
    proj_acc = tile_h * shape.out_w * co * 4
    weights = (ci * cm + shape.k * shape.k * cm + cm * co) * shape.dtype_bytes
    return x_win + exp_acc + dw_blk + proj_acc + weights


def candidate_mbconv_schedules(
    shape: MBConvShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1),
) -> Tuple[MBConvSchedule, ...]:
    """All VMEM-feasible (tile_h, mode) schedules, model-priced.

    Under a mesh, feasibility and channel blocks are solved at the
    per-device shard shape (batch/data, c_mid/model); the retain/recompute
    crossover therefore re-solves per partitioning — a shard's DW slice is
    mp-fold cheaper to retain than the whole expanded tensor."""
    local, eff = mbconv_shard(shape, mesh_shape)
    ci = pick_channel_block(local.c_in, tpu.c_block)
    cm = pick_channel_block(local.c_mid, tpu.c_block)
    co = _blocks(local.c_out, tpu.c_block)
    out: list[MBConvSchedule] = []
    seen = set()
    ths = [max(1, min(th, shape.out_h)) for th in tpu.tile_h_candidates]
    feasible = [th for th in ths
                if mbconv_vmem_footprint_bytes(local, th, tpu)
                <= tpu.vmem_bytes]
    for th in feasible or [1]:
        if th in seen:
            continue
        seen.add(th)
        staged = sharded_mbconv_staged_traffic(shape, th, eff, tpu.c_block)
        for mode in MBCONV_MODES:
            sharded = sharded_mbconv_traffic(shape, th, mode, eff,
                                             tpu.c_block)
            out.append(MBConvSchedule(
                tile_h=th, mode=mode, ci_block=ci, cm_block=cm, co_block=co,
                traffic=sharded.device, staged_traffic=staged.device,
                mesh_shape=eff, collective_words=sharded.collective_words,
            ))
    return tuple(out)


def select_mbconv_schedule(
    shape: MBConvShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1),
) -> MBConvSchedule:
    """Pick (tile_h, mode) minimizing modeled total two-pass traffic (ties
    -> larger tile_h, then retain: one DW round-trip beats recompute
    MACs)."""
    cands = candidate_mbconv_schedules(shape, tpu, mesh_shape)
    return min(cands, key=lambda c: (c.total_bytes, -c.tile_h,
                                     c.mode != "retain"))


def _mbconv_schedule_at(shape: MBConvShape, tile_h: int, mode: str,
                        tpu: TPUConfig,
                        mesh_shape: MeshShape = (1, 1)) -> MBConvSchedule:
    local, eff = mbconv_shard(shape, mesh_shape)
    sharded = sharded_mbconv_traffic(shape, tile_h, mode, eff, tpu.c_block)
    staged = sharded_mbconv_staged_traffic(shape, tile_h, eff, tpu.c_block)
    return MBConvSchedule(
        tile_h=tile_h, mode=mode,
        ci_block=pick_channel_block(local.c_in, tpu.c_block),
        cm_block=pick_channel_block(local.c_mid, tpu.c_block),
        co_block=_blocks(local.c_out, tpu.c_block),
        traffic=sharded.device, staged_traffic=staged.device,
        mesh_shape=eff, collective_words=sharded.collective_words,
    )


def get_mbconv_schedule(
    b: int, h: int, w: int, c_in: int, c_mid: int, c_out: int, k: int,
    s: int, se_ratio: float = 0.25, dtype_bytes: int = 4,
    tpu: TPUConfig = TPUConfig(), mesh_shape: MeshShape = (1, 1),
) -> MBConvSchedule:
    """Cached per-layer-shape two-pass schedule lookup (trace-time safe).

    ``mesh_shape`` enters the cache key (see ``get_fused_schedule``)."""
    shape = MBConvShape(b=b, h=h, w=w, c_in=c_in, c_mid=c_mid, c_out=c_out,
                        k=k, s=s, se_ratio=se_ratio, dtype_bytes=dtype_bytes)
    cache = get_schedule_cache()
    key = _mbconv_key(shape, tpu, mesh_shape)
    hit = cache.get(key)
    tile_h = _entry_tile_h(hit, shape.out_h) if hit is not None else None
    if tile_h is not None and isinstance(hit, dict) \
            and hit.get("mode") in MBCONV_MODES:
        return _mbconv_schedule_at(shape, tile_h, hit["mode"], tpu,
                                   mesh_shape)
    sched = select_mbconv_schedule(shape, tpu, mesh_shape)
    cache.put(key, {"tile_h": sched.tile_h, "mode": sched.mode,
                    "source": "model", "recorded_at": time.time()})
    return sched


# ---------------------------------------------------------------------------
# measured fallback
# ---------------------------------------------------------------------------

def benchmark_fused_sweep(
    x, w_dw, w_pw, *, stride: int, padding: str = "SAME",
    tile_hs: Optional[Sequence[int]] = None, iters: int = 3,
    interpret: Optional[bool] = None, persist: bool = False,
    tpu: TPUConfig = TPUConfig(),
) -> Tuple[int, Tuple[Tuple[int, float], ...]]:
    """Measured fallback: time the real fused kernel per candidate tile_h.

    Returns (best_tile_h, ((tile_h, seconds_per_call), ...)).  Use when the
    analytical model ties candidates or a deployment wants ground truth; the
    sweep runs each candidate ``iters`` times after one warmup call.  With
    ``persist=True`` the winning tile_h is recorded in the schedule cache as
    a ``"measured"`` entry (which outranks model picks and, when a cache dir
    is configured, survives restarts).
    """
    import jax

    from ..kernels.convdk_fused import convdk_fused_separable

    out_h = -(-x.shape[1] // stride)
    if tile_hs is None:
        tile_hs = [t for t in TPUConfig().tile_h_candidates if t <= out_h] or [1]
    results = []
    for th in tile_hs:
        fn = lambda: convdk_fused_separable(  # noqa: E731
            x, w_dw, w_pw, stride=stride, padding=padding, tile_h=th,
            interpret=interpret)
        jax.block_until_ready(fn())                      # warmup / compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn())
        results.append((th, (time.perf_counter() - t0) / iters))
    best = min(results, key=lambda r: r[1])[0]
    if persist:
        b, h, w_in, c_in = x.shape
        shape = SeparableShape(
            b=b, h=h, w=w_in, c_in=c_in, c_out=w_pw.shape[1],
            k=w_dw.shape[0], s=stride, dtype_bytes=x.dtype.itemsize)
        get_schedule_cache().put(
            _sep_key(shape, tpu),
            {"tile_h": best, "source": "measured", "recorded_at": time.time(),
             "timings_s": {str(th): t for th, t in results}})
    return best, tuple(results)

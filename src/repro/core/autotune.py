"""Per-layer schedule selection for the fused ConvDK kernels.

MIREDO-style per-layer solving: instead of one fixed ``tile_h`` for every
block, each layer shape gets its own fused schedule, chosen by the
analytical HBM traffic model in ``core.perfmodel`` (primary) with an
optional measured fallback sweep (ground truth when the model cannot
separate candidates, or when a deployment wants real timings).  Two block
families are solved:

* separable (``FusedSchedule``): DW + PW in one pass — pick ``tile_h`` AND
  the input **residency** ("resident" | "strip_dma" | "strip_dma_db", the
  staging-engine axis: VMEM feasibility counts the slot buffers — 2x strip
  scratch for double-buffering — and the traffic model prices each mode);
* MBConv (``MBConvSchedule``): expand + DW + SE + PW in two passes — pick
  ``tile_h``, the residency, the pass-2 ``mode`` ("retain" writes the
  DW tensor to HBM once and re-reads it; "recompute" re-runs expand+DW
  from the input strips; the traffic model prices the crossover per layer
  shape), AND — under a model-sharded mesh — the ``collective`` axis
  ("ring_allreduce" | "psum_scatter": how the pass-2 projection partial
  is reduced across the model groups; scatter halves the wire words and
  leaves the output sharded on c_out).

Every schedule carries the ``perfmodel.ShardedTraffic`` pair it was
solved from and DELEGATES all byte totals to it (``_ScheduleTraffic``):
the solver optimizes exactly the bytes the model prices — there is no
second accounting to drift.

Schedule solving is trace-time work and must never re-run inside a jitted
step, so selections are cached.  The cache has two layers:

1. an in-process dict (always on), and
2. an optional JSON file under a configurable cache directory, keyed by
   (kernel kind, layer shape, dtype bytes, jax backend) — measured sweeps
   and model picks survive restarts and can ship as a lookup table.
   Enable it with ``set_schedule_cache_dir(path)`` or the
   ``CONVDK_CACHE_DIR`` environment variable; entries recorded from a
   measured sweep (``source == "measured"``) take priority over model
   picks for the same key.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, replace
from functools import lru_cache
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple

from .perfmodel import (
    COLLECTIVE_MODES,
    DEFAULT_COLLECTIVE,
    DEFAULT_LAYOUT,
    DEFAULT_OVERLAP,
    DEFAULT_RESIDENCY,
    MBCONV_MODES,
    RESIDENCY_MODES,
    HBMTraffic,
    MBConvPassCosts,
    MBConvShape,
    PerfCoefficients,
    SeparableShape,
    ShardedTraffic,
    boundary_overlap_us,
    can_psum_scatter,
    can_shard_input,
    fusedmb_shard,
    fusedmb_staging_bytes,
    get_perf_coefficients,
    layout_transition_words,
    mbconv_pass_us,
    mbconv_shard,
    mbconv_staging_bytes,
    pick_channel_block,
    separable_shard,
    separable_staging_bytes,
    shard_factors,
    sharded_fusedmb_pass_costs,
    sharded_fusedmb_staged_traffic,
    sharded_fusedmb_traffic,
    sharded_mbconv_pass_costs,
    sharded_mbconv_staged_traffic,
    sharded_mbconv_traffic,
    sharded_separable_staged_traffic,
    sharded_separable_traffic,
    validate_collective,
    validate_layout,
    validate_overlap,
    validate_residency,
)
from . import telemetry
from .telemetry import measure

MeshShape = Tuple[int, int]   # ("data", "model") axis sizes, (1, 1) = 1 core

# Block activation vocabulary (mirrored by ``configs.base.ACT_MODES`` —
# configs sits above models and cannot be imported from core).  The act
# axis never changes a byte count, but it IS a schedule-cache key segment:
# entries must record the block variant they were solved for, so a future
# act-sensitive refinement (e.g. hard_swish's clip chain changing the
# VMEM scratch) can split the entries without orphaning them.
ACT_MODES: Tuple[str, ...] = ("silu", "relu", "hard_swish")
DEFAULT_ACT = "silu"

# Families a network CHAIN element may take (separable blocks are solved
# per-layer via ``get_fused_schedule`` and never enter the chain DP)
CHAIN_FAMILIES: Tuple[str, ...] = ("mbconv", "fusedmb")


def validate_act(act: str) -> str:
    if act not in ACT_MODES:
        raise ValueError(f"act must be one of {ACT_MODES}, got {act!r}")
    return act

# Solver preference among byte-identical residencies: double-buffering hides
# the strip DMA behind compute at 2x scratch, single-slot DMA is the
# VMEM-tight fallback, and full-height residency is the last resort (its
# traffic collapses only for single-channel-block layers that fit VMEM).
_RESIDENCY_RANK = {"strip_dma_db": 0, "strip_dma": 1, "resident": 2}


@dataclass(frozen=True)
class TPUConfig:
    """Budget knobs for fused-schedule selection on one core."""

    vmem_bytes: int = 16 * 1024 * 1024   # per-core VMEM budget
    c_block: int = 128                   # lane width
    tile_h_candidates: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)


class _ScheduleTraffic:
    """Accounting VIEW shared by both schedule families.

    A schedule carries the two ``perfmodel.ShardedTraffic`` objects it was
    solved from — ``sharded`` (the fused pipeline) and ``staged`` (the
    identically partitioned staged baseline) — and every byte total here
    DELEGATES to them.  ``perfmodel`` is the single pricing authority for
    device bytes, collective bytes and DMA issues; the solver never
    re-derives a mesh-wide total, so the bytes the autotuner optimizes
    are — identically, not approximately — the bytes the traffic model
    prices (the anti-divergence property in tests/test_perfmodel_bands.py
    pins this down).  For the default ``mesh_shape == (1, 1)`` the device
    traffic is the whole layer (the PR-1 semantics, unchanged).  The
    staged baseline pays the SAME collective words (its reductions over
    the sharded channel axis are the same collectives, priced under the
    same ``collective`` mode), so the fused-vs-staged margin stays an
    HBM-side comparison."""

    @property
    def traffic(self) -> HBMTraffic:
        """PER-DEVICE fused HBM traffic (one shard of the launch)."""
        return self.sharded.device

    @property
    def staged_traffic(self) -> HBMTraffic:
        """PER-DEVICE staged-baseline HBM traffic."""
        return self.staged.device

    @property
    def mesh_shape(self) -> Tuple[int, int]:
        return self.sharded.mesh_shape

    @property
    def n_devices(self) -> int:
        return self.sharded.n_devices

    @property
    def collective(self) -> str:
        """The reduction layout the collectives were priced under."""
        return self.sharded.collective

    @property
    def collective_words(self) -> int:
        return self.sharded.collective_words

    @property
    def collective_bytes(self) -> int:
        return self.sharded.collective_bytes

    @property
    def in_layout(self) -> str:
        """Input layout the schedule was priced for (layout axis)."""
        return self.sharded.in_layout

    @property
    def out_layout(self) -> str:
        """Layout the block's output leaves in (sharded on c_out after a
        psum_scatter pass-2, replicated otherwise)."""
        return self.sharded.out_layout

    @property
    def transition_words(self) -> int:
        return self.sharded.transition_words

    @property
    def transition_bytes(self) -> int:
        """Entry-side layout repay (the all-gather a real-expand block
        pays to consume a c_in-sharded arrival)."""
        return self.sharded.transition_bytes

    @property
    def total_bytes(self) -> int:
        """All bytes moved anywhere (every device's HBM + collectives) —
        ``perfmodel.ShardedTraffic.total_bytes``, verbatim."""
        return self.sharded.total_bytes

    @property
    def staged_total_bytes(self) -> int:
        return self.staged.total_bytes

    @property
    def modeled_saving(self) -> float:
        """Fraction of staged bytes the fused schedule avoids."""
        base = self.staged.total_bytes
        return 1.0 - self.sharded.total_bytes / base if base else 0.0


@dataclass(frozen=True)
class FusedSchedule(_ScheduleTraffic):
    """One selected schedule for ``convdk_fused_separable``.

    The separable partitioning (c_out on "model") is collective-free, so
    its ``ShardedTraffic`` always has 0 collective words — the accounting
    view exists for symmetry with ``MBConvSchedule`` (doc on
    ``_ScheduleTraffic``)."""

    tile_h: int
    ci_block: int
    co_block: int
    sharded: ShardedTraffic      # fused pricing (the solver's objective)
    staged: ShardedTraffic       # identically partitioned staged baseline
    residency: str = DEFAULT_RESIDENCY   # input-staging mode


@dataclass(frozen=True)
class MBConvSchedule(_ScheduleTraffic):
    """One selected two-pass schedule for ``convdk_mbconv_fused``.

    Under a mesh the c_mid partitioning pays two cross-device reductions
    (SE squeeze + projection partials) priced inside ``sharded`` /
    ``staged`` under the schedule's **collective** axis — ring all-reduce
    or the psum_scatter pass-2 variant whose output leaves the kernel
    sharded on c_out (doc on ``_ScheduleTraffic``; ``self.collective``
    reads the solved mode)."""

    tile_h: int
    mode: str                    # "retain" | "recompute"
    ci_block: int
    cm_block: int
    co_block: int
    sharded: ShardedTraffic      # fused pricing (the solver's objective)
    staged: ShardedTraffic       # identically partitioned staged baseline
    residency: str = DEFAULT_RESIDENCY   # input-staging mode
    # entry-overlap the schedule was solved under: "pipelined" means this
    # block's pass 1 streams behind the upstream block's pass 2, so its
    # pass-1 footprint was feasibility-checked against HALF the VMEM
    # budget (the two co-resident stages split the core) — a genuinely
    # different solve, hence a cache-key axis (``ov=`` segment)
    overlap: str = DEFAULT_OVERLAP


@dataclass(frozen=True)
class FusedMBSchedule(_ScheduleTraffic):
    """One selected single-pass schedule for ``convdk_fusedmb_fused``.

    Fused-MBConv has no pass-2 mode axis (the whole block is one pass —
    its pass-2 figures are exactly zero, see
    ``perfmodel.fusedmb_pass_traffic``) and no layout axis (the dense
    conv needs all of c_in, so the entry is always replicated).  It keeps
    the residency, collective and overlap axes: the projection partial
    still reduces over the c_mid shards, and the block's single pass can
    still stream behind an upstream two-pass producer's pass 2 (the
    converse never holds — there is no pass 2 here to hide anything
    behind)."""

    tile_h: int
    ci_block: int
    cm_block: int
    co_block: int
    sharded: ShardedTraffic      # fused pricing (the solver's objective)
    staged: ShardedTraffic       # identically partitioned staged baseline
    residency: str = DEFAULT_RESIDENCY   # input-staging mode
    overlap: str = DEFAULT_OVERLAP       # entry overlap (see MBConvSchedule)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _blocks(c: int, cap: int) -> int:
    return min(cap, _round_up(c, 8))


# ---------------------------------------------------------------------------
# persistent schedule cache
# ---------------------------------------------------------------------------

_CACHE_DIR_ENV = "CONVDK_CACHE_DIR"
_CACHE_FILE = "convdk_schedules.json"


def _backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:  # pragma: no cover - jax always importable here
        return "unknown"


class ScheduleCache:
    """Two-layer schedule cache: in-process dict + optional JSON file.

    Disk entries store only the *decision* (tile_h, mode, source); traffic
    numbers are deterministic functions of the shape and are rebuilt by the
    model on load, so the file format survives model refinements.
    """

    def __init__(self, directory: Optional[Path]):
        self.directory = Path(directory).expanduser() if directory else None
        self._mem: Dict[str, dict] = {}
        self._disk: Optional[Dict[str, dict]] = None   # lazily loaded

    @property
    def path(self) -> Optional[Path]:
        return self.directory / _CACHE_FILE if self.directory else None

    @staticmethod
    def _migrate_key(key: str) -> str:
        """Upgrade legacy cache keys in place, chaining the six schema
        migrations so measured sweeps keep outranking model picks instead
        of being silently orphaned:

        * pre-mesh entries (5 segments, no ``mesh`` segment) were all
          solved single-device — they ARE the ``mesh1x1`` picks;
        * pre-residency entries (no ``res=`` segment) were solved before
          residency was a pinnable axis — they ARE the ``res=auto`` picks
          (the solver now chooses the residency; a legacy measured tile_h
          keeps its priority and the residency is re-solved at that
          tile_h, see ``get_fused_schedule``);
        * pre-collective MBConv entries (no ``coll=`` segment) were
          solved before the projection-reduction layout was an axis —
          they ARE the ``coll=auto`` picks (the collective is re-solved
          at the entry's (tile_h, mode, residency); separable keys never
          grow the segment — that partitioning is collective-free);
        * pre-layout MBConv entries (no ``layout=`` segment) were all
          solved for a REPLICATED input arrival — the only entry form
          that existed — so they ARE the ``layout=replicated`` picks
          (unlike residency/collective this axis is a dataflow fact the
          caller states, not a solver choice, so there is no ``auto``);
        * pre-overlap MBConv entries (no ``ov=`` segment) were all
          solved for a SERIAL entry — pipelined entries did not exist,
          and a serial pick was feasibility-checked against the full
          VMEM budget where a pipelined solve halves it — so they ARE
          the ``ov=serial`` picks (like layout, the entry overlap is a
          dataflow fact the network DP states: no ``auto``);
        * pre-family MBConv entries (no ``act=``/``se=`` segments) were
          all solved for the classic EfficientNet block — silu
          activations, SE present (the only variant that existed) — so
          they ARE the ``act=silu|se=on`` picks.  The ``se=off`` and
          non-silu variants are NEW entry forms: an SE-carrying
          schedule's pick must never be echoed for a block whose pass 1
          vanishes (``fusedmb`` keys are born with every segment and
          never migrate)."""
        parts = key.split("|")
        if len(parts) == 5 and parts[0] in ("sep", "mbconv") \
                and not parts[3].startswith("mesh"):
            parts.insert(3, "mesh1x1")
        if len(parts) == 6 and parts[0] in ("sep", "mbconv") \
                and parts[3].startswith("mesh") \
                and not parts[4].startswith("res="):
            parts.insert(4, "res=auto")
        if len(parts) >= 7 and parts[0] == "mbconv" \
                and parts[3].startswith("mesh") \
                and parts[4].startswith("res=") \
                and not parts[5].startswith("coll="):
            parts.insert(5, "coll=auto")
        if len(parts) >= 8 and parts[0] == "mbconv" \
                and parts[4].startswith("res=") \
                and parts[5].startswith("coll=") \
                and not parts[6].startswith("layout="):
            parts.insert(6, "layout=replicated")
        if len(parts) >= 9 and parts[0] == "mbconv" \
                and parts[5].startswith("coll=") \
                and parts[6].startswith("layout=") \
                and not parts[7].startswith("ov="):
            parts.insert(7, "ov=serial")
        if len(parts) >= 10 and parts[0] == "mbconv" \
                and parts[6].startswith("layout=") \
                and parts[7].startswith("ov=") \
                and not parts[8].startswith("act="):
            parts.insert(8, "act=silu")
            parts.insert(9, "se=on")
        return "|".join(parts)

    def _load_disk(self) -> Dict[str, dict]:
        if self._disk is None:
            self._disk = {}
            if self.path is not None:
                try:
                    payload = json.loads(self.path.read_text())
                    if payload.get("version") == 1:
                        for k, v in payload.get("entries", {}).items():
                            new_k = self._migrate_key(k)
                            if new_k != k:
                                telemetry.counter(
                                    "schedule_cache.migrated_keys")
                            self._disk[new_k] = v
                except (OSError, ValueError):
                    pass                   # unreadable cache = empty cache
        return self._disk

    def _flush(self) -> None:
        if self.path is None:
            return
        try:
            self.directory.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(".tmp")
            tmp.write_text(json.dumps(
                {"version": 1, "entries": self._load_disk()},
                indent=1, sort_keys=True))
            tmp.replace(self.path)
        except OSError:
            pass                           # persistence is best-effort

    def get(self, key: str) -> Optional[dict]:
        hit = self._mem.get(key)
        if hit is not None:
            telemetry.counter("schedule_cache.hit.memory")
            return hit
        hit = self._load_disk().get(key)
        if hit is not None:
            telemetry.counter("schedule_cache.hit.disk")
            self._mem[key] = hit
        else:
            telemetry.counter("schedule_cache.miss")
        return hit

    def put(self, key: str, entry: dict, persist: bool = True) -> None:
        telemetry.counter("schedule_cache.put")
        self._mem[key] = entry
        if persist and self.path is not None:
            disk = self._load_disk()
            # never let a model pick clobber a measured entry (malformed
            # old entries — non-dicts — are overwritten, not honored)
            old = disk.get(key)
            if isinstance(old, dict) and old.get("source") == "measured" \
                    and entry.get("source") != "measured":
                return
            disk[key] = entry
            self._flush()

    def clear_memory(self) -> None:
        """Drop the in-process layer (tests: force a disk round-trip)."""
        self._mem.clear()
        self._disk = None


_SCHEDULE_CACHE: Optional[ScheduleCache] = None


def get_schedule_cache() -> ScheduleCache:
    global _SCHEDULE_CACHE
    if _SCHEDULE_CACHE is None:
        env = os.environ.get(_CACHE_DIR_ENV)
        _SCHEDULE_CACHE = ScheduleCache(Path(env) if env else None)
    return _SCHEDULE_CACHE


def set_schedule_cache_dir(directory: Optional[os.PathLike]) -> ScheduleCache:
    """Point the persistent schedule cache at ``directory`` (None = memory
    only).  Resets the in-process layer so the new directory is
    authoritative."""
    global _SCHEDULE_CACHE
    _SCHEDULE_CACHE = ScheduleCache(
        Path(directory) if directory is not None else None)
    return _SCHEDULE_CACHE


def _tpu_key(tpu: TPUConfig) -> str:
    """Every TPUConfig field enters the key: a schedule solved (and
    VMEM-checked) under one config must never be reused for another."""
    ths = "x".join(str(t) for t in tpu.tile_h_candidates)
    return f"vmem{tpu.vmem_bytes}-cb{tpu.c_block}-th{ths}"


def _res_segment(residency: Optional[str]) -> str:
    """Key segment for the REQUESTED residency: a pinned mode gets its own
    entry (its pick is solved under a different feasibility set); ``None``
    (the solver chooses) is the ``res=auto`` entry that legacy keys migrate
    into."""
    if residency is not None:
        validate_residency(residency)
    return f"res={residency or 'auto'}"


def _sep_key(shape: SeparableShape, tpu: TPUConfig,
             mesh_shape: MeshShape = (1, 1),
             residency: Optional[str] = None,
             in_layout: str = DEFAULT_LAYOUT,
             collective: str = DEFAULT_COLLECTIVE) -> str:
    """Schedule-cache key.  The EFFECTIVE mesh factors are part of the key:
    a schedule solved for one partitioning (per-device shard shapes, psum
    terms, VMEM headroom) must never be echoed for another — sharded and
    unsharded picks live in distinct entries.  Likewise the requested
    residency (``res=auto`` when the solver chooses).  The sharded-c_in
    entry form gets its own entries via an APPENDED segment (the default
    replicated key format — and its migration chain — is untouched; the
    classic separable partitioning is collective-free, so only the
    sharded-in form carries a collective)."""
    dp, mp = shard_factors(shape.b, shape.c_out, mesh_shape)
    suffix = ""
    if validate_layout(in_layout) != DEFAULT_LAYOUT:
        # the sharded-in form partitions on c_in, so its EFFECTIVE factors
        # differ from the base key's c_out-derived mesh segment
        dpi, mpi = shard_factors(shape.b, shape.c_in, mesh_shape)
        suffix = (f"|inlay={in_layout}"
                  f"|coll={validate_collective(collective)}"
                  f"|inmesh{dpi}x{mpi}")
    return (f"sep|b{shape.b}-h{shape.h}-w{shape.w}-ci{shape.c_in}"
            f"-co{shape.c_out}-k{shape.k}-s{shape.s}|dtb{shape.dtype_bytes}"
            f"|mesh{dp}x{mp}|{_res_segment(residency)}|{_tpu_key(tpu)}"
            f"|{_backend()}{suffix}")


def _coll_segment(collective: Optional[str]) -> str:
    """Key segment for the REQUESTED collective mode (``coll=auto`` when
    the solver chooses — the segment legacy MBConv keys migrate into)."""
    if collective is not None:
        validate_collective(collective)
    return f"coll={collective or 'auto'}"


def _layout_segment(in_layout: str) -> str:
    """Key segment for the input-layout the schedule is priced for.  This
    axis has no ``auto``: the arrival layout is a dataflow fact the caller
    (or the network-level DP) states — legacy keys migrate into
    ``layout=replicated``, the only entry form that existed."""
    return f"layout={validate_layout(in_layout)}"


def _overlap_segment(overlap: str) -> str:
    """Key segment for the entry-overlap the schedule is solved under.
    Like ``layout=`` this axis has no ``auto``: the network DP states
    whether a block's pass 1 streams behind the upstream pass 2 (which
    halves the VMEM budget its pass-1 footprint may claim) — legacy keys
    migrate into ``ov=serial``, the only entry form that existed."""
    return f"ov={validate_overlap(overlap)}"


def _act_segment(act: str) -> str:
    """Key segment for the block's activation variant.  No ``auto``: the
    act is a model fact the caller states — legacy keys migrate into
    ``act=silu`` (the only variant that existed)."""
    return f"act={validate_act(act)}"


def _se_segment(shape: MBConvShape) -> str:
    """Key segment for the SE axis, derived from the shape: ``se_ratio``
    never entered the legacy key, so an SE-less block would collide with
    the SE form of the same dims — a genuinely different solve (its pass
    1 can vanish entirely).  Legacy keys migrate into ``se=on``."""
    return f"se={'on' if shape.has_se else 'off'}"


def _mbconv_key(shape: MBConvShape, tpu: TPUConfig,
                mesh_shape: MeshShape = (1, 1),
                residency: Optional[str] = None,
                mode: Optional[str] = None,
                collective: Optional[str] = None,
                in_layout: str = DEFAULT_LAYOUT,
                overlap: str = DEFAULT_OVERLAP,
                act: str = DEFAULT_ACT) -> str:
    dp, mp = shard_factors(shape.b, shape.c_mid, mesh_shape)
    # a pinned pass-2 mode gets its OWN entries (appended segment, so the
    # unpinned key format — and its migration chain — is untouched): a
    # tile_h/residency solved under one mode's VMEM footprint must never
    # be echoed for the other
    pin = f"|mode={mode}" if mode is not None else ""
    return (f"mbconv|b{shape.b}-h{shape.h}-w{shape.w}-ci{shape.c_in}"
            f"-cm{shape.c_mid}-co{shape.c_out}-k{shape.k}-s{shape.s}"
            f"|dtb{shape.dtype_bytes}|mesh{dp}x{mp}"
            f"|{_res_segment(residency)}|{_coll_segment(collective)}"
            f"|{_layout_segment(in_layout)}|{_overlap_segment(overlap)}"
            f"|{_act_segment(act)}|{_se_segment(shape)}"
            f"|{_tpu_key(tpu)}|{_backend()}{pin}")


def _entry_tile_h(hit, out_h: int):
    """Validated tile_h from a cache entry, or None if the entry is
    malformed or stale (a bad cache file must degrade to the model, never
    crash schedule lookup)."""
    try:
        tile_h = int(hit["tile_h"])
    except (TypeError, KeyError, ValueError):
        return None
    return tile_h if 1 <= tile_h <= out_h else None


def _entry_residency(hit) -> Optional[str]:
    """Validated residency from a cache entry; None for legacy entries
    (recorded before the residency axis) or malformed values — the caller
    then re-solves the residency at the entry's tile_h."""
    res = hit.get("residency") if isinstance(hit, dict) else None
    return res if res in RESIDENCY_MODES else None


def _entry_collective(hit) -> Optional[str]:
    """Validated collective mode from a cache entry; None for legacy
    entries (recorded before the collective axis) or malformed values —
    the caller then re-solves the collective at the entry's pick."""
    coll = hit.get("collective") if isinstance(hit, dict) else None
    return coll if coll in COLLECTIVE_MODES else None


# Solver preference among byte-identical collective modes: the ring
# all-reduce is the conservative default (output replicated, any consumer
# layout); ties essentially never occur — psum_scatter strictly undercuts
# the ring whenever the projection payload is nonzero.
_COLLECTIVE_RANK = {"ring_allreduce": 0, "psum_scatter": 1}


def _collective_set(shape: MBConvShape, eff: MeshShape,
                    collective: Optional[str]) -> Tuple[str, ...]:
    """Collective modes the solver may price at this partitioning.

    Off-mesh (effective model factor 1) the axis is degenerate: nothing
    crosses devices, so everything normalizes to the ring default — a
    scatter pin is meaningless there and is ignored rather than cached as
    a distinct non-schedule.  On-mesh, ``None`` enumerates the ring plus
    the psum_scatter pass-2 variant — non-dividing c_out no longer
    rejects a scatter: the kernel zero-pads the projection columns to
    the model factor and the model prices the padded payload
    (``perfmodel.scatter_c_out``)."""
    _dp, mp = eff
    if mp <= 1:
        return (DEFAULT_COLLECTIVE,)
    if collective is None:
        if can_psum_scatter(shape, eff):
            return COLLECTIVE_MODES
        return (DEFAULT_COLLECTIVE,)
    validate_collective(collective)
    return (collective,)


# ---------------------------------------------------------------------------
# separable (single-pass) schedules
# ---------------------------------------------------------------------------

def vmem_footprint_bytes(shape: SeparableShape, tile_h: int,
                         tpu: TPUConfig,
                         residency: str = DEFAULT_RESIDENCY) -> int:
    """Modeled VMEM residency of one fused grid cell under one residency.

    Counts the input staging (the strip-DMA slot buffer(s) — 2x for
    double-buffering — or the full-height resident block), the f32 DW
    accumulator, the f32 PW scratch accumulator and both weight blocks:
    the budget the staging engine's rendering of the kernel must respect.
    """
    ci = pick_channel_block(shape.c_in, tpu.c_block)
    co = _blocks(shape.c_out, tpu.c_block)
    tile_h = max(1, min(tile_h, shape.out_h))
    x_win = separable_staging_bytes(shape, tile_h, residency, tpu.c_block)
    dw_acc = tile_h * shape.out_w * ci * 4
    pw_acc = tile_h * shape.out_w * co * 4
    weights = (shape.k * shape.k * ci + ci * co) * shape.dtype_bytes
    return x_win + dw_acc + pw_acc + weights


def _residency_set(residency: Optional[str]) -> Tuple[str, ...]:
    if residency is None:
        return RESIDENCY_MODES
    validate_residency(residency)
    return (residency,)


def candidate_schedules(
    shape: SeparableShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
    in_layout: str = DEFAULT_LAYOUT, collective: str = DEFAULT_COLLECTIVE,
) -> Tuple[FusedSchedule, ...]:
    """All VMEM-feasible (tile_h, residency) schedules, model-priced.

    ``residency=None`` enumerates every staging mode (the solver's
    default); a pinned mode restricts the candidate set.  Under a mesh,
    feasibility and channel blocks are solved at the PER-DEVICE shard
    shape — batch/data with c_out/model for the default replicated entry,
    or c_in/model (full c_out, PW partial reduced per ``collective``) for
    the ``model_sharded`` entry form."""
    validate_layout(in_layout)
    local, eff = separable_shard(shape, mesh_shape, in_layout)
    ci = pick_channel_block(local.c_in, tpu.c_block)
    co = _blocks(local.c_out, tpu.c_block)
    out: list[FusedSchedule] = []
    seen = set()
    ths = [max(1, min(th, shape.out_h)) for th in tpu.tile_h_candidates]
    feasible = [(th, res) for th in ths for res in _residency_set(residency)
                if vmem_footprint_bytes(local, th, tpu, res)
                <= tpu.vmem_bytes]
    for th, res in feasible or [(1, residency or "strip_dma")]:
        if (th, res) in seen:
            continue
        seen.add((th, res))
        out.append(FusedSchedule(
            tile_h=th, ci_block=ci, co_block=co,
            sharded=sharded_separable_traffic(shape, th, eff, tpu.c_block,
                                              res, in_layout, collective),
            staged=sharded_separable_staged_traffic(shape, th, eff,
                                                    tpu.c_block),
            residency=res,
        ))
    return tuple(out)


def select_fused_schedule(
    shape: SeparableShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
    in_layout: str = DEFAULT_LAYOUT, collective: str = DEFAULT_COLLECTIVE,
) -> FusedSchedule:
    """Pick the (tile_h, residency) minimizing modeled total traffic —
    per-device HBM bytes across all devices plus collectives (ties ->
    larger tile_h: fewer grid cells, bigger MXU contractions; then the
    residency rank: double-buffered DMA > single-slot DMA > resident,
    since equal bytes moved earlier hide latency)."""
    cands = candidate_schedules(shape, tpu, mesh_shape, residency,
                                in_layout, collective)
    return min(cands, key=lambda c: (c.total_bytes, -c.tile_h,
                                     _RESIDENCY_RANK[c.residency]))


def _schedule_at(shape: SeparableShape, tile_h: int, tpu: TPUConfig,
                 mesh_shape: MeshShape = (1, 1),
                 residency: str = DEFAULT_RESIDENCY,
                 in_layout: str = DEFAULT_LAYOUT,
                 collective: str = DEFAULT_COLLECTIVE) -> FusedSchedule:
    local, eff = separable_shard(shape, mesh_shape, in_layout)
    return FusedSchedule(
        tile_h=tile_h,
        ci_block=pick_channel_block(local.c_in, tpu.c_block),
        co_block=_blocks(local.c_out, tpu.c_block),
        sharded=sharded_separable_traffic(shape, tile_h, eff, tpu.c_block,
                                          residency, in_layout, collective),
        staged=sharded_separable_staged_traffic(shape, tile_h, eff,
                                                tpu.c_block),
        residency=residency,
    )


def _solve_residency_at(shape: SeparableShape, tile_h: int, tpu: TPUConfig,
                        mesh_shape: MeshShape,
                        in_layout: str = DEFAULT_LAYOUT) -> str:
    """Best residency at a FIXED tile_h (legacy cache entries pin tile_h
    but predate the residency axis): min bytes among VMEM-feasible modes,
    ties broken by the residency rank."""
    local, eff = separable_shard(shape, mesh_shape, in_layout)
    modes = [res for res in RESIDENCY_MODES
             if vmem_footprint_bytes(local, tile_h, tpu, res)
             <= tpu.vmem_bytes] or ["strip_dma"]
    return min(modes, key=lambda res: (
        sharded_separable_traffic(shape, tile_h, eff, tpu.c_block, res,
                                  in_layout).device.total_bytes,
        _RESIDENCY_RANK[res]))


def get_fused_schedule(
    b: int, h: int, w: int, c_in: int, c_out: int, k: int, s: int,
    dtype_bytes: int = 4, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
    in_layout: str = DEFAULT_LAYOUT, collective: str = DEFAULT_COLLECTIVE,
) -> FusedSchedule:
    """Cached per-layer-shape schedule lookup (trace-time safe).

    Consults the in-process cache, then the JSON cache (where a measured
    sweep may have recorded ground truth), then the analytical model.
    ``mesh_shape`` is the ("data", "model") partitioning the schedule will
    run under and ``residency`` the requested staging pin (None = solver's
    choice) — both are cache-key axes, so different partitionings or pins
    never collide; the sharded-c_in entry form (``in_layout`` +
    ``collective``) gets its own appended key segments.  Legacy entries
    (pre-residency) keep their tile_h priority; the residency is
    re-solved at that tile_h."""
    shape = SeparableShape(b=b, h=h, w=w, c_in=c_in, c_out=c_out, k=k, s=s,
                           dtype_bytes=dtype_bytes)
    cache = get_schedule_cache()
    key = _sep_key(shape, tpu, mesh_shape, residency, in_layout, collective)
    hit = cache.get(key)
    tile_h = _entry_tile_h(hit, shape.out_h) if hit is not None else None
    if tile_h is not None:
        res = residency or _entry_residency(hit) \
            or _solve_residency_at(shape, tile_h, tpu, mesh_shape, in_layout)
        return _schedule_at(shape, tile_h, tpu, mesh_shape, res,
                            in_layout, collective)
    sched = select_fused_schedule(shape, tpu, mesh_shape, residency,
                                  in_layout, collective)
    telemetry.counter("autotune.solve.separable")
    telemetry.counter(f"autotune.pick.residency.{sched.residency}")
    cache.put(key, {"tile_h": sched.tile_h, "residency": sched.residency,
                    "source": "model", "recorded_at": time.time()})
    return sched


# ---------------------------------------------------------------------------
# MBConv (two-pass) schedules
# ---------------------------------------------------------------------------

def mbconv_vmem_footprint_bytes(shape: MBConvShape, tile_h: int,
                                tpu: TPUConfig,
                                residency: str = DEFAULT_RESIDENCY,
                                mode: str = "retain") -> int:
    """Modeled VMEM residency of one two-pass MBConv grid cell.

    The dominant terms are the input staging (slot buffers or the resident
    block; ``retain`` adds the pass-2 DW re-read stream) and the f32
    expand accumulator over the staged strip window at ``cm_block`` lanes
    (pass 1 and recompute pass 2 share it); pass 2 adds the f32 projection
    accumulator.  Summing both passes' terms is deliberately conservative
    — the launches are separate, but a schedule that only fits one of them
    is not worth distinguishing."""
    ci = pick_channel_block(shape.c_in, tpu.c_block)
    cm = pick_channel_block(shape.c_mid, tpu.c_block)
    co = _blocks(shape.c_out, tpu.c_block)
    tile_h = max(1, min(tile_h, shape.out_h))
    in_rows = (tile_h - 1) * shape.s + shape.k
    w_need = (shape.out_w - 1) * shape.s + shape.k
    staging = mbconv_staging_bytes(shape, tile_h, mode, residency,
                                   tpu.c_block)
    exp_acc = in_rows * w_need * cm * 4
    dw_blk = tile_h * shape.out_w * cm * 4
    proj_acc = tile_h * shape.out_w * co * 4
    weights = (ci * cm + shape.k * shape.k * cm + cm * co) * shape.dtype_bytes
    return staging + exp_acc + dw_blk + proj_acc + weights


def mbconv_pass_vmem_bytes(shape: MBConvShape, tile_h: int,
                           tpu: TPUConfig,
                           residency: str = DEFAULT_RESIDENCY,
                           mode: str = "retain") -> Tuple[int, int]:
    """``mbconv_vmem_footprint_bytes`` split by pass: ``(pass1, pass2)``
    bytes, summing EXACTLY to the whole-cell footprint (property-tested —
    the conservative serial feasibility check is unchanged by the split).

    Pass 1 holds the input staging, the expand accumulator, the DW block
    and the expand/DW weights; pass 2 holds the DW re-read stream (retain
    — the staging split between the x window and the DW slots follows
    ``mbconv_staging_bytes``) or the recompute re-run of pass 1's input
    terms, plus the projection accumulator and weight.  Cross-block
    pipelining co-resides block i's pass 2 with block i+1's pass 1, so
    the overlap feasibility check is per-pass against HALF the budget
    (``_OVERLAP_VMEM_DIV``), not the summed footprint against all of it.
    """
    ci = pick_channel_block(shape.c_in, tpu.c_block)
    cm = pick_channel_block(shape.c_mid, tpu.c_block)
    co = _blocks(shape.c_out, tpu.c_block)
    tile_h = max(1, min(tile_h, shape.out_h))
    in_rows = (tile_h - 1) * shape.s + shape.k
    w_need = (shape.out_w - 1) * shape.s + shape.k
    # x-window staging only (the recompute form of the staging model);
    # the retain total adds the pass-2 DW re-read slots on top
    x_stage = mbconv_staging_bytes(shape, tile_h, "recompute", residency,
                                   tpu.c_block)
    dw_stage = mbconv_staging_bytes(shape, tile_h, mode, residency,
                                    tpu.c_block) - x_stage
    exp_acc = in_rows * w_need * cm * 4
    dw_blk = tile_h * shape.out_w * cm * 4
    proj_acc = tile_h * shape.out_w * co * 4
    w_p1 = (ci * cm + shape.k * shape.k * cm) * shape.dtype_bytes
    w_p2 = cm * co * shape.dtype_bytes
    pass1 = x_stage + exp_acc + dw_blk + w_p1
    if mode == "retain":
        pass2 = dw_stage + proj_acc + w_p2
    else:
        # recompute pass 2 re-runs the expand+DW front end; it owns the
        # whole-cell terms minus what pass 1 already counted (the sum
        # must stay identical, so pass 2 carries only the projection side)
        pass2 = proj_acc + w_p2
    return pass1, pass2


# A pipelined entry co-resides two stages on one core (upstream pass 2 +
# this block's pass 1), so each stage may claim at most half the budget.
_OVERLAP_VMEM_DIV = 2


def _overlap_vmem_ok(shape: MBConvShape, tile_h: int, tpu: TPUConfig,
                     residency: str, mode: str) -> bool:
    """Pipelined-entry feasibility for THIS block's pass 1: it must fit
    the halved budget while the upstream pass 2 holds the other half.
    (The upstream side is checked symmetrically by the network DP.)"""
    p1, _p2 = mbconv_pass_vmem_bytes(shape, tile_h, tpu, residency, mode)
    return p1 <= tpu.vmem_bytes // _OVERLAP_VMEM_DIV


def candidate_mbconv_schedules(
    shape: MBConvShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
    mode: Optional[str] = None, collective: Optional[str] = None,
    in_layout: str = DEFAULT_LAYOUT, overlap: str = DEFAULT_OVERLAP,
) -> Tuple[MBConvSchedule, ...]:
    """All VMEM-feasible (tile_h, mode, residency, collective) schedules,
    model-priced.

    A pinned ``mode`` restricts the candidate set, so tile_h/residency are
    solved (and VMEM-checked) under THAT mode's footprint — a retain pin
    must pay for the retained-DW stream buffers the recompute winner never
    carried.  Under a mesh, feasibility and channel blocks are solved at
    the per-device shard shape (batch/data, c_mid/model); the
    retain/recompute crossover therefore re-solves per partitioning — a
    shard's DW slice is mp-fold cheaper to retain than the whole expanded
    tensor.  The **collective** axis (projection reduction layout) only
    exists on-mesh: ring all-reduce always, psum_scatter on any on-mesh
    layer (non-dividing c_out pads to the model factor); it does not
    enter the VMEM check — both layouts run the identical kernels.

    ``in_layout`` is the ARRIVAL layout of the block input (a dataflow
    fact, not a solver axis): an identity-expand block consumes a
    ``model_sharded`` arrival collective-free with c_in sharded alongside
    c_mid (feasibility and channel blocks re-solved at the smaller
    shard), while a real expand prices the entry all-gather it must pay
    (``ShardedTraffic.transition_words``).

    ``overlap`` is, like the layout, a dataflow fact the network DP
    states: a ``pipelined`` entry co-resides this block's pass 1 with the
    upstream block's pass 2, so candidates must ALSO fit their pass-1
    footprint into half the VMEM budget (``_overlap_vmem_ok``) — a
    genuinely different feasibility set, hence a different solve."""
    if mode is not None and mode not in MBCONV_MODES:
        raise ValueError(mode)
    validate_layout(in_layout)
    validate_overlap(overlap)
    modes = MBCONV_MODES if mode is None else (mode,)
    local, eff = mbconv_shard(shape, mesh_shape, in_layout)
    colls = _collective_set(shape, eff, collective)
    ci = pick_channel_block(local.c_in, tpu.c_block)
    cm = pick_channel_block(local.c_mid, tpu.c_block)
    co = _blocks(local.c_out, tpu.c_block)
    out: list[MBConvSchedule] = []
    seen = set()
    ths = [max(1, min(th, shape.out_h)) for th in tpu.tile_h_candidates]
    combos = [(th, md, res)
              for th in ths for md in modes
              for res in _residency_set(residency)
              if mbconv_vmem_footprint_bytes(local, th, tpu, res, md)
              <= tpu.vmem_bytes
              and (overlap == DEFAULT_OVERLAP
                   or _overlap_vmem_ok(local, th, tpu, res, md))]
    if not combos:
        combos = [(1, md, residency or "strip_dma") for md in modes]
    staged_cache: dict = {}
    for th, md, res in combos:
        for coll in colls:
            if (th, md, res, coll) in seen:
                continue
            seen.add((th, md, res, coll))
            if (th, coll) not in staged_cache:
                staged_cache[th, coll] = sharded_mbconv_staged_traffic(
                    shape, th, eff, tpu.c_block, coll, in_layout)
            out.append(MBConvSchedule(
                tile_h=th, mode=md, ci_block=ci, cm_block=cm, co_block=co,
                sharded=sharded_mbconv_traffic(shape, th, md, eff,
                                               tpu.c_block, res, coll,
                                               in_layout),
                staged=staged_cache[th, coll],
                residency=res, overlap=overlap,
            ))
    return tuple(out)


def select_mbconv_schedule(
    shape: MBConvShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
    mode: Optional[str] = None, collective: Optional[str] = None,
    in_layout: str = DEFAULT_LAYOUT, overlap: str = DEFAULT_OVERLAP,
) -> MBConvSchedule:
    """Pick (tile_h, mode, residency, collective) minimizing modeled total
    two-pass traffic (ties -> larger tile_h, then retain: one DW
    round-trip beats recompute MACs; then the residency rank, then the
    ring default).  ``mode``/``residency``/``collective`` pins restrict
    the solve; ``in_layout`` states the arrival layout — and ``overlap``
    the entry overlap — the schedule must be priced/checked for."""
    cands = candidate_mbconv_schedules(shape, tpu, mesh_shape, residency,
                                       mode, collective, in_layout, overlap)
    return min(cands, key=lambda c: (c.total_bytes, -c.tile_h,
                                     c.mode != "retain",
                                     _RESIDENCY_RANK[c.residency],
                                     _COLLECTIVE_RANK[c.collective]))


def _mbconv_schedule_at(shape: MBConvShape, tile_h: int, mode: str,
                        tpu: TPUConfig, mesh_shape: MeshShape = (1, 1),
                        residency: str = DEFAULT_RESIDENCY,
                        collective: str = DEFAULT_COLLECTIVE,
                        in_layout: str = DEFAULT_LAYOUT,
                        overlap: str = DEFAULT_OVERLAP
                        ) -> MBConvSchedule:
    local, eff = mbconv_shard(shape, mesh_shape, in_layout)
    if eff[1] <= 1:
        collective = DEFAULT_COLLECTIVE   # degenerate axis: nothing crosses
        in_layout = DEFAULT_LAYOUT
    return MBConvSchedule(
        tile_h=tile_h, mode=mode,
        ci_block=pick_channel_block(local.c_in, tpu.c_block),
        cm_block=pick_channel_block(local.c_mid, tpu.c_block),
        co_block=_blocks(local.c_out, tpu.c_block),
        sharded=sharded_mbconv_traffic(shape, tile_h, mode, eff,
                                       tpu.c_block, residency, collective,
                                       in_layout),
        staged=sharded_mbconv_staged_traffic(shape, tile_h, eff,
                                             tpu.c_block, collective,
                                             in_layout),
        residency=residency, overlap=overlap,
    )


def _solve_mbconv_residency_at(shape: MBConvShape, tile_h: int, mode: str,
                               tpu: TPUConfig, mesh_shape: MeshShape,
                               in_layout: str = DEFAULT_LAYOUT) -> str:
    """Best residency at a FIXED (tile_h, mode) — see
    ``_solve_residency_at``.  Collective words are residency-invariant,
    so per-device bytes decide."""
    local, eff = mbconv_shard(shape, mesh_shape, in_layout)
    modes = [res for res in RESIDENCY_MODES
             if mbconv_vmem_footprint_bytes(local, tile_h, tpu, res, mode)
             <= tpu.vmem_bytes] or ["strip_dma"]
    return min(modes, key=lambda res: (
        sharded_mbconv_traffic(shape, tile_h, mode, eff, tpu.c_block,
                               res, in_layout=in_layout).device.total_bytes,
        _RESIDENCY_RANK[res]))


def _solve_mbconv_collective_at(shape: MBConvShape, tile_h: int, mode: str,
                                tpu: TPUConfig, mesh_shape: MeshShape,
                                residency: str,
                                in_layout: str = DEFAULT_LAYOUT) -> str:
    """Best collective at a FIXED (tile_h, mode, residency) — legacy
    cache entries predate the collective axis: min total bytes among the
    runnable layouts, ties to the ring default."""
    _local, eff = mbconv_shard(shape, mesh_shape, in_layout)
    return min(_collective_set(shape, eff, None), key=lambda coll: (
        sharded_mbconv_traffic(shape, tile_h, mode, eff, tpu.c_block,
                               residency, coll, in_layout).total_bytes,
        _COLLECTIVE_RANK[coll]))


def get_mbconv_schedule(
    b: int, h: int, w: int, c_in: int, c_mid: int, c_out: int, k: int,
    s: int, se_ratio: float = 0.25, dtype_bytes: int = 4,
    tpu: TPUConfig = TPUConfig(), mesh_shape: MeshShape = (1, 1),
    residency: Optional[str] = None, mode: Optional[str] = None,
    collective: Optional[str] = None, in_layout: str = DEFAULT_LAYOUT,
    overlap: str = DEFAULT_OVERLAP, act: str = DEFAULT_ACT,
) -> MBConvSchedule:
    """Cached per-layer-shape two-pass schedule lookup (trace-time safe).

    ``mesh_shape`` and the requested ``residency``/``mode``/``collective``
    pins enter the cache key (see ``get_fused_schedule``): a pinned
    pass-2 mode solves tile_h and residency under that mode's VMEM
    footprint instead of echoing a schedule solved for the other mode,
    and a pinned collective prices (and caches) under that reduction
    layout only.  ``in_layout`` (the arrival layout — a dataflow fact the
    caller states) is a key axis too: a schedule feasibility-checked at
    the c_in-sharded entry shape must never be echoed for a replicated
    arrival.  Legacy entries keep their (tile_h, mode) priority with the
    residency — and, for pre-collective entries, the collective —
    re-solved at that point; pre-layout entries migrate into
    ``layout=replicated`` and pre-overlap entries into ``ov=serial``
    (the only entry forms that existed).  ``overlap`` — the entry
    overlap the network DP states — is a key axis for the same reason
    ``in_layout`` is: a pipelined entry's picks were feasibility-checked
    against the halved VMEM budget and must never be echoed for a serial
    entry (or vice versa).  ``act`` and the SE axis (derived from
    ``se_ratio``) are key segments too: an SE-less block's pass 1 can
    vanish entirely, so its picks live apart from the classic form's —
    legacy entries migrate into ``act=silu|se=on``, the only variant
    that existed, with no cold re-solve."""
    shape = MBConvShape(b=b, h=h, w=w, c_in=c_in, c_mid=c_mid, c_out=c_out,
                        k=k, s=s, se_ratio=se_ratio, dtype_bytes=dtype_bytes)
    cache = get_schedule_cache()
    key = _mbconv_key(shape, tpu, mesh_shape, residency, mode, collective,
                      in_layout, overlap, act)
    hit = cache.get(key)
    tile_h = _entry_tile_h(hit, shape.out_h) if hit is not None else None
    hit_mode = hit.get("mode") if isinstance(hit, dict) else None
    if tile_h is not None and hit_mode in MBCONV_MODES \
            and (mode is None or hit_mode == mode):
        res = residency or _entry_residency(hit) \
            or _solve_mbconv_residency_at(shape, tile_h, hit_mode, tpu,
                                          mesh_shape, in_layout)
        coll = collective or _entry_collective(hit) \
            or _solve_mbconv_collective_at(shape, tile_h, hit_mode, tpu,
                                           mesh_shape, res, in_layout)
        return _mbconv_schedule_at(shape, tile_h, hit_mode, tpu,
                                   mesh_shape, res, coll, in_layout,
                                   overlap)
    sched = select_mbconv_schedule(shape, tpu, mesh_shape, residency, mode,
                                   collective, in_layout, overlap)
    telemetry.counter("autotune.solve.mbconv")
    telemetry.counter(f"autotune.pick.residency.{sched.residency}")
    telemetry.counter(f"autotune.pick.mode.{sched.mode}")
    telemetry.counter(f"autotune.pick.collective.{sched.collective}")
    cache.put(key, {"tile_h": sched.tile_h, "mode": sched.mode,
                    "residency": sched.residency,
                    "collective": sched.collective,
                    "in_layout": sched.in_layout,
                    "overlap": sched.overlap, "source": "model",
                    "recorded_at": time.time()})
    return sched


# ---------------------------------------------------------------------------
# Fused-MBConv (single-pass) schedules
# ---------------------------------------------------------------------------

def _fusedmb_shape(b, h, w, c_in, c_mid, c_out, k, s,
                   dtype_bytes: int = 4) -> MBConvShape:
    """Fused-MBConv blocks reuse the MBConvShape vocabulary with
    ``se_ratio=0`` pinned (the family never carries SE)."""
    return MBConvShape(b=b, h=h, w=w, c_in=c_in, c_mid=c_mid, c_out=c_out,
                       k=k, s=s, se_ratio=0.0, dtype_bytes=dtype_bytes)


def _fusedmb_key(shape: MBConvShape, tpu: TPUConfig,
                 mesh_shape: MeshShape = (1, 1),
                 residency: Optional[str] = None,
                 collective: Optional[str] = None,
                 overlap: str = DEFAULT_OVERLAP,
                 act: str = DEFAULT_ACT) -> str:
    """Schedule-cache key for the Fused-MBConv family.  Born with every
    segment (``act=`` included) — there are no legacy fusedmb entries, so
    the key never migrates.  No ``layout=`` or ``se=`` segments: the
    entry is always replicated and the family never carries SE (both are
    family invariants, not axes)."""
    dp, mp = shard_factors(shape.b, shape.c_mid, mesh_shape)
    return (f"fusedmb|b{shape.b}-h{shape.h}-w{shape.w}-ci{shape.c_in}"
            f"-cm{shape.c_mid}-co{shape.c_out}-k{shape.k}-s{shape.s}"
            f"|dtb{shape.dtype_bytes}|mesh{dp}x{mp}"
            f"|{_res_segment(residency)}|{_coll_segment(collective)}"
            f"|{_overlap_segment(overlap)}|{_act_segment(act)}"
            f"|{_tpu_key(tpu)}|{_backend()}")


def fusedmb_vmem_footprint_bytes(shape: MBConvShape, tile_h: int,
                                 tpu: TPUConfig,
                                 residency: str = DEFAULT_RESIDENCY) -> int:
    """Modeled VMEM residency of one single-pass Fused-MBConv grid cell:
    the input staging, the f32 dense-conv accumulator and f32 projection
    accumulator (both live the whole cell — the conv output feeds the
    projection without leaving VMEM) and both weight blocks."""
    ci = pick_channel_block(shape.c_in, tpu.c_block)
    cm = pick_channel_block(shape.c_mid, tpu.c_block)
    co = _blocks(shape.c_out, tpu.c_block)
    tile_h = max(1, min(tile_h, shape.out_h))
    staging = fusedmb_staging_bytes(shape, tile_h, residency, tpu.c_block)
    conv_acc = tile_h * shape.out_w * cm * 4
    proj_acc = tile_h * shape.out_w * co * 4
    weights = (shape.k * shape.k * ci * cm + cm * co) * shape.dtype_bytes
    return staging + conv_acc + proj_acc + weights


def candidate_fusedmb_schedules(
    shape: MBConvShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
    collective: Optional[str] = None, overlap: str = DEFAULT_OVERLAP,
) -> Tuple[FusedMBSchedule, ...]:
    """All VMEM-feasible (tile_h, residency, collective) single-pass
    schedules, model-priced.  A ``pipelined`` entry checks the WHOLE cell
    footprint against half the budget — the single pass IS the block's
    pass 1, so there is no cheaper per-pass split to co-reside."""
    validate_overlap(overlap)
    local, eff = fusedmb_shard(shape, mesh_shape)
    colls = _collective_set(shape, eff, collective)
    ci = pick_channel_block(local.c_in, tpu.c_block)
    cm = pick_channel_block(local.c_mid, tpu.c_block)
    co = _blocks(local.c_out, tpu.c_block)
    budget = tpu.vmem_bytes if overlap == DEFAULT_OVERLAP \
        else tpu.vmem_bytes // _OVERLAP_VMEM_DIV
    out: list[FusedMBSchedule] = []
    seen = set()
    ths = [max(1, min(th, shape.out_h)) for th in tpu.tile_h_candidates]
    feasible = [(th, res) for th in ths for res in _residency_set(residency)
                if fusedmb_vmem_footprint_bytes(local, th, tpu, res)
                <= budget]
    if not feasible:
        feasible = [(1, residency or "strip_dma")]
    staged_cache: dict = {}
    for th, res in feasible:
        for coll in colls:
            if (th, res, coll) in seen:
                continue
            seen.add((th, res, coll))
            if (th, coll) not in staged_cache:
                staged_cache[th, coll] = sharded_fusedmb_staged_traffic(
                    shape, th, eff, tpu.c_block, coll)
            out.append(FusedMBSchedule(
                tile_h=th, ci_block=ci, cm_block=cm, co_block=co,
                sharded=sharded_fusedmb_traffic(shape, th, eff, tpu.c_block,
                                                res, coll),
                staged=staged_cache[th, coll],
                residency=res, overlap=overlap,
            ))
    return tuple(out)


def select_fusedmb_schedule(
    shape: MBConvShape, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
    collective: Optional[str] = None, overlap: str = DEFAULT_OVERLAP,
) -> FusedMBSchedule:
    """Pick (tile_h, residency, collective) minimizing modeled total
    traffic (ties -> larger tile_h, then the residency rank, then the
    ring default) — the MBConv objective minus the mode axis."""
    cands = candidate_fusedmb_schedules(shape, tpu, mesh_shape, residency,
                                        collective, overlap)
    return min(cands, key=lambda c: (c.total_bytes, -c.tile_h,
                                     _RESIDENCY_RANK[c.residency],
                                     _COLLECTIVE_RANK[c.collective]))


def _fusedmb_schedule_at(shape: MBConvShape, tile_h: int, tpu: TPUConfig,
                         mesh_shape: MeshShape = (1, 1),
                         residency: str = DEFAULT_RESIDENCY,
                         collective: str = DEFAULT_COLLECTIVE,
                         overlap: str = DEFAULT_OVERLAP) -> FusedMBSchedule:
    local, eff = fusedmb_shard(shape, mesh_shape)
    if eff[1] <= 1:
        collective = DEFAULT_COLLECTIVE   # degenerate axis: nothing crosses
    return FusedMBSchedule(
        tile_h=tile_h,
        ci_block=pick_channel_block(local.c_in, tpu.c_block),
        cm_block=pick_channel_block(local.c_mid, tpu.c_block),
        co_block=_blocks(local.c_out, tpu.c_block),
        sharded=sharded_fusedmb_traffic(shape, tile_h, eff, tpu.c_block,
                                        residency, collective),
        staged=sharded_fusedmb_staged_traffic(shape, tile_h, eff,
                                              tpu.c_block, collective),
        residency=residency, overlap=overlap,
    )


def _solve_fusedmb_residency_at(shape: MBConvShape, tile_h: int,
                                tpu: TPUConfig,
                                mesh_shape: MeshShape) -> str:
    """Best residency at a FIXED tile_h (cache entries whose residency
    field is missing or stale) — see ``_solve_residency_at``."""
    local, eff = fusedmb_shard(shape, mesh_shape)
    modes = [res for res in RESIDENCY_MODES
             if fusedmb_vmem_footprint_bytes(local, tile_h, tpu, res)
             <= tpu.vmem_bytes] or ["strip_dma"]
    return min(modes, key=lambda res: (
        sharded_fusedmb_traffic(shape, tile_h, eff, tpu.c_block,
                                res).device.total_bytes,
        _RESIDENCY_RANK[res]))


def _solve_fusedmb_collective_at(shape: MBConvShape, tile_h: int,
                                 tpu: TPUConfig, mesh_shape: MeshShape,
                                 residency: str) -> str:
    """Best collective at a FIXED (tile_h, residency), ties to the ring
    default — see ``_solve_mbconv_collective_at``."""
    _local, eff = fusedmb_shard(shape, mesh_shape)
    return min(_collective_set(shape, eff, None), key=lambda coll: (
        sharded_fusedmb_traffic(shape, tile_h, eff, tpu.c_block,
                                residency, coll).total_bytes,
        _COLLECTIVE_RANK[coll]))


def get_fusedmb_schedule(
    b: int, h: int, w: int, c_in: int, c_mid: int, c_out: int, k: int,
    s: int, dtype_bytes: int = 4, tpu: TPUConfig = TPUConfig(),
    mesh_shape: MeshShape = (1, 1), residency: Optional[str] = None,
    collective: Optional[str] = None, overlap: str = DEFAULT_OVERLAP,
    act: str = DEFAULT_ACT,
) -> FusedMBSchedule:
    """Cached per-layer-shape single-pass schedule lookup (trace-time
    safe) for the Fused-MBConv family — the third pipeline next to
    ``get_fused_schedule`` (separable) and ``get_mbconv_schedule``.  Same
    cache discipline: mesh, pins, overlap and act are key axes; the
    family has no mode (single pass), no se (never carried) and no
    layout (always replicated) axis."""
    shape = _fusedmb_shape(b, h, w, c_in, c_mid, c_out, k, s, dtype_bytes)
    cache = get_schedule_cache()
    key = _fusedmb_key(shape, tpu, mesh_shape, residency, collective,
                       overlap, act)
    hit = cache.get(key)
    tile_h = _entry_tile_h(hit, shape.out_h) if hit is not None else None
    if tile_h is not None:
        res = residency or _entry_residency(hit) \
            or _solve_fusedmb_residency_at(shape, tile_h, tpu, mesh_shape)
        coll = collective or _entry_collective(hit) \
            or _solve_fusedmb_collective_at(shape, tile_h, tpu, mesh_shape,
                                            res)
        return _fusedmb_schedule_at(shape, tile_h, tpu, mesh_shape, res,
                                    coll, overlap)
    sched = select_fusedmb_schedule(shape, tpu, mesh_shape, residency,
                                    collective, overlap)
    telemetry.counter("autotune.solve.fusedmb")
    telemetry.counter(f"autotune.pick.residency.{sched.residency}")
    telemetry.counter(f"autotune.pick.collective.{sched.collective}")
    cache.put(key, {"tile_h": sched.tile_h, "residency": sched.residency,
                    "collective": sched.collective, "source": "model",
                    "recorded_at": time.time()})
    return sched


# ---------------------------------------------------------------------------
# network-level layout solving (MIREDO-style chain DP)
#
# PR 5's per-layer solver flips every on-mesh B0 block to psum_scatter —
# but a per-layer pick cannot see that no consumer keeps the c_out-sharded
# output, so chained blocks silently repay the all-gather at the next
# entry and the scatter win cancels exactly (scatter + repay-gather ==
# ring, word for word — the collective accounting makes that an identity,
# not an estimate).  The DP below solves the CHAIN: states are boundary
# layouts, per-element costs come from ``select_mbconv_schedule`` under
# pinned (collective, in_layout), and boundary transitions are priced by
# ``perfmodel.layout_transition_words``.  The strict network-level win
# comes from the two places the tie theorem does not apply:
#
# * the stem boundary — a model-sharded stem output is materialized once
#   per element instead of once per device of each model group, and
# * identity-expand consumers (B0's block0 is the only e == 1 block) —
#   their entry takes a c_in-sharded arrival collective-free with every
#   pass-1 strip read shrunk by the model factor.
#
# Every e > 1 boundary provably ties: the dense expand needs ALL of c_in
# on every device, so a sharded arrival must be gathered back (priced as
# ``transition_words``), and scatter+gather == ring.  The DP therefore
# keeps interior boundaries replicated (ring exits) and shards exactly
# the boundaries that pay — reversing PR 5's scatter-everywhere greedy.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BlockRow:
    """One family-generic network-chain element: the block FAMILY is data
    on the row, not code in the solver.  Legacy 7-tuples (h, w, c_in,
    c_mid, c_out, k, s) remain accepted everywhere rows are consumed and
    mean ``family="mbconv"`` at the chain-wide ``se_ratio`` — BlockRow is
    how a chain mixes families (EfficientNet-V2's fused stages + MBConv
    tail) and per-block act/SE variants (MobileNet-V3) in one solve."""

    h: int
    w: int
    c_in: int
    c_mid: int
    c_out: int
    k: int
    s: int
    family: str = "mbconv"       # "mbconv" | "fusedmb"
    act: str = DEFAULT_ACT
    se_ratio: float = 0.25       # <= 0 means no SE; ignored for fusedmb

    def __post_init__(self):
        if self.family not in CHAIN_FAMILIES:
            raise ValueError(
                f"family must be one of {CHAIN_FAMILIES}, "
                f"got {self.family!r}")
        validate_act(self.act)
        if self.family == "fusedmb" and self.se_ratio > 0:
            # the family never carries SE — normalize rather than trip
            # every table builder over the default
            object.__setattr__(self, "se_ratio", 0.0)


@dataclass(frozen=True)
class BlockPlan:
    """One chain element's solved assignment inside a ``NetworkPlan``."""

    index: int
    shape: MBConvShape
    in_layout: str               # arrival layout the entry consumes
    out_layout: str              # layout the output leaves in
    # per-layer solve under the pinned axes: MBConvSchedule for the
    # two-pass family, FusedMBSchedule for the single-pass one
    schedule: "MBConvSchedule | FusedMBSchedule"
    boundary_words: int          # all-gather repay paid AT this entry
    # overlap of the boundary ENTERING this block (upstream pass 2 vs
    # this block's pass 1); "pipelined" only where the annotation pass
    # proved eligibility — see ``_annotate_overlap``
    entry_overlap: str = DEFAULT_OVERLAP
    # the per-pass cost split the latency accessors price (filled by the
    # solvers; None for hand-built plans, re-derived lazily)
    pass_costs: Optional[MBConvPassCosts] = None
    family: str = "mbconv"       # which pipeline runs this element
    act: str = DEFAULT_ACT       # activation variant (model fact)

    @property
    def boundary_bytes(self) -> int:
        return self.boundary_words * self.shape.dtype_bytes


@dataclass(frozen=True)
class NetworkPlan:
    """A solved (or greedy-reference) layout chain for a block sequence.

    The chain is the stem output plus every MBConv block: the stem is
    element 0 of the dataflow (its output materialization is priced per
    layout — a replicated stem writes the full activation on every device
    of each model group; a sharded one writes each element once), then
    each block carries its per-layer schedule plus the boundary repay its
    entry paid.  ``head_boundary_words`` is the final repay when the last
    block's output leaves sharded but the head consumes replicated."""

    mesh_shape: MeshShape
    stem_layout: str
    stem_words: int              # stem output materialization, mesh-wide
    blocks: Tuple[BlockPlan, ...]
    head_boundary_words: int
    dtype_bytes: int = 4
    policy: str = "solved"       # "solved" (DP) | "greedy" (per-layer)

    @property
    def stem_bytes(self) -> int:
        return self.stem_words * self.dtype_bytes

    @property
    def block_bytes(self) -> int:
        return sum(p.schedule.total_bytes for p in self.blocks)

    @property
    def boundary_words(self) -> int:
        return (sum(p.boundary_words for p in self.blocks)
                + self.head_boundary_words)

    @property
    def transition_bytes(self) -> int:
        """All layout-transition bytes in the chain: the boundary repays
        (including the head's) plus any entry-internal gathers the
        per-layer schedules carry."""
        return (self.boundary_words * self.dtype_bytes
                + sum(p.schedule.transition_bytes for p in self.blocks))

    @property
    def total_bytes(self) -> int:
        return (self.stem_bytes + self.block_bytes
                + self.boundary_words * self.dtype_bytes)

    @property
    def sharded_pairs(self) -> Tuple[Tuple[int, int], ...]:
        """Adjacent chain pairs whose boundary STAYS sharded (producer
        leaves model_sharded, consumer enters model_sharded).  Indices
        are chain positions with the stem as -1."""
        pairs = []
        prev_idx, prev_lay = -1, self.stem_layout
        for p in self.blocks:
            if prev_lay == "model_sharded" and p.in_layout == "model_sharded":
                pairs.append((prev_idx, p.index))
            prev_idx, prev_lay = p.index, p.out_layout
        return tuple(pairs)

    # -- overlap-aware latency accessors -----------------------------------
    #
    # The byte DP above stays the primary objective; latency is priced on
    # top of the solved plan from the fitted PerfCoefficients applied to
    # each block's per-pass cost split.  The stem is not a two-pass block
    # and is not priced here — these totals compare the SAME chain
    # serialized vs pipelined, which is the only comparison the overlap
    # axis decides.

    @property
    def pipelined_boundaries(self) -> Tuple[int, ...]:
        """Block indices whose ENTRY boundary pipelines (block i-1's
        pass 2 overlapping block i's pass 1; stem→block0 never appears —
        the stem is not a two-pass producer)."""
        return tuple(p.index for p in self.blocks
                     if p.entry_overlap == "pipelined")

    def _costs(self, p: BlockPlan) -> MBConvPassCosts:
        if p.pass_costs is not None:
            return p.pass_costs
        sch = p.schedule
        if p.family == "fusedmb":
            return sharded_fusedmb_pass_costs(
                p.shape, sch.tile_h, self.mesh_shape, 128,
                sch.residency, sch.collective)
        return sharded_mbconv_pass_costs(
            p.shape, sch.tile_h, sch.mode, self.mesh_shape, 128,
            sch.residency, sch.collective, sch.in_layout)

    def block_pass_us(self, index: int,
                      coeffs: Optional[PerfCoefficients] = None
                      ) -> Tuple[float, float]:
        """Calibrated (pass1_us, pass2_us) of one chain block."""
        coeffs = coeffs or get_perf_coefficients()
        pc = self._costs(self.blocks[index])
        return (mbconv_pass_us(coeffs, pc.pass1, pc.pass1_collective_words),
                mbconv_pass_us(coeffs, pc.pass2, pc.pass2_collective_words))

    def serial_latency_us(self,
                          coeffs: Optional[PerfCoefficients] = None
                          ) -> float:
        """Modeled chain latency with every boundary serialized (every
        pass of every block paid in full, back to back)."""
        coeffs = coeffs or get_perf_coefficients()
        return sum(sum(self.block_pass_us(i, coeffs))
                   for i in range(len(self.blocks)))

    def pipelined_latency_us(self,
                             coeffs: Optional[PerfCoefficients] = None
                             ) -> float:
        """Modeled chain latency honoring the solved ``entry_overlap``
        marks: each pipelined boundary pays max(prev pass 2, next pass 1)
        instead of their sum — i.e. the serial total minus the hidden
        min.  Structurally <= ``serial_latency_us`` (both terms are
        nonnegative), equal iff nothing pipelines."""
        coeffs = coeffs or get_perf_coefficients()
        total = self.serial_latency_us(coeffs)
        for i in range(1, len(self.blocks)):
            if self.blocks[i].entry_overlap != "pipelined":
                continue
            _p1_prev, p2_prev = self.block_pass_us(i - 1, coeffs)
            p1_cur, _p2_cur = self.block_pass_us(i, coeffs)
            total -= min(p2_prev, p1_cur)
        return total

    def boundary_latencies(self,
                           coeffs: Optional[PerfCoefficients] = None
                           ) -> Tuple[dict, ...]:
        """Per-interior-boundary latency table (block i-1 → block i):
        the two overlapped pass terms, the serialized and
        overlap-honoring boundary costs, and the solved overlap mark."""
        coeffs = coeffs or get_perf_coefficients()
        out = []
        for i in range(1, len(self.blocks)):
            _p1, p2_prev = self.block_pass_us(i - 1, coeffs)
            p1_cur, _p2 = self.block_pass_us(i, coeffs)
            ov = self.blocks[i].entry_overlap
            out.append({
                "boundary": (self.blocks[i - 1].index, self.blocks[i].index),
                "pass2_us": p2_prev, "pass1_us": p1_cur,
                "serialized_us": boundary_overlap_us(p2_prev, p1_cur,
                                                     "serial"),
                "overlap_us": boundary_overlap_us(p2_prev, p1_cur, ov),
                "overlap": ov,
            })
        return tuple(out)


def _stem_words(b: int, h: int, w: int, c: int, mesh_shape: MeshShape,
                layout: str) -> int:
    """Mesh-wide words the stem output materializes under one boundary
    layout.  Replicated: every device of each model group writes its data
    group's full (B_local, H, W, C) activation — mp copies of the tensor.
    Model-sharded: each element is written exactly once mesh-wide.  Batch
    is assumed data-divisible (it is for every B0 bench shape); the model
    factor only applies when the stem channels actually divide."""
    validate_layout(layout)
    dp, mp = shard_factors(b, c, mesh_shape)
    full = b * h * w * c
    if layout == "model_sharded" and mp > 1:
        return full
    return full * max(1, mesh_shape[1])


def _chain_shapes(rows: Sequence, b: int,
                  se_ratio: float, dtype_bytes: int
                  ) -> Tuple[Tuple[MBConvShape, str, str], ...]:
    """Normalize chain rows to (shape, family, act) triples.

    Rows may be legacy (h, w, c_in, c_mid, c_out, k, s) tuples — MBConv
    at the chain-wide ``se_ratio``, silu — or family-generic
    ``BlockRow``s carrying their own family/act/se_ratio.  Both forms mix
    freely in one chain."""
    out = []
    for row in rows:
        if isinstance(row, BlockRow):
            out.append((
                MBConvShape(b=b, h=row.h, w=row.w, c_in=row.c_in,
                            c_mid=row.c_mid, c_out=row.c_out, k=row.k,
                            s=row.s, se_ratio=row.se_ratio,
                            dtype_bytes=dtype_bytes),
                row.family, row.act))
        else:
            h, w, ci, cm, co, k, s = row
            out.append((
                MBConvShape(b=b, h=h, w=w, c_in=ci, c_mid=cm, c_out=co,
                            k=k, s=s, se_ratio=se_ratio,
                            dtype_bytes=dtype_bytes),
                "mbconv", DEFAULT_ACT))
    return tuple(out)


def network_rows_from_table(
    table: Sequence[Tuple[int, int, int, int, int, int]]
) -> Tuple[Tuple[int, int, int, int, int, int, int], ...]:
    """Adapt a ``core.workloads`` MBConv table — rows of (c_in, c_out,
    expand_ratio, k, s, ifmap hw) — into the (h, w, c_in, c_mid, c_out,
    k, s) chain rows the network solver consumes."""
    return tuple((hw, hw, ci, ci * e, co, k, s)
                 for ci, co, e, k, s, hw in table)


def _allowed_in_layouts(shape: MBConvShape,
                        mesh_shape: MeshShape) -> Tuple[str, ...]:
    """Arrival layouts worth offering the DP: replicated always; a
    model-sharded arrival only where the entry consumes it collective-free
    (identity expand — a real expand's entry gather makes sharded-in
    byte-identical to a boundary repay, so enumerating it only duplicates
    the replicated state)."""
    if can_shard_input(shape, mesh_shape):
        return (DEFAULT_LAYOUT, "model_sharded")
    return (DEFAULT_LAYOUT,)


def _allowed_out_layouts(shape: MBConvShape,
                         mesh_shape: MeshShape) -> Tuple[str, ...]:
    _dp, mp = shard_factors(shape.b, shape.c_mid, mesh_shape)
    if mp > 1:
        return (DEFAULT_LAYOUT, "model_sharded")
    return (DEFAULT_LAYOUT,)


def _block_pass_costs(shape: MBConvShape, sch, mesh_shape: MeshShape,
                      tpu: TPUConfig,
                      family: str = "mbconv") -> MBConvPassCosts:
    if family == "fusedmb":
        return sharded_fusedmb_pass_costs(
            shape, sch.tile_h, mesh_shape, tpu.c_block,
            sch.residency, sch.collective)
    return sharded_mbconv_pass_costs(
        shape, sch.tile_h, sch.mode, mesh_shape, tpu.c_block,
        sch.residency, sch.collective, sch.in_layout)


def _annotate_overlap(plan: NetworkPlan, tpu: TPUConfig,
                      coeffs: Optional[PerfCoefficients] = None
                      ) -> NetworkPlan:
    """Mark every chain boundary that can pipeline (the overlap axis).

    The byte DP stays untouched — overlap never changes what moves, only
    when, so it is annotated on the solved chain per boundary (the
    per-boundary savings are separable, which makes greedy per-boundary
    marking optimal).  Boundary i-1 → i pipelines iff ALL of:

    * no boundary repay and no entry-internal gather at block i's entry —
      an all-gather is a barrier the consumer's first strip must wait on;
    * the producer's pass-2 VMEM occupancy fits half the budget (retain
      pass 2 holds only the DW re-read stream + projection terms; a
      recompute pass 2 re-runs the whole front end and occupies its full
      cell footprint);
    * re-solving block i under ``overlap="pipelined"`` (pass-1 footprint
      against the halved budget, same collective/in_layout pins) finds a
      schedule with EQUAL total bytes — latency is secondary to the DP's
      byte objective, a boundary never buys overlap with extra traffic —
      and the same out_layout (the downstream chain must be unaffected);
    * the overlap actually hides time at the calibration: min(pass2_us,
      pass1_us) > 0.

    Blocks that stay serial keep their DP schedules; pipelined blocks
    carry the byte-equal pipelined re-solve (its ``ov=pipelined`` cache
    entries live under their own key segment)."""
    coeffs = coeffs or get_perf_coefficients()
    blocks = list(plan.blocks)
    half = tpu.vmem_bytes // _OVERLAP_VMEM_DIV
    for i in range(1, len(blocks)):
        prev, cur = blocks[i - 1], blocks[i]
        if prev.family == "fusedmb":
            # single-pass producer: its "pass 2" is exactly zero — there
            # is no compute for the consumer's pass-1 DMA to hide behind,
            # so the boundary stays honestly serial (the calibrated
            # min(p2, p1) == 0 guard below would catch this too; skipping
            # here keeps the mode/vmem probing two-pass-only)
            continue
        if cur.boundary_words != 0 or cur.schedule.transition_bytes != 0:
            continue
        psch = prev.schedule
        local_prev, _eff = mbconv_shard(prev.shape, plan.mesh_shape,
                                        psch.in_layout)
        if psch.mode == "retain":
            _p1v, p2_vmem = mbconv_pass_vmem_bytes(
                local_prev, psch.tile_h, tpu, psch.residency, psch.mode)
        else:
            p2_vmem = mbconv_vmem_footprint_bytes(
                local_prev, psch.tile_h, tpu, psch.residency, psch.mode)
        if p2_vmem > half:
            continue
        if cur.family == "fusedmb":
            # a single-pass CONSUMER can still stream behind a two-pass
            # producer's pass 2 — its whole cell is the pass-1 footprint
            # the halved budget must fit
            resolved = select_fusedmb_schedule(
                cur.shape, tpu, plan.mesh_shape,
                collective=cur.schedule.collective, overlap="pipelined")
        else:
            resolved = select_mbconv_schedule(
                cur.shape, tpu, plan.mesh_shape,
                collective=cur.schedule.collective,
                in_layout=cur.in_layout, overlap="pipelined")
        if (resolved.total_bytes != cur.schedule.total_bytes
                or resolved.out_layout != cur.out_layout):
            continue
        prev_costs = plan._costs(prev)
        cur_costs = _block_pass_costs(cur.shape, resolved,
                                      plan.mesh_shape, tpu, cur.family)
        p2_us = mbconv_pass_us(coeffs, prev_costs.pass2,
                               prev_costs.pass2_collective_words)
        p1_us = mbconv_pass_us(coeffs, cur_costs.pass1,
                               cur_costs.pass1_collective_words)
        if min(p2_us, p1_us) <= 0.0:
            continue
        blocks[i] = replace(cur, schedule=resolved,
                            entry_overlap="pipelined",
                            pass_costs=cur_costs)
        telemetry.counter("autotune.network_plan.pipelined_boundary")
    return replace(plan, blocks=tuple(blocks))


def solve_network_schedule(
    rows: Sequence[Tuple[int, ...]], b: int,
    mesh_shape: MeshShape = (1, 1), tpu: TPUConfig = TPUConfig(),
    dtype_bytes: int = 4, se_ratio: float = 0.25,
) -> NetworkPlan:
    """DP over the block chain picking per-block (residency, collective,
    in-layout, out-layout) jointly to minimize total modeled bytes.

    ``rows`` are legacy (h, w, c_in, c_mid, c_out, k, s) tuples (see
    ``network_rows_from_table``) or family-generic ``BlockRow``s — the
    two forms mix freely, so an EfficientNet-V2 chain states its fused
    stages next to its MBConv tail and a MobileNet-V3 chain states
    per-block act/SE; the stem boundary is seeded from the first block's
    input.  States are boundary layouts; each (state, in-layout,
    out-layout) candidate prices as the boundary transition plus the
    per-layer solve under the pinned (collective, in_layout) — tile_h,
    mode and residency re-solved by the family's selector inside the pin
    (``select_mbconv_schedule`` or ``select_fusedmb_schedule``; the
    fusedmb entry is replicated-only, so a sharded arrival repays at the
    boundary and the DP sees that price).  Byte ties prefer replicated
    boundaries (candidates are enumerated replicated-first and only a
    STRICT improvement replaces a state), so the plan shards exactly the
    boundaries that pay.

    After the byte DP, ``_annotate_overlap`` marks the boundaries that
    can pipeline (upstream pass 2 overlapping the consumer's pass 1) —
    bytes first, then hide what latency the calibration says can hide;
    a single-pass producer's boundary never pipelines (zero pass 2)."""
    chain = _chain_shapes(rows, b, se_ratio, dtype_bytes)
    if not chain:
        raise ValueError("network solve needs at least one block row")
    first = chain[0][0]
    h0, w0, c0 = first.h, first.w, first.c_in
    _dp0, mp0 = shard_factors(b, c0, mesh_shape)
    stem_opts = [DEFAULT_LAYOUT] + (["model_sharded"] if mp0 > 1 else [])
    # state: boundary layout -> (cost bytes, stem layout, block plans)
    states: Dict[str, tuple] = {}
    for lay in stem_opts:
        cost = _stem_words(b, h0, w0, c0, mesh_shape, lay) * dtype_bytes
        cur = states.get(lay)
        if cur is None or cost < cur[0]:
            states[lay] = (cost, lay, ())
    prev_dims = (h0, w0, c0)
    for i, (shape, family, act) in enumerate(chain):
        in_lays = ((DEFAULT_LAYOUT,) if family == "fusedmb"
                   else _allowed_in_layouts(shape, mesh_shape))
        new_states: Dict[str, tuple] = {}
        for prev_lay, (cost, stem_lay, plans) in states.items():
            for in_lay in in_lays:
                bwords = layout_transition_words(
                    b, prev_dims[0], prev_dims[1], prev_dims[2],
                    mesh_shape, prev_lay, in_lay)
                for out_lay in _allowed_out_layouts(shape, mesh_shape):
                    coll = ("psum_scatter" if out_lay == "model_sharded"
                            else DEFAULT_COLLECTIVE)
                    if family == "fusedmb":
                        sch = select_fusedmb_schedule(
                            shape, tpu, mesh_shape, collective=coll)
                    else:
                        sch = select_mbconv_schedule(
                            shape, tpu, mesh_shape, collective=coll,
                            in_layout=in_lay)
                    total = (cost + bwords * dtype_bytes + sch.total_bytes)
                    plan = BlockPlan(
                        index=i, shape=shape, in_layout=sch.in_layout,
                        out_layout=sch.out_layout, schedule=sch,
                        boundary_words=bwords,
                        pass_costs=_block_pass_costs(shape, sch,
                                                     mesh_shape, tpu,
                                                     family),
                        family=family, act=act)
                    cur = new_states.get(sch.out_layout)
                    if cur is None or total < cur[0]:
                        new_states[sch.out_layout] = (
                            total, stem_lay, plans + (plan,))
        states = new_states
        prev_dims = (shape.out_h, shape.out_w, shape.c_out)
    best = None
    for lay, (cost, stem_lay, plans) in states.items():
        head_words = layout_transition_words(
            b, prev_dims[0], prev_dims[1], prev_dims[2], mesh_shape,
            lay, DEFAULT_LAYOUT)
        total = cost + head_words * dtype_bytes
        if best is None or total < best[0]:
            best = (total, stem_lay, plans, head_words)
    total, stem_lay, plans, head_words = best
    plan = NetworkPlan(
        mesh_shape=mesh_shape, stem_layout=stem_lay,
        stem_words=_stem_words(b, h0, w0, c0, mesh_shape, stem_lay),
        blocks=plans, head_boundary_words=head_words,
        dtype_bytes=dtype_bytes, policy="solved")
    assert plan.total_bytes == total   # the parts must re-sum to the DP cost
    plan = _annotate_overlap(plan, tpu)
    assert plan.total_bytes == total   # overlap moves time, never bytes
    return plan


def greedy_network_schedule(
    rows: Sequence[Tuple[int, ...]], b: int,
    mesh_shape: MeshShape = (1, 1), tpu: TPUConfig = TPUConfig(),
    dtype_bytes: int = 4, se_ratio: float = 0.25,
) -> NetworkPlan:
    """The per-layer reference the DP is gated against: every block solved
    in isolation (the PR-5 status quo — replicated arrivals, collective
    chosen per layer, so every on-mesh block flips to psum_scatter), the
    stem replicated, and every sharded exit silently repaying its
    all-gather at the next (replicated) entry."""
    chain = _chain_shapes(rows, b, se_ratio, dtype_bytes)
    if not chain:
        raise ValueError("network solve needs at least one block row")
    first = chain[0][0]
    h0, w0, c0 = first.h, first.w, first.c_in
    plans = []
    prev_lay, prev_dims = DEFAULT_LAYOUT, (h0, w0, c0)
    for i, (shape, family, act) in enumerate(chain):
        if family == "fusedmb":
            sch = select_fusedmb_schedule(shape, tpu, mesh_shape)
        else:
            sch = select_mbconv_schedule(shape, tpu, mesh_shape)
        bwords = layout_transition_words(
            b, prev_dims[0], prev_dims[1], prev_dims[2], mesh_shape,
            prev_lay, DEFAULT_LAYOUT)
        plans.append(BlockPlan(
            index=i, shape=shape, in_layout=DEFAULT_LAYOUT,
            out_layout=sch.out_layout, schedule=sch,
            boundary_words=bwords,
            pass_costs=_block_pass_costs(shape, sch, mesh_shape, tpu,
                                         family),
            family=family, act=act))
        prev_lay = sch.out_layout
        prev_dims = (shape.out_h, shape.out_w, shape.c_out)
    head_words = layout_transition_words(
        b, prev_dims[0], prev_dims[1], prev_dims[2], mesh_shape,
        prev_lay, DEFAULT_LAYOUT)
    return NetworkPlan(
        mesh_shape=mesh_shape, stem_layout=DEFAULT_LAYOUT,
        stem_words=_stem_words(b, h0, w0, c0, mesh_shape, DEFAULT_LAYOUT),
        blocks=tuple(plans), head_boundary_words=head_words,
        dtype_bytes=dtype_bytes, policy="greedy")


@lru_cache(maxsize=64)
def _network_plan_cached(rows: tuple, b: int, mesh_shape: MeshShape,
                         dtype_bytes: int, se_ratio: float,
                         tpu: TPUConfig) -> NetworkPlan:
    return solve_network_schedule(rows, b, mesh_shape, tpu, dtype_bytes,
                                  se_ratio)


def get_network_plan(
    rows: Sequence[Tuple[int, ...]], b: int,
    mesh_shape: MeshShape = (1, 1), dtype_bytes: int = 4,
    se_ratio: float = 0.25, tpu: TPUConfig = TPUConfig(),
) -> NetworkPlan:
    """Trace-time-safe cached network solve (the in-process layer; the
    per-block schedules the plan pins are themselves persisted through
    the regular schedule cache under their ``layout=`` keys when the
    model layer executes the plan).  Counters distinguish a fresh DP
    solve from a cache reuse — the vision serving engine leans on reuse
    being the steady state (one solve per resolution bucket, then every
    batch of that bucket replays it)."""
    misses_before = _network_plan_cached.cache_info().misses
    frozen_rows = tuple(r if isinstance(r, BlockRow) else tuple(r)
                        for r in rows)
    plan = _network_plan_cached(frozen_rows, b, tuple(mesh_shape),
                                dtype_bytes, se_ratio, tpu)
    solved = _network_plan_cached.cache_info().misses > misses_before
    telemetry.counter("autotune.network_plan.solve" if solved
                      else "autotune.network_plan.reuse")
    return plan


# ---------------------------------------------------------------------------
# measured fallback
# ---------------------------------------------------------------------------

def benchmark_fused_sweep(
    x, w_dw, w_pw, *, stride: int, padding: str = "SAME",
    tile_hs: Optional[Sequence[int]] = None, iters: int = 3,
    interpret: Optional[bool] = None, persist: bool = False,
    tpu: TPUConfig = TPUConfig(), residency: Optional[str] = None,
) -> Tuple[int, Tuple[Tuple[int, float], ...]]:
    """Measured fallback: time the real fused kernel per candidate tile_h.

    Returns (best_tile_h, ((tile_h, seconds_per_call), ...)).  Use when the
    analytical model ties candidates or a deployment wants ground truth; the
    sweep routes every candidate through ``telemetry.measure`` (one warmup
    call, then ``iters`` timed calls, best iteration reported), under
    ``residency`` (None = the kernels' default staging mode).  With
    ``persist=True`` the winning tile_h is recorded in the schedule cache —
    under the same residency request it was measured at — as a
    ``"measured"`` entry (which outranks model picks and, when a cache dir
    is configured, survives restarts).
    """
    from ..kernels.convdk_fused import convdk_fused_separable

    res_used = residency or DEFAULT_RESIDENCY
    out_h = -(-x.shape[1] // stride)
    if tile_hs is None:
        tile_hs = [t for t in TPUConfig().tile_h_candidates if t <= out_h] or [1]
    results = []
    for th in tile_hs:
        fn = lambda: convdk_fused_separable(  # noqa: E731
            x, w_dw, w_pw, stride=stride, padding=padding, tile_h=th,
            interpret=interpret, residency=res_used)
        m = measure(fn, iters=iters, warmup=1,
                    name=f"fused_sweep.th{th}.{res_used}")
        results.append((th, m.best_s))
    best = min(results, key=lambda r: r[1])[0]
    if persist:
        b, h, w_in, c_in = x.shape
        shape = SeparableShape(
            b=b, h=h, w=w_in, c_in=c_in, c_out=w_pw.shape[1],
            k=w_dw.shape[0], s=stride, dtype_bytes=x.dtype.itemsize)
        entry = {"tile_h": best, "source": "measured",
                 "recorded_at": time.time(),
                 "timings_s": {str(th): t for th, t in results}}
        if residency is not None:
            # only a REQUESTED residency is ground truth worth recording;
            # an unpinned sweep timed one mode's tile_h candidates without
            # comparing modes, so the auto entry leaves residency to the
            # solver (re-solved at the measured tile_h on lookup)
            entry["residency"] = res_used
        get_schedule_cache().put(
            _sep_key(shape, tpu, residency=residency), entry)
    return best, tuple(results)


def benchmark_mbconv_sweep(
    x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj, *, stride: int,
    padding: str = "SAME", se_ratio: float = 0.25, iters: int = 3,
    interpret: Optional[bool] = None, persist: bool = False,
    tpu: TPUConfig = TPUConfig(),
    candidates: Optional[Sequence[dict]] = None,
) -> Tuple[dict, Tuple[dict, ...]]:
    """Measured MBConv sweep: time the real two-pass kernel per schedule
    point and let the stopwatch arbitrate the axes the byte model ties.

    ``candidates`` is a sequence of ``{"tile_h", "mode", "residency"}``
    dicts; the default set is the solver's own pick under each pinned
    pass-2 mode — the exact pair of points the retain/recompute crossover
    model claims to order, measured at the tile_h/residency each mode's
    VMEM footprint actually allows.  Returns ``(best, results)`` where
    every result dict carries the candidate axes plus ``seconds`` (best
    timed iteration via ``telemetry.measure``).  With ``persist=True``
    the winner lands in the schedule cache under the UNPINNED key as a
    ``"measured"`` entry — the tier model picks can never clobber.
    """
    from ..kernels.convdk_mbconv import convdk_mbconv_fused

    b, h, w_in, c_in = x.shape
    c_mid, c_out = w_proj.shape
    shape = MBConvShape(b=b, h=h, w=w_in, c_in=c_in, c_mid=c_mid,
                        c_out=c_out, k=w_dw.shape[0], s=stride,
                        se_ratio=se_ratio, dtype_bytes=x.dtype.itemsize)
    if candidates is None:
        candidates, seen = [], set()
        for md in MBCONV_MODES:
            pick = select_mbconv_schedule(shape, tpu, mode=md)
            point = (pick.tile_h, pick.mode, pick.residency)
            if point not in seen:
                seen.add(point)
                candidates.append({"tile_h": pick.tile_h, "mode": pick.mode,
                                   "residency": pick.residency})
    results = []
    for cand in candidates:
        th, md = int(cand["tile_h"]), cand["mode"]
        res = validate_residency(cand.get("residency") or DEFAULT_RESIDENCY)
        fn = lambda: convdk_mbconv_fused(  # noqa: E731
            x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
            stride=stride, padding=padding, tile_h=th, mode=md,
            interpret=interpret, residency=res)
        m = measure(fn, iters=iters, warmup=1,
                    name=f"mbconv_sweep.th{th}.{md}.{res}")
        results.append({"tile_h": th, "mode": md, "residency": res,
                        "seconds": m.best_s})
    best = min(results, key=lambda r: r["seconds"])
    if persist:
        entry = {"tile_h": best["tile_h"], "mode": best["mode"],
                 "residency": best["residency"], "source": "measured",
                 "recorded_at": time.time(),
                 "timings_s": {
                     f"th{r['tile_h']}.{r['mode']}.{r['residency']}":
                         r["seconds"] for r in results}}
        get_schedule_cache().put(_mbconv_key(shape, tpu), entry)
    return best, tuple(results)

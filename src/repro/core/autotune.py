"""Per-layer schedule selection for the fused separable ConvDK kernel.

MIREDO-style per-layer solving: instead of one fixed ``tile_h`` for every
separable block, each layer shape gets its own fused schedule, chosen by the
analytical HBM traffic model in ``core.perfmodel`` (primary) with an optional
measured fallback sweep (ground truth when the model cannot separate
candidates, or when ``mode="benchmark"`` is requested).

The selection is cached per layer shape — schedule solving is trace-time
work and must never re-run inside a jitted step.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Sequence, Tuple

from .perfmodel import (
    HBMTraffic,
    SeparableShape,
    fused_separable_traffic,
    pick_channel_block,
    staged_separable_traffic,
)


@dataclass(frozen=True)
class TPUConfig:
    """Budget knobs for fused-schedule selection on one core."""

    vmem_bytes: int = 16 * 1024 * 1024   # per-core VMEM budget
    c_block: int = 128                   # lane width
    tile_h_candidates: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)


@dataclass(frozen=True)
class FusedSchedule:
    """One selected schedule for ``convdk_fused_separable``."""

    tile_h: int
    ci_block: int
    co_block: int
    traffic: HBMTraffic          # modeled fused HBM traffic at this tile_h
    staged_traffic: HBMTraffic   # modeled staged-pipeline traffic (baseline)

    @property
    def modeled_saving(self) -> float:
        """Fraction of staged HBM bytes the fused schedule avoids."""
        base = self.staged_traffic.total_bytes
        return 1.0 - self.traffic.total_bytes / base if base else 0.0


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _blocks(c: int, cap: int) -> int:
    return min(cap, _round_up(c, 8))


def vmem_footprint_bytes(shape: SeparableShape, tile_h: int,
                         tpu: TPUConfig) -> int:
    """Modeled VMEM residency of one fused grid cell (per-strip staging).

    Counts the staged input window, the f32 DW accumulator, the f32 PW
    scratch accumulator and both weight blocks — the production budget a
    DMA'd (``ANY``-space input) rendering of the kernel must respect.
    """
    ci = pick_channel_block(shape.c_in, tpu.c_block)
    co = _blocks(shape.c_out, tpu.c_block)
    tile_h = max(1, min(tile_h, shape.out_h))
    in_rows = (tile_h - 1) * shape.s + shape.k
    x_win = in_rows * shape.padded_w * ci * shape.dtype_bytes
    dw_acc = tile_h * shape.out_w * ci * 4
    pw_acc = tile_h * shape.out_w * co * 4
    weights = (shape.k * shape.k * ci + ci * co) * shape.dtype_bytes
    return x_win + dw_acc + pw_acc + weights


def candidate_schedules(shape: SeparableShape,
                        tpu: TPUConfig = TPUConfig()) -> Tuple[FusedSchedule, ...]:
    """All VMEM-feasible schedules for one layer shape, model-priced."""
    ci = pick_channel_block(shape.c_in, tpu.c_block)
    co = _blocks(shape.c_out, tpu.c_block)
    out: list[FusedSchedule] = []
    seen = set()
    for th in tpu.tile_h_candidates:
        th = max(1, min(th, shape.out_h))
        if th in seen:
            continue
        seen.add(th)
        if vmem_footprint_bytes(shape, th, tpu) > tpu.vmem_bytes:
            continue
        out.append(FusedSchedule(
            tile_h=th, ci_block=ci, co_block=co,
            traffic=fused_separable_traffic(shape, th, tpu.c_block),
            staged_traffic=staged_separable_traffic(shape, th, tpu.c_block),
        ))
    if not out:
        # degenerate fallback: the smallest strip always fits the model
        out.append(FusedSchedule(
            tile_h=1, ci_block=ci, co_block=co,
            traffic=fused_separable_traffic(shape, 1, tpu.c_block),
            staged_traffic=staged_separable_traffic(shape, 1, tpu.c_block),
        ))
    return tuple(out)


def select_fused_schedule(shape: SeparableShape,
                          tpu: TPUConfig = TPUConfig()) -> FusedSchedule:
    """Pick the schedule minimizing modeled HBM traffic (ties -> larger
    tile_h: fewer grid cells, bigger MXU contractions)."""
    cands = candidate_schedules(shape, tpu)
    return min(cands, key=lambda c: (c.traffic.total_bytes, -c.tile_h))


@lru_cache(maxsize=512)
def _cached_schedule(shape: SeparableShape, tpu: TPUConfig) -> FusedSchedule:
    return select_fused_schedule(shape, tpu)


def get_fused_schedule(
    b: int, h: int, w: int, c_in: int, c_out: int, k: int, s: int,
    dtype_bytes: int = 4, tpu: TPUConfig = TPUConfig(),
) -> FusedSchedule:
    """Cached per-layer-shape schedule lookup (trace-time safe)."""
    shape = SeparableShape(b=b, h=h, w=w, c_in=c_in, c_out=c_out, k=k, s=s,
                           dtype_bytes=dtype_bytes)
    return _cached_schedule(shape, tpu)


def benchmark_fused_sweep(
    x, w_dw, w_pw, *, stride: int, padding: str = "SAME",
    tile_hs: Optional[Sequence[int]] = None, iters: int = 3,
    interpret: Optional[bool] = None,
) -> Tuple[int, Tuple[Tuple[int, float], ...]]:
    """Measured fallback: time the real fused kernel per candidate tile_h.

    Returns (best_tile_h, ((tile_h, seconds_per_call), ...)).  Use when the
    analytical model ties candidates or a deployment wants ground truth; the
    sweep runs each candidate ``iters`` times after one warmup call.
    """
    import jax

    from ..kernels.convdk_fused import convdk_fused_separable

    out_h = -(-x.shape[1] // stride)
    if tile_hs is None:
        tile_hs = [t for t in TPUConfig().tile_h_candidates if t <= out_h] or [1]
    results = []
    for th in tile_hs:
        fn = lambda: convdk_fused_separable(  # noqa: E731
            x, w_dw, w_pw, stride=stride, padding=padding, tile_h=th,
            interpret=interpret)
        jax.block_until_ready(fn())                      # warmup / compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn())
        results.append((th, (time.perf_counter() - t0) / iters))
    best = min(results, key=lambda r: r[1])[0]
    return best, tuple(results)

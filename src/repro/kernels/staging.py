"""Memory-space-aware strip-DMA staging engine for the fused ConvDK kernels.

The paper's dataflow claim is about *buffer movement*: input strips stream
through on-chip memory with maximal halo reuse, and the strip loads are the
only input-side traffic.  The first fused renderings of our kernels cheated
on that point — their BlockSpecs kept the full padded height of a channel
block VMEM-resident and carved strips out of it with ``pl.ds``, which is
interpret-friendly but (a) refetches the whole padded height every time the
channel block advances and (b) never exercises the strip-by-strip DMA
structure the traffic model (``core.perfmodel``) prices.

This module is the shared production rendering.  One engine serves every
fused pipeline (separable, MBConv pass 1, both MBConv pass-2 variants,
their sharded wrappers) under a three-mode **residency** axis:

* ``"resident"`` — the legacy rendering: the input is BlockSpec-blocked
  into VMEM (full padded height for halo'd streams, per-strip blocks for
  non-overlapping streams) and windows are ``pl.ds`` slices.  Cheapest
  when the whole (channel-block of the) input fits VMEM and the channel
  grid has one block; priced honestly by the ``resident`` traffic model.
* ``"strip_dma"`` — the input lives in the ``ANY``/HBM memory space; each
  grid cell issues one async copy of exactly its halo'd strip window into
  a single VMEM scratch slot and waits on it before computing.  HBM words
  = the strip-staging accounting (halo rows re-read, never re-written).
* ``"strip_dma_db"`` — same windows, **double-buffered**: two scratch
  slots + two DMA semaphores; each cell prefetches the *next* grid cell's
  window while computing its own, so the strip stream pipelines behind
  compute.  Identical HBM words to ``strip_dma`` (double-buffering buys
  overlap, not traffic) at 2x the strip scratch.

The engine's unit of work is a **window**: the (batch, row-strip,
channel-block) triple one grid cell stages.  ``StripPlan`` carries the
static geometry plus the kernel's grid so the stream can (1) flatten the
grid cell into a linear DMA-stream step and (2) decode step+1 back into
the *next* cell's window coordinates for prefetch — the grid's iteration
order IS the DMA stream order, whatever dims (c_out blocks, c_mid
reduction, ...) interleave between strips.

Everything here runs identically under interpret mode: the pallas
interpreter implements the copy/semaphore primitives (shimmed through
``repro.compat`` for version drift), so CPU parity tests execute the same
DMA-structured code path as a real TPU launch.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import (
    pallas_any_memory_space,
    pallas_async_copy,
    pallas_dma_semaphores,
    pallas_supports_dma,
)
from ..core import telemetry
from ..core.perfmodel import (
    DEFAULT_RESIDENCY,
    RESIDENCY_MODES,
    staging_slots,
    validate_residency,
)

__all__ = [
    "DEFAULT_RESIDENCY",
    "RESIDENCY_MODES",
    "StripPlan",
    "StripStream",
    "strip_plan",
]


@dataclasses.dataclass(frozen=True)
class StripPlan:
    """Static description of one staged input stream of a fused kernel.

    Geometry (one window is ``(in_rows, w_span, c_block)``):

    * ``h_tot`` / ``w_tot`` — full (padded) rows / width of the source
      tensor, as launched: bounds for the last window's slice.
    * ``w_span`` — staged words per row, ``(out_w - 1) * stride + k_w``
      for conv streams (= the whole tap reach), ``out_w`` for
      non-overlapping re-read streams.
    * ``c_block`` — channel lanes per window.
    * ``tile_h`` / ``stride`` / ``k_h`` — strip geometry; ``k_h == 1,
      stride == 1`` describes a non-overlapping row-block stream (the
      retained-DW re-read), anything else a halo'd conv stream.

    Stream structure:

    * ``grid`` — the pallas grid, iteration order; its flattened index is
      the DMA-stream step.
    * ``window_dims`` — which grid dims select (batch, row-strip,
      channel-block) of a cell's window.
    """

    h_tot: int
    w_tot: int
    w_span: int
    c_block: int
    tile_h: int
    grid: Tuple[int, ...]
    window_dims: Tuple[int, int, int]
    stride: int = 1
    k_h: int = 1
    residency: str = DEFAULT_RESIDENCY
    prefetch_priority: Optional[int] = None   # DMA stream priority for
    #                                           prefetches (None = default;
    #                                           dropped where unsupported)

    def __post_init__(self):
        validate_residency(self.residency)
        assert self.w_span <= self.w_tot, (self.w_span, self.w_tot)
        assert len(self.window_dims) == 3 and all(
            0 <= d < len(self.grid) for d in self.window_dims), self

    @property
    def in_rows(self) -> int:
        """Rows per halo'd window (``tile_h`` when non-overlapping)."""
        return (self.tile_h - 1) * self.stride + self.k_h

    @property
    def is_dma(self) -> bool:
        return self.residency != "resident"

    @property
    def halo(self) -> bool:
        """Whether consecutive windows overlap (conv-style strips)."""
        return self.k_h > 1 or self.stride > 1

    @property
    def n_slots(self) -> int:
        return max(1, staging_slots(self.residency))

    @property
    def n_steps(self) -> int:
        return math.prod(self.grid)

    # -- launch-side helpers -------------------------------------------------

    def in_spec(self, index_map) -> pl.BlockSpec:
        """BlockSpec for the staged input.

        ``index_map`` maps grid indices to the RESIDENT block position
        (full-height channel block for halo'd streams, per-strip block for
        non-overlapping streams); DMA modes ignore it — the ref arrives
        un-blocked in the ANY space and the engine carves windows itself.
        """
        if self.is_dma:
            return pl.BlockSpec(memory_space=pallas_any_memory_space())
        rows = self.h_tot if self.halo else self.tile_h
        return pl.BlockSpec((1, rows, self.w_tot, self.c_block), index_map)

    def scratch_shapes(self, dtype) -> tuple:
        """Engine scratch to append to the kernel's ``scratch_shapes``:
        the slot buffer plus (when the build traces real DMAs) the per-slot
        semaphore array.  Empty for ``resident``."""
        if not self.is_dma:
            return ()
        shapes = [pltpu.VMEM(
            (self.n_slots, self.in_rows, self.w_span, self.c_block), dtype)]
        if pallas_supports_dma():
            shapes.append(pallas_dma_semaphores(self.n_slots))
        return tuple(shapes)

    def take_scratch(self, scratch: tuple) -> tuple:
        """Split a kernel's trailing scratch refs: (engine_refs, rest)."""
        n = (2 if pallas_supports_dma() else 1) if self.is_dma else 0
        return (scratch[len(scratch) - n:] if n else (),
                scratch[:len(scratch) - n])


def strip_plan(
    *,
    h_tot: int,
    w_tot: int,
    w_span: int,
    c_block: int,
    tile_h: int,
    grid: Tuple[int, ...],
    window_dims: Tuple[int, int, int],
    stride: int = 1,
    k_h: int = 1,
    residency: Optional[str] = None,
    prefetch_priority: Optional[int] = None,
) -> StripPlan:
    """``StripPlan`` constructor with the engine-wide residency default.

    Building a plan is trace-time work, so the telemetry hooks here tick
    once per kernel BUILD (per compilation), not per execution: a plan's
    stream geometry fully determines its issue count and staged words, so
    counting at construction is both cheap and exact."""
    plan = StripPlan(
        h_tot=h_tot, w_tot=w_tot, w_span=w_span, c_block=c_block,
        tile_h=tile_h, grid=tuple(grid), window_dims=tuple(window_dims),
        stride=stride, k_h=k_h,
        residency=DEFAULT_RESIDENCY if residency is None else residency,
        prefetch_priority=prefetch_priority)
    telemetry.counter("staging.plans")
    telemetry.counter(f"staging.residency.{plan.residency}")
    if plan.is_dma:
        telemetry.counter("staging.dma_issues", plan.n_steps)
        telemetry.counter(
            "staging.window_words",
            plan.n_steps * plan.in_rows * plan.w_span * plan.c_block)
    return plan


class StripStream:
    """Per-grid-cell view of one staged input stream (kernel-side).

    Construct inside the kernel body from the plan, the input ref and the
    engine's scratch refs, then call :meth:`get` once to obtain the
    ``(in_rows, w_span, c_block)`` window of this cell — staged per the
    plan's residency (slice, blocking DMA, or double-buffered DMA with
    next-window prefetch).
    """

    def __init__(self, plan: StripPlan, x_ref, stage_refs: tuple):
        self.plan = plan
        self.x_ref = x_ref
        if plan.is_dma:
            self.buf = stage_refs[0]
            self.sem = stage_refs[1] if len(stage_refs) > 1 else None
        else:
            assert not stage_refs, stage_refs
            self.buf = self.sem = None

    # -- stream arithmetic ---------------------------------------------------

    def _step(self):
        """Flattened grid-cell index — the DMA-stream step."""
        step = pl.program_id(0)
        for d in range(1, len(self.plan.grid)):
            step = step * self.plan.grid[d] + pl.program_id(d)
        return step

    def _window_at(self, step):
        """Decode a step into its window's (batch, strip, chan) indices."""
        sizes = self.plan.grid
        idx = [None] * len(sizes)
        rem = step
        for d in reversed(range(len(sizes))):
            idx[d] = rem % sizes[d]
            rem = rem // sizes[d]
        bd, sd, cd = self.plan.window_dims
        return idx[bd], idx[sd], idx[cd]

    def _window_here(self):
        bd, sd, cd = self.plan.window_dims
        return pl.program_id(bd), pl.program_id(sd), pl.program_id(cd)

    # -- DMA issue -----------------------------------------------------------

    def _dma(self, window, slot):
        p = self.plan
        bi, ti, ci = window
        row0 = ti * p.tile_h * p.stride
        # in the double-buffered stream every copy is a prefetch (started
        # one cell ahead of its consumer), so the plan's prefetch priority
        # applies to all of them — start and wait must describe the same
        # copy, so the priority rides the descriptor uniformly
        prio = p.prefetch_priority if p.residency == "strip_dma_db" else None
        return pallas_async_copy(
            self.x_ref.at[bi, pl.ds(row0, p.in_rows), pl.ds(0, p.w_span),
                          pl.ds(ci * p.c_block, p.c_block)],
            self.buf.at[slot],
            self.sem.at[slot] if self.sem is not None else None,
            priority=prio,
        )

    # -- the one public op ---------------------------------------------------

    def get(self):
        """The current cell's staged window, ``(in_rows, w_span, c_block)``.

        * resident — a ``pl.ds`` slice of the VMEM-resident block,
        * strip_dma — start + wait one async copy into slot 0,
        * strip_dma_db — wait the copy a previous cell prefetched (cell 0
          bootstraps its own), after starting the NEXT cell's prefetch so
          the strip stream stays one window ahead of compute.
        """
        p = self.plan
        if not p.is_dma:
            if not p.halo:
                return self.x_ref[0][:, :p.w_span]       # per-strip block
            _, ti, _ = self._window_here()
            win = self.x_ref[0, pl.ds(ti * p.tile_h * p.stride, p.in_rows)]
            return win[:, :p.w_span]

        step = self._step()
        here = self._window_here()
        if p.residency == "strip_dma":
            dma = self._dma(here, 0)
            dma.start()
            dma.wait()
            return self.buf[0]

        # strip_dma_db: the scratch slots revolve across grid cells — the
        # first cell warms the stream, every cell prefetches its successor.
        @pl.when(step == 0)
        def _warmup():
            self._dma(here, 0).start()

        @pl.when(step + 1 < p.n_steps)
        def _prefetch():
            self._dma(self._window_at(step + 1),
                      (step + 1) % p.n_slots).start()

        self._dma(here, step % p.n_slots).wait()
        return self.buf[step % p.n_slots]

"""Pure-jnp oracles for the ConvDK Pallas kernels.

These are the ground truth the kernels are swept against (shapes x dtypes x
strides) in interpret mode.  They use only jnp / lax primitives.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def depthwise2d_ref(
    x: jax.Array, w: jax.Array, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    """Depthwise Conv2D oracle.  x: (B, H, W, C) NHWC; w: (k_h, k_w, C)."""
    k_h, k_w, c = w.shape
    rhs = jnp.transpose(w, (2, 0, 1))[:, None]  # (C, 1, k_h, k_w) OIHW
    out = jax.lax.conv_general_dilated(
        x, rhs,
        window_strides=(stride, stride),
        padding=padding,
        feature_group_count=c,
        dimension_numbers=("NHWC", "OIHW", "NHWC"),
    )
    return out


def _act_ref(x: jax.Array, act: Optional[str]) -> jax.Array:
    if act is None:
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if act == "silu":
        return x * jax.nn.sigmoid(x)
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    if act == "hard_swish":
        return x * jnp.clip(x + 3.0, 0.0, 6.0) * (1.0 / 6.0)
    if act == "hard_sigmoid":
        return jnp.clip(x + 3.0, 0.0, 6.0) * (1.0 / 6.0)
    raise ValueError(f"unsupported activation: {act}")


def separable_ref(
    x: jax.Array,
    w_dw: jax.Array,
    w_pw: jax.Array,
    stride: int = 1,
    padding: str = "SAME",
    dw_act: Optional[str] = None,
    act: Optional[str] = None,
) -> jax.Array:
    """Depthwise-separable block oracle: DW conv -> dw_act -> 1x1 PW -> act.

    x: (B, H, W, C_in); w_dw: (k_h, k_w, C_in); w_pw: (C_in, C_out).
    The PW contraction runs in f32 (matching the fused kernel's accumulator)
    before casting back to the input dtype.
    """
    y = depthwise2d_ref(x, w_dw, stride=stride, padding=padding)
    y = _act_ref(y.astype(jnp.float32), dw_act)
    z = jax.lax.dot_general(
        y, w_pw.astype(jnp.float32),
        dimension_numbers=(((3,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return _act_ref(z, act).astype(x.dtype)


def mbconv_ref(
    x: jax.Array,
    w_exp: jax.Array,
    w_dw: jax.Array,
    w_se1: Optional[jax.Array],
    b_se1: Optional[jax.Array],
    w_se2: Optional[jax.Array],
    b_se2: Optional[jax.Array],
    w_proj: jax.Array,
    stride: int = 1,
    padding: str = "SAME",
    exp_act: Optional[str] = "silu",
    dw_act: Optional[str] = "silu",
    se_act: Optional[str] = "silu",
    gate_act: Optional[str] = "sigmoid",
) -> jax.Array:
    """MBConv (EfficientNet / MobileNet-V3) block oracle, WITHOUT the
    residual add:

        expand 1x1 -> exp_act -> depthwise k x k / s -> dw_act
        -> SE (global mean pool -> FC -> se_act -> FC -> gate_act, scales
           the DW output; skipped entirely when ``w_se1 is None``)
        -> project 1x1 (linear).

    x: (B, H, W, C_in); w_exp: (C_in, C_mid); w_dw: (k, k, C_mid);
    w_se1/b_se1: (C_mid, C_se)/(C_se,); w_se2/b_se2: (C_se, C_mid)/(C_mid,);
    w_proj: (C_mid, C_out).  For expand_ratio == 1 blocks pass the identity
    as ``w_exp`` with ``exp_act=None`` (the kernel does the same).  For
    no-SE blocks (MobileNet-V3's early/middle stages) pass ``w_se1=None``
    — the pool, both FCs and the gate multiply disappear, exactly like the
    se=off kernel path.  EfficientNet keeps the (silu, sigmoid) defaults;
    MobileNet-V3's SE uses ``se_act="relu"``/``gate_act="hard_sigmoid"``.
    All contractions run in f32, matching the fused kernel's accumulators.
    """
    e = jax.lax.dot_general(
        x.astype(jnp.float32), w_exp.astype(jnp.float32),
        dimension_numbers=(((3,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    e = _act_ref(e, exp_act)
    d = depthwise2d_ref(e, w_dw.astype(jnp.float32), stride=stride,
                        padding=padding)
    d = _act_ref(d.astype(jnp.float32), dw_act)
    if w_se1 is not None:
        pooled = jnp.mean(d, axis=(1, 2))                   # (B, C_mid)
        s1 = _act_ref(pooled @ w_se1.astype(jnp.float32)
                      + b_se1.astype(jnp.float32), se_act)
        gate = _act_ref(s1 @ w_se2.astype(jnp.float32)
                        + b_se2.astype(jnp.float32), gate_act)
        d = d * gate[:, None, None, :]
    out = jax.lax.dot_general(
        d, w_proj.astype(jnp.float32),
        dimension_numbers=(((3,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return out.astype(x.dtype)


def fusedmb_ref(
    x: jax.Array,
    w_conv: jax.Array,
    w_proj: jax.Array,
    stride: int = 1,
    padding: str = "SAME",
    act: Optional[str] = "silu",
) -> jax.Array:
    """Fused-MBConv (EfficientNet-V2) block oracle, WITHOUT the residual:

        dense k x k / s conv (C_in -> C_mid) -> act -> project 1x1 (linear).

    The expand-PW and the depthwise conv of a classic MBConv collapse into
    ONE dense convolution; there is no SE stage (V2's fused stages run
    without it).  x: (B, H, W, C_in); w_conv: (k, k, C_in, C_mid) HWIO;
    w_proj: (C_mid, C_out).  All contractions run in f32, matching the
    single-pass fused kernel's accumulators.
    """
    e = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w_conv.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    e = _act_ref(e, act)
    out = jax.lax.dot_general(
        e, w_proj.astype(jnp.float32),
        dimension_numbers=(((3,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return out.astype(x.dtype)


def causal_conv1d_ref(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
) -> jax.Array:
    """Causal depthwise Conv1D oracle (the Mamba-2 / RecurrentGemma stem).

    x: (B, L, D); w: (k, D); out[t] = sum_i w[i] * x[t - k + 1 + i].
    """
    k, d = w.shape
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    if bias is not None:
        out = out + bias
    if activation == "silu":
        out = out * jax.nn.sigmoid(out)
    return out


def causal_conv1d_update_ref(
    state: jax.Array,
    x_t: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
):
    """Single-token decode step.  state: (B, k-1, D) last inputs; x_t: (B, D).

    Returns (y_t, new_state).
    """
    k, d = w.shape
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, k, D)
    y = jnp.einsum("bkd,kd->bd", window, w)
    if bias is not None:
        y = y + bias
    if activation == "silu":
        y = y * jax.nn.sigmoid(y)
    return y, window[:, 1:, :]

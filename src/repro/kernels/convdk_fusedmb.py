"""Single-pass Fused-MBConv (EfficientNet-V2) ConvDK Pallas kernel.

EfficientNet-V2's early stages replace MBConv's expand-PW + depthwise pair
with ONE dense k x k convolution (``Fused-MBConv``):

    dense k x k / s conv (C_in -> C_mid) -> act -> project 1x1 (+ residual)

There is no SE stage, and therefore no global pool coupling distant strips:
the projection of a strip depends only on that strip's conv output.  That
is exactly the locality the single-strip VMEM residency of
``convdk_fused_separable`` exploits — so unlike MBConv (which needs the
two-pass schedule of ``convdk_mbconv``), Fused-MBConv fuses in **one
pass**: per (c_out block, row strip), the dense conv accumulates over the
c_in blocks of the staged halo'd input window, the activation applies in
VMEM, and the projection contracts over the c_mid blocks — the expanded
tensor NEVER exists in HBM.

Grid layout mirrors MBConv's recompute pass 2: ``(batch, c_out_block,
row_strip, c_mid_block, c_in_block)`` with c_in innermost (the dense-conv
reduction) and c_mid next (the projection reduction).  The input stream
stages through the shared strip engine (``kernels.staging``) under the
schedule's **residency** axis — identical windows to an MBConv pass-1
stream, re-read once per (c_out, c_mid) block pair.

Because the whole block is one pass, its schedule has NO mode axis (there
is no DW tensor to retain or recompute) and its **pass-2 figures are
exactly zero** by convention: ``core.perfmodel.fusedmb_pass_traffic``
prices the entire block as pass 1.  A pipelined network boundary cannot
hide a predecessor's pass-1 DMA behind this block's (empty) pass 2 —
``core.autotune._annotate_overlap`` keeps such boundaries serial.

The sharded wrapper (``convdk_sharded``) puts c_mid on "model" like
MBConv: conv partials are channel-local (every device holds ALL of c_in —
a dense conv cannot consume a c_in-sharded arrival), the projection
reduces over c_mid per the schedule's collective (psum / psum_scatter).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.perfmodel import (
    DEFAULT_COLLECTIVE,
    DEFAULT_RESIDENCY,
    pick_channel_block,
    validate_collective,
)
from .common import default_interpret, round_up as _round_up, spatial_pads
from .ref import _act_ref, fusedmb_ref
from .staging import StripPlan, StripStream, strip_plan


def _fusedmb_kernel(x_ref, wconv_ref, wproj_ref, o_ref, *scratch,
                    plan: StripPlan, k_h, k_w, stride, tile_h, out_w,
                    act: Optional[str]):
    """One (batch, c_out-block, row-strip, c_mid-block, c_in-block) cell.

    x_ref     : unstaged input (engine-staged per ``plan``)
    wconv_ref : (k_h, k_w, CI, CM)    dense conv block
    wproj_ref : (CM, CO)              projection block
    o_ref     : (1, tile_h, out_w, CO)
    scratch   : conv accumulator (tile_h, out_w, CM) f32 carrying partial
                dense-conv sums across the c_in grid dim, projection
                accumulator (tile_h, out_w, CO) f32 carrying partial sums
                across the c_mid grid dim, then the staging engine's refs.
    """
    s = stride
    stage_refs, (conv_ref, proj_ref) = plan.take_scratch(scratch)
    cm = pl.program_id(3)
    ci = pl.program_id(4)
    n_cm = pl.num_programs(3)
    n_ci = pl.num_programs(4)
    win = StripStream(plan, x_ref, stage_refs).get()

    # Dense-conv tap loop: each tap contracts the strided window slice
    # (tile_h, out_w, CI) with its (CI, CM) weight plane — the expand-PW
    # and DW of a classic MBConv, collapsed into one MXU contraction per
    # tap.  Summed over taps here, over c_in blocks via conv_ref.
    part = jnp.zeros((tile_h, out_w, wconv_ref.shape[-1]), jnp.float32)
    for j in range(k_h):
        for i in range(k_w):
            xs = jax.lax.slice(
                win,
                (j, i, 0),
                (j + s * (tile_h - 1) + 1, i + s * (out_w - 1) + 1,
                 win.shape[-1]),
                (s, s, 1),
            )
            part = part + jax.lax.dot_general(
                xs.reshape(tile_h * out_w, xs.shape[-1]).astype(jnp.float32),
                wconv_ref[j, i].astype(jnp.float32),
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(tile_h, out_w, -1)

    @pl.when(ci == 0)
    def _init():
        conv_ref[...] = part

    @pl.when(ci > 0)
    def _accumulate():
        conv_ref[...] = conv_ref[...] + part

    @pl.when(ci == n_ci - 1)
    def _project():
        e = _act_ref(conv_ref[...], act)
        partial = jax.lax.dot_general(
            e.reshape(tile_h * out_w, e.shape[-1]),
            wproj_ref[:, :].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(tile_h, out_w, -1)

        @pl.when(cm == 0)
        def _proj_init():
            proj_ref[...] = partial

        @pl.when(cm > 0)
        def _proj_accumulate():
            proj_ref[...] = proj_ref[...] + partial

        @pl.when(cm == n_cm - 1)
        def _finalize():
            o_ref[0] = proj_ref[...].astype(o_ref.dtype)


def fusedmb_pallas(x_pad, w_conv, w_proj, *, stride, out_w, tile_h, n_th,
                   ci_block, cm_block, co_block, act, interpret,
                   residency=DEFAULT_RESIDENCY):
    """Raw single-pass launch over a pre-padded input.

    x_pad  : (B, H_tot, W_pad, CI_pad)
    w_conv : (k_h, k_w, CI_pad, CM_pad) HWIO
    w_proj : (CM_pad, CO_pad)
    returns (B, n_th*tile_h, out_w, CO_pad)
    """
    b, h_tot, w_pad, ci_pad = x_pad.shape
    k_h, k_w, _, cm_pad = w_conv.shape
    co_pad = w_proj.shape[1]
    grid = (b, co_pad // co_block, n_th, cm_pad // cm_block,
            ci_pad // ci_block)
    in_rows = (tile_h - 1) * stride + k_h
    w_need = (out_w - 1) * stride + k_w

    plan = strip_plan(
        h_tot=h_tot, w_tot=w_pad, w_span=w_need, c_block=ci_block,
        tile_h=tile_h, grid=grid, window_dims=(0, 2, 4), stride=stride,
        k_h=k_h, residency=residency)
    kernel = functools.partial(
        _fusedmb_kernel, plan=plan, k_h=k_h, k_w=k_w, stride=stride,
        tile_h=tile_h, out_w=out_w, act=act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            plan.in_spec(lambda bi, co, ti, cm, ci: (bi, 0, 0, ci)),
            pl.BlockSpec((k_h, k_w, ci_block, cm_block),
                         lambda bi, co, ti, cm, ci: (0, 0, ci, cm)),
            pl.BlockSpec((cm_block, co_block),
                         lambda bi, co, ti, cm, ci: (cm, co)),
        ],
        out_specs=pl.BlockSpec(
            (1, tile_h, out_w, co_block),
            lambda bi, co, ti, cm, ci: (bi, ti, 0, co)),
        out_shape=jax.ShapeDtypeStruct(
            (b, n_th * tile_h, out_w, co_pad), x_pad.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_h, out_w, cm_block), jnp.float32),
            pltpu.VMEM((tile_h, out_w, co_block), jnp.float32),
            *plan.scratch_shapes(x_pad.dtype),
        ],
        interpret=interpret,
    )(x_pad, w_conv, w_proj)


def _fusedmb_impl(x, w_conv, w_proj, stride, padding, tile_h, act, interpret,
                  residency=DEFAULT_RESIDENCY,
                  axis_name: Optional[str] = None,
                  collective: str = DEFAULT_COLLECTIVE,
                  scatter_width: int = 0):
    """Single-pass Fused-MBConv on one device — or one SHARD of the c_mid
    grid when ``axis_name`` names a mesh axis (``shard_map`` body).

    Under c_mid sharding each device's dense conv is channel-local (it
    holds all of c_in — a dense conv cannot consume a sharded arrival),
    and the projection's c_mid reduction crosses devices per
    ``collective`` exactly like MBConv's pass 2: ``psum`` replicates the
    output, ``psum_scatter`` leaves it c_out-sharded at half the wire
    words.  There is no SE stage, hence no squeeze collective at all.
    """
    validate_collective(collective)
    b, h, w_in, c_in = x.shape
    k_h, k_w, ci_w, c_mid = w_conv.shape
    assert ci_w == c_in, (w_conv.shape, c_in)
    c_out = w_proj.shape[1]
    assert w_proj.shape[0] == c_mid, (w_proj.shape, c_mid)
    s = stride

    out_h, out_w, pads = spatial_pads(h, w_in, k_h, k_w, s, padding)

    ci_block = pick_channel_block(c_in)
    ci_pad = _round_up(c_in, ci_block)
    cm_block = pick_channel_block(c_mid)
    cm_pad = _round_up(c_mid, cm_block)
    co_block = min(128, _round_up(c_out, 8))
    co_pad = _round_up(c_out, co_block)

    xp = jnp.pad(x, ((0, 0), pads[0], pads[1], (0, ci_pad - c_in)))
    wconv_p = jnp.pad(w_conv, ((0, 0), (0, 0), (0, ci_pad - c_in),
                               (0, cm_pad - c_mid)))
    wproj_p = jnp.pad(w_proj, ((0, cm_pad - c_mid), (0, co_pad - c_out)))

    # width cover for the i + s*(out_w-1) + 1 tap slice
    need_w = (out_w - 1) * s + k_w
    if need_w > xp.shape[2]:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, need_w - xp.shape[2]), (0, 0)))

    tile_h = max(1, min(tile_h, out_h))
    n_th = -(-out_h // tile_h)
    # height cover so the last strip's window stays in bounds
    need_h = (n_th - 1) * tile_h * s + (tile_h - 1) * s + k_h
    if need_h > xp.shape[1]:
        xp = jnp.pad(xp, ((0, 0), (0, need_h - xp.shape[1]), (0, 0), (0, 0)))

    out = fusedmb_pallas(
        xp, wconv_p, wproj_p, stride=s, out_w=out_w, tile_h=tile_h,
        n_th=n_th, ci_block=ci_block, cm_block=cm_block, co_block=co_block,
        act=act, interpret=interpret, residency=residency)
    if axis_name is not None and collective == "psum_scatter":
        # layout-aware exit, same contract as MBConv pass 2: zero w_proj
        # columns pad a non-dividing c_out to ``scatter_width`` (their
        # partials are exactly zero), the wrapper slices them back.
        cw = scatter_width if scatter_width else c_out
        out = out[:, :out_h, :, :min(cw, out.shape[-1])]
        if out.shape[-1] < cw:
            out = jnp.pad(
                out, ((0, 0), (0, 0), (0, 0), (0, cw - out.shape[-1])))
        out = jax.lax.psum_scatter(out, axis_name,
                                   scatter_dimension=3, tiled=True)
    else:
        out = out[:, :out_h, :, :c_out]
        if axis_name is not None:
            # projection partials: each shard contracted only its c_mid
            # slice
            out = jax.lax.psum(out, axis_name)
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _fusedmb_op(x, w_conv, w_proj, stride, padding, tile_h, act, interpret,
                residency):
    return _fusedmb_impl(x, w_conv, w_proj, stride, padding, tile_h, act,
                         interpret, residency)


def _fusedmb_fwd(x, w_conv, w_proj, stride, padding, tile_h, act, interpret,
                 residency):
    out = _fusedmb_op(x, w_conv, w_proj, stride, padding, tile_h, act,
                      interpret, residency)
    return out, (x, w_conv, w_proj)


def _fusedmb_bwd(stride, padding, tile_h, act, interpret, residency, res, g):
    # Backward through the mathematically identical reference composition —
    # the single-pass kernel computes the same Fused-MBConv block, so the
    # VJP is exact (same pattern as convdk_fused / convdk_mbconv).
    x, w_conv, w_proj = res
    _, vjp = jax.vjp(
        lambda x_, wc_, wp_: fusedmb_ref(
            x_, wc_, wp_, stride=stride, padding=padding, act=act),
        x, w_conv, w_proj,
    )
    return vjp(g)


_fusedmb_op.defvjp(_fusedmb_fwd, _fusedmb_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "tile_h", "act", "interpret",
                     "residency"),
)
def convdk_fusedmb_fused(
    x: jax.Array,
    w_conv: jax.Array,
    w_proj: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    act: Optional[str] = "silu",
    interpret: Optional[bool] = None,
    residency: Optional[str] = None,
) -> jax.Array:
    """Single-pass fused Fused-MBConv block via one ConvDK Pallas kernel
    (differentiable).  No residual add — the model layer owns that.

    x      : (B, H, W, C_in) NHWC
    w_conv : (k_h, k_w, C_in, C_mid) HWIO dense conv (the collapsed
             expand+DW of EfficientNet-V2's fused stages)
    w_proj : (C_mid, C_out) projection PW (linear)
    act    : conv activation (EfficientNet-V2 uses silu)
    residency : "resident" | "strip_dma" | "strip_dma_db" (default) — how
             the input stream is staged (``kernels.staging``).
    Returns (B, H', W', C_out).  The expanded (C_mid) tensor never touches
    HBM; there is no SE stage and no second pass.
    """
    if interpret is None:
        interpret = default_interpret()
    if residency is None:
        residency = DEFAULT_RESIDENCY
    return _fusedmb_op(x, w_conv, w_proj, stride, padding, tile_h, act,
                       interpret, residency)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "tile_h", "act", "interpret"),
)
def convdk_fusedmb_staged(
    x: jax.Array,
    w_conv: jax.Array,
    w_proj: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    act: Optional[str] = "silu",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """The STAGED Fused-MBConv pipeline (comparison baseline,
    differentiable): dense conv -> HBM -> act -> HBM -> projection einsum.
    The expanded (B, H', W', C_mid) tensor round-trips through HBM exactly
    as the weight-stationary baseline, which is what
    ``convdk_fusedmb_fused`` eliminates.  ``tile_h`` is accepted for
    call-site symmetry with the fused entry; the staged rendering has no
    strip structure.
    """
    del tile_h
    if interpret is None:
        interpret = default_interpret()
    e = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w_conv.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    e = _act_ref(e, act)
    out = jnp.einsum("bhwc,cd->bhwd", e, w_proj.astype(jnp.float32))
    return out.astype(x.dtype)

"""Shared helpers for the ConvDK kernel wrappers.

One home for the padding arithmetic and interpret-mode default so the
fused separable, MBConv and staged pipelines can never desynchronize on
them.
"""

from __future__ import annotations

from typing import Tuple

import jax

_DEFAULT_INTERPRET = jax.default_backend() == "cpu"


def default_interpret() -> bool:
    """Pallas interpret-mode default: interpret on CPU backends, compiled
    Mosaic otherwise."""
    return _DEFAULT_INTERPRET


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def spatial_pads(
    h: int, w_in: int, k_h: int, k_w: int, s: int, padding: str
) -> Tuple[int, int, Tuple[Tuple[int, int], Tuple[int, int]]]:
    """(out_h, out_w, ((top, bottom), (left, right))) for one conv layout.

    SAME matches ``jax.lax.conv_general_dilated``'s split (extra pad goes
    to the bottom/right); VALID pads nothing.
    """
    if padding == "SAME":
        out_h, out_w = -(-h // s), -(-w_in // s)
        ph = max(0, (out_h - 1) * s + k_h - h)
        pw = max(0, (out_w - 1) * s + k_w - w_in)
        pads = ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2))
    elif padding == "VALID":
        out_h, out_w = (h - k_h) // s + 1, (w_in - k_w) // s + 1
        pads = ((0, 0), (0, 0))
    else:
        raise ValueError(padding)
    return out_h, out_w, pads

"""ConvDK causal depthwise-Conv1D Pallas TPU kernel.

This is the performance-critical stem of Mamba-2 (d_conv = 4) and
RecurrentGemma (temporal conv, width 4) — the two assigned architectures the
paper's technique applies to (DESIGN.md §Arch-applicability).

ConvDK mapping (stride 1, so l = k and the shift schedule is the polyphase
identity; Condition 1's odd-k requirement is only needed for s > 1, see
DESIGN.md): the sequence strip rests in VMEM (TRF role) and is re-read at k
static shift offsets; each tap multiplies ALL blocks of the strip in one
vector op (TM kernel duplication role).  Channels ride the 128-lane axis.

Optional fusions: bias add and SiLU (both Mamba-2 and RG-LRU apply SiLU
right after the conv), saving one HBM round-trip of the (B, L, D) tensor.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv1d_kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, tile_l: int,
                   activation: Optional[str]):
    """x_ref: (1, 1, tile_l + k - 1, DB); w_ref: (k, DB); b_ref: (1, DB)."""
    x = x_ref[0, 0]                                   # (tile_l + k - 1, DB)
    acc = jnp.zeros((tile_l, x.shape[-1]), jnp.float32)
    for i in range(k):                                # k shift cycles
        xs = jax.lax.slice(x, (i, 0), (i + tile_l, x.shape[-1]))
        acc = acc + xs.astype(jnp.float32) * w_ref[i].astype(jnp.float32)
    acc = acc + b_ref[0].astype(jnp.float32)
    if activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    o_ref[0, 0] = acc.astype(o_ref.dtype)


def conv1d_pallas(
    x_strips: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    *,
    tile_l: int,
    activation: Optional[str] = None,
    d_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Run the ConvDK causal conv1d kernel over pre-staged strips.

    x_strips : (B, n_tl, tile_l + k - 1, D)  — strip t holds (left-padded)
               sequence positions [t*tile_l, t*tile_l + tile_l + k - 1)
    w        : (k, D);  bias: (D,)
    returns  : (B, n_tl, tile_l, D)
    """
    b, n_tl, in_len, d = x_strips.shape
    k, _ = w.shape
    assert in_len == tile_l + k - 1, (in_len, tile_l, k)
    assert d % d_block == 0, (d, d_block)
    grid = (b, n_tl, d // d_block)

    kernel = functools.partial(
        _conv1d_kernel, k=k, tile_l=tile_l, activation=activation
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, in_len, d_block), lambda bi, ti, di: (bi, ti, 0, di)
            ),
            pl.BlockSpec((k, d_block), lambda bi, ti, di: (0, di)),
            pl.BlockSpec((1, d_block), lambda bi, ti, di: (0, di)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, tile_l, d_block), lambda bi, ti, di: (bi, ti, 0, di)
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_tl, tile_l, d), x_strips.dtype),
        interpret=interpret,
    )(x_strips, w, bias[None, :])

"""Public jit'd wrappers for the ConvDK Pallas kernels.

``stage_row_strips`` / ``stage_seq_strips`` are the HBM->VMEM staging step —
the TPU analogue of the paper's IB->TRF strip loads: the input is laid out
as overlapping strips once, so each kernel grid cell consumes a plain
non-overlapping block (halo cost: (k - s) rows per tile_h*s rows, < 13 %;
the strips are the only extra HBM traffic, exactly as the TRF loads are the
only buffer traffic in the CIM macro).

On CPU (tests, smoke runs) the wrappers run the kernels in interpret mode;
pass ``interpret=False`` (default on TPU) for compiled Mosaic kernels.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.perfmodel import pick_channel_block
from .common import default_interpret, round_up as _round_up, spatial_pads
from .convdk_conv1d import conv1d_pallas
from .convdk_dw import dw2d_pallas
from .ref import causal_conv1d_ref, depthwise2d_ref


def stage_row_strips(x: jax.Array, k: int, stride: int, tile_h: int) -> jax.Array:
    """(B, H_pad, W_pad, C) -> (B, n_th, (tile_h-1)*s + k, W_pad, C) strips."""
    b, h_pad, w_pad, c = x.shape
    in_rows = (tile_h - 1) * stride + k
    out_h = (h_pad - k) // stride + 1
    n_th = -(-out_h // tile_h)
    # pad the bottom so the final strip is full-size
    need = (n_th - 1) * tile_h * stride + in_rows
    if need > h_pad:
        x = jnp.pad(x, ((0, 0), (0, need - h_pad), (0, 0), (0, 0)))
    starts = jnp.arange(n_th) * (tile_h * stride)
    idx = starts[:, None] + jnp.arange(in_rows)[None, :]     # (n_th, in_rows)
    return x[:, idx]                                          # gather rows


def stage_seq_strips(x: jax.Array, k: int, tile_l: int) -> jax.Array:
    """(B, L, D) -> causal strips (B, n_tl, tile_l + k - 1, D)."""
    b, l, d = x.shape
    n_tl = -(-l // tile_l)
    xp = jnp.pad(x, ((0, 0), (k - 1, n_tl * tile_l - l), (0, 0)))
    starts = jnp.arange(n_tl) * tile_l
    idx = starts[:, None] + jnp.arange(tile_l + k - 1)[None, :]
    return xp[:, idx]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _dw2d_op(x, w, stride, padding, tile_h, interpret):
    return _dw2d_impl(x, w, stride, padding, tile_h, interpret)


def _dw2d_fwd(x, w, stride, padding, tile_h, interpret):
    return _dw2d_op(x, w, stride, padding, tile_h, interpret), (x, w)


def _dw2d_bwd(stride, padding, tile_h, interpret, res, g):
    # Backward through the mathematically identical jnp reference — the
    # kernel computes the same convolution, so the VJP is exact.
    x, w = res
    _, vjp = jax.vjp(
        lambda x_, w_: depthwise2d_ref(x_, w_, stride=stride, padding=padding),
        x, w,
    )
    return vjp(g)


_dw2d_op.defvjp(_dw2d_fwd, _dw2d_bwd)


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "tile_h", "interpret")
)
def convdk_depthwise2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Depthwise Conv2D via the ConvDK Pallas kernel (differentiable).

    x: (B, H, W, C) NHWC; w: (k_h, k_w, C).  Returns (B, H', W', C).
    """
    if interpret is None:
        interpret = default_interpret()
    return _dw2d_op(x, w, stride, padding, tile_h, interpret)


def _dw2d_impl(x, w, stride, padding, tile_h, interpret):
    b, h, w_in, c = x.shape
    k_h, k_w, cw = w.shape
    assert cw == c, (cw, c)
    s = stride
    out_h, out_w, pads = spatial_pads(h, w_in, k_h, k_w, s, padding)

    # channel blocking: minimal-padding block along the 128-lane axis
    c_block = pick_channel_block(c)
    c_pad = _round_up(c, c_block)
    xp = jnp.pad(x, ((0, 0), pads[0], pads[1], (0, c_pad - c)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, c_pad - c)))
    # ensure the width slice i + s*(out_w-1) + 1 stays in bounds
    need_w = (out_w - 1) * s + k_w
    if need_w > xp.shape[2]:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, need_w - xp.shape[2]), (0, 0)))

    tile_h = min(tile_h, out_h)
    strips = stage_row_strips(xp, k_h, s, tile_h)        # IB->TRF staging
    out = dw2d_pallas(
        strips, wp, stride=s, out_w=out_w, tile_h=tile_h,
        c_block=c_block, interpret=interpret,
    )                                                     # (B, n_th, TH, W', C)
    out = out.reshape(b, -1, out_w, c_pad)[:, :out_h, :, :c]
    return out


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "tile_h", "dw_act", "act",
                     "interpret"),
)
def convdk_separable_staged(
    x: jax.Array,
    w_dw: jax.Array,
    w_pw: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    dw_act: Optional[str] = None,
    act: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """The STAGED two-kernel separable pipeline (comparison baseline).

    Runs the DW ConvDK kernel over pre-staged strips, round-trips the DW
    output through HBM, then applies the pointwise projection as a separate
    matmul — the exact double HBM trip ``convdk_fused_separable`` fuses away.
    Kept as the reference executable for the fused-vs-staged traffic and
    numerics comparisons (benchmarks/kernel_bench.py, tests).
    """
    from .ref import _act_ref  # local import: ref has no dep on ops
    y = convdk_depthwise2d(x, w_dw, stride=stride, padding=padding,
                           tile_h=tile_h, interpret=interpret)
    y = _act_ref(y.astype(jnp.float32), dw_act)
    z = jnp.einsum("bhwc,cd->bhwd", y, w_pw.astype(jnp.float32))
    return _act_ref(z, act).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _conv1d_op(x, w, bias, activation, tile_l, interpret):
    return _conv1d_impl(x, w, bias, activation, tile_l, interpret)


def _conv1d_fwd(x, w, bias, activation, tile_l, interpret):
    return _conv1d_op(x, w, bias, activation, tile_l, interpret), (x, w, bias)


def _conv1d_bwd(activation, tile_l, interpret, res, g):
    x, w, bias = res
    _, vjp = jax.vjp(
        lambda x_, w_, b_: causal_conv1d_ref(x_, w_, b_, activation=activation),
        x, w, bias,
    )
    return vjp(g)


_conv1d_op.defvjp(_conv1d_fwd, _conv1d_bwd)


@functools.partial(
    jax.jit, static_argnames=("activation", "tile_l", "interpret")
)
def convdk_causal_conv1d(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    tile_l: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Causal depthwise Conv1D (+ fused bias/SiLU) via the ConvDK kernel
    (differentiable).

    x: (B, L, D); w: (k, D); bias: (D,) or None.  Returns (B, L, D).
    """
    if interpret is None:
        interpret = default_interpret()
    if bias is None:
        bias = jnp.zeros((x.shape[-1],), x.dtype)
    return _conv1d_op(x, w, bias, activation, tile_l, interpret)


def _conv1d_impl(x, w, bias, activation, tile_l, interpret):
    b, l, d = x.shape
    k, dw = w.shape
    assert dw == d, (dw, d)

    d_block = min(128, _round_up(d, 8))
    d_pad = _round_up(d, d_block)
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad - d)))
    wp = jnp.pad(w, ((0, 0), (0, d_pad - d)))
    bp = jnp.pad(bias, (0, d_pad - d))

    tile_l_eff = min(tile_l, _round_up(l, 8))
    strips = stage_seq_strips(xp, k, tile_l_eff)          # IB->TRF staging
    out = conv1d_pallas(
        strips, wp, bp, tile_l=tile_l_eff, activation=activation,
        d_block=d_block, interpret=interpret,
    )                                                     # (B, n_tl, TL, D)
    return out.reshape(b, -1, d_pad)[:, :l, :d]

"""Fused depthwise-separable ConvDK Pallas kernel (DW + PW in one pass).

The staged pipeline (``ops.convdk_depthwise2d`` + a host-side 1x1 matmul)
round-trips through HBM twice per separable block:

1. ``ops.stage_row_strips`` materializes a *duplicated, overlapping* copy of
   the input (the halo rows of every strip are written twice), and
2. the depthwise output is written back to HBM only to be re-read by the
   pointwise (1x1) projection.

Both trips are exactly the IB<->TRF buffer traffic Algorithms 1-2 of the
paper are designed to eliminate.  This kernel removes them:

* **Strip staging through the shared engine** (``kernels.staging``) — the
  kernel receives the *unstaged* ``(B, H_tot, W_pad, C)`` input and stages
  each grid cell's overlapping ``(tile_h-1)*s + k_h`` row window per the
  schedule's **residency**: a VMEM-resident ``pl.ds`` slice
  (``"resident"``), a per-cell async DMA from the ``ANY``/HBM space
  (``"strip_dma"``), or a double-buffered DMA stream that prefetches the
  next cell's window while this one computes (``"strip_dma_db"``, the
  production default).  Halo rows are re-read, never re-written to HBM
  (the TRF-residency property of Algorithm 1's shift cycles).
* **Fused pointwise projection** — the DW accumulator is contracted with the
  ``(C_in, C_out)`` pointwise weight on the lane axis while still in VMEM.
  Depthwise outputs never touch HBM at all; the only HBM write is the final
  block output.

Grid layout: ``(batch, row_strip, c_out_block, c_in_block)`` with the input
-channel reduction innermost so the f32 scratch accumulator carries partial
PW sums across sequential grid steps (the standard Pallas reduction-dim
pattern).  Because DW is depthwise, its per-``c_in``-block accumulator is
complete before the PW contraction of that block — so a DW-stage activation
(the BN-free stand-in for MobileNet's ReLU6 between DW and PW) can be fused
exactly.

Interpret mode (the CI backend) executes the SAME DMA-structured code path
— the pallas interpreter implements the copy/semaphore primitives — so the
parity suite exercises the production staging structure, not a CI-only
twin.  The traffic model for schedule selection lives in ``core.perfmodel``
/ ``core.autotune`` and prices every residency.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.perfmodel import DEFAULT_RESIDENCY, pick_channel_block
from .common import default_interpret, round_up as _round_up, spatial_pads
from .ref import _act_ref, separable_ref
from .staging import StripPlan, StripStream, strip_plan


def _fused_kernel(x_ref, wdw_ref, wpw_ref, o_ref, *scratch, plan: StripPlan,
                  k_h: int, k_w: int, stride: int, tile_h: int, out_w: int,
                  dw_act: Optional[str], act: Optional[str]):
    """One (batch, row-strip, c_out-block, c_in-block) grid cell.

    x_ref   : unstaged input — a full-height VMEM channel block
              (``resident``) or the whole ``ANY``-space tensor (DMA modes)
    wdw_ref : (k_h, k_w, CI)         depthwise taps (the "TM")
    wpw_ref : (CI, CO)               pointwise projection block
    o_ref   : (1, tile_h, out_w, CO)
    scratch : (tile_h, out_w, CO) f32 PW accumulator (partial sums across
              the innermost c_in grid dim) + the staging engine's refs.
    """
    s = stride
    stage_refs, (acc_ref,) = plan.take_scratch(scratch)
    ci = pl.program_id(3)
    n_ci = pl.num_programs(3)

    # The staged strip window: (in_rows, w_span, CI).  Under strip_dma_db
    # this wait also kicks off the prefetch of the NEXT cell's window.
    x = StripStream(plan, x_ref, stage_refs).get()

    # Algorithm-2 tap loop: l shift cycles x k_h row taps over the resident
    # strip, all width blocks updated per tap (see convdk_dw._dw2d_kernel).
    dw = jnp.zeros((tile_h, out_w, x.shape[-1]), jnp.float32)
    for j in range(k_h):
        for i in range(k_w):
            xs = jax.lax.slice(
                x,
                (j, i, 0),
                (j + s * (tile_h - 1) + 1, i + s * (out_w - 1) + 1,
                 x.shape[-1]),
                (s, s, 1),
            )
            dw = dw + xs.astype(jnp.float32) * wdw_ref[j, i].astype(jnp.float32)

    # Depthwise is per-channel, so this block's DW output is final: the
    # mid-block activation fuses exactly, before the lane-axis contraction.
    dw = _act_ref(dw, dw_act)

    # Fused pointwise: consume the DW accumulator while it is still in VMEM.
    partial = jax.lax.dot_general(
        dw.reshape(tile_h * out_w, dw.shape[-1]),
        wpw_ref[:, :].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(tile_h, out_w, -1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = partial

    @pl.when(ci > 0)
    def _accumulate():
        acc_ref[...] = acc_ref[...] + partial

    @pl.when(ci == n_ci - 1)
    def _finalize():
        o_ref[0] = _act_ref(acc_ref[...], act).astype(o_ref.dtype)


def fused_separable_pallas(
    x_pad: jax.Array,
    w_dw: jax.Array,
    w_pw: jax.Array,
    *,
    stride: int,
    out_w: int,
    tile_h: int,
    n_th: int,
    ci_block: int,
    co_block: int,
    dw_act: Optional[str] = None,
    act: Optional[str] = None,
    interpret: bool = False,
    residency: str = DEFAULT_RESIDENCY,
) -> jax.Array:
    """Raw fused kernel launch over a pre-padded input.

    x_pad : (B, H_tot, W_pad, C_in) with H_tot >= (n_th-1)*tile_h*s + in_rows
    w_dw  : (k_h, k_w, C_in);  w_pw : (C_in, C_out)
    returns (B, n_th*tile_h, out_w, C_out)
    """
    b, h_tot, w_pad, c_in = x_pad.shape
    k_h, k_w, _ = w_dw.shape
    c_out = w_pw.shape[1]
    assert c_in % ci_block == 0, (c_in, ci_block)
    assert c_out % co_block == 0, (c_out, co_block)
    grid = (b, n_th, c_out // co_block, c_in // ci_block)

    plan = strip_plan(
        h_tot=h_tot, w_tot=w_pad,
        w_span=min(w_pad, (out_w - 1) * stride + k_w),
        c_block=ci_block, tile_h=tile_h, grid=grid, window_dims=(0, 1, 3),
        stride=stride, k_h=k_h, residency=residency)

    kernel = functools.partial(
        _fused_kernel, plan=plan, k_h=k_h, k_w=k_w, stride=stride,
        tile_h=tile_h, out_w=out_w, dw_act=dw_act, act=act,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            plan.in_spec(lambda bi, ti, co, ci: (bi, 0, 0, ci)),
            pl.BlockSpec((k_h, k_w, ci_block),
                         lambda bi, ti, co, ci: (0, 0, ci)),
            pl.BlockSpec((ci_block, co_block),
                         lambda bi, ti, co, ci: (ci, co)),
        ],
        out_specs=pl.BlockSpec(
            (1, tile_h, out_w, co_block),
            lambda bi, ti, co, ci: (bi, ti, 0, co),
        ),
        out_shape=jax.ShapeDtypeStruct(
            (b, n_th * tile_h, out_w, c_out), x_pad.dtype),
        scratch_shapes=[pltpu.VMEM((tile_h, out_w, co_block), jnp.float32),
                        *plan.scratch_shapes(x_pad.dtype)],
        interpret=interpret,
    )(x_pad, w_dw, w_pw)


def _fused_impl(x, w_dw, w_pw, stride, padding, tile_h, dw_act, act,
                interpret, residency=DEFAULT_RESIDENCY):
    b, h, w_in, c = x.shape
    k_h, k_w, cw = w_dw.shape
    c_in_pw, c_out = w_pw.shape
    assert cw == c and c_in_pw == c, (cw, c_in_pw, c)
    s = stride
    out_h, out_w, pads = spatial_pads(h, w_in, k_h, k_w, s, padding)

    # input channels: minimal-padding block (padding here costs real strip
    # reads and MACs); output channels: plain 128-lane cap — padding c_out
    # only spends zero-lane MACs and SHRINKS n_co (fewer input re-reads).
    ci_block = pick_channel_block(c)
    ci_pad = _round_up(c, ci_block)
    co_block = min(128, _round_up(c_out, 8))
    co_pad = _round_up(c_out, co_block)
    xp = jnp.pad(x, ((0, 0), pads[0], pads[1], (0, ci_pad - c)))
    wdp = jnp.pad(w_dw, ((0, 0), (0, 0), (0, ci_pad - c)))
    wpp = jnp.pad(w_pw, ((0, ci_pad - c), (0, co_pad - c_out)))

    # width cover for the i + s*(out_w-1) + 1 tap slice
    need_w = (out_w - 1) * s + k_w
    if need_w > xp.shape[2]:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, need_w - xp.shape[2]), (0, 0)))

    tile_h = max(1, min(tile_h, out_h))
    n_th = -(-out_h // tile_h)
    # height cover so the last strip's window stays in bounds
    need_h = (n_th - 1) * tile_h * s + (tile_h - 1) * s + k_h
    if need_h > xp.shape[1]:
        xp = jnp.pad(xp, ((0, 0), (0, need_h - xp.shape[1]), (0, 0), (0, 0)))

    out = fused_separable_pallas(
        xp, wdp, wpp, stride=s, out_w=out_w, tile_h=tile_h, n_th=n_th,
        ci_block=ci_block, co_block=co_block, dw_act=dw_act, act=act,
        interpret=interpret, residency=residency,
    )
    return out[:, :out_h, :, :c_out]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _fused_op(x, w_dw, w_pw, stride, padding, tile_h, dw_act, act, interpret,
              residency):
    return _fused_impl(x, w_dw, w_pw, stride, padding, tile_h, dw_act, act,
                       interpret, residency)


def _fused_fwd(x, w_dw, w_pw, stride, padding, tile_h, dw_act, act, interpret,
               residency):
    out = _fused_op(x, w_dw, w_pw, stride, padding, tile_h, dw_act, act,
                    interpret, residency)
    return out, (x, w_dw, w_pw)


def _fused_bwd(stride, padding, tile_h, dw_act, act, interpret, residency,
               res, g):
    # Backward through the mathematically identical reference composition —
    # the kernel computes the same separable block, so the VJP is exact.
    x, w_dw, w_pw = res
    _, vjp = jax.vjp(
        lambda x_, wd_, wp_: separable_ref(
            x_, wd_, wp_, stride=stride, padding=padding, dw_act=dw_act,
            act=act),
        x, w_dw, w_pw,
    )
    return vjp(g)


_fused_op.defvjp(_fused_fwd, _fused_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "tile_h", "dw_act", "act",
                     "interpret", "residency"),
)
def convdk_fused_separable(
    x: jax.Array,
    w_dw: jax.Array,
    w_pw: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    dw_act: Optional[str] = None,
    act: Optional[str] = None,
    interpret: Optional[bool] = None,
    residency: Optional[str] = None,
) -> jax.Array:
    """Fused depthwise-separable block via one ConvDK Pallas kernel
    (differentiable).

    Computes ``act(pointwise(dw_act(depthwise(x, w_dw)), w_pw))`` with a
    single HBM read of ``x`` and a single HBM write of the block output.

    x    : (B, H, W, C_in) NHWC
    w_dw : (k_h, k_w, C_in) depthwise taps
    w_pw : (C_in, C_out) pointwise projection
    dw_act / act : None | "relu" | "relu6", fused mid-block / output
    activations.
    residency : "resident" | "strip_dma" | "strip_dma_db" (default) — how
    the input stream is staged (see ``kernels.staging``); the autotuner's
    per-layer pick routes through ``models.common.separable_block``.
    Returns (B, H', W', C_out).
    """
    if interpret is None:
        interpret = default_interpret()
    if residency is None:
        residency = DEFAULT_RESIDENCY
    return _fused_op(x, w_dw, w_pw, stride, padding, tile_h, dw_act, act,
                     interpret, residency)

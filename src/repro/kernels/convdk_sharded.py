"""Mesh-native wrappers for the fused ConvDK pipelines (``shard_map``).

The fused kernels in ``convdk_fused`` / ``convdk_mbconv`` keep the
depthwise tensor out of HBM on ONE core; at production scale the
batch/channel grid does not fit a single core, and the paper's traffic
claim must survive partitioning (the Eyeriss/MAERI lesson: reuse arguments
re-prove, they do not transfer).  This module wraps both pipelines in
``shard_map`` over the repo's ("data", "model") mesh
(``repro.sharding`` / ``launch.mesh``), with the axis mapping:

* **batch -> "data"** for both families — pure data parallelism, every
  device runs the identical fused schedule on its batch slice.  A "pod"
  axis (multi-pod meshes) joins it as an outer multiplier: batch shards
  over ("pod", "data") jointly, no new collective;
* **separable: c_out -> "model"** — the kernel grid's channel axis.  The
  PW contraction reduces over c_in, which stays replicated, so each
  device's output-channel slice is complete on-chip and the sharded path
  needs NO collective;
* **MBConv: c_mid -> "model"** — the expanded/DW/SE width (the kernel
  grid's channel axis).  Expand columns, DW taps, the retained DW tensor
  and the excite FC are all local to the shard, but the two contractions
  over the full C_mid become cross-device reductions inside
  ``_mbconv_impl``: the pass-1 SE pool leaves the chip once as a tiny
  (B, C_se) squeeze ``psum``, and pass 2 reduces the projection partials
  per the schedule's **collective** axis — ``psum`` (ring all-reduce,
  replicated output) or ``psum_scatter`` (half the wire words, output
  sharded on c_out for a layout-aware consumer).

Each shard runs the shared strip-staging engine (``kernels.staging``)
under the schedule's residency, so the DMA-structured input streams are
identical on and off the mesh.

Both wrappers are differentiable with the same pattern as their
single-device counterparts: the VJP runs through the mathematically
identical reference composition on the full (replicated) tensors.

**Serving-rate call sites**: the public wrappers dispatch through a
process-wide cache of ``jax.jit``-ted entry points keyed on (mesh, static
schedule) — without it every eager call rebuilt the ``shard_map`` closure
and re-traced the whole fused pipeline (the ROADMAP re-trace edge).
``TRACE_COUNTS`` records actual impl traces per family so the regression
test can pin the cache down.

Per-device HBM traffic and the psum bytes are priced by
``core.perfmodel.sharded_separable_traffic`` /
``sharded_mbconv_traffic``; ``core.autotune`` solves schedules under
``mesh_shape`` and ``residency`` axes so partitionings never collide.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import (
    residual_barrier,
    residual_barrier_needed,
    shard_map_compat,
)
from ..core import telemetry
from ..core.perfmodel import (
    DEFAULT_COLLECTIVE,
    DEFAULT_LAYOUT,
    DEFAULT_RESIDENCY,
    scatter_c_out,
    validate_layout,
)
from .common import default_interpret
from .convdk_fused import _fused_impl
from .convdk_fusedmb import _fusedmb_impl
from .convdk_mbconv import _mbconv_impl
from .ref import _act_ref, fusedmb_ref, mbconv_ref, separable_ref

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"

# Times each sharded impl body was TRACED (not called) — a jit-cache hit
# leaves these untouched.  tests/test_distributed_fused.py pins the
# serving-rate contract: N calls at one (mesh, schedule, shapes) == 1 trace.
TRACE_COUNTS: Dict[str, int] = {"separable": 0, "mbconv": 0, "fusedmb": 0}


def conv_mesh_shape(mesh) -> Tuple[int, int]:
    """Effective (data, model) factors of a mesh (1 for an absent axis).

    A "pod" axis (multi-pod deployments, ``launch.mesh`` multi_pod=True)
    folds into the data factor as a PURE data-parallel outer multiplier:
    batch shards over ("pod", "data") jointly, no new collective appears
    (the MBConv reductions stay inside each model group), and the pricing
    is a per-pod replica of the existing totals — which is exactly what
    ``perfmodel`` computes from the folded dp."""
    return (mesh.shape.get(POD_AXIS, 1) * mesh.shape.get(DATA_AXIS, 1),
            mesh.shape.get(MODEL_AXIS, 1))


def _batch_axes(mesh):
    """The PartitionSpec entry the batch dim shards over: ("pod", "data")
    jointly when the mesh carries a pod axis, plain "data" otherwise."""
    return (POD_AXIS, DATA_AXIS) if POD_AXIS in mesh.shape else DATA_AXIS


def can_shard_fused(mesh, batch: int, channels: int) -> bool:
    """True iff the data/model axes exist and the EFFECTIVE factors
    (pod folded into data) divide (batch, channel grid) — the model-layer
    routing falls back to the single-device kernel otherwise (same drop
    policy as ``sharding.spec_for``)."""
    if DATA_AXIS not in mesh.shape or MODEL_AXIS not in mesh.shape:
        return False
    dp, mp = conv_mesh_shape(mesh)
    return batch % dp == 0 and channels % mp == 0


def _require_shardable(mesh, batch: int, channels: int, channel_name: str):
    if DATA_AXIS not in mesh.shape or MODEL_AXIS not in mesh.shape:
        raise ValueError(
            f"mesh must carry '{DATA_AXIS}' and '{MODEL_AXIS}' axes, got "
            f"{dict(mesh.shape)}")
    dp, mp = conv_mesh_shape(mesh)
    if batch % dp != 0:
        raise ValueError(
            f"batch {batch} not divisible by the effective data factor "
            f"{dp} (pod*data)")
    if channels % mp != 0:
        raise ValueError(
            f"{channel_name} {channels} not divisible by {MODEL_AXIS}={mp}")


# ---------------------------------------------------------------------------
# separable: batch on "data", c_out on "model" (collective-free)
# ---------------------------------------------------------------------------

def _sep_sharded_impl(x, w_dw, w_pw, mesh, stride, padding, tile_h, dw_act,
                      act, interpret, residency, collective, in_layout):
    validate_layout(in_layout)
    sharded_in = in_layout == "model_sharded"
    _dp, mp = conv_mesh_shape(mesh)
    c_in, c_out = x.shape[-1], w_pw.shape[1]
    batch = _batch_axes(mesh)
    TRACE_COUNTS["separable"] += 1

    if not sharded_in:
        # classic partitioning: c_out on "model", c_in replicated — the PW
        # reduction is device-local, no collective
        _require_shardable(mesh, x.shape[0], c_out, "c_out")

        def local(xl, wdl, wpl):
            return _fused_impl(xl, wdl, wpl, stride, padding, tile_h,
                               dw_act, act, interpret, residency)

        return shard_map_compat(
            local, mesh,
            in_specs=(P(batch, None, None, None),   # batch slice, full C_in
                      P(None, None, None),          # DW taps replicated
                      P(None, MODEL_AXIS)),         # PW columns sharded
            out_specs=P(batch, None, None, MODEL_AXIS),
        )(x, w_dw, w_pw)

    # sharded-in partitioning: c_in on "model" — the DW is channel-local
    # on the arriving slice (no gather, the layout win), but the PW now
    # reduces over c_in ACROSS devices: each shard contracts its c_in
    # rows against the FULL c_out width, and the partials reduce per
    # ``collective``.  The output activation is nonlinear, so it must be
    # applied AFTER the reduction — the kernel runs with act=None and the
    # local body applies it to the reduced result.
    _require_shardable(mesh, x.shape[0], c_in, "c_in")
    cw = scatter_c_out(c_out, mp) if collective == "psum_scatter" else c_out

    def local_sharded(xl, wdl, wpl):
        out = _fused_impl(xl, wdl, wpl, stride, padding, tile_h, dw_act,
                          None, interpret, residency)
        if collective == "psum_scatter":
            if out.shape[-1] < cw:
                out = jnp.pad(out, ((0, 0), (0, 0), (0, 0),
                                    (0, cw - out.shape[-1])))
            out = jax.lax.psum_scatter(out, MODEL_AXIS,
                                       scatter_dimension=3, tiled=True)
        else:
            out = jax.lax.psum(out, MODEL_AXIS)
        return _act_ref(out, act).astype(out.dtype)

    out_spec = P(batch, None, None,
                 MODEL_AXIS if collective == "psum_scatter" else None)
    out = shard_map_compat(
        local_sharded, mesh,
        in_specs=(P(batch, None, None, MODEL_AXIS),  # batch + C_in slice
                  P(None, None, MODEL_AXIS),         # DW taps per channel
                  P(MODEL_AXIS, None)),              # PW rows sharded
        out_specs=out_spec,
    )(x, w_dw, w_pw)
    if cw != c_out:
        out = out[..., :c_out]
    return out


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10, 11, 12))
def _sep_sharded_op(x, w_dw, w_pw, mesh, stride, padding, tile_h, dw_act,
                    act, interpret, residency, collective, in_layout):
    return _sep_sharded_impl(x, w_dw, w_pw, mesh, stride, padding, tile_h,
                             dw_act, act, interpret, residency, collective,
                             in_layout)


def _sep_sharded_fwd(x, w_dw, w_pw, mesh, stride, padding, tile_h, dw_act,
                     act, interpret, residency, collective, in_layout):
    out = _sep_sharded_op(x, w_dw, w_pw, mesh, stride, padding, tile_h,
                          dw_act, act, interpret, residency, collective,
                          in_layout)
    # barrier: under the jitted entry, raw-input residuals get forwarded
    # and a cotangent double-counts (see compat.residual_barrier)
    return out, residual_barrier((x, w_dw, w_pw))


def _sep_sharded_bwd(mesh, stride, padding, tile_h, dw_act, act, interpret,
                     residency, collective, in_layout, res, g):
    x, w_dw, w_pw = res
    _, vjp = jax.vjp(
        lambda x_, wd_, wp_: separable_ref(
            x_, wd_, wp_, stride=stride, padding=padding, dw_act=dw_act,
            act=act),
        x, w_dw, w_pw,
    )
    return vjp(g)


_sep_sharded_op.defvjp(_sep_sharded_fwd, _sep_sharded_bwd)


@functools.lru_cache(maxsize=256)
def _sep_sharded_entry(mesh, stride, padding, tile_h, dw_act, act, interpret,
                       residency, collective, in_layout):
    """One jitted entry point per (mesh, static schedule).

    The lru_cache makes repeated calls at serving rate reuse ONE
    ``jax.jit`` callable, whose own cache then keys on shapes/dtypes — the
    shard_map closure is built once per trace instead of once per call."""

    @jax.jit
    def entry(x, w_dw, w_pw):
        return _sep_sharded_op(x, w_dw, w_pw, mesh, stride, padding, tile_h,
                               dw_act, act, interpret, residency, collective,
                               in_layout)

    return entry


def convdk_fused_separable_sharded(
    x: jax.Array,
    w_dw: jax.Array,
    w_pw: jax.Array,
    *,
    mesh,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    dw_act: Optional[str] = None,
    act: Optional[str] = None,
    interpret: Optional[bool] = None,
    residency: Optional[str] = None,
    collective: Optional[str] = None,
    in_layout: Optional[str] = None,
) -> jax.Array:
    """Mesh-sharded fused depthwise-separable block (differentiable).

    ``shard_map`` over ``mesh``: batch on "data" (jointly with "pod"
    when the mesh carries one) for both layouts, then per ``in_layout``:

    * ``"replicated"`` (default): output channels on "model"; every
      device runs the single-device fused kernel — including its
      strip-staging engine, per ``residency`` — on its (batch, c_out)
      tile.  The c_in reduction is device-local (c_in is replicated), so
      no collective is needed — per-device HBM traffic is the
      single-device model evaluated at the shard shape.  Requires
      ``c_out % model == 0``.
    * ``"model_sharded"``: INPUT channels on "model" — the block consumes
      a c_in-sharded arrival without a gather (the DW is channel-local on
      the slice), and the PW partials reduce per ``collective``
      ("ring_allreduce" psum, replicated output; "psum_scatter" leaves
      the output c_out-sharded, zero-padding non-dividing widths).  The
      output activation is applied after the reduction (it is nonlinear).
      Requires ``c_in % model == 0``.

    ``can_shard_fused`` pre-checks divisibility; the model layer falls
    back to the unsharded kernel when the grid does not divide.
    Dispatches through a cached jitted entry point, so repeated
    serving-rate calls do not re-trace the ``shard_map`` closure.
    """
    if interpret is None:
        interpret = default_interpret()
    if residency is None:
        residency = DEFAULT_RESIDENCY
    if collective is None:
        collective = DEFAULT_COLLECTIVE
    if in_layout is None:
        in_layout = DEFAULT_LAYOUT
    # resolve the residual-forwarding probe EAGERLY (it cannot run inside
    # the fwd trace; cheap once cached) so the barrier decision the trace
    # bakes in is the probed one, not the safe fallback
    residual_barrier_needed()
    telemetry.counter("sharded.dispatch.separable")
    telemetry.counter(f"sharded.collective.{collective}")
    return _sep_sharded_entry(mesh, stride, padding, tile_h, dw_act, act,
                              interpret, residency, collective, in_layout)(
        x, w_dw, w_pw)


# ---------------------------------------------------------------------------
# MBConv: batch on "data", c_mid on "model" (SE squeeze + projection psum)
# ---------------------------------------------------------------------------

def _mbconv_sharded_impl(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                         mesh, stride, padding, tile_h, mode, exp_act,
                         dw_act, interpret, residency, collective,
                         in_layout, se_act="silu", gate_act="sigmoid"):
    _require_shardable(mesh, x.shape[0], w_dw.shape[-1], "c_mid")
    validate_layout(in_layout)
    _dp, mp = conv_mesh_shape(mesh)
    c_in, c_out = x.shape[-1], w_proj.shape[1]
    # non-dividing c_out no longer rejects scatter: the projection pads to
    # the next model-factor multiple inside _mbconv_impl (zero columns
    # contribute zero partials — exact), and the gathered-global view is
    # sliced back to c_out below
    cw = scatter_c_out(c_out, mp) if collective == "psum_scatter" else c_out
    sharded_in = in_layout == "model_sharded"
    if sharded_in and c_in % mp != 0:
        raise ValueError(
            f"model_sharded in_layout needs c_in % {MODEL_AXIS} == 0, got "
            f"c_in={c_in} over {MODEL_AXIS}={mp}")
    # identity-expand blocks (the model layer's expand_ratio == 1 form:
    # w_exp == I, exp_act None) consume a c_in-sharded arrival FREE — the
    # arriving slice IS the c_mid slice.  A real expand is dense over ALL
    # of c_in, so a sharded arrival must be gathered back at the entry
    # (priced as perfmodel's transition_words; the ISSUE's row-sharded
    # expand alternative would need a psum BEFORE the nonlinear exp_act
    # inside pass 1 — not expressible at this level — and prices e>=1x
    # worse than the gather anyway).
    identity_expand = c_in == w_dw.shape[-1] and exp_act is None
    TRACE_COUNTS["mbconv"] += 1

    def local(xl, wel, wdl, s1l, b1l, s2l, b2l, wpl):
        if sharded_in:
            if identity_expand:
                # free entry: the c_in slice is the c_mid slice; the
                # identity expand restates itself at the local width
                wel = jnp.eye(xl.shape[-1], dtype=wel.dtype)
            else:
                # gather entry: the dense expand needs all of c_in
                xl = jax.lax.all_gather(xl, MODEL_AXIS, axis=3, tiled=True)
        return _mbconv_impl(xl, wel, wdl, s1l, b1l, s2l, b2l, wpl, stride,
                            padding, tile_h, mode, exp_act, dw_act,
                            interpret, residency, se_act=se_act,
                            gate_act=gate_act, axis_name=MODEL_AXIS,
                            collective=collective, scatter_width=cw)

    batch = _batch_axes(mesh)
    x_spec = P(batch, None, None, MODEL_AXIS if sharded_in else None)
    # free entry: the local identity expand replaces the (sharded-column)
    # w_exp slice, so its spec only has to partition consistently
    exp_spec = (P(MODEL_AXIS, None) if (sharded_in and identity_expand)
                else P(None, MODEL_AXIS))
    # the layout-aware exit: under psum_scatter each shard keeps only its
    # c_out slice, so the output leaves sharded on "model" — a following
    # PW/block that consumes c_out-sharded activations needs no regather
    # (the global VALUES are identical to the ring variant's)
    out_spec = P(batch, None, None,
                 MODEL_AXIS if collective == "psum_scatter" else None)
    out = shard_map_compat(
        local, mesh,
        in_specs=(x_spec,                           # batch slice (+ C_in
                                                    #   slice when sharded-in)
                  exp_spec,                         # expand columns
                  P(None, None, MODEL_AXIS),        # DW taps per channel
                  P(MODEL_AXIS, None),              # squeeze FC rows
                  P(None),                          # squeeze bias (replicated:
                                                    #   added after the psum)
                  P(None, MODEL_AXIS),              # excite FC columns
                  P(MODEL_AXIS),                    # excite bias
                  P(MODEL_AXIS, None)),             # projection rows
        out_specs=out_spec,
    )(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj)
    if cw != c_out:
        out = out[..., :c_out]
    return out


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(8, 9, 10, 11, 12, 13, 14, 15, 16, 17, 18,
                                    19, 20))
def _mbconv_sharded_op(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                       mesh, stride, padding, tile_h, mode, exp_act, dw_act,
                       interpret, residency, collective, in_layout,
                       se_act="silu", gate_act="sigmoid"):
    return _mbconv_sharded_impl(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2,
                                w_proj, mesh, stride, padding, tile_h, mode,
                                exp_act, dw_act, interpret, residency,
                                collective, in_layout, se_act, gate_act)


def _mbconv_sharded_fwd(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                        mesh, stride, padding, tile_h, mode, exp_act, dw_act,
                        interpret, residency, collective, in_layout,
                        se_act="silu", gate_act="sigmoid"):
    out = _mbconv_sharded_op(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2,
                             w_proj, mesh, stride, padding, tile_h, mode,
                             exp_act, dw_act, interpret, residency,
                             collective, in_layout, se_act, gate_act)
    # barrier: under the jitted entry, raw-input residuals get forwarded
    # and the w_dw cotangent double-counts (see compat.residual_barrier —
    # probe-gated, so it auto-disables on fixed JAX builds)
    return out, residual_barrier(
        (x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj))


def _mbconv_sharded_bwd(mesh, stride, padding, tile_h, mode, exp_act,
                        dw_act, interpret, residency, collective, in_layout,
                        se_act, gate_act, res, g):
    _, vjp = jax.vjp(
        lambda *p: mbconv_ref(*p, stride=stride, padding=padding,
                              exp_act=exp_act, dw_act=dw_act,
                              se_act=se_act, gate_act=gate_act),
        *res,
    )
    return vjp(g)


_mbconv_sharded_op.defvjp(_mbconv_sharded_fwd, _mbconv_sharded_bwd)


@functools.lru_cache(maxsize=256)
def _mbconv_sharded_entry(mesh, stride, padding, tile_h, mode, exp_act,
                          dw_act, interpret, residency, collective,
                          in_layout, se_act, gate_act, se):
    """One jitted entry point per (mesh, static schedule) — see
    ``_sep_sharded_entry``.  The collective AND entry layouts are part of
    the static schedule: ring/scatter and replicated/sharded-in variants
    are distinct entries, as are se=on/off (different arg pytrees)."""

    if se:
        @jax.jit
        def entry(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj):
            return _mbconv_sharded_op(x, w_exp, w_dw, w_se1, b_se1, w_se2,
                                      b_se2, w_proj, mesh, stride, padding,
                                      tile_h, mode, exp_act, dw_act,
                                      interpret, residency, collective,
                                      in_layout, se_act, gate_act)
    else:
        @jax.jit
        def entry(x, w_exp, w_dw, w_proj):
            return _mbconv_sharded_op(x, w_exp, w_dw, None, None, None,
                                      None, w_proj, mesh, stride, padding,
                                      tile_h, mode, exp_act, dw_act,
                                      interpret, residency, collective,
                                      in_layout, se_act, gate_act)

    return entry


def convdk_mbconv_fused_sharded(
    x: jax.Array,
    w_exp: jax.Array,
    w_dw: jax.Array,
    w_se1: Optional[jax.Array],
    b_se1: Optional[jax.Array],
    w_se2: Optional[jax.Array],
    b_se2: Optional[jax.Array],
    w_proj: jax.Array,
    *,
    mesh,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    mode: str = "retain",
    exp_act: Optional[str] = "silu",
    dw_act: Optional[str] = "silu",
    se_act: Optional[str] = "silu",
    gate_act: Optional[str] = "sigmoid",
    interpret: Optional[bool] = None,
    residency: Optional[str] = None,
    collective: Optional[str] = None,
    in_layout: Optional[str] = None,
) -> jax.Array:
    """Mesh-sharded two-pass fused MBConv block (differentiable).

    ``shard_map`` over ``mesh``: batch on "data" (jointly with "pod" when
    the mesh carries one), the expanded c_mid grid on "model".  Each
    device runs both fused passes on its channel slice — staged per
    ``residency`` by the shared engine, including the double-buffered
    retained-DW re-read; the pass-1 SE pool crosses devices exactly once
    (a (B, C_se) squeeze ``psum`` before the pass-2 gate), and the pass-2
    projection partials reduce per ``collective``:

    * ``"ring_allreduce"`` (default): ``psum`` into the replicated block
      output;
    * ``"psum_scatter"``: ``psum_scatter`` over the channel dim — half
      the wire words, and the returned global array is SHARDED on c_out
      across "model" (identical values; a following PW/block that
      consumes c_out-sharded activations needs no regather).  A
      non-dividing c_out zero-pads the projection to the next
      model-factor multiple and slices it back (exact).

    ``in_layout`` declares the ARRIVAL layout the entry consumes:
    ``"replicated"`` (default) streams the full c_in per device;
    ``"model_sharded"`` (requires ``c_in % model == 0``) takes a
    c_in-sharded ``x`` — collective-free for identity-expand blocks
    (``exp_act is None`` and ``c_in == c_mid``; the model layer's
    expand_ratio == 1 form, whose ``w_exp`` is the identity), via an
    entry ``all_gather`` otherwise (a real expand is dense over all of
    c_in).

    Collective + transition bytes are priced by
    ``core.perfmodel.sharded_mbconv_traffic`` under the same axes.

    Pass ALL FOUR SE params as ``None`` for a no-SE block (MobileNet-V3's
    early/middle stages): the pass-1 pool, the host MLP, the pass-2 gate
    AND the squeeze ``psum`` all disappear — an se=off block emits zero
    squeeze collectives on the mesh.  ``se_act``/``gate_act`` select the
    SE MLP nonlinearities ((relu, hard_sigmoid) for MobileNet-V3).

    Requires ``b % (pod*data) == 0`` and ``c_mid % model == 0``.
    Dispatches through a cached jitted entry point (no per-call
    re-tracing).
    """
    if interpret is None:
        interpret = default_interpret()
    if residency is None:
        residency = DEFAULT_RESIDENCY
    if collective is None:
        collective = DEFAULT_COLLECTIVE
    if in_layout is None:
        in_layout = DEFAULT_LAYOUT
    se = w_se1 is not None
    # resolve the residual-forwarding probe EAGERLY (see the separable
    # wrapper): the probe itself dispatches through _mbconv_sharded_op
    # with the probing flag set, so this never recurses
    residual_barrier_needed()
    telemetry.counter("sharded.dispatch.mbconv")
    telemetry.counter(f"sharded.collective.{collective}")
    entry = _mbconv_sharded_entry(mesh, stride, padding, tile_h, mode,
                                  exp_act, dw_act, interpret, residency,
                                  collective, in_layout, se_act, gate_act,
                                  se)
    if se:
        return entry(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj)
    return entry(x, w_exp, w_dw, w_proj)


# ---------------------------------------------------------------------------
# Fused-MBConv: batch on "data", c_mid on "model" (projection psum only)
# ---------------------------------------------------------------------------

def _fusedmb_sharded_impl(x, w_conv, w_proj, mesh, stride, padding, tile_h,
                          act, interpret, residency, collective, in_layout):
    _require_shardable(mesh, x.shape[0], w_conv.shape[-1], "c_mid")
    validate_layout(in_layout)
    if in_layout != "replicated":
        # a dense conv consumes EVERY input channel of every pixel — there
        # is no channel-local entry for a c_in-sharded arrival (unlike the
        # identity-expand MBConv), so the solver never offers one
        raise ValueError(
            f"fusedmb consumes replicated arrivals only, got {in_layout!r}")
    _dp, mp = conv_mesh_shape(mesh)
    c_out = w_proj.shape[1]
    cw = scatter_c_out(c_out, mp) if collective == "psum_scatter" else c_out
    TRACE_COUNTS["fusedmb"] += 1

    def local(xl, wcl, wpl):
        return _fusedmb_impl(xl, wcl, wpl, stride, padding, tile_h, act,
                             interpret, residency, axis_name=MODEL_AXIS,
                             collective=collective, scatter_width=cw)

    batch = _batch_axes(mesh)
    out_spec = P(batch, None, None,
                 MODEL_AXIS if collective == "psum_scatter" else None)
    out = shard_map_compat(
        local, mesh,
        in_specs=(P(batch, None, None, None),       # batch slice, full C_in
                  P(None, None, None, MODEL_AXIS),  # conv c_mid planes
                  P(MODEL_AXIS, None)),             # projection rows
        out_specs=out_spec,
    )(x, w_conv, w_proj)
    if cw != c_out:
        out = out[..., :c_out]
    return out


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10, 11))
def _fusedmb_sharded_op(x, w_conv, w_proj, mesh, stride, padding, tile_h,
                        act, interpret, residency, collective, in_layout):
    return _fusedmb_sharded_impl(x, w_conv, w_proj, mesh, stride, padding,
                                 tile_h, act, interpret, residency,
                                 collective, in_layout)


def _fusedmb_sharded_fwd(x, w_conv, w_proj, mesh, stride, padding, tile_h,
                         act, interpret, residency, collective, in_layout):
    out = _fusedmb_sharded_op(x, w_conv, w_proj, mesh, stride, padding,
                              tile_h, act, interpret, residency, collective,
                              in_layout)
    # barrier: under the jitted entry, raw-input residuals get forwarded
    # and a cotangent double-counts (see compat.residual_barrier)
    return out, residual_barrier((x, w_conv, w_proj))


def _fusedmb_sharded_bwd(mesh, stride, padding, tile_h, act, interpret,
                         residency, collective, in_layout, res, g):
    x, w_conv, w_proj = res
    _, vjp = jax.vjp(
        lambda x_, wc_, wp_: fusedmb_ref(
            x_, wc_, wp_, stride=stride, padding=padding, act=act),
        x, w_conv, w_proj,
    )
    return vjp(g)


_fusedmb_sharded_op.defvjp(_fusedmb_sharded_fwd, _fusedmb_sharded_bwd)


@functools.lru_cache(maxsize=256)
def _fusedmb_sharded_entry(mesh, stride, padding, tile_h, act, interpret,
                           residency, collective, in_layout):
    """One jitted entry point per (mesh, static schedule) — see
    ``_sep_sharded_entry``."""

    @jax.jit
    def entry(x, w_conv, w_proj):
        return _fusedmb_sharded_op(x, w_conv, w_proj, mesh, stride, padding,
                                   tile_h, act, interpret, residency,
                                   collective, in_layout)

    return entry


def convdk_fusedmb_fused_sharded(
    x: jax.Array,
    w_conv: jax.Array,
    w_proj: jax.Array,
    *,
    mesh,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    act: Optional[str] = "silu",
    interpret: Optional[bool] = None,
    residency: Optional[str] = None,
    collective: Optional[str] = None,
    in_layout: Optional[str] = None,
) -> jax.Array:
    """Mesh-sharded single-pass Fused-MBConv block (differentiable).

    ``shard_map`` over ``mesh``: batch on "data" (jointly with "pod" when
    the mesh carries one), the expanded c_mid grid on "model".  Each
    device runs the single-pass kernel on its channel slice of the dense
    conv — staged per ``residency`` by the shared engine — and the
    projection's c_mid reduction crosses devices per ``collective``
    (``psum`` replicated output, ``psum_scatter`` c_out-sharded exit at
    half the wire words; non-dividing c_out zero-pads and slices back,
    exact).  There is NO SE stage, so the block's only collective is the
    projection reduction — and no pass 2 at all: a pipelined consumer
    cannot hide behind this block (``core.autotune`` prices that
    honestly).

    ``in_layout`` must be ``"replicated"``: a dense conv consumes every
    input channel, so there is no channel-local entry for a sharded
    arrival (the network solver never offers fusedmb one).

    Requires ``b % (pod*data) == 0`` and ``c_mid % model == 0``.
    Dispatches through a cached jitted entry point (no per-call
    re-tracing).
    """
    if interpret is None:
        interpret = default_interpret()
    if residency is None:
        residency = DEFAULT_RESIDENCY
    if collective is None:
        collective = DEFAULT_COLLECTIVE
    if in_layout is None:
        in_layout = DEFAULT_LAYOUT
    residual_barrier_needed()
    telemetry.counter("sharded.dispatch.fusedmb")
    telemetry.counter(f"sharded.collective.{collective}")
    return _fusedmb_sharded_entry(mesh, stride, padding, tile_h, act,
                                  interpret, residency, collective,
                                  in_layout)(x, w_conv, w_proj)

"""Mesh-native wrappers for the fused ConvDK pipelines (``shard_map``).

The fused kernels in ``convdk_fused`` / ``convdk_mbconv`` keep the
depthwise tensor out of HBM on ONE core; at production scale the
batch/channel grid does not fit a single core, and the paper's traffic
claim must survive partitioning (the Eyeriss/MAERI lesson: reuse arguments
re-prove, they do not transfer).  This module wraps both pipelines in
``shard_map`` over the repo's ("data", "model") mesh
(``repro.sharding`` / ``launch.mesh``), with the axis mapping:

* **batch -> "data"** for both families — pure data parallelism, every
  device runs the identical fused schedule on its batch slice;
* **separable: c_out -> "model"** — the kernel grid's channel axis.  The
  PW contraction reduces over c_in, which stays replicated, so each
  device's output-channel slice is complete on-chip and the sharded path
  needs NO collective;
* **MBConv: c_mid -> "model"** — the expanded/DW/SE width (the kernel
  grid's channel axis).  Expand columns, DW taps, the retained DW tensor
  and the excite FC are all local to the shard, but the two contractions
  over the full C_mid become cross-device ``psum``s inside
  ``_mbconv_impl``: the pass-1 SE pool leaves the chip once as a tiny
  (B, C_se) squeeze partial, and pass 2 psums the projection partials.

Both wrappers are differentiable with the same pattern as their
single-device counterparts: the VJP runs through the mathematically
identical reference composition on the full (replicated) tensors.

Per-device HBM traffic and the psum bytes are priced by
``core.perfmodel.sharded_separable_traffic`` /
``sharded_mbconv_traffic``; ``core.autotune`` solves schedules under a
``mesh_shape`` axis so sharded and unsharded picks never collide.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map_compat
from .common import default_interpret
from .convdk_fused import _fused_impl
from .convdk_mbconv import _mbconv_impl
from .ref import mbconv_ref, separable_ref

DATA_AXIS = "data"
MODEL_AXIS = "model"


def conv_mesh_shape(mesh) -> Tuple[int, int]:
    """(data, model) axis sizes of a mesh (1 for an absent axis)."""
    return (mesh.shape.get(DATA_AXIS, 1), mesh.shape.get(MODEL_AXIS, 1))


def can_shard_fused(mesh, batch: int, channels: int) -> bool:
    """True iff both mesh axes exist and divide (batch, channel grid) —
    the model-layer routing falls back to the single-device kernel
    otherwise (same drop policy as ``sharding.spec_for``)."""
    if DATA_AXIS not in mesh.shape or MODEL_AXIS not in mesh.shape:
        return False
    dp, mp = conv_mesh_shape(mesh)
    return batch % dp == 0 and channels % mp == 0


def _require_shardable(mesh, batch: int, channels: int, channel_name: str):
    if DATA_AXIS not in mesh.shape or MODEL_AXIS not in mesh.shape:
        raise ValueError(
            f"mesh must carry '{DATA_AXIS}' and '{MODEL_AXIS}' axes, got "
            f"{dict(mesh.shape)}")
    dp, mp = conv_mesh_shape(mesh)
    if batch % dp != 0:
        raise ValueError(f"batch {batch} not divisible by {DATA_AXIS}={dp}")
    if channels % mp != 0:
        raise ValueError(
            f"{channel_name} {channels} not divisible by {MODEL_AXIS}={mp}")


# ---------------------------------------------------------------------------
# separable: batch on "data", c_out on "model" (collective-free)
# ---------------------------------------------------------------------------

def _sep_sharded_impl(x, w_dw, w_pw, mesh, stride, padding, tile_h, dw_act,
                      act, interpret):
    _require_shardable(mesh, x.shape[0], w_pw.shape[1], "c_out")

    def local(xl, wdl, wpl):
        return _fused_impl(xl, wdl, wpl, stride, padding, tile_h, dw_act,
                           act, interpret)

    return shard_map_compat(
        local, mesh,
        in_specs=(P(DATA_AXIS, None, None, None),   # batch slice, full C_in
                  P(None, None, None),              # DW taps replicated
                  P(None, MODEL_AXIS)),             # PW columns sharded
        out_specs=P(DATA_AXIS, None, None, MODEL_AXIS),
    )(x, w_dw, w_pw)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _sep_sharded_op(x, w_dw, w_pw, mesh, stride, padding, tile_h, dw_act,
                    act, interpret):
    return _sep_sharded_impl(x, w_dw, w_pw, mesh, stride, padding, tile_h,
                             dw_act, act, interpret)


def _sep_sharded_fwd(x, w_dw, w_pw, mesh, stride, padding, tile_h, dw_act,
                     act, interpret):
    out = _sep_sharded_op(x, w_dw, w_pw, mesh, stride, padding, tile_h,
                          dw_act, act, interpret)
    return out, (x, w_dw, w_pw)


def _sep_sharded_bwd(mesh, stride, padding, tile_h, dw_act, act, interpret,
                     res, g):
    x, w_dw, w_pw = res
    _, vjp = jax.vjp(
        lambda x_, wd_, wp_: separable_ref(
            x_, wd_, wp_, stride=stride, padding=padding, dw_act=dw_act,
            act=act),
        x, w_dw, w_pw,
    )
    return vjp(g)


_sep_sharded_op.defvjp(_sep_sharded_fwd, _sep_sharded_bwd)


def convdk_fused_separable_sharded(
    x: jax.Array,
    w_dw: jax.Array,
    w_pw: jax.Array,
    *,
    mesh,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    dw_act: Optional[str] = None,
    act: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Mesh-sharded fused depthwise-separable block (differentiable).

    ``shard_map`` over ``mesh``: batch on "data", output channels on
    "model"; every device runs the single-device fused kernel on its
    (batch, c_out) tile.  The c_in reduction is device-local (c_in is
    replicated), so no collective is needed — per-device HBM traffic is
    the single-device model evaluated at the shard shape.

    Requires ``b % data == 0`` and ``c_out % model == 0``
    (``can_shard_fused`` pre-checks; the model layer falls back to the
    unsharded kernel when the grid does not divide).
    """
    if interpret is None:
        interpret = default_interpret()
    return _sep_sharded_op(x, w_dw, w_pw, mesh, stride, padding, tile_h,
                           dw_act, act, interpret)


# ---------------------------------------------------------------------------
# MBConv: batch on "data", c_mid on "model" (SE squeeze + projection psum)
# ---------------------------------------------------------------------------

def _mbconv_sharded_impl(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                         mesh, stride, padding, tile_h, mode, exp_act,
                         dw_act, interpret):
    _require_shardable(mesh, x.shape[0], w_dw.shape[-1], "c_mid")

    def local(xl, wel, wdl, s1l, b1l, s2l, b2l, wpl):
        return _mbconv_impl(xl, wel, wdl, s1l, b1l, s2l, b2l, wpl, stride,
                            padding, tile_h, mode, exp_act, dw_act,
                            interpret, axis_name=MODEL_AXIS)

    return shard_map_compat(
        local, mesh,
        in_specs=(P(DATA_AXIS, None, None, None),   # batch slice, full C_in
                  P(None, MODEL_AXIS),              # expand columns
                  P(None, None, MODEL_AXIS),        # DW taps per channel
                  P(MODEL_AXIS, None),              # squeeze FC rows
                  P(None),                          # squeeze bias (replicated:
                                                    #   added after the psum)
                  P(None, MODEL_AXIS),              # excite FC columns
                  P(MODEL_AXIS),                    # excite bias
                  P(MODEL_AXIS, None)),             # projection rows
        out_specs=P(DATA_AXIS, None, None, None),   # replicated post-psum
    )(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(8, 9, 10, 11, 12, 13, 14, 15))
def _mbconv_sharded_op(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                       mesh, stride, padding, tile_h, mode, exp_act, dw_act,
                       interpret):
    return _mbconv_sharded_impl(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2,
                                w_proj, mesh, stride, padding, tile_h, mode,
                                exp_act, dw_act, interpret)


def _mbconv_sharded_fwd(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                        mesh, stride, padding, tile_h, mode, exp_act, dw_act,
                        interpret):
    out = _mbconv_sharded_op(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2,
                             w_proj, mesh, stride, padding, tile_h, mode,
                             exp_act, dw_act, interpret)
    return out, (x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj)


def _mbconv_sharded_bwd(mesh, stride, padding, tile_h, mode, exp_act,
                        dw_act, interpret, res, g):
    _, vjp = jax.vjp(
        lambda *p: mbconv_ref(*p, stride=stride, padding=padding,
                              exp_act=exp_act, dw_act=dw_act),
        *res,
    )
    return vjp(g)


_mbconv_sharded_op.defvjp(_mbconv_sharded_fwd, _mbconv_sharded_bwd)


def convdk_mbconv_fused_sharded(
    x: jax.Array,
    w_exp: jax.Array,
    w_dw: jax.Array,
    w_se1: jax.Array,
    b_se1: jax.Array,
    w_se2: jax.Array,
    b_se2: jax.Array,
    w_proj: jax.Array,
    *,
    mesh,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    mode: str = "retain",
    exp_act: Optional[str] = "silu",
    dw_act: Optional[str] = "silu",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Mesh-sharded two-pass fused MBConv block (differentiable).

    ``shard_map`` over ``mesh``: batch on "data", the expanded c_mid grid
    on "model".  Each device runs both fused passes on its channel slice;
    the pass-1 SE pool crosses devices exactly once (a (B, C_se) squeeze
    ``psum`` before the pass-2 gate), and the pass-2 projection partials
    are psum'd into the replicated block output.  Collective bytes are
    priced by ``core.perfmodel.sharded_mbconv_traffic``.

    Requires ``b % data == 0`` and ``c_mid % model == 0``.
    """
    if interpret is None:
        interpret = default_interpret()
    return _mbconv_sharded_op(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2,
                              w_proj, mesh, stride, padding, tile_h, mode,
                              exp_act, dw_act, interpret)

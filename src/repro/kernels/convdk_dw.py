"""ConvDK depthwise-Conv2D Pallas TPU kernel.

TPU adaptation of the paper's ConvDK dataflow (DESIGN.md §Pillar B):

* CIM TRF strip  ->  a VMEM-resident input strip per grid cell.  The strip is
  staged ONCE from HBM (the IB->TRF load) and then re-read at the kernel-tap
  offsets — the l = lcm(k,s)/s shift cycles of Algorithm 1.  For s = 1 the
  tap loop over ``i`` IS the shift schedule (l = k, every block n active per
  cycle, Theorem-2 coverage = the polyphase identity m = n*k + a); for s = 2
  the strided slices realize the (a, n -> m) arithmetic progressions.
* CIM TM kernel duplication  ->  the weight tap w[j, i, :] is broadcast
  across all N output blocks of the strip in ONE vector op (the VPU plays
  the 180-row multi-access TM; duplication costs no extra HBM reads).
* BIG/LITTLE channel packing  ->  the channel-block grid dimension: channels
  ride the 128-wide lane axis, strips of ``tile_h`` output rows ride the
  grid, mirroring kernel duplication across idle tiles.

The kernel consumes pre-staged overlapping row strips (built by
``ops.stage_row_strips``, the IB->TRF analogue) so every BlockSpec is a plain
non-overlapping block: strip t holds input rows [t*TH*s, t*TH*s + (TH-1)*s + k).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dw2d_kernel(x_ref, w_ref, o_ref, *, k_h: int, k_w: int, stride: int,
                 tile_h: int, out_w: int):
    """One (batch, row-strip, channel-block) grid cell.

    x_ref: (1, 1, (tile_h-1)*s + k_h, W_pad, CB)  VMEM strip (the "TRF")
    w_ref: (k_h, k_w, CB)                         stationary taps (the "TM")
    o_ref: (1, 1, tile_h, out_w, CB)
    """
    s = stride
    x = x_ref[0, 0]                      # (rows, W_pad, CB)
    acc = jnp.zeros((tile_h, out_w, x.shape[-1]), jnp.float32)
    # l shift cycles x k_h row taps: every re-read of the resident strip is
    # one (a, j) pass of Algorithm 2; all N width-blocks update in parallel.
    for j in range(k_h):
        for i in range(k_w):
            xs = jax.lax.slice(
                x,
                (j, i, 0),
                (j + s * (tile_h - 1) + 1, i + s * (out_w - 1) + 1, x.shape[-1]),
                (s, s, 1),
            )
            acc = acc + xs.astype(jnp.float32) * w_ref[j, i].astype(jnp.float32)
    o_ref[0, 0] = acc.astype(o_ref.dtype)


def dw2d_pallas(
    x_strips: jax.Array,
    w: jax.Array,
    *,
    stride: int,
    out_w: int,
    tile_h: int,
    c_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Run the ConvDK DW2D kernel over pre-staged strips.

    x_strips : (B, n_th, in_rows, W_pad, C) with in_rows = (tile_h-1)*s + k_h
    w        : (k_h, k_w, C)
    returns  : (B, n_th, tile_h, out_w, C)
    """
    b, n_th, in_rows, w_pad, c = x_strips.shape
    k_h, k_w, _ = w.shape
    assert c % c_block == 0, (c, c_block)
    grid = (b, n_th, c // c_block)

    kernel = functools.partial(
        _dw2d_kernel, k_h=k_h, k_w=k_w, stride=stride,
        tile_h=tile_h, out_w=out_w,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, in_rows, w_pad, c_block),
                lambda bi, ti, ci: (bi, ti, 0, 0, ci),
            ),
            pl.BlockSpec((k_h, k_w, c_block), lambda bi, ti, ci: (0, 0, ci)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, tile_h, out_w, c_block),
            lambda bi, ti, ci: (bi, ti, 0, 0, ci),
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_th, tile_h, out_w, c), x_strips.dtype),
        interpret=interpret,
    )(x_strips, w)

"""Two-pass fused MBConv (EfficientNet) ConvDK Pallas kernels.

EfficientNet's MBConv inserts squeeze-and-excitation between the depthwise
and projection stages:

    expand 1x1 -> act -> DW k x k / s -> act -> SE(global pool -> MLP ->
    sigmoid gate) -> project 1x1 (+ residual)

The SE *squeeze* is a global pool over the whole DW output, so the
single-strip VMEM residency of ``convdk_fused_separable`` cannot cover the
block: the projection of any strip depends on every strip's DW output.  The
staged rendering therefore round-trips the full expanded DW tensor through
HBM four extra times (DW write, pool read, gate read+write, projection
read) — exactly the weight-stationary baseline traffic the paper eliminates
for plain separable blocks.

This module closes the gap with a **two-pass fused schedule**:

* **Pass 1** (``_mbconv_pass1_kernel``): per (c_mid block, row strip), the
  expand PW runs over the staged input window (reduction over c_in blocks
  in the innermost grid dim), the DW taps consume the expanded strip while
  it is still in VMEM, and the SE pool is accumulated on-chip into a tiny
  (B, C_mid) output — masked so padded strip rows never enter the pool.
  The DW output either goes to HBM ONCE (``mode="retain"``) or is
  discarded (``mode="recompute"``).
* **SE MLP** (host-side, between passes): two tiny FCs + sigmoid on the
  pooled (B, C_mid) vector — negligible traffic, accounted by the model.
* **Pass 2**: the SE gate folds into the projection contraction in the same
  VMEM residency as the DW block — read back from HBM (``retain``,
  ``_mbconv_pass2_retain_kernel``) or recomputed from the input strips
  (``recompute``, ``_mbconv_pass2_recompute_kernel``, same expand+DW loop
  as pass 1).  The only activation write of the whole block is the final
  output.

Every big input stream goes through the shared strip-staging engine
(``kernels.staging``) under the schedule's **residency** axis: the input
windows of pass 1 / recompute pass 2 are halo'd conv strips, and the
``retain`` pass-2 re-read of the DW tensor is a non-overlapping row-block
stream — under ``strip_dma_db`` it becomes a double-buffered DMA stream
that prefetches the next (strip, c_mid block) while the projection of the
current one runs.

Retain pays ``E * (1 + n_co)`` HBM words for the DW tensor ``E``; recompute
re-reads the input strips and expand/DW weights ``n_co`` more times.  The
crossover is priced per layer shape by ``core.perfmodel.mbconv_fused_traffic``
and chosen by ``core.autotune.select_mbconv_schedule`` (MIREDO-style: the
schedule is solved per block topology, not per op).

Blocks with expansion ratio 1 (EfficientNet's MBConv1) pass the identity as
``w_exp`` with ``exp_act=None`` — the kernel math is unchanged and exact.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.perfmodel import (
    DEFAULT_COLLECTIVE,
    DEFAULT_RESIDENCY,
    pick_channel_block,
    validate_collective,
)
from .common import default_interpret, round_up as _round_up, spatial_pads
from .ref import _act_ref, mbconv_ref
from .staging import StripPlan, StripStream, strip_plan


def _dw_taps(e, w_dw_ref, *, k_h, k_w, stride, tile_h, out_w):
    """Algorithm-2 tap loop over an expanded strip resident in VMEM.

    e: (in_rows, w_need, CM) f32 -> (tile_h, out_w, CM) f32.
    """
    s = stride
    dw = jnp.zeros((tile_h, out_w, e.shape[-1]), jnp.float32)
    for j in range(k_h):
        for i in range(k_w):
            xs = jax.lax.slice(
                e,
                (j, i, 0),
                (j + s * (tile_h - 1) + 1, i + s * (out_w - 1) + 1,
                 e.shape[-1]),
                (s, s, 1),
            )
            dw = dw + xs * w_dw_ref[j, i].astype(jnp.float32)
    return dw


def _expand_accumulate(win, wexp_ref, acc_ref, *, ci):
    """One c_in-block partial of the expand PW over the staged strip window.

    ``win`` is the engine-staged ``(in_rows, w_need, CI)`` window; the
    contraction with the (CI, CM) expand block accumulates across the
    innermost c_in grid dimension.
    """
    in_rows, w_need = win.shape[0], win.shape[1]
    partial = jax.lax.dot_general(
        win.reshape(in_rows * w_need, win.shape[-1]).astype(jnp.float32),
        wexp_ref[:, :].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(in_rows, w_need, -1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = partial

    @pl.when(ci > 0)
    def _accumulate():
        acc_ref[...] = acc_ref[...] + partial


def _mbconv_pass1_kernel(x_ref, wexp_ref, wdw_ref, *rest,
                         plan: StripPlan, k_h, k_w, stride, tile_h, out_w,
                         out_h, exp_act: Optional[str],
                         dw_act: Optional[str], se: bool, retain: bool):
    """One (batch, c_mid-block, row-strip, c_in-block) grid cell of pass 1.

    x_ref    : unstaged input (engine-staged per ``plan``)
    wexp_ref : (CI, CM)               expand-PW block
    wdw_ref  : (k_h, k_w, CM)         depthwise taps
    rest     : (pool_ref,) if se — the (1, 1, CM) on-chip SE pool
               accumulator (sums) — then (dw_out_ref,) if retain, then
               acc_ref + staging refs.  An se=off launch carries NO pool
               output at all: the no-SE block pays zero pool VMEM/HBM.
    """
    rest = tuple(rest)
    if se:
        pool_ref, *rest = rest
    if retain:
        dwo_ref, *rest = rest
    stage_refs, (acc_ref,) = plan.take_scratch(tuple(rest))
    ti = pl.program_id(2)
    ci = pl.program_id(3)
    n_ci = pl.num_programs(3)
    win = StripStream(plan, x_ref, stage_refs).get()
    _expand_accumulate(win, wexp_ref, acc_ref, ci=ci)

    @pl.when(ci == n_ci - 1)
    def _finish_strip():
        e = _act_ref(acc_ref[...], exp_act)
        dw = _dw_taps(e, wdw_ref, k_h=k_h, k_w=k_w, stride=stride,
                      tile_h=tile_h, out_w=out_w)
        dw = _act_ref(dw, dw_act)
        if se:
            # mask strip rows past out_h so they never enter the pool
            rows = jax.lax.broadcasted_iota(jnp.int32, (tile_h, out_w), 0) \
                + ti * tile_h
            masked = jnp.where((rows < out_h)[..., None], dw, 0.0)
            sums = jnp.sum(masked, axis=(0, 1), keepdims=True)  # (1, 1, CM)

            @pl.when(ti == 0)
            def _pool_init():
                pool_ref[...] = sums

            @pl.when(ti > 0)
            def _pool_accumulate():
                pool_ref[...] = pool_ref[...] + sums

        if retain:
            dwo_ref[0] = dw.astype(dwo_ref.dtype)


def _mbconv_pass2_recompute_kernel(x_ref, wexp_ref, wdw_ref, *rest,
                                   plan: StripPlan, k_h, k_w, stride,
                                   tile_h, out_w, exp_act: Optional[str],
                                   dw_act: Optional[str], se: bool):
    """One (batch, c_out-block, row-strip, c_mid-block, c_in-block) cell.

    Recomputes expand+DW exactly as pass 1 (the DW tensor never existed in
    HBM), multiplies by the SE gate (when ``se`` — an se=off launch carries
    no scale input at all) and contracts with the projection block —
    partial projection sums carried across the c_mid grid dimension.
    """
    rest = tuple(rest)
    if se:
        scale_ref, *rest = rest
    wproj_ref, o_ref, *scratch = rest
    stage_refs, (acc_ref, proj_ref) = plan.take_scratch(tuple(scratch))
    cm = pl.program_id(3)
    ci = pl.program_id(4)
    n_cm = pl.num_programs(3)
    n_ci = pl.num_programs(4)
    win = StripStream(plan, x_ref, stage_refs).get()
    _expand_accumulate(win, wexp_ref, acc_ref, ci=ci)

    @pl.when(ci == n_ci - 1)
    def _project():
        e = _act_ref(acc_ref[...], exp_act)
        dw = _dw_taps(e, wdw_ref, k_h=k_h, k_w=k_w, stride=stride,
                      tile_h=tile_h, out_w=out_w)
        dw = _act_ref(dw, dw_act)
        if se:
            dw = dw * scale_ref[0, 0].astype(jnp.float32)
        partial = jax.lax.dot_general(
            dw.reshape(tile_h * out_w, dw.shape[-1]),
            wproj_ref[:, :].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(tile_h, out_w, -1)

        @pl.when(cm == 0)
        def _init():
            proj_ref[...] = partial

        @pl.when(cm > 0)
        def _accumulate():
            proj_ref[...] = proj_ref[...] + partial

        @pl.when(cm == n_cm - 1)
        def _finalize():
            o_ref[0] = proj_ref[...].astype(o_ref.dtype)


def _mbconv_pass2_retain_kernel(dw_ref, *rest, plan: StripPlan, tile_h,
                                out_w, se: bool):
    """One (batch, c_out-block, row-strip, c_mid-block) cell: stage the
    retained DW block back (a non-overlapping row-block stream — double-
    buffered DMA under ``strip_dma_db``), fold in the SE gate (when ``se``
    — an se=off launch carries no scale input), contract with the
    projection block (partial sums across the c_mid grid dim)."""
    rest = tuple(rest)
    if se:
        scale_ref, *rest = rest
    wproj_ref, o_ref, *scratch = rest
    stage_refs, (proj_ref,) = plan.take_scratch(tuple(scratch))
    cm = pl.program_id(3)
    n_cm = pl.num_programs(3)
    dw_win = StripStream(plan, dw_ref, stage_refs).get()
    dw = dw_win.astype(jnp.float32)
    if se:
        dw = dw * scale_ref[0, 0].astype(jnp.float32)
    partial = jax.lax.dot_general(
        dw.reshape(tile_h * out_w, dw.shape[-1]),
        wproj_ref[:, :].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(tile_h, out_w, -1)

    @pl.when(cm == 0)
    def _init():
        proj_ref[...] = partial

    @pl.when(cm > 0)
    def _accumulate():
        proj_ref[...] = proj_ref[...] + partial

    @pl.when(cm == n_cm - 1)
    def _finalize():
        o_ref[0] = proj_ref[...].astype(o_ref.dtype)


def mbconv_pass1_pallas(x_pad, w_exp, w_dw, *, stride, out_w, out_h, tile_h,
                        n_th, ci_block, cm_block, exp_act, dw_act, retain,
                        interpret, se=True, residency=DEFAULT_RESIDENCY):
    """Raw pass-1 launch: (pool_sums-or-None, dw_retained-or-None).

    ``se=False`` drops the pool output (and its VMEM accumulator) from the
    launch entirely — an se=off retain pass writes only the DW tensor.
    """
    assert se or retain, "se=off + recompute has no pass 1 at all"
    b, h_tot, w_pad, ci_pad = x_pad.shape
    k_h, k_w, cm_pad = w_dw.shape
    grid = (b, cm_pad // cm_block, n_th, ci_pad // ci_block)
    in_rows = (tile_h - 1) * stride + k_h
    w_need = (out_w - 1) * stride + k_w

    plan = strip_plan(
        h_tot=h_tot, w_tot=w_pad, w_span=w_need, c_block=ci_block,
        tile_h=tile_h, grid=grid, window_dims=(0, 2, 3), stride=stride,
        k_h=k_h, residency=residency)
    kernel = functools.partial(
        _mbconv_pass1_kernel, plan=plan, k_h=k_h, k_w=k_w, stride=stride,
        tile_h=tile_h, out_w=out_w, out_h=out_h, exp_act=exp_act,
        dw_act=dw_act, se=se, retain=retain)
    out_shape = []
    out_specs = []
    if se:
        out_shape.append(jax.ShapeDtypeStruct((b, 1, cm_pad), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1, cm_block),
                                      lambda bi, cm, ti, ci: (bi, 0, cm)))
    if retain:
        out_shape.append(jax.ShapeDtypeStruct(
            (b, n_th * tile_h, out_w, cm_pad), x_pad.dtype))
        out_specs.append(pl.BlockSpec(
            (1, tile_h, out_w, cm_block),
            lambda bi, cm, ti, ci: (bi, ti, 0, cm)))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            plan.in_spec(lambda bi, cm, ti, ci: (bi, 0, 0, ci)),
            pl.BlockSpec((ci_block, cm_block),
                         lambda bi, cm, ti, ci: (ci, cm)),
            pl.BlockSpec((k_h, k_w, cm_block),
                         lambda bi, cm, ti, ci: (0, 0, cm)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((in_rows, w_need, cm_block), jnp.float32),
                        *plan.scratch_shapes(x_pad.dtype)],
        interpret=interpret,
    )(x_pad, w_exp, w_dw)
    outs = list(outs)
    pool = outs.pop(0) if se else None
    dw_ret = outs.pop(0) if retain else None
    return pool, dw_ret


def mbconv_pass2_recompute_pallas(x_pad, w_exp, w_dw, scale, w_proj, *,
                                  stride, out_w, tile_h, n_th, ci_block,
                                  cm_block, co_block, exp_act, dw_act,
                                  interpret, residency=DEFAULT_RESIDENCY):
    """``scale=None`` launches the se=off variant: no gate input, no gate
    multiply — the no-SE block pays zero scale bytes."""
    se = scale is not None
    b, h_tot, w_pad, ci_pad = x_pad.shape
    k_h, k_w, cm_pad = w_dw.shape
    co_pad = w_proj.shape[1]
    grid = (b, co_pad // co_block, n_th, cm_pad // cm_block,
            ci_pad // ci_block)
    in_rows = (tile_h - 1) * stride + k_h
    w_need = (out_w - 1) * stride + k_w

    plan = strip_plan(
        h_tot=h_tot, w_tot=w_pad, w_span=w_need, c_block=ci_block,
        tile_h=tile_h, grid=grid, window_dims=(0, 2, 4), stride=stride,
        k_h=k_h, residency=residency)
    kernel = functools.partial(
        _mbconv_pass2_recompute_kernel, plan=plan, k_h=k_h, k_w=k_w,
        stride=stride, tile_h=tile_h, out_w=out_w, exp_act=exp_act,
        dw_act=dw_act, se=se)
    in_specs = [
        plan.in_spec(lambda bi, co, ti, cm, ci: (bi, 0, 0, ci)),
        pl.BlockSpec((ci_block, cm_block),
                     lambda bi, co, ti, cm, ci: (ci, cm)),
        pl.BlockSpec((k_h, k_w, cm_block),
                     lambda bi, co, ti, cm, ci: (0, 0, cm)),
    ]
    operands = [x_pad, w_exp, w_dw]
    if se:
        in_specs.append(pl.BlockSpec((1, 1, cm_block),
                                     lambda bi, co, ti, cm, ci: (bi, 0, cm)))
        operands.append(scale)
    in_specs.append(pl.BlockSpec((cm_block, co_block),
                                 lambda bi, co, ti, cm, ci: (cm, co)))
    operands.append(w_proj)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, tile_h, out_w, co_block),
            lambda bi, co, ti, cm, ci: (bi, ti, 0, co)),
        out_shape=jax.ShapeDtypeStruct(
            (b, n_th * tile_h, out_w, co_pad), x_pad.dtype),
        scratch_shapes=[
            pltpu.VMEM((in_rows, w_need, cm_block), jnp.float32),
            pltpu.VMEM((tile_h, out_w, co_block), jnp.float32),
            *plan.scratch_shapes(x_pad.dtype),
        ],
        interpret=interpret,
    )(*operands)


def mbconv_pass2_retain_pallas(dw_ret, scale, w_proj, *, out_w, tile_h,
                               n_th, cm_block, co_block, interpret,
                               residency=DEFAULT_RESIDENCY):
    b = dw_ret.shape[0]
    cm_pad = dw_ret.shape[-1]
    co_pad = w_proj.shape[1]
    grid = (b, co_pad // co_block, n_th, cm_pad // cm_block)

    # The retained-DW re-read: non-overlapping tile_h-row blocks (k_h=1,
    # stride=1 geometry) — the double-buffered DMA stream of the tentpole.
    se = scale is not None
    plan = strip_plan(
        h_tot=dw_ret.shape[1], w_tot=dw_ret.shape[2], w_span=out_w,
        c_block=cm_block, tile_h=tile_h, grid=grid, window_dims=(0, 2, 3),
        residency=residency)
    kernel = functools.partial(_mbconv_pass2_retain_kernel, plan=plan,
                               tile_h=tile_h, out_w=out_w, se=se)
    in_specs = [plan.in_spec(lambda bi, co, ti, cm: (bi, ti, 0, cm))]
    operands = [dw_ret]
    if se:
        in_specs.append(pl.BlockSpec((1, 1, cm_block),
                                     lambda bi, co, ti, cm: (bi, 0, cm)))
        operands.append(scale)
    in_specs.append(pl.BlockSpec((cm_block, co_block),
                                 lambda bi, co, ti, cm: (cm, co)))
    operands.append(w_proj)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, tile_h, out_w, co_block),
            lambda bi, co, ti, cm: (bi, ti, 0, co)),
        out_shape=jax.ShapeDtypeStruct(
            (b, n_th * tile_h, out_w, co_pad), dw_ret.dtype),
        scratch_shapes=[pltpu.VMEM((tile_h, out_w, co_block), jnp.float32),
                        *plan.scratch_shapes(dw_ret.dtype)],
        interpret=interpret,
    )(*operands)


def _mbconv_impl(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj, stride,
                 padding, tile_h, mode, exp_act, dw_act, interpret,
                 residency=DEFAULT_RESIDENCY,
                 se_act: Optional[str] = "silu",
                 gate_act: Optional[str] = "sigmoid",
                 axis_name: Optional[str] = None,
                 collective: str = DEFAULT_COLLECTIVE,
                 scatter_width: int = 0):
    """Two-pass fused MBConv on one device — or on one SHARD of the c_mid
    grid when ``axis_name`` names a mesh axis (``shard_map`` body).

    Under c_mid sharding every device runs pass 1 / pass 2 on its own
    channel slice, and the two contractions over the full expanded width
    become cross-device reductions:

    * the SE squeeze FC (``mean @ w_se1`` reduces over C_mid) — the pass-1
      pool leaves the chip exactly once, as a tiny (B, C_se) partial,
      always a full ``psum`` (the excite FC consumes it replicated);
    * the projection PW (``dw @ w_proj`` reduces over C_mid) — each device
      contributes its channel slice's partial output.  This is the
      **collective axis hook**: ``collective == "ring_allreduce"`` emits
      ``jax.lax.psum`` (output replicated), ``"psum_scatter"`` emits
      ``jax.lax.psum_scatter`` over the channel dim — half the wire
      words, and the pass-2 output leaves the kernel SHARDED on c_out for
      a consumer that wants it that way.

    Everything else (expand columns, DW taps, the excite FC rows, the
    retained DW tensor) is local to the shard.

    ``w_se1 is None`` switches SE off (MobileNet-V3's no-SE blocks): the
    pass-1 pool output, the host MLP, the squeeze psum and the pass-2
    scale input all disappear — and under ``mode="recompute"`` pass 1 is
    skipped ENTIRELY (it would produce nothing).  ``se_act``/``gate_act``
    parameterize the SE MLP's nonlinearities (V3 uses relu/hard_sigmoid).
    """
    validate_collective(collective)
    se = w_se1 is not None
    b, h, w_in, c_in = x.shape
    k_h, k_w, c_mid = w_dw.shape
    assert w_exp.shape == (c_in, c_mid), (w_exp.shape, c_in, c_mid)
    c_out = w_proj.shape[1]
    assert w_proj.shape[0] == c_mid, (w_proj.shape, c_mid)
    assert mode in ("retain", "recompute"), mode
    s = stride

    out_h, out_w, pads = spatial_pads(h, w_in, k_h, k_w, s, padding)

    ci_block = pick_channel_block(c_in)
    ci_pad = _round_up(c_in, ci_block)
    cm_block = pick_channel_block(c_mid)
    cm_pad = _round_up(c_mid, cm_block)
    co_block = min(128, _round_up(c_out, 8))
    co_pad = _round_up(c_out, co_block)

    xp = jnp.pad(x, ((0, 0), pads[0], pads[1], (0, ci_pad - c_in)))
    wexp_p = jnp.pad(w_exp, ((0, ci_pad - c_in), (0, cm_pad - c_mid)))
    wdw_p = jnp.pad(w_dw, ((0, 0), (0, 0), (0, cm_pad - c_mid)))
    wproj_p = jnp.pad(w_proj, ((0, cm_pad - c_mid), (0, co_pad - c_out)))

    # width cover for the i + s*(out_w-1) + 1 tap slice
    need_w = (out_w - 1) * s + k_w
    if need_w > xp.shape[2]:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, need_w - xp.shape[2]), (0, 0)))

    tile_h = max(1, min(tile_h, out_h))
    n_th = -(-out_h // tile_h)
    # height cover so the last strip's window stays in bounds
    need_h = (n_th - 1) * tile_h * s + (tile_h - 1) * s + k_h
    if need_h > xp.shape[1]:
        xp = jnp.pad(xp, ((0, 0), (0, need_h - xp.shape[1]), (0, 0), (0, 0)))

    if se or mode == "retain":
        pool, dw_ret = mbconv_pass1_pallas(
            xp, wexp_p, wdw_p, stride=s, out_w=out_w, out_h=out_h,
            tile_h=tile_h, n_th=n_th, ci_block=ci_block, cm_block=cm_block,
            exp_act=exp_act, dw_act=dw_act, retain=(mode == "retain"),
            interpret=interpret, se=se, residency=residency)
    else:
        # se=off + recompute: pass 1 would produce nothing — skip it.
        pool, dw_ret = None, None

    if se:
        # SE MLP on the on-chip-accumulated pool (masked rows excluded; the
        # mean uses the true output element count).  The squeeze FC reduces
        # over C_mid, so under c_mid sharding its partial product is psum'd
        # across the mesh axis before the bias + nonlinearity.
        mean = pool[:, 0, :c_mid] / float(out_h * out_w)      # (B, C_mid) f32
        squeeze = mean @ w_se1.astype(jnp.float32)
        if axis_name is not None:
            squeeze = jax.lax.psum(squeeze, axis_name)
        s1 = _act_ref(squeeze + b_se1.astype(jnp.float32), se_act)
        gate = _act_ref(s1 @ w_se2.astype(jnp.float32)
                        + b_se2.astype(jnp.float32), gate_act)
        scale = jnp.pad(gate, ((0, 0), (0, cm_pad - c_mid)))[:, None, :]
    else:
        scale = None

    if mode == "retain":
        out = mbconv_pass2_retain_pallas(
            dw_ret, scale, wproj_p, out_w=out_w, tile_h=tile_h, n_th=n_th,
            cm_block=cm_block, co_block=co_block, interpret=interpret,
            residency=residency)
    else:
        out = mbconv_pass2_recompute_pallas(
            xp, wexp_p, wdw_p, scale, wproj_p, stride=s, out_w=out_w,
            tile_h=tile_h, n_th=n_th, ci_block=ci_block, cm_block=cm_block,
            co_block=co_block, exp_act=exp_act, dw_act=dw_act,
            interpret=interpret, residency=residency)
    if axis_name is not None and collective == "psum_scatter":
        # reduce-scatter over the channel dim: (mp-1)/mp words per
        # reduced word instead of the ring's 2*(mp-1)/mp, and this
        # shard keeps only its channel slice — the layout-aware exit.
        # Non-dividing c_out scatters at ``scatter_width`` (the next
        # model-factor multiple): the extra columns are zero w_proj
        # columns, so their partials are exactly zero and the wrapper
        # slices them back off the gathered-global view.
        cw = scatter_width if scatter_width else c_out
        out = out[:, :out_h, :, :min(cw, out.shape[-1])]
        if out.shape[-1] < cw:
            out = jnp.pad(
                out, ((0, 0), (0, 0), (0, 0), (0, cw - out.shape[-1])))
        out = jax.lax.psum_scatter(out, axis_name,
                                   scatter_dimension=3, tiled=True)
    else:
        out = out[:, :out_h, :, :c_out]
        if axis_name is not None:
            # projection partials: each shard contracted only its c_mid
            # slice
            out = jax.lax.psum(out, axis_name)
    return out


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(8, 9, 10, 11, 12, 13, 14, 15, 16, 17))
def _mbconv_op(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj, stride,
               padding, tile_h, mode, exp_act, dw_act, interpret, residency,
               se_act="silu", gate_act="sigmoid"):
    return _mbconv_impl(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                        stride, padding, tile_h, mode, exp_act, dw_act,
                        interpret, residency, se_act=se_act,
                        gate_act=gate_act)


def _mbconv_fwd(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj, stride,
                padding, tile_h, mode, exp_act, dw_act, interpret, residency,
                se_act="silu", gate_act="sigmoid"):
    out = _mbconv_op(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                     stride, padding, tile_h, mode, exp_act, dw_act,
                     interpret, residency, se_act, gate_act)
    return out, (x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj)


def _mbconv_bwd(stride, padding, tile_h, mode, exp_act, dw_act, interpret,
                residency, se_act, gate_act, res, g):
    # Backward through the mathematically identical reference composition —
    # the two-pass kernel computes the same MBConv block, so the VJP is
    # exact (same pattern as convdk_fused's VJP).  mbconv_ref skips the SE
    # stage for w_se1=None, matching the se=off kernel path; the SE-param
    # cotangents come back as None there, as custom_vjp expects.
    _, vjp = jax.vjp(
        lambda *p: mbconv_ref(*p, stride=stride, padding=padding,
                              exp_act=exp_act, dw_act=dw_act,
                              se_act=se_act, gate_act=gate_act),
        *res,
    )
    return vjp(g)


_mbconv_op.defvjp(_mbconv_fwd, _mbconv_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "tile_h", "mode", "exp_act",
                     "dw_act", "se_act", "gate_act", "interpret",
                     "residency"),
)
def convdk_mbconv_fused(
    x: jax.Array,
    w_exp: jax.Array,
    w_dw: jax.Array,
    w_se1: Optional[jax.Array],
    b_se1: Optional[jax.Array],
    w_se2: Optional[jax.Array],
    b_se2: Optional[jax.Array],
    w_proj: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    mode: str = "retain",
    exp_act: Optional[str] = "silu",
    dw_act: Optional[str] = "silu",
    se_act: Optional[str] = "silu",
    gate_act: Optional[str] = "sigmoid",
    interpret: Optional[bool] = None,
    residency: Optional[str] = None,
) -> jax.Array:
    """Two-pass fused MBConv block via the ConvDK Pallas kernels
    (differentiable).  No residual add — the model layer owns that.

    x      : (B, H, W, C_in) NHWC
    w_exp  : (C_in, C_mid) expand PW (identity + ``exp_act=None`` for
             expansion ratio 1)
    w_dw   : (k_h, k_w, C_mid) depthwise taps
    w_se1/b_se1, w_se2/b_se2 : SE squeeze/excite FCs — pass ALL FOUR as
             ``None`` for a no-SE block (MobileNet-V3's early/middle
             stages): the pass-1 pool, the host MLP and the pass-2 gate
             disappear and under ``mode="recompute"`` pass 1 is skipped
             entirely.
    w_proj : (C_mid, C_out) projection PW (linear)
    mode   : "retain" | "recompute" — pass-2 DW source (see module doc;
             ``core.autotune.get_mbconv_schedule`` picks per layer shape).
    se_act/gate_act : SE MLP nonlinearities — (silu, sigmoid) for
             EfficientNet, (relu, hard_sigmoid) for MobileNet-V3.
    residency : "resident" | "strip_dma" | "strip_dma_db" (default) — how
             the input / retained-DW streams are staged (``kernels.staging``).
    Returns (B, H', W', C_out).
    """
    if interpret is None:
        interpret = default_interpret()
    if residency is None:
        residency = DEFAULT_RESIDENCY
    return _mbconv_op(x, w_exp, w_dw, w_se1, b_se1, w_se2, b_se2, w_proj,
                      stride, padding, tile_h, mode, exp_act, dw_act,
                      interpret, residency, se_act, gate_act)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "tile_h", "exp_act", "dw_act",
                     "se_act", "gate_act", "interpret"),
)
def convdk_mbconv_staged(
    x: jax.Array,
    w_exp: jax.Array,
    w_dw: jax.Array,
    w_se1: Optional[jax.Array],
    b_se1: Optional[jax.Array],
    w_se2: Optional[jax.Array],
    b_se2: Optional[jax.Array],
    w_proj: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    tile_h: int = 8,
    exp_act: Optional[str] = "silu",
    dw_act: Optional[str] = "silu",
    se_act: Optional[str] = "silu",
    gate_act: Optional[str] = "sigmoid",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """The STAGED MBConv pipeline (comparison baseline, differentiable).

    expand einsum -> HBM -> staged DW ConvDK kernel -> HBM -> SE pool +
    gate -> HBM -> projection einsum: the DW tensor round-trips through HBM
    exactly as the paper's weight-stationary baseline, which is what
    ``convdk_mbconv_fused`` eliminates.  Kept as the reference executable
    for fused-vs-staged numerics and traffic comparisons.
    """
    from .ops import convdk_depthwise2d

    if interpret is None:
        interpret = default_interpret()
    e = jnp.einsum("bhwc,cd->bhwd", x.astype(jnp.float32),
                   w_exp.astype(jnp.float32))
    e = _act_ref(e, exp_act)
    d = convdk_depthwise2d(e, w_dw.astype(jnp.float32), stride=stride,
                           padding=padding, tile_h=tile_h,
                           interpret=interpret)
    d = _act_ref(d.astype(jnp.float32), dw_act)
    if w_se1 is not None:
        pooled = jnp.mean(d, axis=(1, 2))
        s1 = _act_ref(pooled @ w_se1.astype(jnp.float32)
                      + b_se1.astype(jnp.float32), se_act)
        gate = _act_ref(s1 @ w_se2.astype(jnp.float32)
                        + b_se2.astype(jnp.float32), gate_act)
        d = d * gate[:, None, None, :]
    out = jnp.einsum("bhwc,cd->bhwd", d, w_proj.astype(jnp.float32))
    return out.astype(x.dtype)

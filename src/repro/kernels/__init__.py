"""ConvDK Pallas TPU kernels — the paper's compute hot-spot (depthwise
convolution) re-designed for the TPU memory hierarchy (DESIGN.md §Pillar B).
"""

from .convdk_fused import convdk_fused_separable, fused_separable_pallas
from .convdk_fusedmb import (
    convdk_fusedmb_fused,
    convdk_fusedmb_staged,
    fusedmb_pallas,
)
from .convdk_mbconv import convdk_mbconv_fused, convdk_mbconv_staged
from .convdk_sharded import (
    can_shard_fused,
    conv_mesh_shape,
    convdk_fused_separable_sharded,
    convdk_fusedmb_fused_sharded,
    convdk_mbconv_fused_sharded,
)
from .staging import (
    DEFAULT_RESIDENCY,
    RESIDENCY_MODES,
    StripPlan,
    StripStream,
    strip_plan,
)
from .ops import (
    convdk_causal_conv1d,
    convdk_depthwise2d,
    convdk_separable_staged,
    stage_row_strips,
    stage_seq_strips,
)
from .ref import (
    causal_conv1d_ref,
    causal_conv1d_update_ref,
    depthwise2d_ref,
    fusedmb_ref,
    mbconv_ref,
    separable_ref,
)

__all__ = [
    "DEFAULT_RESIDENCY",
    "RESIDENCY_MODES",
    "StripPlan",
    "StripStream",
    "strip_plan",
    "can_shard_fused",
    "conv_mesh_shape",
    "convdk_causal_conv1d",
    "convdk_depthwise2d",
    "convdk_fused_separable",
    "convdk_fused_separable_sharded",
    "convdk_fusedmb_fused",
    "convdk_fusedmb_fused_sharded",
    "convdk_fusedmb_staged",
    "convdk_mbconv_fused",
    "convdk_mbconv_fused_sharded",
    "convdk_mbconv_staged",
    "convdk_separable_staged",
    "fused_separable_pallas",
    "fusedmb_pallas",
    "stage_row_strips",
    "stage_seq_strips",
    "causal_conv1d_ref",
    "causal_conv1d_update_ref",
    "depthwise2d_ref",
    "fusedmb_ref",
    "mbconv_ref",
    "separable_ref",
]

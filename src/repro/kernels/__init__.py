"""ConvDK Pallas TPU kernels — the paper's compute hot-spot (depthwise
convolution) re-designed for the TPU memory hierarchy (DESIGN.md §Pillar B).
"""

from .ops import (
    convdk_causal_conv1d,
    convdk_depthwise2d,
    stage_row_strips,
    stage_seq_strips,
)
from .ref import causal_conv1d_ref, causal_conv1d_update_ref, depthwise2d_ref

__all__ = [
    "convdk_causal_conv1d",
    "convdk_depthwise2d",
    "stage_row_strips",
    "stage_seq_strips",
    "causal_conv1d_ref",
    "causal_conv1d_update_ref",
    "depthwise2d_ref",
]

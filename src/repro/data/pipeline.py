"""Deterministic, resumable synthetic data pipeline.

Produces next-token-predictable synthetic sequences (a noisy mod-vocab
progression) so the end-to-end training example shows a *decreasing* loss
curve — a real learnable signal, not white noise.  The stream state is just
(seed, step); checkpoints persist it, so restarts resume the exact stream
(fault tolerance without external data infra).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    family: str = "dense"     # encoder/vlm need extra tensors
    d_model: int = 0
    n_img_tokens: int = 0


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self) -> Dict[str, int]:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d) -> "DataState":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


def _tokens(rng: np.random.Generator, b: int, s: int, vocab: int) -> np.ndarray:
    """Learnable stream: arithmetic progressions mod vocab with 10 % noise."""
    start = rng.integers(0, vocab, (b, 1))
    stride = rng.integers(1, min(7, vocab), (b, 1))
    seq = (start + stride * np.arange(s)[None, :]) % vocab
    noise = rng.random((b, s)) < 0.10
    seq = np.where(noise, rng.integers(0, vocab, (b, s)), seq)
    return seq.astype(np.int32)


def make_batch(cfg: DataConfig, state: DataState) -> Tuple[Dict, DataState]:
    """Pure function of (cfg, state) -> (batch, next state): resumable."""
    rng = np.random.default_rng((cfg.seed, state.seed, state.step))
    b, s = cfg.global_batch, cfg.seq_len
    if cfg.family == "encoder":
        labels = _tokens(rng, b, s, cfg.vocab)
        embeds = rng.normal(size=(b, s, cfg.d_model)).astype(np.float32)
        # frame embeddings correlate with labels so the task is learnable
        embeds[..., 0] = labels / cfg.vocab
        batch = {"embeds": embeds, "labels": labels}
    elif cfg.family == "vlm":
        n_img = cfg.n_img_tokens
        toks = _tokens(rng, b, s - n_img, cfg.vocab)
        img = rng.normal(size=(b, n_img, cfg.d_model)).astype(np.float32)
        labels = np.concatenate(
            [np.zeros((b, n_img), np.int32), toks], axis=1)
        batch = {"tokens": toks, "img_embeds": img, "labels": labels}
    else:
        toks = _tokens(rng, b, s, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
    return batch, DataState(seed=state.seed, step=state.step + 1)


def iterate(cfg: DataConfig, state: Optional[DataState] = None
            ) -> Iterator[Tuple[Dict, DataState]]:
    state = state or DataState(seed=cfg.seed, step=0)
    while True:
        batch, state = make_batch(cfg, state)
        yield batch, state

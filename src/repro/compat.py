"""Version-compat shims over fast-moving JAX APIs.

The repo targets the installed JAX (CI pins a floor, not an exact version);
the sharding surface in particular moved between 0.4.x and 0.5+:

* ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)`` —
  absent before ~0.4.38; meshes there are implicitly "auto" everywhere.
* ``jax.set_mesh`` — newer spelling of the mesh context; older releases use
  the ``Mesh`` object's own context manager.

Everything that builds or activates a mesh goes through this module so the
suite collects and runs on any supported JAX.
"""

from __future__ import annotations

import contextlib
from typing import Sequence, Tuple

import jax

try:  # jax >= ~0.4.38
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - exercised on old JAX in CI matrix
    AxisType = None


def make_mesh(shape: Sequence[int], axes: Tuple[str, ...]):
    """``jax.make_mesh`` with Auto axis_types where the API supports them."""
    if AxisType is not None:
        try:
            return jax.make_mesh(tuple(shape), tuple(axes),
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across its moves: ``jax.shard_map`` (newest),
    ``jax.experimental.shard_map.shard_map`` (0.4.x).  Replication checking
    is disabled — the fused conv wrappers psum explicitly, and the check's
    kwarg itself was renamed (``check_rep`` -> ``check_vma``) between
    releases."""
    if hasattr(jax, "shard_map"):  # jax >= ~0.6
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:  # pragma: no cover - older spelling of the kwarg
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


@contextlib.contextmanager
def activate_mesh(mesh):
    """Enter a mesh context: ``jax.set_mesh`` when available, else the
    legacy ``Mesh`` context manager."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh

"""Version-compat shims over fast-moving JAX APIs.

The repo targets the installed JAX (CI pins a floor, not an exact version);
the sharding surface in particular moved between 0.4.x and 0.5+:

* ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)`` —
  absent before ~0.4.38; meshes there are implicitly "auto" everywhere.
* ``jax.set_mesh`` — newer spelling of the mesh context; older releases use
  the ``Mesh`` object's own context manager.

Everything that builds or activates a mesh goes through this module so the
suite collects and runs on any supported JAX.
"""

from __future__ import annotations

import contextlib
import os
from typing import Optional, Sequence, Tuple

import jax

try:  # jax >= ~0.4.38
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - exercised on old JAX in CI matrix
    AxisType = None


def make_mesh(shape: Sequence[int], axes: Tuple[str, ...]):
    """``jax.make_mesh`` with Auto axis_types where the API supports them."""
    if AxisType is not None:
        try:
            return jax.make_mesh(tuple(shape), tuple(axes),
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across its moves: ``jax.shard_map`` (newest),
    ``jax.experimental.shard_map.shard_map`` (0.4.x).  Replication checking
    is disabled — the fused conv wrappers psum explicitly, and the check's
    kwarg itself was renamed (``check_rep`` -> ``check_vma``) between
    releases."""
    if hasattr(jax, "shard_map"):  # jax >= ~0.6
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:  # pragma: no cover - older spelling of the kwarg
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# ---------------------------------------------------------------------------
# Pallas DMA surface (the strip-staging engine in kernels/staging.py)
#
# The production rendering of the fused ConvDK kernels keeps the input in
# the ANY/HBM memory space and DMAs each halo'd strip window into VMEM
# scratch with ``pltpu.make_async_copy``.  Interpret mode (the CI backend)
# executes the SAME DMA-structured code path — the interpreter implements
# the copy/semaphore primitives — so parity tests genuinely exercise the
# staging structure.  These shims pin the few symbols that moved between
# pallas releases (memory-space spelling, semaphore types) and degrade to a
# synchronous-copy object on builds without DMA tracing support, keeping
# the kernel code itself version-free.
# ---------------------------------------------------------------------------

def _pltpu():
    from jax.experimental.pallas import tpu as pltpu
    return pltpu


def pallas_any_memory_space():
    """The ANY (compiler-placed, HBM-capable) memory space marker."""
    pltpu = _pltpu()
    if hasattr(pltpu, "ANY"):
        return pltpu.ANY
    return pltpu.TPUMemorySpace.ANY  # pre-0.4.3x spelling


def pallas_supports_dma() -> bool:
    """True when this pallas build can trace async copies + DMA semaphores
    (every supported JAX; the fallback exists so exotic builds still run the
    staged structure, just with synchronous copies and no semaphores)."""
    pltpu = _pltpu()
    return hasattr(pltpu, "make_async_copy") \
        and hasattr(pltpu, "SemaphoreType")


def pallas_dma_semaphores(n: int):
    """Scratch-shape entry for an ``n``-slot DMA semaphore array."""
    return _pltpu().SemaphoreType.DMA((n,))


class _SyncCopy:
    """Degenerate async-copy object: copies on ``start``, no-op ``wait``.

    Only used when ``pallas_supports_dma()`` is False — the staging engine
    then runs the identical start/wait protocol without real semaphores.
    """

    def __init__(self, src, dst):
        self.src, self.dst = src, dst

    def start(self):
        self.dst[...] = self.src[...]

    def wait(self):
        pass


def pallas_async_copy(src, dst, sem, priority=None):
    """``pltpu.make_async_copy`` across versions (sync-copy fallback).

    ``priority`` requests a DMA stream priority for the copy (prefetches
    want the low-priority background stream, ``priority=1``, so demand
    fetches overtake them).  The installed pallas's ``make_async_copy``
    only grew that parameter in later releases, so it is passed through
    WHEN SUPPORTED and silently dropped otherwise —
    ``pallas_dma_priority_supported()`` reports which happened, and the
    bench records the knob as unsupported rather than pretending it was
    exercised."""
    pltpu = _pltpu()
    if sem is not None and hasattr(pltpu, "make_async_copy"):
        if priority is not None and pallas_dma_priority_supported():
            return pltpu.make_async_copy(src, dst, sem, priority=priority)
        return pltpu.make_async_copy(src, dst, sem)
    return _SyncCopy(src, dst)


def pallas_dma_priority_supported() -> bool:
    """Whether ``make_async_copy`` accepts a ``priority`` argument here."""
    pltpu = _pltpu()
    fn = getattr(pltpu, "make_async_copy", None)
    if fn is None:
        return False
    try:
        import inspect
        return "priority" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


# ---------------------------------------------------------------------------
# custom_vjp residual-forwarding bug: probe + barrier
#
# When a ``custom_vjp`` op whose residuals ARE its inputs sits under
# ``jax.jit`` with a ``shard_map`` in its primal (the cached sharded conv
# entry points), the installed JAX's partial-eval forwards the inputs
# straight to the residual outputs; on affected builds the sharded
# MBConv's ``w_dw`` cotangent then comes back multiplied by the model-axis
# size (the forwarded residuals' shardings re-partition the reference-vjp
# backward).  An ``optimization_barrier`` around the residual tuple keeps
# the residuals distinct values, restoring exact gradients.
#
# The barrier is PROBE-GATED: ``residual_forwarding_probe`` runs the real
# sharded MBConv gradient once, at a tiny shape on a (2, 2) slice of the
# local devices, and compares the ``w_dw`` cotangent against the reference
# VJP it is defined to equal.  On fixed JAX builds the barrier therefore
# auto-disables; where the probe cannot run (fewer than 4 devices, or any
# probe failure) the barrier stays on — it is harmless when the bug is
# absent.  ``CONVDK_RESIDUAL_BARRIER`` / ``set_residual_barrier`` force
# the decision ("on" | "off" | "auto").
# ---------------------------------------------------------------------------

_BARRIER_ENV = "CONVDK_RESIDUAL_BARRIER"
_BARRIER_MODES = ("auto", "on", "off")
_barrier_mode = os.environ.get(_BARRIER_ENV, "auto").lower()
if _barrier_mode not in _BARRIER_MODES:   # a typo'd override must be LOUD —
    raise ValueError(                     # silently probing anyway inverts
        f"{_BARRIER_ENV} must be one of {_BARRIER_MODES}, "
        f"got {_barrier_mode!r}")         # the operator's intent
_probe_result: Optional[str] = None    # "buggy" | "fixed" | "unprobed"
_probing = False


def set_residual_barrier(mode: str) -> str:
    """Force the residual barrier "on" / "off", or restore "auto" (the
    probe decides).  Returns the previous mode.  NOTE: the decision is
    baked into traces — clear the sharded entry-point caches
    (``convdk_sharded._sep_sharded_entry`` / ``_mbconv_sharded_entry``)
    when flipping it mid-process."""
    global _barrier_mode, _probe_result
    if mode not in _BARRIER_MODES:
        raise ValueError(f"mode must be one of {_BARRIER_MODES}, got {mode!r}")
    prev, _barrier_mode = _barrier_mode, mode
    if mode == "auto" and _probe_result == "unprobed":
        _probe_result = None   # retry an inconclusive probe; a concluded
    return prev                # buggy/fixed verdict is process-invariant


def residual_forwarding_probe() -> Optional[bool]:
    """Does THIS JAX build miscount custom_vjp residual-forwarded
    cotangents?  True = bug observed, False = exact without the barrier,
    None = cannot probe here (fewer than 4 devices, or the probe failed —
    the barrier then stays on).  The verdict is cached per process;
    inside an ambient trace (the probe's own computation would join it
    and leak tracers) nothing runs and nothing is cached — the next
    EAGER consult (the public wrappers make one per dispatch) resolves
    it."""
    global _probe_result
    if _probe_result is None:
        clean = getattr(jax.core, "trace_state_clean", lambda: True)
        if not clean():
            return None                # un-cached: retry when eager
        _probe_result = _run_forwarding_probe()
    return {"buggy": True, "fixed": False}.get(_probe_result)


def _run_forwarding_probe() -> str:
    global _probing
    if len(jax.devices()) < 4:
        return "unprobed"
    try:
        import numpy as np

        # lazy import: convdk_sharded imports this module at load time
        from .kernels.convdk_sharded import (
            _mbconv_sharded_op,
            _sep_sharded_op,
        )
        from .kernels.ref import mbconv_ref, separable_ref

        mesh = make_mesh((2, 2), ("data", "model"))
        b, hw, ci, co, k, cse = 2, 4, 8, 4, 3, 1
        cm = ci                        # identity expand (ratio-1 block)

        def arr(seed, *shape):
            rng = np.random.default_rng(seed)
            return jax.numpy.asarray(rng.normal(size=shape) * 0.3,
                                     jax.numpy.float32)

        x = arr(0, b, hw, hw, ci)
        weights = (jax.numpy.eye(cm, dtype=jax.numpy.float32),
                   arr(1, k, k, cm), arr(2, cm, cse), arr(3, cse),
                   arr(4, cse, cm), arr(5, cm), arr(6, cm, co))

        # a fresh jit around the raw op: the probe must not populate (or
        # read) the production lru entry-point cache with a barrier-free
        # trace.  Structure matters, and mirrors the production entry
        # points exactly: ALL arrays are jit ARGUMENTS (input->output
        # forwarding only fires on jit inputs, not closure constants),
        # the jit returns the OP OUTPUT (the loss stays outside, as in
        # serving/training loops), and the loss DEPENDS on the primal
        # output ((out**2) — a constant cotangent does not tickle the
        # forwarding rewrite).
        entry = jax.jit(lambda *arrays: _mbconv_sharded_op(
            *arrays, mesh, 1, "SAME", 1, "retain", None, "silu", True,
            "strip_dma_db", "ring_allreduce", "replicated"))

        def loss(wd):
            out = entry(x, weights[0], wd, *weights[2:])
            return (out ** 2).sum()

        _probing = True               # trace the fwd WITHOUT the barrier
        try:
            got = jax.grad(loss)(weights[1])
        finally:
            _probing = False
        want = jax.grad(
            lambda wd: (mbconv_ref(x, weights[0], wd, *weights[2:],
                                   stride=1, exp_act=None) ** 2).sum(),
        )(weights[1])
        if not np.allclose(np.asarray(got), np.asarray(want),
                           rtol=1e-3, atol=1e-3):
            return "buggy"

        # second leg: the SEPARABLE custom_vjp (3-tuple residuals, no
        # psum, c_out-sharded out_specs) — a build could rewrite one
        # family's forwarding and not the other's, and a "fixed" verdict
        # disables the barrier for BOTH
        w_pw = arr(7, ci, co)
        sep_entry = jax.jit(lambda *arrays: _sep_sharded_op(
            *arrays, mesh, 1, "SAME", 1, None, None, True,
            "strip_dma_db", "ring_allreduce", "replicated"))

        def sep_loss(wd):
            return (sep_entry(x, wd, w_pw) ** 2).sum()

        _probing = True
        try:
            got_s = jax.grad(sep_loss)(weights[1])
        finally:
            _probing = False
        want_s = jax.grad(
            lambda wd: (separable_ref(x, wd, w_pw, stride=1, dw_act=None,
                                      act=None) ** 2).sum())(weights[1])
        exact = np.allclose(np.asarray(got_s), np.asarray(want_s),
                            rtol=1e-3, atol=1e-3)
        return "fixed" if exact else "buggy"
    except Exception:                 # any probe failure: keep the barrier
        return "unprobed"


def residual_barrier_needed() -> bool:
    """The probe-gated decision ``residual_barrier`` applies (see the
    section doc): forced modes win (the env var seeds the initial mode,
    ``set_residual_barrier`` overrides it), otherwise the probe — with
    the barrier kept on wherever the probe is inconclusive."""
    if _barrier_mode == "on":
        return True
    if _barrier_mode == "off":
        return False
    return residual_forwarding_probe() is not False


def residual_barrier(res):
    """Block jit's input->output forwarding on a custom_vjp residual tuple
    (section doc above) — unless the probe shows this build is fixed, in
    which case the tuple passes through untouched.  On builds without the
    ``optimization_barrier`` primitive this degrades to identity (those
    builds predate the forwarding rewrite that miscounts)."""
    barrier = getattr(jax.lax, "optimization_barrier", None)
    if barrier is None or _probing or not residual_barrier_needed():
        return res
    return barrier(res)


@contextlib.contextmanager
def activate_mesh(mesh):
    """Enter a mesh context: ``jax.set_mesh`` when available, else the
    legacy ``Mesh`` context manager."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh

"""Version-compat shims over fast-moving JAX APIs.

The repo targets the installed JAX (CI pins a floor, not an exact version);
the sharding surface in particular moved between 0.4.x and 0.5+:

* ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)`` —
  absent before ~0.4.38; meshes there are implicitly "auto" everywhere.
* ``jax.set_mesh`` — newer spelling of the mesh context; older releases use
  the ``Mesh`` object's own context manager.

Everything that builds or activates a mesh goes through this module so the
suite collects and runs on any supported JAX.
"""

from __future__ import annotations

import contextlib
from typing import Sequence, Tuple

import jax

try:  # jax >= ~0.4.38
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - exercised on old JAX in CI matrix
    AxisType = None


def make_mesh(shape: Sequence[int], axes: Tuple[str, ...]):
    """``jax.make_mesh`` with Auto axis_types where the API supports them."""
    if AxisType is not None:
        try:
            return jax.make_mesh(tuple(shape), tuple(axes),
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across its moves: ``jax.shard_map`` (newest),
    ``jax.experimental.shard_map.shard_map`` (0.4.x).  Replication checking
    is disabled — the fused conv wrappers psum explicitly, and the check's
    kwarg itself was renamed (``check_rep`` -> ``check_vma``) between
    releases."""
    if hasattr(jax, "shard_map"):  # jax >= ~0.6
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:  # pragma: no cover - older spelling of the kwarg
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# ---------------------------------------------------------------------------
# Pallas DMA surface (the strip-staging engine in kernels/staging.py)
#
# The production rendering of the fused ConvDK kernels keeps the input in
# the ANY/HBM memory space and DMAs each halo'd strip window into VMEM
# scratch with ``pltpu.make_async_copy``.  Interpret mode (the CI backend)
# executes the SAME DMA-structured code path — the interpreter implements
# the copy/semaphore primitives — so parity tests genuinely exercise the
# staging structure.  These shims pin the few symbols that moved between
# pallas releases (memory-space spelling, semaphore types) and degrade to a
# synchronous-copy object on builds without DMA tracing support, keeping
# the kernel code itself version-free.
# ---------------------------------------------------------------------------

def _pltpu():
    from jax.experimental.pallas import tpu as pltpu
    return pltpu


def pallas_any_memory_space():
    """The ANY (compiler-placed, HBM-capable) memory space marker."""
    pltpu = _pltpu()
    if hasattr(pltpu, "ANY"):
        return pltpu.ANY
    return pltpu.TPUMemorySpace.ANY  # pre-0.4.3x spelling


def pallas_supports_dma() -> bool:
    """True when this pallas build can trace async copies + DMA semaphores
    (every supported JAX; the fallback exists so exotic builds still run the
    staged structure, just with synchronous copies and no semaphores)."""
    pltpu = _pltpu()
    return hasattr(pltpu, "make_async_copy") \
        and hasattr(pltpu, "SemaphoreType")


def pallas_dma_semaphores(n: int):
    """Scratch-shape entry for an ``n``-slot DMA semaphore array."""
    return _pltpu().SemaphoreType.DMA((n,))


class _SyncCopy:
    """Degenerate async-copy object: copies on ``start``, no-op ``wait``.

    Only used when ``pallas_supports_dma()`` is False — the staging engine
    then runs the identical start/wait protocol without real semaphores.
    """

    def __init__(self, src, dst):
        self.src, self.dst = src, dst

    def start(self):
        self.dst[...] = self.src[...]

    def wait(self):
        pass


def pallas_async_copy(src, dst, sem):
    """``pltpu.make_async_copy`` across versions (sync-copy fallback)."""
    pltpu = _pltpu()
    if sem is not None and hasattr(pltpu, "make_async_copy"):
        return pltpu.make_async_copy(src, dst, sem)
    return _SyncCopy(src, dst)


def residual_barrier(res):
    """Block jit's input->output forwarding on a custom_vjp residual tuple.

    When a ``custom_vjp`` op whose residuals ARE its inputs sits under
    ``jax.jit`` with a ``shard_map`` in its primal (the cached sharded
    conv entry points), the installed JAX's partial-eval forwards the
    inputs straight to the residual outputs and the cotangent of one
    operand gets double-counted (observed: the sharded MBConv's ``w_dw``
    gradient exactly 2x).  An ``optimization_barrier`` around the
    residuals keeps them distinct values, restoring exact gradients; on
    builds without the primitive this degrades to identity (those builds
    predate the forwarding rewrite that miscounts).
    """
    barrier = getattr(jax.lax, "optimization_barrier", None)
    return barrier(res) if barrier is not None else res


@contextlib.contextmanager
def activate_mesh(mesh):
    """Enter a mesh context: ``jax.set_mesh`` when available, else the
    legacy ``Mesh`` context manager."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh

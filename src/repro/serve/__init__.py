"""Serving engines: LM (length-bucketed BIG/LITTLE) and vision
(resolution-bucketed batches with per-layer traffic telemetry)."""

from .engine import Engine, ServeConfig
from .vision import (
    VisionEngine,
    VisionRequest,
    VisionResult,
    VisionServeConfig,
)

__all__ = [
    "Engine",
    "ServeConfig",
    "VisionEngine",
    "VisionRequest",
    "VisionResult",
    "VisionServeConfig",
]

"""Batched serving engine: prefill + decode with per-family caches.

The engine jits one prefill function and one decode function per model and
runs greedy/sampled generation over a batch of prompts.  Cache layouts are
family-native (dense KV, MLA latent, sliding-window ring, SSM/LRU constant
state) — chosen by ``init_decode_state``.

BIG/LITTLE-inspired admission (the paper's scheduler idea lifted to
serving, DESIGN.md §Pillar C): requests are bucketed by prompt length and
a bucket is launched either as one BIG batch (few long prompts — prefill
dominated) or as packed LITTLE batches (many short prompts share one decode
batch so the state memory stays fully utilized), mirroring how the CIM
scheduler packs small channels into one TRF.  ``generate_many`` is the
entry point that actually consumes ``schedule()``'s batches: prompts in a
LITTLE pack are left-padded to a shared length bucket so unequal-length
requests stack into one shape-stable prefill.

The vision-side counterpart (admission by RESOLUTION bucket over the fused
EfficientNet pipeline, with per-layer traffic telemetry) lives in
``serve.vision``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import (
    ModelConfig, decode_step, init_decode_state,
)


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    greedy: bool = True
    temperature: float = 1.0
    # LITTLE-packing: prompts shorter than this share a packed batch
    little_threshold: int = 256
    # requests per LITTLE pack (the shared decode batch size)
    little_pack: int = 8
    # LITTLE prompts pad up to a multiple of this, so mixed lengths stack
    # into few distinct prefill shapes (shape-stable jit)
    length_bucket: int = 32
    pad_id: int = 0
    eos_id: Optional[int] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params,
                 serve_cfg: Optional[ServeConfig] = None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg or ServeConfig()
        self._prefill = jax.jit(self._prefill_fn)
        self._step = jax.jit(self._step_fn)
        self._generate_calls = 0       # per-call default-rng derivation

    # -- jitted bodies ------------------------------------------------------
    def _prefill_fn(self, params, tokens, state):
        """Run the prompt through decode steps via scan (exactly matches the
        step-by-step cache semantics for every family)."""
        def body(st, tok):
            logits, st = decode_step(params, st, {"tokens": tok}, self.cfg)
            return st, logits

        state, logits = jax.lax.scan(body, state, tokens.T)
        return state, logits[-1]

    def _step_fn(self, params, state, tok, rng):
        logits, state = decode_step(params, state, {"tokens": tok}, self.cfg)
        if self.scfg.greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                rng, logits / self.scfg.temperature).astype(jnp.int32)
        return state, nxt

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: np.ndarray, rng: Optional[jax.Array] = None
                 ) -> np.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32.

        With ``eos_id`` set, a row that emits EOS stops: its later
        positions are filled with ``eos_id`` (the output stays rectangular)
        and the decode loop exits early once EVERY row has finished — the
        config field is load-bearing, not decorative.

        ``rng=None`` derives a fresh per-call key (folding a call counter
        into a fixed base), so two sampled calls on one engine draw
        different tokens instead of silently replaying key(0).
        """
        b, s_prompt = prompts.shape
        total = s_prompt + self.scfg.max_new_tokens
        state = init_decode_state(self.cfg, b, total,
                                  jnp.dtype(self.cfg.dtype))
        state, last_logits = self._prefill(
            self.params, jnp.asarray(prompts, jnp.int32), state)
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
        if rng is None:
            rng = jax.random.fold_in(jax.random.key(0), self._generate_calls)
            self._generate_calls += 1

        eos = self.scfg.eos_id
        done = np.zeros(b, bool)
        if eos is not None:
            done |= np.asarray(tok) == eos
        outs = [tok]
        for _ in range(self.scfg.max_new_tokens - 1):
            if eos is not None and done.all():
                break                       # every row hit EOS: stop decoding
            rng, sub = jax.random.split(rng)
            state, tok = self._step(self.params, state, tok, sub)
            if eos is not None:
                # rows past their EOS emit eos_id from here on (and the
                # masked token is what feeds the next step's cache)
                tok = jnp.where(jnp.asarray(done), jnp.int32(eos), tok)
                done |= np.asarray(tok) == eos
            outs.append(tok)
        out = np.stack([np.asarray(t) for t in outs], axis=1)
        if out.shape[1] < self.scfg.max_new_tokens:      # early EOS exit
            pad = np.full((b, self.scfg.max_new_tokens - out.shape[1]),
                          eos, np.int32)
            out = np.concatenate([out, pad], axis=1)
        return out

    def generate_many(self, requests: List[np.ndarray],
                      rng: Optional[jax.Array] = None) -> List[np.ndarray]:
        """Serve a mixed request list through BIG/LITTLE admission.

        ``schedule()`` groups request indices into launch batches; each
        LITTLE pack left-pads its prompts with ``pad_id`` to the pack's
        shared length bucket (``length_bucket`` multiples — mixed lengths
        produce few distinct prefill shapes, so the jitted prefill
        retraces per bucket, not per request) and runs one ``generate``.
        Left-padding keeps every prompt's last real token at the final
        scan position, where the prefill reads its next-token logits.
        Returns per-request (max_new_tokens,) outputs in request order.
        """
        outs: List[Optional[np.ndarray]] = [None] * len(requests)
        for idxs in self.schedule(requests):
            longest = max(len(requests[i]) for i in idxs)
            bucket = -(-max(1, longest) // self.scfg.length_bucket) \
                * self.scfg.length_bucket
            prompts = np.full((len(idxs), bucket), self.scfg.pad_id,
                              np.int32)
            for row, i in enumerate(idxs):
                r = np.asarray(requests[i], np.int32).reshape(-1)
                if len(r):
                    prompts[row, bucket - len(r):] = r
            sub = None
            if rng is not None:
                rng, sub = jax.random.split(rng)
            toks = self.generate(prompts, sub)
            for row, i in enumerate(idxs):
                outs[i] = toks[row]
        return outs

    def schedule(self, requests: List[np.ndarray]) -> List[List[int]]:
        """BIG/LITTLE admission: group request indices into launch batches.

        LITTLE requests (shorter than ``little_threshold``) are first
        grouped by their padded length bucket — a pack only holds prompts
        that stack into ONE prefill shape — then packed ``little_pack``
        at a time; BIG prompts run alone.
        """
        buckets: dict = {}
        big = []
        for i, r in enumerate(requests):
            if len(r) < self.scfg.little_threshold:
                key = -(-max(1, len(r)) // self.scfg.length_bucket)
                buckets.setdefault(key, []).append(i)
            else:
                big.append(i)
        batches = []
        pack = max(1, self.scfg.little_pack)
        for key in sorted(buckets):
            little = buckets[key]
            for j in range(0, len(little), pack):
                batches.append(little[j:j + pack])
        for i in big:
            batches.append([i])      # BIG: long prompts run alone
        return batches

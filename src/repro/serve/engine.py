"""Batched serving engine: prefill + decode with per-family caches.

The engine jits one prefill function and one decode function per model and
runs greedy/sampled generation over a batch of prompts.  Cache layouts are
family-native (dense KV, MLA latent, sliding-window ring, SSM/LRU constant
state) — chosen by ``init_decode_state``.

BIG/LITTLE-inspired admission (the paper's scheduler idea lifted to
serving, DESIGN.md §Pillar C): requests are bucketed by prompt length and
a bucket is launched either as one BIG batch (few long prompts — prefill
dominated) or as packed LITTLE batches (many short prompts share one decode
batch so the state memory stays fully utilized), mirroring how the CIM
scheduler packs small channels into one TRF.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import (
    ModelConfig, decode_step, init_decode_state,
)


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    greedy: bool = True
    temperature: float = 1.0
    # LITTLE-packing: prompts shorter than this share a packed batch
    little_threshold: int = 256
    eos_id: Optional[int] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig = None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg or ServeConfig()
        self._prefill = jax.jit(self._prefill_fn)
        self._step = jax.jit(self._step_fn)

    # -- jitted bodies ------------------------------------------------------
    def _prefill_fn(self, params, tokens, state):
        """Run the prompt through decode steps via scan (exactly matches the
        step-by-step cache semantics for every family)."""
        def body(st, tok):
            logits, st = decode_step(params, st, {"tokens": tok}, self.cfg)
            return st, logits

        state, logits = jax.lax.scan(body, state, tokens.T)
        return state, logits[-1]

    def _step_fn(self, params, state, tok, rng):
        logits, state = decode_step(params, state, {"tokens": tok}, self.cfg)
        if self.scfg.greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                rng, logits / self.scfg.temperature).astype(jnp.int32)
        return state, nxt

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: np.ndarray, rng: Optional[jax.Array] = None
                 ) -> np.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32."""
        b, s_prompt = prompts.shape
        total = s_prompt + self.scfg.max_new_tokens
        state = init_decode_state(self.cfg, b, total,
                                  jnp.dtype(self.cfg.dtype))
        state, last_logits = self._prefill(
            self.params, jnp.asarray(prompts, jnp.int32), state)
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
        rng = rng if rng is not None else jax.random.key(0)

        outs = [tok]
        for i in range(self.scfg.max_new_tokens - 1):
            rng, sub = jax.random.split(rng)
            state, tok = self._step(self.params, state, tok, sub)
            outs.append(tok)
        return np.stack([np.asarray(t) for t in outs], axis=1)

    def schedule(self, requests: List[np.ndarray]) -> List[List[int]]:
        """BIG/LITTLE admission: group request indices into launch batches."""
        little, big = [], []
        for i, r in enumerate(requests):
            (little if len(r) < self.scfg.little_threshold else big).append(i)
        batches = []
        if little:
            # LITTLE: pack many short prompts into shared batches of 8+
            for j in range(0, len(little), 8):
                batches.append(little[j:j + 8])
        for i in big:
            batches.append([i])      # BIG: long prompts run alone
        return batches

"""Batched vision serving engine over the fused EfficientNet pipeline.

The LM engine (``serve.engine``) buckets requests by prompt LENGTH; the
vision engine generalizes the same BIG/LITTLE admission idea to image
RESOLUTION: mixed 224/384/512 requests are admitted into per-resolution
buckets and launched as shape-stable jitted batches (one trace per bucket,
never per request), through ``efficientnet_b0_apply`` with the
network-level layout plan (``core.autotune.get_network_plan``) solved ONCE
per bucket and threaded into every launch.

Three serving concerns the benchmark harness never had to answer live
here:

* **Admission + load shedding** — a bounded request queue; ``submit``
  refuses work above the bound (or images above the largest bucket) and
  counts every rejection, so overload is measured instead of unbounded.
* **Traffic telemetry where it happens** — every launched batch charges
  per-(layer x shape-class) counters with the MODELED bytes of the exact
  schedules the blocks run (the plan is passed into the model call, so
  counter bytes and executed schedules cannot drift): the paper's
  "buffer traffic dominates" argument, surfaced per layer while serving.
  ``benchmarks/serve_report.py`` tabulates the counters as a top-N
  bottleneck report and gates the reconciliation.
* **Latency percentiles** — per-request latencies from blocked timings
  (``jax.block_until_ready``, the ``telemetry.measure`` discipline)
  recorded as telemetry series alongside queue depth and wait times.

Admission is a TWO-LEVEL FIFO: ``submit(image, priority=1)`` places a
request in the priority lane, which ``step`` drains ahead of the normal
lane (FIFO within each lane; the batch back-fills from the normal lane's
same bucket).  Shedding is unchanged — the queue bound applies to the
COMBINED depth, so priority requests cannot starve the shed accounting.

Every bucket's plan comes from the network-level solve, which now
includes the cross-block ``overlap`` axis: boundaries the DP proves
pipelinable execute pass 2 of block *i* overlapped with pass 1 of block
*i+1* (``models.blockgraph`` validates the buffer hazards at lowering),
so serving inherits the pipelined chain latency without any engine code
knowing about it.  ``serve.pipelined_boundaries.r<res>`` records how
many boundaries of the bucket's plan pipeline.

Counter naming (shape-class first, then layer):

    serve.admitted / serve.admitted.priority
    serve.shed.queue_full / serve.shed.oversize
    serve.batches.r<res> / serve.requests.r<res> / serve.pad_slots.r<res>
    serve.bytes.r<res>.<layer>       modeled bytes moved (layer = stem,
                                     block00..blockNN, boundaries)
    serve.collective.r<res>.<layer>  modeled interconnect bytes
    serve.trace.r<res>               trace-time: retrace counter
    serve.pipelined_boundaries.r<res>  plan-time: solved overlap count

Series: ``serve.queue_depth``, ``serve.queue_wait_s``, ``serve.latency_s``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import telemetry
from ..core.autotune import NetworkPlan, get_network_plan
from ..models.mbconv import (
    EffNetConfig,
    effnet_block_specs,
    effnet_chain_rows,
    efficientnet_b0_apply,
)

__all__ = [
    "VisionEngine",
    "VisionRequest",
    "VisionResult",
    "VisionServeConfig",
    "layer_names",
]

STEM_STRIDE = 2      # the B0 stem conv halves the spatial dims


@dataclasses.dataclass(frozen=True)
class VisionServeConfig:
    """Admission policy of one vision serving engine.

    ``resolutions`` are the square admission buckets, ascending; a request
    joins the smallest bucket its longest side fits (zero-padded up to the
    bucket — no resampling dependency), and anything above the largest
    bucket is shed.  ``batch_size`` is the shape-stable pack per launch
    (short packs pad with zero images — the padding slots are counted).
    ``max_queue`` bounds the admission queue; ``submit`` sheds above it.
    """

    resolutions: Tuple[int, ...] = (224, 384, 512)
    batch_size: int = 8
    max_queue: int = 64

    def __post_init__(self):
        if not self.resolutions:
            raise ValueError("need at least one resolution bucket")
        if list(self.resolutions) != sorted(set(self.resolutions)):
            raise ValueError(
                f"resolutions must be strictly ascending, "
                f"got {self.resolutions}")
        if min(self.resolutions) < STEM_STRIDE:
            raise ValueError(f"resolutions must be >= {STEM_STRIDE}")
        if self.batch_size < 1 or self.max_queue < 1:
            raise ValueError("batch_size and max_queue must be >= 1")


@dataclasses.dataclass
class VisionRequest:
    """One admitted request waiting in (or leaving) the queue."""

    rid: int
    image: np.ndarray
    bucket: int                  # admission resolution
    t_submit: float
    priority: int = 0            # > 0 = priority lane (drained first)


@dataclasses.dataclass
class VisionResult:
    """One served request: logits plus the serving story around them."""

    rid: int
    bucket: int
    logits: np.ndarray
    latency_s: float             # submit -> blocked batch completion
    queue_wait_s: float          # submit -> batch launch
    traffic_bytes: float         # this request's share of the batch's
    # modeled end-to-end bytes (the full padded batch is charged to the
    # real requests riding it, so padding waste shows up per request)


def layer_names(n_blocks: int) -> Tuple[str, ...]:
    """Per-launch traffic-counter layer labels, chain order."""
    return ("stem",) + tuple(f"block{i:02d}" for i in range(n_blocks)) \
        + ("boundaries",)


class VisionEngine:
    """Admission-bucketed batched inference over the fused B0 pipeline.

    ``submit()`` admits (or sheds) one image; ``step()`` launches ONE
    shape-stable batch — the oldest waiter's bucket, filled FIFO from that
    bucket up to ``batch_size``; ``drain()`` steps until the queue is
    empty.  Every launch reuses the bucket's jitted entry point and its
    once-solved ``NetworkPlan`` (``plan_for``), so steady-state serving
    never re-traces and never re-solves.
    """

    def __init__(self, params, cfg: EffNetConfig = EffNetConfig(),
                 serve_cfg: Optional[VisionServeConfig] = None,
                 mesh=None, kcfg=None):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg or VisionServeConfig()
        self.mesh = mesh
        if kcfg is None:
            from ..configs.base import kernel_config
            kcfg = kernel_config()
        self.kcfg = kcfg
        self.specs = effnet_block_specs(cfg)
        self._queue: Deque[VisionRequest] = deque()
        self._pqueue: Deque[VisionRequest] = deque()
        self._next_rid = 0
        self._plans: Dict[int, NetworkPlan] = {}
        self._applies: Dict[int, object] = {}

    # -- admission -----------------------------------------------------------

    def bucket_for(self, h: int, w: int) -> Optional[int]:
        """Smallest resolution bucket the image fits; None = oversize."""
        side = max(h, w)
        for res in self.scfg.resolutions:
            if side <= res:
                return res
        return None

    def submit(self, image: np.ndarray, priority: int = 0) -> Optional[int]:
        """Admit one (H, W, 3) image.  Returns the request id, or None
        when the request is SHED (queue at bound, or image above the
        largest bucket) — every shed increments its rejection counter.

        ``priority > 0`` admits into the priority lane, which ``step``
        drains ahead of the normal lane.  The queue bound covers BOTH
        lanes combined — priority admission never bypasses shedding, it
        only reorders service among the admitted."""
        image = np.asarray(image)
        if image.ndim != 3 or image.shape[-1] != 3:
            raise ValueError(f"expected an (H, W, 3) image, "
                             f"got shape {image.shape}")
        bucket = self.bucket_for(image.shape[0], image.shape[1])
        if bucket is None:
            telemetry.counter("serve.shed.oversize")
            return None
        if self.pending() >= self.scfg.max_queue:
            telemetry.counter("serve.shed.queue_full")
            return None
        rid = self._next_rid
        self._next_rid += 1
        rq = VisionRequest(rid=rid, image=image, bucket=bucket,
                           t_submit=time.perf_counter(), priority=priority)
        (self._pqueue if priority > 0 else self._queue).append(rq)
        telemetry.counter("serve.admitted")
        if priority > 0:
            telemetry.counter("serve.admitted.priority")
        telemetry.record("serve.queue_depth", self.pending())
        return rid

    def pending(self) -> int:
        return len(self._pqueue) + len(self._queue)

    @property
    def shed(self) -> int:
        """Total requests shed so far (both rejection counters)."""
        t = telemetry.get_telemetry()
        return int(t.get("serve.shed.queue_full")
                   + t.get("serve.shed.oversize"))

    # -- per-bucket plan + jitted entry --------------------------------------

    def _mesh_shape(self) -> Tuple[int, int]:
        if self.mesh is None:
            return (1, 1)
        from ..kernels import conv_mesh_shape
        return conv_mesh_shape(self.mesh)

    def plan_for(self, res: int) -> NetworkPlan:
        """The bucket's network-level layout plan: solved once per
        resolution (chain rows start at the stem-output dims), reused by
        every batch of that bucket — and threaded into the model call, so
        the schedules priced here are the schedules that run."""
        if res not in self._plans:
            stem_hw = -(-res // STEM_STRIDE)
            rows = effnet_chain_rows(self.specs, stem_hw, stem_hw)
            plan = get_network_plan(
                rows, self.scfg.batch_size, self._mesh_shape(),
                dtype_bytes=jnp.dtype(self.cfg.dtype).itemsize,
                se_ratio=self.cfg.se_ratio)
            self._plans[res] = plan
            # solve-time, like the plan itself: how many boundaries of
            # this bucket's chain execute pipelined (pass-2 ∥ pass-1)
            telemetry.counter(f"serve.pipelined_boundaries.r{res}",
                              len(plan.pipelined_boundaries))
        return self._plans[res]

    def modeled_layer_bytes(self, res: int) -> Dict[str, Tuple[int, int]]:
        """Per-LAUNCH modeled traffic of one bucket: layer label ->
        (total bytes, collective bytes).  The exact increments every
        launched batch of this bucket adds to its counters — the
        reconciliation contract ``serve_report``/tests gate on."""
        plan = self.plan_for(res)
        out: Dict[str, Tuple[int, int]] = {"stem": (plan.stem_bytes, 0)}
        for i, bp in enumerate(plan.blocks):
            out[f"block{i:02d}"] = (bp.schedule.total_bytes,
                                    bp.schedule.collective_bytes)
        out["boundaries"] = (plan.boundary_words * plan.dtype_bytes, 0)
        return out

    def _apply_for(self, res: int):
        if res not in self._applies:
            plan = self.plan_for(res)
            cfg, kcfg, mesh = self.cfg, self.kcfg, self.mesh

            def apply(params, images):
                # trace-time increment (telemetry's documented jit
                # semantics): fires once per COMPILATION, so this counter
                # staying at 1 per bucket IS the no-per-request-retrace
                # guarantee the admission design makes
                telemetry.counter(f"serve.trace.r{res}")
                return efficientnet_b0_apply(params, images, cfg, kcfg,
                                             mesh=mesh, plan=plan)

            self._applies[res] = jax.jit(apply)
        return self._applies[res]

    # -- serving -------------------------------------------------------------

    def step(self) -> List[VisionResult]:
        """Launch ONE batch: the oldest PRIORITY waiter's bucket (falling
        back to the oldest normal waiter), filled FIFO from that bucket —
        priority lane first, then back-filled from the normal lane — up
        to ``batch_size`` (short packs zero-pad)."""
        if not self._pqueue and not self._queue:
            return []
        head = self._pqueue[0] if self._pqueue else self._queue[0]
        res = head.bucket
        take: List[VisionRequest] = []
        for lane_name in ("_pqueue", "_queue"):
            lane: Deque[VisionRequest] = getattr(self, lane_name)
            keep: Deque[VisionRequest] = deque()
            for rq in lane:
                if rq.bucket == res and len(take) < self.scfg.batch_size:
                    take.append(rq)
                else:
                    keep.append(rq)
            setattr(self, lane_name, keep)
        return self._launch(res, take)

    def drain(self) -> List[VisionResult]:
        """Step until both lanes are empty; results in completion order."""
        out: List[VisionResult] = []
        while self._pqueue or self._queue:
            out.extend(self.step())
        return out

    def _launch(self, res: int, reqs: List[VisionRequest]
                ) -> List[VisionResult]:
        plan = self.plan_for(res)
        batch = np.zeros((self.scfg.batch_size, res, res, 3), np.float32)
        for row, rq in enumerate(reqs):
            h, w = rq.image.shape[:2]
            batch[row, :h, :w, :] = rq.image
        fn = self._apply_for(res)
        t_launch = time.perf_counter()
        with telemetry.span(f"serve.batch.r{res}"):
            logits = jax.block_until_ready(
                fn(self.params, jnp.asarray(batch)))
        t_done = time.perf_counter()

        telemetry.counter(f"serve.batches.r{res}")
        telemetry.counter(f"serve.requests.r{res}", len(reqs))
        telemetry.counter(f"serve.pad_slots.r{res}",
                          self.scfg.batch_size - len(reqs))
        for layer, (total, coll) in self.modeled_layer_bytes(res).items():
            telemetry.counter(f"serve.bytes.r{res}.{layer}", total)
            telemetry.counter(f"serve.collective.r{res}.{layer}", coll)

        share = plan.total_bytes / max(1, len(reqs))
        arr = np.asarray(logits)
        results = []
        for row, rq in enumerate(reqs):
            latency = t_done - rq.t_submit
            wait = t_launch - rq.t_submit
            telemetry.record("serve.latency_s", latency)
            telemetry.record("serve.queue_wait_s", wait)
            results.append(VisionResult(
                rid=rq.rid, bucket=res, logits=arr[row],
                latency_s=latency, queue_wait_s=wait, traffic_bytes=share))
        return results

    # -- observability -------------------------------------------------------

    def latency_percentiles(self, qs: Sequence[float] = (50, 90, 99)
                            ) -> Dict[str, float]:
        """Nearest-rank percentiles over every served request's blocked
        latency (the ``serve.latency_s`` series)."""
        return telemetry.percentiles(telemetry.series("serve.latency_s"), qs)

"""deepseek-v2-236b [arXiv:2405.04434; hf]: 60L d_model=5120 128H d_ff=1536
(per-expert) vocab=102400, MoE 160 routed top-6 + 2 shared, MLA kv_lora=512.

MLA dims per the paper: q_lora 1536, kv_lora 512, d_nope 128, d_rope 64,
v head dim 128.  Layer 0 uses a dense FFN (d_ff 12288); experts are 160
(divisible by the 16-way model axis, no padding).  Memory note: AdamW m/v
are float32; the 236B cell relies on FSDP(data) x TP(model) 256-way
parameter sharding (see EXPERIMENTS.md §Dry-run memory_analysis)."""

from ..models.model import ModelConfig
from .base import SKIP_LONG, ArchSpec, register

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=12288, vocab=102400,
    n_experts=160, n_experts_pad=160, top_k=6, d_ff_expert=1536,
    n_shared_experts=2, n_dense_prefix=1,
    use_mla=True, q_lora=1536, kv_lora=512, d_nope=128, d_rope=64,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=8, head_dim=16,
    d_ff=128, vocab=64, n_experts=8, n_experts_pad=8, top_k=2,
    d_ff_expert=32, n_shared_experts=1, n_dense_prefix=1,
    use_mla=True, q_lora=32, kv_lora=16, d_nope=16, d_rope=8,
    dtype="float32",
)

register(ArchSpec("deepseek-v2-236b", CONFIG, SMOKE, skips=dict(SKIP_LONG)))

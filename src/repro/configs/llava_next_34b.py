"""llava-next-34b [hf:llava-hf]: 60L d_model=7168 56H (kv=8) head_dim=128
d_ff=20480 vocab=64000 — VLM backbone only; the anyres vision tower is a
STUB (input_specs provide 576 precomputed patch embeddings prepended to the
text sequence, keeping the total length at the cell's seq_len)."""

from ..models.model import ModelConfig
from .base import SKIP_LONG, ArchSpec, register

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000, n_img_tokens=576,
)

SMOKE = ModelConfig(
    name="llava-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=64, n_img_tokens=8, dtype="float32",
)

register(ArchSpec("llava-next-34b", CONFIG, SMOKE, skips=dict(SKIP_LONG)))

"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

The dry-run lowers against these (weak-type-correct, shardable, zero
allocation).  Modality frontends are stubs per the assignment: hubert gets
precomputed frame embeddings, llava gets precomputed patch embeddings.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..models.model import ModelConfig, init_decode_state
from .base import ArchSpec, ShapeCell

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(spec: ArchSpec, cell: ShapeCell,
                cfg: ModelConfig = None) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for one cell (without decode state)."""
    cfg = cfg or spec.config
    b, s = cell.global_batch, cell.seq_len
    dt = cfg.adtype
    if cfg.family == "encoder":
        batch = {"embeds": _sds((b, s, cfg.d_model), dt)}
        if cell.kind == "train":
            batch["labels"] = _sds((b, s), I32)
        return batch
    if cfg.family == "vlm" and cell.kind != "decode":
        n_img = cfg.n_img_tokens
        batch = {
            "tokens": _sds((b, s - n_img), I32),
            "img_embeds": _sds((b, n_img, cfg.d_model), dt),
        }
        if cell.kind == "train":
            batch["labels"] = _sds((b, s), I32)
        return batch
    if cell.kind == "decode":
        return {"tokens": _sds((b,), I32)}
    batch = {"tokens": _sds((b, s), I32)}
    if cell.kind == "train":
        batch["labels"] = _sds((b, s), I32)
    return batch


def decode_state_specs(cfg: ModelConfig, batch: int, s_max: int):
    """Abstract decode-state pytree (shapes only, via eval_shape)."""
    return jax.eval_shape(
        lambda: init_decode_state(cfg, batch, s_max, cfg.adtype))

"""granite-moe-3b-a800m [hf:ibm-granite]: 32L d_model=1536 24H (kv=8)
d_ff=512 (per-expert) vocab=49155, MoE 40 experts top-8 (assignment header;
the hf 1b-a400m card lists 32 — we follow the assigned 40).  Experts are
padded 40 -> 48 for the 16-way model axis (router masks the 8 pads).
Embeddings tied (granite style)."""

from ..models.model import ModelConfig
from .base import SKIP_LONG, ArchSpec, register

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    n_experts=40, n_experts_pad=48, top_k=8, d_ff_expert=512,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab=64, n_experts=5, n_experts_pad=8, top_k=2,
    d_ff_expert=32, tie_embeddings=True, dtype="float32",
)

register(ArchSpec("granite-moe-3b-a800m", CONFIG, SMOKE,
                  skips=dict(SKIP_LONG)))

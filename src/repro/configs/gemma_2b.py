"""gemma-2b [arXiv:2403.08295; hf]: 18L d_model=2048 8H MQA(kv=1)
head_dim=256 d_ff=16384 vocab=256000 — GeGLU, tied embeddings, sqrt(d)
embedding scale."""

from ..models.model import ModelConfig
from .base import SKIP_LONG, ArchSpec, register

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000,
    act="gelu", glu=True, tie_embeddings=True, embed_scale=True,
)

SMOKE = ModelConfig(
    name="gemma-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=64, act="gelu", glu=True, tie_embeddings=True,
    embed_scale=True, dtype="float32",
)

register(ArchSpec("gemma-2b", CONFIG, SMOKE, skips=dict(SKIP_LONG)))

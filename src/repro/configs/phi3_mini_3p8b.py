"""phi3-mini-3.8b [arXiv:2404.14219]: 32L d_model=3072 32H (kv=32)
head_dim=96 d_ff=8192 vocab=32064 — RoPE + SwiGLU."""

from ..models.model import ModelConfig
from .base import SKIP_LONG, ArchSpec, register

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064,
)

SMOKE = ModelConfig(
    name="phi3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=64, dtype="float32",
)

register(ArchSpec("phi3-mini-3.8b", CONFIG, SMOKE, skips=dict(SKIP_LONG)))

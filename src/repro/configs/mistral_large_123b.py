"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407]: 88L
d_model=12288 96H (kv=8) head_dim=128 d_ff=28672 vocab=32768."""

from ..models.model import ModelConfig
from .base import SKIP_LONG, ArchSpec, register

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=32768,
)

SMOKE = ModelConfig(
    name="mistral-large-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=64, dtype="float32",
)

register(ArchSpec("mistral-large-123b", CONFIG, SMOKE,
                  skips=dict(SKIP_LONG)))

"""EfficientNet-B0 configs: the standalone classifier and the VLM stem.

Two entry points, both running every MBConv block through the two-pass
fused ConvDK pipeline (``kernels.convdk_mbconv_fused``):

* ``efficientnet_b0()`` — the full B0 classifier config consumed by
  ``models.mbconv.efficientnet_b0_def`` / ``efficientnet_b0_apply``
  (`width_mult` scales it down to CI-sized instances with the exact B0
  topology).
* ``efficientnet_b0_vlm()`` — a VLM ``ModelConfig`` whose conv vision stem
  uses SE-equipped MBConv blocks (``vision_stem_arch="mbconv"``) instead of
  plain separable blocks, wiring the new subsystem into the multimodal
  model zoo.
"""

from __future__ import annotations

from ..models.mbconv import EFFNET_B0_STAGES, EffNetConfig
from ..models.model import ModelConfig

__all__ = ["EFFNET_B0_STAGES", "EffNetConfig", "efficientnet_b0",
           "efficientnet_b0_vlm"]


def efficientnet_b0(**overrides) -> EffNetConfig:
    """The canonical EfficientNet-B0 (224x224, 1000 classes) config."""
    return EffNetConfig(**overrides)


def efficientnet_b0_smoke(**overrides) -> EffNetConfig:
    """A CI-sized B0: same 16-block topology at 1/4 width."""
    overrides.setdefault("width_mult", 0.25)
    overrides.setdefault("num_classes", 10)
    return EffNetConfig(**overrides)


def efficientnet_b0_vlm(**overrides) -> ModelConfig:
    """A small VLM whose vision frontend is an MBConv (SE) stem."""
    defaults = dict(
        name="effnet-b0-vlm", family="vlm", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab=256,
        vision_stem=True, vision_stem_arch="mbconv", vision_stem_c0=16,
        vision_stem_blocks=2,
    )
    defaults.update(overrides)
    return ModelConfig(**defaults)

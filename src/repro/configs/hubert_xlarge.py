"""hubert-xlarge [arXiv:2106.07447]: 48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504.  Encoder-only audio backbone; the conv frame frontend is a STUB —
input_specs provide precomputed frame embeddings (B, S, 1280) per assignment.
Training objective: masked-unit prediction over the 504 k-means units."""

from ..models.model import ModelConfig
from .base import SKIP_ENC, ArchSpec, register

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504,
    act="gelu", glu=False,          # HuBERT uses plain GELU MLPs
)

SMOKE = ModelConfig(
    name="hubert-smoke", family="encoder",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=32, act="gelu", glu=False, dtype="float32",
)

register(ArchSpec("hubert-xlarge", CONFIG, SMOKE, skips=dict(SKIP_ENC)))

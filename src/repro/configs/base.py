"""Architecture registry: full configs, reduced smoke configs, input shapes
and per-cell skip rules for the 10 assigned architectures.

Shape cells (assignment):
  train_4k     seq 4,096   global_batch 256   (train_step)
  prefill_32k  seq 32,768  global_batch 32    (serve prefill)
  decode_32k   seq 32,768  global_batch 128   (serve_step, 1 new token)
  long_500k    seq 524,288 global_batch 1     (serve_step, sub-quadratic only)

Skips (DESIGN.md §Arch-applicability):
  * encoder-only (hubert): no autoregressive step -> decode_32k & long_500k skip
  * pure full-attention archs: long_500k skip (O(S^2) attention)
  * SSM / hybrid: all four cells run (constant-state or windowed decode)
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional

from ..models.model import ModelConfig

# one warning per deprecation category per process — tests reset this set
# to re-arm a category
_WARNED: set = set()


def _warn_once(key: str, msg: str) -> None:
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(msg, DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class SchedulePin:
    """One object pinning any subset of the solved schedule axes.

    Collapses the per-axis pins that used to be scattered across
    ``ConvKernelConfig`` (``fused_mbconv``/``mbconv_mode``/``residency``/
    ``collective``/``shard_fused``) plus the new **layout** axis into a
    single value the block entries take as ``pin=``.  Every field is
    optional — ``None`` leaves that axis to the solver (or to the
    config's legacy per-axis field, which still works behind a
    deprecation shim):

    * ``fused``: run the fused ConvDK pipeline (family-specific default:
      ``fused_separable`` / ``fused_mbconv``);
    * ``mode``: MBConv pass-2 DW source ("retain" | "recompute");
    * ``residency``: input-staging mode ("resident" | "strip_dma" |
      "strip_dma_db");
    * ``collective``: projection-reduction layout under a model-sharded
      mesh ("ring_allreduce" | "psum_scatter");
    * ``layout``: the OUTPUT layout to leave the block in ("replicated" |
      "model_sharded") — sugar over ``collective`` ("model_sharded"
      requires the psum_scatter exit, "replicated" the ring); pinning
      both to conflicting values raises;
    * ``shard``: route through the ``shard_map`` wrappers when a mesh is
      handed in (``shard_fused``);
    * ``act``: the block family's main activation ("silu" | "relu" |
      "hard_swish") — a first-class family axis: EfficientNet blocks run
      silu, MobileNet-V3 mixes relu and hard_swish per stage;
    * ``se``: squeeze-excite presence ("on" | "off") — se=off blocks skip
      the pass-1 pool, the pass-2 gate and their psums/VMEM entirely
      (MobileNet-V3's no-SE blocks must not pay SE bytes; the Fused-MBConv
      family is always se=off).
    """

    fused: Optional[bool] = None
    mode: Optional[str] = None
    residency: Optional[str] = None
    collective: Optional[str] = None
    layout: Optional[str] = None
    shard: Optional[bool] = None
    act: Optional[str] = None
    se: Optional[str] = None

    def merged_over(self, other: "SchedulePin") -> "SchedulePin":
        """This pin's explicit fields, falling back to ``other``'s."""
        return SchedulePin(*(
            a if a is not None else b
            for a, b in zip(dataclasses.astuple(self),
                            dataclasses.astuple(other))))

    @property
    def resolved_collective(self) -> Optional[str]:
        """The collective the (collective, layout) pair pins, if any —
        the layout axis is sugar: a "model_sharded" exit IS the
        psum_scatter exit, a pinned "replicated" exit the ring."""
        from_layout = {None: None, "replicated": "ring_allreduce",
                       "model_sharded": "psum_scatter"}[self.layout]
        if (self.collective is not None and from_layout is not None
                and self.collective != from_layout):
            raise ValueError(
                f"pin conflict: collective={self.collective!r} vs "
                f"layout={self.layout!r} (which implies {from_layout!r})")
        return self.collective if self.collective is not None else from_layout


# ConvKernelConfig fields that SchedulePin supersedes (the deprecation
# shim in set_kernel_config warns once when they are set directly)
_LEGACY_PIN_FIELDS = ("fused_separable", "fused_mbconv", "mbconv_mode",
                      "residency", "collective", "shard_fused",
                      "act", "se")

# The solved/priced values of the two family axes.  ``act`` names the
# family's MAIN activation (expand/DW for MBConv, the dense conv for
# Fused-MBConv); the SE-internal squeeze/gate acts are family facts the
# model layer states, not pinnable axes.
ACT_MODES = ("silu", "relu", "hard_swish")
SE_MODES = ("on", "off")

# block families the pin resolver (and the kernel stack) knows about:
# the two-pass SE-aware MBConv, the single-pass separable, and the
# single-pass Fused-MBConv (dense expand+DW collapse, always se=off)
BLOCK_FAMILIES = ("mbconv", "separable", "fusedmb")


def resolve_pin(cfg: "ConvKernelConfig", pin: Optional[SchedulePin] = None,
                family: str = "mbconv") -> SchedulePin:
    """The effective pin for one block call: explicit ``pin`` fields win
    over ``cfg.pin`` fields, which win over the legacy per-axis config
    fields (``family`` picks which fused toggle backs ``fused``)."""
    assert family in BLOCK_FAMILIES, family
    base = cfg.pin if cfg.pin is not None else SchedulePin()
    if pin is not None:
        base = pin.merged_over(base)
    legacy = SchedulePin(
        fused=(cfg.fused_separable if family == "separable"
               else cfg.fused_mbconv),
        mode=cfg.mbconv_mode, residency=cfg.residency,
        collective=cfg.collective, shard=cfg.shard_fused,
        act=cfg.act, se=cfg.se)
    resolved = base.merged_over(legacy)
    if resolved.act is not None and resolved.act not in ACT_MODES:
        raise ValueError(
            f"act must be one of {ACT_MODES}, got {resolved.act!r}")
    if resolved.se is not None and resolved.se not in SE_MODES:
        raise ValueError(
            f"se must be one of {SE_MODES}, got {resolved.se!r}")
    if family == "fusedmb" and resolved.se == "on":
        raise ValueError(
            "the fusedmb family has no SE stage: se='on' cannot be pinned "
            "on a Fused-MBConv block")
    return resolved


@dataclasses.dataclass(frozen=True)
class ConvKernelConfig:
    """Routing policy for depthwise-separable conv blocks.

    ``fused_separable`` routes ``models.common.separable_block`` through the
    single-pass ``kernels.convdk_fused_separable`` (in-kernel strip staging,
    DW+PW in one VMEM residency); off = the staged two-kernel pipeline.
    ``fused_mbconv`` routes ``models.mbconv.mbconv_block`` through the
    TWO-PASS fused ``kernels.convdk_mbconv_fused`` (SE pool accumulated
    on-chip in pass 1, SE gate folded into the projection in pass 2); off =
    the staged DW->HBM->SE->PW baseline.
    ``mbconv_mode`` pins the pass-2 DW source ("retain" | "recompute");
    None lets the autotuner pick per layer shape from the traffic model.
    ``collective`` pins the MBConv projection-reduction layout under a
    model-sharded mesh ("ring_allreduce" | "psum_scatter" — scatter
    leaves the block output sharded on c_out and halves the wire words);
    None lets the autotuner solve it per layer shape (ring wherever
    scatter is not runnable).
    ``residency`` pins the input-staging mode of the fused kernels
    ("resident" | "strip_dma" | "strip_dma_db", see ``kernels.staging``);
    None lets the autotuner solve it per layer shape (or falls back to the
    kernels' double-buffered default when ``autotune`` is off).
    ``autotune`` picks ``tile_h`` (plus the MBConv mode and the residency)
    per layer shape from the HBM traffic model (``core.autotune``); off =
    the fixed ``tile_h`` default.
    ``shard_fused`` routes the fused kernels through their ``shard_map``
    wrappers (``kernels.convdk_sharded``: batch on "data", the channel
    grid on "model", the MBConv SE pool psum'd across the model axis)
    whenever the block wrapper is handed a mesh whose axes divide the
    grid; off = ignore the mesh and run the single-device kernels (the
    staged baselines always run single-device — GSPMD owns them).
    ``act`` / ``se`` pin the family axes process-wide ("silu" | "relu" |
    "hard_swish"; "on" | "off") — None leaves them to the block spec (the
    model layer states them per block: EfficientNet-B0 is act=silu/se=on
    throughout, MobileNet-V3 mixes per stage).  Like the other per-axis
    fields they are superseded by ``pin=SchedulePin(act=..., se=...)``.
    ``interpret`` forces Pallas interpret mode (None = auto: interpret on
    CPU backends, compiled Mosaic on TPU).
    """

    fused_separable: bool = True
    fused_mbconv: bool = True
    mbconv_mode: Optional[str] = None
    residency: Optional[str] = None
    collective: Optional[str] = None
    autotune: bool = True
    shard_fused: bool = True
    tile_h: int = 8
    interpret: Optional[bool] = None
    pin: Optional[SchedulePin] = None
    act: Optional[str] = None
    se: Optional[str] = None


_KERNEL_CONFIG = ConvKernelConfig()


def kernel_config() -> ConvKernelConfig:
    """The process-wide conv-kernel routing config."""
    return _KERNEL_CONFIG


def set_kernel_config(**overrides) -> ConvKernelConfig:
    """Replace fields of the global conv-kernel config (returns the new one).

    Example: ``set_kernel_config(fused_separable=False)`` to A/B the staged
    pipeline in benchmarks.

    Setting the per-axis schedule pins directly (``mbconv_mode``,
    ``residency``, ``collective``, the fused/shard toggles) still works
    but is deprecated: pass ``pin=SchedulePin(...)`` instead — one object
    carrying every pinned axis, including the new layout axis.
    """
    global _KERNEL_CONFIG
    legacy = sorted(set(overrides) & set(_LEGACY_PIN_FIELDS))
    if legacy:
        _warn_once(
            "set_kernel_config_axis_pins",
            f"set_kernel_config({', '.join(legacy)}=...) pins schedule "
            "axes through the legacy per-axis fields; pass "
            "pin=SchedulePin(...) instead (one object, all axes)")
    _KERNEL_CONFIG = dataclasses.replace(_KERNEL_CONFIG, **overrides)
    return _KERNEL_CONFIG


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    config: ModelConfig
    smoke: ModelConfig
    skips: Dict[str, str]  # shape name -> reason

    def applicable(self, shape: str) -> bool:
        return shape not in self.skips

    def cells(self):
        return [(s, None if self.applicable(s) else self.skips[s])
                for s in SHAPES]


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    assert spec.arch_id not in _REGISTRY, spec.arch_id
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    return _REGISTRY[arch_id]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


SKIP_LONG = {"long_500k": "full quadratic attention; 524k decode requires "
                          "sub-quadratic sequence mixing"}
SKIP_ENC = {"decode_32k": "encoder-only: no autoregressive decode step",
            "long_500k": "encoder-only: no autoregressive decode step"}


def _ensure_loaded():
    # import the per-arch modules exactly once (they self-register)
    if _REGISTRY:
        return
    from . import (  # noqa: F401
        deepseek_v2_236b, gemma_2b, granite_moe_3b_a800m, hubert_xlarge,
        llava_next_34b, mamba2_2p7b, mistral_large_123b, phi3_mini_3p8b,
        qwen1p5_4b, recurrentgemma_9b,
    )

"""mamba2-2.7b [arXiv:2405.21060]: 64L d_model=2560 attn-free, vocab=50280,
ssm_state=128 — SSD (state-space duality), d_inner = 2*d = 5120, 80 heads of
dim 64, d_conv 4.  The causal depthwise conv stem is the ConvDK hot-spot.

All four cells run: decode is a constant-size state recurrence."""

from ..models.model import ModelConfig
from .base import ArchSpec, register

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, vocab=50280,
    d_state=128, d_conv=4, expand=2, ssd_chunk=256,
    n_heads=80, n_kv_heads=80, head_dim=64,  # SSD heads (d_inner/64)
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, vocab=64, d_state=16, d_conv=4, expand=2,
    ssd_chunk=16, n_heads=2, n_kv_heads=2, head_dim=64, dtype="float32",
)

register(ArchSpec("mamba2-2.7b", CONFIG, SMOKE, skips={}))

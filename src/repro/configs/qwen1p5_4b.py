"""qwen1.5-4b [hf:Qwen]: 40L d_model=2560 20H (kv=20) head_dim=128
d_ff=6912 vocab=151936 — QKV bias."""

from ..models.model import ModelConfig
from .base import SKIP_LONG, ArchSpec, register

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, vocab=151936, qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=64, qkv_bias=True, dtype="float32",
)

register(ArchSpec("qwen1.5-4b", CONFIG, SMOKE, skips=dict(SKIP_LONG)))

"""recurrentgemma-9b [arXiv:2402.19427]: 38L d_model=4096 16H MQA(kv=1)
head_dim=256 d_ff=12288 vocab=256000 — Griffin: RG-LRU + 2048-window local
attention, pattern (R, R, A); lru width 4096.  38 = 12 x (R,R,A) + (R,R)
remainder (scan over 12 pattern blocks + 2 unrolled layers).

All four shape cells run: decode state is O(1) per recurrent layer and the
window cache is a 2048-slot ring buffer, so long_500k is linear."""

from ..models.model import ModelConfig
from .base import ArchSpec, register

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    window=2048, pattern=("R", "R", "A"), lru_width=4096,
    act="gelu", glu=True, tie_embeddings=True, embed_scale=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=64, window=8, pattern=("R", "R", "A"), lru_width=64,
    act="gelu", glu=True, tie_embeddings=True, embed_scale=True,
    dtype="float32",
)

register(ArchSpec("recurrentgemma-9b", CONFIG, SMOKE, skips={}))

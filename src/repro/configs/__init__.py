from .base import SHAPES, ArchSpec, ShapeCell, get_arch, list_archs
from .efficientnet_b0 import (
    efficientnet_b0,
    efficientnet_b0_smoke,
    efficientnet_b0_vlm,
)
from .specs import decode_state_specs, input_specs

__all__ = ["SHAPES", "ArchSpec", "ShapeCell", "get_arch", "list_archs",
           "input_specs", "decode_state_specs", "efficientnet_b0",
           "efficientnet_b0_smoke", "efficientnet_b0_vlm"]

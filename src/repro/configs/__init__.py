from .base import SHAPES, ArchSpec, ShapeCell, get_arch, list_archs
from .specs import decode_state_specs, input_specs

__all__ = ["SHAPES", "ArchSpec", "ShapeCell", "get_arch", "list_archs",
           "input_specs", "decode_state_specs"]

from .base import (
    SHAPES,
    ArchSpec,
    SchedulePin,
    ShapeCell,
    get_arch,
    kernel_config,
    list_archs,
    resolve_pin,
    set_kernel_config,
)
from .efficientnet_b0 import (
    efficientnet_b0,
    efficientnet_b0_smoke,
    efficientnet_b0_vlm,
)
from .specs import decode_state_specs, input_specs

__all__ = ["SHAPES", "ArchSpec", "SchedulePin", "ShapeCell", "get_arch",
           "kernel_config", "list_archs", "resolve_pin",
           "set_kernel_config", "input_specs", "decode_state_specs",
           "efficientnet_b0", "efficientnet_b0_smoke",
           "efficientnet_b0_vlm"]

"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all in seconds-per-step on the
TARGET hardware (TPU v5e):

    compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
    collective = collective_bytes_per_device / link_bw    (~50 GB/s/link ICI)

``cost_analysis()`` provides per-device FLOPs and bytes; collective bytes
are NOT in cost_analysis, so ``collective_bytes`` parses the post-SPMD HLO
text and sums the output-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.  The dominant term is the
bottleneck the §Perf loop iterates on; MODEL_FLOPS / HLO_FLOPs measures how
much compiled compute is "useful" (catches remat/redundancy waste).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW_PER_LINK = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "f32[256,1024]{1,0}" or "bf16[8,128]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind from post-SPMD HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # "%x = f32[..]{..} all-reduce(" — kind appears as the op name
            marker = f" {kind}("
            if marker in stripped or stripped.startswith(f"{kind}("):
                lhs = stripped.split(marker)[0]
                # shape expression sits between '=' and the op name
                if "=" in lhs:
                    lhs = lhs.split("=", 1)[1]
                out[kind] += _shape_bytes(lhs)
                break
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    ici_links: int = 2          # 2D torus: >=2 links usable per sharded axis
    model_flops: Optional[float] = None   # 6*N*D (or 6*N_active*D)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / (ICI_BW_PER_LINK
                                                   * self.ici_links)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> Optional[float]:
        if not self.model_flops:
            return None
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total else None

    @property
    def roofline_fraction(self) -> float:
        """How close the dominant term pins us to the ridge: the fraction of
        the bound time that is useful compute."""
        if self.bound_time == 0:
            return 0.0
        return self.t_compute / self.bound_time

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "n_devices": self.n_devices,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_estimate(n_params_active: int, tokens: int,
                         kind: str = "train") -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens

"""Optimizers: AdamW and Adafactor-style factored second moment, with
global-norm clipping, cosine LR schedule, and optional int8 gradient
compression with error feedback.

No optax dependency — pure JAX, pytree-structured states, so optimizer
state shapes flow through ``jax.eval_shape`` for the dry-run and through the
sharded checkpointer unchanged (optimizer moments inherit the parameter's
NamedSharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    factored: bool = False        # Adafactor-style V for >=2D params
    compress_grads: bool = False  # int8 + error feedback


def cosine_lr(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * jnp.minimum(warm, decayed)


class OptState(NamedTuple):
    step: jax.Array
    m: Any                 # first moment (pytree)
    v: Any                 # second moment (pytree; factored tuples when on)
    err: Any               # compression error-feedback buffers (or None tree)


def _v_init(p: jax.Array, factored: bool):
    if factored and p.ndim >= 2:
        return (jnp.zeros(p.shape[:-1], jnp.float32),
                jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
    return jnp.zeros(p.shape, jnp.float32)


def _v_update(v, g2, b2: float, factored: bool):
    if factored and isinstance(v, tuple):
        vr, vc = v
        vr = b2 * vr + (1 - b2) * g2.mean(-1)
        vc = b2 * vc + (1 - b2) * g2.mean(-2)
        return (vr, vc)
    return b2 * v + (1 - b2) * g2


def _v_rsqrt(v, g: jax.Array, eps: float, factored: bool):
    if factored and isinstance(v, tuple):
        vr, vc = v
        # rank-1 reconstruction: V ~ vr vc^T / mean(vr)
        denom = jnp.maximum(vr.mean(-1, keepdims=True), 1e-30)
        vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
        return g * jax.lax.rsqrt(vhat + eps)
    return g * jax.lax.rsqrt(v + eps)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------

def compress_int8(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (g + err) to int8 with a per-tensor scale; returns
    (q, scale, new_err).  new_err carries the quantization residual forward
    (error feedback), so the bias vanishes over steps."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.abs(g32).max(), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g32 - deq


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# optimizer factory
# ---------------------------------------------------------------------------

def make_optimizer(cfg: OptimConfig):
    """Returns (init_fn, update_fn).

    update_fn(grads, state, params) -> (new_params, new_state, metrics)
    """

    def init_fn(params) -> OptState:
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        v = jax.tree.map(lambda p: _v_init(p, cfg.factored), params)
        err = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
               if cfg.compress_grads else None)
        return OptState(step=jnp.zeros((), jnp.int32), m=m, v=v, err=err)

    def update_fn(grads, state: OptState, params):
        metrics = {}
        # --- optional int8 compression with error feedback ---
        if cfg.compress_grads:
            packed = jax.tree.map(compress_int8, grads, state.err)
            leaves, treedef = jax.tree.flatten(
                packed, is_leaf=lambda x: isinstance(x, tuple)
                and len(x) == 3 and hasattr(x[0], "dtype"))
            grads = jax.tree.unflatten(
                treedef, [decompress_int8(q, s) for (q, s, _) in leaves])
            new_err = jax.tree.unflatten(treedef, [e for (_, _, e) in leaves])
        else:
            new_err = None

        # --- clip by global norm ---
        gnorm = global_norm(grads)
        metrics["grad_norm"] = gnorm
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        step = state.step + 1
        lr = cosine_lr(cfg, step)
        metrics["lr"] = lr
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            m_new = cfg.b1 * m + (1 - cfg.b1) * g
            v_new = _v_update(v, jnp.square(g), cfg.b2, cfg.factored)
            mh = m_new / bc1
            if cfg.factored and isinstance(v_new, tuple):
                vh = (v_new[0] / bc2, v_new[1] / bc2)
            else:
                vh = v_new / bc2
            delta = _v_rsqrt(vh, mh, cfg.eps, cfg.factored)
            p_new = (p.astype(jnp.float32)
                     - lr * (delta + cfg.weight_decay * p.astype(jnp.float32)))
            return p_new.astype(p.dtype), m_new, v_new

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = jax.tree.flatten(
            state.v, is_leaf=lambda x: isinstance(x, tuple))[0]
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_params, OptState(step=step, m=new_m, v=new_v,
                                    err=new_err), metrics

    return init_fn, update_fn

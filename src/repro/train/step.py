"""Train / serve step builders — the functions the launcher jits and the
dry-run lowers.

``make_train_step`` closes over the model config and optimizer; supports
microbatch gradient accumulation (a ``lax.scan`` over microbatches, grads
accumulated in fp32) so the global batch never has to fit activations at
once.  ``make_serve_step`` is the single-token decode step (greedy or
sampled) the ``decode_*``/``long_*`` cells lower.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.common import softmax_cross_entropy
from ..models.model import ModelConfig, decode_step, forward
from .optim import OptimConfig, make_optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optim: OptimConfig = OptimConfig()
    microbatches: int = 1


def compute_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    labels = batch["labels"]
    if labels.shape[1] == logits.shape[1]:
        # next-token shift for LM families; encoder predicts in place
        if cfg.family == "encoder":
            return softmax_cross_entropy(logits, labels)
        return softmax_cross_entropy(logits[:, :-1], labels[:, 1:])
    # vlm with text-only labels: image positions carry no loss
    n_img = logits.shape[1] - labels.shape[1]
    logits = logits[:, n_img:]
    return softmax_cross_entropy(logits[:, :-1], labels[:, 1:])


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    init_opt, update = make_optimizer(tcfg.optim)
    n_micro = tcfg.microbatches

    def train_step(params, opt_state, batch):
        if n_micro <= 1:
            loss, grads = jax.value_and_grad(compute_loss)(params, batch, cfg)
        else:
            def micro(i, carry):
                acc, loss_acc = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // n_micro),
                        x.shape[0] // n_micro, axis=0),
                    batch)
                l, g = jax.value_and_grad(compute_loss)(params, mb, cfg)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n_micro, acc, g)
                return acc, loss_acc + l / n_micro

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, loss = jax.lax.fori_loop(
                0, n_micro, lambda i, c: micro(i, c), (zeros, 0.0))
        new_params, new_opt, metrics = update(grads, opt_state, params)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return init_opt, train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits = forward(params, batch, cfg)
        return jnp.argmax(logits[:, -1], axis=-1)
    return prefill_step


def make_serve_step(cfg: ModelConfig, greedy: bool = True):
    def serve_step(params, state, tokens, rng: Optional[jax.Array] = None):
        logits, new_state = decode_step(params, state, {"tokens": tokens}, cfg)
        if greedy or rng is None:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rng, logits).astype(jnp.int32)
        return nxt, new_state
    return serve_step

"""Sharded, mesh-agnostic checkpointing with elastic restore.

Format: one directory per step::

    ckpt_dir/step_000123/
      manifest.json            # leaf index: name -> shape/dtype/file, extras
      arrays/<leaf-name>.npy   # one file per pytree leaf

Leaves are saved as full (unsharded) host arrays — mesh-AGNOSTIC by
construction, so a checkpoint written from a (16, 16) mesh restores onto a
(2, 16, 16) mesh (or a single CPU) unchanged: ``restore`` re-places every
leaf with the *target* mesh's NamedSharding (elastic scaling).  For
multi-host deployment the same manifest format extends to per-shard files
keyed by shard index; the single-controller container exercises the
full-array path.

Fault-tolerance contract used by the train loop:
* atomic publish — arrays are written into a tmp dir, renamed at the end;
  a crash mid-save never corrupts the latest checkpoint;
* ``latest_step`` scans for the newest complete manifest (restart picks it
  up after a node failure);
* SIGTERM triggers an emergency save at the next step boundary (see
  ``launch/train.py``).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_names(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[name] = leaf
    return out


def save(ckpt_dir: str, step: int, tree: Any,
         extras: Optional[Dict[str, Any]] = None) -> str:
    """Atomically save a pytree (params / opt state / data state bundle)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_save_")
    arrays_dir = os.path.join(tmp, "arrays")
    os.makedirs(arrays_dir)

    manifest = {"step": step, "leaves": {}, "extras": extras or {}}
    for name, leaf in _leaf_names(tree).items():
        if leaf is None:
            manifest["leaves"][name] = {"none": True}
            continue
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        np.save(os.path.join(arrays_dir, fname), arr)
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype), "file": fname}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d[5:]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like``; re-place onto ``shardings``
    (a parallel pytree of NamedSharding) when given — the elastic path."""
    base = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)

    names = _leaf_names(like)
    shard_map_ = _leaf_names(shardings) if shardings is not None else {}
    loaded = {}
    for name, leaf in names.items():
        entry = manifest["leaves"].get(name)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        if entry.get("none"):
            loaded[name] = None
            continue
        arr = np.load(os.path.join(base, "arrays", entry["file"]))
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"{name}: ckpt shape {arr.shape} != {want}")
        sh = shard_map_.get(name)
        loaded[name] = (jax.device_put(arr, sh) if sh is not None
                        else jax.numpy.asarray(arr))

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, _ in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append(loaded[name])
    tree = jax.tree_util.tree_unflatten(jax.tree.structure(like), out)
    return tree, manifest["extras"]

"""Training launcher with fault tolerance.

Features exercised end-to-end (and covered by tests/test_train_integration):

* sharded params/optimizer via the logical-rule table (any mesh),
* deterministic resumable data pipeline,
* periodic + SIGTERM-triggered checkpointing (atomic publish),
* automatic restore-from-latest on start (crash/preemption restart),
* elastic restore: a checkpoint from one mesh restores onto another,
* step-retry loop: a transient step failure (e.g. a flaky host) is retried
  up to ``max_retries`` times before aborting (straggler/failure hygiene).

Run: PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
         --steps 20 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import signal
import time
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..data.pipeline import DataConfig, DataState, make_batch
from ..models.model import model_def
from ..models.param import logical_axes, materialize
from ..sharding import tree_shardings
from ..train import checkpoint as ckpt
from ..train.optim import OptimConfig
from ..train.step import TrainConfig, make_train_step
from .mesh import make_local_mesh


class Trainer:
    def __init__(self, cfg, tcfg: TrainConfig, data_cfg: DataConfig,
                 ckpt_dir: Optional[str] = None, mesh=None, seed: int = 0):
        self.cfg, self.tcfg, self.data_cfg = cfg, tcfg, data_cfg
        self.ckpt_dir = ckpt_dir
        self.mesh = mesh
        self._sigterm = False
        init_opt, train_step = make_train_step(cfg, tcfg)

        if mesh is not None:
            pdefs = model_def(cfg)
            p_axes = logical_axes(pdefs)
            params = materialize(pdefs, jax.random.key(seed))
            p_sh = tree_shardings(
                p_axes, jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
                mesh)
            self.params = jax.device_put(params, p_sh)
            self.p_sh = p_sh
            self.opt_state = jax.jit(init_opt)(self.params)
            self.train_step = jax.jit(train_step, donate_argnums=(0, 1))
        else:
            self.params = materialize(model_def(cfg), jax.random.key(seed))
            self.p_sh = None
            self.opt_state = init_opt(self.params)
            self.train_step = jax.jit(train_step, donate_argnums=(0, 1))

        self.data_state = DataState(seed=data_cfg.seed, step=0)
        self.step = 0

    # -- fault tolerance ----------------------------------------------------
    def install_signal_handler(self):
        def _handler(signum, frame):
            self._sigterm = True
        signal.signal(signal.SIGTERM, _handler)

    def maybe_restore(self) -> bool:
        if not self.ckpt_dir:
            return False
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is None:
            return False
        bundle = {"params": self.params, "opt": self.opt_state}
        restored, extras = ckpt.restore(
            self.ckpt_dir, latest, bundle,
            shardings={"params": self.p_sh, "opt": None}
            if self.p_sh is not None else None)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.data_state = DataState.from_dict(extras["data_state"])
        self.step = latest
        return True

    def save(self):
        if not self.ckpt_dir:
            return
        ckpt.save(self.ckpt_dir, self.step,
                  {"params": self.params, "opt": self.opt_state},
                  extras={"data_state": self.data_state.to_dict()})

    # -- loop -----------------------------------------------------------------
    def run(self, steps: int, ckpt_every: int = 50, max_retries: int = 2,
            log_every: int = 10):
        losses = []
        while self.step < steps:
            batch_np, next_data_state = make_batch(self.data_cfg,
                                                   self.data_state)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            for attempt in range(max_retries + 1):
                try:
                    self.params, self.opt_state, metrics = self.train_step(
                        self.params, self.opt_state, batch)
                    break
                except Exception:           # noqa: BLE001 — transient retry
                    if attempt == max_retries:
                        self.save()          # emergency checkpoint, then die
                        raise
                    time.sleep(0.1)
            self.data_state = next_data_state
            self.step += 1
            losses.append(float(metrics["loss"]))
            if log_every and self.step % log_every == 0:
                print(f"step {self.step}: loss {losses[-1]:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
            if (ckpt_every and self.step % ckpt_every == 0) or self._sigterm:
                self.save()
                if self._sigterm:
                    print("SIGTERM: emergency checkpoint saved", flush=True)
                    return losses
        self.save()
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, family=cfg.family,
                      d_model=cfg.d_model, n_img_tokens=cfg.n_img_tokens)
    tcfg = TrainConfig(optim=OptimConfig(peak_lr=1e-3, warmup_steps=10,
                                         decay_steps=args.steps),
                       microbatches=args.microbatches)
    tr = Trainer(cfg, tcfg, dcfg, ckpt_dir=args.ckpt_dir,
                 mesh=make_local_mesh())
    tr.install_signal_handler()
    if tr.maybe_restore():
        print(f"restored from step {tr.step}", flush=True)
    losses = tr.run(args.steps, ckpt_every=args.ckpt_every)
    print(f"final loss: {losses[-1]:.4f} (from {losses[0]:.4f})", flush=True)


if __name__ == "__main__":
    main()

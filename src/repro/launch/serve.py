"""Serving launcher: batched generation with the per-family cache engine.

Run: PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
         --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_arch
from ..models.model import model_def
from ..models.param import materialize
from ..serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    if cfg.family == "encoder":
        raise SystemExit(f"{args.arch} is encoder-only: no decode")

    params = materialize(model_def(cfg), jax.random.key(0))
    engine = Engine(cfg, params,
                    ServeConfig(max_new_tokens=args.new_tokens))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))

    t0 = time.time()
    out = engine.generate(prompts.astype(np.int32))
    dt = time.time() - t0
    tput = args.batch * args.new_tokens / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tput:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()

"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and smoke tests must keep seeing 1 device.

Mesh construction goes through ``repro.compat`` so the module imports (and
the suite collects) on JAX versions without ``jax.sharding.AxisType``.
"""

from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has — used by examples and integration tests."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))

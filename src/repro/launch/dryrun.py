import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs abstract params / optimizer state / inputs
     (ShapeDtypeStructs — zero allocation),
  3. jits the train_step / prefill_step / serve_step with NamedShardings
     from the logical-axis rule table,
  4. ``.lower().compile()`` — any sharding mismatch, non-divisible dim, or
     unsupported collective fails HERE, which is the point,
  5. records memory_analysis / cost_analysis / per-collective bytes into a
     JSON blob consumed by EXPERIMENTS.md §Dry-run and §Roofline.

Roofline probes: XLA's cost model counts a ``while`` (scan) body ONCE,
ignoring the trip count (verified by probe, DESIGN.md §Risks).  The
scan-over-layers program is therefore lowered a second and third time at
UNROLLED depth d1/d2 (with unchunked attention so no intra-layer scans
remain); per-layer FLOPs/bytes/collective-bytes are the (d2 - d1) delta and
the full-depth roofline is ``base + L * per_layer``.  Memory comes from the
full scanned program (loop temp accounting is correct there).

Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
          --shape train_4k [--multi-pod] [--out results/dryrun]
      PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs import SHAPES, get_arch, input_specs, list_archs
from ..configs.base import ArchSpec, ShapeCell
from ..configs.specs import decode_state_specs
from ..models.model import ModelConfig, decode_state_axes, model_def
from ..models.param import abstract, count_params, logical_axes
from ..roofline.analysis import (
    RooflineTerms, collective_bytes, model_flops_estimate,
)
from ..sharding import spec_for, tree_shardings
from ..train.optim import OptState
from ..train.step import (
    TrainConfig, make_prefill_step, make_serve_step, make_train_step,
)
from .mesh import make_production_mesh


def _batch_shardings(batch_abs: Dict[str, Any], mesh) -> Dict[str, Any]:
    out = {}
    for k, v in batch_abs.items():
        logical = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, spec_for(logical, v.shape, mesh))
    return out


def _opt_axes(param_axes) -> OptState:
    return OptState(step=(), m=param_axes, v=param_axes, err=None)


def _active_params(cfg: ModelConfig, n_params: int) -> int:
    """Active params per token for MODEL_FLOPS (MoE: only top-k experts)."""
    if cfg.family != "moe":
        return n_params
    per_expert = 3 * cfg.d_model * cfg.d_ff_expert
    n_moe_layers = cfg.n_layers - cfg.n_dense_prefix
    inactive = (cfg.n_experts - cfg.top_k) * per_expert * n_moe_layers
    return n_params - inactive


def _lower_cell(cfg: ModelConfig, spec: ArchSpec, cell: ShapeCell, mesh):
    """Build abstract inputs + shardings and return the lowered step."""
    pdefs = model_def(cfg)
    params_abs = abstract(pdefs, param_dtype=jnp.dtype(cfg.param_dtype))
    p_axes = logical_axes(pdefs)
    p_sh = tree_shardings(p_axes, params_abs, mesh)
    batch_abs = input_specs(spec, cell, cfg)
    b_sh = _batch_shardings(batch_abs, mesh)

    if cell.kind == "train":
        init_opt, train_step = make_train_step(cfg, TrainConfig())
        opt_abs = jax.eval_shape(init_opt, params_abs)
        o_sh = tree_shardings(_opt_axes(p_axes), opt_abs, mesh)
        fn = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        return fn.lower(params_abs, opt_abs, batch_abs)
    if cell.kind == "prefill":
        fn = jax.jit(make_prefill_step(cfg), in_shardings=(p_sh, b_sh))
        return fn.lower(params_abs, batch_abs)
    state_abs = decode_state_specs(cfg, cell.global_batch, cell.seq_len)
    s_sh = tree_shardings(decode_state_axes(cfg), state_abs, mesh)
    fn = jax.jit(make_serve_step(cfg),
                 in_shardings=(p_sh, s_sh, b_sh["tokens"]),
                 out_shardings=(None, s_sh), donate_argnums=(1,))
    return fn.lower(params_abs, state_abs, batch_abs["tokens"])


def _cost_of(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
        "coll_by_kind": coll,
    }


def _probe_depths(cfg: ModelConfig) -> Tuple[ModelConfig, ModelConfig,
                                             float, float, float]:
    """Two reduced unrolled configs + (units1, units2, full_units)."""
    # probes unroll layers AND disable chunked attention (q_chunk sentinel)
    # so no intra-layer scan hides FLOPs from the cost model.
    big = 1 << 30
    if cfg.family == "hybrid":
        pat = cfg.pattern or ("R", "R", "A")
        blk = len(pat)
        full_units = cfg.n_layers / blk          # blocks incl. fractional rem
        c1 = dataclasses.replace(cfg, n_layers=blk, scan_layers=False,
                                 q_chunk=big)
        c2 = dataclasses.replace(cfg, n_layers=2 * blk, scan_layers=False,
                                 q_chunk=big)
        return c1, c2, 1.0, 2.0, full_units
    pre = cfg.n_dense_prefix
    c1 = dataclasses.replace(cfg, n_layers=pre + 1, scan_layers=False,
                             q_chunk=big)
    c2 = dataclasses.replace(cfg, n_layers=pre + 3, scan_layers=False,
                             q_chunk=big)
    return c1, c2, 1.0, 3.0, float(cfg.n_layers - pre)


def roofline_probe(spec: ArchSpec, cell: ShapeCell, mesh) -> Dict[str, Any]:
    """Depth-extrapolated per-device roofline costs for the full model."""
    cfg = spec.config
    c1, c2, u1, u2, full_u = _probe_depths(cfg)
    costs = []
    for c in (c1, c2):
        lowered = _lower_cell(c, spec, cell, mesh)
        costs.append(_cost_of(lowered.compile()))
    per_unit = {k: (costs[1][k] - costs[0][k]) / (u2 - u1)
                for k in ("flops", "bytes", "coll")}
    base = {k: costs[0][k] - u1 * per_unit[k] for k in per_unit}
    full = {k: base[k] + full_u * per_unit[k] for k in per_unit}
    return {
        "probe_depths": [c1.n_layers, c2.n_layers],
        "per_unit": per_unit, "base": base, "full": full,
        "probe_coll_by_kind": costs[1]["coll_by_kind"],
    }


def dryrun_cell(arch_id: str, shape_name: str, multi_pod: bool = False,
                verbose: bool = True, probe: bool = True) -> Dict[str, Any]:
    spec = get_arch(arch_id)
    cell = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": cell.kind,
    }
    if not spec.applicable(shape_name):
        rec["status"] = "skip"
        rec["reason"] = spec.skips[shape_name]
        return rec

    cfg = spec.config
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    with jax.set_mesh(mesh):
        rec["n_params"] = count_params(model_def(cfg))

        lowered = _lower_cell(cfg, spec, cell, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        scan_cost = _cost_of(compiled)
        mem = compiled.memory_analysis()
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "scan_cost_raw": scan_cost,          # scan body counted once
            "hlo_bytes": len(compiled.as_text()),
        })
        if mem is not None:
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "alias_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    rec.setdefault("memory_analysis", {})[attr] = int(v)

        # roofline: single-pod only (the table's mesh), depth-extrapolated
        if probe and not multi_pod:
            pr = roofline_probe(spec, cell, mesh)
            rec["probe"] = pr
            if cell.kind == "decode":
                tokens = cell.global_batch
            else:
                tokens = cell.global_batch * cell.seq_len
            mf = model_flops_estimate(
                _active_params(cfg, rec["n_params"]), tokens,
                "train" if cell.kind == "train" else "serve")
            terms = RooflineTerms(
                flops_per_device=pr["full"]["flops"],
                bytes_per_device=pr["full"]["bytes"],
                collective_bytes_per_device=pr["full"]["coll"],
                n_devices=n_dev, model_flops=mf,
            )
            rec["roofline"] = terms.to_dict()
        if verbose:
            dom = rec.get("roofline", {}).get("dominant", "-")
            print(f"[dryrun] {arch_id} x {shape_name} x {rec['mesh']}: OK "
                  f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s, "
                  f"dominant={dom})", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[dryrun] {tag}: cached", flush=True)
                continue
            try:
                rec = dryrun_cell(arch, shape, multi_pod=mp,
                                  probe=not args.no_probe)
            except Exception as e:  # noqa: BLE001 — record, keep sweeping
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"[dryrun] {tag}: ERROR {e}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()

"""Logical-axis sharding rules -> NamedSharding / PartitionSpec.

One rule table maps logical axis names (used in every ``P`` declaration and
every activation constraint) to physical mesh axes.  ``spec_for`` drops a
rule whenever the tensor dim is not divisible by the mesh-axis size (e.g.
MQA's single KV head can never shard over the 16-way model axis) — the same
policy GSPMD would need spelled out by hand, centralized here.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = Union[str, Tuple[str, ...], None]

# logical axis -> mesh axes.  "batch" spreads over pod+data (pure DP across
# pods, DP/FSDP within a pod); params FSDP-shard on "data" via "embed".
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,                # sequence sharding: enabled per-cell (SP)
    "act_embed": None,
    "act_heads": "model",
    "act_ff": "model",
    "act_experts": "model",
    "act_vocab": "model",       # logits: never materialize full-vocab rows
    "seq_model": "model",       # Megatron-SP residual stream (§Perf)
    # params
    "embed": "data",            # FSDP axis
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "dinner": "model",          # mamba/griffin inner width
    "layer": None,
    "lora": None,
    "dstate": None,
    "dconv": None,
    "window": None,
}


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def _present(mesh: Mesh, axes: MeshAxes) -> MeshAxes:
    """Keep only mesh axes that exist in this mesh (pod axis is optional)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.shape else None
    kept = tuple(a for a in axes if a in mesh.shape)
    return kept if kept else None


def spec_for(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[Dict[str, MeshAxes]] = None,
) -> PartitionSpec:
    """PartitionSpec for one tensor, dropping non-divisible rules."""
    rules = rules or DEFAULT_RULES
    entries = []
    used: set = set()
    for name, dim in zip(logical, shape):
        axes = _present(mesh, rules.get(name)) if name else None
        if axes is not None:
            ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
            # each mesh axis may appear at most once in a spec
            ax_tuple = tuple(a for a in ax_tuple if a not in used)
            size = 1
            for a in ax_tuple:
                size *= mesh.shape[a]
            if ax_tuple and size > 1 and dim % size == 0:
                used.update(ax_tuple)
                entries.append(ax_tuple if len(ax_tuple) > 1 else ax_tuple[0])
                continue
        entries.append(None)
    return PartitionSpec(*entries)


def tree_shardings(
    axes_tree: Any,
    shape_tree: Any,
    mesh: Mesh,
    rules: Optional[Dict[str, MeshAxes]] = None,
):
    """NamedShardings for a whole param tree (axes tree parallel to shapes)."""
    def one(axes, shaped):
        return NamedSharding(mesh, spec_for(axes, shaped.shape, mesh, rules))
    return jax.tree.map(
        one, axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def shard(x: jax.Array, *logical: Optional[str], rules=None) -> jax.Array:
    """Activation sharding constraint by logical axes.

    No-op outside a mesh context (CPU unit tests), so model code can call it
    unconditionally.
    """
    mesh = None
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            mesh = am
    except Exception:
        mesh = None
    if mesh is None:
        # Legacy mesh context (`with mesh:` on JAX without set_mesh /
        # get_abstract_mesh): the active physical mesh lives in the
        # thread-resources env.  Private API, so fully exception-guarded.
        try:
            from jax._src.mesh import thread_resources
            pm = thread_resources.env.physical_mesh
            if pm is not None and not pm.empty:
                mesh = pm
        except Exception:
            mesh = None
    if mesh is None:
        return x
    spec = spec_for(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, spec)


def batch_spec(mesh: Mesh) -> PartitionSpec:
    axes = _present(mesh, DEFAULT_RULES["batch"])
    if axes is None:
        return PartitionSpec()
    return PartitionSpec(axes)

"""Test-support utilities, including a minimal ``hypothesis`` fallback.

The property suites (``test_convdk_numerics``, ``test_schedule_theorems``,
``test_tiling_properties``) are written against real Hypothesis, which the
dev requirements install in CI.  On machines without it the suite must
still COLLECT AND RUN — property coverage degrades to a deterministic
pseudo-random example sweep instead of erroring at import time.

``install_hypothesis_fallback()`` (called from ``tests/conftest.py``)
registers a stub module under the ``hypothesis`` name implementing exactly
the surface the suites use: ``given``, ``settings`` and the
``integers`` / ``sampled_from`` / ``floats`` / ``builds`` strategies.  Examples are drawn
from a fixed-seed ``random.Random`` so failures reproduce across runs.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_FALLBACK_SEED = 0xC0FFEE
# Cap the fallback sweep: the stub trades Hypothesis' shrinking and coverage
# guidance for bounded deterministic sampling, so huge max_examples buy
# nothing.
_MAX_EXAMPLES_CAP = 100


class _Strategy:
    """A draw function wrapped as a minimal strategy object."""

    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int = 0, max_value: int = 1 << 16) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_ignored) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def builds(target, **kwargs) -> _Strategy:
    return _Strategy(lambda rng: target(
        **{k: v.example_from(rng) for k, v in kwargs.items()}))


def settings(max_examples: int = 25, deadline=None, **_ignored):
    """Records the example budget on the wrapped test (order-agnostic with
    ``given``: the attribute is read at call time from either wrapper)."""
    def deco(fn):
        fn._fallback_max_examples = min(max_examples, _MAX_EXAMPLES_CAP)
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples", 25))
            rng = random.Random(_FALLBACK_SEED)
            for _ in range(n):
                drawn = {k: s.example_from(rng) for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)
        # Hide the strategy parameters from pytest's fixture resolution:
        # without this, __wrapped__ makes inspect.signature() report the
        # original (ks, N, ...) signature and pytest demands fixtures.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(
            p for name, p in inspect.signature(fn).parameters.items()
            if name not in strategies)
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco


def install_hypothesis_fallback() -> bool:
    """Register the stub under ``hypothesis`` if the real package is absent.

    Returns True when the fallback was installed (real Hypothesis missing).
    """
    try:
        import hypothesis  # noqa: F401
        return False
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.sampled_from = sampled_from
    strat.floats = floats
    strat.builds = builds
    mod.strategies = strat
    mod.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
    return True

"""MBConv (EfficientNet) blocks and the EfficientNet-B0 builder.

``mbconv_block`` is the model-level entry point for one mobile inverted
bottleneck with squeeze-and-excitation:

    expand 1x1 -> silu -> DW k x k / s -> silu -> SE -> project 1x1
    (+ identity residual when s == 1 and C_in == C_out)

Routing follows ``repro.configs.base.kernel_config()``: with
``kcfg.fused_mbconv`` (the default) the block runs the TWO-PASS fused
ConvDK pipeline (``kernels.convdk_mbconv_fused``) with a per-layer-shape
schedule — tile_h AND the pass-2 retain/recompute mode — solved by
``core.autotune.get_mbconv_schedule`` from the HBM traffic model.
Otherwise the staged baseline (``kernels.convdk_mbconv_staged``) runs: the
DW tensor round-trips through HBM around the SE stage.

``efficientnet_b0_def`` / ``efficientnet_b0_apply`` assemble the full
EfficientNet-B0 (stem conv -> 16 MBConv blocks -> head conv -> pool ->
classifier), every MBConv routed through the two-pass fused kernel.  The
stage table reproduces ``core.workloads.EFFICIENTNET_B0`` exactly (a test
asserts the consistency).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .param import P

# (expand_ratio, kernel, stride, c_out, repeats) — EfficientNet-B0 stages
# 2-8 [arXiv:1905.11946, Table 1]; the first block of a stage carries the
# stride, channel changes happen on that block, SE ratio 0.25 throughout.
EFFNET_B0_STAGES: Tuple[Tuple[int, int, int, int, int], ...] = (
    (1, 3, 1, 16, 1),
    (6, 3, 2, 24, 2),
    (6, 5, 2, 40, 2),
    (6, 3, 2, 80, 3),
    (6, 5, 1, 112, 3),
    (6, 5, 2, 192, 4),
    (6, 3, 1, 320, 1),
)


@dataclasses.dataclass(frozen=True)
class EffNetConfig:
    """EfficientNet-family hyperparameters (B0 defaults).

    ``width_mult`` scales every channel count through ``round_filters``
    (divisor-8 rounding, the paper's compound-scaling rule) — small
    multipliers give CI-sized models with the exact B0 topology.
    """

    num_classes: int = 1000
    width_mult: float = 1.0
    se_ratio: float = 0.25
    stem_c: int = 32
    head_c: int = 1280
    stages: Tuple[Tuple[int, int, int, int, int], ...] = EFFNET_B0_STAGES
    dtype: str = "float32"


def round_filters(c: int, width_mult: float, divisor: int = 8) -> int:
    """EfficientNet channel rounding: scale, snap to the divisor, never
    drop below 90 % of the scaled value."""
    if width_mult == 1.0:
        return c
    c_scaled = c * width_mult
    new_c = max(divisor, int(c_scaled + divisor / 2) // divisor * divisor)
    if new_c < 0.9 * c_scaled:
        new_c += divisor
    return int(new_c)


@dataclasses.dataclass(frozen=True)
class MBConvSpec:
    """One resolved MBConv block instance inside a network."""

    c_in: int
    c_out: int
    expand_ratio: int
    k: int
    s: int
    se_ratio: float = 0.25

    @property
    def c_mid(self) -> int:
        return self.c_in * self.expand_ratio

    @property
    def c_se(self) -> int:
        return max(1, int(self.c_in * self.se_ratio))

    @property
    def has_residual(self) -> bool:
        return self.s == 1 and self.c_in == self.c_out


def effnet_block_specs(cfg: EffNetConfig) -> List[MBConvSpec]:
    """The per-block MBConv table of one EfficientNet config."""
    specs: List[MBConvSpec] = []
    c_in = round_filters(cfg.stem_c, cfg.width_mult)
    for expand, k, s, c_out, repeats in cfg.stages:
        c_out = round_filters(c_out, cfg.width_mult)
        for i in range(repeats):
            specs.append(MBConvSpec(c_in=c_in, c_out=c_out,
                                    expand_ratio=expand, k=k,
                                    s=s if i == 0 else 1,
                                    se_ratio=cfg.se_ratio))
            c_in = c_out
    return specs


def effnet_chain_rows(specs: List[MBConvSpec], h: int, w: int
                      ) -> Tuple[Tuple[int, int, int, int, int, int, int],
                                 ...]:
    """(h, w, c_in, c_mid, c_out, k, s) chain rows for the network-level
    layout solver (``core.autotune.get_network_plan``), threading the
    spatial dims through each block's stride.  ``h``/``w`` are the
    STEM-OUTPUT dims (the first block's input) — callers with image dims
    divide by the stem stride first.  Shared by ``efficientnet_b0_apply``
    and the vision serving engine, so both price the same chain."""
    rows, hh, ww = [], h, w
    for sp in specs:
        rows.append((hh, ww, sp.c_in, sp.c_mid, sp.c_out, sp.k, sp.s))
        hh, ww = -(-hh // sp.s), -(-ww // sp.s)
    return tuple(rows)


# ---------------------------------------------------------------------------
# one MBConv block
# ---------------------------------------------------------------------------

def mbconv_def(c_in: int, c_out: int, k: int = 3, expand_ratio: int = 6,
               se_ratio: float = 0.25) -> dict:
    """Params of one MBConv block.  Convs are bias-free (BN would own the
    bias); the SE FCs carry biases, as in the reference EfficientNet."""
    spec = MBConvSpec(c_in=c_in, c_out=c_out, expand_ratio=expand_ratio,
                      k=k, s=1, se_ratio=se_ratio)
    c_mid, c_se = spec.c_mid, spec.c_se
    p: Dict[str, Any] = {
        "dw": P((k, k, c_mid), (None, None, None)),
        "se_w1": P((c_mid, c_se), (None, None), scale=2.0),
        "se_b1": P((c_se,), (None,), init="zeros"),
        "se_w2": P((c_se, c_mid), (None, None), scale=2.0),
        "se_b2": P((c_mid,), (None,), init="zeros"),
        "proj": P((c_mid, c_out), (None, None), scale=2.0),
    }
    if expand_ratio != 1:
        p["exp"] = P((c_in, c_mid), (None, None), scale=2.0)
    return p


def mbconv_block(
    x,
    params=None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    exp_act: Optional[str] = "silu",
    dw_act: Optional[str] = "silu",
    cfg=None,
    mesh=None,
    pin=None,
    in_layout: str = "replicated",
    overlap: Optional[str] = None,
    kcfg=None,
):
    """Apply one MBConv block, routed by the conv-kernel config.

    Canonical signature: ``mbconv_block(x, params, *, cfg, mesh, pin,
    in_layout)`` returning ``(y, out_layout)`` — symmetric with
    ``separable_block``, so the network-level layout solver can thread a
    block chain through either family.  The legacy positional order
    (``params`` first, bare-array return) and the ``kcfg=`` kwarg keep
    working behind a warn-once deprecation shim.

    With ``fused`` (the default) the block runs the two-pass fused ConvDK
    pipeline: pass 1 fuses expand-PW + DW per strip and accumulates the
    SE pool on-chip; pass 2 folds the SE gate into the projection in the
    same VMEM residency.  The per-layer (tile_h, mode, residency)
    schedule — residency being the strip-staging mode of
    ``kernels.staging`` — comes from ``core.autotune.get_mbconv_schedule``
    unless ``pin`` (or the legacy config fields) pins one.  The identity
    residual is added when the shapes allow (s == 1, C_in == C_out).

    With a ``mesh`` (and the shard toggle), the fused pipeline runs
    mesh-sharded via ``shard_map``: batch on "data" (jointly with a "pod"
    axis when present), the expanded c_mid grid on "model", the SE pool
    psum'd across the model axis
    (``kernels.convdk_mbconv_fused_sharded``) — falling back to the
    single-device kernel when the mesh axes do not divide the grid.  The
    (tile_h, mode, residency, collective) schedule is then solved per
    (partitioning, layout); when the solver picks ``psum_scatter`` the
    block output comes back sharded on c_out (identical values) and
    ``out_layout`` reports ``"model_sharded"``.

    ``in_layout`` declares the ARRIVAL layout: ``"model_sharded"``
    (c_in sharded on "model", dividing) is consumed collective-free by
    identity-expand blocks (the only place it strictly wins — the
    network DP exploits exactly this) and via an entry all-gather by
    real-expand blocks (byte-identical to a boundary regather: the dense
    expand needs all of c_in, which is why e > 1 boundaries tie).

    ``overlap`` declares the ENTRY-boundary overlap mode the caller's
    chain executor runs this block under ("serial" | "pipelined", see
    ``core.perfmodel.OVERLAP_MODES``; None = serial).  It does not change
    the block's math — it threads into the schedule lookup so a
    pipelined entry solves under the halved pass-1 VMEM budget (two
    blocks share VMEM while their stages overlap) and caches under its
    own ``ov=`` key segment.

    x: (B, H, W, C_in) NHWC -> (B, H', W', C_out).
    """
    from ..configs.base import _warn_once, kernel_config, resolve_pin
    legacy_call = isinstance(x, dict)
    if legacy_call:
        _warn_once(
            "mbconv_block_positional",
            "mbconv_block(params, x) is deprecated; call "
            "mbconv_block(x, params, ...) — the new order returns "
            "(y, out_layout)")
        x, params = params, x
    if kcfg is not None:
        _warn_once(
            "block_kcfg_kwarg",
            "the kcfg= kwarg on block entries is deprecated; pass cfg=")
        if cfg is None:
            cfg = kcfg
    if cfg is None:
        cfg = kernel_config()
    from ..core.perfmodel import validate_layout
    from ..kernels import (
        can_shard_fused, conv_mesh_shape, convdk_mbconv_fused,
        convdk_mbconv_fused_sharded, convdk_mbconv_staged,
    )

    validate_layout(in_layout)
    eff = resolve_pin(cfg, pin, family="mbconv")
    c_in = x.shape[-1]
    c_mid = params["dw"].shape[-1]
    c_out = params["proj"].shape[-1]
    if "exp" in params:
        w_exp = params["exp"].astype(x.dtype)
        eff_exp_act = exp_act
    else:
        # expansion ratio 1 (MBConv1): identity expand, no expand activation
        assert c_mid == c_in, (c_mid, c_in)
        w_exp = jnp.eye(c_mid, dtype=x.dtype)
        eff_exp_act = None

    sharded = (mesh is not None and eff.shard and eff.fused
               and can_shard_fused(mesh, x.shape[0], c_mid))
    mesh_shape = conv_mesh_shape(mesh) if sharded else (1, 1)
    # a sharded arrival additionally needs c_in to divide the model factor
    eff_in_layout = ("model_sharded"
                     if (sharded and in_layout == "model_sharded"
                         and c_in % mesh_shape[1] == 0)
                     else "replicated")
    pinned_collective = eff.resolved_collective
    tile_h, mode = cfg.tile_h, eff.mode or "retain"
    residency = eff.residency
    collective = pinned_collective
    if cfg.autotune:
        from ..core.autotune import get_mbconv_schedule
        from ..core.perfmodel import DEFAULT_OVERLAP
        b, h, w, _ = x.shape
        se_ratio = params["se_w1"].shape[1] / max(1, c_in)
        # a pinned mbconv_mode enters the solve: tile_h/residency must be
        # VMEM-feasible under THAT mode's footprint, not the free winner's
        sch = get_mbconv_schedule(
            b, h, w, c_in, c_mid, c_out, params["dw"].shape[0], stride,
            se_ratio=se_ratio, dtype_bytes=x.dtype.itemsize,
            mesh_shape=mesh_shape, residency=eff.residency,
            mode=eff.mode, collective=pinned_collective,
            in_layout=eff_in_layout,
            overlap=overlap if overlap is not None else DEFAULT_OVERLAP)
        tile_h = sch.tile_h
        mode = sch.mode
        residency = sch.residency
        collective = sch.collective

    args = (x, w_exp, params["dw"].astype(x.dtype),
            params["se_w1"], params["se_b1"], params["se_w2"],
            params["se_b2"], params["proj"].astype(x.dtype))
    if sharded:
        out = convdk_mbconv_fused_sharded(
            *args, mesh=mesh, stride=stride, padding=padding, tile_h=tile_h,
            mode=mode, exp_act=eff_exp_act, dw_act=dw_act,
            interpret=cfg.interpret, residency=residency,
            collective=collective, in_layout=eff_in_layout)
        # a padded scatter (non-dividing c_out) comes back sliced — not
        # cleanly shard-consumable, so it reports replicated
        out_layout = ("model_sharded"
                      if (collective == "psum_scatter"
                          and c_out % mesh_shape[1] == 0)
                      else "replicated")
    elif eff.fused:
        out = convdk_mbconv_fused(
            *args, stride=stride, padding=padding, tile_h=tile_h, mode=mode,
            exp_act=eff_exp_act, dw_act=dw_act, interpret=cfg.interpret,
            residency=residency)
        out_layout = "replicated"
    else:
        out = convdk_mbconv_staged(
            *args, stride=stride, padding=padding, tile_h=tile_h,
            exp_act=eff_exp_act, dw_act=dw_act, interpret=cfg.interpret)
        out_layout = "replicated"
    if stride == 1 and c_in == c_out and out.shape == x.shape:
        out = out + x
    if legacy_call:
        return out
    return out, out_layout


# ---------------------------------------------------------------------------
# EfficientNet-B0
# ---------------------------------------------------------------------------

def efficientnet_b0_def(cfg: EffNetConfig = EffNetConfig()) -> dict:
    """Param tree: stem conv -> MBConv blocks -> head conv -> classifier."""
    specs = effnet_block_specs(cfg)
    stem_c = round_filters(cfg.stem_c, cfg.width_mult)
    head_c = round_filters(cfg.head_c, cfg.width_mult)
    p: Dict[str, Any] = {
        "stem": P((3, 3, 3, stem_c), (None,) * 4),
        "head": P((specs[-1].c_out, head_c), (None, None), scale=2.0),
        "cls_w": P((head_c, cfg.num_classes), (None, None)),
        "cls_b": P((cfg.num_classes,), (None,), init="zeros"),
    }
    for i, sp in enumerate(specs):
        p[f"block{i}"] = mbconv_def(sp.c_in, sp.c_out, k=sp.k,
                                    expand_ratio=sp.expand_ratio,
                                    se_ratio=sp.se_ratio)
    return p


def efficientnet_b0_apply(params: dict, images: jax.Array,
                          cfg: EffNetConfig = EffNetConfig(),
                          kcfg=None, mesh=None, plan=None) -> jax.Array:
    """(B, H, W, 3) images -> (B, num_classes) logits.

    Every MBConv block runs the two-pass fused ConvDK pipeline (or the
    staged baseline, per ``kcfg``) — EfficientNet-B0 end to end through the
    paper's dataflow.  With ``mesh``, every shardable block runs the
    mesh-sharded fused pipeline (see ``mbconv_block``), and the per-block
    schedules come from the NETWORK-level layout solve
    (``core.autotune.get_network_plan``): the DP picks each block's
    (residency, mode, collective, in/out layout) jointly over the whole
    chain — the stem output materializes model-sharded when the plan says
    so (a ``with_sharding_constraint``; block0's identity expand then
    consumes it collective-free), and every block call threads the solved
    layout chain via ``pin=`` / ``in_layout=``.

    ``plan`` passes a pre-solved ``core.autotune.NetworkPlan`` explicitly
    (it must match this call's chain shapes): the vision serving engine
    solves one plan per resolution bucket and threads it here, so the
    bytes its telemetry counters charge are — by construction — the
    schedules the blocks actually run.

    The block chain itself lowers through ``models.blockgraph``: the
    specs (and plan, when present) build a ``BlockGraph`` whose nodes
    carry explicit per-pass buffer sets and the plan's solved
    ``entry_overlap``, ``validate()`` proves every pipelined boundary
    hazard-free, and ``lower()`` runs the chain — bit-exact with the
    former sequential loop."""
    specs = effnet_block_specs(cfg)
    dt = jnp.dtype(cfg.dtype)
    x = jax.lax.conv_general_dilated(
        images.astype(dt), params["stem"].astype(dt), (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.nn.silu(x)

    if kcfg is None:
        from ..configs.base import kernel_config
        kcfg = kernel_config()
    if plan is None and (mesh is not None and kcfg.shard_fused
                         and kcfg.fused_mbconv and kcfg.autotune):
        from ..core.autotune import get_network_plan
        from ..kernels import conv_mesh_shape
        b, h, w, _c0 = x.shape
        plan = get_network_plan(effnet_chain_rows(specs, h, w), b,
                                conv_mesh_shape(mesh),
                                dtype_bytes=dt.itemsize,
                                se_ratio=cfg.se_ratio)
    if plan is not None:
        if mesh is not None and plan.stem_layout == "model_sharded":
            # materialize the stem output once per element mesh-wide: each
            # device of a model group holds only its c0/mp channel slice,
            # which block0's sharded-in entry consumes without a gather
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as _P
            from ..kernels.convdk_sharded import MODEL_AXIS, _batch_axes
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, _P(_batch_axes(mesh), None, None,
                                          MODEL_AXIS)))

    # the 16-block chain lowers through its dataflow-graph form: each
    # block is a BlockNode with explicit per-pass read/write buffer
    # sets, validate() proves every plan-pipelined boundary hazard-free
    # (only the boundary activation flows producer-pass-2 ->
    # consumer-pass-1), and lower() executes the nodes in chain order —
    # operation-for-operation what the old Python loop did, so forward
    # and grad are bit-exact with it
    from .blockgraph import build_mbconv_graph
    graph = build_mbconv_graph(specs, params, kcfg=kcfg, mesh=mesh,
                               plan=plan)
    graph.validate()
    x = graph.lower(x)
    x = jax.nn.silu(jnp.einsum("bhwc,cd->bhwd", x,
                               params["head"].astype(x.dtype)))
    x = x.mean(axis=(1, 2))
    return x @ params["cls_w"].astype(x.dtype) + params["cls_b"].astype(x.dtype)

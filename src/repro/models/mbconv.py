"""MBConv (EfficientNet) blocks and the EfficientNet-B0 builder.

``mbconv_block`` is the model-level entry point for one mobile inverted
bottleneck with squeeze-and-excitation:

    expand 1x1 -> silu -> DW k x k / s -> silu -> SE -> project 1x1
    (+ identity residual when s == 1 and C_in == C_out)

Routing follows ``repro.configs.base.kernel_config()``: with
``kcfg.fused_mbconv`` (the default) the block runs the TWO-PASS fused
ConvDK pipeline (``kernels.convdk_mbconv_fused``) with a per-layer-shape
schedule — tile_h AND the pass-2 retain/recompute mode — solved by
``core.autotune.get_mbconv_schedule`` from the HBM traffic model.
Otherwise the staged baseline (``kernels.convdk_mbconv_staged``) runs: the
DW tensor round-trips through HBM around the SE stage.

``efficientnet_b0_def`` / ``efficientnet_b0_apply`` assemble the full
EfficientNet-B0 (stem conv -> 16 MBConv blocks -> head conv -> pool ->
classifier), every MBConv routed through the two-pass fused kernel.  The
stage table reproduces ``core.workloads.EFFICIENTNET_B0`` exactly (a test
asserts the consistency).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .param import P

# (expand_ratio, kernel, stride, c_out, repeats) — EfficientNet-B0 stages
# 2-8 [arXiv:1905.11946, Table 1]; the first block of a stage carries the
# stride, channel changes happen on that block, SE ratio 0.25 throughout.
EFFNET_B0_STAGES: Tuple[Tuple[int, int, int, int, int], ...] = (
    (1, 3, 1, 16, 1),
    (6, 3, 2, 24, 2),
    (6, 5, 2, 40, 2),
    (6, 3, 2, 80, 3),
    (6, 5, 1, 112, 3),
    (6, 5, 2, 192, 4),
    (6, 3, 1, 320, 1),
)


@dataclasses.dataclass(frozen=True)
class EffNetConfig:
    """EfficientNet-family hyperparameters (B0 defaults).

    ``width_mult`` scales every channel count through ``round_filters``
    (divisor-8 rounding, the paper's compound-scaling rule) — small
    multipliers give CI-sized models with the exact B0 topology.
    """

    num_classes: int = 1000
    width_mult: float = 1.0
    se_ratio: float = 0.25
    stem_c: int = 32
    head_c: int = 1280
    stages: Tuple[Tuple[int, int, int, int, int], ...] = EFFNET_B0_STAGES
    dtype: str = "float32"


def round_filters(c: int, width_mult: float, divisor: int = 8) -> int:
    """EfficientNet channel rounding: scale, snap to the divisor, never
    drop below 90 % of the scaled value."""
    if width_mult == 1.0:
        return c
    c_scaled = c * width_mult
    new_c = max(divisor, int(c_scaled + divisor / 2) // divisor * divisor)
    if new_c < 0.9 * c_scaled:
        new_c += divisor
    return int(new_c)


@dataclasses.dataclass(frozen=True)
class MBConvSpec:
    """One resolved block instance inside a network.

    The block FAMILY is data on the spec (``"mbconv"`` — the two-pass
    SE-aware pipeline — or ``"fusedmb"`` — EfficientNet-V2's single-pass
    dense-conv + projection collapse), as are the per-block activation
    and SE facts MobileNet-V3 varies stage by stage: ``act`` is the main
    activation (expand/DW for MBConv, the dense conv for Fused-MBConv),
    ``se_ratio <= 0`` means NO squeeze-excite (the kernels then skip the
    pool/gate entirely), and ``se_act``/``gate_act`` are the SE-internal
    nonlinearities ((silu, sigmoid) for EfficientNet, (relu,
    hard_sigmoid) for V3).  ``c_mid_override`` pins the expanded width
    directly for tables whose expansion is not an integer multiple of
    ``c_in`` (most of MobileNet-V3)."""

    c_in: int
    c_out: int
    expand_ratio: int
    k: int
    s: int
    se_ratio: float = 0.25
    c_mid_override: Optional[int] = None
    act: str = "silu"
    se_act: str = "silu"
    gate_act: str = "sigmoid"
    family: str = "mbconv"

    def __post_init__(self):
        from ..configs.base import BLOCK_FAMILIES
        if self.family not in ("mbconv", "fusedmb"):
            raise ValueError(
                f"MBConvSpec.family must be 'mbconv' or 'fusedmb' "
                f"(of {BLOCK_FAMILIES}), got {self.family!r}")
        if self.family == "fusedmb" and self.se_ratio > 0:
            # the fusedmb family never carries SE — normalize, mirroring
            # core.autotune.BlockRow
            object.__setattr__(self, "se_ratio", 0.0)

    @property
    def c_mid(self) -> int:
        if self.c_mid_override is not None:
            return self.c_mid_override
        return self.c_in * self.expand_ratio

    @property
    def has_se(self) -> bool:
        return self.family == "mbconv" and self.se_ratio > 0

    @property
    def c_se(self) -> int:
        if not self.has_se:
            return 0
        return max(1, int(self.c_in * self.se_ratio))

    @property
    def has_residual(self) -> bool:
        return self.s == 1 and self.c_in == self.c_out


def effnet_block_specs(cfg: EffNetConfig) -> List[MBConvSpec]:
    """The per-block MBConv table of one EfficientNet config."""
    specs: List[MBConvSpec] = []
    c_in = round_filters(cfg.stem_c, cfg.width_mult)
    for expand, k, s, c_out, repeats in cfg.stages:
        c_out = round_filters(c_out, cfg.width_mult)
        for i in range(repeats):
            specs.append(MBConvSpec(c_in=c_in, c_out=c_out,
                                    expand_ratio=expand, k=k,
                                    s=s if i == 0 else 1,
                                    se_ratio=cfg.se_ratio))
            c_in = c_out
    return specs


def effnet_chain_rows(specs: List[MBConvSpec], h: int, w: int
                      ) -> Tuple[Tuple[int, int, int, int, int, int, int],
                                 ...]:
    """(h, w, c_in, c_mid, c_out, k, s) chain rows for the network-level
    layout solver (``core.autotune.get_network_plan``), threading the
    spatial dims through each block's stride.  ``h``/``w`` are the
    STEM-OUTPUT dims (the first block's input) — callers with image dims
    divide by the stem stride first.  Shared by ``efficientnet_b0_apply``
    and the vision serving engine, so both price the same chain."""
    rows, hh, ww = [], h, w
    for sp in specs:
        rows.append((hh, ww, sp.c_in, sp.c_mid, sp.c_out, sp.k, sp.s))
        hh, ww = -(-hh // sp.s), -(-ww // sp.s)
    return tuple(rows)


def block_chain_rows(specs: List[MBConvSpec], h: int, w: int) -> tuple:
    """Family-generic chain rows (``core.autotune.BlockRow``) for the
    network-level layout solver — like ``effnet_chain_rows`` but carrying
    each spec's family, act and SE ratio, so mixed-family chains
    (EfficientNet-V2) and per-block act/SE variants (MobileNet-V3) solve
    through the same DP."""
    from ..core.autotune import BlockRow
    rows, hh, ww = [], h, w
    for sp in specs:
        rows.append(BlockRow(hh, ww, sp.c_in, sp.c_mid, sp.c_out, sp.k,
                             sp.s, family=sp.family, act=sp.act,
                             se_ratio=sp.se_ratio))
        hh, ww = -(-hh // sp.s), -(-ww // sp.s)
    return tuple(rows)


# ---------------------------------------------------------------------------
# one MBConv block
# ---------------------------------------------------------------------------

def mbconv_def(c_in: int, c_out: int, k: int = 3, expand_ratio: int = 6,
               se_ratio: float = 0.25, c_mid: Optional[int] = None) -> dict:
    """Params of one MBConv block.  Convs are bias-free (BN would own the
    bias); the SE FCs carry biases, as in the reference EfficientNet.
    ``se_ratio <= 0`` omits the SE FCs entirely (the param tree IS the
    se=off contract: ``mbconv_block`` passes ``None`` SE weights to the
    kernels when the keys are absent).  ``c_mid`` pins a non-integer
    expansion width directly (MobileNet-V3 tables)."""
    spec = MBConvSpec(c_in=c_in, c_out=c_out, expand_ratio=expand_ratio,
                      k=k, s=1, se_ratio=se_ratio, c_mid_override=c_mid)
    c_mid, c_se = spec.c_mid, spec.c_se
    p: Dict[str, Any] = {
        "dw": P((k, k, c_mid), (None, None, None)),
        "proj": P((c_mid, c_out), (None, None), scale=2.0),
    }
    if spec.has_se:
        p["se_w1"] = P((c_mid, c_se), (None, None), scale=2.0)
        p["se_b1"] = P((c_se,), (None,), init="zeros")
        p["se_w2"] = P((c_se, c_mid), (None, None), scale=2.0)
        p["se_b2"] = P((c_mid,), (None,), init="zeros")
    if c_mid != c_in:
        p["exp"] = P((c_in, c_mid), (None, None), scale=2.0)
    return p


def fusedmb_def(c_in: int, c_out: int, c_mid: int, k: int = 3) -> dict:
    """Params of one Fused-MBConv block: the dense k x k conv that
    collapses expand+DW (HWIO), plus the 1x1 projection."""
    return {
        "conv": P((k, k, c_in, c_mid), (None,) * 4),
        "proj": P((c_mid, c_out), (None, None), scale=2.0),
    }


def block_def(sp: MBConvSpec) -> dict:
    """Family dispatch: the param tree of one spec'd block."""
    if sp.family == "fusedmb":
        return fusedmb_def(sp.c_in, sp.c_out, sp.c_mid, k=sp.k)
    return mbconv_def(sp.c_in, sp.c_out, k=sp.k,
                      expand_ratio=sp.expand_ratio, se_ratio=sp.se_ratio,
                      c_mid=sp.c_mid_override)


def mbconv_block(
    x,
    params=None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    exp_act: Optional[str] = "silu",
    dw_act: Optional[str] = "silu",
    se_act: Optional[str] = "silu",
    gate_act: Optional[str] = "sigmoid",
    cfg=None,
    mesh=None,
    pin=None,
    in_layout: str = "replicated",
    overlap: Optional[str] = None,
    kcfg=None,
):
    """Apply one MBConv block, routed by the conv-kernel config.

    Canonical signature: ``mbconv_block(x, params, *, cfg, mesh, pin,
    in_layout)`` returning ``(y, out_layout)`` — symmetric with
    ``separable_block``, so the network-level layout solver can thread a
    block chain through either family.  The legacy positional order
    (``params`` first, bare-array return) and the ``kcfg=`` kwarg keep
    working behind a warn-once deprecation shim.

    With ``fused`` (the default) the block runs the two-pass fused ConvDK
    pipeline: pass 1 fuses expand-PW + DW per strip and accumulates the
    SE pool on-chip; pass 2 folds the SE gate into the projection in the
    same VMEM residency.  The per-layer (tile_h, mode, residency)
    schedule — residency being the strip-staging mode of
    ``kernels.staging`` — comes from ``core.autotune.get_mbconv_schedule``
    unless ``pin`` (or the legacy config fields) pins one.  The identity
    residual is added when the shapes allow (s == 1, C_in == C_out).

    With a ``mesh`` (and the shard toggle), the fused pipeline runs
    mesh-sharded via ``shard_map``: batch on "data" (jointly with a "pod"
    axis when present), the expanded c_mid grid on "model", the SE pool
    psum'd across the model axis
    (``kernels.convdk_mbconv_fused_sharded``) — falling back to the
    single-device kernel when the mesh axes do not divide the grid.  The
    (tile_h, mode, residency, collective) schedule is then solved per
    (partitioning, layout); when the solver picks ``psum_scatter`` the
    block output comes back sharded on c_out (identical values) and
    ``out_layout`` reports ``"model_sharded"``.

    ``in_layout`` declares the ARRIVAL layout: ``"model_sharded"``
    (c_in sharded on "model", dividing) is consumed collective-free by
    identity-expand blocks (the only place it strictly wins — the
    network DP exploits exactly this) and via an entry all-gather by
    real-expand blocks (byte-identical to a boundary regather: the dense
    expand needs all of c_in, which is why e > 1 boundaries tie).

    ``overlap`` declares the ENTRY-boundary overlap mode the caller's
    chain executor runs this block under ("serial" | "pipelined", see
    ``core.perfmodel.OVERLAP_MODES``; None = serial).  It does not change
    the block's math — it threads into the schedule lookup so a
    pipelined entry solves under the halved pass-1 VMEM budget (two
    blocks share VMEM while their stages overlap) and caches under its
    own ``ov=`` key segment.

    x: (B, H, W, C_in) NHWC -> (B, H', W', C_out).
    """
    from ..configs.base import _warn_once, kernel_config, resolve_pin
    legacy_call = isinstance(x, dict)
    if legacy_call:
        _warn_once(
            "mbconv_block_positional",
            "mbconv_block(params, x) is deprecated; call "
            "mbconv_block(x, params, ...) — the new order returns "
            "(y, out_layout)")
        x, params = params, x
    if kcfg is not None:
        _warn_once(
            "block_kcfg_kwarg",
            "the kcfg= kwarg on block entries is deprecated; pass cfg=")
        if cfg is None:
            cfg = kcfg
    if cfg is None:
        cfg = kernel_config()
    from ..core.perfmodel import validate_layout
    from ..kernels import (
        can_shard_fused, conv_mesh_shape, convdk_mbconv_fused,
        convdk_mbconv_fused_sharded, convdk_mbconv_staged,
    )

    validate_layout(in_layout)
    eff = resolve_pin(cfg, pin, family="mbconv")
    c_in = x.shape[-1]
    c_mid = params["dw"].shape[-1]
    c_out = params["proj"].shape[-1]
    # the param tree IS the SE contract: absent SE FCs mean a no-SE block
    # (MobileNet-V3's early/middle stages) — the kernels then skip the
    # pass-1 pool, the host MLP and the pass-2 gate entirely
    has_se = "se_w1" in params
    if eff.se == "on" and not has_se:
        raise ValueError("se='on' pinned on a block whose params carry "
                         "no SE FCs")
    if "exp" in params:
        w_exp = params["exp"].astype(x.dtype)
        eff_exp_act = exp_act
    else:
        # expansion ratio 1 (MBConv1): identity expand, no expand activation
        assert c_mid == c_in, (c_mid, c_in)
        w_exp = jnp.eye(c_mid, dtype=x.dtype)
        eff_exp_act = None

    sharded = (mesh is not None and eff.shard and eff.fused
               and can_shard_fused(mesh, x.shape[0], c_mid))
    mesh_shape = conv_mesh_shape(mesh) if sharded else (1, 1)
    # a sharded arrival additionally needs c_in to divide the model factor
    eff_in_layout = ("model_sharded"
                     if (sharded and in_layout == "model_sharded"
                         and c_in % mesh_shape[1] == 0)
                     else "replicated")
    pinned_collective = eff.resolved_collective
    tile_h, mode = cfg.tile_h, eff.mode or "retain"
    residency = eff.residency
    collective = pinned_collective
    if cfg.autotune:
        from ..core.autotune import (
            ACT_MODES, DEFAULT_ACT, get_mbconv_schedule,
        )
        from ..core.perfmodel import DEFAULT_OVERLAP
        b, h, w, _ = x.shape
        se_ratio = (params["se_w1"].shape[1] / max(1, c_in)) if has_se \
            else 0.0
        sched_act = dw_act if dw_act in ACT_MODES else DEFAULT_ACT
        # a pinned mbconv_mode enters the solve: tile_h/residency must be
        # VMEM-feasible under THAT mode's footprint, not the free winner's
        sch = get_mbconv_schedule(
            b, h, w, c_in, c_mid, c_out, params["dw"].shape[0], stride,
            se_ratio=se_ratio, dtype_bytes=x.dtype.itemsize,
            mesh_shape=mesh_shape, residency=eff.residency,
            mode=eff.mode, collective=pinned_collective,
            in_layout=eff_in_layout,
            overlap=overlap if overlap is not None else DEFAULT_OVERLAP,
            act=sched_act)
        tile_h = sch.tile_h
        mode = sch.mode
        residency = sch.residency
        collective = sch.collective

    args = (x, w_exp, params["dw"].astype(x.dtype),
            params.get("se_w1"), params.get("se_b1"), params.get("se_w2"),
            params.get("se_b2"), params["proj"].astype(x.dtype))
    if sharded:
        out = convdk_mbconv_fused_sharded(
            *args, mesh=mesh, stride=stride, padding=padding, tile_h=tile_h,
            mode=mode, exp_act=eff_exp_act, dw_act=dw_act,
            se_act=se_act, gate_act=gate_act,
            interpret=cfg.interpret, residency=residency,
            collective=collective, in_layout=eff_in_layout)
        # a padded scatter (non-dividing c_out) comes back sliced — not
        # cleanly shard-consumable, so it reports replicated
        out_layout = ("model_sharded"
                      if (collective == "psum_scatter"
                          and c_out % mesh_shape[1] == 0)
                      else "replicated")
    elif eff.fused:
        out = convdk_mbconv_fused(
            *args, stride=stride, padding=padding, tile_h=tile_h, mode=mode,
            exp_act=eff_exp_act, dw_act=dw_act, se_act=se_act,
            gate_act=gate_act, interpret=cfg.interpret,
            residency=residency)
        out_layout = "replicated"
    else:
        out = convdk_mbconv_staged(
            *args, stride=stride, padding=padding, tile_h=tile_h,
            exp_act=eff_exp_act, dw_act=dw_act, se_act=se_act,
            gate_act=gate_act, interpret=cfg.interpret)
        out_layout = "replicated"
    if stride == 1 and c_in == c_out and out.shape == x.shape:
        out = out + x
    if legacy_call:
        return out
    return out, out_layout


# ---------------------------------------------------------------------------
# one Fused-MBConv block
# ---------------------------------------------------------------------------

def fusedmb_block(
    x,
    params,
    *,
    stride: int = 1,
    padding: str = "SAME",
    act: Optional[str] = "silu",
    cfg=None,
    mesh=None,
    pin=None,
    in_layout: str = "replicated",
    overlap: Optional[str] = None,
):
    """Apply one Fused-MBConv block (EfficientNet-V2's fused stages),
    routed by the conv-kernel config — returns ``(y, out_layout)``,
    symmetric with ``mbconv_block``/``separable_block`` so the
    network-level layout solver threads mixed-family chains through one
    executor.

    With ``fused`` (the default) the whole block runs as the SINGLE-PASS
    ``kernels.convdk_fusedmb_fused`` pipeline: dense k x k conv
    (collapsed expand+DW), activation and the 1x1 projection in one VMEM
    residency — the expanded (C_mid) tensor never touches HBM, there is
    no SE stage and no second pass.  The (tile_h, residency, collective)
    schedule comes from ``core.autotune.get_fusedmb_schedule``.

    The family consumes REPLICATED arrivals only (the dense conv needs
    all of c_in): ``in_layout="model_sharded"`` raises, mirroring the
    kernel and perfmodel contracts — the network DP never proposes it.
    Under a mesh the expanded c_mid grid shards on "model" and the
    projection reduction crosses devices per the solved collective; a
    ``psum_scatter`` exit on a dividing c_out reports
    ``out_layout="model_sharded"``.  The identity residual is added when
    the shapes allow (s == 1, C_in == C_out).

    x: (B, H, W, C_in) NHWC -> (B, H', W', C_out).
    """
    from ..configs.base import kernel_config, resolve_pin
    if cfg is None:
        cfg = kernel_config()
    from ..core.perfmodel import validate_layout
    from ..kernels import (
        can_shard_fused, conv_mesh_shape, convdk_fusedmb_fused,
        convdk_fusedmb_fused_sharded, convdk_fusedmb_staged,
    )

    validate_layout(in_layout)
    if in_layout == "model_sharded":
        raise ValueError(
            "fusedmb consumes replicated arrivals only, got "
            f"{in_layout!r}")
    eff = resolve_pin(cfg, pin, family="fusedmb")
    w_conv = params["conv"].astype(x.dtype)
    w_proj = params["proj"].astype(x.dtype)
    c_in = x.shape[-1]
    c_mid = w_conv.shape[-1]
    c_out = w_proj.shape[-1]
    k = w_conv.shape[0]

    sharded = (mesh is not None and eff.shard and eff.fused
               and can_shard_fused(mesh, x.shape[0], c_mid))
    mesh_shape = conv_mesh_shape(mesh) if sharded else (1, 1)
    collective = eff.resolved_collective
    tile_h, residency = cfg.tile_h, eff.residency
    if cfg.autotune:
        from ..core.autotune import (
            ACT_MODES, DEFAULT_ACT, get_fusedmb_schedule,
        )
        from ..core.perfmodel import DEFAULT_OVERLAP
        b, h, w, _ = x.shape
        sched_act = act if act in ACT_MODES else DEFAULT_ACT
        sch = get_fusedmb_schedule(
            b, h, w, c_in, c_mid, c_out, k, stride,
            dtype_bytes=x.dtype.itemsize, mesh_shape=mesh_shape,
            residency=eff.residency, collective=collective,
            overlap=overlap if overlap is not None else DEFAULT_OVERLAP,
            act=sched_act)
        tile_h = sch.tile_h
        residency = sch.residency
        collective = sch.collective

    if sharded:
        out = convdk_fusedmb_fused_sharded(
            x, w_conv, w_proj, mesh=mesh, stride=stride, padding=padding,
            tile_h=tile_h, act=act, interpret=cfg.interpret,
            residency=residency, collective=collective,
            in_layout="replicated")
        out_layout = ("model_sharded"
                      if (collective == "psum_scatter"
                          and c_out % mesh_shape[1] == 0)
                      else "replicated")
    elif eff.fused:
        out = convdk_fusedmb_fused(
            x, w_conv, w_proj, stride=stride, padding=padding,
            tile_h=tile_h, act=act, interpret=cfg.interpret,
            residency=residency)
        out_layout = "replicated"
    else:
        out = convdk_fusedmb_staged(
            x, w_conv, w_proj, stride=stride, padding=padding,
            tile_h=tile_h, act=act, interpret=cfg.interpret)
        out_layout = "replicated"
    if stride == 1 and c_in == c_out and out.shape == x.shape:
        out = out + x
    return out, out_layout


# ---------------------------------------------------------------------------
# EfficientNet-B0
# ---------------------------------------------------------------------------

def efficientnet_b0_def(cfg: EffNetConfig = EffNetConfig()) -> dict:
    """Param tree: stem conv -> MBConv blocks -> head conv -> classifier."""
    specs = effnet_block_specs(cfg)
    stem_c = round_filters(cfg.stem_c, cfg.width_mult)
    head_c = round_filters(cfg.head_c, cfg.width_mult)
    p: Dict[str, Any] = {
        "stem": P((3, 3, 3, stem_c), (None,) * 4),
        "head": P((specs[-1].c_out, head_c), (None, None), scale=2.0),
        "cls_w": P((head_c, cfg.num_classes), (None, None)),
        "cls_b": P((cfg.num_classes,), (None,), init="zeros"),
    }
    for i, sp in enumerate(specs):
        p[f"block{i}"] = mbconv_def(sp.c_in, sp.c_out, k=sp.k,
                                    expand_ratio=sp.expand_ratio,
                                    se_ratio=sp.se_ratio)
    return p


def efficientnet_b0_apply(params: dict, images: jax.Array,
                          cfg: EffNetConfig = EffNetConfig(),
                          kcfg=None, mesh=None, plan=None) -> jax.Array:
    """(B, H, W, 3) images -> (B, num_classes) logits.

    Every MBConv block runs the two-pass fused ConvDK pipeline (or the
    staged baseline, per ``kcfg``) — EfficientNet-B0 end to end through the
    paper's dataflow.  With ``mesh``, every shardable block runs the
    mesh-sharded fused pipeline (see ``mbconv_block``), and the per-block
    schedules come from the NETWORK-level layout solve
    (``core.autotune.get_network_plan``): the DP picks each block's
    (residency, mode, collective, in/out layout) jointly over the whole
    chain — the stem output materializes model-sharded when the plan says
    so (a ``with_sharding_constraint``; block0's identity expand then
    consumes it collective-free), and every block call threads the solved
    layout chain via ``pin=`` / ``in_layout=``.

    ``plan`` passes a pre-solved ``core.autotune.NetworkPlan`` explicitly
    (it must match this call's chain shapes): the vision serving engine
    solves one plan per resolution bucket and threads it here, so the
    bytes its telemetry counters charge are — by construction — the
    schedules the blocks actually run.

    The block chain itself lowers through ``models.blockgraph``: the
    specs (and plan, when present) build a ``BlockGraph`` whose nodes
    carry explicit per-pass buffer sets and the plan's solved
    ``entry_overlap``, ``validate()`` proves every pipelined boundary
    hazard-free, and ``lower()`` runs the chain — bit-exact with the
    former sequential loop."""
    specs = effnet_block_specs(cfg)
    dt = jnp.dtype(cfg.dtype)
    x = jax.lax.conv_general_dilated(
        images.astype(dt), params["stem"].astype(dt), (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.nn.silu(x)

    if kcfg is None:
        from ..configs.base import kernel_config
        kcfg = kernel_config()
    if plan is None and (mesh is not None and kcfg.shard_fused
                         and kcfg.fused_mbconv and kcfg.autotune):
        from ..core.autotune import get_network_plan
        from ..kernels import conv_mesh_shape
        b, h, w, _c0 = x.shape
        plan = get_network_plan(effnet_chain_rows(specs, h, w), b,
                                conv_mesh_shape(mesh),
                                dtype_bytes=dt.itemsize,
                                se_ratio=cfg.se_ratio)
    if plan is not None:
        if mesh is not None and plan.stem_layout == "model_sharded":
            # materialize the stem output once per element mesh-wide: each
            # device of a model group holds only its c0/mp channel slice,
            # which block0's sharded-in entry consumes without a gather
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as _P
            from ..kernels.convdk_sharded import MODEL_AXIS, _batch_axes
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, _P(_batch_axes(mesh), None, None,
                                          MODEL_AXIS)))

    # the 16-block chain lowers through its dataflow-graph form: each
    # block is a BlockNode with explicit per-pass read/write buffer
    # sets, validate() proves every plan-pipelined boundary hazard-free
    # (only the boundary activation flows producer-pass-2 ->
    # consumer-pass-1), and lower() executes the nodes in chain order —
    # operation-for-operation what the old Python loop did, so forward
    # and grad are bit-exact with it
    from .blockgraph import build_mbconv_graph
    graph = build_mbconv_graph(specs, params, kcfg=kcfg, mesh=mesh,
                               plan=plan)
    graph.validate()
    x = graph.lower(x)
    x = jax.nn.silu(jnp.einsum("bhwc,cd->bhwd", x,
                               params["head"].astype(x.dtype)))
    x = x.mean(axis=(1, 2))
    return x @ params["cls_w"].astype(x.dtype) + params["cls_b"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MobileNet-V3-Large
# ---------------------------------------------------------------------------

# (c_mid, c_out, k, s, SE, act) per block — MobileNet-V3-Large
# [arXiv:1905.02244, Table 1]; c_in threads from the previous block (stem
# 16).  The expanded widths are NOT integer multiples of c_in (72 = 3 x
# 24 but 200 = 2.5 x 80), so the specs pin c_mid directly.  The DW stage
# of every row reproduces core.workloads.MOBILENET_V3_LARGE (a test pins
# the two views together).
MOBILENET_V3_LARGE_BLOCKS: Tuple[
        Tuple[int, int, int, int, bool, str], ...] = (
    (16, 16, 3, 1, False, "relu"),
    (64, 24, 3, 2, False, "relu"),
    (72, 24, 3, 1, False, "relu"),
    (72, 40, 5, 2, True, "relu"),
    (120, 40, 5, 1, True, "relu"),
    (120, 40, 5, 1, True, "relu"),
    (240, 80, 3, 2, False, "hard_swish"),
    (200, 80, 3, 1, False, "hard_swish"),
    (184, 80, 3, 1, False, "hard_swish"),
    (184, 80, 3, 1, False, "hard_swish"),
    (480, 112, 3, 1, True, "hard_swish"),
    (672, 112, 3, 1, True, "hard_swish"),
    (672, 160, 5, 2, True, "hard_swish"),
    (960, 160, 5, 1, True, "hard_swish"),
    (960, 160, 5, 1, True, "hard_swish"),
)


@dataclasses.dataclass(frozen=True)
class MobileNetV3Config:
    """MobileNet-V3-Large hyperparameters.  ``width_mult`` scales every
    channel count (including the pinned expanded widths) through
    ``round_filters`` — small multipliers give CI-sized models with the
    exact V3-Large topology, SE placement and act mix."""

    num_classes: int = 1000
    width_mult: float = 1.0
    se_ratio: float = 0.25
    stem_c: int = 16
    head_c: int = 960
    cls_c: int = 1280
    blocks: Tuple[Tuple[int, int, int, int, bool, str], ...] = \
        MOBILENET_V3_LARGE_BLOCKS
    dtype: str = "float32"


def mobilenet_v3_specs(cfg: MobileNetV3Config) -> List[MBConvSpec]:
    """The per-block spec table of one MobileNet-V3 config: per-block
    act, SE-on-some-blocks (se_ratio 0 elsewhere), and the V3 SE flavor
    (relu squeeze, hard_sigmoid gate)."""
    specs: List[MBConvSpec] = []
    c_in = round_filters(cfg.stem_c, cfg.width_mult)
    for c_mid, c_out, k, s, se, act in cfg.blocks:
        c_mid = round_filters(c_mid, cfg.width_mult)
        c_out = round_filters(c_out, cfg.width_mult)
        specs.append(MBConvSpec(
            c_in=c_in, c_out=c_out, expand_ratio=1, k=k, s=s,
            se_ratio=cfg.se_ratio if se else 0.0, c_mid_override=c_mid,
            act=act, se_act="relu", gate_act="hard_sigmoid"))
        c_in = c_out
    return specs


def mobilenet_v3_def(cfg: MobileNetV3Config = MobileNetV3Config()) -> dict:
    """Param tree: stem conv -> V3 blocks -> head conv -> FC -> classifier."""
    specs = mobilenet_v3_specs(cfg)
    stem_c = round_filters(cfg.stem_c, cfg.width_mult)
    head_c = round_filters(cfg.head_c, cfg.width_mult)
    cls_c = round_filters(cfg.cls_c, cfg.width_mult)
    p: Dict[str, Any] = {
        "stem": P((3, 3, 3, stem_c), (None,) * 4),
        "head": P((specs[-1].c_out, head_c), (None, None), scale=2.0),
        "fc": P((head_c, cls_c), (None, None), scale=2.0),
        "cls_w": P((cls_c, cfg.num_classes), (None, None)),
        "cls_b": P((cfg.num_classes,), (None,), init="zeros"),
    }
    for i, sp in enumerate(specs):
        p[f"block{i}"] = block_def(sp)
    return p


def mobilenet_v3_apply(params: dict, images: jax.Array,
                       cfg: MobileNetV3Config = MobileNetV3Config(),
                       kcfg=None, mesh=None, plan=None) -> jax.Array:
    """(B, H, W, 3) images -> (B, num_classes) logits.

    MobileNet-V3-Large end to end through the paper's dataflow: every
    block runs the two-pass fused ConvDK pipeline with its OWN act and
    SE facts — relu early stages, hard_swish late stages, SE on the
    blocks Table 1 marks (the no-SE blocks pay zero SE bytes: no pool,
    no gate, no squeeze collective under a mesh).  The chain lowers
    through ``models.blockgraph`` exactly as EfficientNet-B0 does, and
    with a mesh the per-block schedules come from the network-level
    layout solve over family-generic ``BlockRow``s carrying the per-row
    act/SE axes."""
    specs = mobilenet_v3_specs(cfg)
    dt = jnp.dtype(cfg.dtype)
    x = jax.lax.conv_general_dilated(
        images.astype(dt), params["stem"].astype(dt), (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.nn.hard_swish(x)

    if kcfg is None:
        from ..configs.base import kernel_config
        kcfg = kernel_config()
    if plan is None and (mesh is not None and kcfg.shard_fused
                         and kcfg.fused_mbconv and kcfg.autotune):
        from ..core.autotune import get_network_plan
        from ..kernels import conv_mesh_shape
        b, h, w, _c0 = x.shape
        plan = get_network_plan(block_chain_rows(specs, h, w), b,
                                conv_mesh_shape(mesh),
                                dtype_bytes=dt.itemsize,
                                se_ratio=cfg.se_ratio)
    if plan is not None:
        if mesh is not None and plan.stem_layout == "model_sharded":
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as _P
            from ..kernels.convdk_sharded import MODEL_AXIS, _batch_axes
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, _P(_batch_axes(mesh), None, None,
                                          MODEL_AXIS)))

    from .blockgraph import build_block_graph
    graph = build_block_graph(specs, params, kcfg=kcfg, mesh=mesh,
                              plan=plan)
    graph.validate()
    x = graph.lower(x)
    x = jax.nn.hard_swish(jnp.einsum("bhwc,cd->bhwd", x,
                                     params["head"].astype(x.dtype)))
    x = x.mean(axis=(1, 2))
    x = jax.nn.hard_swish(x @ params["fc"].astype(x.dtype))
    return x @ params["cls_w"].astype(x.dtype) + params["cls_b"].astype(x.dtype)


# ---------------------------------------------------------------------------
# EfficientNet-V2-S
# ---------------------------------------------------------------------------

# (family, expand_ratio, k, s, c_out, repeats) — EfficientNet-V2-S body
# [arXiv:2104.00298, Table 2]: Fused-MBConv stages 1-3 (the dense
# expand+DW collapse, no SE), MBConv tail with SE 0.25.  The first block
# of a stage carries the stride.
EFFNET_V2_S_STAGES: Tuple[Tuple[str, int, int, int, int, int], ...] = (
    ("fusedmb", 1, 3, 1, 24, 2),
    ("fusedmb", 4, 3, 2, 48, 4),
    ("fusedmb", 4, 3, 2, 64, 4),
    ("mbconv", 4, 3, 2, 128, 6),
    ("mbconv", 6, 3, 1, 160, 9),
    ("mbconv", 6, 3, 2, 256, 15),
)


@dataclasses.dataclass(frozen=True)
class EffNetV2Config:
    """EfficientNet-V2-S hyperparameters (same ``width_mult`` scaling
    rule as ``EffNetConfig``; shrink ``stages`` for CI-sized chains that
    keep the fused-head + MBConv-tail mix)."""

    num_classes: int = 1000
    width_mult: float = 1.0
    se_ratio: float = 0.25
    stem_c: int = 24
    head_c: int = 1280
    stages: Tuple[Tuple[str, int, int, int, int, int], ...] = \
        EFFNET_V2_S_STAGES
    dtype: str = "float32"


def effnet_v2_block_specs(cfg: EffNetV2Config) -> List[MBConvSpec]:
    """The per-block spec table of one EfficientNet-V2 config — a
    mixed-family chain: ``fusedmb`` specs for the fused stages (silu
    dense conv, never SE; the expansion-1 stage widens c_mid to c_out so
    the single-pass kernel's projection stays well-formed), ``mbconv``
    specs for the tail (silu, SE 0.25)."""
    specs: List[MBConvSpec] = []
    c_in = round_filters(cfg.stem_c, cfg.width_mult)
    for family, expand, k, s, c_out, repeats in cfg.stages:
        c_out = round_filters(c_out, cfg.width_mult)
        for i in range(repeats):
            c_mid = max(c_in * expand, c_out) if family == "fusedmb" \
                else None
            specs.append(MBConvSpec(
                c_in=c_in, c_out=c_out, expand_ratio=expand, k=k,
                s=s if i == 0 else 1,
                se_ratio=0.0 if family == "fusedmb" else cfg.se_ratio,
                c_mid_override=c_mid, family=family))
            c_in = c_out
    return specs


def efficientnet_v2_s_def(cfg: EffNetV2Config = EffNetV2Config()) -> dict:
    """Param tree: stem conv -> Fused-MBConv + MBConv blocks -> head conv
    -> classifier."""
    specs = effnet_v2_block_specs(cfg)
    stem_c = round_filters(cfg.stem_c, cfg.width_mult)
    head_c = round_filters(cfg.head_c, cfg.width_mult)
    p: Dict[str, Any] = {
        "stem": P((3, 3, 3, stem_c), (None,) * 4),
        "head": P((specs[-1].c_out, head_c), (None, None), scale=2.0),
        "cls_w": P((head_c, cfg.num_classes), (None, None)),
        "cls_b": P((cfg.num_classes,), (None,), init="zeros"),
    }
    for i, sp in enumerate(specs):
        p[f"block{i}"] = block_def(sp)
    return p


def efficientnet_v2_s_apply(params: dict, images: jax.Array,
                            cfg: EffNetV2Config = EffNetV2Config(),
                            kcfg=None, mesh=None, plan=None) -> jax.Array:
    """(B, H, W, 3) images -> (B, num_classes) logits.

    EfficientNet-V2-S end to end: the fused stages run the SINGLE-PASS
    ``kernels.convdk_fusedmb_fused`` pipeline, the tail the two-pass
    MBConv pipeline — one mixed-family chain through
    ``models.blockgraph`` (one-pass nodes validate with empty pass 2;
    boundaries behind them stay serial) and, with a mesh, one
    family-generic network-level layout solve (fusedmb entries always
    replicated, the DP prices the boundary regathers accordingly)."""
    specs = effnet_v2_block_specs(cfg)
    dt = jnp.dtype(cfg.dtype)
    x = jax.lax.conv_general_dilated(
        images.astype(dt), params["stem"].astype(dt), (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.nn.silu(x)

    if kcfg is None:
        from ..configs.base import kernel_config
        kcfg = kernel_config()
    if plan is None and (mesh is not None and kcfg.shard_fused
                         and kcfg.fused_mbconv and kcfg.autotune):
        from ..core.autotune import get_network_plan
        from ..kernels import conv_mesh_shape
        b, h, w, _c0 = x.shape
        plan = get_network_plan(block_chain_rows(specs, h, w), b,
                                conv_mesh_shape(mesh),
                                dtype_bytes=dt.itemsize,
                                se_ratio=cfg.se_ratio)
    if plan is not None:
        if mesh is not None and plan.stem_layout == "model_sharded":
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as _P
            from ..kernels.convdk_sharded import MODEL_AXIS, _batch_axes
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, _P(_batch_axes(mesh), None, None,
                                          MODEL_AXIS)))

    from .blockgraph import build_block_graph
    graph = build_block_graph(specs, params, kcfg=kcfg, mesh=mesh,
                              plan=plan)
    graph.validate()
    x = graph.lower(x)
    x = jax.nn.silu(jnp.einsum("bhwc,cd->bhwd", x,
                               params["head"].astype(x.dtype)))
    x = x.mean(axis=(1, 2))
    return x @ params["cls_w"].astype(x.dtype) + params["cls_b"].astype(x.dtype)

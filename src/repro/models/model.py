"""Unified model assembly for all assigned architecture families.

One ``ModelConfig`` describes any of: dense decoder LMs (gemma / phi3 /
mistral-large / qwen1.5), MoE LMs (deepseek-v2 w/ MLA, granite), SSM
(mamba2), hybrid (recurrentgemma RG-LRU + local attention), encoder-only
(hubert) and VLM backbones (llava-next).

Key structural choices (DESIGN.md §Pillar C):

* **scan-over-layers**: per-layer params are stacked on a leading "layer"
  axis and the stack runs under ``jax.lax.scan`` — HLO size is O(1) in
  depth, which is what makes the 88-layer / 236B dry-run compile on a CPU
  host with 512 virtual devices.  Heterogeneous stacks (recurrentgemma's
  (R, R, A) pattern) scan over pattern blocks, remainder layers unrolled.
* **remat**: the scan body is wrapped in ``jax.checkpoint`` per config.
* Decode state is a per-layer-stacked pytree scanned in lock-step with the
  layer params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard
from . import attention as attn_mod
from . import mbconv as mbconv_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssd as ssd_mod
from .common import (
    dense, dense_def, embed, embed_def, head_def, rmsnorm, rmsnorm_def,
    separable_block, separable_def, unembed,
)
from .ffn import ffn, ffn_def
from .param import P, stack_defs


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"   # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 256
    vocab: int = 256
    act: str = "silu"
    glu: bool = True
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma-style sqrt(d) embedding scale
    logit_cap: float = 0.0
    # moe
    n_experts: int = 0
    n_experts_pad: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    n_dense_prefix: int = 0        # leading layers with dense FFN (deepseek)
    capacity_factor: float = 1.25
    # mla
    use_mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    d_nope: int = 0
    d_rope: int = 0
    # ssm
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssd_chunk: int = 256
    # hybrid
    window: int = 0
    pattern: Tuple[str, ...] = ()
    lru_width: int = 0
    # vlm
    n_img_tokens: int = 0
    vision_stem: bool = False      # conv patch-embed stem over raw images
    vision_stem_c0: int = 32       # stem width; doubles per separable block
    vision_stem_blocks: int = 2    # stride-2 separable blocks after the stem
    vision_stem_arch: str = "separable"  # "separable" | "mbconv" (SE) blocks
    # execution
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    use_convdk_kernel: bool = False
    q_chunk: int = 2048
    kv_chunk: int = 1024
    mla_absorb: bool = True
    # §Perf knobs (hillclimb; see EXPERIMENTS.md)
    vocab_pad_multiple: int = 0    # pad vocab so logits shard on "model"
    seq_shard_attn: bool = False   # sequence-parallel attention (shard_map)
    seq_shard_resid: bool = False  # Megatron-SP: seq-shard the residual stream

    # ---- derived ----
    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m if m else self.vocab

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssd_heads(self) -> int:
        return self.d_inner // 64 if self.family == "ssm" else 0

    def attn_cfg(self, window=None) -> attn_mod.AttnConfig:
        return attn_mod.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            rope_theta=self.rope_theta, qkv_bias=self.qkv_bias,
            causal=self.family != "encoder",
            window=window, q_chunk=self.q_chunk, kv_chunk=self.kv_chunk,
            logit_cap=self.logit_cap, seq_shard=self.seq_shard_attn,
        )

    def mla_cfg(self) -> mla_mod.MLAConfig:
        return mla_mod.MLAConfig(
            d_model=self.d_model, n_heads=self.n_heads, q_lora=self.q_lora,
            kv_lora=self.kv_lora, d_nope=self.d_nope, d_rope=self.d_rope,
            d_v=self.head_dim, rope_theta=self.rope_theta,
            q_chunk=self.q_chunk, kv_chunk=self.kv_chunk,
        )

    def moe_cfg(self) -> moe_mod.MoEConfig:
        return moe_mod.MoEConfig(
            d_model=self.d_model, n_experts=self.n_experts,
            n_experts_pad=self.n_experts_pad or self.n_experts,
            top_k=self.top_k, d_ff=self.d_ff_expert, act=self.act,
            capacity_factor=self.capacity_factor,
        )

    def ssd_cfg(self) -> ssd_mod.SSDConfig:
        return ssd_mod.SSDConfig(
            d_model=self.d_model, d_inner=self.d_inner,
            n_heads=self.d_inner // 64, head_dim=64, d_state=self.d_state,
            n_groups=1, d_conv=self.d_conv, chunk=self.ssd_chunk,
            use_kernel=self.use_convdk_kernel,
        )

    def rglru_cfg(self) -> rglru_mod.RGLRUConfig:
        return rglru_mod.RGLRUConfig(
            d_model=self.d_model, width=self.lru_width or self.d_model,
            d_conv=self.d_conv, use_kernel=self.use_convdk_kernel,
        )

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind sequence, e.g. ('A',)*n or ('R','R','A')*m."""
        if self.family == "hybrid":
            pat = self.pattern or ("R", "R", "A")
            reps = -(-self.n_layers // len(pat))
            return (pat * reps)[: self.n_layers]
        if self.family == "ssm":
            return ("S",) * self.n_layers
        return ("A",) * self.n_layers


# ---------------------------------------------------------------------------
# per-layer definitions
# ---------------------------------------------------------------------------

def _layer_def(cfg: ModelConfig, kind: str, moe: bool) -> dict:
    d = cfg.d_model
    if kind == "S":
        return {"norm": rmsnorm_def(d), "ssd": ssd_mod.ssd_def(cfg.ssd_cfg())}
    if kind == "R":
        return {"norm": rmsnorm_def(d),
                "rec": rglru_mod.rglru_def(cfg.rglru_cfg()),
                "ln2": rmsnorm_def(d),
                "ffn": ffn_def(d, cfg.d_ff, cfg.act, cfg.glu)}
    # attention layer
    p: Dict[str, Any] = {"ln1": rmsnorm_def(d)}
    if cfg.use_mla:
        p["attn"] = mla_mod.mla_def(cfg.mla_cfg())
    else:
        p["attn"] = attn_mod.attn_def(cfg.attn_cfg())
    p["ln2"] = rmsnorm_def(d)
    if moe:
        p["moe"] = moe_mod.moe_def(cfg.moe_cfg())
        if cfg.n_shared_experts:
            p["shared"] = ffn_def(d, cfg.n_shared_experts * cfg.d_ff_expert,
                                  cfg.act, cfg.glu)
    else:
        p["ffn"] = ffn_def(d, cfg.d_ff, cfg.act, cfg.glu)
    return p


def _apply_layer(lp: dict, x: jax.Array, cfg: ModelConfig, kind: str,
                 positions, use_chunked=None) -> jax.Array:
    if kind == "S":
        return x + ssd_mod.ssd_block(lp["ssd"], rmsnorm(lp["norm"], x),
                                     cfg.ssd_cfg())
    if kind == "R":
        h = x + rglru_mod.rglru_block(lp["rec"], rmsnorm(lp["norm"], x),
                                      cfg.rglru_cfg())
        return h + ffn(lp["ffn"], rmsnorm(lp["ln2"], h), cfg.act)
    window = cfg.window if (cfg.family == "hybrid" and kind == "A"
                            and cfg.window) else None
    h = rmsnorm(lp["ln1"], x)
    if cfg.use_mla:
        h = mla_mod.mla_attention(lp["attn"], h, cfg.mla_cfg(), positions)
    else:
        h = attn_mod.attention(lp["attn"], h, cfg.attn_cfg(window),
                               positions, use_chunked)
    x = x + h
    h = rmsnorm(lp["ln2"], x)
    if "moe" in lp:
        y = moe_mod.moe_apply(lp["moe"], h, cfg.moe_cfg())
        if "shared" in lp:
            y = y + ffn(lp["shared"], h, cfg.act)
    else:
        y = ffn(lp["ffn"], h, cfg.act)
    return x + y


# ---------------------------------------------------------------------------
# whole-model definition
# ---------------------------------------------------------------------------

def _layer_groups(cfg: ModelConfig):
    """Split layers into (prefix unrolled, scanned stack of identical
    blocks, remainder unrolled).  Each group entry = (kinds_tuple, count)."""
    kinds = cfg.layer_kinds()
    n_prefix = cfg.n_dense_prefix
    prefix = kinds[:n_prefix]
    rest = kinds[n_prefix:]
    if cfg.family == "hybrid":
        pat = cfg.pattern or ("R", "R", "A")
        blk = len(pat)
        n_blocks = len(rest) // blk
        rem = rest[n_blocks * blk:]
        return prefix, pat, n_blocks, rem
    return prefix, (rest[0],) if rest else (), len(rest), ()


def model_def(cfg: ModelConfig) -> dict:
    p: Dict[str, Any] = {"embed": embed_def(cfg.padded_vocab, cfg.d_model)}
    prefix, pat, n_blocks, rem = _layer_groups(cfg)
    moe = cfg.family == "moe"
    if prefix:
        p["prefix"] = [
            _layer_def(cfg, k, moe=False) for k in prefix  # dense prefix
        ]
    if n_blocks:
        block = {f"{i}_{k}": _layer_def(cfg, k, moe) for i, k in enumerate(pat)}
        p["stack"] = stack_defs(block, n_blocks)
    if rem:
        p["rem"] = [_layer_def(cfg, k, moe) for k in rem]
    p["final_norm"] = rmsnorm_def(cfg.d_model)
    if not cfg.tie_embeddings:
        p["head"] = head_def(cfg.d_model, cfg.padded_vocab)
    if cfg.family == "vlm":
        # frontend stub: precomputed patch embeddings get one projection
        p["img_proj"] = dense_def(cfg.d_model, cfg.d_model, ("embed", None))
        if cfg.vision_stem:
            p["vstem"] = vision_stem_def(cfg)
    if cfg.family == "encoder":
        # frontend stub: precomputed frame embeddings get one projection
        p["frame_proj"] = dense_def(cfg.d_model, cfg.d_model, ("embed", None))
    return p


def _stem_is_mbconv(cfg: ModelConfig) -> bool:
    if cfg.vision_stem_arch not in ("separable", "mbconv"):
        raise ValueError(
            f"vision_stem_arch must be 'separable' or 'mbconv', "
            f"got {cfg.vision_stem_arch!r}")
    return cfg.vision_stem_arch == "mbconv"


def vision_stem_def(cfg: ModelConfig) -> dict:
    """Conv patch-embed stem: 3x3/2 stem conv, then stride-2 blocks —
    separable (fused single-pass kernel) or MBConv with SE (two-pass fused
    kernel) per ``vision_stem_arch`` — then a 1x1 lift to d_model."""
    c = cfg.vision_stem_c0
    p: Dict[str, Any] = {"stem": P((3, 3, 3, c), (None,) * 4)}
    for i in range(cfg.vision_stem_blocks):
        if _stem_is_mbconv(cfg):
            p[f"sep{i}"] = mbconv_mod.mbconv_def(c, c * 2, k=3,
                                                 expand_ratio=4)
        else:
            p[f"sep{i}"] = separable_def(c, c * 2, k=3)
        c *= 2
    p["lift"] = dense_def(c, cfg.d_model, (None, "embed"))
    return p


def apply_vision_stem(params: dict, images: jax.Array,
                      cfg: ModelConfig) -> jax.Array:
    """(B, H, W, 3) raw images -> (B, n_patches, d_model) patch embeddings.

    Every block routes through a fused ConvDK kernel (behind the
    ``configs.base.kernel_config()`` flags): one-pass DW+PW for separable
    stems, the two-pass SE-aware pipeline for MBConv stems — the paper's
    dataflow as the VLM vision frontend.
    """
    x = jax.lax.conv_general_dilated(
        images.astype(jnp.float32), params["stem"].astype(jnp.float32),
        (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.nn.relu(x)
    for i in range(cfg.vision_stem_blocks):
        if _stem_is_mbconv(cfg):
            x, _lay = mbconv_mod.mbconv_block(x, params[f"sep{i}"], stride=2)
        else:
            x, _lay = separable_block(x, params[f"sep{i}"], stride=2)
    b, h, w, c = x.shape
    tokens = dense(params["lift"], x.reshape(b, h * w, c))
    return tokens.astype(cfg.adtype)


def _apply_block(lp: dict, x, cfg, pat, positions, use_chunked):
    for i, k in enumerate(pat):
        x = _apply_layer(lp[f"{i}_{k}"], x, cfg, k, positions, use_chunked)
    return x


def _mask_pad_logits(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Vocab-padding mask: padded classes get -inf so CE / sampling ignore
    them.  Elementwise on the sharded vocab dim — no resharding."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    pad = jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, logits.ndim - 1) >= cfg.vocab
    return jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)


def forward(
    params: dict,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    use_chunked: Optional[bool] = None,
) -> jax.Array:
    """Full-sequence forward -> logits (B, S, V).

    batch: {"tokens": (B,S)} and/or {"embeds": (B,S,D)} and/or
           {"img_embeds": (B,N,D)} (VLM: image embeds are prepended).
    """
    dt = cfg.adtype
    if "embeds" in batch:
        x = batch["embeds"].astype(dt)
        if cfg.family == "encoder":
            x = dense(params["frame_proj"], x)
    else:
        x = embed(params["embed"], batch["tokens"], dt)
    if cfg.family == "vlm" and "images" in batch and cfg.vision_stem:
        embeds = apply_vision_stem(params["vstem"], batch["images"], cfg)
        img = dense(params["img_proj"], embeds)
        x = jnp.concatenate([img, x], axis=1)
    elif cfg.family == "vlm" and "img_embeds" in batch:
        img = dense(params["img_proj"], batch["img_embeds"].astype(dt))
        x = jnp.concatenate([img, x], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    x = shard(x, "batch", "seq", "act_embed")

    s = x.shape[1]
    positions = jnp.arange(s)
    prefix, pat, n_blocks, rem = _layer_groups(cfg)

    for lp, k in zip(params.get("prefix", []), prefix):
        x = _apply_layer(lp, x, cfg, k, positions, use_chunked)

    if n_blocks:
        seq_ax = "seq_model" if cfg.seq_shard_resid else "seq"

        def body(x, lp):
            x = _apply_block(lp, x, cfg, pat, positions, use_chunked)
            return shard(x, "batch", seq_ax, "act_embed"), None

        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["stack"])
        else:
            stacked = params["stack"]
            for i in range(n_blocks):
                lp = jax.tree.map(lambda a: a[i], stacked)
                x, _ = body(x, lp)

    for lp, k in zip(params.get("rem", []), rem):
        x = _apply_layer(lp, x, cfg, k, positions, use_chunked)

    x = rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["head"], x)
    logits = shard(logits, "batch", "seq", "act_vocab")
    logits = _mask_pad_logits(logits, cfg)
    if cfg.logit_cap > 0:
        logits = cfg.logit_cap * jnp.tanh(logits / cfg.logit_cap)
    return logits


# ---------------------------------------------------------------------------
# decode (single-token serve step)
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, kind: str, batch: int, s_max: int, dtype):
    if kind == "S":
        return ssd_mod.init_ssd_state(batch, cfg.ssd_cfg(), dtype)
    if kind == "R":
        return rglru_mod.init_rglru_state(batch, cfg.rglru_cfg(), dtype)
    if cfg.use_mla:
        return mla_mod.init_mla_cache(batch, s_max, cfg.mla_cfg(), dtype)
    window = cfg.window if (cfg.family == "hybrid" and cfg.window) else None
    return attn_mod.init_kv_cache(batch, s_max, cfg.attn_cfg(window), dtype)


def init_decode_state(cfg: ModelConfig, batch: int, s_max: int,
                      dtype=jnp.bfloat16) -> dict:
    """Per-layer cache pytree, stacked along the scan axis for the stack."""
    prefix, pat, n_blocks, rem = _layer_groups(cfg)
    state: Dict[str, Any] = {}
    if prefix:
        state["prefix"] = [_layer_cache(cfg, k, batch, s_max, dtype)
                           for k in prefix]
    if n_blocks:
        block = {f"{i}_{k}": _layer_cache(cfg, k, batch, s_max, dtype)
                 for i, k in enumerate(pat)}
        state["stack"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_blocks,) + a.shape).copy(), block)
    if rem:
        state["rem"] = [_layer_cache(cfg, k, batch, s_max, dtype) for k in rem]
    return state


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def _layer_cache_axes(cfg: ModelConfig, kind: str):
    """Logical sharding axes parallel to ``_layer_cache`` structures."""
    if kind == "S":
        return ssd_mod.SSDState(
            conv_x=("batch", None, "dinner"),
            conv_b=("batch", None, None),
            conv_c=("batch", None, None),
            ssm=("batch", "heads", None, None),
        )
    if kind == "R":
        return rglru_mod.RGLRUState(conv=("batch", None, "dinner"),
                                    h=("batch", "dinner"))
    if cfg.use_mla:
        return mla_mod.MLACache(c_kv=("batch", None, None),
                                k_rope=("batch", None, None), pos=())
    return attn_mod.KVCache(k=("batch", None, "kv_heads", None),
                            v=("batch", None, "kv_heads", None),
                            slot_pos=(None,), pos=())


def decode_state_axes(cfg: ModelConfig) -> dict:
    """Logical axes pytree matching ``init_decode_state`` exactly."""
    prefix, pat, n_blocks, rem = _layer_groups(cfg)
    axes: Dict[str, Any] = {}
    if prefix:
        axes["prefix"] = [_layer_cache_axes(cfg, k) for k in prefix]
    if n_blocks:
        block = {f"{i}_{k}": _layer_cache_axes(cfg, k)
                 for i, k in enumerate(pat)}
        axes["stack"] = jax.tree.map(lambda t: (None,) + t, block,
                                     is_leaf=_is_axes)
    if rem:
        axes["rem"] = [_layer_cache_axes(cfg, k) for k in rem]
    return axes


def _decode_layer(lp: dict, x: jax.Array, cache, cfg: ModelConfig, kind: str):
    if kind == "S":
        y, nc = ssd_mod.ssd_decode_step(lp["ssd"], rmsnorm(lp["norm"], x),
                                        cache, cfg.ssd_cfg())
        return x + y, nc
    if kind == "R":
        y, nc = rglru_mod.rglru_decode_step(lp["rec"], rmsnorm(lp["norm"], x),
                                            cache, cfg.rglru_cfg())
        h = x + y
        return h + ffn(lp["ffn"], rmsnorm(lp["ln2"], h), cfg.act), nc
    h = rmsnorm(lp["ln1"], x)
    if cfg.use_mla:
        y, nc = mla_mod.mla_decode(lp["attn"], h, cache, cfg.mla_cfg(),
                                   absorb=cfg.mla_absorb)
    else:
        window = cfg.window if (cfg.family == "hybrid" and cfg.window) else None
        y, nc = attn_mod.decode_attention(lp["attn"], h, cache,
                                          cfg.attn_cfg(window))
    x = x + y
    h = rmsnorm(lp["ln2"], x)
    if "moe" in lp:
        y = moe_mod.moe_apply(lp["moe"], h, cfg.moe_cfg())
        if "shared" in lp:
            y = y + ffn(lp["shared"], h, cfg.act)
    else:
        y = ffn(lp["ffn"], h, cfg.act)
    return x + y, nc


def decode_step(
    params: dict, state: dict, batch_t: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, dict]:
    """One serve step: next-token logits (B, V) + updated state."""
    dt = cfg.adtype
    if "embeds" in batch_t:
        x = batch_t["embeds"].astype(dt)
        if x.ndim == 2:
            x = x[:, None]
    else:
        tok = batch_t["tokens"]
        if tok.ndim == 1:
            tok = tok[:, None]
        x = embed(params["embed"], tok, dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    x = shard(x, "batch", None, "act_embed")

    prefix, pat, n_blocks, rem = _layer_groups(cfg)
    new_state: Dict[str, Any] = {}

    if prefix:
        caches = []
        for lp, k, c in zip(params["prefix"], prefix, state["prefix"]):
            x, nc = _decode_layer(lp, x, c, cfg, k)
            caches.append(nc)
        new_state["prefix"] = caches

    if n_blocks:
        def body(x, scanned):
            lp, cache_blk = scanned
            new_blk = {}
            for i, k in enumerate(pat):
                key = f"{i}_{k}"
                x, nc = _decode_layer(lp[key], x, cache_blk[key], cfg, k)
                new_blk[key] = nc
            return x, new_blk

        x, new_stack = jax.lax.scan(body, x, (params["stack"], state["stack"]))
        new_state["stack"] = new_stack

    if rem:
        caches = []
        for lp, k, c in zip(params["rem"], rem, state["rem"]):
            x, nc = _decode_layer(lp, x, c, cfg, k)
            caches.append(nc)
        new_state["rem"] = caches

    x = rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["head"], x)
    logits = shard(logits, "batch", None, "act_vocab")
    logits = _mask_pad_logits(logits, cfg)
    if cfg.logit_cap > 0:
        logits = cfg.logit_cap * jnp.tanh(logits / cfg.logit_cap)
    return logits[:, 0], new_state

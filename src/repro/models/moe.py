"""Mixture-of-Experts with sort-based capacity dispatch and expert
parallelism.

Design (DESIGN.md §Risks):

* Dispatch is SORT-based (argsort by expert id + rank-within-expert via
  cummax), not GShard one-hot einsum — the one-hot dispatch tensor is
  O(T * E * C) and explodes at 160-expert / 65k-token shards.
* Expert parallelism runs under ``jax.shard_map`` over the "model" mesh
  axis: activations arrive batch-sharded (replicated across "model"), each
  model shard owns E/M experts, computes its local experts' contributions
  for ALL its tokens, and a single psum over "model" combines — the same
  collective cost as a tensor-parallel FFN all-reduce, with zero all_to_all.
* Experts are padded to a multiple of the model-axis size (router logits of
  padding experts are masked to -inf), e.g. granite's 40 -> 48.
* Per-expert capacity C = ceil(cf * T * k / E) bounds the buffer; overflow
  tokens fall into a discard slot (standard capacity-drop semantics).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import act_fn
from .param import P


class MoEConfig(NamedTuple):
    d_model: int
    n_experts: int          # real experts
    n_experts_pad: int      # padded for mesh divisibility
    top_k: int
    d_ff: int               # per-expert hidden
    act: str = "silu"
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


def moe_def(cfg: MoEConfig) -> dict:
    e, d, f = cfg.n_experts_pad, cfg.d_model, cfg.d_ff
    return {
        "router": P((d, e), ("embed", None)),
        "gate": P((e, d, f), ("experts", "embed", "ff")),
        "up": P((e, d, f), ("experts", "embed", "ff")),
        "down": P((e, f, d), ("experts", "ff", "embed")),
    }


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    cap = math.ceil(cfg.capacity_factor * tokens * cfg.top_k
                    / cfg.n_experts)
    return max(cfg.top_k, -(-cap // 8) * 8)   # round up to 8


def _moe_local(x, router_w, w_gate, w_up, w_down, *, cfg: MoEConfig,
               e_start, capacity: int):
    """Local-shard MoE: x (T, D); w_* hold E_loc experts starting at e_start.

    Returns this shard's partial output (T, D) — caller psums over "model".
    """
    t, d = x.shape
    e_loc = w_gate.shape[0]
    k = cfg.top_k

    # --- routing (fp32 for numerics) ---
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (T, E_pad)
    if cfg.n_experts_pad > cfg.n_experts:
        pad_mask = jnp.arange(cfg.n_experts_pad) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                         # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- sort-based dispatch ---
    flat_e = top_e.reshape(-1)                                     # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = order // k                                                # token ids
    sw = top_w.reshape(-1)[order]
    idx = jnp.arange(t * k)
    starts = jnp.where(jnp.concatenate([jnp.array([True]),
                                        se[1:] != se[:-1]]), idx, 0)
    rank = idx - jax.lax.cummax(starts)                            # pos in expert

    local = (se >= e_start) & (se < e_start + e_loc) & (rank < capacity)
    slot = jnp.where(local, (se - e_start) * capacity + rank,
                     e_loc * capacity)                             # discard slot
    gathered = x[st] * local[:, None].astype(x.dtype)
    buf = jnp.zeros((e_loc * capacity + 1, d), x.dtype).at[slot].add(gathered)
    buf = buf[:-1].reshape(e_loc, capacity, d)

    # --- expert FFNs (grouped GEMMs) ---
    act = act_fn(cfg.act)
    hg = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(x.dtype))
    hu = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(x.dtype))
    h = act(hg) * hu
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype))

    # --- combine ---
    yflat = jnp.concatenate(
        [y.reshape(e_loc * capacity, d), jnp.zeros((1, d), y.dtype)], axis=0)
    contrib = yflat[slot] * (sw * local).astype(y.dtype)[:, None]
    out = jnp.zeros((t, d), x.dtype).at[st].add(contrib)
    return out


def moe_apply(params: dict, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).  Expert-parallel over the "model" axis when
    a mesh context is active; plain local execution otherwise."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)

    mesh = None
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty and "model" in am.shape:
            mesh = am
    except Exception:
        mesh = None

    if mesh is None or mesh.shape["model"] == 1:
        cap = _capacity(xf.shape[0], cfg)
        out = _moe_local(xf, params["router"], params["gate"], params["up"],
                         params["down"], cfg=cfg, e_start=0, capacity=cap)
        return out.reshape(b, s, d)

    m = mesh.shape["model"]
    assert cfg.n_experts_pad % m == 0, (cfg.n_experts_pad, m)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    t_local = xf.shape[0] // math.prod(mesh.shape[a] for a in dp_axes)
    cap = _capacity(t_local, cfg)

    from jax.sharding import PartitionSpec as PS

    def shard_fn(xl, rw, wg, wu, wd):
        e_start = jax.lax.axis_index("model") * (cfg.n_experts_pad // m)
        out = _moe_local(xl, rw, wg, wu, wd, cfg=cfg,
                         e_start=e_start, capacity=cap)
        return jax.lax.psum(out, "model")

    out = jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(PS(dp_axes, None), PS(None, None),
                  PS("model", None, None), PS("model", None, None),
                  PS("model", None, None)),
        out_specs=PS(dp_axes, None),
    )(xf, params["router"], params["gate"], params["up"], params["down"])
    return out.reshape(b, s, d)

"""Shared building blocks: norms, RoPE, dense projections, embeddings, loss."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .param import P


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_def(d: int) -> dict:
    return {"scale": P((d,), (None,), init="ones")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_def(d: int) -> dict:
    return {"scale": P((d,), (None,), init="ones"),
            "bias": P((d,), (None,), init="zeros")}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

def dense_def(d_in: int, d_out: int, axes: Tuple[Optional[str], ...],
              bias: bool = False, scale: Optional[float] = None) -> dict:
    d = {"w": P((d_in, d_out), axes, scale=scale)}
    if bias:
        d["b"] = P((d_out,), (axes[-1],), init="zeros")
    return d


def dense(params: dict, x: jax.Array) -> jax.Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# depthwise-separable conv block (MobileNet/EfficientNet building block)
# ---------------------------------------------------------------------------

def separable_def(c_in: int, c_out: int, k: int = 3) -> dict:
    """Params of one depthwise-separable block: k x k DW taps + 1x1 PW."""
    return {
        "dw": P((k, k, c_in), (None, None, None)),
        "pw": P((c_in, c_out), (None, None), scale=2.0),
    }


def separable_block(
    params: dict,
    x: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    dw_act: Optional[str] = "relu",
    act: Optional[str] = "relu",
    kcfg=None,
    mesh=None,
) -> jax.Array:
    """Apply one separable block, routed by the conv-kernel config.

    With ``kcfg.fused_separable`` (the default) the whole block runs as ONE
    Pallas kernel — in-kernel strip staging, DW taps, mid-block activation
    and the 1x1 projection in a single VMEM residency (one HBM read of
    ``x``, one HBM write of the output).  Otherwise the staged two-kernel
    pipeline runs (DW kernel -> HBM -> PW matmul).  ``kcfg`` defaults to
    ``repro.configs.base.kernel_config()``.

    With a ``mesh`` (and ``kcfg.shard_fused``), the fused kernel runs
    mesh-sharded via ``shard_map``: batch on "data", c_out on "model"
    (``kernels.convdk_fused_separable_sharded``) — falling back to the
    single-device kernel when the mesh axes do not divide the grid.  The
    schedule is then solved per partitioning (``mesh_shape`` is a cache
    key axis).

    x: (B, H, W, C_in) NHWC -> (B, H', W', C_out).
    """
    if kcfg is None:
        # lazy import: configs.base imports models.model -> models.common
        from ..configs.base import kernel_config
        kcfg = kernel_config()
    from ..kernels import (
        can_shard_fused, conv_mesh_shape, convdk_fused_separable,
        convdk_fused_separable_sharded, convdk_separable_staged,
    )

    w_dw = params["dw"].astype(x.dtype)
    w_pw = params["pw"].astype(x.dtype)
    sharded = (mesh is not None and kcfg.shard_fused and kcfg.fused_separable
               and can_shard_fused(mesh, x.shape[0], w_pw.shape[1]))
    mesh_shape = conv_mesh_shape(mesh) if sharded else (1, 1)
    tile_h, residency = kcfg.tile_h, kcfg.residency
    if kcfg.autotune:
        from ..core.autotune import get_fused_schedule
        b, h, w, c_in = x.shape
        sch = get_fused_schedule(
            b, h, w, c_in, w_pw.shape[1], w_dw.shape[0], stride,
            dtype_bytes=x.dtype.itemsize, mesh_shape=mesh_shape,
            residency=kcfg.residency)
        tile_h, residency = sch.tile_h, sch.residency
    if sharded:
        return convdk_fused_separable_sharded(
            x, w_dw, w_pw, mesh=mesh, stride=stride, padding=padding,
            tile_h=tile_h, dw_act=dw_act, act=act, interpret=kcfg.interpret,
            residency=residency)
    if kcfg.fused_separable:
        return convdk_fused_separable(
            x, w_dw, w_pw, stride=stride, padding=padding, tile_h=tile_h,
            dw_act=dw_act, act=act, interpret=kcfg.interpret,
            residency=residency)
    return convdk_separable_staged(
        x, w_dw, w_pw, stride=stride, padding=padding, tile_h=tile_h,
        dw_act=dw_act, act=act, interpret=kcfg.interpret)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """x: (..., S, H, D) with positions (..., S) (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings & head
# ---------------------------------------------------------------------------

def embed_def(vocab: int, d: int) -> dict:
    return {"table": P((vocab, d), ("vocab", "embed"), init="embed")}


def embed(params: dict, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """Logits via the (possibly tied) embedding table."""
    return x @ params["table"].astype(x.dtype).T


def head_def(d: int, vocab: int) -> dict:
    return {"w": P((d, vocab), ("embed", "vocab"))}


# ---------------------------------------------------------------------------
# activations / loss
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "gelu_exact": lambda x: jax.nn.gelu(x, approximate=False),
        "relu": jax.nn.relu,
    }[name]


def softmax_cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Mean CE over valid positions.  logits (..., V), labels (...) int.

    The gold logit is extracted with a one-hot masked REDUCTION, not
    ``take_along_axis``: a gather along the vocab dim would force GSPMD to
    all-gather the (tokens, V) logits that are deliberately vocab-sharded
    (34 GB/device for a 256k vocab at 65k tokens) — the reduction keeps
    every op tiled."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()

"""Shared building blocks: norms, RoPE, dense projections, embeddings, loss."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .param import P


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_def(d: int) -> dict:
    return {"scale": P((d,), (None,), init="ones")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_def(d: int) -> dict:
    return {"scale": P((d,), (None,), init="ones"),
            "bias": P((d,), (None,), init="zeros")}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

def dense_def(d_in: int, d_out: int, axes: Tuple[Optional[str], ...],
              bias: bool = False, scale: Optional[float] = None) -> dict:
    d = {"w": P((d_in, d_out), axes, scale=scale)}
    if bias:
        d["b"] = P((d_out,), (axes[-1],), init="zeros")
    return d


def dense(params: dict, x: jax.Array) -> jax.Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# depthwise-separable conv block (MobileNet/EfficientNet building block)
# ---------------------------------------------------------------------------

def separable_def(c_in: int, c_out: int, k: int = 3) -> dict:
    """Params of one depthwise-separable block: k x k DW taps + 1x1 PW."""
    return {
        "dw": P((k, k, c_in), (None, None, None)),
        "pw": P((c_in, c_out), (None, None), scale=2.0),
    }


def separable_block(
    x,
    params=None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    dw_act: Optional[str] = "relu",
    act: Optional[str] = "relu",
    cfg=None,
    mesh=None,
    pin=None,
    in_layout: str = "replicated",
    kcfg=None,
):
    """Apply one separable block, routed by the conv-kernel config.

    Canonical signature: ``separable_block(x, params, *, cfg, mesh, pin,
    in_layout)`` returning ``(y, out_layout)`` — symmetric with
    ``mbconv_block``, so the network-level layout solver can thread a
    block chain through either family.  The legacy positional order
    (``params`` first, bare-array return) and the ``kcfg=`` kwarg keep
    working behind a warn-once deprecation shim.

    With ``fused`` (the default) the whole block runs as ONE Pallas
    kernel — in-kernel strip staging, DW taps, mid-block activation and
    the 1x1 projection in a single VMEM residency (one HBM read of
    ``x``, one HBM write of the output).  Otherwise the staged two-kernel
    pipeline runs (DW kernel -> HBM -> PW matmul).  ``cfg`` defaults to
    ``repro.configs.base.kernel_config()``; ``pin`` (a ``SchedulePin``)
    overrides any subset of the solved axes.

    With a ``mesh`` (and the shard toggle), the fused kernel runs
    mesh-sharded via ``shard_map``; ``in_layout`` declares the arrival
    layout: ``"replicated"`` shards c_out on "model" (collective-free),
    ``"model_sharded"`` consumes a c_in-sharded arrival without a gather
    and reduces the PW partials per the pinned collective
    (``kernels.convdk_fused_separable_sharded``) — falling back to the
    single-device kernel when the mesh axes do not divide the grid.  The
    schedule is solved per (partitioning, layout).  ``out_layout`` is
    ``"model_sharded"`` iff the output physically leaves sharded on
    c_out for a layout-aware consumer (sharded-in + psum_scatter exit on
    a dividing c_out), else ``"replicated"``.

    x: (B, H, W, C_in) NHWC -> (B, H', W', C_out).
    """
    from ..configs.base import _warn_once, kernel_config, resolve_pin
    legacy_call = isinstance(x, dict)
    if legacy_call:
        _warn_once(
            "separable_block_positional",
            "separable_block(params, x) is deprecated; call "
            "separable_block(x, params, ...) — the new order returns "
            "(y, out_layout)")
        x, params = params, x
    if kcfg is not None:
        _warn_once(
            "block_kcfg_kwarg",
            "the kcfg= kwarg on block entries is deprecated; pass cfg=")
        if cfg is None:
            cfg = kcfg
    if cfg is None:
        cfg = kernel_config()
    from ..core.perfmodel import DEFAULT_COLLECTIVE, validate_layout
    from ..kernels import (
        can_shard_fused, conv_mesh_shape, convdk_fused_separable,
        convdk_fused_separable_sharded, convdk_separable_staged,
    )

    validate_layout(in_layout)
    eff = resolve_pin(cfg, pin, family="separable")
    w_dw = params["dw"].astype(x.dtype)
    w_pw = params["pw"].astype(x.dtype)
    c_out = w_pw.shape[1]
    want_sharded_in = in_layout == "model_sharded"
    # the arrival layout picks the partitioned axis the mesh must divide:
    # classic replicated-in shards c_out, sharded-in shards c_in
    shard_c = x.shape[-1] if want_sharded_in else c_out
    sharded = (mesh is not None and eff.shard and eff.fused
               and can_shard_fused(mesh, x.shape[0], shard_c))
    mesh_shape = conv_mesh_shape(mesh) if sharded else (1, 1)
    eff_in_layout = "model_sharded" if (sharded and want_sharded_in) \
        else "replicated"
    collective = eff.resolved_collective or DEFAULT_COLLECTIVE
    tile_h, residency = cfg.tile_h, eff.residency
    if cfg.autotune:
        from ..core.autotune import get_fused_schedule
        b, h, w, c_in = x.shape
        sch = get_fused_schedule(
            b, h, w, c_in, c_out, w_dw.shape[0], stride,
            dtype_bytes=x.dtype.itemsize, mesh_shape=mesh_shape,
            residency=eff.residency, in_layout=eff_in_layout,
            collective=collective)
        tile_h, residency = sch.tile_h, sch.residency
    if sharded:
        out = convdk_fused_separable_sharded(
            x, w_dw, w_pw, mesh=mesh, stride=stride, padding=padding,
            tile_h=tile_h, dw_act=dw_act, act=act, interpret=cfg.interpret,
            residency=residency, collective=collective,
            in_layout=eff_in_layout)
        out_layout = ("model_sharded"
                      if (eff_in_layout == "model_sharded"
                          and collective == "psum_scatter"
                          and c_out % mesh_shape[1] == 0)
                      else "replicated")
    elif eff.fused:
        out = convdk_fused_separable(
            x, w_dw, w_pw, stride=stride, padding=padding, tile_h=tile_h,
            dw_act=dw_act, act=act, interpret=cfg.interpret,
            residency=residency)
        out_layout = "replicated"
    else:
        out = convdk_separable_staged(
            x, w_dw, w_pw, stride=stride, padding=padding, tile_h=tile_h,
            dw_act=dw_act, act=act, interpret=cfg.interpret)
        out_layout = "replicated"
    if legacy_call:
        return out
    return out, out_layout


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """x: (..., S, H, D) with positions (..., S) (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings & head
# ---------------------------------------------------------------------------

def embed_def(vocab: int, d: int) -> dict:
    return {"table": P((vocab, d), ("vocab", "embed"), init="embed")}


def embed(params: dict, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """Logits via the (possibly tied) embedding table."""
    return x @ params["table"].astype(x.dtype).T


def head_def(d: int, vocab: int) -> dict:
    return {"w": P((d, vocab), ("embed", "vocab"))}


# ---------------------------------------------------------------------------
# activations / loss
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "gelu_exact": lambda x: jax.nn.gelu(x, approximate=False),
        "relu": jax.nn.relu,
    }[name]


def softmax_cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Mean CE over valid positions.  logits (..., V), labels (...) int.

    The gold logit is extracted with a one-hot masked REDUCTION, not
    ``take_along_axis``: a gather along the vocab dim would force GSPMD to
    all-gather the (tokens, V) logits that are deliberately vocab-sharded
    (34 GB/device for a 256k vocab at 65k tokens) — the reduction keeps
    every op tiled."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()

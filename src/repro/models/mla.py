"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed into a ``kv_lora``-dim latent ``c_kv`` plus one shared
RoPE key; queries go through a ``q_lora`` bottleneck.  The decode cache holds
ONLY (c_kv, k_rope) — the latent cache that makes MLA's KV memory ~1/8 of
GQA's.  Decode supports the ABSORBED form (W_uk folded into the query,
W_uv folded into the output), so per-step FLOPs never expand the latents
back to per-head K/V.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard
from .common import apply_rope, dense, dense_def, rmsnorm, rmsnorm_def
from .param import P

NEG_INF = -2.0e38


class MLAConfig(NamedTuple):
    d_model: int
    n_heads: int
    q_lora: int
    kv_lora: int
    d_nope: int          # per-head non-rotary dim
    d_rope: int          # rotary dim (shared key)
    d_v: int             # per-head value dim
    rope_theta: float = 10000.0
    q_chunk: int = 2048
    kv_chunk: int = 1024


def mla_def(cfg: MLAConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dq = cfg.d_nope + cfg.d_rope
    return {
        "dq": dense_def(d, cfg.q_lora, ("embed", "lora")),
        "q_norm": rmsnorm_def(cfg.q_lora),
        "uq": dense_def(cfg.q_lora, h * dq, ("lora", "heads")),
        "dkv": dense_def(d, cfg.kv_lora, ("embed", "lora")),
        "kv_norm": rmsnorm_def(cfg.kv_lora),
        "kr": dense_def(d, cfg.d_rope, ("embed", None)),
        "uk": P((cfg.kv_lora, h, cfg.d_nope), ("lora", "heads", None)),
        "uv": P((cfg.kv_lora, h, cfg.d_v), ("lora", "heads", None)),
        "o": dense_def(h * cfg.d_v, d, ("heads", "embed")),
    }


def _project_q(params, x, cfg: MLAConfig, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    q = dense(params["uq"], rmsnorm(params["q_norm"], dense(params["dq"], x)))
    q = q.reshape(b, s, h, cfg.d_nope + cfg.d_rope)
    q_nope, q_rope = q[..., :cfg.d_nope], q[..., cfg.d_nope:]
    q_rope = apply_rope(q_rope, positions[None, :], cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(params, x, cfg: MLAConfig, positions):
    c_kv = rmsnorm(params["kv_norm"], dense(params["dkv"], x))  # (B,S,L)
    k_rope = dense(params["kr"], x)[:, :, None, :]              # (B,S,1,dr)
    k_rope = apply_rope(k_rope, positions[None, :], cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(
    params: dict, x: jax.Array, cfg: MLAConfig,
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence (training / prefill) MLA.  x: (B, S, D).

    The two-part MLA score (nope + shared-rope) is folded into ONE standard
    attention by concatenating [q_nope | q_rope] against
    [k_nope | broadcast k_rope] (d_qk = d_nope + d_rope, d_v = d_v), so the
    long-context path reuses the chunked online-softmax machinery —
    without it the 32k deepseek cells materialize (B,H,S,S) f32 scores
    (165-217 GB/device, §Perf).
    """
    from .attention import _chunked_sdpa, _mask_bias, _sdpa

    b, s, _ = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope = _project_q(params, x, cfg, positions)
    c_kv, k_rope = _project_kv_latent(params, x, cfg, positions)

    # expand latents for training (absorbed path is decode-only)
    k_nope = jnp.einsum("bsl,lhd->bshd", c_kv, params["uk"].astype(x.dtype))
    v = jnp.einsum("bsl,lhd->bshd", c_kv, params["uv"].astype(x.dtype))
    q_nope = shard(q_nope, "batch", None, "act_heads", None)
    k_nope = shard(k_nope, "batch", None, "act_heads", None)
    v = shard(v, "batch", None, "act_heads", None)

    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)      # (B,S,H,dn+dr)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, cfg.d_rope))], axis=-1)
    scale = (cfg.d_nope + cfg.d_rope) ** -0.5
    if s > cfg.q_chunk:
        out = _chunked_sdpa(q_cat, k_cat, v, positions, positions,
                            True, None, scale, 0.0,
                            cfg.q_chunk, cfg.kv_chunk)
    else:
        bias = _mask_bias(positions, positions, True, None)
        out = _sdpa(q_cat, k_cat, v, bias, scale, 0.0)
    out = out.reshape(b, s, h * cfg.d_v).astype(x.dtype)
    return dense(params["o"], out)


# ---------------------------------------------------------------------------
# latent-cache decode (absorbed)
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jax.Array      # (B, S_max, kv_lora)
    k_rope: jax.Array    # (B, S_max, d_rope)
    pos: jax.Array


def init_mla_cache(batch: int, s_max: int, cfg: MLAConfig,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, s_max, cfg.kv_lora), dtype),
        k_rope=jnp.zeros((batch, s_max, cfg.d_rope), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def mla_decode(
    params: dict, x_t: jax.Array, cache: MLACache, cfg: MLAConfig,
    absorb: bool = True,
) -> Tuple[jax.Array, MLACache]:
    """One-token MLA step with the latent cache.  x_t: (B, 1, D)."""
    b = x_t.shape[0]
    h = cfg.n_heads
    pos = cache.pos
    posv = jnp.broadcast_to(pos[None, None], (b, 1))
    q_nope, q_rope = _project_q(params, x_t, cfg, posv[0])
    c_t, kr_t = _project_kv_latent(params, x_t, cfg, posv[0])

    c_all = jax.lax.dynamic_update_slice(
        cache.c_kv, c_t.astype(cache.c_kv.dtype), (0, pos, 0))
    kr_all = jax.lax.dynamic_update_slice(
        cache.k_rope, kr_t.astype(cache.k_rope.dtype), (0, pos, 0))

    s_max = c_all.shape[1]
    valid = jnp.arange(s_max) <= pos
    bias = jnp.where(valid, 0.0, NEG_INF)
    scale = (cfg.d_nope + cfg.d_rope) ** -0.5

    if absorb:
        # fold W_uk into q: (B,1,H,dn) x (L,H,dn) -> (B,H,L)
        q_abs = jnp.einsum("bqhd,lhd->bhl", q_nope.astype(jnp.float32),
                           params["uk"].astype(jnp.float32))
        s_lat = jnp.einsum("bhl,bsl->bhs", q_abs,
                           c_all.astype(jnp.float32))
        s_rope = jnp.einsum("bqhd,bsd->bhs", q_rope.astype(jnp.float32),
                            kr_all.astype(jnp.float32))
        probs = jax.nn.softmax((s_lat + s_rope) * scale + bias[None, None],
                               axis=-1)
        o_lat = jnp.einsum("bhs,bsl->bhl", probs, c_all.astype(jnp.float32))
        out = jnp.einsum("bhl,lhd->bhd", o_lat,
                         params["uv"].astype(jnp.float32))
    else:
        k_nope = jnp.einsum("bsl,lhd->bshd", c_all.astype(jnp.float32),
                            params["uk"].astype(jnp.float32))
        v = jnp.einsum("bsl,lhd->bshd", c_all.astype(jnp.float32),
                       params["uv"].astype(jnp.float32))
        s_n = jnp.einsum("bqhd,bshd->bhs", q_nope.astype(jnp.float32), k_nope)
        s_r = jnp.einsum("bqhd,bsd->bhs", q_rope.astype(jnp.float32),
                         kr_all.astype(jnp.float32))
        probs = jax.nn.softmax((s_n + s_r) * scale + bias[None, None], axis=-1)
        out = jnp.einsum("bhs,bshd->bhd", probs, v)

    out = out.reshape(b, 1, h * cfg.d_v).astype(x_t.dtype)
    y = dense(params["o"], out)
    return y, MLACache(c_kv=c_all, k_rope=kr_all, pos=pos + 1)

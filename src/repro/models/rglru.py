"""Griffin / RecurrentGemma recurrent block (arXiv:2402.19427).

Block:  x -> { gate branch: GeLU(W_y x) }
             { rec  branch: RG-LRU(ConvDK-conv1d(W_x x)) }
        out = W_o(gate * rec)

RG-LRU:  r_t = sigmoid(W_a u_t + b_a)          (recurrence gate)
         i_t = sigmoid(W_i u_t + b_i)          (input gate)
         log a_t = -c * softplus(Lambda) * r_t  (c = 8)
         h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The sequence recurrence runs as a parallel associative scan (O(log L)
depth); decode is the O(1) single-step update — this is why the
``long_500k`` cell is linear for recurrentgemma.  The temporal conv (width
4) is the paper-technique hot-spot (ConvDK kernel / shift-add path).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.ops import convdk_causal_conv1d
from ..kernels.ref import causal_conv1d_ref, causal_conv1d_update_ref
from ..sharding import shard
from .common import dense, dense_def
from .param import P

_C = 8.0
_EPS = 1e-6


class RGLRUConfig(NamedTuple):
    d_model: int
    width: int            # lru width
    d_conv: int = 4
    use_kernel: bool = False


def rglru_def(cfg: RGLRUConfig) -> dict:
    d, w = cfg.d_model, cfg.width
    return {
        "in_x": dense_def(d, w, ("embed", "dinner")),
        "in_y": dense_def(d, w, ("embed", "dinner")),
        "conv": {"w": P((cfg.d_conv, w), ("dconv", "dinner")),
                 "b": P((w,), ("dinner",), init="zeros")},
        "gate_a": dense_def(w, w, ("dinner", None)),
        "gate_i": dense_def(w, w, ("dinner", None)),
        "lam": P((w,), (None,), init="constant", scale=1.1),
        "out": dense_def(w, d, ("dinner", "embed")),
    }


def _gates(params, u):
    r = jax.nn.sigmoid(dense(params["gate_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(params["gate_i"], u).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), _EPS))
    return a, beta * i * u.astype(jnp.float32)


def rglru_scan(params, u: jax.Array,
               init_h: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """u: (B, L, W) -> (h (B,L,W), final h (B,W)) via associative scan."""
    a, b = _gates(params, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    if init_h is not None:
        b = b.at[:, 0].add(a[:, 0] * init_h.astype(jnp.float32))
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype), h[:, -1]


def rglru_block(params: dict, x: jax.Array, cfg: RGLRUConfig) -> jax.Array:
    """Full recurrent block (training / prefill).  x: (B, L, D)."""
    gate = jax.nn.gelu(dense(params["in_y"], x), approximate=True)
    u = dense(params["in_x"], x)
    if cfg.use_kernel:
        u = convdk_causal_conv1d(u, params["conv"]["w"], params["conv"]["b"])
    else:
        u = causal_conv1d_ref(u, params["conv"]["w"].astype(u.dtype),
                              params["conv"]["b"].astype(u.dtype))
    u = shard(u, "batch", None, "act_ff")
    h, _ = rglru_scan(params, u)
    return dense(params["out"], gate * h)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class RGLRUState(NamedTuple):
    conv: jax.Array      # (B, d_conv-1, W)
    h: jax.Array         # (B, W) float32


def init_rglru_state(batch: int, cfg: RGLRUConfig,
                     dtype=jnp.bfloat16) -> RGLRUState:
    return RGLRUState(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.width), dtype),
        h=jnp.zeros((batch, cfg.width), jnp.float32),
    )


def rglru_decode_step(
    params: dict, x_t: jax.Array, state: RGLRUState, cfg: RGLRUConfig
) -> Tuple[jax.Array, RGLRUState]:
    """One token.  x_t: (B, 1, D).  O(1) state update."""
    gate = jax.nn.gelu(dense(params["in_y"], x_t)[:, 0], approximate=True)
    u = dense(params["in_x"], x_t)[:, 0]
    u, new_conv = causal_conv1d_update_ref(
        state.conv, u, params["conv"]["w"].astype(u.dtype),
        params["conv"]["b"].astype(u.dtype))
    a, b = _gates(params, u[:, None])
    h = a[:, 0] * state.h + b[:, 0]
    y = dense(params["out"], (gate * h.astype(gate.dtype))[:, None])
    return y, RGLRUState(conv=new_conv, h=h)

from .mbconv import (
    EffNetConfig,
    efficientnet_b0_apply,
    efficientnet_b0_def,
    mbconv_block,
    mbconv_def,
)
from .model import (
    ModelConfig,
    decode_step,
    forward,
    init_decode_state,
    model_def,
)
from .param import abstract, count_params, logical_axes, materialize

__all__ = [
    "ModelConfig", "decode_step", "forward", "init_decode_state",
    "model_def", "abstract", "count_params", "logical_axes", "materialize",
    "EffNetConfig", "efficientnet_b0_apply", "efficientnet_b0_def",
    "mbconv_block", "mbconv_def",
]

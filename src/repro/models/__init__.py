from .mbconv import (
    EffNetConfig,
    EffNetV2Config,
    MobileNetV3Config,
    block_def,
    efficientnet_b0_apply,
    efficientnet_b0_def,
    efficientnet_v2_s_apply,
    efficientnet_v2_s_def,
    fusedmb_block,
    fusedmb_def,
    mbconv_block,
    mbconv_def,
    mobilenet_v3_apply,
    mobilenet_v3_def,
)
from .model import (
    ModelConfig,
    decode_step,
    forward,
    init_decode_state,
    model_def,
)
from .param import abstract, count_params, logical_axes, materialize

__all__ = [
    "ModelConfig", "decode_step", "forward", "init_decode_state",
    "model_def", "abstract", "count_params", "logical_axes", "materialize",
    "EffNetConfig", "EffNetV2Config", "MobileNetV3Config", "block_def",
    "efficientnet_b0_apply", "efficientnet_b0_def",
    "efficientnet_v2_s_apply", "efficientnet_v2_s_def",
    "fusedmb_block", "fusedmb_def", "mbconv_block", "mbconv_def",
    "mobilenet_v3_apply", "mobilenet_v3_def",
]

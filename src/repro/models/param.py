"""Parameter-definition trees.

Models declare their parameters once as a tree of ``P`` leaves (shape +
logical sharding axes + initializer).  From that single declaration we derive:

* ``materialize``    — real initialized params (training / smoke tests),
* ``abstract``       — ShapeDtypeStructs (the multi-pod dry-run never
                       allocates),
* ``logical_axes``   — the parallel tree of logical-axis tuples consumed by
                       ``repro.sharding`` to build NamedShardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter declaration.

    shape : tensor shape.
    axes  : logical axis names, one per dim (None = never sharded).
    init  : "normal" (trunc-normal fan-in scaled), "zeros", "ones",
            "embed" (scaled by 1), or "constant".
    scale : overrides the init scale.
    """

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"
    scale: Optional[float] = None
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTree = Any  # nested dict of P (defs) or jax.Array (materialized)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # convention: the LAST axis is the output features axis
    return int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]


def _init_leaf(p: P, key: jax.Array, param_dtype) -> jax.Array:
    dtype = param_dtype or p.dtype
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "constant":
        return jnp.full(p.shape, p.scale or 0.0, dtype)
    if p.init == "embed":
        scale = p.scale if p.scale is not None else 1.0
        return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dtype)
    # trunc-normal, fan-in scaled (LeCun)
    scale = p.scale if p.scale is not None else 1.0
    std = scale / np.sqrt(max(1, _fan_in(p.shape)))
    return (jax.random.truncated_normal(key, -2.0, 2.0, p.shape, jnp.float32)
            * std).astype(dtype)


def _is_def(x) -> bool:
    return isinstance(x, P)


def materialize(tree: ParamTree, key: jax.Array, param_dtype=None) -> ParamTree:
    """Initialize every leaf with an independent fold of ``key``."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(p, k, param_dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract(tree: ParamTree, param_dtype=None) -> ParamTree:
    """ShapeDtypeStructs for the dry-run — no device allocation."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, param_dtype or p.dtype),
        tree, is_leaf=_is_def,
    )


def logical_axes(tree: ParamTree) -> ParamTree:
    """Parallel tree of logical-axis tuples."""
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_def)


def count_params(tree: ParamTree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=_is_def)
    return sum(int(np.prod(p.shape)) for p in leaves)


def stack_defs(tree: ParamTree, n: int, axis_name: Optional[str] = "layer") -> ParamTree:
    """Prepend a scan ('layer') dimension of size ``n`` to every leaf —
    the parameter layout for scan-over-layers stacks."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, (axis_name,) + p.axes,
                    init=p.init, scale=p.scale, dtype=p.dtype),
        tree, is_leaf=_is_def,
    )

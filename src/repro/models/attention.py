"""Attention: MHA / GQA / MQA with RoPE, causal & sliding-window masks,
chunked online-softmax for long context, and KV-cache decode.

Shapes: activations are (B, S, D); heads live in (B, S, H, Dh) between the
projections.  GQA repeats KV heads by ``H // KV`` inside the score einsum
(no materialized repeat).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard
from .common import apply_rope, dense, dense_def

NEG_INF = -2.0e38


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    causal: bool = True
    window: Optional[int] = None       # sliding-window size (local attention)
    q_chunk: int = 2048
    kv_chunk: int = 1024
    # soft logit cap (Gemma-2-style); 0 disables
    logit_cap: float = 0.0
    # sequence-parallel attention (§Perf): shard q rows over "model" when
    # the head counts cannot divide the model axis (MQA / 20-head / 24-head
    # archs) — otherwise those cells replicate all attention compute+memory
    # on every model shard.
    seq_shard: bool = False


def attn_def(cfg: AttnConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "q": dense_def(d, h * hd, ("embed", "heads"), bias=cfg.qkv_bias),
        "k": dense_def(d, kv * hd, ("embed", "kv_heads"), bias=cfg.qkv_bias),
        "v": dense_def(d, kv * hd, ("embed", "kv_heads"), bias=cfg.qkv_bias),
        "o": dense_def(h * hd, d, ("heads", "embed")),
    }


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]) -> jax.Array:
    """(Sq, Sk) additive mask bias from position vectors."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def _sdpa(q, k, v, bias, scale, logit_cap):
    """q (B,Sq,H,D), k (B,Sk,KV,D), v (B,Sk,KV,Dv) -> (B,Sq,H,Dv)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    dv = v.shape[-1]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_cap > 0:
        scores = logit_cap * jnp.tanh(scores / logit_cap)
    scores = scores + bias[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def _chunked_sdpa(q, k, v, q_pos, k_pos, causal, window, scale, logit_cap,
                  q_chunk, kv_chunk):
    """Online-softmax over KV chunks; peak memory O(Sq * kv_chunk).

    Supports d_v != d_qk (MLA routes its concatenated [nope|rope] keys with
    128-dim values through here).  Causal/window blocks that are fully
    masked still execute (static grid) but their contribution is exactly
    zero; the §Perf loop can skip them via a triangular grid if the cell is
    compute-bound.
    """
    b, sq, h, d = q.shape
    dv = v.shape[-1]
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    n_q = -(-sq // q_chunk)
    n_k = -(-sk // kv_chunk)
    q_pad = n_q * q_chunk - sq
    k_pad = n_k * kv_chunk - sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, q_pad), constant_values=-1)
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        # padded keys get position +inf-ish so causal mask kills them
        k_pos = jnp.pad(k_pos, (0, k_pad), constant_values=2**30)

    qc = q.reshape(b, n_q, q_chunk, kv, g, d).astype(jnp.float32)
    kc = k.reshape(b, n_k, kv_chunk, kv, d).astype(jnp.float32)
    vc = v.reshape(b, n_k, kv_chunk, kv, dv).astype(jnp.float32)
    qp = q_pos.reshape(n_q, q_chunk)
    kp = k_pos.reshape(n_k, kv_chunk)

    def q_block(qi):
        qb, qpb = qc[:, qi], qp[qi]                    # (B,Qc,KV,G,D), (Qc,)

        def kv_step(carry, ki):
            acc, m, l = carry
            kb, vb, kpb = kc[:, ki], vc[:, ki], kp[ki]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb) * scale
            if logit_cap > 0:
                s = logit_cap * jnp.tanh(s / logit_cap)
            s = s + _mask_bias(qpb, kpb, causal, window)[None, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            # guard fully-masked rows (m_new = -inf) against NaN
            m_safe = jnp.maximum(m_new, -1e30)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.maximum(m, -1e30) - m_safe)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vb)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, kv, g, q_chunk, dv), jnp.float32)
        m0 = jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(jax.checkpoint(kv_step),
                                      (acc0, m0, l0), jnp.arange(n_k))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,KV,G,Qc,D)
        return jnp.einsum("bkgqd->bqkgd", out)

    # remat the per-q-block pass: without this, autodiff saves every
    # (B,KV,G,Qc,Kc) score block across the KV scan — O(S^2) residuals that
    # defeat the online-softmax memory bound.  Recompute them in bwd instead.
    out = jax.lax.map(jax.checkpoint(q_block), jnp.arange(n_q))
    out = jnp.moveaxis(out, 0, 1).reshape(b, n_q * q_chunk, h, dv)
    return out[:, :sq].astype(q.dtype)


def attention(
    params: dict,
    x: jax.Array,
    cfg: AttnConfig,
    positions: Optional[jax.Array] = None,
    use_chunked: Optional[bool] = None,
) -> jax.Array:
    """Self-attention over x (B, S, D)."""
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(s)
    q = dense(params["q"], x).reshape(b, s, h, hd)
    k = dense(params["k"], x).reshape(b, s, kvh, hd)
    v = dense(params["v"], x).reshape(b, s, kvh, hd)
    q = apply_rope(q, positions[None, :], cfg.rope_theta)
    k = apply_rope(k, positions[None, :], cfg.rope_theta)
    q = shard(q, "batch", None, "act_heads", None)
    k = shard(k, "batch", None, "act_heads", None)
    v = shard(v, "batch", None, "act_heads", None)

    scale = hd ** -0.5
    if use_chunked is None:
        use_chunked = s > cfg.q_chunk

    mesh = _seq_shard_mesh(cfg, s)
    if mesh is not None:
        out = _seq_parallel_sdpa(mesh, q, k, v, positions, cfg, scale,
                                 use_chunked)
    elif use_chunked:
        out = _chunked_sdpa(q, k, v, positions, positions, cfg.causal,
                            cfg.window, scale, cfg.logit_cap,
                            cfg.q_chunk, cfg.kv_chunk)
    else:
        bias = _mask_bias(positions, positions, cfg.causal, cfg.window)
        out = _sdpa(q, k, v, bias, scale, cfg.logit_cap)
    out = shard(out, "batch", None, "act_heads", None)
    return dense(params["o"], out.reshape(b, s, h * hd))


def _seq_shard_mesh(cfg: AttnConfig, s: int):
    """Return the mesh when sequence-parallel attention should engage."""
    if not cfg.seq_shard:
        return None
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if am is None or am.empty or "model" not in am.shape:
        return None
    m = am.shape["model"]
    if m <= 1 or s % m != 0:
        return None
    if cfg.n_kv_heads % m == 0:
        return None   # heads shard fine; no need for SP
    return am


def _seq_parallel_sdpa(mesh, q, k, v, positions, cfg: AttnConfig, scale,
                       use_chunked):
    """Context parallelism: each "model" shard owns s/M query rows and the
    full K/V (replicated); the causal mask follows the per-shard positions.
    Communication: one all-gather of the (B,S,H,D) output downstream instead
    of replicating the whole S x S score computation M times."""
    from jax.sharding import PartitionSpec as PS

    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    m = mesh.shape["model"]
    s_loc = q.shape[1] // m

    def shard_fn(q_l, k_f, v_f, pos_l, pos_f):
        if use_chunked and s_loc > cfg.q_chunk:
            return _chunked_sdpa(q_l, k_f, v_f, pos_l, pos_f, cfg.causal,
                                 cfg.window, scale, cfg.logit_cap,
                                 cfg.q_chunk, cfg.kv_chunk)
        bias = _mask_bias(pos_l, pos_f, cfg.causal, cfg.window)
        return _sdpa(q_l, k_f, v_f, bias, scale, cfg.logit_cap)

    return jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(PS(dp, "model"), PS(dp), PS(dp), PS("model"), PS()),
        out_specs=PS(dp, "model"),
    )(q, k, v, positions, positions)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Dense KV cache; for windowed attention it is a RING buffer of size
    ``window`` (slot = pos % window), which is what keeps recurrentgemma's
    524k-token decode cell at O(window) memory."""

    k: jax.Array        # (B, S_cache, KV, Dh)
    v: jax.Array
    slot_pos: jax.Array  # (S_cache,) int32 — true position held per slot (-1 empty)
    pos: jax.Array      # () int32 — next write position


def init_kv_cache(batch: int, s_max: int, cfg: AttnConfig,
                  dtype=jnp.bfloat16) -> KVCache:
    s_cache = min(s_max, cfg.window) if cfg.window else s_max
    shape = (batch, s_cache, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        slot_pos=jnp.full((s_cache,), -1, jnp.int32),
        pos=jnp.zeros((), jnp.int32),
    )


def decode_attention(
    params: dict, x_t: jax.Array, cache: KVCache, cfg: AttnConfig
) -> Tuple[jax.Array, KVCache]:
    """One-token step.  x_t: (B, 1, D).  Returns (out (B,1,D), new cache).

    Dense cache: write slot = pos.  Ring cache (windowed): slot = pos % W.
    """
    b = x_t.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = cache.pos
    q = dense(params["q"], x_t).reshape(b, 1, h, hd)
    k = dense(params["k"], x_t).reshape(b, 1, kvh, hd)
    v = dense(params["v"], x_t).reshape(b, 1, kvh, hd)
    posv = pos[None, None]
    q = apply_rope(q, jnp.broadcast_to(posv, (b, 1)), cfg.rope_theta)
    k = apply_rope(k, jnp.broadcast_to(posv, (b, 1)), cfg.rope_theta)

    s_cache = cache.k.shape[1]
    slot = pos % s_cache if cfg.window else pos
    k_all = jax.lax.dynamic_update_slice(
        cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
    v_all = jax.lax.dynamic_update_slice(
        cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache.slot_pos, pos[None], (slot,))
    k_all = shard(k_all, "batch", None, "act_heads", None)
    v_all = shard(v_all, "batch", None, "act_heads", None)

    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if cfg.window is not None:
        valid &= slot_pos > pos - cfg.window
    bias = jnp.where(valid, 0.0, NEG_INF)               # (S_cache,)

    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k_all.astype(jnp.float32)) * hd ** -0.5
    if cfg.logit_cap > 0:
        scores = cfg.logit_cap * jnp.tanh(scores / cfg.logit_cap)
    probs = jax.nn.softmax(scores + bias[None, None, None, None], axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_all.astype(jnp.float32))
    out = out.reshape(b, 1, h * hd).astype(x_t.dtype)
    y = dense(params["o"], out)
    return y, KVCache(k=k_all, v=v_all, slot_pos=slot_pos, pos=pos + 1)

"""Dataflow-graph form of an MBConv block chain.

``efficientnet_b0_apply`` used to call its 16 blocks in a bare Python
loop, which leaves the chain's buffer structure implicit: each two-pass
fused block (``kernels.convdk_mbconv_fused``) writes a set of
intermediate buffers in pass 1 (the retained DW tensor, the SE pool and
gate scale) that only pass 2 of the SAME block reads — so pass 2 of
block *i* and pass 1 of block *i+1* touch disjoint buffers except for
the activation streamed between them.  That disjointness is exactly what
the cross-block pipelining axis of ``core.autotune`` exploits (pricing a
pipelined boundary as ``max(pass2_us, pass1_us)`` instead of their sum),
and it deserves to be checkable rather than folklore.

``BlockGraph`` makes it explicit: every block becomes a ``BlockNode``
carrying per-pass ``StageIO`` read/write buffer sets plus the block's
apply closure, and ``validate()`` proves each boundary the plan marked
``pipelined`` is hazard-free — the ONLY buffer flowing from the
producer's pass 2 into the consumer's pass 1 is the boundary activation
(which the executor streams strip-by-strip, the one-level-up analogue of
``kernels/staging.py`` double-buffering), with no write-after-write or
write-after-read conflicts on the side buffers.  ``lower(x)`` then
executes the chain in node order, calling each node's closure exactly as
the old loop did — forward and grad stay bit-exact because each closure
wraps the whole-block ``custom_vjp`` kernel unchanged.

Buffer naming convention (canonical, used by the builders and tests):

* ``act{i}``    — the activation entering node *i* (node *i* writes
  ``act{i+1}``);
* ``dw{i}``     — node *i*'s retained DW tensor (retain mode only);
* ``pool{i}``   — node *i*'s on-chip SE pool result;
* ``scale{i}``  — node *i*'s SE gate, written by the between-pass SE MLP
  (accounted to pass 1, matching ``perfmodel.mbconv_pass_traffic``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, FrozenSet, Optional, Tuple

from ..core.perfmodel import DEFAULT_OVERLAP, validate_overlap


class GraphValidationError(ValueError):
    """A BlockGraph chain or overlap annotation is ill-formed."""


@dataclasses.dataclass(frozen=True)
class StageIO:
    """The HBM-level buffer sets one pass of a block touches."""

    reads: FrozenSet[str]
    writes: FrozenSet[str]

    @staticmethod
    def of(reads, writes) -> "StageIO":
        return StageIO(reads=frozenset(reads), writes=frozenset(writes))


@dataclasses.dataclass(frozen=True)
class BlockNode:
    """One block of the chain: per-pass buffer sets + the apply closure.

    ``entry_overlap`` annotates the ENTRY boundary (this node's pass 1
    against the previous node's pass 2) — mirroring
    ``autotune.BlockPlan.entry_overlap``, so a plan lowers 1:1 onto a
    graph.  ``apply`` maps the boundary activation to the next one;
    it is excluded from equality so nodes compare structurally.
    """

    index: int
    name: str
    pass1: StageIO
    pass2: StageIO
    entry_overlap: str = DEFAULT_OVERLAP
    apply: Optional[Callable] = dataclasses.field(
        default=None, compare=False, repr=False)

    def __post_init__(self):
        validate_overlap(self.entry_overlap)

    @property
    def input_buffer(self) -> str:
        return f"act{self.index}"

    @property
    def output_buffer(self) -> str:
        return f"act{self.index + 1}"

    @property
    def one_pass(self) -> bool:
        """True for single-pass families (Fused-MBConv): the whole block
        is pass 1 and pass 2 touches nothing."""
        return not (self.pass2.reads or self.pass2.writes)


def mbconv_stage_io(index: int, mode: str = "retain",
                    residual: bool = False, se: bool = True
                    ) -> Tuple[StageIO, StageIO]:
    """The canonical (pass1, pass2) buffer sets of one two-pass fused
    MBConv block, matching the kernel's dataflow:

    * pass 1 reads the entry activation, writes the SE pool and gate
      scale (the SE MLP between the passes is accounted to pass 1, as in
      ``perfmodel.mbconv_pass_traffic``) plus the retained DW tensor in
      retain mode;
    * pass 2 reads the gate scale plus either the retained DW tensor
      (retain) or the entry activation again (recompute re-runs the
      front end), plus the entry activation for the identity residual
      when present, and writes the exit activation.

    ``se=False`` (a no-SE block, MobileNet-V3's early/middle stages)
    drops the pool and gate-scale buffers from both passes.
    """
    a_in, a_out = f"act{index}", f"act{index + 1}"
    dw, pool, scale = f"dw{index}", f"pool{index}", f"scale{index}"
    if not se:
        # no-SE block: no pool, no gate scale.  retain still stages the
        # DW tensor between the passes; recompute's pass 1 writes NOTHING
        # (the kernel skips it entirely) — the node degenerates toward
        # one-pass, but keeps the two-pass form because the kernel still
        # runs the projection as pass 2.
        p1_writes = {dw} if mode == "retain" else set()
        p2_reads = {dw} if mode == "retain" else {a_in}
        if residual:
            p2_reads = set(p2_reads) | {a_in}
        return (StageIO.of({a_in}, p1_writes),
                StageIO.of(p2_reads, {a_out}))
    p1_writes = {pool, scale}
    p2_reads = {scale}
    if mode == "retain":
        p1_writes.add(dw)
        p2_reads.add(dw)
    else:
        p2_reads.add(a_in)
    if residual:
        p2_reads.add(a_in)
    return (StageIO.of({a_in}, p1_writes),
            StageIO.of(p2_reads, {a_out}))


def fusedmb_stage_io(index: int) -> Tuple[StageIO, StageIO]:
    """The (pass1, pass2) buffer sets of one SINGLE-PASS Fused-MBConv
    block: the whole block is pass 1 (entry activation in, exit
    activation out — the expanded tensor never touches HBM, there is no
    SE side buffer), and pass 2 is EMPTY.  ``validate()`` recognizes the
    empty pass 2 as the one-pass form: the exit activation must then be
    written by pass 1, and a downstream consumer can never pipeline its
    entry against this node (nothing flows producer-pass-2 ->
    consumer-pass-1) — matching ``core.autotune``'s serial pricing of
    boundaries behind a one-pass producer.  The identity residual reads
    the same entry activation pass 1 already reads."""
    a_in, a_out = f"act{index}", f"act{index + 1}"
    return (StageIO.of({a_in}, {a_out}), StageIO.of((), ()))


@dataclasses.dataclass(frozen=True)
class BlockGraph:
    """A validated chain of ``BlockNode``s ``lower()`` executes in order."""

    nodes: Tuple[BlockNode, ...]

    @property
    def pipelined_boundaries(self) -> Tuple[int, ...]:
        """Node indices whose ENTRY boundary is pipelined."""
        return tuple(n.index for n in self.nodes[1:]
                     if n.entry_overlap == "pipelined")

    def validate(self) -> None:
        """Prove the chain well-formed and every pipelined boundary legal.

        Chain (all boundaries): node indices are 0..n-1 in order, each
        node's pass 1 reads its entry activation, and its pass 2 writes
        exactly its exit activation — the RAW chain the executor relies
        on.  Pipelined boundaries additionally require hazard freedom
        between the overlapped stages (producer pass 2 ∥ consumer
        pass 1):

        * the only buffer flowing producer-pass-2 → consumer-pass-1 is
          the boundary activation (streamed strip-by-strip);
        * no write-write conflict between the overlapped stages;
        * consumer pass 1 writes nothing producer pass 2 reads (no WAR
          on the side buffers — e.g. a recompute producer still reading
          ITS entry activation must not see it clobbered).
        """
        for i, node in enumerate(self.nodes):
            if node.index != i:
                raise GraphValidationError(
                    f"node {i} carries index {node.index}; chain order "
                    "and buffer naming must agree")
            if node.input_buffer not in node.pass1.reads:
                raise GraphValidationError(
                    f"{node.name}: pass 1 does not read its entry "
                    f"activation {node.input_buffer!r}")
            writer = node.pass1 if node.one_pass else node.pass2
            if node.output_buffer not in writer.writes:
                raise GraphValidationError(
                    f"{node.name}: "
                    f"{'pass 1' if node.one_pass else 'pass 2'} does not "
                    f"write its exit activation {node.output_buffer!r}")
        if self.nodes and self.nodes[0].entry_overlap == "pipelined":
            raise GraphValidationError(
                f"{self.nodes[0].name}: the first node has no producer "
                "to overlap with")
        for node in self.nodes[1:]:
            if node.entry_overlap != "pipelined":
                continue
            prev = self.nodes[node.index - 1]
            if prev.one_pass:
                raise GraphValidationError(
                    f"boundary {prev.name}->{node.name}: the producer is "
                    "single-pass (no pass 2 to overlap with); the entry "
                    "must be serial")
            streamed = prev.pass2.writes & node.pass1.reads
            if streamed != {node.input_buffer}:
                raise GraphValidationError(
                    f"boundary {prev.name}->{node.name}: pipelining "
                    f"requires exactly the boundary activation "
                    f"{node.input_buffer!r} to flow producer-pass-2 -> "
                    f"consumer-pass-1, got {sorted(streamed)}")
            waw = prev.pass2.writes & node.pass1.writes
            if waw:
                raise GraphValidationError(
                    f"boundary {prev.name}->{node.name}: write-write "
                    f"conflict on {sorted(waw)} between overlapped "
                    "stages")
            war = node.pass1.writes & prev.pass2.reads
            if war:
                raise GraphValidationError(
                    f"boundary {prev.name}->{node.name}: consumer "
                    f"pass 1 overwrites {sorted(war)} while producer "
                    "pass 2 still reads it")

    def lower(self, x):
        """Execute the chain: thread ``x`` through every node's apply
        closure in node order — operation-for-operation identical to the
        sequential loop, so forward and grad are bit-exact with it."""
        from ..core import telemetry
        telemetry.counter("blockgraph.lower")
        telemetry.counter("blockgraph.pipelined_boundaries",
                          len(self.pipelined_boundaries))
        for node in self.nodes:
            if node.apply is None:
                raise GraphValidationError(
                    f"{node.name}: no apply closure bound; build the "
                    "graph through build_mbconv_graph to lower it")
            x = node.apply(x)
        return x


def build_block_graph(specs, params, *, kcfg=None, mesh=None,
                      plan=None) -> BlockGraph:
    """The ``BlockGraph`` of a block chain (stem and head stay in the
    caller).  Family-generic: each spec's ``family`` picks the node form
    — two-pass ``mbconv`` nodes (per-pass buffer sets reflecting the
    solved mode and the spec's SE presence) or one-pass ``fusedmb``
    nodes (empty pass 2, categorically serial exits).  Each node's apply
    closure performs the exact block call the sequential loop used to
    make — same ``SchedulePin``, same ``in_layout``, the spec's own
    act/SE routing — so ``graph.lower(x)`` is bit-exact with the loop;
    with a ``plan``, each node additionally inherits the plan's solved
    ``entry_overlap``.

    Without a plan every boundary is serial and the buffer sets use the
    nodes' default retain dataflow — the graph is then purely the
    structural form of the loop.
    """
    from ..configs.base import SchedulePin
    from .mbconv import fusedmb_block, mbconv_block

    if plan is not None and len(plan.blocks) != len(specs):
        raise GraphValidationError(
            f"plan covers {len(plan.blocks)} blocks, chain has "
            f"{len(specs)}")
    nodes = []
    for i, sp in enumerate(specs):
        family = getattr(sp, "family", "mbconv")
        if plan is not None:
            bp = plan.blocks[i]
            # FusedMBSchedule has no mode axis (single pass)
            mode = getattr(bp.schedule, "mode", "retain")
            pin = SchedulePin(mode=getattr(bp.schedule, "mode", None),
                              residency=bp.schedule.residency,
                              collective=bp.schedule.collective)
            overlap = bp.entry_overlap
            in_layout = bp.in_layout
        else:
            mode, overlap = "retain", DEFAULT_OVERLAP
            pin, in_layout = None, "replicated"

        if family == "fusedmb":
            def apply(x, _p=params[f"block{i}"], _sp=sp, _pin=pin,
                      _ov=overlap if plan is not None else None):
                y, _ = fusedmb_block(x, _p, stride=_sp.s, act=_sp.act,
                                     cfg=kcfg, mesh=mesh, pin=_pin,
                                     overlap=_ov)
                return y

            p1, p2 = fusedmb_stage_io(i)
            name = f"fusedmb{i}"
        else:
            def apply(x, _p=params[f"block{i}"], _sp=sp, _pin=pin,
                      _lay=in_layout,
                      _ov=overlap if plan is not None else None):
                y, _ = mbconv_block(
                    x, _p, stride=_sp.s, cfg=kcfg, mesh=mesh, pin=_pin,
                    in_layout=_lay, overlap=_ov,
                    exp_act=getattr(_sp, "act", "silu"),
                    dw_act=getattr(_sp, "act", "silu"),
                    se_act=getattr(_sp, "se_act", "silu"),
                    gate_act=getattr(_sp, "gate_act", "sigmoid"))
                return y

            p1, p2 = mbconv_stage_io(
                i, mode=mode, residual=sp.has_residual,
                se=getattr(sp, "has_se", True))
            name = f"mbconv{i}"
        nodes.append(BlockNode(index=i, name=name, pass1=p1,
                               pass2=p2, entry_overlap=overlap,
                               apply=apply))
    return BlockGraph(nodes=tuple(nodes))


# legacy name — the builder grew family dispatch and kept its behavior
# for all-MBConv chains bit-for-bit
build_mbconv_graph = build_block_graph

"""Mamba-2 SSD (state-space duality) block — chunked algorithm
(arXiv:2405.21060, Sec. 6).

The sequence is split into chunks of ``Q``; intra-chunk terms are dense
(quadratic within the chunk, MXU-friendly), inter-chunk terms flow through a
parallel associative scan over the (decay, state) pairs — O(log n_chunks)
depth, constant state (B, H, P, N).  Decode is a single-token recurrence on
that same state, which is what makes the ``long_500k`` cell linear-time.

The depthwise causal conv stem is the paper-technique hot-spot: it runs the
ConvDK Pallas kernel when ``use_kernel`` (CPU tests use interpret mode; the
XLA shift-add path is used in dry-run lowering for clean HLO).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.ops import convdk_causal_conv1d
from ..kernels.ref import causal_conv1d_ref, causal_conv1d_update_ref
from ..sharding import shard
from .common import dense, dense_def, rmsnorm, rmsnorm_def
from .param import P


class SSDConfig(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int          # d_inner // head_dim
    head_dim: int
    d_state: int
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256
    use_kernel: bool = False


def ssd_def(cfg: SSDConfig) -> dict:
    d, di, gn = cfg.d_model, cfg.d_inner, cfg.n_groups * cfg.d_state
    h = cfg.n_heads
    # z/x/B/C/dt are SEPARATE projections: a fused (d, 2di+2gn+h) matmul
    # shards its output as one axis whose split boundaries straddle the
    # model shards, costing a collective-permute chain per layer (§Perf,
    # mamba2 iteration 3).  Separate outputs shard cleanly; XLA still fuses
    # the shared input reads.
    return {
        "in_z": dense_def(d, di, ("embed", "dinner")),
        "in_x": dense_def(d, di, ("embed", "dinner")),
        "in_b": dense_def(d, gn, ("embed", None)),
        "in_c": dense_def(d, gn, ("embed", None)),
        "in_dt": dense_def(d, h, ("embed", None)),
        "conv_x": {"w": P((cfg.d_conv, di), ("dconv", "dinner")),
                   "b": P((di,), ("dinner",), init="zeros")},
        "conv_b": {"w": P((cfg.d_conv, gn), ("dconv", None)),
                   "b": P((gn,), (None,), init="zeros")},
        "conv_c": {"w": P((cfg.d_conv, gn), ("dconv", None)),
                   "b": P((gn,), (None,), init="zeros")},
        "a_log": P((h,), (None,), init="constant", scale=0.0),
        "d_skip": P((h,), (None,), init="ones"),
        "dt_bias": P((h,), (None,), init="zeros"),
        "norm": rmsnorm_def(di),
        "out_proj": dense_def(di, d, ("dinner", "embed")),
    }


def _conv(p, x, use_kernel: bool):
    if use_kernel:
        return convdk_causal_conv1d(x, p["w"], p["b"], activation="silu")
    return causal_conv1d_ref(x, p["w"].astype(x.dtype),
                             p["b"].astype(x.dtype), activation="silu")


def ssd_chunked(
    x: jax.Array,       # (B, L, H, P)
    dt: jax.Array,      # (B, L, H)  — post-softplus
    a: jax.Array,       # (H,)       — negative decay rates
    bm: jax.Array,      # (B, L, G, N)
    cm: jax.Array,      # (B, L, G, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,   # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    b, l, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // q
    hg = h // g  # heads per group

    xc = x.reshape(b, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    bc = bm.reshape(b, nc, q, g, n).astype(jnp.float32)
    cc = cm.reshape(b, nc, q, g, n).astype(jnp.float32)

    da = dtc * a.astype(jnp.float32)                  # (B,nc,Q,H) <= 0
    cs = jnp.cumsum(da, axis=2)                       # decay log to t (incl.)
    seg = jnp.exp(cs[:, :, -1])                       # (B,nc,H) chunk decay

    # Heads are grouped as (G, HG) so B/C (per-group) are consumed by the
    # einsums WITHOUT jnp.repeat onto the model-sharded head axis — the
    # repeat forced a collective-permute of (B,L,H,N) every layer (§Perf,
    # mamba2 iteration 2).
    xg = xc.reshape(b, nc, q, g, hg, p)
    dtg = dtc.reshape(b, nc, q, g, hg)
    csg = cs.reshape(b, nc, q, g, hg)

    # ---- intra-chunk (dense, MXU) ----
    cb = jnp.einsum("bcqgn,bctgn->bcgqt", cc, bc)     # (B,nc,G,Q_q,Q_t)
    cst = csg.transpose(0, 1, 3, 4, 2)                # (B,nc,G,HG,Q)
    decay = jnp.exp(cst[..., :, None] - cst[..., None, :])
    # decay[..., q, t] = exp(cs[q] - cs[t]); causal within the chunk
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, None, None], decay, 0.0)
    w_qt = decay * dtg.transpose(0, 1, 3, 4, 2)[..., None, :]
    y_intra = jnp.einsum("bcgqt,bcghqt,bctghp->bcqghp", cb, w_qt, xg)

    # ---- chunk-local states ----
    # state_c = sum_t exp(cs_last - cs[t]) * dt[t] * B[t] (x) x[t]
    sdec = jnp.exp(cs[:, :, -1:, :] - cs)             # (B,nc,Q,H)
    sdt = (sdec * dtc).reshape(b, nc, q, g, hg)
    state = jnp.einsum("bcqgh,bcqgn,bcqghp->bcghpn", sdt, bc, xg)
    state = state.reshape(b, nc, h, p, n)

    # ---- inter-chunk associative scan over (decay, state) pairs ----
    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a2 * a1, a2[..., None, None] * s1 + s2

    if init_state is not None:
        init32 = init_state.astype(jnp.float32)
        state = state.at[:, 0].add(seg[:, 0][..., None, None] * init32)
    _, sc_s = jax.lax.associative_scan(combine, (seg, state), axis=1)
    # S_prev for chunk c = accumulated state through chunk c-1
    first = (jnp.zeros_like(sc_s[:, :1]) if init_state is None
             else init32[:, None])
    s_prev = jnp.concatenate([first, sc_s[:, :-1]], axis=1)  # (B,nc,H,P,N)

    # ---- inter-chunk output ----
    qdec = jnp.exp(csg)                                # (B,nc,Q,G,HG)
    s_prev_g = s_prev.reshape(b, nc, g, hg, p, n)
    y_inter = jnp.einsum("bcqgn,bcqgh,bcghpn->bcqghp", cc, qdec, s_prev_g)

    y = (y_intra + y_inter).reshape(b, nc * q, h, p)[:, :l]
    return y.astype(x.dtype), sc_s[:, -1].astype(x.dtype)


def ssd_block(
    params: dict, x: jax.Array, cfg: SSDConfig
) -> jax.Array:
    """Full Mamba-2 block (training / prefill).  x: (B, L, D)."""
    b, l, d = x.shape
    di, h, p = cfg.d_inner, cfg.n_heads, cfg.head_dim

    z = dense(params["in_z"], x)
    xr = dense(params["in_x"], x)
    br = dense(params["in_b"], x)
    cr = dense(params["in_c"], x)
    dt = dense(params["in_dt"], x)
    xr = _conv(params["conv_x"], xr, cfg.use_kernel)
    br = _conv(params["conv_b"], br, cfg.use_kernel)
    cr = _conv(params["conv_c"], cr, cfg.use_kernel)
    xr = shard(xr, "batch", None, "act_ff")

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xr.reshape(b, l, h, p)
    bm = br.reshape(b, l, cfg.n_groups, cfg.d_state)
    cm = cr.reshape(b, l, cfg.n_groups, cfg.d_state)

    y, _ = ssd_chunked(xh, dt, a, bm, cm, cfg.chunk)
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, l, di)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return dense(params["out_proj"], y)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class SSDState(NamedTuple):
    conv_x: jax.Array    # (B, d_conv-1, d_inner)
    conv_b: jax.Array    # (B, d_conv-1, G*N)
    conv_c: jax.Array    # (B, d_conv-1, G*N)
    ssm: jax.Array       # (B, H, P, N)


def init_ssd_state(batch: int, cfg: SSDConfig, dtype=jnp.bfloat16) -> SSDState:
    gn = cfg.n_groups * cfg.d_state
    return SSDState(
        conv_x=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        conv_b=jnp.zeros((batch, cfg.d_conv - 1, gn), dtype),
        conv_c=jnp.zeros((batch, cfg.d_conv - 1, gn), dtype),
        ssm=jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                      jnp.float32),
    )


def ssd_decode_step(
    params: dict, x_t: jax.Array, state: SSDState, cfg: SSDConfig
) -> Tuple[jax.Array, SSDState]:
    """One token.  x_t: (B, 1, D) -> (y (B,1,D), new state).  O(1) in L."""
    b = x_t.shape[0]
    di, h, p = cfg.d_inner, cfg.n_heads, cfg.head_dim

    z = dense(params["in_z"], x_t)[:, 0]
    xr = dense(params["in_x"], x_t)[:, 0]
    br = dense(params["in_b"], x_t)[:, 0]
    cr = dense(params["in_c"], x_t)[:, 0]
    dt = dense(params["in_dt"], x_t)[:, 0]

    def step_conv(pr, st, u):
        y, ns = causal_conv1d_update_ref(
            st, u, pr["w"].astype(u.dtype), pr["b"].astype(u.dtype),
            activation="silu")
        return y, ns

    xr, ncx = step_conv(params["conv_x"], state.conv_x, xr)
    br, ncb = step_conv(params["conv_b"], state.conv_b, br)
    cr, ncc = step_conv(params["conv_c"], state.conv_c, cr)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xr.reshape(b, h, p).astype(jnp.float32)
    bm = br.reshape(b, cfg.n_groups, cfg.d_state).astype(jnp.float32)
    cm = cr.reshape(b, cfg.n_groups, cfg.d_state).astype(jnp.float32)
    hg = h // cfg.n_groups
    bmh = jnp.repeat(bm, hg, axis=1)                   # (B,H,N)
    cmh = jnp.repeat(cm, hg, axis=1)

    decay = jnp.exp(dt * a)                            # (B,H)
    new_ssm = (decay[..., None, None] * state.ssm
               + (dt[..., None] * xh)[..., None] * bmh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, cmh)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, di)
    y = rmsnorm(params["norm"], (y * jax.nn.silu(z.astype(jnp.float32))
                                 ).astype(x_t.dtype))
    out = dense(params["out_proj"], y[:, None])
    return out, SSDState(conv_x=ncx, conv_b=ncb, conv_c=ncc, ssm=new_ssm)

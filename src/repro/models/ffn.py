"""Feed-forward blocks: plain MLP and GLU variants (SwiGLU/GeGLU)."""

from __future__ import annotations

import jax

from ..sharding import shard
from .common import act_fn, dense, dense_def


def ffn_def(d: int, d_ff: int, act: str = "silu", glu: bool = True) -> dict:
    p = {"up": dense_def(d, d_ff, ("embed", "ff")),
         "down": dense_def(d_ff, d, ("ff", "embed"))}
    if glu:
        p["gate"] = dense_def(d, d_ff, ("embed", "ff"))
    return p


def ffn(params: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    up = dense(params["up"], x)
    if "gate" in params:
        up = act_fn(act)(dense(params["gate"], x)) * up
    else:
        up = act_fn(act)(up)
    up = shard(up, "batch", None, "act_ff")
    return dense(params["down"], up)

"""Vision serving report: drive a mixed-resolution request stream through
``serve.VisionEngine`` and tabulate what the telemetry counters saw.

Output sections:

* **top-N (layer x shape-class) traffic rows** — the serving-time
  bottleneck table: for every resolution bucket and chain layer, the
  bytes the engine charged while serving (counter value = n_batches x
  the solved plan's modeled bytes for that layer), sorted descending.
* **per-bucket summary** — batches / requests / pad slots / one-trace
  check per bucket, plus admission + shedding totals.
* **latency** — p50/p90/p99 over per-request blocked timings, and queue
  wait percentiles.

Exit status is the CI gate: nonzero unless (a) the table is non-empty,
(b) every bucket compiled exactly once (trace counter == 1), and
(c) every served layer's counter bytes reconcile EXACTLY with
n_batches x the solved schedule's modeled bytes — the engine may not
drift from ``perfmodel``'s ShardedTraffic pricing.

``--smoke`` serves CI-sized buckets (28/48/64 at width_mult 0.25) so the
report runs in interpret mode in seconds; default buckets are the paper
sizes (224/384/512).
"""

from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs.efficientnet_b0 import efficientnet_b0_smoke
from repro.core import telemetry
from repro.models.mbconv import efficientnet_b0_def
from repro.models.param import materialize
from repro.serve import VisionEngine, VisionServeConfig
from repro.serve.vision import layer_names


def _parse_resolutions(text: str):
    return tuple(int(tok) for tok in text.split(",") if tok.strip())


def build_stream(resolutions, n_requests: int, seed: int):
    """A mixed stream: sides drawn uniformly over admission-valid sizes,
    skewed so every bucket gets traffic (round-robin over buckets, with
    the side jittered below each bucket bound)."""
    rng = np.random.default_rng(seed)
    lo = 2
    sides = []
    for i in range(n_requests):
        res = resolutions[i % len(resolutions)]
        floor = resolutions[i % len(resolutions) - 1] + 1 \
            if i % len(resolutions) else lo
        sides.append(int(rng.integers(floor, res + 1)))
    rng.shuffle(sides)
    return [rng.random((s, s, 3), dtype=np.float32) for s in sides]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized buckets (28/48/64, width_mult 0.25)")
    ap.add_argument("--resolutions", type=_parse_resolutions, default=None,
                    help="comma list of admission buckets (ascending)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--width-mult", type=float, default=None)
    ap.add_argument("--top", type=int, default=12,
                    help="rows in the (layer x shape-class) table")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.resolutions is not None:
        resolutions = args.resolutions
    elif args.smoke:
        resolutions = (28, 48, 64)
    else:
        resolutions = (224, 384, 512)
    width = args.width_mult if args.width_mult is not None \
        else (0.25 if args.smoke else 1.0)

    telemetry.reset()
    cfg = efficientnet_b0_smoke(width_mult=width, num_classes=10)
    params = materialize(efficientnet_b0_def(cfg), jax.random.key(args.seed))
    eng = VisionEngine(params, cfg, VisionServeConfig(
        resolutions=resolutions, batch_size=args.batch_size,
        max_queue=args.max_queue))

    stream = build_stream(resolutions, args.requests, args.seed)
    admitted = sum(eng.submit(img) is not None for img in stream)
    results = eng.drain()
    t = telemetry.get_telemetry()

    # -- top-N (layer x shape-class) traffic table --------------------------
    rows = []
    for res in resolutions:
        nb = int(t.get(f"serve.batches.r{res}"))
        if not nb:
            continue
        for layer in layer_names(len(eng.specs)):
            rows.append((
                f"r{res}", layer,
                int(t.get(f"serve.bytes.r{res}.{layer}")),
                int(t.get(f"serve.collective.r{res}.{layer}")),
                nb,
            ))
    rows.sort(key=lambda r: -r[2])
    print(f"# serve_report: {len(results)} served / {admitted} admitted / "
          f"{eng.shed} shed; buckets={','.join(map(str, resolutions))} "
          f"batch={args.batch_size} width={width}")
    print("shape_class,layer,bytes,collective_bytes,batches")
    for r in rows[:args.top]:
        print(",".join(map(str, r)))

    # -- per-bucket summary -------------------------------------------------
    print("\nbucket,batches,requests,pad_slots,traces")
    for res in resolutions:
        print(f"r{res},{int(t.get(f'serve.batches.r{res}'))},"
              f"{int(t.get(f'serve.requests.r{res}'))},"
              f"{int(t.get(f'serve.pad_slots.r{res}'))},"
              f"{int(t.get(f'serve.trace.r{res}'))}")
    print(f"shed_queue_full={int(t.get('serve.shed.queue_full'))} "
          f"shed_oversize={int(t.get('serve.shed.oversize'))}")

    # -- latency ------------------------------------------------------------
    lat = eng.latency_percentiles()
    wait = telemetry.percentiles(telemetry.series("serve.queue_wait_s"))
    print("\nlatency_s:", " ".join(f"{k}={v:.4f}"
                                   for k, v in sorted(lat.items())))
    print("queue_wait_s:", " ".join(f"{k}={v:.4f}"
                                    for k, v in sorted(wait.items())))

    # -- gates --------------------------------------------------------------
    ok = True
    if not rows:
        print("GATE FAIL: empty traffic table (nothing served?)")
        ok = False
    for res in resolutions:
        nb = int(t.get(f"serve.batches.r{res}"))
        if not nb:
            continue
        if t.get(f"serve.trace.r{res}") != 1:
            print(f"GATE FAIL: r{res} retraced "
                  f"({int(t.get(f'serve.trace.r{res}'))} compilations)")
            ok = False
        modeled = eng.modeled_layer_bytes(res)
        for layer, (total, coll) in modeled.items():
            got = t.get(f"serve.bytes.r{res}.{layer}")
            if got != nb * total:
                print(f"GATE FAIL: r{res}.{layer} counter {int(got)} != "
                      f"{nb} x modeled {total}")
                ok = False
            got_c = t.get(f"serve.collective.r{res}.{layer}")
            if got_c != nb * coll:
                print(f"GATE FAIL: r{res}.{layer} collective {int(got_c)} "
                      f"!= {nb} x modeled {coll}")
                ok = False
        plan = eng.plan_for(res)
        if sum(tb for tb, _ in modeled.values()) != plan.total_bytes:
            print(f"GATE FAIL: r{res} layer rows do not sum to "
                  f"plan.total_bytes")
            ok = False
    print(f"\ngate: {'OK' if ok else 'FAIL'} — counters "
          f"{'reconcile exactly with' if ok else 'DRIFTED from'} "
          f"solved-schedule ShardedTraffic bytes")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark aggregator — one section per paper table/figure plus the
framework-level harnesses.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

from repro.core.telemetry import measure


def main() -> None:
    print("name,us_per_call,derived")

    # --- paper tables (Figs. 7-8): analytical CIM model -------------------
    from benchmarks.cim_tables import run_all
    out = {}
    m = measure(lambda: out.setdefault("r", run_all(quiet=True)),
                iters=1, warmup=0, name="cim_tables")
    results = out["r"]
    us = m.best_us
    for model, util in results["fig7a"].items():
        print(f"fig7a_util_{model},{us:.0f},ws_convdk={util:.2f}%")
    for model, red in results["fig7c"].items():
        print(f"fig7c_buffer_reduction_{model},{us:.0f},"
              f"ws={red['ws']:.1f}%;is={red['is']:.1f}%")
    for model, red in results["fig7d"].items():
        print(f"fig7d_energy_reduction_{model},{us:.0f},"
              f"ws_total={red['ws_total']:.1f}%")
    for model, red in results["fig7e"].items():
        print(f"fig7e_latency_reduction_{model},{us:.0f},"
              f"ws={red['ws']:.1f}%")
    for model, red in results["fig8"].items():
        print(f"fig8_buffer_latency_reduction_{model},{us:.0f},"
              f"ws={red['ws']:.1f}%")

    # --- ConvDK kernels ----------------------------------------------------
    from benchmarks.kernel_bench import rows as kernel_rows
    for name, us, derived in kernel_rows():
        print(f"{name},{us:.1f},{derived}")

    # --- roofline table (if the dry-run sweep has been run) ----------------
    try:
        from benchmarks.roofline_bench import load
        recs = load()
        for r in recs:
            if r.get("status") == "ok" and "roofline" in r:
                rl = r["roofline"]
                bound = max(rl["t_compute_s"], rl["t_memory_s"],
                            rl["t_collective_s"]) * 1e6
                print(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
                      f"{bound:.0f},dom={rl['dominant']};"
                      f"frac={rl['roofline_fraction']:.3f}")
    except Exception:
        pass


if __name__ == "__main__":
    main()

"""Roofline table from the dry-run sweep (results/dryrun/*.json), plus the
measured-calibration report that closes the perfmodel loop.

Default: prints the per-cell three-term roofline and the dominant
bottleneck; used by EXPERIMENTS.md §Roofline.  Run the sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

``--bench BENCH_<host>.json`` instead calibrates the byte model against a
measured trajectory artifact (``kernel_bench --measure``): least-squares
perfmodel coefficients (us per modeled MB, us per DMA issue, us per
collective MB), modeled-vs-measured rank agreement per schedule axis, and
the measurement's verdict on the open DMA knobs (prefetch ``priority=1``,
k_w-direction strip split).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def load() -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs=None, mesh="16x16", quiet=False) -> List[Dict]:
    recs = recs or load()
    rows = [r for r in recs if r.get("mesh") == mesh]
    if not quiet:
        print(f"\n== roofline, mesh {mesh} "
              "(t in ms/step on v5e: 197 TF/s bf16, 819 GB/s HBM, "
              "2x50 GB/s ICI) ==")
        print(f"{'arch':22s} {'shape':12s} {'status':7s} {'t_comp':>8s} "
              f"{'t_mem':>8s} {'t_coll':>8s} {'dominant':>10s} "
              f"{'useful':>7s} {'frac':>6s}")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or "roofline" not in r:
            if not quiet:
                why = r.get("reason", r.get("error", ""))[:40]
                print(f"{r['arch']:22s} {r['shape']:12s} {r['status']:7s} "
                      f"{why}")
            continue
        rl = r["roofline"]
        uf = rl.get("useful_flops_fraction")
        if not quiet:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['status']:7s} "
                  f"{rl['t_compute_s']*1e3:8.1f} {rl['t_memory_s']*1e3:8.1f} "
                  f"{rl['t_collective_s']*1e3:8.1f} {rl['dominant']:>10s} "
                  f"{uf if uf is None else round(uf, 3)!s:>7s} "
                  f"{rl['roofline_fraction']:6.3f}")
    return rows


def rows_csv() -> List[tuple]:
    out = []
    for r in load():
        if r.get("status") == "ok" and "roofline" in r:
            rl = r["roofline"]
            name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
            out.append((name, rl["bound_time_s"] * 1e6
                        if "bound_time_s" in rl
                        else max(rl["t_compute_s"], rl["t_memory_s"],
                                 rl["t_collective_s"]) * 1e6,
                        f"dom={rl['dominant']}"))
    return out


def calibration_report(bench_path: str) -> Dict:
    """Fit perfmodel coefficients from a BENCH trajectory artifact and
    report whether the byte model ORDERS schedule points the way the
    stopwatch does.

    Every measured candidate point (layer x schedule axes) is one fit
    sample; the fitted ``us_per_dma_issue`` is the term PR 4 flagged as
    unmodeled, and its sign/size is what decides the k_w-direction strip
    split (which buys no bytes, only finer issues).  Returns the report
    as a dict (tests consume it); prints the human table."""
    from repro.core.perfmodel import fit_perf_coefficients
    from repro.core.trajectory import load_bench, rank_agreement

    bench = load_bench(bench_path)
    samples = []
    for rec in bench["records"]:
        for c in rec.get("candidates", ()):
            samples.append({
                "walltime_us": c["walltime_us"],
                "modeled_bytes": c["modeled_bytes"],
                "dma_issues": c.get("modeled_dma_issues", 0),
                "collective_bytes": rec.get("collective_bytes", 0),
            })
    coeffs = fit_perf_coefficients(samples)
    host = bench.get("host", {})
    print(f"== perfmodel calibration: {len(samples)} measured points, "
          f"{len(bench['records'])} layers, host "
          f"{host.get('node')}/{host.get('backend')} ==")
    print(f"base_us              {coeffs.base_us:12.2f}")
    print(f"us_per_modeled_MB    {coeffs.us_per_mb:12.2f}")
    print(f"us_per_dma_issue     {coeffs.us_per_dma_issue:12.4f}")
    print(f"us_per_collective_MB {coeffs.us_per_collective_mb:12.2f}")
    print(f"fit_rms_us           {coeffs.rms_us:12.2f}")
    agreements = {}
    print("\n== modeled-vs-measured rank agreement per schedule axis ==")
    print("axis,pairs,agree,model_ties,agreement")
    for axis in ("mode", "residency", "tile_h"):
        agr = rank_agreement(bench["records"], axis)
        agreements[axis] = agr
        if agr is None:
            print(f"{axis},0,0,0,n/a (no controlled pairs measured)")
        else:
            frac = ("n/a" if agr["agreement"] is None
                    else f"{agr['agreement']:.2f}")
            print(f"{axis},{agr['pairs']},{agr['agree']},"
                  f"{agr['model_ties']},{frac}")
    knobs = bench.get("knobs", {})
    print("\n== DMA knob verdicts (measured, not argued) ==")
    if knobs.get("prefetch_priority_supported"):
        print("prefetch priority=1: exercised by the double-buffered "
              "stream on this backend — compare same-host artifacts with "
              "and without it")
    else:
        print("prefetch priority=1: NOT exercised — the installed "
              "pallas's make_async_copy has no priority parameter "
              "(compat drops the knob; recorded, not pretended)")
    issue = coeffs.us_per_dma_issue
    if issue > 0:
        print(f"k_w strip split: REJECTED at this calibration — each "
              f"extra issue costs {issue:.4f}us and a k_w split buys no "
              f"bytes, only finer issues")
    else:
        print("k_w strip split: not refuted — fitted per-issue cost is "
              "non-positive at this calibration (issue rate not the "
              "bottleneck here); re-fit on TPU before building it")
    return {"coefficients": coeffs.as_dict(), "rank_agreement": agreements,
            "knobs": knobs, "n_samples": len(samples)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=None, metavar="BENCH.json",
                    help="calibrate perfmodel coefficients from a "
                         "kernel_bench --measure trajectory artifact "
                         "instead of printing the dry-run roofline table")
    ap.add_argument("--mesh", default="16x16",
                    help="dry-run mesh to tabulate (default 16x16)")
    args = ap.parse_args()
    if args.bench is not None:
        calibration_report(args.bench)
        return
    recs = load()
    if not recs:
        print("no dry-run results found; run repro.launch.dryrun first")
        return
    table(recs, args.mesh)
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skip")
    err = sum(1 for r in recs if r["status"] == "error")
    print(f"\ncells: {ok} ok, {skip} skip, {err} error "
          f"(of {len(recs)} records)")


if __name__ == "__main__":
    main()

"""Roofline table from the dry-run sweep (results/dryrun/*.json).

Prints the per-cell three-term roofline and the dominant bottleneck; used by
EXPERIMENTS.md §Roofline.  Run the sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def load() -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs=None, mesh="16x16", quiet=False) -> List[Dict]:
    recs = recs or load()
    rows = [r for r in recs if r.get("mesh") == mesh]
    if not quiet:
        print(f"\n== roofline, mesh {mesh} "
              "(t in ms/step on v5e: 197 TF/s bf16, 819 GB/s HBM, "
              "2x50 GB/s ICI) ==")
        print(f"{'arch':22s} {'shape':12s} {'status':7s} {'t_comp':>8s} "
              f"{'t_mem':>8s} {'t_coll':>8s} {'dominant':>10s} "
              f"{'useful':>7s} {'frac':>6s}")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or "roofline" not in r:
            if not quiet:
                why = r.get("reason", r.get("error", ""))[:40]
                print(f"{r['arch']:22s} {r['shape']:12s} {r['status']:7s} "
                      f"{why}")
            continue
        rl = r["roofline"]
        uf = rl.get("useful_flops_fraction")
        if not quiet:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['status']:7s} "
                  f"{rl['t_compute_s']*1e3:8.1f} {rl['t_memory_s']*1e3:8.1f} "
                  f"{rl['t_collective_s']*1e3:8.1f} {rl['dominant']:>10s} "
                  f"{uf if uf is None else round(uf, 3)!s:>7s} "
                  f"{rl['roofline_fraction']:6.3f}")
    return rows


def rows_csv() -> List[tuple]:
    out = []
    for r in load():
        if r.get("status") == "ok" and "roofline" in r:
            rl = r["roofline"]
            name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
            out.append((name, rl["bound_time_s"] * 1e6
                        if "bound_time_s" in rl
                        else max(rl["t_compute_s"], rl["t_memory_s"],
                                 rl["t_collective_s"]) * 1e6,
                        f"dom={rl['dominant']}"))
    return out


def main():
    recs = load()
    if not recs:
        print("no dry-run results found; run repro.launch.dryrun first")
        return
    table(recs, "16x16")
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skip")
    err = sum(1 for r in recs if r["status"] == "error")
    print(f"\ncells: {ok} ok, {skip} skip, {err} error "
          f"(of {len(recs)} records)")


if __name__ == "__main__":
    main()

"""Reproduce the paper's evaluation (Figs. 7(a)-(e) and Fig. 8).

Run:  PYTHONPATH=src python -m benchmarks.cim_tables

One function per paper artifact; each prints a table and returns the raw
numbers so tests and `benchmarks.run` can gate them against PAPER_BANDS.
"""

from __future__ import annotations

from typing import Dict

from repro.core.perfmodel import (
    DATAFLOWS,
    MacroConfig,
    NetworkCost,
    compare_networks,
    reduction,
)
from repro.core.workloads import NETWORKS, PAPER_BANDS

MACRO = MacroConfig()


def _all() -> Dict[str, Dict[str, NetworkCost]]:
    return {name: compare_networks(name, layers, MACRO)
            for name, layers in NETWORKS.items()}


def fig7a(results=None, quiet=False) -> Dict[str, float]:
    """TM utilization of WS ConvDK per model (percent)."""
    results = results or _all()
    out = {}
    if not quiet:
        print("\n== Fig 7(a): TM utilization, WS ConvDK (percent) ==")
        print(f"{'model':24s} {'ours':>8s} {'paper':>8s}")
    for name, flows in results.items():
        util = flows["ws_convdk"].mean_tm_utilization() * 100
        out[name] = util
        if not quiet:
            print(f"{name:24s} {util:8.2f} {PAPER_BANDS['utilization'][name]:8.2f}")
    return out


def fig7b(results=None, quiet=False) -> Dict[str, Dict[str, float]]:
    """DRAM traffic normalized to WS baseline (should be ~1.0 everywhere)."""
    results = results or _all()
    out = {}
    if not quiet:
        print("\n== Fig 7(b): DRAM traffic normalized to WS baseline ==")
    for name, flows in results.items():
        base = flows["ws_base"].dram_words
        out[name] = {df: flows[df].dram_words / base for df in DATAFLOWS}
        if not quiet:
            row = " ".join(f"{df}={v:.3f}" for df, v in out[name].items())
            print(f"{name:24s} {row}")
    return out


def fig7c(results=None, quiet=False) -> Dict[str, Dict[str, float]]:
    """Buffer traffic (words) reduction vs the matching baseline (percent)."""
    results = results or _all()
    out = {}
    if not quiet:
        print("\n== Fig 7(c): buffer-traffic reduction vs baseline (percent) ==")
        print(f"{'model':24s} {'WS ConvDK':>10s} {'IS ConvDK':>10s}")
    for name, flows in results.items():
        ws = reduction(flows["ws_base"].buffer_words,
                       flows["ws_convdk"].buffer_words)
        is_ = reduction(flows["is_base"].buffer_words,
                        flows["is_convdk"].buffer_words)
        out[name] = {"ws": ws, "is": is_}
        if not quiet:
            print(f"{name:24s} {ws:10.1f} {is_:10.1f}")
    if not quiet:
        lo, hi = PAPER_BANDS["buffer_traffic_reduction_ws"]
        print(f"{'paper band (WS)':24s} {lo:.1f} .. {hi:.1f}")
    return out


def fig7d(results=None, quiet=False) -> Dict[str, Dict[str, float]]:
    """Traffic-energy reductions: buffer-only and total (incl. DRAM)."""
    results = results or _all()
    out = {}
    if not quiet:
        print("\n== Fig 7(d): traffic-energy reduction (percent) ==")
        print(f"{'model':24s} {'WS buf':>8s} {'WS tot':>8s} {'IS buf':>8s} {'IS tot':>8s}")
    for name, flows in results.items():
        e = {df: flows[df].energy_pj(MACRO) for df in DATAFLOWS}

        def _buf(df):
            # input-side buffer streams (IB + WB ports) + tile write energy;
            # OB words are identical across dataflows (module note 4 in
            # repro.core.perfmodel) and enter the total only.
            d = e[df]
            words = flows[df].buffer_words
            return words * 8 * MACRO.e_buffer_pj + d["tm"] + d["trf"]

        ws_buf = reduction(_buf("ws_base"), _buf("ws_convdk"))
        ws_tot = reduction(e["ws_base"]["total"], e["ws_convdk"]["total"])
        is_buf = reduction(_buf("is_base"), _buf("is_convdk"))
        is_tot = reduction(e["is_base"]["total"], e["is_convdk"]["total"])
        out[name] = {"ws_buffer": ws_buf, "ws_total": ws_tot,
                     "is_buffer": is_buf, "is_total": is_tot}
        if not quiet:
            print(f"{name:24s} {ws_buf:8.1f} {ws_tot:8.1f} {is_buf:8.1f} {is_tot:8.1f}")
    return out


def fig7e(results=None, quiet=False) -> Dict[str, Dict[str, float]]:
    """Total latency reduction vs the matching baseline (percent)."""
    results = results or _all()
    out = {}
    if not quiet:
        print("\n== Fig 7(e): total-latency reduction vs baseline (percent) ==")
        print(f"{'model':24s} {'WS':>8s} {'IS':>8s} {'base buf share %':>18s}")
    for name, flows in results.items():
        ws = reduction(flows["ws_base"].total_clks, flows["ws_convdk"].total_clks)
        is_ = reduction(flows["is_base"].total_clks, flows["is_convdk"].total_clks)
        share = 100 * flows["ws_base"].buffer_clks / flows["ws_base"].total_clks
        out[name] = {"ws": ws, "is": is_, "ws_base_buffer_share": share}
        if not quiet:
            print(f"{name:24s} {ws:8.1f} {is_:8.1f} {share:18.1f}")
    return out


def fig8(results=None, quiet=False) -> Dict[str, Dict[str, float]]:
    """Buffer-traffic latency breakdown + reduction (Fig. 8)."""
    results = results or _all()
    out = {}
    if not quiet:
        print("\n== Fig 8: buffer-traffic latency reduction (percent) ==")
        print(f"{'model':24s} {'WS':>8s} {'IS':>8s} {'compute WS':>12s}")
    for name, flows in results.items():
        ws = reduction(flows["ws_base"].buffer_clks, flows["ws_convdk"].buffer_clks)
        is_ = reduction(flows["is_base"].buffer_clks, flows["is_convdk"].buffer_clks)
        comp = reduction(flows["ws_base"].compute_clks, flows["ws_convdk"].compute_clks)
        out[name] = {"ws": ws, "is": is_, "compute_ws": comp}
        if not quiet:
            print(f"{name:24s} {ws:8.1f} {is_:8.1f} {comp:12.1f}")
    return out


def run_all(quiet=False):
    results = _all()
    return {
        "fig7a": fig7a(results, quiet),
        "fig7b": fig7b(results, quiet),
        "fig7c": fig7c(results, quiet),
        "fig7d": fig7d(results, quiet),
        "fig7e": fig7e(results, quiet),
        "fig8": fig8(results, quiet),
    }


if __name__ == "__main__":
    run_all()
